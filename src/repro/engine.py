"""Program-once / execute-many analog MVM engine (the public API).

The paper's energy win comes from writing the RRAM conductance image *once*
and amortizing it over many analog MVMs.  :class:`AnalogEngine` makes that the
API: ``engine.program(a)`` pays the write cost and returns an
:class:`AnalogMatrix` handle (the encoded per-tile image ``A_tilde``, the
tier-1 correction operand ``dA = A - A_tilde``, and the one-time
:class:`~repro.core.write_verify.WriteStats`); ``engine.mvm(A, x)`` (or simply
``A @ x``) then runs tier-1 error correction + tier-2 denoising without any
re-programming, for ``x`` of shape ``(n,)`` or ``(n, batch)``.

One ``execution=`` switch selects where the programmed image lives:

  * ``"local"``       -- dense per-capacity-block tiles on this process;
  * ``"streamed"``    -- programming consumes a ``block_fn(i, j)`` producer so
                         the source matrix never materializes (the paper's
                         65,025^2 case); the encoded tiles are kept;
  * ``"distributed"`` -- the image is placed once, block-sharded over a JAX
                         device mesh.  ``program`` accepts a dense array
                         (sharded via :func:`repro.core.distributed.shard_matrix`)
                         OR a traceable ``block_fn(i, j)`` producer, in which
                         case each device derives its window of the global
                         block grid from its mesh coordinates and scan-programs
                         only its local blocks -- the global matrix is never
                         materialized on any host or device.  MVMs run tier-1
                         locally, psum partials over the contraction axis and
                         denoise on-node; the output stays row-sharded.

Placement x pipeline matrix (which combinations fuse, which fall back)::

    execution     source      backend=reference          backend=pallas
    ------------  ----------  -------------------------  ------------------------
    local         dense a     vmapped block pipeline     fused rram_ec_matmul
                                                         (one whole-image kernel)
    streamed      traceable   ONE lax.scan dispatch per  same scan, tile step =
                  block_fn    program / MVM              rram_ec_tile_mvm kernel
    streamed      opaque      host loop, one jitted      host loop, kernel tile
                  block_fn    dispatch per block         step per block
    distributed   dense a     shard_map over the shared  shard_map'd kernel tile
                              local_dense_mvm stage      step (capability probe)
    distributed   traceable   shard_map'd scan pipeline  shard_map'd scan with
                  block_fn    per device, ONE dispatch,  the kernel tile step
                              psum partials              (capability probe)
    distributed   opaque      rejected (cannot trace inside shard_map; use
                  block_fn    execution="streamed" for the host-loop fallback)

Every cell of the matrix also executes TRANSPOSED: ``A.T @ y`` (=
:meth:`AnalogEngine.rmvm`, via the zero-copy :class:`TransposedAnalogMatrix`
view) runs the corrected ``A^T y`` against the SAME programmed image --
tier-1 ``A_tilde^T y + dA^T y_tilde`` from the stored operands, row blocks
as the contraction (psum over the mesh ROW axes under distributed execution,
output COLUMN-sharded), tier-2 denoise over the column output, the same
per-block k_x key halves as a forward call (a 1x1 mesh stays draw-identical
to streamed in both directions), and ``resident=False`` handles re-encode
inside the transposed scan exactly as they do forward (no A-sized array in
either direction).  The pallas tile step reads the same fused kernel in the
``y^T A`` direction (:func:`repro.kernels.ops.rram_ec_tile_rmvm`); see
DESIGN.md section 5.

``backend="pallas"`` under ``execution="distributed"`` is gated by
:func:`repro.core.distributed.pallas_shard_map_supported`, a compile-only
probe run once per (backend, mesh shape): where the kernel cannot lower
inside shard_map the engine warns and falls back to the reference tile step
in the same scan pipeline -- identical numerics, only the kernel fusion is
lost.  Producer-driven distributed programming requires the block grid to
divide evenly over the mesh (``mb % R == 0``, ``nb % C == 0``; row/column
sizes must be capacity multiples on axes split more than one way).

``program(block_fn, ..., resident=False)`` (distributed only) keeps NO
conductance image resident: every MVM re-encodes each block inside the scan
body (draws identical to program-then-execute), so no device ever holds more
than O(one capacity block) of A -- the paper's >= 65,536^2 solves run with
zero A-sized allocations anywhere in the program (write energy is still
billed once, as the physical hardware would).

Traceable block producers (streamed execution)
----------------------------------------------

A streamed producer is *traceable* when ``block_fn(i, j)`` is a pure jax
function of the two block-index scalars: it must accept traced int32 scalars
(so only jax ops on ``i``/``j`` -- array indexing, ``jax.random.fold_in``,
arithmetic -- no ``int(i)``, host I/O, or Python control flow on the values)
and return a fixed-shape capacity-sized block.  Every procedurally generated
paper workload (e.g. :class:`repro.core.matrices.ImplicitBandedMatrix`)
qualifies.  For traceable producers the engine fuses the whole mb x nb block
sweep into single ``lax.scan`` pipelines: ``program`` is one device dispatch,
and every ``mvm`` -- input-DAC encode, per-block dA re-derivation, tier-1 EC
(the Pallas ``rram_ec_matmul`` tile step under ``backend="pallas"``), fp32
row accumulation and tier-2 denoise -- is ONE dispatch instead of mb * nb.
Solvers driving a streamed handle therefore trace into one compiled program
end-to-end.

Traceability is auto-detected with an abstract trace at ``program`` time; set
a ``block_fn.traceable = False`` attribute to force the compatibility host
loop (one jitted dispatch per block -- the pre-scan behavior), which is also
what opaque producers (ones that fail the abstract trace) fall back to.

and a ``backend=`` switch dispatches the inner product:

  * ``"reference"`` -- pure-jnp blockwise oracle (always available);
  * ``"pallas"``    -- the fused TPU kernel :func:`repro.kernels.rram_ec_matmul`
                       plus the tier-2 stencil/Thomas kernels (interpret mode
                       on CPU).

Usage::

    import jax, jax.numpy as jnp
    from repro.core import CrossbarConfig, MCAGeometry, get_device
    from repro.engine import AnalogEngine

    cfg = CrossbarConfig(device=get_device("taox-hfox"),
                         geom=MCAGeometry(1, 1, 66, 66), k_iters=5, ec=True)
    engine = AnalogEngine(cfg)
    A = engine.program(a, jax.random.PRNGKey(1))   # one-time write
    print(A.write_stats.energy_j)                  # programming cost, paid once
    y1 = A @ x1                                    # corrected MVMs: no encode
    y2 = A @ x2                                    #   work, only the x DAC pass
    y, call_stats = engine.mvm_with_stats(A, x3)   # per-call input-write cost

The legacy one-shot entry points (``corrected_mvm``,
``streamed_corrected_mvm``, ``distributed_corrected_mvm``) remain as thin
deprecation shims over the same two-stage dataflow.

Solver entry points
-------------------

:mod:`repro.solvers` builds iterative linear solves on top of this engine --
the workload the program-once model exists for (MELISO+ is an in-memory
linear SOlver).  Every method touches the programmed image only through
``engine.mvm``, so it works across all execution modes and backends::

    from repro import solvers
    A = engine.program(a, key)                  # one-time write
    solvers.cg(A, b, tol=1e-4)                  # SPD Krylov solve
    solvers.richardson(A, b)                    # auto-omega stationary solve
    solvers.gmres(A, b); solvers.bicgstab(A, b) # general matrices
    solvers.refine(A, b)                        # analog inner + digital outer
    solvers.pdhg(A, b, c)                       # LP: min c'x, Ax=b, x>=0
                                                #   (matvec + rmatvec per iter)

Each returns a :class:`~repro.solvers.SolveResult` whose ledger splits energy
into this handle's one-time ``write_stats`` and the accumulated per-MVM
``input_write_stats`` -- the amortization curve of Figs. 4-5.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import crossbar
from repro.core.crossbar import CrossbarConfig
from repro.core.error_correction import denoise_least_square
from repro.core.write_verify import WriteStats

__all__ = ["AnalogEngine", "AnalogMatrix", "TransposedAnalogMatrix",
           "EXECUTION_MODES", "BACKENDS"]

EXECUTION_MODES = ("local", "streamed", "distributed")
BACKENDS = ("reference", "pallas")


@dataclasses.dataclass
class AnalogMatrix:
    """Handle to a matrix programmed onto the (simulated) analog hardware.

    Holds the per-tile conductance image and tier-1 correction operand in the
    layout of its engine's execution mode, the one-time programming
    :class:`WriteStats`, and the base PRNG key whose per-block ``k_x`` halves
    drive the input DAC noise of successive executions.
    """

    engine: "AnalogEngine"
    shape: Tuple[int, int]
    base_key: jax.Array
    write_stats: WriteStats
    # local / streamed layout: (mb, nb, cap_m, cap_n) stacked capacity tiles.
    at_blocks: Optional[jnp.ndarray] = None
    da_blocks: Optional[jnp.ndarray] = None
    # streamed layout keeps the producer instead of materializing da_blocks,
    # so the resident state is exactly the programmed image (1x, not 2x).
    block_fn: Optional[Callable[[int, int], jnp.ndarray]] = None
    # whether block_fn traced as a pure jax function of the index scalars
    # (scan-fused single-dispatch pipelines) or needs the host loop.
    block_traceable: bool = False
    # distributed dense layout: (m, n) arrays block-sharded over the mesh.
    at_dense: Optional[jnp.ndarray] = None
    da_dense: Optional[jnp.ndarray] = None
    # producer-driven distributed layout: at_blocks is the global (mb, nb,
    # cap_m, cap_n) block array sharded over the mesh (None for
    # resident=False handles, which re-encode inside every MVM's scan).
    mesh_sharded: bool = False
    # device-lifetime state (repro.reliability): when an AgeLedger is
    # attached (reliability.aging.attach_age), every execute applies the aged
    # image -- drift + replayable stuck-at faults -- inside the SAME jitted
    # dispatch, and host-side executes advance the per-block MVM count.
    age: Optional["object"] = None
    calls: int = 0
    # cached dense padded layout for the pallas backend (built on first use).
    _padded: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
    # per-handle jitted scan pipelines keyed by use_kernel (built on first
    # execute; dies with the handle -- see the jit-scoping note below).
    _scan_exec: Optional[dict] = None

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    def _grid(self) -> Tuple[int, int]:
        """(mb, nb) capacity-block grid of this handle."""
        if self.at_blocks is not None:
            return self.at_blocks.shape[:2]
        cap_m, cap_n = self.engine.cfg.geom.capacity
        return -(-self.m // cap_m), -(-self.n // cap_n)

    @property
    def a_tilde(self) -> jnp.ndarray:
        """The programmed conductance image, dense and unpadded (m, n).

        An explicitly materializing view: for non-resident (``resident=False``)
        distributed handles it re-derives the image with one scanned sweep.
        """
        if self.at_dense is not None:
            return self.at_dense
        if self.at_blocks is not None:
            return _assemble(self.at_blocks, self.m, self.n)
        mb, nb = self._grid()
        at = jax.jit(functools.partial(
            crossbar.streamed_program_blocks, self.block_fn,
            cfg=self.engine.cfg, mb=mb, nb=nb))(self.base_key)
        return _assemble(at, self.m, self.n)

    @property
    def da(self) -> jnp.ndarray:
        """The tier-1 correction operand A - A_tilde, dense unpadded (m, n)."""
        if self.da_dense is not None:
            return self.da_dense
        if self.da_blocks is not None:
            return _assemble(self.da_blocks, self.m, self.n)
        if self.at_blocks is not None:
            return _assemble(self._producer_blocks() - self.at_blocks,
                             self.m, self.n)
        return self.dense() - self.a_tilde

    def dense(self) -> jnp.ndarray:
        """The exact source matrix A = A_tilde + dA, dense unpadded (m, n).

        For streamed handles this skips the A_tilde/dA round trip entirely:
        A_tilde + (block - A_tilde) == block, so one producer sweep suffices.
        """
        if self.at_dense is not None:
            return self.at_dense + self.da_dense
        if self.da_blocks is not None:
            return _assemble(self.at_blocks + self.da_blocks, self.m, self.n)
        return _assemble(self._producer_blocks(), self.m, self.n)

    def _producer_blocks(self) -> jnp.ndarray:
        """All producer blocks, (mb, nb, cap_m, cap_n): one scanned dispatch
        for traceable producers, a host loop for opaque ones."""
        mb, nb = self._grid()
        if self.block_traceable:
            return jax.jit(functools.partial(
                crossbar.produce_blocks, self.block_fn, mb, nb))()
        return jnp.stack([jnp.stack([self.block_fn(i, j) for j in range(nb)])
                          for i in range(mb)])

    def __matmul__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.engine.mvm(self, x)

    @property
    def T(self) -> "TransposedAnalogMatrix":
        """Zero-copy transposed view: ``A.T @ y`` runs the corrected
        TRANSPOSED MVM ``A^T y`` against the SAME programmed image (no
        re-encode, no second handle -- the crossbar is read backwards)."""
        return TransposedAnalogMatrix(self)

    def input_write_stats(self, batch: int = 1) -> WriteStats:
        """Per-execution write cost (x DAC pass + EC X^T replica)."""
        return self.engine.input_write_stats(self, batch)

    @property
    def image_nbytes(self) -> int:
        """Resident bytes of this handle's programmed operands.

        Counts the stored image/correction layout (blocks or dense) plus any
        derived caches built by executions (padded pallas layout); block_fn
        producers are code, not residency, and count zero.  This is the unit
        the serving :class:`~repro.serving.cache.ImageCache` budgets in."""
        total = 0
        for arr in (self.at_blocks, self.da_blocks, self.at_dense,
                    self.da_dense):
            if arr is not None and hasattr(arr, "nbytes"):
                total += int(arr.nbytes)
        if self._padded is not None:
            total += sum(int(p.nbytes) for p in self._padded
                         if hasattr(p, "nbytes"))
        return total

    def release(self) -> int:
        """Drop derived execution caches (padded layout, jitted scan
        pipelines), returning the bytes freed.  The programmed image itself
        survives -- eviction of the image is the cache owner dropping its
        reference to the whole handle; ``release`` is the cheaper lever for
        staying under budget without paying a reprogram."""
        freed = 0
        if self._padded is not None:
            freed = sum(int(p.nbytes) for p in self._padded
                        if hasattr(p, "nbytes"))
            self._padded = None
        self._scan_exec = None
        return freed


@dataclasses.dataclass(frozen=True)
class TransposedAnalogMatrix:
    """Transposed view of an :class:`AnalogMatrix` (``A.T``).

    Holds NO operands of its own: every execution reads the parent's
    programmed conductance image in the transposed direction through
    :meth:`AnalogEngine.rmvm` (tier-1 ``A_tilde^T y + dA^T y_tilde``,
    row-block partials summed, tier-2 denoise over the column output), so the
    one-time write cost is shared with the forward view and a PDHG-style
    solver alternating ``A @ x`` / ``A.T @ y`` programs the matrix exactly
    once.  ``A.T.T is A``.
    """

    parent: AnalogMatrix

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.parent.shape[1], self.parent.shape[0])

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def T(self) -> AnalogMatrix:
        return self.parent

    @property
    def engine(self) -> "AnalogEngine":
        return self.parent.engine

    @property
    def write_stats(self) -> WriteStats:
        """The parent's one-time programming cost (shared, never re-paid)."""
        return self.parent.write_stats

    def __matmul__(self, y: jnp.ndarray) -> jnp.ndarray:
        return self.parent.engine.rmvm(self.parent, y)

    def dense(self) -> jnp.ndarray:
        """The exact transposed source matrix A^T, dense unpadded (n, m)."""
        return self.parent.dense().T

    def input_write_stats(self, batch: int = 1) -> WriteStats:
        """Per-execution cost of one transposed MVM (y DAC pass + EC Y^T
        replica over the row dimension)."""
        return self.parent.engine.input_write_stats(self.parent, batch,
                                                    transpose=True)


_assemble = crossbar.assemble_blocks


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_reference(at_blocks, da_blocks, xb, key, *, cfg, m, n):
    return crossbar.programmed_block_mvm(
        at_blocks, da_blocks, xb, key, cfg, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_reference_t(at_blocks, da_blocks, yb, key, *, cfg, m, n):
    return crossbar.programmed_block_rmvm(
        at_blocks, da_blocks, yb, key, cfg, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n", "transpose"))
def _exec_reference_aged(at_blocks, da_blocks, xb, key, age, *, cfg, m, n,
                         transpose):
    """Aged execute: ONE dispatch containing the aging transform AND the
    corrected MVM.  The physical image drifts / latches
    (:func:`repro.reliability.aging.aged_blocks`) while the stored tier-1
    operand ``dA`` stays as measured at program time, so the corrected
    product honestly degrades with age instead of silently self-correcting.
    """
    from repro.reliability.aging import aged_blocks
    at_aged = aged_blocks(at_blocks, age, cfg.device)
    run = crossbar.programmed_block_rmvm if transpose \
        else crossbar.programmed_block_mvm
    return run(at_aged, da_blocks, xb, key, cfg, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_pallas(at, da, xb, key, *, cfg, m, n):
    """Tier-1 via the fused Pallas EC kernel + tier-2 via the solver kernels.

    ``at``/``da`` are the dense *padded* operands (assembled once at first use
    and cached on the handle).  The kernel path encodes x with a single DAC
    pass (one noise draw for the whole padded vector) instead of the reference
    path's per-(block, chunk) draws -- statistically identical, one kernel
    launch.
    """
    from repro.kernels import ops as kops

    x_pad = jnp.pad(xb, ((0, at.shape[1] - xb.shape[0]), (0, 0)))
    if cfg.encode_inputs:
        x_t = crossbar._encode_vec(x_pad, jax.random.fold_in(key, 1), cfg)
    else:
        x_t = x_pad
    if cfg.ec:
        # y^T = x^T A_tilde^T + x_tilde^T dA^T, one fused kernel call.
        p = kops.rram_ec_matmul(x_pad.T, x_t.T, at.T, da.T).T[:m]
    else:
        p = (at @ x_t)[:m]
    if cfg.ec:
        if cfg.denoise_method == "neumann":
            p = kops.denoise_stencil(p, lam=cfg.lam, h=cfg.h)
        elif cfg.denoise_method == "thomas":
            p = kops.denoise_thomas(p, lam=cfg.lam, h=cfg.h)
        else:
            p = denoise_least_square(p, lam=cfg.lam, h=cfg.h,
                                     method=cfg.denoise_method)
    return p


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_pallas_t(at, da, yb, key, *, cfg, m, n):
    """Transposed tier-1 via the same fused Pallas EC kernel read backwards.

    ``at``/``da`` are the dense padded operands shared with the forward path
    (one cache on the handle serves both directions).  The kernel computes
    ``z^T = y^T A_tilde + y_tilde^T dA`` in one call; the y DAC pass uses a
    single whole-vector draw (fold 2 of the call key, keeping it distinct
    from the forward path's fold 1 when a caller reuses a key across
    directions) -- statistically identical to the per-block reference draws.
    """
    from repro.kernels import ops as kops

    y_pad = jnp.pad(yb, ((0, at.shape[0] - yb.shape[0]), (0, 0)))
    if cfg.encode_inputs:
        y_t = crossbar._encode_vec(y_pad, jax.random.fold_in(key, 2), cfg)
    else:
        y_t = y_pad
    if cfg.ec:
        p = kops.rram_ec_matmul(y_pad.T, y_t.T, at, da).T[:n]
    else:
        p = (at.T @ y_t)[:n]
    if cfg.ec:
        if cfg.denoise_method == "neumann":
            p = kops.denoise_stencil(p, lam=cfg.lam, h=cfg.h)
        elif cfg.denoise_method == "thomas":
            p = kops.denoise_thomas(p, lam=cfg.lam, h=cfg.h)
        else:
            p = denoise_least_square(p, lam=cfg.lam, h=cfg.h,
                                     method=cfg.denoise_method)
    return p


# Scan-fused streamed pipelines: the pure stages live in
# :mod:`repro.core.crossbar` (streamed_program_blocks / streamed_block_mvm /
# produce_blocks); jit scoping is deliberate.  Program-time and da/dense
# sweeps use locally-scoped jits (one trace per call, garbage-collected with
# it); the execute-many hot path caches its jitted pipeline ON THE HANDLE
# (:attr:`AnalogMatrix._scan_exec`), so a warm streamed MVM re-invokes the
# producer zero times yet the trace -- and the producer closure it pins --
# dies with the handle instead of accumulating in a process-wide cache.


class AnalogEngine:
    """Program-once / execute-many corrected-MVM engine.

    Parameters
    ----------
    cfg:
        The :class:`CrossbarConfig` describing one multi-MCA system (for
        ``execution="distributed"``: the per-device system).
    execution:
        ``"local"`` | ``"streamed"`` | ``"distributed"``.
    backend:
        ``"reference"`` (pure jnp) | ``"pallas"`` (fused TPU kernels; interpret
        mode on CPU).  Under ``execution="distributed"`` the Pallas tile step
        runs inside ``shard_map`` where the capability probe
        (:func:`repro.core.distributed.pallas_shard_map_supported`) confirms
        it lowers; otherwise the engine warns once and falls back to the
        reference tile step (identical numerics).
    mesh, row_axes, col_axis:
        Mesh placement for ``execution="distributed"``: rows shard over
        ``row_axes``, the contraction over ``col_axis``.
    """

    def __init__(
        self,
        cfg: CrossbarConfig,
        *,
        execution: str = "local",
        backend: str = "reference",
        mesh=None,
        row_axes: Tuple[str, ...] = ("data",),
        col_axis: str = "model",
    ):
        if execution not in EXECUTION_MODES:
            raise ValueError(
                f"unknown execution mode {execution!r}; expected one of "
                f"{EXECUTION_MODES}")
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of {BACKENDS}")
        if execution == "distributed" and mesh is None:
            raise ValueError("execution='distributed' requires a mesh")
        self.cfg = cfg
        self.execution = execution
        self.backend = backend
        self.mesh = mesh
        self.row_axes = tuple(row_axes)
        self.col_axis = col_axis
        self._streamed_step = {}        # jitted per-block host-loop steps,
                                        # keyed (use_kernel, transpose)
        if execution == "distributed":
            from repro.core import distributed as D
            self._dist_program = jax.jit(D.make_distributed_program(
                cfg, mesh, self.row_axes, col_axis))
            self._dist_mvm = jax.jit(D.make_distributed_programmed_mvm(
                cfg, mesh, self.row_axes, col_axis))
            # dense execute pipelines keyed by (use_kernel, transpose)
            # (pallas / transposed variants built lazily, the former behind
            # the shard_map capability probe).
            self._dist_mvm_cache = {(False, False): self._dist_mvm}

    def _dist_use_kernel(self) -> bool:
        """Whether distributed execution may fuse the Pallas tile step."""
        if self.backend != "pallas" or not self.cfg.ec:
            return False
        from repro.core import distributed as D
        return D.pallas_shard_map_supported(self.mesh)

    def _dense_dist_exec(self, transpose: bool = False):
        """The jitted shard_map'd dense execute stage for this backend
        (forward or transposed)."""
        use_kernel = self._dist_use_kernel()
        fn = self._dist_mvm_cache.get((use_kernel, transpose))
        if fn is None:
            from repro.core import distributed as D
            make = D.make_distributed_rmvm if transpose else \
                D.make_distributed_programmed_mvm
            fn = jax.jit(make(
                self.cfg, self.mesh, self.row_axes, self.col_axis,
                use_kernel=use_kernel))
            self._dist_mvm_cache[(use_kernel, transpose)] = fn
        return fn

    # ------------------------------------------------------------- programming
    def program(
        self,
        a: Union[jnp.ndarray, Callable[[int, int], jnp.ndarray]],
        key: jax.Array,
        *,
        shape: Optional[Tuple[int, int]] = None,
        resident: bool = True,
    ) -> AnalogMatrix:
        """Write ``a`` onto the analog system once; returns the reusable handle.

        ``a`` is a dense (m, n) array, or -- for ``execution="streamed"`` and
        ``execution="distributed"`` -- a ``block_fn(i, j)`` producer of
        capacity-sized (already padded) blocks, in which case ``shape=(m, n)``
        gives the logical problem size.  Producers that trace as pure jax
        functions of the index scalars (see the module docstring) are
        programmed and executed as single-dispatch ``lax.scan`` pipelines
        (mesh-sharded windows of the global block grid under distributed
        execution); opaque producers take a host loop per block (streamed
        only -- distributed execution rejects them).

        ``resident=False`` (distributed producers only) keeps no conductance
        image: each MVM re-encodes blocks inside its scan with the identical
        draws, so no device ever allocates more than one capacity block of A.
        """
        if callable(a) and not hasattr(a, "shape"):
            if self.execution not in ("streamed", "distributed"):
                raise ValueError("a block_fn producer requires "
                                 "execution='streamed' or 'distributed'")
            if shape is None:
                raise ValueError("program(block_fn, ...) requires shape=(m, n)")
            if self.execution == "distributed":
                return self._program_distributed_streamed(
                    a, shape, key, resident)
            if not resident:
                raise ValueError("resident=False requires "
                                 "execution='distributed' (streamed handles "
                                 "keep the programmed image)")
            return self._program_streamed(a, shape, key)
        if not resident:
            raise ValueError(
                "resident=False requires a block_fn producer under "
                "execution='distributed'")
        m, n = a.shape
        if self.execution == "distributed":
            return self._program_distributed(a, key)
        at_blocks, da_blocks = crossbar.program_blocks(a, key, self.cfg)
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key,
            write_stats=crossbar.matrix_write_cost(m, n, self.cfg),
            at_blocks=at_blocks, da_blocks=da_blocks)

    def _program_streamed(self, block_fn, shape, key) -> AnalogMatrix:
        m, n = shape
        cap_m, cap_n = self.cfg.geom.capacity
        mb, nb = -(-m // cap_m), -(-n // cap_n)
        traceable = crossbar.producer_is_traceable(block_fn, cap_m, cap_n)
        if traceable:
            # One scanned dispatch programs every capacity block (local jit:
            # programming runs once per handle, no process-wide cache entry).
            at_blocks = jax.jit(functools.partial(
                crossbar.streamed_program_blocks, block_fn,
                cfg=self.cfg, mb=mb, nb=nb))(key)
        else:
            # Compatibility host loop: one jitted dispatch per block.
            keys = crossbar.block_keys(key, mb, nb)

            def enc(blk, k):
                k_a, _ = jax.random.split(k)
                return crossbar.encode_tiled(blk, k_a, self.cfg)

            step = jax.jit(enc)
            at_blocks = jnp.stack(
                [jnp.stack([step(block_fn(i, j), keys[i, j])
                            for j in range(nb)])
                 for i in range(mb)])
        # Only the programmed image is kept resident (the simulated hardware
        # state); the tier-1 operand dA is re-derived per block at execute
        # time from the producer, so huge matrices are never held twice.
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key,
            write_stats=crossbar.matrix_write_cost(m, n, self.cfg),
            at_blocks=at_blocks, block_fn=block_fn,
            block_traceable=traceable)

    def _program_distributed(self, a, key) -> AnalogMatrix:
        from repro.core import distributed as D
        m, n = a.shape
        row_spec = self.row_axes if len(self.row_axes) > 1 else self.row_axes[0]
        a_sh = D.shard_matrix(a, self.mesh, row_spec, self.col_axis)
        at, da, stats = self._dist_program(a_sh, key)
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key, write_stats=stats,
            at_dense=at, da_dense=da, mesh_sharded=True)

    def _program_distributed_streamed(self, block_fn, shape, key,
                                      resident) -> AnalogMatrix:
        """Producer-driven distributed programming: each device scan-programs
        its window of the global block grid; A never materializes anywhere."""
        from repro.core import distributed as D
        m, n = shape
        cap_m, cap_n = self.cfg.geom.capacity
        mb, nb = -(-m // cap_m), -(-n // cap_n)
        if not crossbar.producer_is_traceable(block_fn, cap_m, cap_n):
            raise ValueError(
                "execution='distributed' requires a traceable block_fn "
                "producer (a pure jax function of the two index scalars): "
                "opaque producers cannot run inside shard_map -- use "
                "execution='streamed' for the host-loop fallback")
        n_row, n_col = D.mesh_grid_shape(self.mesh, self.row_axes,
                                         self.col_axis)
        if mb % n_row or nb % n_col:
            raise ValueError(
                f"the {mb} x {nb} capacity-block grid does not divide over "
                f"the {n_row} x {n_col} mesh; pick a capacity/mesh so every "
                "device owns an equal block window")
        if n_row > 1 and m != mb * cap_m:
            raise ValueError(
                f"m={m} must be a multiple of the capacity row size {cap_m} "
                "to row-shard a producer grid (produce padded blocks and "
                "declare the padded shape)")
        if n_col > 1 and n != nb * cap_n:
            raise ValueError(
                f"n={n} must be a multiple of the capacity column size "
                f"{cap_n} to column-shard a producer grid")
        at_blocks = None
        if resident:
            # ONE jitted dispatch programs every device's block window.
            prog = jax.jit(D.make_distributed_streamed_program(
                block_fn, self.cfg, self.mesh, self.row_axes, self.col_axis,
                mb=mb, nb=nb))
            at_blocks = prog(key)
        # Per-device footprint; mean across the uniform shards == per-device
        # value (the Figs. 4-5 reporting convention).
        m_loc = m if n_row == 1 else (mb // n_row) * cap_m
        n_loc = n if n_col == 1 else (nb // n_col) * cap_n
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key,
            write_stats=crossbar.matrix_write_cost(m_loc, n_loc, self.cfg),
            at_blocks=at_blocks, block_fn=block_fn, block_traceable=True,
            mesh_sharded=True)

    def encode_dense(self, a: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """The programmed image of ``a`` as a dense unpadded array.

        Pure jax function of (a, key): safe under jit/vmap (used by
        :func:`repro.models.rram.program_rram` for stacked layer kernels).
        """
        at_blocks, _ = crossbar.program_blocks(a, key, self.cfg)
        return _assemble(at_blocks, *a.shape)

    # --------------------------------------------------------------- execution
    def mvm(self, A: AnalogMatrix, x: jnp.ndarray, *,
            key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Corrected MVM against the programmed image: zero re-encode work.

        ``x``: (n,) or (n, batch).  ``key`` overrides the input-DAC noise key;
        by default successive calls consume fresh folds of the handle's base
        key (call 0 reproduces the legacy one-shot draws exactly).
        """
        y, _ = self._execute(A, x, key)
        return y

    def mvm_with_stats(self, A: AnalogMatrix, x: jnp.ndarray, *,
                       key: Optional[jax.Array] = None
                       ) -> Tuple[jnp.ndarray, WriteStats]:
        """Like :meth:`mvm` but also returns this call's input-write cost."""
        return self._execute(A, x, key, with_stats=True)

    def rmvm(self, A: AnalogMatrix, y: jnp.ndarray, *,
             key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Corrected TRANSPOSED MVM ``A.T @ y`` against the programmed image.

        ``y``: (m,) or (m, batch); returns (n,) / (n, batch).  Reads the SAME
        conductance image as :meth:`mvm` -- zero re-encode, zero extra
        programming cost; only the y vector passes through the DAC (per
        row-block chunk, consuming the identical per-block k_x key halves a
        forward call would) and tier-2 denoising runs over the column output.
        Under ``execution="distributed"`` the row shards are the contraction
        axis: partials psum over the ROW axes and the output comes back
        COLUMN-sharded (over ``col_axis``).  ``A.T @ y`` is the operator
        form; :class:`TransposedAnalogMatrix` documents the view.
        """
        z, _ = self._execute(A, y, key, transpose=True)
        return z

    def rmvm_with_stats(self, A: AnalogMatrix, y: jnp.ndarray, *,
                        key: Optional[jax.Array] = None
                        ) -> Tuple[jnp.ndarray, WriteStats]:
        """Like :meth:`rmvm` but also returns this call's input-write cost."""
        return self._execute(A, y, key, with_stats=True, transpose=True)

    # ------------------------------------------------------- analysis hooks
    def mvm_fn(self, A: AnalogMatrix, *, transpose: bool = False):
        """Traceable ``(vec, key) -> out`` closure over a programmed handle.

        The canonical pipeline surface for jaxpr-level tooling: the
        invariant registry (:mod:`repro.analysis.pipelines`) traces these
        closures with ``ShapeDtypeStruct`` placeholders, so the verifier
        passes see exactly the computation :meth:`mvm` / :meth:`rmvm`
        dispatch.  See DESIGN.md section 10.
        """
        if transpose:
            return lambda y, key: self.rmvm(A, y, key=key)
        return lambda x, key: self.mvm(A, x, key=key)

    @property
    def collective_axes(self) -> Tuple[str, ...]:
        """Mesh axes a distributed execution may legally reduce over
        (the CollectiveAudit whitelist); empty for single-device modes."""
        if self.execution != "distributed":
            return ()
        return (*self.row_axes, self.col_axis)

    def input_write_stats(self, A: AnalogMatrix, batch: int = 1,
                          *, transpose: bool = False) -> WriteStats:
        """Per-execution input-write cost, in the same reporting convention as
        the handle's ``write_stats`` (distributed: mean across devices, the
        paper's Figs. 4-5 convention).  Non-divisible mesh shapes bill the
        ceil-divided per-device footprint -- the rows/cols a real placement
        would pad onto the largest shard -- instead of silently flooring.
        ``transpose=True`` bills a transposed execution (the m-length y DAC
        pass + the row-dimension EC replica)."""
        m, n = A.shape
        if self.execution == "distributed":
            sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            for ax in self.row_axes:
                m = -(-m // sizes[ax])
            n = -(-n // sizes[self.col_axis])
        return crossbar.input_write_cost(m, n, self.cfg, batch=batch,
                                         transpose=transpose)

    def _execute(self, A, x, key, with_stats=False, transpose=False):
        if isinstance(A, TransposedAnalogMatrix):
            # A transposed view executes as the opposite direction of its
            # parent: (A.T).T @ x is a forward MVM of the parent.  The same
            # cross-engine guard as the direct path applies BEFORE
            # delegating, so a view can't smuggle a handle past it.
            if A.parent.engine is not self and A.parent.engine.cfg != self.cfg:
                raise ValueError(
                    "AnalogMatrix was programmed by an incompatible "
                    "engine configuration")
            return A.parent.engine._execute(A.parent, x, key,
                                            with_stats=with_stats,
                                            transpose=not transpose)
        if A.engine is not self and A.engine.cfg != self.cfg:
            raise ValueError("AnalogMatrix was programmed by an incompatible "
                             "engine configuration")
        if self.execution == "distributed":
            # Only handles programmed BY a distributed engine may execute
            # here: producer handles from a streamed engine skipped the
            # mesh/grid validation (mb % R, capacity multiples, traceability)
            # and would mis-shape or die opaquely inside shard_map.
            if A.at_dense is None and not (A.block_fn is not None
                                           and A.mesh_sharded):
                raise ValueError(
                    "AnalogMatrix holds block tiles but this engine executes "
                    "distributed; program it with the distributed engine")
        elif A.at_blocks is None or A.mesh_sharded:
            raise ValueError(
                "AnalogMatrix holds mesh-sharded operands but this engine "
                f"executes {self.execution!r}; program it with this engine")
        squeeze = x.ndim == 1
        xb = x[:, None] if squeeze else x
        contraction = A.m if transpose else A.n
        if xb.shape[0] != contraction:
            direction = "A.T @ y" if transpose else "A @ x"
            raise ValueError(
                f"{direction}: input has {xb.shape[0]} rows but the "
                f"programmed matrix is {A.m} x {A.n}")
        if key is None:
            # The default key schedule advances Python-side per call; under a
            # jit trace it would freeze at its trace-time value and every
            # execution would reuse identical DAC noise -- require an explicit
            # key there instead of silently correlating the draws.
            if not getattr(jax.core, "trace_state_clean", lambda: True)():
                raise ValueError(
                    "engine.mvm inside jit needs an explicit key= (the "
                    "default call-counter key schedule is host-side state)")
            key = A.base_key if A.calls == 0 else \
                jax.random.fold_in(A.base_key, A.calls)
        A.calls += 1
        m, n = A.shape
        if self.execution == "distributed":
            if A.at_dense is not None:
                p, stats = self._dense_dist_exec(transpose)(
                    A.at_dense, A.da_dense, xb, key)
            else:
                # Producer-driven: ONE shard_map'd scan dispatch, output
                # stays row-sharded (column-sharded for transposed calls);
                # per-call cost is analytic (the same ceil-divided per-device
                # mean as input_write_stats).
                p = self._exec_dist_streamed(A, xb, key, transpose)
                stats = self.input_write_stats(A, xb.shape[1],
                                               transpose=transpose) \
                    if with_stats else None
        else:
            stats = None
            if A.age is not None and A.da_blocks is not None \
                    and self.backend == "reference":
                # Aged execute: drift + stuck-at faults applied to the image
                # inside the one jitted dispatch (DESIGN.md section 12).
                p = _exec_reference_aged(A.at_blocks, A.da_blocks, xb, key,
                                         A.age, cfg=self.cfg, m=m, n=n,
                                         transpose=transpose)
                # Host-dispatched executes age the image by one read disturb
                # per call; traced executes (inside a solver's jit) advance
                # the ledger explicitly via A.age = A.age.advanced(mvms).
                if getattr(jax.core, "trace_state_clean", lambda: True)():
                    A.age = A.age.advanced(1)
            elif A.age is not None:
                raise ValueError(
                    "an AgeLedger is attached but this execution path cannot "
                    "apply it: aged execution needs execution='local', "
                    "backend='reference' and resident at/da blocks")
            elif A.da_blocks is None:
                # Streamed handle: dA is not resident; re-derive per block.
                p = self._exec_streamed(A, xb, key, transpose)
            elif self.backend == "pallas":
                if A._padded is None:
                    mb, nb, cm, cn = A.at_blocks.shape
                    padded = (_assemble(A.at_blocks, mb * cm, nb * cn),
                              _assemble(A.da_blocks, mb * cm, nb * cn))
                    # Only cache outside a trace: caching mid-trace would pin
                    # tracers on the handle and leak them into later calls
                    # (e.g. a solver's while_loop executing many MVMs).  If
                    # this jax has no trace_state_clean, skip caching -- the
                    # safe direction is recompute, never cache a maybe-tracer.
                    if getattr(jax.core, "trace_state_clean",
                               lambda: False)():
                        A._padded = padded
                else:
                    padded = A._padded
                run = _exec_pallas_t if transpose else _exec_pallas
                p = run(*padded, xb, key, cfg=self.cfg, m=m, n=n)
            else:
                run = _exec_reference_t if transpose else _exec_reference
                p = run(A.at_blocks, A.da_blocks, xb, key,
                        cfg=self.cfg, m=m, n=n)
        if with_stats and stats is None:
            stats = crossbar.input_write_cost(m, n, self.cfg,
                                              batch=xb.shape[1],
                                              transpose=transpose)
        return (p[:, 0] if squeeze else p), stats

    def _exec_streamed(self, A, xb, key, transpose=False):
        """Streamed execute: dA = block_fn - A_tilde is re-derived per
        capacity block (O(block) extra memory), so the streamed path never
        holds the source matrix twice.  Traceable producers run the
        scan-fused single-dispatch pipeline (forward or transposed); opaque
        ones take the compatibility host loop (one jitted dispatch per
        block)."""
        cfg = self.cfg
        if cfg.ec and cfg.ec_mode not in ("fused", "faithful"):
            raise ValueError(f"unknown first-order EC mode {cfg.ec_mode!r}")
        m, n = A.shape
        use_kernel = self.backend == "pallas" and cfg.ec
        if A.block_traceable:
            cache_key = (use_kernel, transpose)
            fn = (A._scan_exec or {}).get(cache_key)
            if fn is None:
                # Jitted once per handle (per backend and direction): warm
                # MVMs are cache hits with zero host-side producer work, and
                # the trace is released with the handle rather than pinned
                # process-wide.
                stage = crossbar.streamed_block_rmvm if transpose \
                    else crossbar.streamed_block_mvm
                fn = jax.jit(functools.partial(
                    stage, A.block_fn,
                    cfg=cfg, m=m, n=n, use_kernel=use_kernel))
                if A._scan_exec is None:
                    A._scan_exec = {}
                A._scan_exec[cache_key] = fn
            return fn(A.at_blocks, xb, key)
        return self._exec_streamed_host(A, xb, key, use_kernel, transpose)

    def _exec_dist_streamed(self, A, xb, key, transpose=False):
        """Producer-driven distributed execute: each device runs the
        scan-fused streamed pipeline over its window of the global block
        grid (one dispatch), partials psum over the contraction axis (the
        column axis forward, the ROW axes transposed), tier-2 denoises
        on-node, and the output stays sharded over the non-contracted axis.
        The jitted shard_map pipeline is cached on the handle per backend
        and direction, so solver loops re-enter a warm trace."""
        use_kernel = self._dist_use_kernel()
        cache_key = ("dist", use_kernel, A.at_blocks is not None, transpose)
        fn = (A._scan_exec or {}).get(cache_key)
        if fn is None:
            from repro.core import distributed as D
            m, n = A.shape
            mb, nb = A._grid()
            make = D.make_distributed_streamed_rmvm if transpose else \
                D.make_distributed_streamed_mvm
            fn = jax.jit(make(
                A.block_fn, self.cfg, self.mesh, self.row_axes, self.col_axis,
                m=m, n=n, mb=mb, nb=nb, resident=A.at_blocks is not None,
                use_kernel=use_kernel))
            if A._scan_exec is None:
                A._scan_exec = {}
            A._scan_exec[cache_key] = fn
        if A.at_blocks is not None:
            return fn(A.at_blocks, xb, key)
        return fn(xb, key)

    def _exec_streamed_host(self, A, xb, key, use_kernel, transpose=False):
        """The compat-only Python block loop (the one remaining in the repo):
        O(mb * nb) dispatches per MVM, kept for producers that cannot trace.
        Same per-block keys, draws and tile math as the scanned pipelines,
        in either direction (``transpose`` chunks the input over row blocks
        and accumulates over them -- the contraction axis of A^T)."""
        cfg = self.cfg
        m, n = A.shape
        mb, nb, cap_m, cap_n = A.at_blocks.shape
        batch = xb.shape[1]
        pad_to = mb * cap_m if transpose else nb * cap_n
        x_pad = jnp.pad(xb, ((0, pad_to - xb.shape[0]), (0, 0)))
        x_chunks = x_pad.reshape(mb if transpose else nb, -1, batch)
        keys = crossbar.block_keys(key, mb, nb)

        step = self._streamed_step.get((use_kernel, transpose))
        if step is None:
            def step(at_blk, a_blk, x_blk, k):
                _, k_x = jax.random.split(k)
                x_t = crossbar._encode_vec(x_blk, k_x, cfg) \
                    if cfg.encode_inputs else x_blk
                from repro.kernels import ops as kops
                if transpose:
                    if not cfg.ec:
                        return at_blk.T @ x_t
                    if use_kernel:
                        return kops.rram_ec_tile_rmvm(x_blk, x_t, at_blk,
                                                      a_blk - at_blk)
                    if cfg.ec_mode == "faithful":
                        return (at_blk.T @ x_blk + a_blk.T @ x_t
                                - at_blk.T @ x_t)
                    return at_blk.T @ x_blk + (a_blk - at_blk).T @ x_t
                if not cfg.ec:
                    return at_blk @ x_t
                if use_kernel:
                    return kops.rram_ec_tile_mvm(x_blk, x_t, at_blk,
                                                 a_blk - at_blk)
                if cfg.ec_mode == "faithful":
                    return at_blk @ x_blk + a_blk @ x_t - at_blk @ x_t
                return at_blk @ x_blk + (a_blk - at_blk) @ x_t

            # Jitted once per engine (per direction/backend): execute-many
            # calls reuse the trace.
            step = jax.jit(step)
            self._streamed_step[(use_kernel, transpose)] = step
        out_blocks, acc_cap = (nb, cap_n) if transpose else (mb, cap_m)
        rows = []
        for o in range(out_blocks):
            acc = jnp.zeros((acc_cap, batch), jnp.float32)
            for c in range(mb if transpose else nb):
                i, j = (c, o) if transpose else (o, c)
                acc = acc + step(A.at_blocks[i, j], A.block_fn(i, j),
                                 x_chunks[c], keys[i, j])
            rows.append(acc)
        p = jnp.concatenate(rows, axis=0)[:n if transpose else m]
        if cfg.ec:
            p = denoise_least_square(p, lam=cfg.lam, h=cfg.h,
                                     method=cfg.denoise_method)
        return p
