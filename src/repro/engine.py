"""Program-once / execute-many analog MVM engine (the public API).

The paper's energy win comes from writing the RRAM conductance image *once*
and amortizing it over many analog MVMs.  :class:`AnalogEngine` makes that the
API: ``engine.program(a)`` pays the write cost and returns an
:class:`AnalogMatrix` handle (the encoded per-tile image ``A_tilde``, the
tier-1 correction operand ``dA = A - A_tilde``, and the one-time
:class:`~repro.core.write_verify.WriteStats`); ``engine.mvm(A, x)`` (or simply
``A @ x``) then runs tier-1 error correction + tier-2 denoising without any
re-programming, for ``x`` of shape ``(n,)`` or ``(n, batch)``.

One ``execution=`` switch selects where the programmed image lives:

  * ``"local"``       -- dense per-capacity-block tiles on this process;
  * ``"streamed"``    -- programming consumes a ``block_fn(i, j)`` producer so
                         the source matrix never materializes (the paper's
                         65,025^2 case); the encoded tiles are kept;
  * ``"distributed"`` -- the image is placed once, block-sharded over a JAX
                         device mesh.  ``program`` accepts a dense array
                         (sharded via :func:`repro.core.distributed.shard_matrix`)
                         OR a traceable ``block_fn(i, j)`` producer, in which
                         case each device derives its window of the global
                         block grid from its mesh coordinates and scan-programs
                         only its local blocks -- the global matrix is never
                         materialized on any host or device.  MVMs run tier-1
                         locally, psum partials over the contraction axis and
                         denoise on-node; the output stays row-sharded.

Placement x pipeline matrix (which combinations fuse, which fall back)::

    execution     source      backend=reference          backend=pallas
    ------------  ----------  -------------------------  ------------------------
    local         dense a     vmapped block pipeline     fused rram_ec_matmul
                                                         (one whole-image kernel)
    streamed      traceable   ONE lax.scan dispatch per  same scan, tile step =
                  block_fn    program / MVM              rram_ec_tile_mvm kernel
    streamed      opaque      host loop, one jitted      host loop, kernel tile
                  block_fn    dispatch per block         step per block
    distributed   dense a     shard_map over the shared  shard_map'd kernel tile
                              local_dense_mvm stage      step (capability probe)
    distributed   traceable   shard_map'd scan pipeline  shard_map'd scan with
                  block_fn    per device, ONE dispatch,  the kernel tile step
                              psum partials              (capability probe)
    distributed   opaque      rejected (cannot trace inside shard_map; use
                  block_fn    execution="streamed" for the host-loop fallback)

Every cell of the matrix also executes TRANSPOSED: ``A.T @ y`` (=
:meth:`AnalogEngine.rmvm`, via the zero-copy :class:`TransposedAnalogMatrix`
view) runs the corrected ``A^T y`` against the SAME programmed image --
tier-1 ``A_tilde^T y + dA^T y_tilde`` from the stored operands, row blocks
as the contraction (psum over the mesh ROW axes under distributed execution,
output COLUMN-sharded), tier-2 denoise over the column output, the same
per-block k_x key halves as a forward call (a 1x1 mesh stays draw-identical
to streamed in both directions), and ``resident=False`` handles re-encode
inside the transposed scan exactly as they do forward (no A-sized array in
either direction).  The pallas tile step reads the same fused kernel in the
``y^T A`` direction (:func:`repro.kernels.ops.rram_ec_tile_rmvm`); see
DESIGN.md section 5.

``backend="pallas"`` under ``execution="distributed"`` is gated by
:func:`repro.core.distributed.pallas_shard_map_supported`, a compile-only
probe run once per (backend, mesh shape): where the kernel cannot lower
inside shard_map the engine warns and falls back to the reference tile step
in the same scan pipeline -- identical numerics, only the kernel fusion is
lost.  Producer-driven distributed programming requires the block grid to
divide evenly over the mesh (``mb % R == 0``, ``nb % C == 0``; row/column
sizes must be capacity multiples on axes split more than one way).

``program(block_fn, ..., resident=False)`` (distributed only) keeps NO
conductance image resident: every MVM re-encodes each block inside the scan
body (draws identical to program-then-execute), so no device ever holds more
than O(one capacity block) of A -- the paper's >= 65,536^2 solves run with
zero A-sized allocations anywhere in the program (write energy is still
billed once, as the physical hardware would).

Traceable block producers (streamed execution)
----------------------------------------------

A streamed producer is *traceable* when ``block_fn(i, j)`` is a pure jax
function of the two block-index scalars: it must accept traced int32 scalars
(so only jax ops on ``i``/``j`` -- array indexing, ``jax.random.fold_in``,
arithmetic -- no ``int(i)``, host I/O, or Python control flow on the values)
and return a fixed-shape capacity-sized block.  Every procedurally generated
paper workload (e.g. :class:`repro.core.matrices.ImplicitBandedMatrix`)
qualifies.  For traceable producers the engine fuses the whole mb x nb block
sweep into single ``lax.scan`` pipelines: ``program`` is one device dispatch,
and every ``mvm`` -- input-DAC encode, per-block dA re-derivation, tier-1 EC
(the Pallas ``rram_ec_matmul`` tile step under ``backend="pallas"``), fp32
row accumulation and tier-2 denoise -- is ONE dispatch instead of mb * nb.
Solvers driving a streamed handle therefore trace into one compiled program
end-to-end.

Traceability is auto-detected with an abstract trace at ``program`` time; set
a ``block_fn.traceable = False`` attribute to force the compatibility host
loop (one jitted dispatch per block -- the pre-scan behavior), which is also
what opaque producers (ones that fail the abstract trace) fall back to.

and a ``backend=`` switch dispatches the inner product:

  * ``"reference"`` -- pure-jnp blockwise oracle (always available);
  * ``"pallas"``    -- the fused TPU kernel :func:`repro.kernels.rram_ec_matmul`
                       plus the tier-2 stencil/Thomas kernels (interpret mode
                       on CPU).

Usage::

    import jax, jax.numpy as jnp
    from repro.core import CrossbarConfig, MCAGeometry, get_device
    from repro.engine import AnalogEngine

    cfg = CrossbarConfig(device=get_device("taox-hfox"),
                         geom=MCAGeometry(1, 1, 66, 66), k_iters=5, ec=True)
    engine = AnalogEngine(cfg)
    A = engine.program(a, jax.random.PRNGKey(1))   # one-time write
    print(A.write_stats.energy_j)                  # programming cost, paid once
    y1 = A @ x1                                    # corrected MVMs: no encode
    y2 = A @ x2                                    #   work, only the x DAC pass
    y, call_stats = engine.mvm_with_stats(A, x3)   # per-call input-write cost

The legacy one-shot entry points (``corrected_mvm``,
``streamed_corrected_mvm``, ``distributed_corrected_mvm``) remain as thin
deprecation shims over the same two-stage dataflow.

Solver entry points
-------------------

:mod:`repro.solvers` builds iterative linear solves on top of this engine --
the workload the program-once model exists for (MELISO+ is an in-memory
linear SOlver).  Every method touches the programmed image only through
``engine.mvm``, so it works across all execution modes and backends::

    from repro import solvers
    A = engine.program(a, key)                  # one-time write
    solvers.cg(A, b, tol=1e-4)                  # SPD Krylov solve
    solvers.richardson(A, b)                    # auto-omega stationary solve
    solvers.gmres(A, b); solvers.bicgstab(A, b) # general matrices
    solvers.refine(A, b)                        # analog inner + digital outer
    solvers.pdhg(A, b, c)                       # LP: min c'x, Ax=b, x>=0
                                                #   (matvec + rmatvec per iter)

Each returns a :class:`~repro.solvers.SolveResult` whose ledger splits energy
into this handle's one-time ``write_stats`` and the accumulated per-MVM
``input_write_stats`` -- the amortization curve of Figs. 4-5.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import crossbar
from repro.core.crossbar import CrossbarConfig
from repro.core.error_correction import denoise_least_square
from repro.core.write_verify import WriteStats

__all__ = ["AnalogEngine", "AnalogMatrix", "AnalogMatrixGroup",
           "TransposedAnalogMatrix", "EXECUTION_MODES", "BACKENDS",
           "SCAN_CACHE_MAX", "CHAIN_ACTIVATIONS"]

EXECUTION_MODES = ("local", "streamed", "distributed")
BACKENDS = ("reference", "pallas")

#: Per-handle bound on cached jitted execute pipelines.  Long-lived serving
#: handles see many (backend, direction, batch-bucket) combinations; each
#: cached entry pins a compiled XLA executable, so an unbounded dict is a
#: slow leak.  The cache is an LRU keyed BY batch size (among other things):
#: evicting an entry drops its jit object and every trace inside it.
SCAN_CACHE_MAX = 8

#: Static elementwise nonlinearities :meth:`AnalogEngine.chain_mvm` may fuse
#: between chained group members (None = pure linear chain).
CHAIN_ACTIVATIONS = {
    None: lambda x: x,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
}


class _BoundedCache:
    """Tiny LRU for per-handle jitted pipelines (see :data:`SCAN_CACHE_MAX`).

    Dropping an entry releases the jit wrapper -- and with it every compiled
    trace it held -- so a handle that cycles through many batch buckets keeps
    at most ``maxsize`` live executables instead of growing without bound.
    """

    def __init__(self, maxsize: int = SCAN_CACHE_MAX):
        self.maxsize = maxsize
        self._entries: "collections.OrderedDict" = collections.OrderedDict()

    def get(self, key):
        fn = self._entries.get(key)
        if fn is not None:
            self._entries.move_to_end(key)
        return fn

    def put(self, key, fn) -> None:
        self._entries[key] = fn
        self._entries.move_to_end(key)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries


def _scan_cache(handle) -> _BoundedCache:
    """The handle's bounded pipeline cache, created on first use."""
    if not isinstance(handle._scan_exec, _BoundedCache):
        handle._scan_exec = _BoundedCache()
    return handle._scan_exec


def _scale_stats(stats: WriteStats, factor: float) -> WriteStats:
    """``factor`` members' worth of one member's :class:`WriteStats`."""
    return WriteStats(
        energy_j=stats.energy_j * factor,
        latency_s=stats.latency_s * factor,
        iterations=stats.iterations,
        final_delta=stats.final_delta,
    )


@dataclasses.dataclass
class AnalogMatrix:
    """Handle to a matrix programmed onto the (simulated) analog hardware.

    Holds the per-tile conductance image and tier-1 correction operand in the
    layout of its engine's execution mode, the one-time programming
    :class:`WriteStats`, and the base PRNG key whose per-block ``k_x`` halves
    drive the input DAC noise of successive executions.
    """

    engine: "AnalogEngine"
    shape: Tuple[int, int]
    base_key: jax.Array
    write_stats: WriteStats
    # local / streamed layout: (mb, nb, cap_m, cap_n) stacked capacity tiles.
    at_blocks: Optional[jnp.ndarray] = None
    da_blocks: Optional[jnp.ndarray] = None
    # streamed layout keeps the producer instead of materializing da_blocks,
    # so the resident state is exactly the programmed image (1x, not 2x).
    block_fn: Optional[Callable[[int, int], jnp.ndarray]] = None
    # whether block_fn traced as a pure jax function of the index scalars
    # (scan-fused single-dispatch pipelines) or needs the host loop.
    block_traceable: bool = False
    # distributed dense layout: (m, n) arrays block-sharded over the mesh.
    at_dense: Optional[jnp.ndarray] = None
    da_dense: Optional[jnp.ndarray] = None
    # producer-driven distributed layout: at_blocks is the global (mb, nb,
    # cap_m, cap_n) block array sharded over the mesh (None for
    # resident=False handles, which re-encode inside every MVM's scan).
    mesh_sharded: bool = False
    # device-lifetime state (repro.reliability): when an AgeLedger is
    # attached (reliability.aging.attach_age), every execute applies the aged
    # image -- drift + replayable stuck-at faults -- inside the SAME jitted
    # dispatch, and host-side executes advance the per-block MVM count.
    age: Optional["object"] = None
    calls: int = 0
    # cached dense padded layout for the pallas backend (built on first use).
    _padded: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
    # per-handle jitted scan pipelines: a _BoundedCache LRU keyed by
    # (backend, direction, batch bucket), built on first execute; dies with
    # the handle -- see the jit-scoping note below.
    _scan_exec: Optional["_BoundedCache"] = None

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    def _grid(self) -> Tuple[int, int]:
        """(mb, nb) capacity-block grid of this handle."""
        if self.at_blocks is not None:
            return self.at_blocks.shape[:2]
        cap_m, cap_n = self.engine.cfg.geom.capacity
        return -(-self.m // cap_m), -(-self.n // cap_n)

    @property
    def a_tilde(self) -> jnp.ndarray:
        """The programmed conductance image, dense and unpadded (m, n).

        An explicitly materializing view: for non-resident (``resident=False``)
        distributed handles it re-derives the image with one scanned sweep.
        """
        if self.at_dense is not None:
            return self.at_dense
        if self.at_blocks is not None:
            return _assemble(self.at_blocks, self.m, self.n)
        mb, nb = self._grid()
        at = jax.jit(functools.partial(
            crossbar.streamed_program_blocks, self.block_fn,
            cfg=self.engine.cfg, mb=mb, nb=nb))(self.base_key)
        return _assemble(at, self.m, self.n)

    @property
    def da(self) -> jnp.ndarray:
        """The tier-1 correction operand A - A_tilde, dense unpadded (m, n)."""
        if self.da_dense is not None:
            return self.da_dense
        if self.da_blocks is not None:
            return _assemble(self.da_blocks, self.m, self.n)
        if self.at_blocks is not None:
            return _assemble(self._producer_blocks() - self.at_blocks,
                             self.m, self.n)
        return self.dense() - self.a_tilde

    def dense(self) -> jnp.ndarray:
        """The exact source matrix A = A_tilde + dA, dense unpadded (m, n).

        For streamed handles this skips the A_tilde/dA round trip entirely:
        A_tilde + (block - A_tilde) == block, so one producer sweep suffices.
        """
        if self.at_dense is not None:
            return self.at_dense + self.da_dense
        if self.da_blocks is not None:
            return _assemble(self.at_blocks + self.da_blocks, self.m, self.n)
        return _assemble(self._producer_blocks(), self.m, self.n)

    def _producer_blocks(self) -> jnp.ndarray:
        """All producer blocks, (mb, nb, cap_m, cap_n): one scanned dispatch
        for traceable producers, a host loop for opaque ones."""
        mb, nb = self._grid()
        if self.block_traceable:
            return jax.jit(functools.partial(
                crossbar.produce_blocks, self.block_fn, mb, nb))()
        return jnp.stack([jnp.stack([self.block_fn(i, j) for j in range(nb)])
                          for i in range(mb)])

    def __matmul__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.engine.mvm(self, x)

    @property
    def T(self) -> "TransposedAnalogMatrix":
        """Zero-copy transposed view: ``A.T @ y`` runs the corrected
        TRANSPOSED MVM ``A^T y`` against the SAME programmed image (no
        re-encode, no second handle -- the crossbar is read backwards)."""
        return TransposedAnalogMatrix(self)

    def input_write_stats(self, batch: int = 1) -> WriteStats:
        """Per-execution write cost (x DAC pass + EC X^T replica)."""
        return self.engine.input_write_stats(self, batch)

    @property
    def image_nbytes(self) -> int:
        """Resident bytes of this handle's programmed operands.

        Counts the stored image/correction layout (blocks or dense) plus any
        derived caches built by executions (padded pallas layout); block_fn
        producers are code, not residency, and count zero.  This is the unit
        the serving :class:`~repro.serving.cache.ImageCache` budgets in."""
        total = 0
        for arr in (self.at_blocks, self.da_blocks, self.at_dense,
                    self.da_dense):
            if arr is not None and hasattr(arr, "nbytes"):
                total += int(arr.nbytes)
        if self._padded is not None:
            total += sum(int(p.nbytes) for p in self._padded
                         if hasattr(p, "nbytes"))
        return total

    def release(self) -> int:
        """Drop derived execution caches (padded layout, jitted scan
        pipelines), returning the bytes freed.  The programmed image itself
        survives -- eviction of the image is the cache owner dropping its
        reference to the whole handle; ``release`` is the cheaper lever for
        staying under budget without paying a reprogram."""
        freed = 0
        if self._padded is not None:
            freed = sum(int(p.nbytes) for p in self._padded
                        if hasattr(p, "nbytes"))
            self._padded = None
        self._scan_exec = None
        return freed


@dataclasses.dataclass(frozen=True)
class TransposedAnalogMatrix:
    """Transposed view of an :class:`AnalogMatrix` (``A.T``).

    Holds NO operands of its own: every execution reads the parent's
    programmed conductance image in the transposed direction through
    :meth:`AnalogEngine.rmvm` (tier-1 ``A_tilde^T y + dA^T y_tilde``,
    row-block partials summed, tier-2 denoise over the column output), so the
    one-time write cost is shared with the forward view and a PDHG-style
    solver alternating ``A @ x`` / ``A.T @ y`` programs the matrix exactly
    once.  ``A.T.T is A``.
    """

    parent: AnalogMatrix

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.parent.shape[1], self.parent.shape[0])

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def T(self) -> AnalogMatrix:
        return self.parent

    @property
    def engine(self) -> "AnalogEngine":
        return self.parent.engine

    @property
    def write_stats(self) -> WriteStats:
        """The parent's one-time programming cost (shared, never re-paid)."""
        return self.parent.write_stats

    def __matmul__(self, y: jnp.ndarray) -> jnp.ndarray:
        return self.parent.engine.rmvm(self.parent, y)

    def dense(self) -> jnp.ndarray:
        """The exact transposed source matrix A^T, dense unpadded (n, m)."""
        return self.parent.dense().T

    def input_write_stats(self, batch: int = 1) -> WriteStats:
        """Per-execution cost of one transposed MVM (y DAC pass + EC Y^T
        replica over the row dimension)."""
        return self.parent.engine.input_write_stats(self.parent, batch,
                                                    transpose=True)


@dataclasses.dataclass
class AnalogMatrixGroup:
    """A stack of same-geometry programmed images executed as ONE dispatch.

    Built by :meth:`AnalogEngine.program_group` (a pytree of same-shape
    matrices or a tuple of traceable producers) or :meth:`AnalogEngine.group`
    (stacking existing compatible handles).  The ``size`` member images share
    one stacked layout along a leading image axis; every execute --
    :meth:`AnalogEngine.group_mvm`, :meth:`~AnalogEngine.group_rmvm`,
    :meth:`~AnalogEngine.chain_mvm` -- runs the whole group in a single
    device dispatch, so an L-layer analog model costs O(1) launches instead
    of O(L).  Member ``g`` draws exactly what a solo handle programmed with
    ``member_keys[g]`` draws: grouping changes the dispatch count, never the
    key schedule.  ``group()``-built stacks carry the solo images bit-exactly;
    ``program_group``'s fused encode agrees with the eager per-member path to
    float32 rounding (XLA may reassociate the vmapped arithmetic).  See
    DESIGN.md section 13.
    """

    engine: "AnalogEngine"
    size: int
    shape: Tuple[int, int]          # per-member (m, n)
    base_key: jax.Array
    member_keys: jax.Array          # stacked per-member base keys, leading g
    write_stats: WriteStats         # total across all members
    # local / streamed layout: (g, mb, nb, cap_m, cap_n) stacked tiles.
    at_blocks: Optional[jnp.ndarray] = None
    da_blocks: Optional[jnp.ndarray] = None
    # streamed layout: one traceable producer per member (dA re-derived per
    # block inside the grouped scan; da_blocks stays None).
    block_fns: Optional[Tuple[Callable, ...]] = None
    # distributed dense layout: (g, m, n) stacked arrays, each member
    # block-sharded over the mesh (leading axis replicated).
    at_dense: Optional[jnp.ndarray] = None
    da_dense: Optional[jnp.ndarray] = None
    mesh_sharded: bool = False
    # stacked AgeLedger (leading g on every field) attached by
    # repro.reliability.aging.attach_group_age: the grouped execute ages
    # every member inside the same single dispatch.
    ages: Optional["object"] = None
    calls: int = 0
    _padded: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
    _scan_exec: Optional["_BoundedCache"] = None

    @property
    def m(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    def _grid(self) -> Tuple[int, int]:
        """(mb, nb) capacity-block grid of every member."""
        if self.at_blocks is not None:
            return self.at_blocks.shape[1:3]
        cap_m, cap_n = self.engine.cfg.geom.capacity
        return -(-self.m // cap_m), -(-self.n // cap_n)

    def member(self, g: int) -> AnalogMatrix:
        """Member ``g`` as a standalone :class:`AnalogMatrix` view.

        Slices the stacked operands (no copy beyond the slice); the view
        executes through the solo paths with the member's own base key and
        a proportional share of the group's one-time write cost.
        """
        if not 0 <= g < self.size:
            raise IndexError(f"member {g} of a size-{self.size} group")
        stats = _scale_stats(self.write_stats, 1.0 / self.size)
        if self.at_dense is not None:
            return AnalogMatrix(
                engine=self.engine, shape=self.shape,
                base_key=self.member_keys[g], write_stats=stats,
                at_dense=self.at_dense[g], da_dense=self.da_dense[g],
                mesh_sharded=True)
        return AnalogMatrix(
            engine=self.engine, shape=self.shape,
            base_key=self.member_keys[g], write_stats=stats,
            at_blocks=self.at_blocks[g],
            da_blocks=None if self.da_blocks is None else self.da_blocks[g],
            block_fn=None if self.block_fns is None else self.block_fns[g],
            block_traceable=self.block_fns is not None)

    def __matmul__(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.engine.group_mvm(self, x)

    def input_write_stats(self, batch: int = 1,
                          *, transpose: bool = False) -> WriteStats:
        """Per-execution input-write cost of the WHOLE group (``size``
        members' DAC passes + EC replicas)."""
        one = self.engine.input_write_stats(self, batch, transpose=transpose)
        return _scale_stats(one, self.size)

    @property
    def image_nbytes(self) -> int:
        """Resident bytes of the stacked operands plus derived caches."""
        total = 0
        for arr in (self.at_blocks, self.da_blocks, self.at_dense,
                    self.da_dense):
            if arr is not None and hasattr(arr, "nbytes"):
                total += int(arr.nbytes)
        if self._padded is not None:
            total += sum(int(p.nbytes) for p in self._padded
                         if hasattr(p, "nbytes"))
        return total

    def release(self) -> int:
        """Drop derived execution caches (padded stack, jitted grouped
        pipelines), returning the bytes freed; the programmed stack stays."""
        freed = 0
        if self._padded is not None:
            freed = sum(int(p.nbytes) for p in self._padded
                        if hasattr(p, "nbytes"))
            self._padded = None
        self._scan_exec = None
        return freed


_assemble = crossbar.assemble_blocks


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_reference(at_blocks, da_blocks, xb, key, *, cfg, m, n):
    return crossbar.programmed_block_mvm(
        at_blocks, da_blocks, xb, key, cfg, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_reference_t(at_blocks, da_blocks, yb, key, *, cfg, m, n):
    return crossbar.programmed_block_rmvm(
        at_blocks, da_blocks, yb, key, cfg, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n", "transpose"))
def _exec_reference_aged(at_blocks, da_blocks, xb, key, age, *, cfg, m, n,
                         transpose):
    """Aged execute: ONE dispatch containing the aging transform AND the
    corrected MVM.  The physical image drifts / latches
    (:func:`repro.reliability.aging.aged_blocks`) while the stored tier-1
    operand ``dA`` stays as measured at program time, so the corrected
    product honestly degrades with age instead of silently self-correcting.
    """
    from repro.reliability.aging import aged_blocks
    at_aged = aged_blocks(at_blocks, age, cfg.device)
    run = crossbar.programmed_block_rmvm if transpose \
        else crossbar.programmed_block_mvm
    return run(at_aged, da_blocks, xb, key, cfg, m=m, n=n)


def _pallas_corrected(at, da, xb, key, cfg, m, n, transpose):
    """Shared Pallas execute body (unjitted; used solo-jitted and grouped).

    ``at``/``da`` are the dense *padded* operands.  The kernel path encodes
    the input with a single DAC pass (one noise draw for the whole padded
    vector -- fold 1 of the call key forward, fold 2 transposed, keeping the
    directions distinct when a caller reuses a key) instead of the reference
    path's per-(block, chunk) draws -- statistically identical, one kernel
    launch: ``y^T = x^T At^T + xt^T dA^T`` forward,
    ``z^T = y^T At + yt^T dA`` backwards through the same operands.
    """
    from repro.kernels import ops as kops

    pad_to = at.shape[0] if transpose else at.shape[1]
    x_pad = jnp.pad(xb, ((0, pad_to - xb.shape[0]), (0, 0)))
    if cfg.encode_inputs:
        fold = 2 if transpose else 1
        x_t = crossbar._encode_vec(x_pad, jax.random.fold_in(key, fold), cfg)
    else:
        x_t = x_pad
    if cfg.ec:
        if transpose:
            p = kops.rram_ec_matmul(x_pad.T, x_t.T, at, da).T[:n]
        else:
            p = kops.rram_ec_matmul(x_pad.T, x_t.T, at.T, da.T).T[:m]
    else:
        p = (at.T @ x_t)[:n] if transpose else (at @ x_t)[:m]
    if cfg.ec:
        if cfg.denoise_method == "neumann":
            p = kops.denoise_stencil(p, lam=cfg.lam, h=cfg.h)
        elif cfg.denoise_method == "thomas":
            p = kops.denoise_thomas(p, lam=cfg.lam, h=cfg.h)
        else:
            p = denoise_least_square(p, lam=cfg.lam, h=cfg.h,
                                     method=cfg.denoise_method)
    return p


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_pallas(at, da, xb, key, *, cfg, m, n):
    """Tier-1 via the fused Pallas EC kernel + tier-2 via the solver kernels
    (see :func:`_pallas_corrected`); ``at``/``da`` are the dense padded
    operands assembled once at first use and cached on the handle."""
    return _pallas_corrected(at, da, xb, key, cfg, m, n, transpose=False)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n"))
def _exec_pallas_t(at, da, yb, key, *, cfg, m, n):
    """Transposed tier-1 via the same fused Pallas EC kernel read backwards
    (one padded-operand cache on the handle serves both directions)."""
    return _pallas_corrected(at, da, yb, key, cfg, m, n, transpose=True)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n", "transpose"))
def _exec_group_reference(at_g, da_g, xb_g, keys, *, cfg, m, n, transpose):
    """Grouped execute: every member's corrected MVM in ONE dispatch (the
    vmapped :func:`repro.core.crossbar.grouped_block_mvm` stage; member g
    consumes ``keys[g]`` exactly as its solo execute would)."""
    run = crossbar.grouped_block_rmvm if transpose \
        else crossbar.grouped_block_mvm
    return run(at_g, da_g, xb_g, keys, cfg, m=m, n=n)


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n", "transpose"))
def _exec_group_pallas(at_g, da_g, xb_g, keys, *, cfg, m, n, transpose):
    """Grouped Pallas execute: ONE dispatch, one ``lax.map`` over members,
    each running the fused whole-image EC kernel body with its own key --
    member g's draws are identical to its solo :func:`_exec_pallas` call."""
    def one(ops):
        at, da, xb, k = ops
        return _pallas_corrected(at, da, xb, k, cfg, m, n, transpose)

    return jax.lax.map(one, (at_g, da_g, xb_g, keys))


@functools.partial(jax.jit, static_argnames=("cfg", "m", "n", "transpose"))
def _exec_group_reference_aged(at_g, da_g, xb_g, keys, ages, *, cfg, m, n,
                               transpose):
    """Grouped AGED execute: one dispatch containing every member's aging
    transform (drift + replayable stuck-at faults, per member ledger) AND the
    grouped corrected MVM -- aging adds zero dispatches to a group exactly as
    it adds zero to a solo handle (DESIGN.md section 12)."""
    from repro.reliability.aging import aged_blocks
    at_aged = jax.vmap(lambda at, age: aged_blocks(at, age, cfg.device))(
        at_g, ages)
    run = crossbar.grouped_block_rmvm if transpose \
        else crossbar.grouped_block_mvm
    return run(at_aged, da_g, xb_g, keys, cfg, m=m, n=n)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "m", "n", "activation",
                                    "use_kernel"))
def _exec_chain(at_g, da_g, x0, keys, *, cfg, m, n, activation, use_kernel):
    """Whole-model chained forward: ONE ``lax.scan`` over the image axis
    threads the activation through every member -- an L-layer analog MLP
    forward is a single device dispatch.  Member g's corrected MVM consumes
    ``keys[g]`` (the same per-block k_x halves as its solo execute); the
    static ``activation`` from :data:`CHAIN_ACTIVATIONS` applies between
    members."""
    act = CHAIN_ACTIVATIONS[activation]

    def body(x, ops):
        at, da, k = ops
        y = crossbar.programmed_block_mvm(at, da, x, k, cfg, m=m, n=n,
                                          use_kernel=use_kernel)
        return act(y), None

    y, _ = jax.lax.scan(body, x0, (at_g, da_g, keys))
    return y


# Scan-fused streamed pipelines: the pure stages live in
# :mod:`repro.core.crossbar` (streamed_program_blocks / streamed_block_mvm /
# produce_blocks); jit scoping is deliberate.  Program-time and da/dense
# sweeps use locally-scoped jits (one trace per call, garbage-collected with
# it); the execute-many hot path caches its jitted pipeline ON THE HANDLE
# (:attr:`AnalogMatrix._scan_exec`), so a warm streamed MVM re-invokes the
# producer zero times yet the trace -- and the producer closure it pins --
# dies with the handle instead of accumulating in a process-wide cache.


class AnalogEngine:
    """Program-once / execute-many corrected-MVM engine.

    Parameters
    ----------
    cfg:
        The :class:`CrossbarConfig` describing one multi-MCA system (for
        ``execution="distributed"``: the per-device system).
    execution:
        ``"local"`` | ``"streamed"`` | ``"distributed"``.
    backend:
        ``"reference"`` (pure jnp) | ``"pallas"`` (fused TPU kernels; interpret
        mode on CPU).  Under ``execution="distributed"`` the Pallas tile step
        runs inside ``shard_map`` where the capability probe
        (:func:`repro.core.distributed.pallas_shard_map_supported`) confirms
        it lowers; otherwise the engine warns once and falls back to the
        reference tile step (identical numerics).
    mesh, row_axes, col_axis:
        Mesh placement for ``execution="distributed"``: rows shard over
        ``row_axes``, the contraction over ``col_axis``.
    """

    def __init__(
        self,
        cfg: CrossbarConfig,
        *,
        execution: str = "local",
        backend: str = "reference",
        mesh=None,
        row_axes: Tuple[str, ...] = ("data",),
        col_axis: str = "model",
    ):
        if execution not in EXECUTION_MODES:
            raise ValueError(
                f"unknown execution mode {execution!r}; expected one of "
                f"{EXECUTION_MODES}")
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; expected one of {BACKENDS}")
        if execution == "distributed" and mesh is None:
            raise ValueError("execution='distributed' requires a mesh")
        self.cfg = cfg
        self.execution = execution
        self.backend = backend
        self.mesh = mesh
        self.row_axes = tuple(row_axes)
        self.col_axis = col_axis
        self._streamed_step = {}        # jitted per-block host-loop steps,
                                        # keyed (use_kernel, transpose)
        if execution == "distributed":
            from repro.core import distributed as D
            self._dist_program = jax.jit(D.make_distributed_program(
                cfg, mesh, self.row_axes, col_axis))
            self._dist_mvm = jax.jit(D.make_distributed_programmed_mvm(
                cfg, mesh, self.row_axes, col_axis))
            # dense execute pipelines keyed by (use_kernel, transpose)
            # (pallas / transposed variants built lazily, the former behind
            # the shard_map capability probe).
            self._dist_mvm_cache = {(False, False): self._dist_mvm}

    def _dist_use_kernel(self) -> bool:
        """Whether distributed execution may fuse the Pallas tile step."""
        if self.backend != "pallas" or not self.cfg.ec:
            return False
        from repro.core import distributed as D
        return D.pallas_shard_map_supported(self.mesh)

    def _dense_dist_exec(self, transpose: bool = False):
        """The jitted shard_map'd dense execute stage for this backend
        (forward or transposed)."""
        use_kernel = self._dist_use_kernel()
        fn = self._dist_mvm_cache.get((use_kernel, transpose))
        if fn is None:
            from repro.core import distributed as D
            make = D.make_distributed_rmvm if transpose else \
                D.make_distributed_programmed_mvm
            fn = jax.jit(make(
                self.cfg, self.mesh, self.row_axes, self.col_axis,
                use_kernel=use_kernel))
            self._dist_mvm_cache[(use_kernel, transpose)] = fn
        return fn

    # ------------------------------------------------------------- programming
    def program(
        self,
        a: Union[jnp.ndarray, Callable[[int, int], jnp.ndarray]],
        key: jax.Array,
        *,
        shape: Optional[Tuple[int, int]] = None,
        resident: bool = True,
    ) -> AnalogMatrix:
        """Write ``a`` onto the analog system once; returns the reusable handle.

        ``a`` is a dense (m, n) array, or -- for ``execution="streamed"`` and
        ``execution="distributed"`` -- a ``block_fn(i, j)`` producer of
        capacity-sized (already padded) blocks, in which case ``shape=(m, n)``
        gives the logical problem size.  Producers that trace as pure jax
        functions of the index scalars (see the module docstring) are
        programmed and executed as single-dispatch ``lax.scan`` pipelines
        (mesh-sharded windows of the global block grid under distributed
        execution); opaque producers take a host loop per block (streamed
        only -- distributed execution rejects them).

        ``resident=False`` (distributed producers only) keeps no conductance
        image: each MVM re-encodes blocks inside its scan with the identical
        draws, so no device ever allocates more than one capacity block of A.
        """
        if callable(a) and not hasattr(a, "shape"):
            if self.execution not in ("streamed", "distributed"):
                raise ValueError("a block_fn producer requires "
                                 "execution='streamed' or 'distributed'")
            if shape is None:
                raise ValueError("program(block_fn, ...) requires shape=(m, n)")
            if self.execution == "distributed":
                return self._program_distributed_streamed(
                    a, shape, key, resident)
            if not resident:
                raise ValueError("resident=False requires "
                                 "execution='distributed' (streamed handles "
                                 "keep the programmed image)")
            return self._program_streamed(a, shape, key)
        if not resident:
            raise ValueError(
                "resident=False requires a block_fn producer under "
                "execution='distributed'")
        m, n = a.shape
        if self.execution == "distributed":
            return self._program_distributed(a, key)
        at_blocks, da_blocks = crossbar.program_blocks(a, key, self.cfg)
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key,
            write_stats=crossbar.matrix_write_cost(m, n, self.cfg),
            at_blocks=at_blocks, da_blocks=da_blocks)

    def _program_streamed(self, block_fn, shape, key) -> AnalogMatrix:
        m, n = shape
        cap_m, cap_n = self.cfg.geom.capacity
        mb, nb = -(-m // cap_m), -(-n // cap_n)
        traceable = crossbar.producer_is_traceable(block_fn, cap_m, cap_n)
        if traceable:
            # One scanned dispatch programs every capacity block (local jit:
            # programming runs once per handle, no process-wide cache entry).
            at_blocks = jax.jit(functools.partial(
                crossbar.streamed_program_blocks, block_fn,
                cfg=self.cfg, mb=mb, nb=nb))(key)
        else:
            # Compatibility host loop: one jitted dispatch per block.
            keys = crossbar.block_keys(key, mb, nb)

            def enc(blk, k):
                k_a, _ = jax.random.split(k)
                return crossbar.encode_tiled(blk, k_a, self.cfg)

            step = jax.jit(enc)
            at_blocks = jnp.stack(
                [jnp.stack([step(block_fn(i, j), keys[i, j])
                            for j in range(nb)])
                 for i in range(mb)])
        # Only the programmed image is kept resident (the simulated hardware
        # state); the tier-1 operand dA is re-derived per block at execute
        # time from the producer, so huge matrices are never held twice.
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key,
            write_stats=crossbar.matrix_write_cost(m, n, self.cfg),
            at_blocks=at_blocks, block_fn=block_fn,
            block_traceable=traceable)

    def _program_distributed(self, a, key) -> AnalogMatrix:
        from repro.core import distributed as D
        m, n = a.shape
        row_spec = self.row_axes if len(self.row_axes) > 1 else self.row_axes[0]
        a_sh = D.shard_matrix(a, self.mesh, row_spec, self.col_axis)
        at, da, stats = self._dist_program(a_sh, key)
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key, write_stats=stats,
            at_dense=at, da_dense=da, mesh_sharded=True)

    def _program_distributed_streamed(self, block_fn, shape, key,
                                      resident) -> AnalogMatrix:
        """Producer-driven distributed programming: each device scan-programs
        its window of the global block grid; A never materializes anywhere."""
        from repro.core import distributed as D
        m, n = shape
        cap_m, cap_n = self.cfg.geom.capacity
        mb, nb = -(-m // cap_m), -(-n // cap_n)
        if not crossbar.producer_is_traceable(block_fn, cap_m, cap_n):
            raise ValueError(
                "execution='distributed' requires a traceable block_fn "
                "producer (a pure jax function of the two index scalars): "
                "opaque producers cannot run inside shard_map -- use "
                "execution='streamed' for the host-loop fallback")
        n_row, n_col = D.mesh_grid_shape(self.mesh, self.row_axes,
                                         self.col_axis)
        if mb % n_row or nb % n_col:
            raise ValueError(
                f"the {mb} x {nb} capacity-block grid does not divide over "
                f"the {n_row} x {n_col} mesh; pick a capacity/mesh so every "
                "device owns an equal block window")
        if n_row > 1 and m != mb * cap_m:
            raise ValueError(
                f"m={m} must be a multiple of the capacity row size {cap_m} "
                "to row-shard a producer grid (produce padded blocks and "
                "declare the padded shape)")
        if n_col > 1 and n != nb * cap_n:
            raise ValueError(
                f"n={n} must be a multiple of the capacity column size "
                f"{cap_n} to column-shard a producer grid")
        at_blocks = None
        if resident:
            # ONE jitted dispatch programs every device's block window.
            prog = jax.jit(D.make_distributed_streamed_program(
                block_fn, self.cfg, self.mesh, self.row_axes, self.col_axis,
                mb=mb, nb=nb))
            at_blocks = prog(key)
        # Per-device footprint; mean across the uniform shards == per-device
        # value (the Figs. 4-5 reporting convention).
        m_loc = m if n_row == 1 else (mb // n_row) * cap_m
        n_loc = n if n_col == 1 else (nb // n_col) * cap_n
        return AnalogMatrix(
            engine=self, shape=(m, n), base_key=key,
            write_stats=crossbar.matrix_write_cost(m_loc, n_loc, self.cfg),
            at_blocks=at_blocks, block_fn=block_fn, block_traceable=True,
            mesh_sharded=True)

    def encode_dense(self, a: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
        """The programmed image of ``a`` as a dense unpadded array.

        Pure jax function of (a, key): safe under jit/vmap (used by
        :func:`repro.models.rram.program_rram` for stacked layer kernels).
        """
        at_blocks, _ = crossbar.program_blocks(a, key, self.cfg)
        return _assemble(at_blocks, *a.shape)

    # ------------------------------------------------------ group programming
    def program_group(
        self,
        source,
        key: jax.Array,
        *,
        shape: Optional[Tuple[int, int]] = None,
    ) -> AnalogMatrixGroup:
        """Program a whole stack of matrices as ONE grouped dispatch.

        ``source`` is a pytree of same-shape 2-D arrays (list, dict, nested
        -- the leaves stack in ``jax.tree_util`` leaf order), a single
        pre-stacked (g, m, n) array, or -- under ``execution="streamed"`` --
        a sequence of traceable ``block_fn(i, j)`` producers with
        ``shape=(m, n)``.  Member ``g`` is programmed with
        ``fold_in(key, g)`` and its image is bit-identical to a solo
        ``program`` under that key; only the dispatch count changes (one
        launch for the whole group instead of one per member).  Under
        ``execution="distributed"`` the stack programs in one ``shard_map``
        with each member block-sharded over the mesh.
        """
        leaves = jax.tree_util.tree_leaves(source)
        if not leaves:
            raise ValueError("program_group needs at least one member")
        producers = [f for f in leaves
                     if callable(f) and not hasattr(f, "shape")]
        if producers and len(producers) != len(leaves):
            raise ValueError(
                "program_group members must be all arrays or all block_fn "
                "producers, not a mix")
        if producers:
            return self._program_group_streamed(tuple(producers), key, shape)
        if len(leaves) == 1 and getattr(leaves[0], "ndim", 0) == 3:
            stack = jnp.asarray(leaves[0])
        else:
            shapes = sorted({tuple(getattr(l, "shape", ())) for l in leaves})
            if len(shapes) != 1 or len(shapes[0]) != 2:
                raise ValueError(
                    "program_group needs geometry-compatible members: every "
                    f"leaf must be the same 2-D (m, n) shape, got {shapes} "
                    "(group same-shape kernels; program the rest solo)")
            stack = jnp.stack([jnp.asarray(l) for l in leaves])
        size, m, n = stack.shape
        member_keys = jax.vmap(
            lambda g: jax.random.fold_in(key, g))(jnp.arange(size))
        if self.execution == "distributed":
            return self._program_group_distributed(stack, key, member_keys)
        at_g, da_g = jax.jit(functools.partial(
            crossbar.group_program_blocks, cfg=self.cfg))(stack, member_keys)
        stats = _scale_stats(crossbar.matrix_write_cost(m, n, self.cfg), size)
        return AnalogMatrixGroup(
            engine=self, size=size, shape=(m, n), base_key=key,
            member_keys=member_keys, write_stats=stats,
            at_blocks=at_g, da_blocks=da_g)

    def _program_group_streamed(self, block_fns, key, shape
                                ) -> AnalogMatrixGroup:
        if self.execution == "distributed":
            raise ValueError(
                "program_group does not take producer groups under "
                "execution='distributed' (one producer already scan-programs "
                "the whole mesh); program members individually or use "
                "execution='streamed'")
        if self.execution != "streamed":
            raise ValueError(
                "a producer group requires execution='streamed'")
        if shape is None:
            raise ValueError(
                "program_group(producers, ...) requires shape=(m, n)")
        m, n = shape
        cap_m, cap_n = self.cfg.geom.capacity
        mb, nb = -(-m // cap_m), -(-n // cap_n)
        for g, fn in enumerate(block_fns):
            if not crossbar.producer_is_traceable(fn, cap_m, cap_n):
                raise ValueError(
                    f"group member {g}'s block_fn is not traceable: grouped "
                    "streamed execution selects producers by lax.switch "
                    "inside one scan, so every member must trace as a pure "
                    "jax function of the index scalars (program opaque "
                    "producers individually instead)")
        size = len(block_fns)
        member_keys = jax.vmap(
            lambda g: jax.random.fold_in(key, g))(jnp.arange(size))
        at_g = jax.jit(functools.partial(
            crossbar.grouped_streamed_program_blocks, block_fns,
            cfg=self.cfg, mb=mb, nb=nb))(member_keys)
        stats = _scale_stats(crossbar.matrix_write_cost(m, n, self.cfg), size)
        return AnalogMatrixGroup(
            engine=self, size=size, shape=(m, n), base_key=key,
            member_keys=member_keys, write_stats=stats,
            at_blocks=at_g, block_fns=block_fns)

    def _program_group_distributed(self, stack, key, member_keys
                                   ) -> AnalogMatrixGroup:
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core import distributed as D
        size, m, n = stack.shape
        row_spec = self.row_axes if len(self.row_axes) > 1 else self.row_axes[0]
        a_sh = jax.device_put(stack, NamedSharding(
            self.mesh, PartitionSpec(None, row_spec, self.col_axis)))
        prog = self._dist_mvm_cache.get("group_program")
        if prog is None:
            prog = jax.jit(D.make_distributed_group_program(
                self.cfg, self.mesh, self.row_axes, self.col_axis))
            self._dist_mvm_cache["group_program"] = prog
        at_g, da_g, stats = prog(a_sh, member_keys)
        return AnalogMatrixGroup(
            engine=self, size=size, shape=(m, n), base_key=key,
            member_keys=member_keys, write_stats=stats,
            at_dense=at_g, da_dense=da_g, mesh_sharded=True)

    def group(self, handles: Sequence[AnalogMatrix]) -> AnalogMatrixGroup:
        """Stack already-programmed compatible handles into a group.

        No re-programming: the members' images stack verbatim (member ``g``
        of the group is bit-identical to ``handles[g]``), so grouped
        execution of existing handles gives the single-dispatch pipeline for
        free.  Members must share one engine configuration and one (m, n)
        shape, hold resident LOCAL images (dense blocks, or all-streamed with
        traceable producers), and carry no attached :class:`AgeLedger` --
        attach ages to the GROUP via
        :func:`repro.reliability.aging.attach_group_age` instead.
        """
        handles = list(handles)
        if not handles:
            raise ValueError("group() needs at least one handle")
        shapes = sorted({h.shape for h in handles})
        if len(shapes) != 1:
            raise ValueError(
                "group() members must be geometry-compatible (one shared "
                f"(m, n) shape); got {shapes}")
        for g, h in enumerate(handles):
            if isinstance(h, TransposedAnalogMatrix):
                raise ValueError(
                    "group() stacks forward handles; run the transposed "
                    "direction through group_rmvm")
            if h.engine is not self and h.engine.cfg != self.cfg:
                raise ValueError(
                    f"group() member {g} was programmed by an incompatible "
                    "engine configuration")
            if h.mesh_sharded or h.at_dense is not None:
                raise ValueError(
                    "group() stacks local handles; distributed images group "
                    "at program time via program_group")
            if h.at_blocks is None:
                raise ValueError(
                    f"group() member {g} holds no resident image "
                    "(resident=False handles cannot be grouped)")
            if h.age is not None:
                raise ValueError(
                    f"group() member {g} has an AgeLedger attached; group "
                    "first, then age the group via attach_group_age")
        streamed = [h.da_blocks is None for h in handles]
        if any(streamed):
            if not all(streamed):
                raise ValueError(
                    "group() members must be all dense or all streamed")
            if not all(h.block_traceable for h in handles):
                raise ValueError(
                    "grouped streamed execution requires every member's "
                    "producer to be traceable")
            block_fns = tuple(h.block_fn for h in handles)
            da_g = None
        else:
            block_fns = None
            da_g = jnp.stack([h.da_blocks for h in handles])
        at_g = jnp.stack([h.at_blocks for h in handles])
        member_keys = jnp.stack([h.base_key for h in handles])
        total = WriteStats(
            energy_j=sum(h.write_stats.energy_j for h in handles),
            latency_s=sum(h.write_stats.latency_s for h in handles),
            iterations=handles[0].write_stats.iterations,
            final_delta=max(h.write_stats.final_delta for h in handles))
        return AnalogMatrixGroup(
            engine=self, size=len(handles), shape=handles[0].shape,
            base_key=handles[0].base_key, member_keys=member_keys,
            write_stats=total, at_blocks=at_g, da_blocks=da_g,
            block_fns=block_fns)

    # --------------------------------------------------------------- execution
    def mvm(self, A: AnalogMatrix, x: jnp.ndarray, *,
            key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Corrected MVM against the programmed image: zero re-encode work.

        ``x``: (n,) or (n, batch).  ``key`` overrides the input-DAC noise key;
        by default successive calls consume fresh folds of the handle's base
        key (call 0 reproduces the legacy one-shot draws exactly).
        """
        y, _ = self._execute(A, x, key)
        return y

    def mvm_with_stats(self, A: AnalogMatrix, x: jnp.ndarray, *,
                       key: Optional[jax.Array] = None
                       ) -> Tuple[jnp.ndarray, WriteStats]:
        """Like :meth:`mvm` but also returns this call's input-write cost."""
        return self._execute(A, x, key, with_stats=True)

    def rmvm(self, A: AnalogMatrix, y: jnp.ndarray, *,
             key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Corrected TRANSPOSED MVM ``A.T @ y`` against the programmed image.

        ``y``: (m,) or (m, batch); returns (n,) / (n, batch).  Reads the SAME
        conductance image as :meth:`mvm` -- zero re-encode, zero extra
        programming cost; only the y vector passes through the DAC (per
        row-block chunk, consuming the identical per-block k_x key halves a
        forward call would) and tier-2 denoising runs over the column output.
        Under ``execution="distributed"`` the row shards are the contraction
        axis: partials psum over the ROW axes and the output comes back
        COLUMN-sharded (over ``col_axis``).  ``A.T @ y`` is the operator
        form; :class:`TransposedAnalogMatrix` documents the view.
        """
        z, _ = self._execute(A, y, key, transpose=True)
        return z

    def rmvm_with_stats(self, A: AnalogMatrix, y: jnp.ndarray, *,
                        key: Optional[jax.Array] = None
                        ) -> Tuple[jnp.ndarray, WriteStats]:
        """Like :meth:`rmvm` but also returns this call's input-write cost."""
        return self._execute(A, y, key, with_stats=True, transpose=True)

    # --------------------------------------------------------- group execution
    def group_mvm(self, G: AnalogMatrixGroup, x: jnp.ndarray, *,
                  key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Corrected MVM of EVERY group member in one device dispatch.

        ``x`` broadcasts or distributes over the image axis:

        * ``(n,)`` / ``(n, batch)`` -- the same input to every member;
        * ``(size, n)`` / ``(size, n, batch)`` -- one input per member
          (a shape that is both -- square ``size == n`` 2-D input --
          resolves per-member).

        Returns ``(size, m)`` / ``(size, m, batch)``.  ``key`` seeds member
        ``g``'s DAC draws with ``fold_in(key, g)``; by default successive
        calls consume per-member folds of ``member_keys`` -- member ``g``'s
        call ``c`` draws match a solo handle's call ``c`` exactly.
        """
        y, _ = self._group_execute(G, x, key)
        return y

    def group_mvm_with_stats(self, G: AnalogMatrixGroup, x: jnp.ndarray, *,
                             key: Optional[jax.Array] = None
                             ) -> Tuple[jnp.ndarray, WriteStats]:
        """Like :meth:`group_mvm` plus the whole group's input-write cost."""
        return self._group_execute(G, x, key, with_stats=True)

    def group_rmvm(self, G: AnalogMatrixGroup, y: jnp.ndarray, *,
                   key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Corrected TRANSPOSED MVM of every member in one dispatch
        (``A_g.T @ y_g`` against the same stacked image; ``y``: ``(m,)``,
        ``(m, batch)``, ``(size, m)`` or ``(size, m, batch)``)."""
        z, _ = self._group_execute(G, y, key, transpose=True)
        return z

    def group_rmvm_with_stats(self, G: AnalogMatrixGroup, y: jnp.ndarray, *,
                              key: Optional[jax.Array] = None
                              ) -> Tuple[jnp.ndarray, WriteStats]:
        """Like :meth:`group_rmvm` plus the group's input-write cost."""
        return self._group_execute(G, y, key, with_stats=True, transpose=True)

    def chain_mvm(self, G: AnalogMatrixGroup, x: jnp.ndarray, *,
                  key: Optional[jax.Array] = None,
                  activation: Optional[str] = None) -> jnp.ndarray:
        """Whole-model CHAINED forward in one dispatch: member 0's output
        feeds member 1's input and so on -- an L-layer analog forward pass is
        a single ``lax.scan`` launch.  Members must be square (``m == n``);
        ``activation`` (a :data:`CHAIN_ACTIVATIONS` name or None) applies
        between members inside the same dispatch.  ``x``: (n,) or (n, batch).
        """
        if isinstance(G, AnalogMatrix):
            raise TypeError("chain_mvm takes an AnalogMatrixGroup; wrap solo "
                            "handles with engine.group([...])")
        if G.m != G.n:
            raise ValueError(
                f"chain_mvm threads each member's output into the next, so "
                f"members must be square; the group is {G.m} x {G.n}")
        if activation not in CHAIN_ACTIVATIONS:
            names = sorted(k for k in CHAIN_ACTIVATIONS if k is not None)
            raise ValueError(
                f"unknown chain activation {activation!r}; expected None or "
                f"one of {names}")
        if G.at_blocks is None or G.da_blocks is None:
            raise ValueError(
                "chain_mvm needs a LOCAL resident group (dense members with "
                "stacked at/da blocks)")
        if G.ages is not None:
            raise ValueError("chain_mvm does not apply attached ages; "
                             "detach them or use group_mvm")
        squeeze = x.ndim == 1
        xb = x[:, None] if squeeze else x
        if xb.shape[0] != G.n:
            raise ValueError(
                f"chain_mvm: input has {xb.shape[0]} rows but the members "
                f"are {G.m} x {G.n}")
        keys = self._group_keys(G, key)
        G.calls += 1
        use_kernel = self.backend == "pallas" and self.cfg.ec
        y = _exec_chain(G.at_blocks, G.da_blocks, xb, keys, cfg=self.cfg,
                        m=G.m, n=G.n, activation=activation,
                        use_kernel=use_kernel)
        return y[:, 0] if squeeze else y

    def _group_keys(self, G: AnalogMatrixGroup, key) -> jax.Array:
        """Per-member execute keys: explicit ``key`` fans out as
        ``fold_in(key, g)``; the default schedule folds each member's base
        key by the call counter, matching the solo per-handle schedule
        draw-for-draw."""
        if key is not None:
            return jax.vmap(lambda g: jax.random.fold_in(key, g))(
                jnp.arange(G.size))
        if not getattr(jax.core, "trace_state_clean", lambda: True)():
            raise ValueError(
                "engine.group_mvm inside jit needs an explicit key= (the "
                "default call-counter key schedule is host-side state)")
        if G.calls == 0:
            return G.member_keys
        return jax.vmap(lambda k: jax.random.fold_in(k, G.calls))(
            G.member_keys)

    def _group_input(self, G, x, transpose):
        """Normalize group input to (size, contraction, batch) + output mode."""
        contraction = G.m if transpose else G.n
        direction = "G.T @ y" if transpose else "G @ x"
        if x.ndim == 1:
            if x.shape[0] != contraction:
                raise ValueError(
                    f"{direction}: input has {x.shape[0]} rows but members "
                    f"are {G.m} x {G.n}")
            return jnp.broadcast_to(x[None, :, None],
                                    (G.size, contraction, 1)), True
        if x.ndim == 2:
            if x.shape == (G.size, contraction):
                return x[:, :, None], True
            if x.shape[0] == contraction:
                return jnp.broadcast_to(x[None], (G.size,) + x.shape), False
            raise ValueError(
                f"{direction}: 2-D input must be ({contraction}, batch) or "
                f"(size={G.size}, {contraction}); got {x.shape}")
        if x.ndim == 3:
            if x.shape[0] != G.size or x.shape[1] != contraction:
                raise ValueError(
                    f"{direction}: 3-D input must be (size={G.size}, "
                    f"{contraction}, batch); got {x.shape}")
            return x, False
        raise ValueError(f"{direction}: input must be 1-, 2- or 3-D")

    def _group_execute(self, G, x, key, with_stats=False, transpose=False):
        if not isinstance(G, AnalogMatrixGroup):
            raise TypeError("group_mvm takes an AnalogMatrixGroup; use "
                            "engine.mvm for solo handles")
        if G.engine is not self and G.engine.cfg != self.cfg:
            raise ValueError("AnalogMatrixGroup was programmed by an "
                             "incompatible engine configuration")
        if self.execution == "distributed":
            if G.at_dense is None:
                raise ValueError(
                    "this engine executes distributed but the group holds "
                    "block tiles; build it with the distributed engine's "
                    "program_group")
        elif G.at_blocks is None:
            raise ValueError(
                "the group holds mesh-sharded operands but this engine "
                f"executes {self.execution!r}; build it with this engine")
        xb, squeeze = self._group_input(G, x, transpose)
        keys = self._group_keys(G, key)
        G.calls += 1
        m, n = G.shape
        batch = xb.shape[2]
        stats = None
        if self.execution == "distributed":
            p, stats = self._group_dist_exec(transpose)(
                G.at_dense, G.da_dense, xb, keys)
        elif G.ages is not None:
            if self.backend != "reference" or G.da_blocks is None:
                raise ValueError(
                    "aged group execution needs execution='local', "
                    "backend='reference' and resident da blocks")
            p = _exec_group_reference_aged(
                G.at_blocks, G.da_blocks, xb, keys, G.ages,
                cfg=self.cfg, m=m, n=n, transpose=transpose)
            if getattr(jax.core, "trace_state_clean", lambda: True)():
                G.ages = G.ages.advanced(1)
        elif G.da_blocks is None:
            # Streamed group: dA re-derived per block from each member's
            # producer inside one grouped scan pipeline.
            use_kernel = self.backend == "pallas" and self.cfg.ec
            cache = _scan_cache(G)
            cache_key = (use_kernel, transpose, batch)
            fn = cache.get(cache_key)
            if fn is None:
                stage = crossbar.grouped_streamed_block_rmvm if transpose \
                    else crossbar.grouped_streamed_block_mvm
                fn = jax.jit(functools.partial(
                    stage, G.block_fns,
                    cfg=self.cfg, m=m, n=n, use_kernel=use_kernel))
                cache.put(cache_key, fn)
            p = fn(G.at_blocks, xb, keys)
        elif self.backend == "pallas":
            padded = G._padded
            if padded is None:
                _, mb, nb, cm, cn = G.at_blocks.shape
                asm = jax.vmap(
                    functools.partial(_assemble, m=mb * cm, n=nb * cn))
                padded = (asm(G.at_blocks), asm(G.da_blocks))
                if getattr(jax.core, "trace_state_clean", lambda: False)():
                    G._padded = padded
            p = _exec_group_pallas(*padded, xb, keys, cfg=self.cfg,
                                   m=m, n=n, transpose=transpose)
        else:
            p = _exec_group_reference(G.at_blocks, G.da_blocks, xb, keys,
                                      cfg=self.cfg, m=m, n=n,
                                      transpose=transpose)
        if with_stats and stats is None:
            stats = G.input_write_stats(batch, transpose=transpose)
        return (p[:, :, 0] if squeeze else p), stats

    def _group_dist_exec(self, transpose: bool = False):
        """The jitted shard_map'd GROUP execute stage for this backend."""
        use_kernel = self._dist_use_kernel()
        fn = self._dist_mvm_cache.get(("group", use_kernel, transpose))
        if fn is None:
            from repro.core import distributed as D
            make = D.make_distributed_group_rmvm if transpose else \
                D.make_distributed_group_mvm
            fn = jax.jit(make(
                self.cfg, self.mesh, self.row_axes, self.col_axis,
                use_kernel=use_kernel))
            self._dist_mvm_cache[("group", use_kernel, transpose)] = fn
        return fn

    # ------------------------------------------------------- analysis hooks
    def mvm_fn(self, A: AnalogMatrix, *, transpose: bool = False):
        """Traceable ``(vec, key) -> out`` closure over a programmed handle.

        The canonical pipeline surface for jaxpr-level tooling: the
        invariant registry (:mod:`repro.analysis.pipelines`) traces these
        closures with ``ShapeDtypeStruct`` placeholders, so the verifier
        passes see exactly the computation :meth:`mvm` / :meth:`rmvm`
        dispatch.  See DESIGN.md section 10.
        """
        if transpose:
            return lambda y, key: self.rmvm(A, y, key=key)
        return lambda x, key: self.mvm(A, x, key=key)

    def group_mvm_fn(self, G: AnalogMatrixGroup, *, transpose: bool = False):
        """Traceable ``(vec, key) -> out`` closure over a grouped handle --
        the :meth:`mvm_fn` analogue the invariant registry traces to pin the
        whole group to ONE top-level dispatch."""
        if transpose:
            return lambda y, key: self.group_rmvm(G, y, key=key)
        return lambda x, key: self.group_mvm(G, x, key=key)

    def chain_fn(self, G: AnalogMatrixGroup, *,
                 activation: Optional[str] = None):
        """Traceable closure over the chained whole-model forward
        (:meth:`chain_mvm`)."""
        return lambda x, key: self.chain_mvm(G, x, key=key,
                                             activation=activation)

    @property
    def collective_axes(self) -> Tuple[str, ...]:
        """Mesh axes a distributed execution may legally reduce over
        (the CollectiveAudit whitelist); empty for single-device modes."""
        if self.execution != "distributed":
            return ()
        return (*self.row_axes, self.col_axis)

    def input_write_stats(self, A: AnalogMatrix, batch: int = 1,
                          *, transpose: bool = False) -> WriteStats:
        """Per-execution input-write cost, in the same reporting convention as
        the handle's ``write_stats`` (distributed: mean across devices, the
        paper's Figs. 4-5 convention).  Non-divisible mesh shapes bill the
        ceil-divided per-device footprint -- the rows/cols a real placement
        would pad onto the largest shard -- instead of silently flooring.
        ``transpose=True`` bills a transposed execution (the m-length y DAC
        pass + the row-dimension EC replica)."""
        m, n = A.shape
        if self.execution == "distributed":
            sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            for ax in self.row_axes:
                m = -(-m // sizes[ax])
            n = -(-n // sizes[self.col_axis])
        return crossbar.input_write_cost(m, n, self.cfg, batch=batch,
                                         transpose=transpose)

    def _execute(self, A, x, key, with_stats=False, transpose=False):
        if isinstance(A, AnalogMatrixGroup):
            raise TypeError("engine.mvm/rmvm take a solo AnalogMatrix; "
                            "use engine.group_mvm/group_rmvm for groups")
        if isinstance(A, TransposedAnalogMatrix):
            # A transposed view executes as the opposite direction of its
            # parent: (A.T).T @ x is a forward MVM of the parent.  The same
            # cross-engine guard as the direct path applies BEFORE
            # delegating, so a view can't smuggle a handle past it.
            if A.parent.engine is not self and A.parent.engine.cfg != self.cfg:
                raise ValueError(
                    "AnalogMatrix was programmed by an incompatible "
                    "engine configuration")
            return A.parent.engine._execute(A.parent, x, key,
                                            with_stats=with_stats,
                                            transpose=not transpose)
        if A.engine is not self and A.engine.cfg != self.cfg:
            raise ValueError("AnalogMatrix was programmed by an incompatible "
                             "engine configuration")
        if self.execution == "distributed":
            # Only handles programmed BY a distributed engine may execute
            # here: producer handles from a streamed engine skipped the
            # mesh/grid validation (mb % R, capacity multiples, traceability)
            # and would mis-shape or die opaquely inside shard_map.
            if A.at_dense is None and not (A.block_fn is not None
                                           and A.mesh_sharded):
                raise ValueError(
                    "AnalogMatrix holds block tiles but this engine executes "
                    "distributed; program it with the distributed engine")
        elif A.at_blocks is None or A.mesh_sharded:
            raise ValueError(
                "AnalogMatrix holds mesh-sharded operands but this engine "
                f"executes {self.execution!r}; program it with this engine")
        squeeze = x.ndim == 1
        xb = x[:, None] if squeeze else x
        contraction = A.m if transpose else A.n
        if xb.shape[0] != contraction:
            direction = "A.T @ y" if transpose else "A @ x"
            raise ValueError(
                f"{direction}: input has {xb.shape[0]} rows but the "
                f"programmed matrix is {A.m} x {A.n}")
        if key is None:
            # The default key schedule advances Python-side per call; under a
            # jit trace it would freeze at its trace-time value and every
            # execution would reuse identical DAC noise -- require an explicit
            # key there instead of silently correlating the draws.
            if not getattr(jax.core, "trace_state_clean", lambda: True)():
                raise ValueError(
                    "engine.mvm inside jit needs an explicit key= (the "
                    "default call-counter key schedule is host-side state)")
            key = A.base_key if A.calls == 0 else \
                jax.random.fold_in(A.base_key, A.calls)
        A.calls += 1
        m, n = A.shape
        if self.execution == "distributed":
            if A.at_dense is not None:
                p, stats = self._dense_dist_exec(transpose)(
                    A.at_dense, A.da_dense, xb, key)
            else:
                # Producer-driven: ONE shard_map'd scan dispatch, output
                # stays row-sharded (column-sharded for transposed calls);
                # per-call cost is analytic (the same ceil-divided per-device
                # mean as input_write_stats).
                p = self._exec_dist_streamed(A, xb, key, transpose)
                stats = self.input_write_stats(A, xb.shape[1],
                                               transpose=transpose) \
                    if with_stats else None
        else:
            stats = None
            if A.age is not None and A.da_blocks is not None \
                    and self.backend == "reference":
                # Aged execute: drift + stuck-at faults applied to the image
                # inside the one jitted dispatch (DESIGN.md section 12).
                p = _exec_reference_aged(A.at_blocks, A.da_blocks, xb, key,
                                         A.age, cfg=self.cfg, m=m, n=n,
                                         transpose=transpose)
                # Host-dispatched executes age the image by one read disturb
                # per call; traced executes (inside a solver's jit) advance
                # the ledger explicitly via A.age = A.age.advanced(mvms).
                if getattr(jax.core, "trace_state_clean", lambda: True)():
                    A.age = A.age.advanced(1)
            elif A.age is not None:
                raise ValueError(
                    "an AgeLedger is attached but this execution path cannot "
                    "apply it: aged execution needs execution='local', "
                    "backend='reference' and resident at/da blocks")
            elif A.da_blocks is None:
                # Streamed handle: dA is not resident; re-derive per block.
                p = self._exec_streamed(A, xb, key, transpose)
            elif self.backend == "pallas":
                if A._padded is None:
                    mb, nb, cm, cn = A.at_blocks.shape
                    padded = (_assemble(A.at_blocks, mb * cm, nb * cn),
                              _assemble(A.da_blocks, mb * cm, nb * cn))
                    # Only cache outside a trace: caching mid-trace would pin
                    # tracers on the handle and leak them into later calls
                    # (e.g. a solver's while_loop executing many MVMs).  If
                    # this jax has no trace_state_clean, skip caching -- the
                    # safe direction is recompute, never cache a maybe-tracer.
                    if getattr(jax.core, "trace_state_clean",
                               lambda: False)():
                        A._padded = padded
                else:
                    padded = A._padded
                run = _exec_pallas_t if transpose else _exec_pallas
                p = run(*padded, xb, key, cfg=self.cfg, m=m, n=n)
            else:
                run = _exec_reference_t if transpose else _exec_reference
                p = run(A.at_blocks, A.da_blocks, xb, key,
                        cfg=self.cfg, m=m, n=n)
        if with_stats and stats is None:
            stats = crossbar.input_write_cost(m, n, self.cfg,
                                              batch=xb.shape[1],
                                              transpose=transpose)
        return (p[:, 0] if squeeze else p), stats

    def _exec_streamed(self, A, xb, key, transpose=False):
        """Streamed execute: dA = block_fn - A_tilde is re-derived per
        capacity block (O(block) extra memory), so the streamed path never
        holds the source matrix twice.  Traceable producers run the
        scan-fused single-dispatch pipeline (forward or transposed); opaque
        ones take the compatibility host loop (one jitted dispatch per
        block)."""
        cfg = self.cfg
        if cfg.ec and cfg.ec_mode not in ("fused", "faithful"):
            raise ValueError(f"unknown first-order EC mode {cfg.ec_mode!r}")
        m, n = A.shape
        use_kernel = self.backend == "pallas" and cfg.ec
        if A.block_traceable:
            # Bounded LRU keyed INCLUDING the batch size: each jit object
            # holds exactly one compiled batch bucket, so a long-lived
            # serving handle cycling through buckets keeps at most
            # SCAN_CACHE_MAX live executables (eviction drops the jit object
            # and every trace inside it) instead of growing per
            # (backend, direction, batch) without bound.
            cache = _scan_cache(A)
            cache_key = (use_kernel, transpose, xb.shape[1])
            fn = cache.get(cache_key)
            if fn is None:
                stage = crossbar.streamed_block_rmvm if transpose \
                    else crossbar.streamed_block_mvm
                fn = jax.jit(functools.partial(
                    stage, A.block_fn,
                    cfg=cfg, m=m, n=n, use_kernel=use_kernel))
                cache.put(cache_key, fn)
            return fn(A.at_blocks, xb, key)
        return self._exec_streamed_host(A, xb, key, use_kernel, transpose)

    def _exec_dist_streamed(self, A, xb, key, transpose=False):
        """Producer-driven distributed execute: each device runs the
        scan-fused streamed pipeline over its window of the global block
        grid (one dispatch), partials psum over the contraction axis (the
        column axis forward, the ROW axes transposed), tier-2 denoises
        on-node, and the output stays sharded over the non-contracted axis.
        The jitted shard_map pipeline is cached on the handle per backend
        and direction, so solver loops re-enter a warm trace."""
        use_kernel = self._dist_use_kernel()
        cache = _scan_cache(A)
        cache_key = ("dist", use_kernel, A.at_blocks is not None, transpose,
                     xb.shape[1])
        fn = cache.get(cache_key)
        if fn is None:
            from repro.core import distributed as D
            m, n = A.shape
            mb, nb = A._grid()
            make = D.make_distributed_streamed_rmvm if transpose else \
                D.make_distributed_streamed_mvm
            fn = jax.jit(make(
                A.block_fn, self.cfg, self.mesh, self.row_axes, self.col_axis,
                m=m, n=n, mb=mb, nb=nb, resident=A.at_blocks is not None,
                use_kernel=use_kernel))
            cache.put(cache_key, fn)
        if A.at_blocks is not None:
            return fn(A.at_blocks, xb, key)
        return fn(xb, key)

    def _exec_streamed_host(self, A, xb, key, use_kernel, transpose=False):
        """The compat-only Python block loop (the one remaining in the repo):
        O(mb * nb) dispatches per MVM, kept for producers that cannot trace.
        Same per-block keys, draws and tile math as the scanned pipelines,
        in either direction (``transpose`` chunks the input over row blocks
        and accumulates over them -- the contraction axis of A^T)."""
        cfg = self.cfg
        m, n = A.shape
        mb, nb, cap_m, cap_n = A.at_blocks.shape
        batch = xb.shape[1]
        pad_to = mb * cap_m if transpose else nb * cap_n
        x_pad = jnp.pad(xb, ((0, pad_to - xb.shape[0]), (0, 0)))
        x_chunks = x_pad.reshape(mb if transpose else nb, -1, batch)
        keys = crossbar.block_keys(key, mb, nb)

        step = self._streamed_step.get((use_kernel, transpose))
        if step is None:
            def step(at_blk, a_blk, x_blk, k):
                _, k_x = jax.random.split(k)
                x_t = crossbar._encode_vec(x_blk, k_x, cfg) \
                    if cfg.encode_inputs else x_blk
                from repro.kernels import ops as kops
                if transpose:
                    if not cfg.ec:
                        return at_blk.T @ x_t
                    if use_kernel:
                        return kops.rram_ec_tile_rmvm(x_blk, x_t, at_blk,
                                                      a_blk - at_blk)
                    if cfg.ec_mode == "faithful":
                        return (at_blk.T @ x_blk + a_blk.T @ x_t
                                - at_blk.T @ x_t)
                    return at_blk.T @ x_blk + (a_blk - at_blk).T @ x_t
                if not cfg.ec:
                    return at_blk @ x_t
                if use_kernel:
                    return kops.rram_ec_tile_mvm(x_blk, x_t, at_blk,
                                                 a_blk - at_blk)
                if cfg.ec_mode == "faithful":
                    return at_blk @ x_blk + a_blk @ x_t - at_blk @ x_t
                return at_blk @ x_blk + (a_blk - at_blk) @ x_t

            # Jitted once per engine (per direction/backend): execute-many
            # calls reuse the trace.
            step = jax.jit(step)
            self._streamed_step[(use_kernel, transpose)] = step
        out_blocks, acc_cap = (nb, cap_n) if transpose else (mb, cap_m)
        rows = []
        for o in range(out_blocks):
            acc = jnp.zeros((acc_cap, batch), jnp.float32)
            for c in range(mb if transpose else nb):
                i, j = (c, o) if transpose else (o, c)
                acc = acc + step(A.at_blocks[i, j], A.block_fn(i, j),
                                 x_chunks[c], keys[i, j])
            rows.append(acc)
        p = jnp.concatenate(rows, axis=0)[:n if transpose else m]
        if cfg.ec:
            p = denoise_least_square(p, lam=cfg.lam, h=cfg.h,
                                     method=cfg.denoise_method)
        return p
