"""repro: MELISO+ (distributed RRAM in-memory computing with integrated
error correction) as a production-grade JAX training/inference framework.

The public serving surface is :class:`repro.engine.AnalogEngine` -- program a
matrix onto the analog system once, execute many corrected MVMs against it.
"""
__version__ = "1.1.0"

from repro.engine import AnalogEngine, AnalogMatrix  # noqa: E402,F401
