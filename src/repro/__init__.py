"""repro: MELISO+ (distributed RRAM in-memory computing with integrated
error correction) as a production-grade JAX training/inference framework."""
__version__ = "1.0.0"
