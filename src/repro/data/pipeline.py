"""Deterministic synthetic LM data pipeline, host-sharded and double-buffered.

Batches are a pure function of (seed, step, arch) -- restarts and elastic
rescales replay identical data (the fault-tolerance contract).  A background
prefetch thread overlaps host batch synthesis + device transfer with the
current step.  Tokens follow a Zipf-flavored unigram mix with a short Markov
flavor so the loss has learnable structure for the convergence tests.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["synthetic_batch", "Prefetcher", "batches"]


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.Generator(np.random.Philox(key=[seed, step]))
    v = cfg.vocab
    # Zipf unigram + first-order structure: next token correlated with prev.
    base = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    tok = (base + np.cumsum(base, axis=1)) % (v - 2) + 1
    tokens = tok.astype(np.int32)
    labels = np.concatenate([tokens[:, 1:], np.full((batch, 1), -1, np.int32)],
                            axis=1)
    out: Dict[str, Any] = {"tokens": tokens, "labels": labels}
    if cfg.family == "whisper":
        out["frames"] = rng.standard_normal((batch, seq, cfg.d_model)).astype(
            np.float32)
    if cfg.family == "llama_vision":
        out["patches"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.d_model)).astype(np.float32)
    return out


def batches(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
            start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, batch, seq, step, seed)
        step += 1


class Prefetcher:
    """Background thread: synthesize + device_put the next batch while the
    current step runs."""

    def __init__(self, it: Iterator, shardings: Optional[Any] = None, depth: int = 2):
        self.it = it
        self.shardings = shardings
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        for item in self.it:
            if self._stop.is_set():
                return
            if self.shardings is not None:
                item = jax.tree.map(
                    lambda a, s: jax.device_put(a, s), item, self.shardings)
            else:
                item = jax.tree.map(jnp.asarray, item)
            self.q.put(item)

    def __next__(self):
        return self.q.get()

    def __iter__(self):
        return self

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
