"""Production mesh topology.

A function, not a module-level constant: importing this module never touches
jax device state.  Single pod = 16x16 = 256 chips (v5e pod slice); multi-pod
adds a leading 2-wide "pod" axis (512 chips) used for data parallelism with
compressed cross-pod gradient reduction.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "make_production_mesh"]


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the jax version has them.

    jax < 0.5 has no ``jax.sharding.AxisType``; meshes there are implicitly
    Auto, so omitting the argument is the exact equivalent.
    """
    kw = {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
