"""Build the jitted (train|prefill|decode) step for an (arch x shape x mesh)
cell: the function, its abstract arguments, and in/out shardings.  Used by the
dry-run, the benchmarks, and the real launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, RRAMBackendConfig, TrainConfig
from repro.configs.registry import (batch_specs, decode_cache_specs,
                                    decode_cache_len, model_module)
from repro.distributed.sharding import (batch_pspec, cache_pspecs, data_axes,
                                        mesh_axis_sizes, param_pspecs)
from repro.models import params as PM
from repro.models.common import Runtime
from repro.models.rram import program_specs
from repro.train.optimizer import adamw_init

__all__ = ["CellSpec", "build_cell", "make_runtime"]


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one cell."""
    fn: Any                      # callable to jit
    args: Tuple                  # abstract (ShapeDtypeStruct) args
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...] = ()
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


def make_runtime(mesh: Mesh, rram: Optional[RRAMBackendConfig] = None,
                 **kw) -> Runtime:
    kw.setdefault("q_chunk", 512)     # bounds flash-attention block buffers
    kw.setdefault("kv_chunk", 512)
    return Runtime(rram=rram, mesh=mesh, batch_axes=data_axes(mesh),
                   key=None, **kw)


def _ns(mesh, tree):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree)


def build_cell(arch: ArchConfig, shape_name: str, mesh: Mesh,
               *,
               rram: Optional[RRAMBackendConfig] = None,
               tcfg: Optional[TrainConfig] = None,
               reduced: bool = False,
               runtime_kw: Optional[Dict] = None) -> CellSpec:
    shape = SHAPES[shape_name]
    cfg = arch.reduced() if reduced else arch.model
    mod = model_module(cfg)
    runtime_kw = dict(runtime_kw or {})
    if shape.kind == "train":
        # Static causal skip halves attention block work (-35% memory term,
        # EXPERIMENTS.md Perf T2); only for train seqs -- at 32k prefill the
        # unrolled block schedule would blow up compile time.
        runtime_kw.setdefault("causal_skip", True)
    rt = make_runtime(mesh, rram=rram, **runtime_kw)
    pd = jnp.dtype(cfg.param_dtype)

    specs = mod.init_specs(cfg)
    if rram is not None and rram.enabled:
        specs = program_specs(specs, rram)
    params_abs = PM.abstract(specs, pd)

    if shape.kind == "train":
        mode = arch.train_sharding
        pspecs = param_pspecs(specs, mesh, mode)
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        # ZeRO: optimizer state follows the FSDP rules even if params are TP.
        opt_pspecs = type(opt_abs)(
            m=param_pspecs(specs, mesh, "fsdp_tp"),
            v=param_pspecs(specs, mesh, "fsdp_tp"),
            count=P())
        bspecs = batch_specs(arch, shape, reduced)
        bps = jax.tree.map(
            lambda l: batch_pspec(l.shape, mesh, shape.global_batch), bspecs)
        dsz = 1
        for a in data_axes(mesh):
            dsz *= mesh_axis_sizes(mesh)[a]
        # 16 accumulation steps (1 sequence per device per microbatch at the
        # assigned shapes) bounds live activations; must stay divisible by
        # the data-parallel degree.
        micro = max(shape.global_batch // 16, dsz)
        tcfg = tcfg or TrainConfig(microbatch=micro, remat="block")
        from repro.train.train_loop import make_train_step
        fn = make_train_step(mod, cfg, tcfg, rt,
                             grad_shardings=_ns(mesh, param_pspecs(
                                 specs, mesh, "fsdp_tp")))
        metrics_sh = {"loss": P(), "grad_norm": P(), "lr": P()}
        return CellSpec(
            fn=fn,
            args=(params_abs, opt_abs, bspecs),
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_pspecs),
                          _ns(mesh, bps)),
            out_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_pspecs),
                           _ns(mesh, metrics_sh)),
            donate=(0, 1),
            meta={"kind": "train", "tokens": shape.global_batch * shape.seq_len},
        )

    # Inference sharding: TP keeps weights resident (no per-token gathers --
    # the earlier FSDP fallback for B=1 long-context traded 7 ms of HBM reads
    # for 210 ms of all-gathers per token; see EXPERIMENTS.md section Perf
    # iteration L1).
    pspecs = param_pspecs(specs, mesh, arch.infer_sharding)

    if shape.kind == "prefill":
        bspecs = batch_specs(arch, shape, reduced)
        bps = jax.tree.map(
            lambda l: batch_pspec(l.shape, mesh, shape.global_batch), bspecs)
        max_len = decode_cache_len(cfg, shape)

        def prefill_fn(params, batch):
            if cfg.family == "rwkv6":
                return mod.prefill(params, batch, cfg, rt)
            return mod.prefill(params, batch, cfg, rt, max_len)

        out_abs = jax.eval_shape(prefill_fn, params_abs, bspecs)
        vocab_ok = cfg.vocab % mesh_axis_sizes(mesh)["model"] == 0
        logits_sh = P(data_axes(mesh), None, "model" if vocab_ok else None)
        cache_sh = cache_pspecs(out_abs[1], mesh, shape.global_batch)
        return CellSpec(
            fn=prefill_fn,
            args=(params_abs, bspecs),
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, bps)),
            out_shardings=(_ns(mesh, logits_sh), _ns(mesh, cache_sh)),
            meta={"kind": "prefill",
                  "tokens": shape.global_batch * shape.seq_len},
        )

    # decode
    caches_abs = decode_cache_specs(arch, shape, reduced)
    tokens_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    cache_sh = cache_pspecs(caches_abs, mesh, shape.global_batch)
    tok_sh = batch_pspec(tokens_abs.shape, mesh, shape.global_batch)

    def decode_fn(params, tokens, caches):
        return mod.decode_step(params, tokens, caches, cfg, rt)

    vocab_ok = cfg.vocab % mesh_axis_sizes(mesh)["model"] == 0
    logits_sh = P(data_axes(mesh) if shape.global_batch > 1 else None,
                  None, "model" if vocab_ok else None)
    return CellSpec(
        fn=decode_fn,
        args=(params_abs, tokens_abs, caches_abs),
        in_shardings=(_ns(mesh, pspecs), NamedSharding(mesh, tok_sh),
                      _ns(mesh, cache_sh)),
        out_shardings=(NamedSharding(mesh, logits_sh), _ns(mesh, cache_sh)),
        donate=(2,),
        meta={"kind": "decode", "tokens": shape.global_batch},
    )
