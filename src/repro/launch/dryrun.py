import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape) cell
on the production meshes and record memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2x16x16
    PYTHONPATH=src python -m repro.launch.dryrun --cell meliso   # paper MVM

Results are cached one JSON per cell under experiments/dryrun/ (re-runs skip
cached cells unless --force); EXPERIMENTS.md section Dry-run/Roofline is
generated from these files by analysis/report.py.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.core.compat import set_mesh
import jax.numpy as jnp

from repro.analysis.model_flops import model_flops
from repro.analysis.roofline import analyze_compiled
from repro.configs import ARCHS, get_arch
from repro.configs.base import RRAMBackendConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_id(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    mesh = "pod2x16x16" if multi_pod else "16x16"
    suffix = f"_{tag}" if tag else ""
    return f"{arch}_{shape}_{mesh}{suffix}".replace("/", "-")


def run_lm_cell(arch_name: str, shape_name: str, multi_pod: bool,
                rram: bool = False, runtime_kw: Optional[Dict] = None,
                dump_hlo: Optional[str] = None,
                micro: Optional[int] = None) -> Dict:
    arch = get_arch(arch_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rcfg = RRAMBackendConfig(enabled=True) if rram else None
    tcfg = None
    if micro:
        from repro.configs.base import TrainConfig
        tcfg = TrainConfig(microbatch=micro, remat="block")
    cell = build_cell(arch, shape_name, mesh, rram=rcfg,
                      runtime_kw=runtime_kw, tcfg=tcfg)

    t0 = time.perf_counter()
    with set_mesh(mesh):
        jitted = jax.jit(cell.fn,
                         in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mf = model_flops(arch, shape_name)
    rec = analyze_compiled(compiled, mesh.size, model_flops=mf["model_flops"])
    rec.update({
        "arch": arch_name, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "kind": cell.meta["kind"], "rram": rram,
        "params": mf["params"], "active_params": mf["active_params"],
        "lower_s": t_lower, "compile_s": t_compile,
        "runtime_kw": {k: str(v) for k, v in (runtime_kw or {}).items()},
    })
    print(compiled.memory_analysis())
    print({k: v for k, v in (compiled.cost_analysis() or {}).items()
           if k in ("flops", "bytes accessed")})
    if dump_hlo:
        with open(dump_hlo, "w") as f:
            f.write(compiled.as_text())
    return rec


def run_meliso_cell(multi_pod: bool, n: int = 65536,
                    ec: bool = True, ec_mode: str = "fused",
                    denoise: str = "neumann", cell_size: int = 512,
                    dump_hlo: Optional[str] = None,
                    prng: str = "threefry") -> Dict:
    """The paper's own workload: distributed two-tier-EC MVM at 65,536^2."""
    from repro.core import CrossbarConfig, MCAGeometry, get_device
    from repro.core.distributed import (make_distributed_program,
                                        make_distributed_programmed_mvm)

    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    row_axes = tuple(a for a in ("pod", "data") if a in axes)
    rows_div = 1
    for a in row_axes:
        rows_div *= axes[a]
    local_m, local_n = n // rows_div, n // axes["model"]
    geom = MCAGeometry(tile_rows=max(local_m // cell_size, 1),
                       tile_cols=max(local_n // cell_size, 1),
                       cell_rows=cell_size, cell_cols=cell_size)
    ccfg = CrossbarConfig(device=get_device("taox-hfox"), geom=geom,
                          k_iters=5, ec=ec, ec_mode=ec_mode,
                          denoise_method=denoise)
    # Lower the full program+execute pipeline (the one-shot serving shape).
    program = make_distributed_program(ccfg, mesh, row_axes, "model")
    execute = make_distributed_programmed_mvm(
        ccfg, mesh, row_axes, "model", stats_include_matrix=True)

    def fn(a, x, key):
        at, da, _ = program(a, key)
        return execute(at, da, x, key)

    a_abs = jax.ShapeDtypeStruct((n, n), jnp.float32)
    x_abs = jax.ShapeDtypeStruct((n, 1), jnp.float32)
    # prng="rbg": hardware rng-bit-generator -- one pass, no threefry counter
    # arrays (EXPERIMENTS.md Perf M2); threefry is the reproducible default.
    # "threefry" is accepted as an alias; jax registers it as "threefry2x32".
    impl = {"threefry": "threefry2x32"}.get(prng, prng)
    key_abs = jax.eval_shape(lambda: jax.random.key(0, impl=impl))

    t0 = time.perf_counter()
    with set_mesh(mesh):
        lowered = jax.jit(fn).lower(a_abs, x_abs, key_abs)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    # Useful compute: tier-1 EC = 2 matmuls (fused) or 3 (faithful) + denoise.
    mm = 2 if (ec and ec_mode == "fused") else (3 if ec else 1)
    useful = 2.0 * n * n * mm
    rec = analyze_compiled(compiled, mesh.size, model_flops=useful)
    rec.update({
        "arch": "meliso-mvm", "shape": f"mvm_{n}",
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "kind": "mvm", "ec": ec, "ec_mode": ec_mode, "denoise": denoise,
        "cell_size": cell_size, "prng": prng,
        "lower_s": t_lower, "compile_s": t_compile,
    })
    print(compiled.memory_analysis())
    if dump_hlo:
        with open(dump_hlo, "w") as f:
            f.write(compiled.as_text())
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cell", default=None, choices=[None, "meliso"],
                    help="special non-LM cells")
    ap.add_argument("--rram", action="store_true",
                    help="lower the serve step on the analog RRAM backend")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--dump-hlo", default=None)
    ap.add_argument("--runtime-kw", default=None,
                    help="JSON dict of Runtime overrides (perf experiments)")
    ap.add_argument("--micro", type=int, default=None,
                    help="global microbatch override (perf experiments)")
    ap.add_argument("--prng", default="threefry",
                    help="meliso cell PRNG impl (threefry | rbg)")
    ap.add_argument("--ec-mode", default="fused", choices=["fused", "faithful"])
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    meshes = ([False, True] if args.both_meshes
              else [args.multi_pod])
    runtime_kw = json.loads(args.runtime_kw) if args.runtime_kw else None

    if args.cell == "meliso":
        for mp in meshes:
            cid = cell_id("meliso-mvm", "mvm_65k", mp, args.tag)
            path = os.path.join(OUT_DIR, cid + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[cached] {cid}")
                continue
            print(f"[run] {cid}")
            rec = run_meliso_cell(mp, dump_hlo=args.dump_hlo, prng=args.prng,
                                  ec_mode=args.ec_mode)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        return

    archs = [args.arch] if args.arch else list(ARCHS)
    n_ok = n_fail = 0
    for arch_name in archs:
        arch = get_arch(arch_name)
        shapes = [args.shape] if args.shape else list(arch.shapes)
        for shape_name in shapes:
            if shape_name not in arch.shapes:
                print(f"[skip] {arch_name} x {shape_name}: "
                      f"{dict(arch.skip_reasons).get(shape_name, 'not in arch.shapes')}")
                continue
            for mp in meshes:
                tag = (args.tag + ("_rram" if args.rram else "")).strip("_")
                cid = cell_id(arch_name, shape_name, mp, tag)
                path = os.path.join(OUT_DIR, cid + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {cid}")
                    continue
                print(f"[run] {cid}", flush=True)
                try:
                    rec = run_lm_cell(arch_name, shape_name, mp,
                                      rram=args.rram, runtime_kw=runtime_kw,
                                      dump_hlo=args.dump_hlo, micro=args.micro)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[ok] {cid}: dominant={rec['dominant']} "
                          f"compute={rec['compute_s']:.3e}s "
                          f"mem={rec['memory_s']:.3e}s "
                          f"coll={rec['collective_s']:.3e}s "
                          f"fits={rec['memory']['fits_hbm']}", flush=True)
                    n_ok += 1
                except Exception:
                    n_fail += 1
                    err = traceback.format_exc()
                    print(f"[FAIL] {cid}\n{err}", flush=True)
                    with open(path + ".err", "w") as f:
                        f.write(err)
    print(f"dryrun complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
