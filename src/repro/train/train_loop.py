"""Training loop: jitted microbatched train step + the production driver
(checkpointing, preemption, watchdog, deterministic data).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.common import Runtime
from repro.distributed import (PREEMPTED, CheckpointManager, Watchdog,
                               install_preemption_handler)
from .optimizer import OptState, adamw_init, adamw_update

__all__ = ["make_train_step", "Trainer"]


def make_train_step(mod, cfg: ModelConfig, tcfg: TrainConfig,
                    rt: Optional[Runtime] = None,
                    grad_shardings=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With ``tcfg.microbatch`` set, the global batch is split into
    B/microbatch accumulation steps via lax.scan (fp32 grad accumulators);
    remat policy is threaded through ``rt.remat``.  ``grad_shardings``
    (optional NamedSharding tree matching params) pins per-microbatch grads
    to the ZeRO layout so GSPMD emits reduce-scatters instead of full
    all-reduces inside the accumulation loop (EXPERIMENTS.md section Perf).
    """
    rt = rt or Runtime()
    rt.remat = tcfg.remat if tcfg.remat != "none" else rt.remat

    def loss_fn(p, mb):
        return mod.loss(p, mb, cfg, rt)

    def train_step(params, opt_state: OptState, batch):
        bsz = batch["tokens"].shape[0]
        if tcfg.microbatch and tcfg.microbatch < bsz:
            n_acc = bsz // tcfg.microbatch
            mb_batch = jax.tree.map(
                lambda a: a.reshape((n_acc, tcfg.microbatch) + a.shape[1:]),
                batch)
            if rt.mesh is not None:
                # The (B,) -> (n_acc, micro) reshape is ambiguous to GSPMD;
                # without this constraint it may shard the *accumulation* dim
                # and leave the microbatch unsharded on every device.
                from jax.sharding import PartitionSpec as P
                mb_batch = jax.tree.map(
                    lambda a: jax.lax.with_sharding_constraint(
                        a, P(None, rt.batch_axes,
                             *([None] * (a.ndim - 2)))),
                    mb_batch)

            def body(acc, mb):
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                if grad_shardings is not None:
                    grads = jax.tree.map(
                        lambda g, s: jax.lax.with_sharding_constraint(g, s),
                        grads, grad_shardings)
                acc_loss, acc_grads = acc
                acc_grads = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc_grads, grads)
                return (acc_loss + loss, acc_grads), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss_sum, grads), _ = jax.lax.scan(body, zero, mb_batch)
            loss = loss_sum / n_acc
            grads = jax.tree.map(lambda g: g / n_acc, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        params, opt_state, metrics = adamw_update(grads, opt_state, params, tcfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


@dataclasses.dataclass
class Trainer:
    """Production driver: deterministic data, async checkpoints, preemption
    handling and straggler watchdog around a jitted train step."""

    mod: Any
    cfg: ModelConfig
    tcfg: TrainConfig
    params: Any
    opt_state: Optional[OptState] = None
    rt: Optional[Runtime] = None
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 100
    step: int = 0
    watchdog: Watchdog = dataclasses.field(default_factory=Watchdog)
    donate: bool = True

    def __post_init__(self):
        if self.opt_state is None:
            self.opt_state = adamw_init(self.params)
        self.rt = self.rt or Runtime()
        install_preemption_handler()
        step_fn = make_train_step(self.mod, self.cfg, self.tcfg, self.rt)
        self._step_fn = jax.jit(
            step_fn, donate_argnums=(0, 1) if self.donate else ())

    # ------------------------------------------------------------------ API
    def state(self):
        return {"params": self.params, "opt": self.opt_state._asdict()}

    def save(self, blocking: bool = False):
        if self.ckpt:
            self.ckpt.save(self.step, self.state(), blocking=blocking,
                           extra={"step": self.step})

    def restore(self, step: Optional[int] = None, shardings=None):
        tree = self.ckpt.restore(self.state(), step=step, shardings=shardings)
        self.params = tree["params"]
        self.opt_state = OptState(**tree["opt"])
        self.step = int(self.opt_state.count)

    def run(self, data_iter, n_steps: int) -> Dict[str, list]:
        history = {"loss": [], "grad_norm": [], "step_time": []}
        for _ in range(n_steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            metrics["loss"].block_until_ready()
            dt = time.perf_counter() - t0
            self.step += 1
            history["loss"].append(float(metrics["loss"]))
            history["grad_norm"].append(float(metrics["grad_norm"]))
            history["step_time"].append(dt)
            self.watchdog.record(self.step, dt)
            if self.ckpt and self.step % self.ckpt_every == 0:
                self.save()
            if PREEMPTED.is_set():
                self.save(blocking=True)
                break
        if self.ckpt:
            self.ckpt.wait()
        return history
