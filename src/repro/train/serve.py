"""Serving: prefill + greedy decode drivers, with optional RRAM analog
backend (the paper's technique as a deployment mode -- weights are programmed
onto an :class:`~repro.engine.AnalogEngine` exactly once at server
construction; per-token MVMs then run through the two-tier-EC analog
simulation with zero re-encode work, so decode steps pay only the input-DAC
cost).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.engine import AnalogEngine
from repro.models.common import Runtime
from repro.models.rram import crossbar_cfg, program_rram

__all__ = ["Server", "greedy_generate"]


@dataclasses.dataclass
class Server:
    mod: Any
    cfg: ModelConfig
    params: Any
    rt: Optional[Runtime] = None
    max_len: int = 512
    write_stats: Any = None     # one-time analog programming cost (rram backend)
    engine: Optional[AnalogEngine] = None   # the programmed analog engine

    def __post_init__(self):
        self.rt = self.rt or Runtime()
        if self.rt.rram is not None and self.rt.rram.enabled:
            self.engine = self.engine or AnalogEngine(crossbar_cfg(self.rt.rram))
            self.params, self.write_stats = program_rram(
                self.params, self.rt.rram, jax.random.PRNGKey(7),
                engine=self.engine)
        self._prefill = jax.jit(
            lambda p, b: self.mod.prefill(p, b, self.cfg, self.rt, self.max_len))
        self._decode = jax.jit(
            lambda p, t, c: self.mod.decode_step(p, t, c, self.cfg, self.rt))

    def generate(self, batch: Dict, n_tokens: int) -> jnp.ndarray:
        """Greedy continuation of ``batch['tokens']`` (B, T) -> (B, n_tokens)."""
        logits, caches = self._prefill(self.params, batch)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        for _ in range(n_tokens - 1):
            logits, caches = self._decode(self.params, tok, caches)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)


def greedy_generate(mod, params, cfg: ModelConfig, batch: Dict,
                    n_tokens: int, rt: Optional[Runtime] = None,
                    max_len: int = 512) -> jnp.ndarray:
    return Server(mod, cfg, params, rt=rt, max_len=max_len).generate(
        batch, n_tokens)
