"""Serving: prefill + scan-fused greedy decode, with optional RRAM analog
backend (the paper's technique as a deployment mode -- weights are programmed
onto an :class:`~repro.engine.AnalogEngine` exactly once at server
construction; per-token MVMs then run through the two-tier-EC analog
simulation with zero re-encode work, so decode steps pay only the input-DAC
cost).

Dispatch discipline: ``generate`` is TWO device dispatches total -- one jitted
prefill and one jitted ``lax.scan`` over the whole token axis (the PR 3
dispatch-fusion pattern applied to decode).  The per-token Python loop of the
seed implementation (one dispatch per token) is gone; the
``repro.analysis.verify`` DispatchCount pass pins the fused pipeline in the
invariant manifest (see :func:`Server.decode_fn` and
:mod:`repro.analysis.pipelines`).

Programming is factored out of construction: a :class:`Server` built with
already-programmed params (``w_tilde``/``dw`` present -- e.g. handed out by
the :mod:`repro.serving` image cache) skips ``program_rram`` entirely, so a
cache hit pays zero write cost.  The programming PRNG key is injectable
(``key=``): two tenants programming the SAME weights under different keys get
independent device draws (required for honest cache-reprogram noise
statistics; the seed's hardcoded ``PRNGKey(7)`` remains the default).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.engine import AnalogEngine, _BoundedCache
from repro.models.common import Runtime
from repro.models.rram import crossbar_cfg, is_programmed, program_rram, \
    programming_dispatch_plan

__all__ = ["Server", "greedy_generate"]


@dataclasses.dataclass
class Server:
    """One deployed model instance: programmed weights + jitted step fns.

    ``key`` seeds BOTH the one-time analog programming draws and the runtime
    DAC noise schedule (prefill consumes fold 1.0, decode step ``t`` consumes
    fold 1.(t+1)); pass per-tenant keys so cache entries for the same weights
    carry independent conductance noise.  ``engine``/``write_stats`` may be
    supplied by a cache along with pre-programmed ``params``; programming runs
    here only when the params are not yet programmed.
    """

    mod: Any
    cfg: ModelConfig
    params: Any
    rt: Optional[Runtime] = None
    max_len: int = 512
    write_stats: Any = None     # one-time analog programming cost (rram backend)
    engine: Optional[AnalogEngine] = None   # the programmed analog engine
    key: Optional[jax.Array] = None         # programming + DAC noise key

    def __post_init__(self):
        self.rt = self.rt or Runtime()
        if self.key is None:
            self.key = jax.random.PRNGKey(7)
        # programming dispatches this construction actually paid: 0 for a
        # cache hit (already-programmed params) or the digital baseline,
        # O(distinct kernel shapes) for the grouped program_rram walk.
        self.program_dispatches = 0
        if self.rt.rram is not None and self.rt.rram.enabled:
            self.engine = self.engine or AnalogEngine(crossbar_cfg(self.rt.rram))
            if not is_programmed(self.params):
                self.params, self.write_stats = program_rram(
                    self.params, self.rt.rram, self.key, engine=self.engine)
                self.program_dispatches = \
                    programming_dispatch_plan(self.params)["groups"]
        self._prefill = jax.jit(self._prefill_fn)
        # jitted fused decode scans keyed by n_tokens: a bounded LRU (one
        # compiled executable per bucket), so a long-lived server cycling
        # through many decode buckets holds a fixed number of pipelines.
        self._decode = _BoundedCache()

    def _rt_for(self, key: jax.Array) -> Runtime:
        """A fresh Runtime carrying ``key`` (``key`` may be a tracer)."""
        return dataclasses.replace(self.rt, key=key, _salt=0)

    def _prefill_fn(self, params, batch) -> Tuple[jnp.ndarray, Any]:
        """(first greedy token (B, 1) int32, filled caches)."""
        rt = self._rt_for(jax.random.fold_in(self._noise_base(), 0))
        logits, caches = self.mod.prefill(params, batch, self.cfg, rt,
                                          self.max_len)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return tok, caches

    def _noise_base(self) -> jax.Array:
        """Runtime DAC-noise base key (distinct from the programming draws
        consumed directly off ``self.key`` by ``program_rram``)."""
        if self.rt.key is not None:
            return self.rt.key
        return jax.random.fold_in(self.key, 1)

    def _decode_scan(self, n: int):
        """The fused decode pipeline: ONE ``lax.scan`` over the token axis.

        Returns the jitted ``(params, tok, caches) -> ((B, n) tokens, caches)``
        callable; step ``t`` consumes its own fold of the noise base key, so
        successive decode steps draw independent DAC noise (the seed's
        per-token Python loop reused one trace -- and one key -- per step).
        """
        fn = self._decode.get(n)
        if fn is not None:
            return fn
        base = self._noise_base()

        def run(params, tok, caches):
            def body(carry, t):
                tok, caches = carry
                rt = self._rt_for(jax.random.fold_in(base, t + 1))
                logits, caches = self.mod.decode_step(params, tok, caches,
                                                      self.cfg, rt)
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                tok = tok.astype(jnp.int32)
                return (tok, caches), tok[:, 0]

            (tok, caches), toks = jax.lax.scan(
                body, (tok, caches), jnp.arange(n, dtype=jnp.int32))
            return toks.T, caches           # (B, n)

        fn = jax.jit(run)
        self._decode.put(n, fn)
        return fn

    def dispatches_per_batch(self, n_tokens: int) -> int:
        """Device dispatches one ``generate`` call costs: one jitted prefill
        plus (for ``n_tokens > 1``) ONE fused decode scan -- O(1) in both the
        token count and the model's layer count."""
        return 1 if n_tokens == 1 else 2

    def prefill(self, batch: Dict) -> Tuple[jnp.ndarray, Any]:
        """One jitted prefill dispatch: (first token (B, 1), caches)."""
        return self._prefill(self.params, batch)

    def decode_tokens(self, tok: jnp.ndarray, caches: Any,
                      n: int) -> Tuple[jnp.ndarray, Any]:
        """Greedy-decode ``n`` tokens after ``tok`` in ONE fused dispatch."""
        return self._decode_scan(n)(self.params, tok, caches)

    def decode_fn(self, n: int):
        """The jitted fused decode callable, for jaxpr-level verification.

        ``repro.analysis.pipelines`` traces this with ShapeDtypeStruct
        placeholders and the DispatchCount pass asserts the whole ``n``-token
        decode is a single device dispatch (see DESIGN.md section 10)."""
        fused = self._decode_scan(n)
        return lambda tok, caches: fused(self.params, tok, caches)

    def generate(self, batch: Dict, n_tokens: int) -> jnp.ndarray:
        """Greedy continuation of ``batch['tokens']`` (B, T) -> (B, n_tokens).

        One prefill dispatch + ONE fused decode dispatch, any ``n_tokens``.
        """
        tok, caches = self.prefill(batch)
        if n_tokens == 1:
            return tok
        toks, _ = self.decode_tokens(tok, caches, n_tokens - 1)
        return jnp.concatenate([tok, toks], axis=1)


def greedy_generate(mod, params, cfg: ModelConfig, batch: Dict,
                    n_tokens: int, rt: Optional[Runtime] = None,
                    max_len: int = 512) -> jnp.ndarray:
    return Server(mod, cfg, params, rt=rt, max_len=max_len).generate(
        batch, n_tokens)
