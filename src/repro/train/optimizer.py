"""AdamW with global-norm clipping and warmup+cosine schedule (optax is not
installed; this is the framework's own optimizer, ZeRO-shardable).

State is a pytree {m, v, count}; m/v are fp32 regardless of param dtype
(mixed-precision master statistics).  Under ``fsdp_tp`` sharding rules the
state is sharded over (data, model) -- ZeRO-1 -- because the state trees reuse
the parameter logical axes.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

__all__ = ["OptState", "adamw_init", "adamw_update", "lr_schedule",
           "global_norm"]


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params),
                    count=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: TrainConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads, state: OptState, params, cfg: TrainConfig,
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    count = state.count + 1
    lr = lr_schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                     state.v, grads)
    c = count.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1 ** c)
    vhat_scale = 1.0 / (1 - b2 ** c)

    def upd(p, m_, v_):
        step_ = m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + 1e-8)
        step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(m, v, count), metrics
