from .optimizer import OptState, adamw_init, adamw_update, lr_schedule
from .train_loop import Trainer, make_train_step
from .serve import Server, greedy_generate
