"""repro.serving -- analog LM serving under synthetic traffic.

The paper's "LLM/generative-AI" claim made measurable: a multi-tenant serving
stack over the program-once analog engine.  ``traffic`` draws deterministic
request traces (Poisson arrivals, Zipf tenant skew); ``cache`` keeps
programmed images under a capacity budget with write-cost-aware eviction (the
``SolveLedger`` one-time-write vs per-MVM split as the eviction signal);
``batching`` packs compatible requests at padded bucket shapes; ``metrics``
accounts tokens/sec, tail latency, and joules-per-token on the simulated
clock; ``simulator`` ties them into one deterministic event loop driving real
``Server`` prefill + scan-fused decode.  See docs/serving.md.
"""
from .batching import Batch, BatchingConfig, RequestQueue, bucket_for
from .cache import CacheEntry, CacheOutcome, CacheOverBudgetError, \
    ImageCache, POLICIES
from .metrics import DIGITAL_FLOPS_PER_S, DIGITAL_J_PER_FLOP, \
    MetricsAccumulator, RequestRecord, digital_cost, percentile
from .simulator import ReliabilityConfig, ServingConfig, SimResult, simulate
from .traffic import Request, TenantSpec, TrafficConfig, generate_trace, \
    zipf_weights

__all__ = [
    "Batch", "BatchingConfig", "RequestQueue", "bucket_for",
    "CacheEntry", "CacheOutcome", "CacheOverBudgetError", "ImageCache",
    "POLICIES",
    "DIGITAL_FLOPS_PER_S", "DIGITAL_J_PER_FLOP", "MetricsAccumulator",
    "RequestRecord", "digital_cost", "percentile",
    "ReliabilityConfig", "ServingConfig", "SimResult", "simulate",
    "Request", "TenantSpec", "TrafficConfig", "generate_trace",
    "zipf_weights",
]
