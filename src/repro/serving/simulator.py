"""Deterministic event-loop serving simulator: traffic -> cache -> batcher ->
prefill/fused-decode.

One simulated analog engine serves a multi-tenant request trace.  The loop:

  1. if nothing has arrived, jump the clock to the next arrival;
  2. pack a batch around the oldest waiting request
     (:class:`~repro.serving.batching.RequestQueue` -- head-of-line FIFO);
  3. acquire the tenant's programmed image from the
     :class:`~repro.serving.cache.ImageCache` -- a miss runs
     ``program_rram``/``reprogram_rram`` under a fresh per-build key and
     stalls the engine for the write-verify latency;
  4. execute the batch through the REAL :class:`~repro.train.serve.Server`
     numerics (one jitted prefill + ONE scan-fused decode dispatch) at the
     padded bucket shapes, while the analytic cost model
     (:func:`~repro.models.rram.forward_input_stats` /
     :func:`~repro.serving.metrics.digital_cost`) advances the simulated
     clock and energy ledgers;
  5. record each member's finish at its OWN last token (shorter members of a
     batch finish before the batch's padded decode completes).

Everything observable -- request order, eviction sequence, latencies, joules
-- is a pure function of the config; the replay test runs ``simulate`` twice
in one process and asserts identical records and summaries.

Model execution can be disabled (``run_model=False``) for policy sweeps where
only the clock/energy trajectory matters; metrics are identical either way
because service costs are analytic (the numerics validate the pipeline and
return the actual greedy tokens).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import RRAMBackendConfig
from repro.configs.registry import get_arch, model_module
from repro.core.devices import get_device
from repro.core.write_verify import WriteStats
from repro.models import params as P
from repro.models.common import Runtime
from repro.models.rram import analog_image_bytes, forward_input_stats, \
    strip_rram
from repro.reliability.aging import predicted_residual
from repro.train.serve import Server

from .batching import Batch, BatchingConfig, RequestQueue
from .cache import ImageCache
from .metrics import MetricsAccumulator, RequestRecord, digital_cost
from .traffic import TenantSpec, TrafficConfig, generate_trace

__all__ = ["ReliabilityConfig", "ServingConfig", "SimResult", "simulate"]


@dataclasses.dataclass(frozen=True)
class ReliabilityConfig:
    """Online-refresh scheduling for long-lived serving deployments.

    Cached images age on the simulated clock (conductance drift) and with
    every token served (read-disturb faults).  Before serving a resident
    image the scheduler evaluates the analytic health proxy
    :func:`repro.reliability.aging.predicted_residual` and refreshes in
    place when the AGING EXCESS -- ``sqrt(predicted^2 - fresh^2)``, the
    quadrature contribution of drift + stuck cells over the fresh
    programming floor -- exceeds ``refresh_threshold``.  Thresholding the
    excess (not the total) makes the knob device-independent and prevents
    a refresh storm when the threshold is set below a device's noise floor
    (refresh cannot go below the floor, so comparing the total would
    re-trigger on every batch forever).  A refresh stalls the engine for
    ``refresh_fraction`` of the tenant's full build latency and bills the
    same fraction of its write energy (the tile-selective amortization
    measured numerically in ``repro.reliability.refresh``)."""

    refresh_threshold: float = 0.05
    refresh_fraction: float = 0.25   # tile-selective cost vs full reprogram


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """One serving scenario: who sends traffic, on what backend, under which
    cache policy.  ``rram=None`` is the digital fp32 baseline (no programming,
    no cache pressure -- weights live in DRAM)."""

    tenants: Tuple[TenantSpec, ...]
    traffic: TrafficConfig
    batching: BatchingConfig = BatchingConfig()
    rram: Optional[RRAMBackendConfig] = None
    cache_capacity_bytes: int = 1 << 30
    policy: str = "write_cost"
    seed: int = 0
    max_len: int = 128
    run_model: bool = True
    reliability: Optional[ReliabilityConfig] = None


@dataclasses.dataclass
class SimResult:
    summary: Dict[str, Any]
    records: Tuple[RequestRecord, ...]
    cache_stats: Optional[Dict[str, Any]]


def _digital_params(arch_name: str, seed: int):
    """(cfg, mod, digital params, n_params) for one zoo arch, reduced."""
    cfg = get_arch(arch_name).reduced()
    mod = model_module(cfg)
    prm = P.materialize(mod.init_specs(cfg), jax.random.PRNGKey(seed),
                        jnp.float32)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(prm)
                   if hasattr(x, "shape"))
    return cfg, mod, prm, n_params


def _batch_inputs(batch: Batch, cfg) -> Dict[str, jnp.ndarray]:
    """Synthesize the padded model inputs for one batch, deterministically
    from each request's ``token_seed`` (pad rows repeat the last member)."""
    rows = []
    for r in batch.requests:
        rng = np.random.Generator(np.random.PCG64(r.token_seed))
        rows.append(rng.integers(0, cfg.vocab, size=batch.prompt_bucket))
    while len(rows) < batch.batch_pad:
        rows.append(rows[-1])
    out: Dict[str, jnp.ndarray] = {
        "tokens": jnp.asarray(np.stack(rows), dtype=jnp.int32)}
    if cfg.family == "whisper":
        out["frames"] = _extra_feature(
            batch, (batch.prompt_bucket, cfg.d_model))
    elif cfg.family == "llama_vision":
        out["patches"] = _extra_feature(
            batch, (cfg.n_patches, cfg.d_model))
    return out


def _extra_feature(batch: Batch, shape: Tuple[int, ...]) -> jnp.ndarray:
    rows = []
    for r in batch.requests:
        rng = np.random.Generator(np.random.PCG64(r.token_seed + 1))
        rows.append(rng.standard_normal(size=shape) * 0.1)
    while len(rows) < batch.batch_pad:
        rows.append(rows[-1])
    return jnp.asarray(np.stack(rows), dtype=jnp.float32)


class _Fleet:
    """Per-tenant Server acquisition through the image cache.

    Digital weights are materialized ONCE per arch and shared by every tenant
    of that arch; each (tenant, build) programs its own analog image under
    ``fold_in(base, tenant_index, build_count)`` -- independent device draws
    per tenant and per reprogram."""

    def __init__(self, cfg: ServingConfig):
        self.cfg = cfg
        self._arch: Dict[str, Tuple[Any, Any, Any, int]] = {}
        self._builds: Dict[str, int] = {}
        self._tenant_ix = {t.name: i for i, t in enumerate(cfg.tenants)}
        self._tenant_arch = {t.name: t.arch for t in cfg.tenants}
        self._digital_servers: Dict[str, Server] = {}
        self.cache: Optional[ImageCache] = None
        if cfg.rram is not None:
            self.cache = ImageCache(cfg.cache_capacity_bytes, cfg.policy)
        # per-tenant age of the CURRENT resident image: (programmed-at
        # sim-time, tokens served since).  Reset on build and on refresh.
        self._age: Dict[str, Tuple[float, float]] = {}

    def note_programmed(self, tenant: str, now: float) -> None:
        self._age[tenant] = (now, 0.0)

    def note_served(self, tenant: str, tokens: int) -> None:
        t0, mvms = self._age.get(tenant, (0.0, 0.0))
        self._age[tenant] = (t0, mvms + float(tokens))

    def predicted(self, tenant: str, now: float) -> float:
        """Analytic health of the tenant's resident image at sim-time now."""
        rram = self.cfg.rram
        assert rram is not None
        t0, mvms = self._age.get(tenant, (now, 0.0))
        return predicted_residual(get_device(rram.device),
                                  k_iters=rram.k_iters,
                                  seconds=max(0.0, now - t0), mvms=mvms,
                                  n=rram.cell_rows)

    def aging_excess(self, tenant: str, now: float) -> float:
        """Drift + stuck-cell contribution over the fresh programming floor
        (quadrature residue) -- what a refresh can actually remove."""
        rram = self.cfg.rram
        assert rram is not None
        fresh = predicted_residual(get_device(rram.device),
                                   k_iters=rram.k_iters, seconds=0.0,
                                   mvms=0.0, n=rram.cell_rows)
        pred = self.predicted(tenant, now)
        return max(0.0, pred * pred - fresh * fresh) ** 0.5

    def refresh_stats(self, tenant: str, fraction: float) -> WriteStats:
        """Tile-selective refresh cost: ``fraction`` of the tenant's full
        build write-verify cost (energy AND latency scale with tiles)."""
        assert self.cache is not None
        full = self.cache.entries[tenant].write_stats
        return WriteStats(energy_j=full.energy_j * fraction,
                          latency_s=full.latency_s * fraction,
                          iterations=full.iterations,
                          final_delta=full.final_delta)

    def arch_state(self, arch: str):
        if arch not in self._arch:
            self._arch[arch] = _digital_params(arch, self.cfg.seed)
        return self._arch[arch]

    def n_params(self, arch: str) -> int:
        return self.arch_state(arch)[3]

    def acquire(self, tenant: str, now: float) -> Tuple[Server, Any]:
        """(server, cache outcome or None).  Analog: through the cache, a
        miss programs (stalling for write latency is the caller's job, via
        the outcome's write_stats)."""
        arch = self._tenant_arch[tenant]
        cfg, mod, prm, _ = self.arch_state(arch)
        if self.cache is None:
            srv = self._digital_servers.get(tenant)
            if srv is None:
                srv = Server(mod, cfg, prm, rt=Runtime(),
                             max_len=self.cfg.max_len,
                             key=jax.random.PRNGKey(self.cfg.seed))
                self._digital_servers[tenant] = srv
            return srv, None

        def build():
            n = self._builds.get(tenant, 0)
            self._builds[tenant] = n + 1
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed),
                                   self._tenant_ix[tenant]), n)
            srv = Server(mod, cfg, strip_rram(prm),
                         rt=Runtime(rram=self.cfg.rram),
                         max_len=self.cfg.max_len, key=key)
            return srv, analog_image_bytes(srv.params), srv.write_stats

        return self.cache.get(tenant, build, now)


def simulate(cfg: ServingConfig) -> SimResult:
    """Run the trace to completion; returns summary + per-request records."""
    trace = generate_trace(cfg.tenants, cfg.traffic)
    queue = RequestQueue(cfg.batching)
    for r in trace:
        queue.add(r)
    fleet = _Fleet(cfg)
    metrics = MetricsAccumulator()
    now = 0.0

    while len(queue):
        batch = queue.form_batch(now)
        if batch is None:
            nxt = queue.next_arrival(now)
            assert nxt is not None, "queue non-empty but nothing arriving"
            now = nxt
            continue

        server, outcome = fleet.acquire(batch.tenant, now)
        if outcome is not None and not outcome.hit:
            # reprogramming stalls the engine for the write-verify latency
            now += float(outcome.write_stats.latency_s)
            fleet.note_programmed(batch.tenant, now)
            metrics.add_program_dispatches(server.program_dispatches)
        elif outcome is not None and cfg.reliability is not None:
            # resident image: check analytic health before serving from it
            if fleet.aging_excess(batch.tenant, now) \
                    > cfg.reliability.refresh_threshold:
                rs = fleet.refresh_stats(batch.tenant,
                                         cfg.reliability.refresh_fraction)
                now += float(rs.latency_s)          # refresh stalls the engine
                fleet.cache.note_refresh(batch.tenant, rs)
                metrics.add_refresh(float(rs.energy_j), float(rs.latency_s))
                fleet.note_programmed(batch.tenant, now)
        if outcome is not None and cfg.reliability is not None:
            # the health this batch is actually served at (post any refresh)
            metrics.add_health(fleet.predicted(batch.tenant, now))

        start = now
        if cfg.run_model:
            toks = server.generate(_batch_inputs(batch, server.cfg),
                                   batch.decode_bucket)
            assert toks.shape == (batch.batch_pad, batch.decode_bucket)

        # analytic service cost at the PADDED shapes
        if cfg.rram is not None:
            pre = forward_input_stats(server.params, cfg.rram,
                                      batch=batch.padded_prompt_tokens)
            step = forward_input_stats(server.params, cfg.rram,
                                       batch=batch.batch_pad)
            pre_j, pre_s = float(pre.energy_j), float(pre.latency_s)
            step_j, step_s = float(step.energy_j), float(step.latency_s)
        else:
            n_params = fleet.n_params(batch.arch)
            pre_c = digital_cost(n_params, batch.padded_prompt_tokens)
            step_c = digital_cost(n_params, batch.batch_pad)
            pre_j, pre_s = pre_c["energy_j"], pre_c["latency_s"]
            step_j, step_s = step_c["energy_j"], step_c["latency_s"]

        exec_j = pre_j + step_j * batch.decode_bucket
        useful = batch.useful_prompt_tokens + batch.useful_decode_tokens
        padded = batch.padded_prompt_tokens + batch.padded_decode_tokens
        metrics.add_batch(exec_j, useful, padded,
                          dispatches=server.dispatches_per_batch(
                              batch.decode_bucket))

        for r in batch.requests:
            r_useful = r.prompt_len + r.decode_len
            metrics.add_record(RequestRecord(
                rid=r.rid, tenant=r.tenant, arch=r.arch,
                arrival_s=r.arrival_s, start_s=start,
                finish_s=start + pre_s + step_s * r.decode_len,
                prompt_len=r.prompt_len, decode_len=r.decode_len,
                energy_j=exec_j * r_useful / max(useful, 1)))
        # the engine is busy until the padded decode completes
        now = start + pre_s + step_s * batch.decode_bucket
        if cfg.rram is not None:
            # every padded token is a physical read against the image
            fleet.note_served(batch.tenant, batch.padded_prompt_tokens
                              + batch.batch_pad * batch.decode_bucket)

    cache_stats = fleet.cache.stats() if fleet.cache is not None else None
    return SimResult(summary=metrics.summary(cache_stats),
                     records=tuple(sorted(metrics.records,
                                          key=lambda r: r.rid)),
                     cache_stats=cache_stats)
