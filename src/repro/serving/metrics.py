"""Per-request and aggregate serving metrics on the simulated clock.

Latency here is SIMULATED time: arrivals come from the traffic trace, service
times from the analytic cost models below -- never from wall-clock, so every
number is deterministic under a fixed seed.

Energy accounting follows the program-once split end to end:

  * analog service cost = per-MVM input-DAC writes
    (:func:`repro.models.rram.forward_input_stats` -- prefill bills
    ``batch * prompt_bucket`` DAC vectors, each decode step bills ``batch``),
    billed at PADDED shapes: padding waste is real work and shows up in
    joules-per-token;
  * analog write cost = the one-time (re)programming :class:`WriteStats`
    accumulated by the image cache, reported separately AND folded into
    total joules-per-token (the amortization the eviction policy optimizes);
  * the digital fp32 baseline prices the same padded token stream at
    ``2 * n_params`` FLOPs per token against documented per-FLOP energy and
    sustained-throughput constants (DIGITAL_J_PER_FLOP / DIGITAL_FLOPS_PER_S,
    an A100-class fp32 envelope) -- a like-for-like yardstick, not a
    measurement.

``joules_per_token`` divides by USEFUL tokens (requested prompt+decode
lengths), so both padding and reprogram churn degrade it honestly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

__all__ = ["RequestRecord", "MetricsAccumulator", "percentile",
           "digital_cost", "DIGITAL_J_PER_FLOP", "DIGITAL_FLOPS_PER_S"]

# fp32 digital baseline envelope (A100-class): ~19.5 TFLOP/s peak derated to
# a sustained 10 TFLOP/s at ~250 W -> 2.5e-11 J/FLOP.
DIGITAL_J_PER_FLOP = 2.5e-11
DIGITAL_FLOPS_PER_S = 1.0e13


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """One finished request on the simulated clock."""

    rid: int
    tenant: str
    arch: str
    arrival_s: float
    start_s: float         # service start (after queueing + any reprogram)
    finish_s: float        # last decoded token emitted
    prompt_len: int
    decode_len: int
    energy_j: float        # this request's share of its batches' exec energy

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        return self.start_s - self.arrival_s


def percentile(values: List[float], q: float) -> float:
    """Deterministic linear-interpolation percentile (q in [0, 100])."""
    if not values:
        return 0.0
    vs = sorted(values)
    if len(vs) == 1:
        return vs[0]
    pos = (q / 100.0) * (len(vs) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(vs) - 1)
    frac = pos - lo
    return vs[lo] * (1.0 - frac) + vs[hi] * frac


class MetricsAccumulator:
    """Collects request records + execution energy; emits the summary dict."""

    def __init__(self) -> None:
        self.records: List[RequestRecord] = []
        self.exec_energy_j = 0.0     # all executed work incl. padding
        self.padded_tokens = 0
        self.useful_tokens = 0
        self.n_batches = 0
        # device-dispatch accounting: execution launches (prefill + fused
        # decode per batch -- O(1), not O(layers)) and one-time programming
        # launches (grouped program_rram: O(distinct kernel shapes) per
        # build, not O(kernels)).
        self.exec_dispatches = 0
        self.program_dispatches = 0
        # device-lifetime reliability (repro.reliability): populated only
        # when the simulator runs with a ReliabilityConfig.
        self.refreshes = 0
        self.refresh_energy_j = 0.0
        self.refresh_stall_s = 0.0
        self.predicted_residuals: List[float] = []

    def add_batch(self, energy_j: float, useful_tokens: int,
                  padded_tokens: int, dispatches: int = 0) -> None:
        self.exec_energy_j += float(energy_j)
        self.useful_tokens += int(useful_tokens)
        self.padded_tokens += int(padded_tokens)
        self.n_batches += 1
        self.exec_dispatches += int(dispatches)

    def add_program_dispatches(self, dispatches: int) -> None:
        """One (re)program's device-launch count (a cache-miss build)."""
        self.program_dispatches += int(dispatches)

    def add_record(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def add_health(self, predicted_residual: float) -> None:
        """Record the analytic image-health estimate at service time."""
        self.predicted_residuals.append(float(predicted_residual))

    def add_refresh(self, energy_j: float, stall_s: float) -> None:
        self.refreshes += 1
        self.refresh_energy_j += float(energy_j)
        self.refresh_stall_s += float(stall_s)

    def summary(self, cache_stats: Optional[Dict[str, Any]] = None
                ) -> Dict[str, Any]:
        lats = [r.latency_s for r in self.records]
        t0 = min((r.arrival_s for r in self.records), default=0.0)
        t1 = max((r.finish_s for r in self.records), default=0.0)
        makespan = max(t1 - t0, 1e-12)
        write_j = float(cache_stats["write_energy_j"]) if cache_stats else 0.0
        total_j = self.exec_energy_j + write_j
        useful = max(self.useful_tokens, 1)
        out = {
            "n_requests": len(self.records),
            "n_batches": self.n_batches,
            "useful_tokens": self.useful_tokens,
            "padded_tokens": self.padded_tokens,
            "padding_overhead": (self.padded_tokens / max(self.useful_tokens, 1)
                                 ) - 1.0,
            "makespan_s": makespan,
            "tokens_per_s": self.useful_tokens / makespan,
            "p50_latency_s": percentile(lats, 50.0),
            "p99_latency_s": percentile(lats, 99.0),
            "p999_latency_s": percentile(lats, 99.9),
            "mean_queue_s": (sum(r.queue_s for r in self.records)
                             / max(len(self.records), 1)),
            "exec_energy_j": self.exec_energy_j,
            "write_energy_j": write_j,
            "total_energy_j": total_j,
            "joules_per_token": total_j / useful,
            "exec_dispatches": self.exec_dispatches,
            "dispatches_per_batch": (self.exec_dispatches
                                     / max(self.n_batches, 1)),
            "program_dispatches": self.program_dispatches,
        }
        if cache_stats:
            out["cache"] = dict(cache_stats)
        if self.refreshes or self.predicted_residuals:
            preds = self.predicted_residuals
            out["reliability"] = {
                "refreshes": self.refreshes,
                "refresh_energy_j": self.refresh_energy_j,
                "refresh_stall_s": self.refresh_stall_s,
                "mean_predicted_residual": (sum(preds) / len(preds)
                                            if preds else 0.0),
                "max_predicted_residual": max(preds, default=0.0),
            }
        return out


def digital_cost(n_params: int, tokens: int) -> Dict[str, float]:
    """Energy/latency of pushing ``tokens`` positions through an
    ``n_params``-parameter model on the fp32 digital baseline."""
    flops = 2.0 * float(n_params) * float(tokens)
    return {"energy_j": flops * DIGITAL_J_PER_FLOP,
            "latency_s": flops / DIGITAL_FLOPS_PER_S}
