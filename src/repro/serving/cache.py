"""Multi-tenant image cache with write-cost-aware eviction.

An analog deployment's defining asymmetry (the ``SolveLedger`` split in
``solvers/base.py``): programming a conductance image is expensive -- the
full write-verify :class:`~repro.core.write_verify.WriteStats` energy -- but
*executing* against a resident image costs only the per-MVM input-DAC write.
A multi-tenant server with more programmed images than crossbar capacity must
therefore choose victims by what it will cost to bring them BACK, not just by
when they were last touched.

Three policies, selected by name:

  * ``"lru"``     -- classic: evict the least-recently-used entry.
  * ``"never"``   -- admission beyond capacity raises
    :class:`CacheOverBudgetError` (models a deployment with no eviction:
    useful as the OOM control in tests).
  * ``"write_cost"`` -- the headline policy: each entry's keep-priority is
    ``reprogram_energy_j * recent_hit_rate`` (an exponentially-decayed
    hits-per-second estimate), i.e. the expected write energy per second
    saved by keeping the image resident.  Evict the minimum.  A big, hot
    image survives a burst of small cold tenants that would flush it under
    LRU -- that difference is exactly the benchmark's total-write-energy gap.

The cache is value-agnostic: entries are built by a caller-supplied thunk
returning ``(value, size_bytes, write_stats)``, so the same class caches
programmed param pytrees (sized by ``models.rram.analog_image_bytes``) or raw
:class:`~repro.engine.AnalogMatrix` handles (sized by ``image_nbytes``, with
a ``release_hook`` calling ``handle.release()`` on eviction).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Hashable, List, Optional, Tuple

from repro.core.write_verify import WriteStats

__all__ = ["ImageCache", "CacheEntry", "CacheOutcome", "CacheOverBudgetError",
           "POLICIES"]

POLICIES = ("lru", "never", "write_cost")


class CacheOverBudgetError(RuntimeError):
    """Raised when admission would exceed capacity and the policy forbids
    eviction (``"never"``), or when a single entry exceeds total capacity."""


@dataclasses.dataclass
class CacheEntry:
    key: Hashable
    value: Any
    size_bytes: int
    write_stats: WriteStats          # cost of the build that produced value
    created_s: float
    last_used_s: float
    hits: int = 0
    _rate: float = 0.0               # decayed hit counter (see hit_rate)
    _rate_t: float = 0.0

    def hit_rate(self, now: float, tau_s: float) -> float:
        """Exponentially-decayed hits-per-second, horizon ``tau_s``."""
        return self._decayed(now, tau_s) / tau_s

    def _decayed(self, now: float, tau_s: float) -> float:
        dt = max(0.0, now - self._rate_t)
        return self._rate * math.exp(-dt / tau_s)

    def touch(self, now: float, tau_s: float) -> None:
        self._rate = self._decayed(now, tau_s) + 1.0
        self._rate_t = now
        self.last_used_s = now
        self.hits += 1


@dataclasses.dataclass(frozen=True)
class CacheOutcome:
    """What one ``get`` did: hit or (re)build, and who got evicted for it."""

    hit: bool
    reprogrammed: bool               # a miss on a key that was resident before
    write_stats: WriteStats          # build cost charged by THIS get (zero on hit)
    evicted: Tuple[Hashable, ...] = ()


class ImageCache:
    """Capacity-budgeted cache of programmed analog images.

    ``get(key, build, now)`` returns ``(value, outcome)``; ``build`` runs only
    on a miss and must return ``(value, size_bytes, write_stats)``.  Evictions
    call ``release_hook(key, value)`` when provided.  All state the policies
    read (recency, decayed hit rates) advances on the caller's simulated
    clock, so a fixed trace produces a fixed eviction sequence."""

    def __init__(self, capacity_bytes: int, policy: str = "write_cost",
                 *, tau_s: float = 30.0,
                 release_hook: Optional[Callable[[Hashable, Any], None]] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        self.capacity_bytes = int(capacity_bytes)
        self.policy = policy
        self.tau_s = float(tau_s)
        self.release_hook = release_hook
        self.entries: Dict[Hashable, CacheEntry] = {}
        self._ever_built: set = set()
        # aggregate counters, read by metrics/benchmarks
        self.hits = 0
        self.misses = 0
        self.reprograms = 0          # builds beyond the first, per key
        self.evictions = 0
        self.refreshes = 0           # in-place tile refreshes of resident entries
        self.write_energy_j = 0.0    # total build (programming) energy
        self.write_latency_s = 0.0

    @property
    def used_bytes(self) -> int:
        return sum(e.size_bytes for e in self.entries.values())

    def get(self, key: Hashable, build: Callable[[], Tuple[Any, int, WriteStats]],
            now: float) -> Tuple[Any, CacheOutcome]:
        entry = self.entries.get(key)
        if entry is not None:
            entry.touch(now, self.tau_s)
            self.hits += 1
            return entry.value, CacheOutcome(
                hit=True, reprogrammed=False, write_stats=WriteStats.zero())

        self.misses += 1
        reprogrammed = key in self._ever_built
        if reprogrammed:
            self.reprograms += 1
        self._ever_built.add(key)
        value, size_bytes, stats = build()
        self.write_energy_j += float(stats.energy_j)
        self.write_latency_s += float(stats.latency_s)

        if size_bytes > self.capacity_bytes:
            raise CacheOverBudgetError(
                f"entry {key!r} ({size_bytes} B) exceeds cache capacity "
                f"({self.capacity_bytes} B)")
        evicted = self._make_room(size_bytes, now)
        entry = CacheEntry(key=key, value=value, size_bytes=size_bytes,
                           write_stats=stats, created_s=now, last_used_s=now)
        entry.touch(now, self.tau_s)
        self.entries[key] = entry
        return value, CacheOutcome(hit=False, reprogrammed=reprogrammed,
                                   write_stats=stats, evicted=tuple(evicted))

    def _make_room(self, need_bytes: int, now: float) -> List[Hashable]:
        evicted: List[Hashable] = []
        while self.used_bytes + need_bytes > self.capacity_bytes:
            if self.policy == "never":
                raise CacheOverBudgetError(
                    f"cache over budget ({self.used_bytes + need_bytes} B > "
                    f"{self.capacity_bytes} B) and policy is 'never'")
            victim = self._pick_victim(now)
            self._evict(victim)
            evicted.append(victim)
        return evicted

    def _pick_victim(self, now: float) -> Hashable:
        if self.policy == "lru":
            return min(self.entries.values(),
                       key=lambda e: (e.last_used_s, str(e.key))).key
        # write_cost: keep-priority = expected reprogram energy saved per
        # second; ties broken by recency then key for determinism.
        return min(self.entries.values(),
                   key=lambda e: (e.write_stats.energy_j
                                  * e.hit_rate(now, self.tau_s),
                                  e.last_used_s, str(e.key))).key

    def _evict(self, key: Hashable) -> None:
        entry = self.entries.pop(key)
        self.evictions += 1
        if self.release_hook is not None:
            self.release_hook(key, entry.value)

    def note_refresh(self, key: Hashable, stats: WriteStats) -> None:
        """Bill an in-place tile refresh of a resident entry.

        The entry stays resident (no eviction, no rebuild, no hit-rate
        bump); only the programming ledger moves -- refresh writes are
        real write-verify energy and latency, just amortized to a tile
        subset instead of the full image."""
        if key not in self.entries:
            raise KeyError(f"cannot refresh non-resident entry {key!r}")
        self.refreshes += 1
        self.write_energy_j += float(stats.energy_j)
        self.write_latency_s += float(stats.latency_s)

    def stats(self) -> Dict[str, Any]:
        return {"policy": self.policy, "capacity_bytes": self.capacity_bytes,
                "used_bytes": self.used_bytes, "entries": len(self.entries),
                "hits": self.hits, "misses": self.misses,
                "reprograms": self.reprograms, "evictions": self.evictions,
                "refreshes": self.refreshes,
                "write_energy_j": self.write_energy_j,
                "write_latency_s": self.write_latency_s}
