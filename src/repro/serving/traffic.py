"""Seeded synthetic traffic: deterministic request traces for the serving
simulator.

A trace is a list of :class:`Request` drawn from three independent processes:

  * **arrivals** -- Poisson at ``rate_rps`` (exponential inter-arrival gaps);
  * **tenant popularity** -- Zipf over the tenant list (rank ``k`` gets mass
    ``(k+1)^-zipf_s``), so a skewed ``zipf_s`` concentrates traffic on a few
    hot images -- the regime where write-cost-aware eviction matters;
  * **lengths** -- prompt/decode lengths drawn from small categorical mixes
    (chat-style short prompts next to document-style long ones).

All randomness comes from one ``numpy.random.Generator(PCG64(seed))``, so the
trace is bit-identical across runs and platforms: same seed -> same requests
in the same order with the same lengths and arrival times (the replay test
asserts this end to end through the simulator).  No jax arrays here -- the
trace is host-side metadata; token content is synthesized later from
``Request.token_seed``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

__all__ = ["TenantSpec", "TrafficConfig", "Request", "generate_trace",
           "zipf_weights"]


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant: a name and the zoo model it serves.

    Tenants listed earlier get higher Zipf rank (more traffic).  Two tenants
    may share an ``arch`` -- they still program (and cache) separate analog
    images, under independent PRNG keys."""

    name: str
    arch: str


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Knobs of the synthetic trace (all defaults give a small, mixed load)."""

    n_requests: int = 64
    rate_rps: float = 4.0            # mean Poisson arrival rate, requests/s
    zipf_s: float = 1.1              # tenant popularity skew (0 = uniform)
    prompt_lens: Tuple[int, ...] = (8, 16, 32)
    prompt_mix: Tuple[float, ...] = (0.5, 0.3, 0.2)
    decode_lens: Tuple[int, ...] = (4, 8, 16)
    decode_mix: Tuple[float, ...] = (0.5, 0.3, 0.2)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request, fully determined at trace-generation time."""

    rid: int
    tenant: str
    arch: str
    arrival_s: float
    prompt_len: int
    decode_len: int
    token_seed: int      # seeds the synthetic prompt-token draw


def zipf_weights(n: int, s: float) -> np.ndarray:
    """Normalized Zipf mass over ``n`` ranks: ``p_k \\propto (k+1)^-s``."""
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-float(s))
    return w / w.sum()


def generate_trace(tenants: Sequence[TenantSpec],
                   cfg: TrafficConfig) -> Tuple[Request, ...]:
    """The deterministic trace: ``cfg.n_requests`` requests, arrival-sorted."""
    if not tenants:
        raise ValueError("need at least one tenant")
    rng = np.random.Generator(np.random.PCG64(cfg.seed))
    pops = zipf_weights(len(tenants), cfg.zipf_s)
    pmix = np.asarray(cfg.prompt_mix, dtype=np.float64)
    dmix = np.asarray(cfg.decode_mix, dtype=np.float64)
    pmix = pmix / pmix.sum()
    dmix = dmix / dmix.sum()

    gaps = rng.exponential(scale=1.0 / cfg.rate_rps, size=cfg.n_requests)
    arrivals = np.cumsum(gaps)
    tenant_idx = rng.choice(len(tenants), size=cfg.n_requests, p=pops)
    prompt_idx = rng.choice(len(cfg.prompt_lens), size=cfg.n_requests, p=pmix)
    decode_idx = rng.choice(len(cfg.decode_lens), size=cfg.n_requests, p=dmix)
    token_seeds = rng.integers(0, 2**31 - 1, size=cfg.n_requests)

    out = []
    for i in range(cfg.n_requests):
        t = tenants[int(tenant_idx[i])]
        out.append(Request(
            rid=i, tenant=t.name, arch=t.arch,
            arrival_s=float(arrivals[i]),
            prompt_len=int(cfg.prompt_lens[int(prompt_idx[i])]),
            decode_len=int(cfg.decode_lens[int(decode_idx[i])]),
            token_seed=int(token_seeds[i])))
    return tuple(out)
