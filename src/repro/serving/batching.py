"""Continuous-batching request queue: pack compatible requests, pad to
buckets.

The engine executes one programmed image at a time, so a batch must share a
(tenant, arch) pair; within that, requests are packed up to ``max_batch`` and
padded along three axes to keep the jit-compile count bounded:

  * **prompt** -- requests are grouped by prompt bucket (smallest power-of-two
    style bucket >= prompt_len) and the synthetic prompt is materialized at
    bucket length, so prefill shapes come from a fixed small set;
  * **decode** -- the batch decodes to the bucket of its LONGEST member's
    decode_len (shorter members' tails are padding work);
  * **batch** -- the packed group is padded up to the smallest batch bucket
    by repeating the last row.

Padding is never hidden: padded rows/steps execute (and are billed energy by
the cost model) but contribute zero useful tokens, so over-padding shows up
directly in joules-per-token.

Scheduling is head-of-line FIFO: ``form_batch`` always serves the OLDEST
waiting request, packing only requests compatible with it.  That gives a
simple no-starvation bound -- a request's wait is at most the service time of
the batches ahead of it in arrival order, never a function of its tenant's
popularity (the packing-invariant test asserts an explicit deadline bound on
a skewed trace).

The KV-cache layout constrains the design: ``cache["len"]`` is one scalar
shared by the whole batch (see DESIGN.md section 9), so sequences cannot join
mid-flight at per-token granularity.  Batching is therefore *group-level*
continuous batching -- new batches form whenever the engine goes idle, but a
running batch's membership is fixed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from .traffic import Request

__all__ = ["BatchingConfig", "Batch", "RequestQueue", "bucket_for"]


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (buckets must be sorted ascending)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"length {n} exceeds largest bucket {buckets[-1]}")


@dataclasses.dataclass(frozen=True)
class BatchingConfig:
    max_batch: int = 4
    prompt_buckets: Tuple[int, ...] = (8, 16, 32, 64)
    decode_buckets: Tuple[int, ...] = (4, 8, 16, 32)
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8)

    def __post_init__(self):
        for name in ("prompt_buckets", "decode_buckets", "batch_buckets"):
            b = getattr(self, name)
            if tuple(sorted(b)) != tuple(b):
                raise ValueError(f"{name} must be sorted ascending: {b}")
        if self.max_batch > self.batch_buckets[-1]:
            raise ValueError("max_batch exceeds largest batch bucket")


@dataclasses.dataclass(frozen=True)
class Batch:
    """One packed execution: requests + the padded shapes it will run at."""

    requests: Tuple[Request, ...]
    tenant: str
    arch: str
    prompt_bucket: int      # all members share this prompt bucket
    decode_bucket: int      # bucket of the longest member decode_len
    batch_pad: int          # padded batch size actually executed

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def useful_prompt_tokens(self) -> int:
        return sum(r.prompt_len for r in self.requests)

    @property
    def useful_decode_tokens(self) -> int:
        return sum(r.decode_len for r in self.requests)

    @property
    def padded_prompt_tokens(self) -> int:
        return self.batch_pad * self.prompt_bucket

    @property
    def padded_decode_tokens(self) -> int:
        return self.batch_pad * self.decode_bucket


class RequestQueue:
    """FIFO admission + head-of-line compatible packing."""

    def __init__(self, cfg: BatchingConfig):
        self.cfg = cfg
        self._waiting: List[Request] = []

    def add(self, req: Request) -> None:
        self._waiting.append(req)

    def __len__(self) -> int:
        return len(self._waiting)

    @property
    def waiting(self) -> Tuple[Request, ...]:
        return tuple(self._waiting)

    def next_arrival(self, now: float) -> Optional[float]:
        """Earliest arrival time strictly after ``now`` among queued
        requests (the simulator advances its clock here when idle)."""
        future = [r.arrival_s for r in self._waiting if r.arrival_s > now]
        return min(future) if future else None

    def form_batch(self, now: float) -> Optional[Batch]:
        """Pack a batch around the oldest arrived request, or None if no
        request has arrived by ``now``."""
        arrived = [r for r in self._waiting if r.arrival_s <= now]
        if not arrived:
            return None
        arrived.sort(key=lambda r: (r.arrival_s, r.rid))
        head = arrived[0]
        head_bucket = bucket_for(head.prompt_len, self.cfg.prompt_buckets)
        picked = [head]
        for r in arrived[1:]:
            if len(picked) >= self.cfg.max_batch:
                break
            if (r.tenant == head.tenant and r.arch == head.arch
                    and bucket_for(r.prompt_len, self.cfg.prompt_buckets)
                    == head_bucket):
                picked.append(r)
        for r in picked:
            self._waiting.remove(r)
        decode_bucket = bucket_for(max(r.decode_len for r in picked),
                                   self.cfg.decode_buckets)
        batch_pad = bucket_for(len(picked), self.cfg.batch_buckets)
        return Batch(requests=tuple(picked), tenant=head.tenant,
                     arch=head.arch, prompt_bucket=head_bucket,
                     decode_bucket=decode_bucket, batch_pad=batch_pad)
