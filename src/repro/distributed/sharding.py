"""Logical-axis sharding rules (MaxText-style, divisibility-aware).

Rule tables map logical axis names (see models/params.py) to an ordered list
of candidate mesh axes; the resolver shards a tensor dim on the first
candidate whose size divides the dim and which is not already used by another
dim of the same tensor -- otherwise the dim is replicated.  This is what makes
kv_heads=4 work on a model=16 mesh (the fused kv*head_dim weight dims stay
divisible; the separate-dim KV caches fall through to head_dim or replicate).

Two parameter rule sets:
  * TP       -- inference: weights resident, sharded over `model` only.
  * FSDP_TP  -- training: weights/optimizer state additionally sharded over
                `data` (+`pod`) on the embed dim (ZeRO-ish; GSPMD inserts the
                per-layer all-gathers, which overlap with compute).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import is_spec

__all__ = [
    "param_rules", "resolve_pspec", "param_pspecs", "param_shardings",
    "batch_pspec", "cache_pspecs", "mesh_axis_sizes", "data_axes",
]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def param_rules(mode: str, mesh: Mesh) -> Dict[str, Tuple[str, ...]]:
    da = data_axes(mesh)
    # one *combined* candidate (("pod","data"),) -- not two alternatives --
    # so multi-pod FSDP shards 32-way, falling back to "data" alone when the
    # dim divides only that.
    fsdp = ((da, da[-1]) if len(da) > 1 else (da[0],)) if mode == "fsdp_tp" else ()
    return {
        "vocab": ("model",),
        "mlp": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "state": (),
        "expert": (),            # expert compute is TP inside shard_map
        "embed": fsdp,           # FSDP shards the d_model dim over data(+pod)
        "head_dim": (),
        "layer": (),
        None: (),
    }


def resolve_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  rules: Dict, sizes: Dict[str, int]) -> P:
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        cands = rules.get(ax, ())
        pick = None
        for c in cands:
            if isinstance(c, str):
                c = (c,)
            total = 1
            for cc in c:
                total *= sizes[cc]
            if all(cc not in used for cc in c) and dim % total == 0 and dim > 0:
                pick = c
                break
        if pick is None:
            out.append(None)
        else:
            used.update(pick)
            out.append(pick if len(pick) > 1 else pick[0])
    return P(*out)


def param_pspecs(specs, mesh: Mesh, mode: str = "tp"):
    """Spec tree -> pytree of PartitionSpecs."""
    rules = param_rules(mode, mesh)
    sizes = mesh_axis_sizes(mesh)
    return jax.tree.map(
        lambda s: resolve_pspec(s.shape, s.axes, rules, sizes),
        specs, is_leaf=is_spec)


def param_shardings(specs, mesh: Mesh, mode: str = "tp"):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                        param_pspecs(specs, mesh, mode))


def batch_pspec(leaf_shape: Sequence[int], mesh: Mesh,
                global_batch: int) -> P:
    """Batch inputs: the dim equal to global_batch shards over (pod, data)."""
    da = data_axes(mesh)
    out = []
    assigned = False
    for dim in leaf_shape:
        if not assigned and dim == global_batch and dim % _prod(mesh, da) == 0:
            out.append(da if len(da) > 1 else da[0])
            assigned = True
        else:
            out.append(None)
    return P(*out)


def _prod(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    sizes = mesh_axis_sizes(mesh)
    r = 1
    for a in axes:
        r *= sizes[a]
    return r


def cache_pspecs(cache_tree, mesh: Mesh, global_batch: int):
    """Decode caches: batch dim -> data axes; then the largest remaining dim
    divisible by the model-axis size -> model.  Robust across families and
    per-layer stacking."""
    da = data_axes(mesh)
    dsz = _prod(mesh, da)
    msz = mesh_axis_sizes(mesh).get("model", 1)

    def leaf_spec(leaf):
        shape = leaf.shape
        out: list = [None] * len(shape)
        used_b = False
        for i, dim in enumerate(shape):
            if not used_b and dim == global_batch and dim % dsz == 0:
                out[i] = da if len(da) > 1 else da[0]
                used_b = True
                break
        # model axis on the largest divisible non-batch dim
        best, best_dim = None, 0
        for i, dim in enumerate(shape):
            if out[i] is None and dim % msz == 0 and dim > best_dim and dim >= msz:
                best, best_dim = i, dim
        if best is not None and msz > 1:
            out[best] = "model"
        return P(*out)

    return jax.tree.map(leaf_spec, cache_tree)
