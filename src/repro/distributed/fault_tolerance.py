"""Fault tolerance: async atomic checkpointing with elastic (mesh-changing)
restore, preemption handling, and a straggler watchdog.

Checkpoint layout (one directory per step, atomically renamed into place):

    <dir>/step_000120/
        manifest.json        # step, mesh shape/axes, leaf paths/shapes/dtypes
        arrays.npz           # one entry per pytree leaf (path-keyed)

Restore targets *any* mesh: arrays are loaded on host and device_put with the
target NamedShardings, so a job checkpointed on (16, 16) restarts cleanly on
(8, 16) or (2, 16, 16) -- elastic scaling.  Saves run on a background thread
(snapshot is taken synchronously via device_get, I/O is async); ``wait()``
joins before the next save or shutdown.  A SIGTERM handler flips
``preempted`` so the training loop can checkpoint-and-exit.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager", "Watchdog", "install_preemption_handler",
           "PREEMPTED"]

PREEMPTED = threading.Event()


def install_preemption_handler() -> None:
    """SIGTERM -> graceful checkpoint-and-exit flag (cluster preemption)."""
    def handler(signum, frame):
        PREEMPTED.set()
    try:
        signal.signal(signal.SIGTERM, handler)
    except ValueError:
        pass  # not in main thread (tests)


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): np.asarray(jax.device_get(v))
            for p, v in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False,
             extra: Optional[Dict] = None) -> None:
        self.wait()
        arrays = _flatten(tree)          # snapshot now (synchronous device_get)
        manifest = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in arrays.items()},
            "extra": extra or {},
            "devices": jax.device_count(),
            "time": time.time(),
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """The manifest.json of ``step`` (default: latest): step number,
        leaf shapes/dtypes, the ``extra`` dict passed at save time, device
        count and wall time -- the metadata a recovery loop inspects before
        deciding what to restore."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, target_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Rebuild ``target_tree``-structured state from disk.  ``shardings``
        (same structure, NamedShardings) retargets any mesh -- elastic."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(path, "arrays.npz")) as data:
            flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
            out = []
            for p, leaf in flat:
                key = jax.tree_util.keystr(p)
                arr = data[key]
                want = jnp.dtype(leaf.dtype)
                arr = arr.astype(want)
                out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree


@dataclasses.dataclass
class Watchdog:
    """Step-time EMA straggler detector: flags steps slower than
    ``threshold`` x the running median and can trigger a callback (e.g.
    checkpoint + reconfigure) after ``patience`` consecutive slow steps."""

    threshold: float = 2.5
    patience: int = 3
    on_straggler: Optional[Callable[[int], None]] = None
    _times: List[float] = dataclasses.field(default_factory=list)
    _slow: int = 0
    events: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self._times.append(seconds)
        hist = sorted(self._times[-50:])
        med = hist[len(hist) // 2]
        if len(self._times) >= 5 and seconds > self.threshold * med:
            self._slow += 1
            self.events.append(step)
            if self._slow >= self.patience and self.on_straggler:
                self.on_straggler(step)
                self._slow = 0
            return True
        self._slow = 0
        return False
