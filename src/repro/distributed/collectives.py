"""Distributed-optimization primitives: compressed cross-pod gradient
reduction and a ring collective-matmul (comm/compute overlap).

``compressed_psum`` is the int8 gradient-compression path: per-tensor absmax
scale, stochastic-free symmetric int8 quantization, integer psum (no
saturation: int32 accumulate), dequantize, plus an *error-feedback* residual
returned to the caller so quantization error is re-injected next step (the
standard EF-SGD trick that keeps convergence).  On a 2-pod mesh this cuts
cross-pod gradient bytes 4x (bf16 -> int8 on the wire, int32 only inside the
reduction tree).

``ring_collective_matmul`` overlaps an all-gather of the weight shards with
partial matmuls via ``ppermute`` -- the classic TPU collective-matmul schedule
used when FSDP weight gathers would otherwise serialize in front of the dot.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compat import axis_size, pvary

__all__ = ["int8_quantize", "int8_dequantize", "compressed_psum",
           "ring_collective_matmul"]


def int8_quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str,
                    error_feedback: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8-on-the-wire psum over ``axis_name`` with error feedback.

    Returns (reduced fp32 mean-preserving sum, new error-feedback residual).
    Must be called inside shard_map/pmap with ``axis_name`` bound."""
    xf = x.astype(jnp.float32)
    if error_feedback is not None:
        xf = xf + error_feedback
    # Shared scale: a scalar pmax (negligible wire cost) so every participant
    # quantizes onto the same grid -- then the int8 payload reduces exactly
    # in int32 and one dequantize recovers the sum.
    local_max = jnp.max(jnp.abs(xf))
    scale = jax.lax.pmax(local_max, axis_name) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    out = qsum.astype(jnp.float32) * scale
    residual = xf - int8_dequantize(q, scale)
    return out, residual


def ring_collective_matmul(
    x: jnp.ndarray,          # (m, k_global) -- activations, k replicated
    w_local: jnp.ndarray,    # (k_local, n) -- this device's weight shard
    axis_name: str,
) -> jnp.ndarray:
    """y = x @ w_global computed as a ring: each step multiplies the resident
    weight shard while the next shard is in flight (ppermute), so the gather
    communication hides behind the MXU.

    Must be called inside shard_map with ``axis_name`` bound; w is k-sharded
    over that axis.
    """
    n_dev = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k_local = w_local.shape[0]
    perm = [(j, (j + 1) % n_dev) for j in range(n_dev)]

    def body(i, carry):
        acc, w = carry
        # Which global k-slice does the currently-resident shard cover?
        src = (idx - i) % n_dev
        x_slice = jax.lax.dynamic_slice_in_dim(x, src * k_local, k_local, 1)
        acc = acc + x_slice @ w
        w = jax.lax.ppermute(w, axis_name, perm)   # next shard in flight
        return acc, w

    acc0 = jnp.zeros((x.shape[0], w_local.shape[1]),
                     jnp.promote_types(x.dtype, jnp.float32))
    # The accumulator is device-varying (it mixes ring-rotated shards):
    # mark it so the loop carry types match under shard_map's vma tracking.
    acc0 = pvary(acc0, axis_name)
    acc, _ = jax.lax.fori_loop(0, n_dev, body, (acc0, w_local))
    return acc.astype(x.dtype)
