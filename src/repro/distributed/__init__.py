from .sharding import (batch_pspec, cache_pspecs, data_axes, param_pspecs,
                       param_shardings)
from .collectives import compressed_psum, int8_quantize, ring_collective_matmul
from .fault_tolerance import CheckpointManager, Watchdog
