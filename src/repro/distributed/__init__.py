from .sharding import (batch_pspec, cache_pspecs, data_axes, param_pspecs,
                       param_shardings)
from .collectives import compressed_psum, int8_quantize, ring_collective_matmul
from .fault_tolerance import (PREEMPTED, CheckpointManager, Watchdog,
                              install_preemption_handler)

__all__ = [
    "batch_pspec", "cache_pspecs", "data_axes", "param_pspecs",
    "param_shardings",
    "compressed_psum", "int8_quantize", "ring_collective_matmul",
    "CheckpointManager", "Watchdog", "install_preemption_handler",
    "PREEMPTED",
]
