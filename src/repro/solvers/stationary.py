"""Stationary iterative methods: Richardson (with auto-``omega``) and Jacobi.

The MELISO+ workhorse loop is Richardson iteration

    x_{k+1} = x_k + omega * (b - A x_k)

against one programmed analog image -- one corrected MVM per iteration, zero
re-programming.  Instead of a hand-tuned ``omega`` (the old example hard-coded
1/3), :func:`spectral_bounds` estimates the extremal eigenvalues of an SPD
``A`` with matvec-only power iteration (a second, shifted pass recovers
``lambda_min`` from ``lambda_max``) and :func:`richardson` defaults to the
optimal relaxation ``omega* = 2 / (lambda_min + lambda_max)``, deflated 5% on
the top end to absorb estimation error and analog noise.

The whole solve -- spectral estimate, ``lax.while_loop`` with tolerance-based
early stopping, residual history -- traces into one jitted computation, for
``b`` of shape (n,) or (n, batch).  With ``backend="pallas"`` the residual +
relaxed-step update fuses into :func:`repro.kernels.solver_richardson_update`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .base import (LinearOperator, SolveResult, as_operator, col_norms,
                   init_history, pack_result, use_pallas)

__all__ = ["richardson", "jacobi", "spectral_bounds", "estimate_omega"]

_TINY = 1e-30


def _power_iterate(matvec, n: int, key: jax.Array, iters: int,
                   shift: Optional[jnp.ndarray] = None):
    """(unit iterate, dominant |eigenvalue|) of A (or shift*I - A) by power
    iteration.

    Matvec-only: runs unchanged against analog/digital operators; each step
    consumes a fresh fold of ``key`` for the analog DAC noise.  The final
    iterate is exposed (not just the eigenvalue) so Krylov refiners --
    :func:`repro.solvers.lanczos` -- can seed their basis from it.
    """
    v0 = jax.random.normal(jax.random.fold_in(key, 0), (n, 1), jnp.float32)
    v0 = v0 / jnp.maximum(col_norms(v0), _TINY)

    def body(i, carry):
        v, _ = carry
        w = matvec(v, jax.random.fold_in(key, 1 + i))
        if shift is not None:
            w = shift * v - w
        lam = col_norms(w)[0]
        return w / jnp.maximum(lam, _TINY), lam

    v, lam = jax.lax.fori_loop(0, iters, body, (v0, jnp.float32(0.0)))
    return v, lam


def _power_extreme(matvec, n: int, key: jax.Array, iters: int,
                   shift: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Dominant |eigenvalue| only; see :func:`_power_iterate`."""
    return _power_iterate(matvec, n, key, iters, shift=shift)[1]


def spectral_bounds(
    A, *, key: Optional[jax.Array] = None, iters: int = 16,
    method: str = "power",
) -> Tuple[float, float]:
    """(lambda_min, lambda_max) estimates for SPD ``A``, matvec-only.

    ``method="power"``: ``lambda_max`` by plain power iteration, then
    ``lambda_min`` by a second power iteration on the shifted operator
    ``lambda_max * I - A`` (whose dominant eigenvalue is
    ``lambda_max - lambda_min``); costs ``2 * iters`` MVMs.
    ``method="lanczos"``: both ends from ONE Krylov sweep of
    :func:`repro.solvers.lanczos` (``iters`` steps; typically sharper per
    MVM, since Lanczos converges superlinearly at the spectrum ends where
    the shifted power method crawls).
    """
    op = as_operator(A)
    key = jax.random.PRNGKey(0) if key is None else key
    if method == "lanczos":
        from .eigen import lanczos
        res = lanczos(op, tol=0.0, maxiter=max(int(iters), 2), key=key)
        return float(res.eigenvalues[0]), float(res.eigenvalues[1])
    if method != "power":
        raise ValueError(f"method must be 'power' or 'lanczos', got "
                         f"{method!r}")

    @jax.jit
    def core(key):
        lmax = _power_extreme(op.matvec, op.n, jax.random.fold_in(key, 101),
                              iters)
        mu = _power_extreme(op.matvec, op.n, jax.random.fold_in(key, 202),
                            iters, shift=lmax)
        return lmax, lmax - mu

    lmax, lmin = core(key)
    return float(lmin), float(lmax)


def estimate_omega(A, *, key: Optional[jax.Array] = None,
                   iters: int = 16, method: str = "power") -> float:
    """The auto relaxation factor :func:`richardson` uses when ``omega=None``;
    ``method="lanczos"`` swaps the power-iteration bounds for a Lanczos
    sweep (see :func:`spectral_bounds`)."""
    lmin, lmax = spectral_bounds(A, key=key, iters=iters, method=method)
    return float(2.0 / (1.05 * lmax + max(lmin, 0.0)))


def _stationary_core(op: LinearOperator, scale_fn, b, x0, key, omega,
                     tol: float, maxiter: int, use_pallas: bool,
                     power_iters: int):
    """Shared Richardson/Jacobi while_loop.  ``scale_fn(r)`` maps the raw
    residual to the update direction (identity / D^{-1} r)."""
    batch = b.shape[1]
    bn = jnp.maximum(col_norms(b), _TINY)

    if omega is None:
        pkey = jax.random.fold_in(key, 900_001)
        lmax = _power_extreme(op.matvec, op.n, jax.random.fold_in(pkey, 1),
                              power_iters)
        mu = _power_extreme(op.matvec, op.n, jax.random.fold_in(pkey, 2),
                            power_iters, shift=lmax)
        lmin = jnp.maximum(lmax - mu, 0.0)
        om = 2.0 / (1.05 * lmax + lmin)
        # Power iteration runs on a single column whatever the RHS batch;
        # billed separately at the batch-1 input-write rate (see SolveLedger).
        pi_mvms = jnp.int32(2 * power_iters)
    else:
        om = jnp.float32(omega)
        pi_mvms = jnp.int32(0)

    def cond(state):
        k, _x, _h, rel, _m = state
        # NaN-robust: a NaN residual counts as not converged.
        return jnp.logical_and(k < maxiter,
                               jnp.logical_not(jnp.all(rel <= tol)))

    def body(state):
        k, x, hist, _rel, mvms = state
        y = op.matvec(x, jax.random.fold_in(key, k))
        if use_pallas and scale_fn is None:
            from repro.kernels import solver_richardson_update
            x_new, r = solver_richardson_update(x, b, y, om)
        else:
            r = b - y
            step = r if scale_fn is None else scale_fn(r)
            x_new = x + om * step
        rel = col_norms(r) / bn
        hist = hist.at[k].set(rel)
        return k + 1, x_new, hist, rel, mvms + 1

    state0 = (jnp.int32(0), x0, init_history(maxiter, batch),
              jnp.full((batch,), jnp.inf, jnp.float32), jnp.int32(0))
    k, x, hist, _rel, mvms = jax.lax.while_loop(cond, body, state0)
    return x, hist, k, mvms, pi_mvms


def richardson(
    A,
    b: jnp.ndarray,
    *,
    omega: Optional[float] = None,
    tol: float = 1e-6,
    maxiter: int = 200,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
    power_iters: int = 16,
    backend: Optional[str] = None,
) -> SolveResult:
    """Richardson iteration ``x += omega * (b - A x)``, matvec-only.

    ``omega=None`` (the default) spends ``2 * power_iters`` extra MVMs on a
    power-iteration spectral estimate and uses the optimal SPD relaxation
    ``2 / (lambda_min + lambda_max)`` (top deflated 5%); those MVMs are
    charged to the ledger.  ``backend="pallas"`` fuses the update step.
    """
    op = as_operator(A)
    pallas = use_pallas(backend)
    squeeze = b.ndim == 1
    bb = (b[:, None] if squeeze else b).astype(jnp.float32)
    x0b = jnp.zeros_like(bb) if x0 is None else \
        (x0[:, None] if squeeze else x0).astype(jnp.float32)
    key = jax.random.PRNGKey(0) if key is None else key

    core = jax.jit(functools.partial(
        _stationary_core, op, None, tol=tol, maxiter=maxiter,
        use_pallas=pallas, power_iters=power_iters, omega=omega))
    x, hist, k, mvms, pi_mvms = core(bb, x0b, key)
    return pack_result(op, "richardson", x, hist, k, mvms, tol, squeeze,
                       mvms_single=pi_mvms)


def jacobi(
    A,
    b: jnp.ndarray,
    *,
    diag: Optional[jnp.ndarray] = None,
    omega: float = 1.0,
    tol: float = 1e-6,
    maxiter: int = 200,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """(Weighted) Jacobi ``x += omega * D^{-1} (b - A x)``.

    The diagonal is digital metadata: taken from ``diag`` if given, else
    reconstructed from the programmed operands (``A_tilde + dA``) -- the
    analog array itself is only ever touched through MVMs.
    """
    op = as_operator(A)
    if diag is None:
        if op.dense is None:
            raise ValueError("jacobi needs diag= for a bare matvec operator")
        diag = jnp.diagonal(op.dense())
    dinv = (1.0 / jnp.asarray(diag, jnp.float32))[:, None]

    squeeze = b.ndim == 1
    bb = (b[:, None] if squeeze else b).astype(jnp.float32)
    x0b = jnp.zeros_like(bb) if x0 is None else \
        (x0[:, None] if squeeze else x0).astype(jnp.float32)
    key = jax.random.PRNGKey(0) if key is None else key

    core = jax.jit(functools.partial(
        _stationary_core, op, lambda r: dinv * r, tol=tol, maxiter=maxiter,
        use_pallas=False, power_iters=0, omega=omega))
    x, hist, k, mvms, _pi = core(bb, x0b, key)
    return pack_result(op, "jacobi", x, hist, k, mvms, tol, squeeze)
