"""Linearized ADMM for box-constrained quadratic programs, matvec+rmatvec.

Beside :mod:`~repro.solvers.pdhg`'s equality-constrained LPs, the other
workhorse of the first-order-on-analog literature is the box-constrained QP

    min_x  (1/2) || A x - b ||^2  +  q' x      s.t.  lo <= x <= hi

(portfolio construction, MPC, bounded deblurring, ...).  The splitting is
``f(x) = (1/2)||Ax - b||^2 + q'x`` against the box indicator ``g(z)`` with
the consensus constraint ``x = z``; the x-update LINEARIZES ``f`` around the
current iterate, so each iteration is exactly

    grad  = A'(A x - b) + q                      # one matvec + one rmatvec
    x_new = x - mu * (grad + rho * (x - z + u))  # linearized prox step
    z_new = clip(x_new + u, lo, hi)              # exact box projection
    u_new = u + x_new - z_new                    # scaled dual ascent

-- one forward plus one transposed corrected MVM against the ONE programmed
image, the same per-iteration budget as PDHG and the bidiagonalization
solvers.  ``mu < 1 / (||A||_2^2 + rho)`` guarantees the linearized step is a
majorizer; the default estimates ``||A||_2`` with the same power iteration
PDHG uses (or feed :func:`repro.solvers.operator_norm`'s sharper Lanczos
estimate through ``mu=`` yourself).

Residual semantics: the recorded history is the digitally-recomputable KKT
measure at the primal iterate,

    ( || x - clip(x - grad(x), lo, hi) ||  +  || x - z || ) / (1 + ||x||)

i.e. projected-gradient stationarity plus consensus infeasibility.  The
gradient in the recorded value is the one the iteration just computed (so
it sees analog noise); the contract suite recomputes the same formula
digitally from the returned ``(x, dual=z)``.  The feasible split copy ``z``
is returned in ``SolveResult.dual`` -- take ``res.dual`` when a hard
in-box iterate is required, ``res.x`` for the stationarity-optimal one.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .base import (LinearOperator, SolveResult, as_operator, col_norms,
                   init_history, pack_result)
from .pdhg import _power_norm

__all__ = ["admm", "admm_pipeline", "random_box_qp"]

_TINY = 1e-30


def random_box_qp(
    key: jax.Array,
    m: int,
    n: int,
    batch: int = 1,
    active_frac: float = 0.3,
) -> Tuple[jnp.ndarray, ...]:
    """A random box-constrained QP with a KNOWN optimal point.

    Construction: draw ``A`` (m, n) Gaussian and an optimal ``x*`` in the
    box ``[-1, 1]^n`` with ~``active_frac`` of its components ON the bounds.
    KKT for the box-QP says the gradient at the optimum satisfies
    ``grad_i >= 0`` where ``x*_i = lo_i``, ``<= 0`` where ``x*_i = hi_i``
    and ``= 0`` in the interior -- so draw such a ``g``, pick any ``b``, and
    back out ``q = g - A'(A x* - b)``.  Then ``x*`` is exactly optimal: an
    oracle target without an external QP solver.

    Returns ``(a, b, q, lo, hi, x_star)``; vector outputs are squeezed to
    1-D when ``batch == 1``.
    """
    ka, kx, kg, kb, kw = jax.random.split(key, 5)
    a = jax.random.normal(ka, (m, n), jnp.float32) / jnp.sqrt(float(n))
    lo = -jnp.ones((n,), jnp.float32)
    hi = jnp.ones((n,), jnp.float32)
    interior = jax.random.uniform(kx, (n, batch), jnp.float32,
                                  minval=-0.9, maxval=0.9)
    side = jax.random.uniform(kw, (n, batch)) < 0.5
    bound = jnp.where(side, lo[:, None], hi[:, None])
    active = jax.random.uniform(kg, (n, batch)) < active_frac
    x_star = jnp.where(active, bound, interior)
    # Multiplier magnitudes; sign follows which bound is active.
    mult = jnp.abs(jax.random.normal(kg, (n, batch), jnp.float32))
    grad = jnp.where(active, jnp.where(side, mult, -mult), 0.0)
    b = jax.random.normal(kb, (m, batch), jnp.float32)
    q = grad - a.T @ (a @ x_star - b)
    if batch == 1:
        return a, b[:, 0], q[:, 0], lo, hi, x_star[:, 0]
    return a, b, q, lo, hi, x_star


def _admm_core(op: LinearOperator, b, q, x0, key, *, lo, hi, rho: float,
               mu, tol: float, maxiter: int, power_iters: int):
    batch = b.shape[1]
    lo_c = lo[:, None]
    hi_c = hi[:, None]

    if mu is None:
        norm_a = _power_norm(op, jax.random.fold_in(key, 900_005),
                             power_iters)
        mu_v = 1.0 / (1.05 * (jnp.square(norm_a) + rho))
        # Each power step is one forward + one transposed batch-1 MVM,
        # billed separately from the solve's full-batch iterations.
        pi_mvms = jnp.int32(power_iters)
    else:
        mu_v = jnp.float32(mu)
        pi_mvms = jnp.int32(0)

    def kkt(x, z, grad):
        stat = col_norms(x - jnp.clip(x - grad, lo_c, hi_c))
        feas = col_norms(x - z)
        return (stat + feas) / (1.0 + col_norms(x))

    z0 = jnp.clip(x0, lo_c, hi_c)
    u0 = jnp.zeros_like(x0)
    ax0 = op.matvec(x0, jax.random.fold_in(key, 0))
    grad0 = op.rmatvec(ax0 - b, jax.random.fold_in(key, 1)) + q
    rel0 = kkt(x0, z0, grad0)

    def cond(state):
        k = state[0]
        rel = state[6]
        return jnp.logical_and(k < maxiter,
                               jnp.logical_not(jnp.all(rel <= tol)))

    def body(state):
        k, x, z, u, grad, hist, _rel, mvms = state
        x = x - mu_v * (grad + rho * (x - z + u))
        z = jnp.clip(x + u, lo_c, hi_c)
        u = u + x - z
        # Gradient at the NEW iterate -- the iteration's one matvec+rmatvec
        # pair -- so the recorded KKT residual is evaluated at exactly the
        # (x, z) this state returns (digitally recomputable by the contract
        # suite from the final result).
        ax = op.matvec(x, jax.random.fold_in(key, 2 + 2 * k))
        grad = op.rmatvec(ax - b, jax.random.fold_in(key, 3 + 2 * k)) + q
        rel = kkt(x, z, grad)
        hist = hist.at[k].set(rel)
        return k + 1, x, z, u, grad, hist, rel, mvms + 1

    hist0 = init_history(maxiter, batch)
    state0 = (jnp.int32(0), x0, z0, u0, grad0, hist0, rel0, jnp.int32(1))
    out = jax.lax.while_loop(cond, body, state0)
    k, x, z, hist, mvms = out[0], out[1], out[2], out[5], out[7]
    return x, z, hist, k, mvms, pi_mvms, rel0


def admm_pipeline(
    op: LinearOperator,
    *,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    rho: float = 1.0,
    mu: Optional[float] = None,
    tol: float = 1e-4,
    maxiter: int = 500,
    power_iters: int = 16,
):
    """The jit-able ADMM core ``(b, q, x0, key) -> (x, z, hist, k, mvms,
    pi_mvms, rel0)``.

    Exposed for the invariant gate; ``b`` is (m, batch), ``q``/``x0``
    (n, batch), ``lo``/``hi`` (n,) bound vectors.  ``mu=None`` adds the
    power-iteration ``||A||_2`` estimate to the traced program.
    """
    return functools.partial(_admm_core, op, lo=lo, hi=hi, rho=rho, mu=mu,
                             tol=tol, maxiter=maxiter,
                             power_iters=power_iters)


def admm(
    A,
    b: jnp.ndarray,
    q: jnp.ndarray,
    *,
    lo,
    hi,
    rho: float = 1.0,
    mu: Optional[float] = None,
    tol: float = 1e-4,
    maxiter: int = 500,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
    power_iters: int = 16,
) -> SolveResult:
    """Solve ``min (1/2)||Ax - b||^2 + q'x  s.t.  lo <= x <= hi`` by
    linearized ADMM: one corrected matvec + one corrected rmatvec per
    iteration against the programmed image.

    ``b`` is (m,) / (m, batch), ``q`` (n,) / (n, batch) -- each column an
    independent QP over the shared bounds ``lo``/``hi`` (scalars or (n,)
    vectors).  ``rho`` is the consensus penalty; ``mu`` the linearized step
    (default ``1 / (1.05 (||A||_2^2 + rho))`` with the norm from
    ``power_iters`` power-iteration steps, billed to the ledger).  Returns a
    :class:`SolveResult` with the stationarity iterate in ``x``, the
    box-feasible split copy in ``dual``, and the KKT residual history
    (projected-gradient stationarity + consensus gap, relative).
    """
    op = as_operator(A)
    if op.rmatvec is None:
        raise ValueError(
            "admm needs an operator with rmatvec (A.T @ u): pass an "
            "AnalogMatrix / dense array, or as_operator(mv, shape=..., "
            "rmatvec=...)")
    m, n = op.shape
    squeeze = b.ndim == 1
    if (q.ndim == 1) != squeeze:
        raise ValueError("b and q must both be vectors or both be panels")
    bb = (b[:, None] if squeeze else b).astype(jnp.float32)
    qq = (q[:, None] if squeeze else q).astype(jnp.float32)
    if bb.shape[0] != m or qq.shape[0] != n:
        raise ValueError(
            f"b has {bb.shape[0]} rows and q {qq.shape[0]} for an operator "
            f"of shape {op.shape}; expected ({m}, batch) and ({n}, batch)")
    if bb.shape[1] != qq.shape[1]:
        raise ValueError(f"b batch {bb.shape[1]} != q batch {qq.shape[1]}")
    lo_v = jnp.broadcast_to(jnp.asarray(lo, jnp.float32), (n,))
    hi_v = jnp.broadcast_to(jnp.asarray(hi, jnp.float32), (n,))
    if bool(jnp.any(lo_v > hi_v)):
        raise ValueError("box is empty: lo > hi somewhere")
    x0b = jnp.zeros_like(qq) if x0 is None else \
        (x0[:, None] if squeeze else x0).astype(jnp.float32)
    key = jax.random.PRNGKey(0) if key is None else key

    core = jax.jit(admm_pipeline(op, lo=lo_v, hi=hi_v, rho=rho, mu=mu,
                                 tol=tol, maxiter=maxiter,
                                 power_iters=power_iters))
    x, z, hist, k, mvms, pi_mvms, rel0 = core(bb, qq, x0b, key)
    res = pack_result(op, "admm", x, hist, k, mvms, tol, squeeze,
                      mvms_single=int(pi_mvms), rel0=rel0, mvms_t=int(mvms),
                      mvms_single_t=int(pi_mvms))
    res.dual = z[:, 0] if squeeze else z
    return res
