"""Solver-layer plumbing: operators, results, and the energy/latency ledger.

MELISO+ is an in-memory *linear solver*: the regime that pays for programming
an RRAM image once is hundreds of matvecs against it (the companion PDHG paper
runs exactly this loop).  This module is the contract between the iterative
methods (:mod:`stationary`, :mod:`krylov`, :mod:`refinement`) and whatever
supplies the matvec:

  * :func:`as_operator` adapts an :class:`~repro.engine.AnalogMatrix` (noisy,
    error-corrected analog MVM + real write-cost accounting), a transposed
    :class:`~repro.engine.TransposedAnalogMatrix` view, a dense
    ``jnp.ndarray`` (exact digital matvec, zero analog cost -- the oracle used
    in tests), or a bare ``matvec(v, key)`` callable into one
    :class:`LinearOperator` interface.  Every solver is matvec-only -- plus
    ``rmatvec`` (the corrected TRANSPOSED MVM ``A.T @ u`` against the same
    programmed image) for the primal-dual methods -- so the same code runs
    unchanged against ``local``, ``streamed`` and ``distributed`` execution
    and both engine backends.
  * :class:`SolveResult` is what every solver returns: the solution, the
    per-iteration relative-residual history, convergence info, and a
    :class:`SolveLedger` splitting energy/latency into the one-time
    programming cost (``write_stats``, paid at ``engine.program``) and the
    per-iteration input-write cost (one x DAC pass + EC X^T replica per MVM).

Key discipline: each analog MVM inside a solve consumes ``fold_in(key, i)``
for a global matvec counter ``i``, so a solve is reproducible given its base
key and two solvers issued the same draws never correlate across iterations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.write_verify import WriteStats
from repro.engine import AnalogMatrix, TransposedAnalogMatrix

__all__ = [
    "LinearOperator", "SolveLedger", "SolveResult", "as_operator",
    "col_norms", "init_history", "use_pallas",
]

_TINY = 1e-30


def use_pallas(backend: Optional[str]) -> bool:
    """Validate a solver ``backend=`` switch (None -> reference path)."""
    if backend is None:
        return False
    if backend not in ("reference", "pallas"):
        raise ValueError(f"unknown solver backend {backend!r}")
    return backend == "pallas"


def col_norms(v: jnp.ndarray) -> jnp.ndarray:
    """Column-wise l2 norms of an (n, batch) panel -> (batch,)."""
    return jnp.sqrt(jnp.sum(jnp.square(v), axis=0))


def init_history(maxiter: int, batch: int) -> jnp.ndarray:
    """NaN-filled (maxiter, batch) relative-residual history; iterations that
    never run stay NaN so plots/tests can distinguish 'converged early'."""
    return jnp.full((maxiter, batch), jnp.nan, jnp.float32)


@dataclasses.dataclass(frozen=True)
class LinearOperator:
    """Matvec-only view of a (square or rectangular) matrix.

    ``matvec(v, key)`` maps (n, batch) -> (m, batch); ``key`` seeds the input
    DAC noise of an analog execution and is ignored by digital operators.
    ``rmatvec(u, key)`` -- when available -- maps (m, batch) -> (n, batch)
    through the TRANSPOSED corrected MVM ``A.T @ u`` against the same
    programmed image (``None`` for operators that cannot transpose, e.g. a
    bare matvec callable without an explicit ``rmatvec=``); primal-dual
    methods (:func:`repro.solvers.pdhg`) require it.
    ``input_stats_t`` bills one transposed MVM's input writes (the m-length
    DAC pass + the row-dimension EC replica).
    """

    matvec: Callable[[jnp.ndarray, jax.Array], jnp.ndarray]
    shape: Tuple[int, int]
    write_stats: WriteStats                      # one-time programming cost
    input_stats: Callable[[int], WriteStats]     # per-MVM cost, fn of batch
    dense: Optional[Callable[[], jnp.ndarray]]   # digital reconstruction
    analog: bool
    rmatvec: Optional[Callable[[jnp.ndarray, jax.Array], jnp.ndarray]] = None
    input_stats_t: Optional[Callable[[int], WriteStats]] = None

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def T(self) -> "LinearOperator":
        """The transposed operator (matvec/rmatvec and shapes swapped).

        Requires ``rmatvec``; shares the parent's write_stats (the programmed
        image is one physical object, whichever direction it is read)."""
        if self.rmatvec is None:
            raise ValueError("operator has no rmatvec; cannot transpose")
        return LinearOperator(
            matvec=self.rmatvec, rmatvec=self.matvec,
            shape=(self.shape[1], self.shape[0]),
            write_stats=self.write_stats,
            input_stats=self.input_stats_t or self.input_stats,
            input_stats_t=self.input_stats,
            dense=(lambda: self.dense().T) if self.dense is not None else None,
            analog=self.analog,
        )


def _zero_stats(_batch: int = 1) -> WriteStats:
    return WriteStats.zero()


def as_operator(
    A: Union[AnalogMatrix, jnp.ndarray, Callable],
    *,
    shape: Optional[Tuple[int, int]] = None,
    rmatvec: Optional[Callable] = None,
) -> LinearOperator:
    """Adapt ``A`` into a :class:`LinearOperator`.

    ``A`` may be an :class:`AnalogMatrix` handle (programmed once; each matvec
    is a corrected analog execution whose input-write cost lands in the
    ledger -- and ``rmatvec`` is its corrected TRANSPOSED execution against
    the same image), a :class:`~repro.engine.TransposedAnalogMatrix` view
    (``A.T``: matvec/rmatvec swapped), a dense array (exact digital matvec +
    rmatvec, zero ledger), or a callable ``matvec(v, key)`` with
    ``shape=(m, n)`` (optionally ``rmatvec=`` for methods that need
    ``A.T @ u``).
    """
    if isinstance(A, LinearOperator):
        return A
    if isinstance(A, TransposedAnalogMatrix):
        return as_operator(A.parent).T
    if isinstance(A, AnalogMatrix):
        # Streamed handles with a traceable producer keep the whole solve one
        # compiled program: each matvec inside the solver's jitted core traces
        # the engine's scan-fused pipeline inline (one dispatch per MVM), and
        # ``dense()`` reconstructs A with a single producer sweep (used by
        # jacobi's diagonal and refine's digital outer residual).
        # Distributed handles stay distributed: the matvec's output is
        # row-sharded straight out of shard_map, and because the solver
        # reductions are plain per-column jnp ops, GSPMD keeps the x/r/p
        # panels sharded across the whole jitted while_loop -- no gathers.
        eng = A.engine
        return LinearOperator(
            matvec=lambda v, k: eng.mvm(A, v, key=k),
            rmatvec=lambda u, k: eng.rmvm(A, u, key=k),
            shape=A.shape,
            write_stats=A.write_stats,
            input_stats=lambda batch: eng.input_write_stats(A, batch),
            input_stats_t=lambda batch: eng.input_write_stats(
                A, batch, transpose=True),
            dense=A.dense,
            analog=True,
        )
    if callable(A) and not hasattr(A, "shape"):
        if shape is None:
            raise ValueError("as_operator(matvec, ...) requires shape=(m, n)")
        return LinearOperator(matvec=A, rmatvec=rmatvec, shape=tuple(shape),
                              write_stats=WriteStats.zero(),
                              input_stats=_zero_stats, dense=None,
                              analog=False)
    a = jnp.asarray(A)
    if a.ndim != 2:
        raise ValueError(f"expected a matrix, got shape {a.shape}")
    return LinearOperator(matvec=lambda v, _k: a @ v,
                          rmatvec=lambda u, _k: a.T @ u,
                          shape=a.shape,
                          write_stats=WriteStats.zero(),
                          input_stats=_zero_stats, dense=lambda: a,
                          analog=False)


@dataclasses.dataclass(frozen=True)
class SolveLedger:
    """Energy/latency split of one solve under the program-once model.

    ``write_stats`` is the one-time conductance-image programming cost (zero
    for digital operators); ``input_stats`` is the cost of ONE analog MVM's
    input writes (x DAC pass + EC X^T replica, scaling with the RHS batch);
    ``mvms`` counts the full-batch analog MVMs the solve executed.  Setup
    MVMs that run on a single column regardless of the RHS batch (the
    power-iteration spectral estimate) are billed separately as
    ``mvms_single`` at the ``input_stats_single`` (batch=1) rate, so the
    amortized totals are ``write + mvms*input + mvms_single*input_single``.
    Primal-dual solves additionally execute TRANSPOSED MVMs against the same
    image: those are counted in ``mvms_t`` at the ``input_stats_t`` rate
    (the m-length y DAC pass + the row-dimension EC replica), and their
    batch-1 setup half (the power-iteration steps on ``A.T A`` alternate one
    forward with one transposed MVM) in ``mvms_single_t`` at the batch-1
    transposed rate -- the matrix write is still paid exactly once,
    whichever directions read it.
    """

    write_stats: WriteStats
    input_stats: WriteStats
    mvms: int
    input_stats_single: Optional[WriteStats] = None
    mvms_single: int = 0
    input_stats_t: Optional[WriteStats] = None
    mvms_t: int = 0
    input_stats_single_t: Optional[WriteStats] = None
    mvms_single_t: int = 0

    @property
    def write_energy_j(self) -> float:
        return float(self.write_stats.energy_j)

    def _rates(self):
        single = self.input_stats_single or self.input_stats
        transposed = self.input_stats_t or self.input_stats
        single_t = self.input_stats_single_t or transposed
        return ((self.input_stats, self.mvms), (single, self.mvms_single),
                (transposed, self.mvms_t), (single_t, self.mvms_single_t))

    @property
    def iteration_energy_j(self) -> float:
        return sum(float(rate.energy_j) * count
                   for rate, count in self._rates())

    @property
    def total_energy_j(self) -> float:
        return self.write_energy_j + self.iteration_energy_j

    @property
    def total_latency_s(self) -> float:
        return float(self.write_stats.latency_s) + sum(
            float(rate.latency_s) * count for rate, count in self._rates())


@dataclasses.dataclass
class SolveResult:
    """What every solver in :mod:`repro.solvers` returns.

    ``residuals`` is the per-iteration relative residual ``||r_k|| / ||b||``,
    shaped (maxiter,) for a vector RHS or (maxiter, batch) for multi-RHS;
    entries past ``iterations`` are NaN.  For restarted GMRES one "iteration"
    is one restart cycle.  ``initial_residual`` is the worst-column relative
    residual at ENTRY (after the init MVM, before any update): a solve that
    is already converged there stops at ``iterations == 0`` with an all-NaN
    history, and ``final_residual``/``converged`` report the entry residual
    instead of the old dishonest ``-inf`` / ``False``.  Solvers without an
    init MVM (the stationary methods always run >= 1 iteration) leave it NaN.
    """

    x: jnp.ndarray
    residuals: jnp.ndarray
    iterations: int
    converged: bool
    ledger: SolveLedger
    solver: str
    initial_residual: float = float("nan")
    # Primal-dual solves (pdhg) also return the dual variable y; None for
    # the purely-primal linear-system solvers.
    dual: Optional[jnp.ndarray] = None
    # Checkpoint restores a fault-tolerant wrapper performed to finish this
    # solve (repro.reliability.ft_solve); 0 for a clean run.
    restores: int = 0
    # Eigen-solves (lanczos / lobpcg) return their eigenvalue estimates here
    # (ascending, matching the columns of x); None for linear solves.
    eigenvalues: Optional[jnp.ndarray] = None

    @property
    def final_residual(self) -> float:
        """Worst-column relative residual at the last recorded iteration (the
        entry residual when the solve converged before iterating)."""
        if self.iterations == 0:
            return self.initial_residual
        r = self.residuals if self.residuals.ndim == 2 \
            else self.residuals[:, None]
        row = r[self.iterations - 1]
        if bool(jnp.all(jnp.isnan(row))):
            # Breakdown (e.g. a device fault mid-solve): the recorded row is
            # all NaN.  Report NaN -- which compares False against any tol --
            # instead of the old -inf, which read as "converged".
            return float("nan")
        return float(jnp.nanmax(row))

    def __repr__(self) -> str:  # keep large arrays out of logs
        m, b = (self.residuals.shape + (1,))[:2]
        return (f"SolveResult(solver={self.solver!r}, n={self.x.shape[0]}, "
                f"batch={b}, iterations={self.iterations}, "
                f"converged={self.converged}, "
                f"final_residual={self.final_residual:.3e}, "
                f"mvms={self.ledger.mvms}, "
                f"energy_j={self.ledger.total_energy_j:.3e})")


def pack_result(
    op: LinearOperator,
    solver: str,
    x: jnp.ndarray,
    hist: jnp.ndarray,
    iterations,
    mvms,
    tol: float,
    squeeze: bool,
    mvms_single: int = 0,
    rel0=None,
    mvms_t: int = 0,
    mvms_single_t: int = 0,
) -> SolveResult:
    """Assemble a :class:`SolveResult` from a jitted core's raw outputs.

    ``mvms`` are full-batch solve MVMs; ``mvms_single`` are batch-1 setup
    MVMs (spectral estimates), billed at the batch-1 input-write rate;
    ``mvms_t`` / ``mvms_single_t`` are the full-batch / batch-1 TRANSPOSED
    counterparts, billed at the transposed rates.  ``rel0`` is the per-column relative
    residual at entry (from the core's init MVM), which makes iteration-0
    convergence honest: zero RHS or an exact ``x0`` yields
    ``converged=True`` with ``final_residual == rel0`` rather than
    ``False`` / ``-inf``.
    """
    batch = x.shape[1]
    iterations = int(iterations)
    initial = float(jnp.max(rel0)) if rel0 is not None else float("nan")
    stats_t = op.input_stats_t or op.input_stats
    res = SolveResult(
        x=x[:, 0] if squeeze else x,
        residuals=hist[:, 0] if squeeze else hist,
        iterations=iterations,
        converged=False,
        ledger=SolveLedger(write_stats=op.write_stats,
                           input_stats=op.input_stats(batch),
                           mvms=int(mvms),
                           input_stats_single=op.input_stats(1),
                           mvms_single=int(mvms_single),
                           input_stats_t=stats_t(batch),
                           mvms_t=int(mvms_t),
                           input_stats_single_t=stats_t(1),
                           mvms_single_t=int(mvms_single_t)),
        solver=solver,
        initial_residual=initial,
    )
    # NaN-robust: a NaN final residual (breakdown, or iteration 0 with no
    # recorded entry residual) compares False and stays not-converged.
    res.converged = bool(res.final_residual <= tol)
    return res
