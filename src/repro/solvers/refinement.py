"""Mixed-precision iterative refinement: analog inner solve, digital outer.

The paper's two-tier error-correction philosophy (cheap analog compute, a thin
exact correction layered on top) lifted to the solver level:

    r_k = b - A x_k          (digital fp32, the EXACT matrix A_tilde + dA)
    d_k ~= A^{-1} r_k        (analog inner solve against the programmed image)
    x_{k+1} = x_k + d_k

The inner solve only needs a crude correction (its error contracts the outer
residual by the factor it achieves), so it runs few iterations at a loose
tolerance entirely on the analog array; the outer loop's exact residual lets
the combination converge *below the analog noise floor* that caps a bare
Krylov/stationary solve.  Costs one digital (n, n) matvec per outer step.

Matvec-only on the analog side; the digital matrix is reconstructed once from
the programmed operands (or passed via ``a_digital`` when the caller has it).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .base import (SolveResult, as_operator, col_norms, init_history,
                   pack_result, use_pallas)
from .krylov import _cg_core
from .stationary import _stationary_core, spectral_bounds

__all__ = ["refine"]

_TINY = 1e-30


def refine(
    A,
    b: jnp.ndarray,
    *,
    inner: str = "cg",
    inner_iters: int = 8,
    inner_tol: float = 1e-2,
    tol: float = 1e-8,
    maxiter: int = 20,
    omega: Optional[float] = None,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
    a_digital: Optional[jnp.ndarray] = None,
    backend: Optional[str] = None,
) -> SolveResult:
    """Iterative refinement with an analog inner solver.

    ``inner`` is ``"cg"`` or ``"richardson"`` (each capped at ``inner_iters``
    analog MVM iterations / ``inner_tol``); the outer residual is exact fp32.
    The residual history records the *digital* relative residual after each
    outer correction, so it keeps falling where a pure analog solve plateaus.
    """
    op = as_operator(A)
    if a_digital is None:
        if op.dense is None:
            raise ValueError(
                "refine needs a_digital= for a bare matvec operator")
        a_digital = op.dense()
    ad = jnp.asarray(a_digital, jnp.float32)
    if inner not in ("cg", "richardson"):
        raise ValueError(f"unknown inner solver {inner!r}")

    squeeze = b.ndim == 1
    bb = (b[:, None] if squeeze else b).astype(jnp.float32)
    x0b = jnp.zeros_like(bb) if x0 is None else \
        (x0[:, None] if squeeze else x0).astype(jnp.float32)
    key = jax.random.PRNGKey(0) if key is None else key

    pallas = use_pallas(backend)
    mvms_single = 0
    if inner == "cg":
        inner_core = functools.partial(
            _cg_core, op, tol=inner_tol, maxiter=inner_iters,
            use_pallas=pallas)
    else:
        if omega is None:
            # Resolve omega ONCE for the unchanged operator -- estimating it
            # inside every outer iteration would re-spend 2*iters analog MVMs
            # per correction on the same spectral bounds.
            pi_iters = 8
            lmin, lmax = spectral_bounds(
                op, key=jax.random.fold_in(key, 900_002), iters=pi_iters)
            omega = 2.0 / (1.05 * lmax + max(lmin, 0.0))
            mvms_single = 2 * pi_iters
        inner_core = functools.partial(
            _stationary_core, op, None, omega=omega, tol=inner_tol,
            maxiter=inner_iters, use_pallas=pallas, power_iters=0)

    def core(b, x0, key):
        batch = b.shape[1]
        bn = jnp.maximum(col_norms(b), _TINY)
        r0 = b - ad @ x0                                 # digital, exact

        def cond(state):
            k, _x, _r, rel, _h, _m = state
            return jnp.logical_and(k < maxiter,
                                   jnp.logical_not(jnp.all(rel <= tol)))

        def body(state):
            k, x, r, _rel, hist, mvms = state
            ikey = jax.random.fold_in(key, 500_000 + k)
            out = inner_core(r, jnp.zeros_like(r), ikey)
            d, inner_mvms = out[0], out[3]
            x = x + d
            r = b - ad @ x                               # digital, exact
            rel = col_norms(r) / bn
            hist = hist.at[k].set(rel)
            return k + 1, x, r, rel, hist, mvms + inner_mvms

        rel0 = col_norms(r0) / bn
        state0 = (jnp.int32(0), x0, r0, rel0,
                  init_history(maxiter, batch), jnp.int32(0))
        k, x, _r, _rel, hist, mvms = jax.lax.while_loop(cond, body, state0)
        return x, hist, k, mvms, rel0

    x, hist, k, mvms, rel0 = jax.jit(core)(bb, x0b, key)
    return pack_result(op, f"refine[{inner}]", x, hist, k, mvms, tol, squeeze,
                       mvms_single=mvms_single, rel0=rel0)
