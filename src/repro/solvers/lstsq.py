"""Least-squares solvers: LSQR and LSMR on the matvec+rmatvec operator.

The tile geometry has always supported non-square crossbars, and PR 5's
transposed corrected MVM (``rmatvec``) supplies exactly the two products
Golub-Kahan bidiagonalization consumes -- so overdetermined systems

    min_x || A x - b ||_2,        A (m, n) rectangular

run against ONE programmed rectangular image at one corrected ``A @ v`` plus
one corrected ``A.T @ u`` per iteration, the same per-iteration budget as
:mod:`~repro.solvers.pdhg` (and the regime of the companion RRAM-PDHG
paper).  Both methods are transcribed from the Paige-Saunders / Fong-Saunders
recurrences:

  * :func:`lsqr` -- CG on the normal equations ``A'A x = A'b`` in exact
    arithmetic, but built on the bidiagonalization so it never forms (or
    squares the conditioning of) ``A'A``;
  * :func:`lsmr` -- MINRES on the normal equations: the normal-equations
    residual ``||A'r_k||`` decreases MONOTONICALLY, which is the better
    behaved choice when analog noise makes late LSQR iterates fluctuate.

Residual semantics: least-squares solves of inconsistent systems do NOT
drive ``||b - A x||`` to zero, so the recorded per-iteration history (and
``SolveResult.final_residual``) is the *normal-equations* relative residual

    || A' (b - A x_k) ||  /  || A' b ||

which converges to zero for consistent AND inconsistent problems (the
optimality condition of least squares is ``A'r = 0``).  Both methods carry
this quantity for free from the rotation recurrences (``phibar * alpha * c``
for LSQR, ``|zetabar|`` for LSMR); the solver-contract suite recomputes it
digitally from the returned ``x``.

Everything else matches the house style: per-column multi-RHS panels,
NaN-robust ``lax.while_loop`` early stopping, the whole solve (init MVMs
included) one jitted program, forward and transposed MVMs billed separately
to the :class:`~repro.solvers.base.SolveLedger`, and unchanged operation
across ``local`` / ``streamed`` / ``distributed`` execution (including
``resident=False`` producers, where a 65,536^2 least-squares solve runs
with no A-sized array anywhere -- pinned by the invariant gate).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .base import (LinearOperator, SolveResult, as_operator, col_norms,
                   init_history, pack_result)

__all__ = ["lsqr", "lsmr", "lsqr_pipeline", "lsmr_pipeline"]

_TINY = 1e-30


def _normalize(v):
    """(v / ||v||, ||v||) per column, guarded against zero columns."""
    nrm = col_norms(v)
    return v / jnp.maximum(nrm, _TINY)[None, :], nrm


def _unconverged(rel, tol):
    """NaN-robust: a NaN residual (breakdown) counts as not converged."""
    return jnp.logical_not(jnp.all(rel <= tol))


def _bidiag_init(op: LinearOperator, b, x0, key):
    """Shared Golub-Kahan start: u1 = r0/beta1, v1 = A'u1/alpha1.

    Consumes one forward MVM (the init residual ``b - A x0``) and one
    transposed MVM; ``alpha1 * beta1`` is ``||A'r0||``, the normal-equations
    residual at entry.
    """
    r0 = b - op.matvec(x0, jax.random.fold_in(key, 0))
    u, beta = _normalize(r0)
    v, alpha = _normalize(op.rmatvec(u, jax.random.fold_in(key, 1)))
    return u, v, alpha, beta


def _atb_norm(op: LinearOperator, b, key, alpha, beta, explicit_x0: bool):
    """||A'b|| -- the denominator of the recorded relative residual.

    With the default zero ``x0`` this is exactly ``alpha1 * beta1`` from the
    bidiagonalization start (``r0 = b``), costing nothing.  With a caller
    ``x0`` the start vector is ``b - A x0``, so one extra transposed
    full-panel MVM recovers the true normalization (billed by the wrapper).
    """
    if not explicit_x0:
        return jnp.maximum(alpha * beta, _TINY)
    atb = op.rmatvec(b, jax.random.fold_in(key, 900_011))
    return jnp.maximum(col_norms(atb), _TINY)


def _bidiag_step(op, u, v, alpha, key, k):
    """One Golub-Kahan continuation: new (u, beta, v, alpha).

    ``beta_{k+1} u_{k+1} = A v_k - alpha_k u_k`` (forward MVM, fold 2+2k),
    ``alpha_{k+1} v_{k+1} = A' u_{k+1} - beta_{k+1} v_k`` (transposed,
    fold 3+2k).  Folds continue the 0/1 init so every analog dispatch in the
    solve sees a distinct key.
    """
    u, beta = _normalize(
        op.matvec(v, jax.random.fold_in(key, 2 + 2 * k)) - alpha[None, :] * u)
    v, alpha = _normalize(
        op.rmatvec(u, jax.random.fold_in(key, 3 + 2 * k)) - beta[None, :] * v)
    return u, beta, v, alpha


# --------------------------------------------------------------------------- #
# LSQR (Paige & Saunders 1982)
# --------------------------------------------------------------------------- #

def _lsqr_core(op: LinearOperator, b, x0, key, *, tol: float, maxiter: int,
               explicit_x0: bool):
    batch = b.shape[1]
    u, v, alpha, beta = _bidiag_init(op, b, x0, key)
    atb = _atb_norm(op, b, key, alpha, beta, explicit_x0)
    rel0 = alpha * beta / atb

    def cond(state):
        k = state[0]
        rel = state[9]
        return jnp.logical_and(k < maxiter, _unconverged(rel, tol))

    def body(state):
        k, x, u, v, w, alpha, rhobar, phibar, hist, _rel, mvms = state
        u, beta, v, alpha = _bidiag_step(op, u, v, alpha, key, k)
        # Givens rotation eliminating beta from the lower bidiagonal.
        rho = jnp.maximum(
            jnp.sqrt(jnp.square(rhobar) + jnp.square(beta)), _TINY)
        c = rhobar / rho
        s = beta / rho
        theta = s * alpha
        rhobar = -c * alpha
        phi = c * phibar
        phibar = s * phibar
        x = x + (phi / rho)[None, :] * w
        w = v - (theta / rho)[None, :] * w
        # ||A'r_k|| = phibar_{k+1} * alpha_{k+1} * |c_k| (Paige-Saunders).
        rel = jnp.abs(phibar * alpha * c) / atb
        hist = hist.at[k].set(rel)
        return k + 1, x, u, v, w, alpha, rhobar, phibar, hist, rel, mvms + 1

    hist0 = init_history(maxiter, batch)
    state0 = (jnp.int32(0), x0, u, v, v, alpha, alpha, beta, hist0, rel0,
              jnp.int32(1))
    out = jax.lax.while_loop(cond, body, state0)
    k, x, hist, mvms = out[0], out[1], out[8], out[10]
    return x, hist, k, mvms, rel0


def lsqr_pipeline(
    op: LinearOperator,
    *,
    tol: float = 1e-4,
    maxiter: int = 200,
    explicit_x0: bool = False,
):
    """The jit-able LSQR core ``(b, x0, key) -> (x, hist, k, mvms, rel0)``.

    Exposed (like :func:`~repro.solvers.cg_pipeline`) so jaxpr-level tooling
    -- :mod:`repro.analysis.pipelines`, the invariant gate -- can trace the
    exact computation a least-squares solve dispatches.  ``b`` is an
    (m, batch) panel, ``x0`` (n, batch).  ``explicit_x0`` is the
    python-static switch for a caller-supplied start point (adds the one
    ``||A'b||`` normalization rmatvec).
    """
    return functools.partial(_lsqr_core, op, tol=tol, maxiter=maxiter,
                             explicit_x0=explicit_x0)


# --------------------------------------------------------------------------- #
# LSMR (Fong & Saunders 2011)
# --------------------------------------------------------------------------- #

def _lsmr_core(op: LinearOperator, b, x0, key, *, tol: float, maxiter: int,
               explicit_x0: bool):
    batch = b.shape[1]
    u, v, alpha, beta = _bidiag_init(op, b, x0, key)
    atb = _atb_norm(op, b, key, alpha, beta, explicit_x0)
    rel0 = alpha * beta / atb
    ones = jnp.ones((batch,), jnp.float32)
    zeros = jnp.zeros((batch,), jnp.float32)

    def cond(state):
        k = state[0]
        rel = state[14]
        return jnp.logical_and(k < maxiter, _unconverged(rel, tol))

    def body(state):
        (k, x, u, v, h, hbar, alpha, alphabar, zetabar, cbar, sbar, rho_old,
         rhobar_old, hist, _rel, mvms) = state
        u, beta, v, alpha = _bidiag_step(op, u, v, alpha, key, k)
        # First rotation: eliminate beta from the lower bidiagonal.
        rho = jnp.maximum(
            jnp.sqrt(jnp.square(alphabar) + jnp.square(beta)), _TINY)
        c = alphabar / rho
        s = beta / rho
        theta_new = s * alpha
        alphabar = c * alpha
        # Second rotation: the MINRES-style QR of the R factor.
        thetabar = sbar * rho
        rhotemp = cbar * rho
        rhobar = jnp.maximum(
            jnp.sqrt(jnp.square(rhotemp) + jnp.square(theta_new)), _TINY)
        cbar = rhotemp / rhobar
        sbar = theta_new / rhobar
        zeta = cbar * zetabar
        zetabar = -sbar * zetabar
        # Solution update through the two-level direction recurrences.
        hbar = h - (thetabar * rho
                    / jnp.maximum(rho_old * rhobar_old, _TINY))[None, :] * hbar
        x = x + (zeta / (rho * rhobar))[None, :] * hbar
        h = v - (theta_new / rho)[None, :] * h
        # ||A'r_k|| = |zetabar_{k+1}| -- monotone by construction.
        rel = jnp.abs(zetabar) / atb
        hist = hist.at[k].set(rel)
        return (k + 1, x, u, v, h, hbar, alpha, alphabar, zetabar, cbar, sbar,
                rho, rhobar, hist, rel, mvms + 1)

    hist0 = init_history(maxiter, batch)
    state0 = (jnp.int32(0), x0, u, v, v, jnp.zeros_like(x0), alpha, alpha,
              alpha * beta, ones, zeros, ones, ones, hist0, rel0,
              jnp.int32(1))
    out = jax.lax.while_loop(cond, body, state0)
    k, x, hist, mvms = out[0], out[1], out[13], out[15]
    return x, hist, k, mvms, rel0


def lsmr_pipeline(
    op: LinearOperator,
    *,
    tol: float = 1e-4,
    maxiter: int = 200,
    explicit_x0: bool = False,
):
    """The jit-able LSMR core ``(b, x0, key) -> (x, hist, k, mvms, rel0)``;
    see :func:`lsqr_pipeline` for the calling convention."""
    return functools.partial(_lsmr_core, op, tol=tol, maxiter=maxiter,
                             explicit_x0=explicit_x0)


# --------------------------------------------------------------------------- #
# Wrappers
# --------------------------------------------------------------------------- #

def _lstsq_solve(core_fn, name: str, A, b, *, tol, maxiter, x0, key):
    op = as_operator(A)
    if op.rmatvec is None:
        raise ValueError(
            f"{name} needs an operator with rmatvec (A.T @ u): pass an "
            "AnalogMatrix / dense array, or as_operator(mv, shape=..., "
            "rmatvec=...)")
    m, n = op.shape
    squeeze = b.ndim == 1
    bb = (b[:, None] if squeeze else b).astype(jnp.float32)
    if bb.shape[0] != m:
        raise ValueError(
            f"b has {bb.shape[0]} rows for an operator of shape {op.shape}; "
            f"expected ({m}, batch)")
    explicit_x0 = x0 is not None
    x0b = jnp.zeros((n, bb.shape[1]), jnp.float32) if x0 is None else \
        (x0[:, None] if squeeze else x0).astype(jnp.float32)
    key = jax.random.PRNGKey(0) if key is None else key
    core = jax.jit(core_fn(op, tol=tol, maxiter=maxiter,
                           explicit_x0=explicit_x0))
    x, hist, k, mvms, rel0 = core(bb, x0b, key)
    # Forward MVMs: init + one per iteration; transposed MVMs mirror them
    # exactly, plus the full-panel ||A'b|| normalization when x0 was given.
    return pack_result(op, name, x, hist, k, mvms, tol, squeeze, rel0=rel0,
                       mvms_t=int(mvms) + (1 if explicit_x0 else 0))


def lsqr(
    A,
    b: jnp.ndarray,
    *,
    tol: float = 1e-4,
    maxiter: int = 200,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """LSQR for ``min ||A x - b||`` on rectangular ``A``; one corrected
    matvec + one corrected rmatvec per iteration.

    ``b`` is (m,) / (m, batch); each column is an independent least-squares
    problem.  The residual history and convergence test use the
    normal-equations relative residual ``||A'(b - A x)|| / ||A'b||`` (zero
    at optimality for consistent AND inconsistent systems).  Returns a
    :class:`~repro.solvers.base.SolveResult` whose ledger bills forward and
    transposed MVMs separately against the one-time image write.
    """
    return _lstsq_solve(lsqr_pipeline, "lsqr", A, b, tol=tol,
                        maxiter=maxiter, x0=x0, key=key)


def lsmr(
    A,
    b: jnp.ndarray,
    *,
    tol: float = 1e-4,
    maxiter: int = 200,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """LSMR for ``min ||A x - b||``: MINRES on the normal equations, so
    ``||A'r||`` decreases monotonically -- the stabler pick when analog
    noise makes late LSQR iterates fluctuate.  Same contract as
    :func:`lsqr`."""
    return _lstsq_solve(lsmr_pipeline, "lsmr", A, b, tol=tol,
                        maxiter=maxiter, x0=x0, key=key)
