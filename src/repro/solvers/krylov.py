"""Krylov-subspace solvers: CG (SPD), BiCGSTAB and GMRES(m) (general).

All three touch ``A`` only through ``matvec(v, key)``, so they run unchanged
against every :class:`~repro.engine.AnalogEngine` execution mode (``local`` /
``streamed`` / ``distributed``) and backend.  Multi-RHS panels ``b`` of shape
(n, batch) are solved simultaneously -- every inner product, step length and
convergence test is per-column -- and the whole solve (including the
``lax.while_loop`` early stopping) traces into one jitted computation.

Distributed operands stay distributed: a producer-driven
``execution="distributed"`` handle's matvec emits its output row-sharded from
shard_map, and since every reduction here is a per-column ``jnp.sum`` /
norm (scalars replicate, panels never reshape), GSPMD propagates the row
sharding through the whole while_loop -- a sharded CG solve is ONE compiled
program whose x/r/p panels never gather onto a single device.

Analog caveat, and why these still work here: each MVM carries fresh DAC
noise, so Krylov recurrences see a slightly *inexact* operator.  With the
two-tier error correction on, the per-MVM relative error is ~1e-3, which
inexact-Krylov theory tolerates until the residual approaches the noise
floor; solves to tolerances below that floor should wrap the method in
:func:`repro.solvers.refinement.refine` (digital outer residual).

``backend="pallas"`` fuses CG's twin axpy (x/r update) into
:func:`repro.kernels.solver_cg_update`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .base import (LinearOperator, SolveResult, as_operator, col_norms,
                   init_history, pack_result, use_pallas)

__all__ = ["cg", "bicgstab", "gmres", "cg_pipeline"]

_TINY = 1e-30


def _cdot(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Per-column inner products of (n, batch) panels -> (batch,)."""
    return jnp.sum(u * v, axis=0)


def _safe(d: jnp.ndarray) -> jnp.ndarray:
    """Sign-preserving division guard (BiCGSTAB scalars are signed)."""
    return jnp.where(jnp.abs(d) < _TINY, _TINY, d)


def _unconverged(rel: jnp.ndarray, tol: float) -> jnp.ndarray:
    """NaN-robust: a NaN residual (breakdown) counts as not converged."""
    return jnp.logical_not(jnp.all(rel <= tol))


def _prep(b, x0):
    squeeze = b.ndim == 1
    bb = (b[:, None] if squeeze else b).astype(jnp.float32)
    x0b = jnp.zeros_like(bb) if x0 is None else \
        (x0[:, None] if squeeze else x0).astype(jnp.float32)
    return bb, x0b, squeeze


# --------------------------------------------------------------------------- #
# Conjugate gradients (SPD)
# --------------------------------------------------------------------------- #

def _cg_core(op: LinearOperator, b, x0, key, *, tol: float, maxiter: int,
             use_pallas: bool, divergence: Optional[float] = None):
    batch = b.shape[1]
    bn = jnp.maximum(col_norms(b), _TINY)
    r0 = b - op.matvec(x0, jax.random.fold_in(key, 0))
    rho0 = _cdot(r0, r0)
    rel0 = jnp.sqrt(rho0) / bn
    # Divergence tracking is a python-static switch: with divergence=None the
    # carry and jaxpr are byte-identical to the plain core (the invariant
    # gate pins that trace); with a factor set, the loop also carries the
    # best residual seen and exits on NaN or rel > divergence * best --
    # instead of burning maxiter NaN iterations after a device fault.
    track = divergence is not None

    def cond(state):
        if track:
            k, _x, _r, _p, _rho, _h, rel, best, _m = state
            spike = jnp.logical_or(
                jnp.any(jnp.isnan(rel)),
                jnp.any(rel > divergence * jnp.maximum(best, tol)))
            healthy = jnp.logical_not(spike)
        else:
            k, _x, _r, _p, _rho, _h, rel, _m = state
            healthy = True
        return jnp.logical_and(
            jnp.logical_and(k < maxiter, _unconverged(rel, tol)), healthy)

    def body(state):
        if track:
            k, x, r, p, rho, hist, _rel, best, mvms = state
        else:
            k, x, r, p, rho, hist, _rel, mvms = state
        ap = op.matvec(p, jax.random.fold_in(key, 1 + k))
        alpha = rho / jnp.maximum(_cdot(p, ap), _TINY)
        if use_pallas:
            from repro.kernels import solver_cg_update
            x, r = solver_cg_update(x, r, p, ap, alpha)
        else:
            x = x + alpha[None, :] * p
            r = r - alpha[None, :] * ap
        rho_new = _cdot(r, r)
        beta = rho_new / jnp.maximum(rho, _TINY)
        p = r + beta[None, :] * p
        rel = jnp.sqrt(rho_new) / bn
        hist = hist.at[k].set(rel)
        if track:
            best = jnp.minimum(best, rel)
            return k + 1, x, r, p, rho_new, hist, rel, best, mvms + 1
        return k + 1, x, r, p, rho_new, hist, rel, mvms + 1

    hist0 = init_history(maxiter, batch)
    if track:
        state0 = (jnp.int32(0), x0, r0, r0, rho0, hist0, rel0, rel0,
                  jnp.int32(1))
        k, x, _r, _p, _rho, hist, _rel, _best, mvms = jax.lax.while_loop(
            cond, body, state0)
    else:
        state0 = (jnp.int32(0), x0, r0, r0, rho0, hist0, rel0, jnp.int32(1))
        k, x, _r, _p, _rho, hist, _rel, mvms = jax.lax.while_loop(
            cond, body, state0)
    return x, hist, k, mvms, rel0


def cg_pipeline(
    op: LinearOperator,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    backend: Optional[str] = None,
    divergence: Optional[float] = None,
):
    """The jit-able CG core ``(b, x0, key) -> (x, hist, k, mvms, rel0)``.

    This is the whole-solve pipeline :func:`cg` jits -- exposed so
    jaxpr-level tooling (:mod:`repro.analysis.pipelines`, the invariant
    gate) can trace the exact computation a solve dispatches.  ``b`` and
    ``x0`` are (n, batch) panels.  ``divergence`` (a factor, e.g. 10) adds
    in-loop fault detection: exit as soon as any column's residual is NaN or
    exceeds ``divergence`` x the best residual seen -- the hook
    :mod:`repro.reliability.ft_solve` uses to stop a faulted segment early.
    See DESIGN.md sections 10 and 12.
    """
    return functools.partial(_cg_core, op, tol=tol, maxiter=maxiter,
                             use_pallas=use_pallas(backend),
                             divergence=divergence)


def cg(
    A,
    b: jnp.ndarray,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
    backend: Optional[str] = None,
    divergence: Optional[float] = None,
) -> SolveResult:
    """Conjugate gradients for SPD ``A``; one MVM per iteration.

    ``divergence`` enables early exit on NaN/residual-spike (see
    :func:`cg_pipeline`); the default None keeps the classic trace.
    """
    op = as_operator(A)
    bb, x0b, squeeze = _prep(b, x0)
    key = jax.random.PRNGKey(0) if key is None else key
    core = jax.jit(cg_pipeline(op, tol=tol, maxiter=maxiter,
                               backend=backend, divergence=divergence))
    x, hist, k, mvms, rel0 = core(bb, x0b, key)
    return pack_result(op, "cg", x, hist, k, mvms, tol, squeeze, rel0=rel0)


# --------------------------------------------------------------------------- #
# BiCGSTAB (general square A)
# --------------------------------------------------------------------------- #

def _bicgstab_core(op: LinearOperator, b, x0, key, *, tol: float,
                   maxiter: int):
    batch = b.shape[1]
    bn = jnp.maximum(col_norms(b), _TINY)
    r0 = b - op.matvec(x0, jax.random.fold_in(key, 0))
    rhat = r0                       # fixed shadow residual
    ones = jnp.ones((batch,), jnp.float32)
    zeros_p = jnp.zeros_like(b)

    def cond(state):
        k, _x, _r, _p, _v, _rho, _a, _w, _h, rel, _m = state
        return jnp.logical_and(k < maxiter, _unconverged(rel, tol))

    def body(state):
        k, x, r, p, v, rho, alpha, w, hist, _rel, mvms = state
        rho_new = _cdot(rhat, r)
        beta = (rho_new / _safe(rho)) * (alpha / _safe(w))
        p = r + beta[None, :] * (p - w[None, :] * v)
        v = op.matvec(p, jax.random.fold_in(key, 1 + 2 * k))
        alpha = rho_new / _safe(_cdot(rhat, v))
        s = r - alpha[None, :] * v
        t = op.matvec(s, jax.random.fold_in(key, 2 + 2 * k))
        w = _cdot(t, s) / _safe(_cdot(t, t))
        x = x + alpha[None, :] * p + w[None, :] * s
        r = s - w[None, :] * t
        rel = col_norms(r) / bn
        hist = hist.at[k].set(rel)
        return (k + 1, x, r, p, v, rho_new, alpha, w, hist, rel, mvms + 2)

    rel0 = col_norms(r0) / bn
    state0 = (jnp.int32(0), x0, r0, zeros_p, zeros_p, ones, ones, ones,
              init_history(maxiter, batch), rel0, jnp.int32(1))
    out = jax.lax.while_loop(cond, body, state0)
    k, x, hist, mvms = out[0], out[1], out[8], out[10]
    return x, hist, k, mvms, rel0


def bicgstab(
    A,
    b: jnp.ndarray,
    *,
    tol: float = 1e-6,
    maxiter: int = 200,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """BiCGSTAB for general square ``A``; two MVMs per iteration."""
    op = as_operator(A)
    bb, x0b, squeeze = _prep(b, x0)
    key = jax.random.PRNGKey(0) if key is None else key
    core = jax.jit(functools.partial(_bicgstab_core, op, tol=tol,
                                     maxiter=maxiter))
    x, hist, k, mvms, rel0 = core(bb, x0b, key)
    return pack_result(op, "bicgstab", x, hist, k, mvms, tol, squeeze,
                       rel0=rel0)


# --------------------------------------------------------------------------- #
# Restarted GMRES(m) (general square A)
# --------------------------------------------------------------------------- #

def _gmres_cycle(op: LinearOperator, x, r, key, m: int):
    """One Arnoldi(m) + least-squares correction.  Fixed-shape: the Krylov
    basis V is (m+1, n, batch) with unfilled rows zero; projections mask by
    position so the loop carries static shapes."""
    n, batch = r.shape
    beta = col_norms(r)
    V = jnp.zeros((m + 1, n, batch), jnp.float32)
    V = V.at[0].set(r / jnp.maximum(beta, _TINY)[None, :])
    H = jnp.zeros((m + 1, m, batch), jnp.float32)
    rows = jnp.arange(m + 1)

    def arnoldi(j, carry):
        V, H = carry
        vj = jax.lax.dynamic_index_in_dim(V, j, axis=0, keepdims=False)
        w = op.matvec(vj, jax.random.fold_in(key, 10 + j))
        # Classical Gram-Schmidt against the filled basis (rows <= j), twice
        # (CGS2) for fp32 stability at the usual m ~ 20.
        mask = (rows <= j).astype(jnp.float32)[:, None]
        h1 = jnp.einsum("inb,nb->ib", V, w) * mask
        w = w - jnp.einsum("ib,inb->nb", h1, V)
        h2 = jnp.einsum("inb,nb->ib", V, w) * mask
        w = w - jnp.einsum("ib,inb->nb", h2, V)
        hcol = h1 + h2
        hnorm = col_norms(w)
        hcol = hcol + (rows == j + 1).astype(jnp.float32)[:, None] * hnorm
        V = V.at[j + 1].set(w / jnp.maximum(hnorm, _TINY)[None, :])
        H = H.at[:, j].set(hcol)
        return V, H

    V, H = jax.lax.fori_loop(0, m, arnoldi, (V, H))

    # Per-column least squares min ||beta e1 - H y|| via ridge-stabilized
    # normal equations (m x m, tiny next to the MVMs).
    Hb = jnp.moveaxis(H, -1, 0)                     # (batch, m+1, m)
    rhs = jnp.zeros((batch, m + 1), jnp.float32).at[:, 0].set(beta)
    gram = jnp.einsum("bij,bik->bjk", Hb, Hb) \
        + 1e-12 * jnp.eye(m, dtype=jnp.float32)
    hty = jnp.einsum("bij,bi->bj", Hb, rhs)
    y = jnp.linalg.solve(gram, hty[..., None])[..., 0]   # (batch, m)
    dx = jnp.einsum("bj,jnb->nb", y, V[:m])
    return x + dx


def _gmres_core(op: LinearOperator, b, x0, key, *, tol: float, maxiter: int,
                restart: int):
    batch = b.shape[1]
    bn = jnp.maximum(col_norms(b), _TINY)
    ncycles = max(1, -(-maxiter // restart))
    r0 = b - op.matvec(x0, jax.random.fold_in(key, 0))

    def cond(state):
        c, _x, _r, rel, _h, _m = state
        return jnp.logical_and(c < ncycles, _unconverged(rel, tol))

    def body(state):
        c, x, r, _rel, hist, mvms = state
        ckey = jax.random.fold_in(key, 1000 + c)
        x = _gmres_cycle(op, x, r, ckey, restart)
        r = b - op.matvec(x, jax.random.fold_in(ckey, 1))
        rel = col_norms(r) / bn
        hist = hist.at[c].set(rel)
        return c + 1, x, r, rel, hist, mvms + restart + 1

    rel0 = col_norms(r0) / bn
    state0 = (jnp.int32(0), x0, r0, rel0,
              init_history(ncycles, batch), jnp.int32(1))
    c, x, _r, _rel, hist, mvms = jax.lax.while_loop(cond, body, state0)
    return x, hist, c, mvms, rel0


def gmres(
    A,
    b: jnp.ndarray,
    *,
    restart: int = 20,
    tol: float = 1e-6,
    maxiter: int = 200,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """Restarted GMRES(m) for general square ``A``.

    ``maxiter`` bounds total MVMs (``ceil(maxiter / restart)`` cycles of
    ``restart + 1`` MVMs each); ``SolveResult.iterations`` and the residual
    history are per *cycle*.
    """
    op = as_operator(A)
    bb, x0b, squeeze = _prep(b, x0)
    key = jax.random.PRNGKey(0) if key is None else key
    core = jax.jit(functools.partial(_gmres_core, op, tol=tol,
                                     maxiter=maxiter, restart=restart))
    x, hist, c, mvms, rel0 = core(bb, x0b, key)
    return pack_result(op, "gmres", x, hist, c, mvms, tol, squeeze, rel0=rel0)
