"""Primal-dual hybrid gradient (Chambolle-Pock) for linear programs.

The companion paper ("From GPUs to RRAMs: Distributed In-Memory Primal-Dual
Hybrid Gradient Method for Solving Large-Scale Linear Optimization Problems",
PAPERS.md) shows the SAME program-once crossbar image that serves linear
*systems* also serves linear *optimization*: PDHG touches the constraint
matrix only through ``A @ x`` and ``A.T @ y``, both of which the engine now
runs as corrected analog executions against one programmed image
(:meth:`~repro.engine.AnalogEngine.mvm` / ``rmvm``).  The LP solved here is
the standard-form problem

    min  c'x   s.t.  A x = b,  x >= 0,           A (m, n), m <= n typical

whose saddle form  min_{x>=0} max_y  c'x + y'(Ax - b)  yields the iteration

    x_{k+1} = proj_+( x_k - tau * (c + A'y_k) )          (1 rmatvec)
    y_{k+1} = y_k + sigma * (A (2 x_{k+1} - x_k) - b)    (1 matvec)

convergent for ``tau * sigma * ||A||_2^2 < 1``.  The step sizes default to
``tau = sigma = eta / ||A||_2`` with ``||A||_2`` estimated matvec-only by
power iteration on ``A.T A`` (each power step is one matvec + one rmatvec
against the programmed image, billed to the ledger as batch-1 setup MVMs).

Convergence is tracked per column with the standard PDLP-style KKT residual

    kkt = max( ||Ax - b|| / (1 + ||b||),                  primal feasibility
               ||proj_+(-(c + A'y))|| / (1 + ||c||),      dual feasibility
               |c'x + b'y| / (1 + |c'x| + |b'y|) )        duality gap

(the dual of the LP above is ``max -b'y  s.t.  A'y >= -c``), and the whole
solve -- step-size estimate, ``lax.while_loop`` early stopping, residual
history -- traces into ONE jitted computation.  ``A x_{k+1}`` is carried by
the exact recurrence ``A x_{k+1} = (A x_bar + A x_k) / 2``, so the KKT check
costs no extra MVMs.

Multi-RHS batching solves one LP per column of ``(b, c)`` panels
simultaneously; every inner product and test is per-column, so a batched
solve equals the stacked single-column solves on a digital operator.

Like every solver in :mod:`repro.solvers` this is matvec-only and runs
unchanged across ``local`` / ``streamed`` / ``distributed`` execution and
both backends -- including ``resident=False`` distributed producers, where a
>= 65,536^2 LP is solved with no A-sized array ever allocated (the transposed
scan re-encodes blocks exactly like the forward one; see
DESIGN.md section 5).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .base import (LinearOperator, SolveResult, as_operator, col_norms,
                   init_history, pack_result)

__all__ = ["pdhg", "pdhg_pipeline", "random_feasible_lp"]

_TINY = 1e-30


def random_feasible_lp(
    key: jax.Array,
    m: int,
    n: int,
    batch: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """A random standard-form LP with a KNOWN optimal primal-dual pair.

    Construction: draw ``A`` (m, n) Gaussian, split a Gaussian vector ``u``
    into the complementary pair ``x* = max(u, 0)`` / ``s = max(-u, 0)``
    (``s'x* = 0`` by construction), draw ``y*`` and set ``b = A x*``,
    ``c = A'y* + s``.  Then ``x*`` is primal feasible, ``(y*, s)`` is dual
    feasible (``c - A'y* = s >= 0``) and complementary slackness holds, so
    ``x*`` / ``y*`` are optimal with objective ``c'x* = b'y*`` -- an exact
    target for solver tests without running an external LP oracle.

    Returns ``(a, b, c, x_star, y_star)``; the vector outputs are squeezed to
    1-D when ``batch == 1``.
    """
    ka, ku, ky = jax.random.split(key, 3)
    a = jax.random.normal(ka, (m, n), jnp.float32) / jnp.sqrt(float(n))
    u = jax.random.normal(ku, (n, batch), jnp.float32)
    x_star = jnp.maximum(u, 0.0)
    s = jnp.maximum(-u, 0.0)
    y_star = jax.random.normal(ky, (m, batch), jnp.float32)
    b = a @ x_star
    c = a.T @ y_star + s
    if batch == 1:
        return a, b[:, 0], c[:, 0], x_star[:, 0], y_star[:, 0]
    return a, b, c, x_star, y_star


def _power_norm(op: LinearOperator, key: jax.Array, iters: int) -> jnp.ndarray:
    """||A||_2 estimate by power iteration on A.T A, matvec-only.

    Each step is one matvec + one rmatvec against the programmed image (2
    batch-1 MVMs); the dominant eigenvalue of A.T A is ||A||_2^2.
    """
    v0 = jax.random.normal(jax.random.fold_in(key, 0), (op.shape[1], 1),
                           jnp.float32)
    v0 = v0 / jnp.maximum(col_norms(v0), _TINY)

    def body(i, carry):
        v, _ = carry
        w = op.matvec(v, jax.random.fold_in(key, 1 + 2 * i))
        u = op.rmatvec(w, jax.random.fold_in(key, 2 + 2 * i))
        lam = col_norms(u)[0]
        return u / jnp.maximum(lam, _TINY), lam

    _, lam = jax.lax.fori_loop(0, iters, body, (v0, jnp.float32(0.0)))
    return jnp.sqrt(jnp.maximum(lam, _TINY))


def _pdhg_core(op: LinearOperator, b, c, x0, y0, key, *, tau, sigma, eta,
               tol: float, maxiter: int, power_iters: int,
               divergence: Optional[float] = None):
    batch = b.shape[1]
    # Static switch, as in krylov._cg_core: divergence=None keeps the carry
    # and jaxpr identical to the plain core; a factor adds best-KKT tracking
    # and NaN/spike early exit for fault-tolerant wrappers.
    track = divergence is not None
    bn = 1.0 + col_norms(b)
    cn = 1.0 + col_norms(c)

    if tau is None or sigma is None:
        norm_a = _power_norm(op, jax.random.fold_in(key, 900_003),
                             power_iters)
        step = eta / norm_a
        tau_v = step if tau is None else jnp.float32(tau)
        sigma_v = step if sigma is None else jnp.float32(sigma)
        # Each power step is one forward + one transposed batch-1 MVM; they
        # are billed separately (the two directions' input writes differ).
        pi_mvms = jnp.int32(power_iters)
    else:
        tau_v, sigma_v = jnp.float32(tau), jnp.float32(sigma)
        pi_mvms = jnp.int32(0)

    def kkt(x, y, ax, aty):
        primal = col_norms(ax - b) / bn
        dual = col_norms(jnp.maximum(-(c + aty), 0.0)) / cn
        pobj = jnp.sum(c * x, axis=0)
        dobj = -jnp.sum(b * y, axis=0)
        gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
        return jnp.maximum(jnp.maximum(primal, dual), gap)

    aty0 = op.rmatvec(y0, jax.random.fold_in(key, 0))
    ax0 = op.matvec(x0, jax.random.fold_in(key, 1))
    rel0 = kkt(x0, y0, ax0, aty0)

    def cond(state):
        if track:
            k, _x, _y, _ax, _aty, _h, rel, best, _m = state
            spike = jnp.logical_or(
                jnp.any(jnp.isnan(rel)),
                jnp.any(rel > divergence * jnp.maximum(best, tol)))
            healthy = jnp.logical_not(spike)
        else:
            k, _x, _y, _ax, _aty, _h, rel, _m = state
            healthy = True
        # NaN-robust: a NaN residual counts as not converged.
        return jnp.logical_and(
            jnp.logical_and(k < maxiter,
                            jnp.logical_not(jnp.all(rel <= tol))), healthy)

    def body(state):
        if track:
            k, x, y, ax, aty, hist, _rel, best, mvms = state
        else:
            k, x, y, ax, aty, hist, _rel, mvms = state
        x_new = jnp.maximum(x - tau_v * (c + aty), 0.0)
        x_bar = 2.0 * x_new - x
        ax_bar = op.matvec(x_bar, jax.random.fold_in(key, 2 + 2 * k))
        y_new = y + sigma_v * (ax_bar - b)
        aty_new = op.rmatvec(y_new, jax.random.fold_in(key, 3 + 2 * k))
        # A x_{k+1} from the over-relaxation identity x_bar = 2 x_{k+1} - x_k
        # -- exact for a linear digital operator, an averaged (noise-damped)
        # estimate for the analog one; no extra MVM either way.
        ax_new = 0.5 * (ax_bar + ax)
        rel = kkt(x_new, y_new, ax_new, aty_new)
        hist = hist.at[k].set(rel)
        if track:
            best = jnp.minimum(best, rel)
            return (k + 1, x_new, y_new, ax_new, aty_new, hist, rel, best,
                    mvms + 1)
        return k + 1, x_new, y_new, ax_new, aty_new, hist, rel, mvms + 1

    hist0 = init_history(maxiter, batch)
    if track:
        state0 = (jnp.int32(0), x0, y0, ax0, aty0, hist0, rel0, rel0,
                  jnp.int32(1))
        k, x, y, _ax, _aty, hist, _rel, _best, mvms = jax.lax.while_loop(
            cond, body, state0)
    else:
        state0 = (jnp.int32(0), x0, y0, ax0, aty0, hist0, rel0, jnp.int32(1))
        k, x, y, _ax, _aty, hist, _rel, mvms = jax.lax.while_loop(
            cond, body, state0)
    # mvms counts FORWARD full-batch MVMs (init + 1/iter); the transposed
    # count mirrors it exactly (init rmatvec + 1/iter).
    return x, y, hist, k, mvms, pi_mvms, rel0


def pdhg_pipeline(
    op: LinearOperator,
    *,
    tau: Optional[float] = None,
    sigma: Optional[float] = None,
    eta: float = 0.9,
    tol: float = 1e-4,
    maxiter: int = 2000,
    power_iters: int = 16,
    divergence: Optional[float] = None,
):
    """The jit-able PDHG core ``(b, c, x0, y0, key) -> (...)``.

    The whole-solve pipeline :func:`pdhg` jits (step-size power iteration,
    while-loop, KKT residuals), exposed so jaxpr-level tooling
    (:mod:`repro.analysis.pipelines`, the invariant gate) can trace the
    exact computation a solve dispatches.  All vector operands are
    (m, batch) / (n, batch) panels.  ``divergence`` (a factor) adds in-loop
    fault detection -- exit on NaN or a KKT residual above ``divergence`` x
    the best seen (see DESIGN.md sections 10 and 12).
    """
    return functools.partial(
        _pdhg_core, op, tau=tau, sigma=sigma, eta=eta, tol=tol,
        maxiter=maxiter, power_iters=power_iters, divergence=divergence)


def pdhg(
    A,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    tol: float = 1e-4,
    maxiter: int = 2000,
    eta: float = 0.9,
    tau: Optional[float] = None,
    sigma: Optional[float] = None,
    x0: Optional[jnp.ndarray] = None,
    y0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
    power_iters: int = 16,
    divergence: Optional[float] = None,
) -> SolveResult:
    """Solve ``min c'x  s.t.  A x = b, x >= 0`` by PDHG, matvec/rmatvec-only.

    ``A`` is anything :func:`~repro.solvers.as_operator` accepts that has an
    ``rmatvec`` (an :class:`~repro.engine.AnalogMatrix`, a dense array, or a
    bare matvec with ``rmatvec=`` supplied) -- one iteration is exactly one
    corrected ``A.T @ y`` plus one corrected ``A @ x`` against the programmed
    image.  ``b`` is (m,) / (m, batch) and ``c`` (n,) / (n, batch); each
    column is an independent LP.  ``tau``/``sigma`` default to
    ``eta / ||A||_2`` with the norm estimated by ``power_iters`` steps of
    power iteration on ``A.T A`` (billed as ``power_iters`` forward plus
    ``power_iters`` transposed batch-1 setup MVMs, each at its own
    input-write rate).  Returns a :class:`SolveResult` whose ``x`` is the primal
    solution, ``dual`` the dual variable ``y``, and ``residuals`` the
    per-iteration KKT residual (max of primal/dual infeasibility and the
    relative duality gap); the ledger splits forward and transposed MVMs.
    """
    op = as_operator(A)
    if op.rmatvec is None:
        raise ValueError(
            "pdhg needs an operator with rmatvec (A.T @ y): pass an "
            "AnalogMatrix / dense array, or as_operator(mv, shape=..., "
            "rmatvec=...)")
    m, n = op.shape
    squeeze = b.ndim == 1
    if (c.ndim == 1) != squeeze:
        raise ValueError("b and c must both be vectors or both be panels")
    bb = (b[:, None] if squeeze else b).astype(jnp.float32)
    cc = (c[:, None] if squeeze else c).astype(jnp.float32)
    if bb.shape[0] != m or cc.shape[0] != n:
        raise ValueError(
            f"b has {bb.shape[0]} rows and c {cc.shape[0]} for an operator "
            f"of shape {op.shape}; expected ({m}, batch) and ({n}, batch)")
    if bb.shape[1] != cc.shape[1]:
        raise ValueError(
            f"b batch {bb.shape[1]} != c batch {cc.shape[1]}")
    x0b = jnp.zeros_like(cc) if x0 is None else \
        (x0[:, None] if squeeze else x0).astype(jnp.float32)
    y0b = jnp.zeros_like(bb) if y0 is None else \
        (y0[:, None] if squeeze else y0).astype(jnp.float32)
    key = jax.random.PRNGKey(0) if key is None else key

    core = jax.jit(pdhg_pipeline(op, tau=tau, sigma=sigma, eta=eta, tol=tol,
                                 maxiter=maxiter, power_iters=power_iters,
                                 divergence=divergence))
    x, y, hist, k, mvms, pi_mvms, rel0 = core(bb, cc, x0b, y0b, key)
    res = pack_result(op, "pdhg", x, hist, k, mvms, tol, squeeze,
                      mvms_single=int(pi_mvms), rel0=rel0, mvms_t=int(mvms),
                      mvms_single_t=int(pi_mvms))
    res.dual = y[:, 0] if squeeze else y
    return res
