"""The solver registry: one metadata record per solver family member.

Chowdhury et al.'s VMM-benchmarking argument (PAPERS.md) is that analog
solvers diverge from their digital oracles in family-specific ways, so the
test surface has to be SYSTEMATIC: every solver declares, in one place, how
to build a random problem it should solve, how to run it, and how to
digitally recompute the residual it reports.  The property-based contract
suite (``tests/test_solver_contracts.py``) then asserts the same four
invariants for every entry -- residual honesty (the recorded
``final_residual`` matches the digital recompute), ``converged <=>
final_residual <= tol``, iteration-0 honesty on trivial problems, and
:class:`~repro.solvers.base.SolveLedger` additivity -- instead of each
solver hand-rolling its own copies.

Each :class:`SolverSpec` works on PROBLEM dicts:

  ``{"a": dense matrix, "b": rhs, ...family extras...}``

built by ``spec.make_problem(key, n, batch)`` (SPD for the linear/eigen
families, rectangular for least-squares, LP/QP tuples with KNOWN optima for
the primal-dual families) and ``spec.make_trivial(n, batch)`` (the
zero-RHS / exact-``x0`` instance for entry honesty, ``None`` when the
family has no such instance).  ``spec.solve(problem_or_A, problem, ...)``
takes the operator separately from the problem so the contract and parity
suites can substitute an :class:`~repro.engine.AnalogMatrix` (or any
placement x backend combination) for the dense ``a`` without touching the
rest of the problem data.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .admm import admm, random_box_qp
from .eigen import lanczos, lobpcg
from .krylov import bicgstab, cg, gmres
from .lstsq import lsmr, lsqr
from .pdhg import pdhg, random_feasible_lp
from .refinement import refine
from .stationary import jacobi, richardson

__all__ = ["SolverSpec", "registry"]

_TINY = 1e-30


def _norms(v):
    v = v if v.ndim == 2 else v[:, None]
    return jnp.sqrt(jnp.sum(jnp.square(v), axis=0))


# --------------------------------------------------------------------------- #
# Problem generators
# --------------------------------------------------------------------------- #

def _spd(key, n: int, cond: float = 50.0) -> jnp.ndarray:
    """Random SPD with eigenvalues log-spaced over ``cond`` (a rotated
    diagonal, so the conditioning is exact, not a sample statistic)."""
    kq, = jax.random.split(key, 1)
    q, _ = jnp.linalg.qr(jax.random.normal(kq, (n, n), jnp.float32))
    lam = jnp.logspace(0.0, jnp.log10(cond), n, dtype=jnp.float32)
    return (q * lam[None, :]) @ q.T


def _linear_problem(key, n: int, batch: int, cond: float = 50.0):
    ka, kb = jax.random.split(key)
    return {"a": _spd(ka, n, cond),
            "b": jax.random.normal(kb, (n, batch), jnp.float32)}


def _linear_trivial(n: int, batch: int):
    return {"a": jnp.eye(n, dtype=jnp.float32) * 2.0,
            "b": jnp.zeros((n, batch), jnp.float32)}


def _diag_dominant_problem(key, n: int, batch: int, cond: float = 50.0):
    """Jacobi needs strict diagonal dominance, not just SPD."""
    ka, kb = jax.random.split(key)
    off = jax.random.normal(ka, (n, n), jnp.float32) / float(n)
    a = 0.5 * (off + off.T) + jnp.eye(n, dtype=jnp.float32) * 2.0
    return {"a": a, "b": jax.random.normal(kb, (n, batch), jnp.float32)}


def _lstsq_problem(key, n: int, batch: int, cond: float = 50.0):
    """Rectangular m > n with singular values log-spaced over sqrt(cond)
    (the normal equations then see ``cond``), plus an inconsistent RHS."""
    ka, kb, kq = jax.random.split(key, 3)
    m = n + max(n // 2, 4)
    u, _ = jnp.linalg.qr(jax.random.normal(ka, (m, n), jnp.float32))
    v, _ = jnp.linalg.qr(jax.random.normal(kq, (n, n), jnp.float32))
    sig = jnp.logspace(0.0, 0.5 * jnp.log10(cond), n, dtype=jnp.float32)
    a = (u * sig[None, :]) @ v.T
    return {"a": a, "b": jax.random.normal(kb, (m, batch), jnp.float32)}


def _lstsq_trivial(n: int, batch: int):
    m = n + max(n // 2, 4)
    a = jnp.concatenate(
        [jnp.eye(n, dtype=jnp.float32), jnp.ones((m - n, n), jnp.float32)],
        axis=0)
    return {"a": a, "b": jnp.zeros((m, batch), jnp.float32)}


def _lp_problem(key, n: int, batch: int, cond: float = 50.0):
    m = max(n // 2, 2)
    a, b, c, x_star, y_star = random_feasible_lp(key, m, n, batch)
    return {"a": a, "b": b, "c": c, "x_star": x_star, "y_star": y_star}


def _lp_trivial(n: int, batch: int):
    m = max(n // 2, 2)
    return {"a": jnp.eye(m, n, dtype=jnp.float32),
            "b": jnp.zeros((m, batch), jnp.float32),
            "c": jnp.zeros((n, batch), jnp.float32)}


def _qp_problem(key, n: int, batch: int, cond: float = 50.0):
    m = n + max(n // 2, 4)
    a, b, q, lo, hi, x_star = random_box_qp(key, m, n, batch)
    return {"a": a, "b": b, "q": q, "lo": lo, "hi": hi, "x_star": x_star}


def _qp_trivial(n: int, batch: int):
    m = n + max(n // 2, 4)
    a = jnp.concatenate(
        [jnp.eye(n, dtype=jnp.float32), jnp.ones((m - n, n), jnp.float32)],
        axis=0)
    return {"a": a, "b": jnp.zeros((m, batch), jnp.float32),
            "q": jnp.zeros((n, batch), jnp.float32),
            "lo": -jnp.ones((n,), jnp.float32),
            "hi": jnp.ones((n,), jnp.float32)}


def _eigen_problem(key, n: int, batch: int, cond: float = 50.0):
    return {"a": _spd(key, n, cond)}


def _eigen_trivial(n: int, batch: int):
    # Every vector of the identity is an eigenvector: any starting block is
    # exact, so a block method must report entry convergence.
    return {"a": jnp.eye(n, dtype=jnp.float32)}


# --------------------------------------------------------------------------- #
# Digital residual recomputation (the contract's ground truth)
# --------------------------------------------------------------------------- #

def _recompute_linear(problem, result) -> float:
    a, b = problem["a"], problem["b"]
    x = result.x if result.x.ndim == 2 else result.x[:, None]
    bb = b if b.ndim == 2 else b[:, None]
    rel = _norms(bb - a @ x) / jnp.maximum(_norms(bb), _TINY)
    return float(jnp.max(rel))


def _recompute_lstsq(problem, result) -> float:
    a, b = problem["a"], problem["b"]
    x = result.x if result.x.ndim == 2 else result.x[:, None]
    bb = b if b.ndim == 2 else b[:, None]
    num = _norms(a.T @ (bb - a @ x))
    den = jnp.maximum(_norms(a.T @ bb), _TINY)
    return float(jnp.max(num / den))


def _recompute_lp(problem, result) -> float:
    """PDHG's KKT residual, digitally: max of primal/dual infeasibility and
    the relative duality gap at (result.x, result.dual)."""
    a = problem["a"]
    b = problem["b"] if problem["b"].ndim == 2 else problem["b"][:, None]
    c = problem["c"] if problem["c"].ndim == 2 else problem["c"][:, None]
    x = result.x if result.x.ndim == 2 else result.x[:, None]
    y = result.dual if result.dual.ndim == 2 else result.dual[:, None]
    primal = _norms(a @ x - b) / (1.0 + _norms(b))
    dual = _norms(jnp.maximum(-(c + a.T @ y), 0.0)) / (1.0 + _norms(c))
    pobj = jnp.sum(c * x, axis=0)
    dobj = -jnp.sum(b * y, axis=0)
    gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
    return float(jnp.max(jnp.maximum(jnp.maximum(primal, dual), gap)))


def _recompute_qp(problem, result) -> float:
    """ADMM's KKT measure, digitally: projected-gradient stationarity plus
    the consensus gap to the feasible split copy in ``result.dual``."""
    a = problem["a"]
    b = problem["b"] if problem["b"].ndim == 2 else problem["b"][:, None]
    q = problem["q"] if problem["q"].ndim == 2 else problem["q"][:, None]
    lo, hi = problem["lo"][:, None], problem["hi"][:, None]
    x = result.x if result.x.ndim == 2 else result.x[:, None]
    z = result.dual if result.dual.ndim == 2 else result.dual[:, None]
    grad = a.T @ (a @ x - b) + q
    stat = _norms(x - jnp.clip(x - grad, lo, hi))
    feas = _norms(x - z)
    return float(jnp.max((stat + feas) / (1.0 + _norms(x))))


def _recompute_eigen(problem, result) -> float:
    """Relative Ritz residual of every returned (eigenvalue, column) pair."""
    a = problem["a"]
    x = result.x if result.x.ndim == 2 else result.x[:, None]
    theta = result.eigenvalues
    resid = _norms(a @ x - x * theta[None, :])
    return float(jnp.max(resid / jnp.maximum(jnp.abs(theta), _TINY)))


# --------------------------------------------------------------------------- #
# Spec + registry
# --------------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """Everything the contract/parity suites need to exercise one solver.

    ``solve(A, problem, *, tol, maxiter, key)`` runs the solver with ``A``
    standing in for ``problem["a"]`` (a dense array in the digital contract
    tests, an :class:`~repro.engine.AnalogMatrix` in the parity matrix).
    ``recompute(problem, result)`` returns the family's residual evaluated
    digitally at the returned iterates -- the quantity the recorded
    ``final_residual`` must honestly track.  ``slack``/``floor`` bound the
    allowed recurrence drift: ``recompute <= max(slack * recorded, floor)``
    (``floor`` absorbs the float32 noise floor once a recurrence has
    converged below what a digital recompute can resolve).
    """

    name: str
    family: str                 # linear | lstsq | lp | qp | eigen
    solve: Callable
    make_problem: Callable
    recompute: Callable
    make_trivial: Optional[Callable] = None
    needs_rmatvec: bool = False
    multi_rhs: bool = True
    slack: float = 3.0
    floor: float = 5e-4
    # Residuals recorded one step behind the returned iterate (the
    # stationary methods) get a looser two-sided comparison.
    lagged_history: bool = False


def _s_richardson(A, p, *, tol, maxiter, key):
    return richardson(A, p["b"], tol=tol, maxiter=maxiter,
                                  key=key)


def _s_jacobi(A, p, *, tol, maxiter, key):
    return jacobi(A, p["b"], tol=tol, maxiter=maxiter, key=key,
                              diag=jnp.diagonal(p["a"]))


def _s_cg(A, p, *, tol, maxiter, key):
    return cg(A, p["b"], tol=tol, maxiter=maxiter, key=key)


def _s_bicgstab(A, p, *, tol, maxiter, key):
    return bicgstab(A, p["b"], tol=tol, maxiter=maxiter, key=key)


def _s_gmres(A, p, *, tol, maxiter, key):
    return gmres(A, p["b"], tol=tol, maxiter=maxiter, key=key)


def _s_refine(A, p, *, tol, maxiter, key):
    return refine(A, p["b"], tol=tol, maxiter=maxiter, key=key,
                              a_digital=p["a"])


def _s_pdhg(A, p, *, tol, maxiter, key):
    return pdhg(A, p["b"], p["c"], tol=tol, maxiter=maxiter, key=key)


def _s_lsqr(A, p, *, tol, maxiter, key):
    return lsqr(A, p["b"], tol=tol, maxiter=maxiter, key=key)


def _s_lsmr(A, p, *, tol, maxiter, key):
    return lsmr(A, p["b"], tol=tol, maxiter=maxiter, key=key)


def _s_lanczos(A, p, *, tol, maxiter, key):
    return lanczos(A, tol=tol, maxiter=max(maxiter, 2), key=key)


def _s_lobpcg(A, p, *, tol, maxiter, key):
    return lobpcg(A, 2, which="smallest", tol=tol, maxiter=maxiter,
                         key=key)


def _s_admm(A, p, *, tol, maxiter, key):
    return admm(A, p["b"], p["q"], lo=p["lo"], hi=p["hi"], tol=tol,
                      maxiter=maxiter, key=key)


_REGISTRY = (
    SolverSpec("richardson", "linear", _s_richardson, _linear_problem,
               _recompute_linear, lagged_history=True),
    SolverSpec("jacobi", "linear", _s_jacobi, _diag_dominant_problem,
               _recompute_linear, lagged_history=True),
    SolverSpec("cg", "linear", _s_cg, _linear_problem, _recompute_linear,
               make_trivial=_linear_trivial),
    SolverSpec("bicgstab", "linear", _s_bicgstab, _linear_problem,
               _recompute_linear, make_trivial=_linear_trivial),
    SolverSpec("gmres", "linear", _s_gmres, _linear_problem,
               _recompute_linear, make_trivial=_linear_trivial),
    SolverSpec("refine", "linear", _s_refine, _linear_problem,
               _recompute_linear, make_trivial=_linear_trivial),
    SolverSpec("pdhg", "lp", _s_pdhg, _lp_problem, _recompute_lp,
               make_trivial=_lp_trivial, needs_rmatvec=True),
    SolverSpec("lsqr", "lstsq", _s_lsqr, _lstsq_problem, _recompute_lstsq,
               make_trivial=_lstsq_trivial, needs_rmatvec=True),
    SolverSpec("lsmr", "lstsq", _s_lsmr, _lstsq_problem, _recompute_lstsq,
               make_trivial=_lstsq_trivial, needs_rmatvec=True),
    # The |beta_k s_k| residual estimate collapses once the Krylov space
    # exhausts (k ~ n) while float32 orthogonality loss keeps the true Ritz
    # residual near 1e-3: the honesty floor is the float32 Lanczos floor,
    # not the generic recompute floor.
    SolverSpec("lanczos", "eigen", _s_lanczos, _eigen_problem,
               _recompute_eigen, multi_rhs=False, floor=5e-3),
    SolverSpec("lobpcg", "eigen", _s_lobpcg, _eigen_problem,
               _recompute_eigen, make_trivial=_eigen_trivial,
               multi_rhs=False),
    SolverSpec("admm", "qp", _s_admm, _qp_problem, _recompute_qp,
               make_trivial=_qp_trivial, needs_rmatvec=True),
)


def registry() -> tuple:
    """All registered solvers, in documentation order.  The contract suite
    parameterizes over this tuple, so a solver added here is automatically
    held to the residual/convergence/ledger invariants."""
    return _REGISTRY
