"""Extremal eigenpair solvers: Lanczos and LOBPCG on the analog operator.

Eigen-solves are the purest expression of the paper's amortization thesis:
the iteration touches ``A`` ONLY through matvecs against the one programmed
image, and what comes back (extremal eigenvalues / singular values) feeds
straight back into the step-size machinery of the other solvers --
:func:`repro.solvers.richardson`'s relaxation ``2/(lmin+lmax)``,
:func:`repro.solvers.pdhg`'s ``tau = sigma = eta/||A||_2``.  Two methods:

  * :func:`lanczos` -- both extremal eigenpairs of a SYMMETRIC operator from
    one Krylov sweep.  The basis is seeded from the same power-iteration
    estimator :mod:`repro.solvers.stationary` uses (the power iterate is
    already rich in the dominant eigenvector, so Lanczos converges in fewer
    analog MVMs than a cold random start), fully reorthogonalized (float32 +
    analog noise make the textbook three-term recurrence lose orthogonality
    fast), with Ritz pairs extracted per iteration from a masked fixed-shape
    tridiagonal -- the same masked-basis device-friendly pattern as
    ``_gmres_cycle``.
  * :func:`lobpcg` -- a block of ``k`` extremal eigenpairs; each iteration is
    ONE batched 3k-column matvec (the [X | R | P] search subspace in a single
    analog dispatch), which is exactly the regime where the engine's
    batched-input amortization pays.

Both record the per-iteration relative Ritz residual
``||A y - theta y|| / |theta|`` as the :class:`SolveResult` history (the
solver-contract suite recomputes it digitally from the returned pairs), bill
every analog MVM to the :class:`~repro.solvers.base.SolveLedger`, and run as
single jitted programs with NaN-robust ``lax.while_loop`` early stopping.

:func:`operator_norm` estimates ``||A||_2`` for RECTANGULAR operators by
running :func:`lanczos` on the symmetric augmentation ``[[0, A], [A', 0]]``
(extremal eigenvalue = extremal singular value; one matvec + one rmatvec per
Lanczos step) -- the drop-in upgrade for PDHG's power-iteration step sizing.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .base import (LinearOperator, SolveResult, as_operator, col_norms,
                   init_history, pack_result)
from .stationary import _power_iterate

__all__ = ["lanczos", "lobpcg", "operator_norm", "lanczos_pipeline",
           "lobpcg_pipeline"]

_TINY = 1e-30


def _unconverged(rel, tol):
    """NaN-robust: a NaN Ritz residual (breakdown) counts as not converged."""
    return jnp.logical_not(jnp.all(rel <= tol))


# --------------------------------------------------------------------------- #
# Lanczos
# --------------------------------------------------------------------------- #

def _lanczos_core(op: LinearOperator, key, *, tol: float, maxiter: int,
                  seed_iters: int):
    n = op.n
    m = maxiter
    # Seed from the power-iteration estimator (stationary.py): the iterate is
    # dominated by the top eigenvector, which Lanczos then refines while
    # simultaneously pulling out the bottom of the spectrum.
    v1, _ = _power_iterate(op.matvec, n, jax.random.fold_in(key, 900_007),
                           seed_iters)
    idx = jnp.arange(m)

    def cond(state):
        k = state[0]
        rel = state[10]
        return jnp.logical_and(k < maxiter, _unconverged(rel, tol))

    def body(state):
        (k, V, vk, v_prev, beta_prev, alphas, betas, Y, theta2, hist, _rel,
         mvms) = state
        w = op.matvec(vk, jax.random.fold_in(key, k))
        alpha = jnp.sum(vk * w)
        w = w - alpha * vk - beta_prev * v_prev
        # Full reorthogonalization against the stored basis; unfilled columns
        # of V are zero, so the masked projection is just V (V' w).
        w = w - V @ (V.T @ w)
        beta = col_norms(w)[0]
        alphas = alphas.at[k].set(alpha)
        betas = betas.at[k].set(beta)
        V = V.at[:, k].set(vk[:, 0])
        # Fixed-shape masked tridiagonal: the active (k+1)-block of T, padded
        # on the diagonal with the mean of the seen alphas.  The pad block is
        # decoupled (its off-diagonals are masked to zero) and the mean of a
        # symmetric matrix's diagonal lies inside its spectrum, so the padded
        # eigenvalues sit strictly between the true extremal Ritz values.
        pad = jnp.sum(alphas) / (k + 1)
        diag = jnp.where(idx <= k, alphas, pad)
        off = jnp.where(idx[:-1] < k, betas[:-1], 0.0)
        t_mat = jnp.diag(diag) + jnp.diag(off, 1) + jnp.diag(off, -1)
        theta, s_mat = jnp.linalg.eigh(t_mat)
        s_pair = jnp.stack([s_mat[:, 0], s_mat[:, -1]], axis=1)  # (m, 2)
        theta2 = jnp.stack([theta[0], theta[-1]])
        # Ritz residual ||A y - theta y|| = |beta_k * s[k]| (last active row).
        resid = jnp.abs(beta * s_pair[k, :])
        rel = resid / jnp.maximum(jnp.abs(theta2), _TINY)
        # One Lanczos step cannot separate the spectrum ends; the k=0 Ritz
        # data is degenerate by construction, so never report it converged.
        rel = jnp.where(k < 1, jnp.full_like(rel, jnp.inf), rel)
        hist = hist.at[k].set(rel)
        Y = V @ s_pair
        v_next = w / jnp.maximum(beta, _TINY)
        return (k + 1, V, v_next, vk, beta, alphas, betas, Y, theta2, hist,
                rel, mvms + 1)

    zcol = jnp.zeros((n, 1), jnp.float32)
    state0 = (jnp.int32(0), jnp.zeros((n, m), jnp.float32), v1, zcol,
              jnp.float32(0.0), jnp.zeros((m,), jnp.float32),
              jnp.zeros((m,), jnp.float32), jnp.zeros((n, 2), jnp.float32),
              jnp.zeros((2,), jnp.float32), init_history(m, 2),
              jnp.full((2,), jnp.inf, jnp.float32), jnp.int32(seed_iters))
    out = jax.lax.while_loop(cond, body, state0)
    k, y_pair, theta2, hist, mvms = out[0], out[7], out[8], out[9], out[11]
    return y_pair, theta2, hist, k, mvms


def lanczos_pipeline(
    op: LinearOperator,
    *,
    tol: float = 1e-4,
    maxiter: int = 48,
    seed_iters: int = 8,
):
    """The jit-able Lanczos core ``(key) -> (Y, theta, hist, k, mvms)``.

    ``Y`` is the (n, 2) [bottom | top] Ritz-vector panel, ``theta`` the
    matching (2,) eigenvalue estimates.  Exposed for the invariant gate: the
    whole sweep -- power-iteration seeding, reorthogonalized recurrence,
    per-step tridiagonal Ritz extraction -- is one traced program.
    """
    return functools.partial(_lanczos_core, op, tol=tol, maxiter=maxiter,
                             seed_iters=seed_iters)


def lanczos(
    A,
    *,
    tol: float = 1e-4,
    maxiter: int = 48,
    seed_iters: int = 8,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """Both extremal eigenpairs of a symmetric operator, matvec-only.

    Returns a :class:`SolveResult` whose ``x`` is the (n, 2) panel of
    [lambda_min | lambda_max] eigenvectors, with the estimates themselves in
    ``result.eigenvalues`` (shape (2,), ascending).  The residual history is
    the relative Ritz residual ``||A y - theta y|| / |theta|`` per pair; all
    MVMs (the ``seed_iters`` power-iteration seed steps plus one per Lanczos
    step, every one batch-1) are billed at the batch-1 input rate.

    Feed the output back into step sizing:
    ``2.0 / (1.05 * lmax + lmin)`` is :func:`repro.solvers.richardson`'s
    relaxation (see ``estimate_omega(method="lanczos")``).
    """
    op = as_operator(A)
    m_, n_ = op.shape
    if m_ != n_:
        raise ValueError(
            f"lanczos needs a symmetric (square) operator, got {op.shape}; "
            "for rectangular A use operator_norm (singular values)")
    if maxiter < 2:
        raise ValueError("lanczos needs maxiter >= 2")
    key = jax.random.PRNGKey(0) if key is None else key
    core = jax.jit(lanczos_pipeline(op, tol=tol, maxiter=maxiter,
                                    seed_iters=seed_iters))
    y_pair, theta2, hist, k, mvms = core(key)
    res = pack_result(op, "lanczos", y_pair, hist, k, jnp.int32(0), tol,
                      squeeze=False, mvms_single=int(mvms))
    res.eigenvalues = theta2
    return res


# --------------------------------------------------------------------------- #
# LOBPCG
# --------------------------------------------------------------------------- #

def _rayleigh_ritz(s_basis, a_s, nev: int, largest: bool):
    """Ritz pairs of the projected operator on an orthonormal basis.

    Returns the ``nev`` extremal ``(theta, X, AX)`` with theta ascending;
    ``AX`` comes free from the already-computed ``A @ basis``.
    """
    m_proj = s_basis.T @ a_s
    m_proj = 0.5 * (m_proj + m_proj.T)
    theta, c_mat = jnp.linalg.eigh(m_proj)
    sel = slice(-nev, None) if largest else slice(None, nev)
    c_sel = c_mat[:, sel]
    return theta[sel], s_basis @ c_sel, a_s @ c_sel


def _lobpcg_core(op: LinearOperator, x0, key, *, tol: float, maxiter: int,
                 largest: bool):
    nev = x0.shape[1]
    x_blk, _ = jnp.linalg.qr(x0)
    ax_blk = op.matvec(x_blk, jax.random.fold_in(key, 0))
    theta, x_blk, ax_blk = _rayleigh_ritz(x_blk, ax_blk, nev, largest)
    rel0 = col_norms(ax_blk - x_blk * theta[None, :]) \
        / jnp.maximum(jnp.abs(theta), _TINY)

    def cond(state):
        k = state[0]
        rel = state[6]
        return jnp.logical_and(k < maxiter, _unconverged(rel, tol))

    def body(state):
        k, x_blk, ax_blk, p_blk, theta, hist, _rel, mvms = state
        r_blk = ax_blk - x_blk * theta[None, :]
        s_basis, _ = jnp.linalg.qr(
            jnp.concatenate([x_blk, r_blk, p_blk], axis=1))
        # The whole [X | R | P] subspace in ONE batched analog dispatch.
        a_s = op.matvec(s_basis, jax.random.fold_in(key, 1 + k))
        theta, x_new, ax_new = _rayleigh_ritz(s_basis, a_s, nev, largest)
        # Conjugate-direction memory: the part of the step outside old X.
        p_blk = x_new - x_blk @ (x_blk.T @ x_new)
        rel = col_norms(ax_new - x_new * theta[None, :]) \
            / jnp.maximum(jnp.abs(theta), _TINY)
        hist = hist.at[k].set(rel)
        # The 3k-column panel bills as three k-column MVMs (input cost is
        # linear in batch width).
        return k + 1, x_new, ax_new, p_blk, theta, hist, rel, mvms + 3

    state0 = (jnp.int32(0), x_blk, ax_blk, jnp.zeros_like(x_blk), theta,
              init_history(maxiter, nev), rel0, jnp.int32(1))
    out = jax.lax.while_loop(cond, body, state0)
    k, x_blk, theta, hist, mvms = out[0], out[1], out[4], out[5], out[7]
    return x_blk, theta, hist, k, mvms, rel0


def lobpcg_pipeline(
    op: LinearOperator,
    *,
    tol: float = 1e-4,
    maxiter: int = 100,
    largest: bool = True,
):
    """The jit-able LOBPCG core ``(x0, key) -> (X, theta, hist, k, mvms,
    rel0)``; ``x0`` is the (n, k) starting block."""
    return functools.partial(_lobpcg_core, op, tol=tol, maxiter=maxiter,
                             largest=largest)


def lobpcg(
    A,
    k: int = 1,
    *,
    which: str = "largest",
    tol: float = 1e-4,
    maxiter: int = 100,
    x0: Optional[jnp.ndarray] = None,
    key: Optional[jax.Array] = None,
) -> SolveResult:
    """``k`` extremal eigenpairs of a symmetric operator by LOBPCG.

    ``which`` is ``"largest"`` or ``"smallest"``.  Each iteration costs one
    batched 3k-column matvec against the programmed image (billed as three
    k-column MVMs).  Returns ``x`` as the (n, k) eigenvector block (or (n,)
    for ``k=1``) and the estimates in ``result.eigenvalues`` (ascending).
    """
    op = as_operator(A)
    m_, n_ = op.shape
    if m_ != n_:
        raise ValueError(
            f"lobpcg needs a symmetric (square) operator, got {op.shape}")
    if which not in ("largest", "smallest"):
        raise ValueError(f"which must be 'largest' or 'smallest', got "
                         f"{which!r}")
    if not 1 <= k <= n_ // 3:
        raise ValueError(
            f"lobpcg needs 1 <= k <= n//3 (the [X|R|P] subspace must fit), "
            f"got k={k} for n={n_}")
    key = jax.random.PRNGKey(0) if key is None else key
    squeeze = x0 is not None and x0.ndim == 1
    if x0 is None:
        x0b = jax.random.normal(jax.random.fold_in(key, 900_009), (n_, k),
                                jnp.float32)
    else:
        x0b = (x0[:, None] if squeeze else x0).astype(jnp.float32)
        if x0b.shape != (n_, k):
            raise ValueError(f"x0 has shape {x0b.shape}, expected ({n_}, {k})")
    squeeze = squeeze or (x0 is None and k == 1)
    core = jax.jit(lobpcg_pipeline(op, tol=tol, maxiter=maxiter,
                                   largest=(which == "largest")))
    x_blk, theta, hist, it, mvms, rel0 = core(x0b, key)
    res = pack_result(op, "lobpcg", x_blk, hist, it, mvms, tol,
                      squeeze=squeeze, rel0=rel0)
    res.eigenvalues = theta
    return res


# --------------------------------------------------------------------------- #
# Rectangular feedback: ||A||_2 for PDHG step sizing
# --------------------------------------------------------------------------- #

def _augmented(op: LinearOperator) -> LinearOperator:
    """The symmetric augmentation ``H = [[0, A], [A', 0]]`` of a rectangular
    operator: ``eig(H) = +/- singular values of A``.  One H-matvec is one
    forward plus one transposed analog MVM against the same image."""
    m, n = op.shape

    def aug_mv(v, key):
        top = op.matvec(v[m:], jax.random.fold_in(key, 0))
        bot = op.rmatvec(v[:m], jax.random.fold_in(key, 1))
        return jnp.concatenate([top, bot], axis=0)

    return LinearOperator(
        matvec=aug_mv, rmatvec=aug_mv, shape=(m + n, m + n),
        write_stats=op.write_stats, input_stats=op.input_stats,
        input_stats_t=op.input_stats_t, dense=None, analog=op.analog)


def operator_norm(
    A,
    *,
    tol: float = 1e-3,
    maxiter: int = 32,
    key: Optional[jax.Array] = None,
) -> float:
    """``||A||_2`` (the largest singular value) of a rectangular operator.

    Runs :func:`lanczos` on the symmetric augmentation ``[[0, A], [A', 0]]``
    -- each step is one forward + one transposed MVM, like one PDHG
    iteration -- and converges quadratically faster than the plain power
    method :func:`repro.solvers.pdhg` defaults to.  Typical use::

        step = 0.9 / operator_norm(A_analog, key=key)
        res = pdhg(A_analog, b, c, tau=step, sigma=step)
    """
    op = as_operator(A)
    if op.rmatvec is None:
        raise ValueError("operator_norm needs an operator with rmatvec")
    res = lanczos(_augmented(op), tol=tol, maxiter=maxiter, key=key)
    return float(res.eigenvalues[1])
