"""Analog iterative linear solvers on top of the program-once AnalogEngine.

MELISO+ is an In-Memory Linear SOlver: program a matrix image once, then
amortize the write cost over the many corrected MVMs of an iterative solve.
This package turns any :class:`~repro.engine.AnalogMatrix` (or dense array,
or bare matvec) into ``A x = b`` solutions:

  * :mod:`~repro.solvers.stationary` -- Richardson (auto-``omega`` from a
    matvec-only power-iteration spectral estimate) and Jacobi;
  * :mod:`~repro.solvers.krylov` -- CG (SPD), BiCGSTAB and restarted GMRES(m);
  * :mod:`~repro.solvers.refinement` -- mixed-precision iterative refinement
    (analog inner solve, digital fp32 exact-residual outer loop);
  * :mod:`~repro.solvers.pdhg` -- primal-dual hybrid gradient for LINEAR
    PROGRAMS (``min c'x  s.t.  A x = b, x >= 0``): each iteration is one
    corrected ``A @ x`` plus one corrected transposed ``A.T @ y`` against the
    same programmed image -- the workload of the companion RRAM-PDHG paper;
  * :mod:`~repro.solvers.lstsq` -- LSQR and LSMR least-squares for
    RECTANGULAR operators (``min ||A x - b||`` on non-square crossbars),
    one matvec + one rmatvec per Golub-Kahan step;
  * :mod:`~repro.solvers.eigen` -- extremal eigenpairs (Lanczos seeded from
    the power-iteration estimator; block LOBPCG) and the Lanczos
    ``operator_norm`` that feeds PDHG/Richardson step sizing;
  * :mod:`~repro.solvers.admm` -- linearized ADMM for BOX-CONSTRAINED
    QUADRATIC PROGRAMS (``min (1/2)||Ax-b||^2 + q'x  s.t. lo <= x <= hi``),
    also one matvec + one rmatvec per iteration;
  * :mod:`~repro.solvers.registry` -- one metadata record per solver (oracle
    family, residual recompute, problem generator) driving the
    property-based contract suite;
  * :mod:`~repro.solvers.base` -- :class:`SolveResult` with per-iteration
    residual history and a :class:`SolveLedger` splitting energy/latency into
    the one-time programming cost and the per-iteration input-write cost
    (forward and transposed executions billed separately).

See ``docs/solvers.md`` for the full API reference, the operator protocol
(including ``rmatvec``), and guidance on which solver to pick.

Every method is matvec-only, supports multi-RHS batching ``(n, batch)``, jits
end-to-end (``lax.while_loop`` early stopping), and runs unchanged across the
engine's ``local`` / ``streamed`` / ``distributed`` execution modes and
``reference`` / ``pallas`` backends (``backend="pallas"`` additionally fuses
the solver update steps into Pallas kernels).

Quickstart::

    from repro import solvers
    A = engine.program(a, key)              # one-time write cost
    res = solvers.cg(A, b, tol=1e-4)        # matvec-only analog solve
    res.x, res.residuals, res.iterations
    res.ledger.write_energy_j               # paid once
    res.ledger.iteration_energy_j           # mvms x input-write cost
"""
from .admm import admm, admm_pipeline, random_box_qp
from .base import LinearOperator, SolveLedger, SolveResult, as_operator
from .eigen import (lanczos, lanczos_pipeline, lobpcg, lobpcg_pipeline,
                    operator_norm)
from .krylov import bicgstab, cg, cg_pipeline, gmres
from .lstsq import lsmr, lsmr_pipeline, lsqr, lsqr_pipeline
from .pdhg import pdhg, pdhg_pipeline, random_feasible_lp
from .refinement import refine
from .registry import SolverSpec, registry
from .stationary import estimate_omega, jacobi, richardson, spectral_bounds

__all__ = [
    "LinearOperator", "SolveLedger", "SolveResult", "as_operator",
    "admm", "admm_pipeline", "random_box_qp",
    "bicgstab", "cg", "cg_pipeline", "gmres", "pdhg", "pdhg_pipeline",
    "random_feasible_lp", "refine",
    "lanczos", "lanczos_pipeline", "lobpcg", "lobpcg_pipeline",
    "operator_norm",
    "lsmr", "lsmr_pipeline", "lsqr", "lsqr_pipeline",
    "SolverSpec", "registry",
    "estimate_omega", "jacobi", "richardson", "spectral_bounds",
]
