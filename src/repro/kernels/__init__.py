"""Pallas TPU kernels for the RRAM crossbar hot spots (validated in
interpret mode on CPU; see ops.py for the public wrappers)."""
from .ops import (
    denoise_stencil,
    denoise_thomas,
    on_cpu,
    rram_ec_matmul,
    rram_ec_tile_mvm,
    rram_ec_tile_rmvm,
    rram_encode_matmul,
    solver_cg_update,
    solver_richardson_update,
)

__all__ = [
    "denoise_stencil",
    "denoise_thomas",
    "on_cpu",
    "rram_ec_matmul",
    "rram_ec_tile_mvm",
    "rram_ec_tile_rmvm",
    "rram_encode_matmul",
    "solver_cg_update",
    "solver_richardson_update",
]
