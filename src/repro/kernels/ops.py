"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode -- the
kernel body runs as traced JAX ops per grid point, validating the exact TPU
dataflow.  On TPU backends the same calls lower through Mosaic.  The wrappers
handle padding to block multiples and un-padding, so callers pass natural
shapes.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .rram_mvm import DEFAULT_BLOCK_K, DEFAULT_BLOCK_M, DEFAULT_BLOCK_N
from .rram_mvm import ec_matmul as _ec_matmul
from .rram_mvm import encode_matmul as _encode_matmul
from .solver_update import cg_update as _cg_update
from .solver_update import richardson_update as _richardson_update
from .tridiag import stencil_denoise as _stencil
from .tridiag import thomas_solve as _thomas

__all__ = [
    "on_cpu",
    "rram_encode_matmul",
    "rram_ec_matmul",
    "rram_ec_tile_mvm",
    "rram_ec_tile_rmvm",
    "rram_ec_group_mvm",
    "rram_ec_group_rmvm",
    "denoise_thomas",
    "denoise_stencil",
    "solver_richardson_update",
    "solver_cg_update",
]


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = []
    for dim, mult in zip(x.shape, mults):
        pads.append((0, (-dim) % mult))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _pick_blocks(m, k, n, bm, bk, bn):
    """Shrink default blocks for small problems (keeps interpret tests fast and
    avoids padding a 66x66 paper matrix to 512^2)."""
    return min(bm, max(8, m)), min(bk, max(8, k)), min(bn, max(8, n))


def rram_encode_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    eps: jnp.ndarray,
    *,
    sigma: float,
    levels: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y = x @ encode(w); per-(block_k, block_n) tile = one MCA array."""
    m, k = x.shape
    _, n = w.shape
    bm, bk, bn = _pick_blocks(m, k, n, block_m, block_k, block_n)
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    ep = _pad_to(eps, (bk, bn))
    out = _encode_matmul(
        xp, wp, ep, sigma=sigma, levels=levels,
        block_m=bm, block_k=bk, block_n=bn,
        interpret=on_cpu() if interpret is None else interpret)
    return out[:m, :n]


def rram_ec_matmul(
    x: jnp.ndarray,
    x_tilde: jnp.ndarray,
    w_tilde: jnp.ndarray,
    dw: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused tier-1 EC matmul p = x @ W_tilde + x_tilde @ dW."""
    m, k = x.shape
    _, n = w_tilde.shape
    bm, bk, bn = _pick_blocks(m, k, n, block_m, block_k, block_n)
    xp = _pad_to(x, (bm, bk))
    xtp = _pad_to(x_tilde, (bm, bk))
    wtp = _pad_to(w_tilde, (bk, bn))
    dwp = _pad_to(dw, (bk, bn))
    out = _ec_matmul(
        xp, xtp, wtp, dwp, block_m=bm, block_k=bk, block_n=bn,
        interpret=on_cpu() if interpret is None else interpret)
    return out[:m, :n]


def rram_ec_tile_mvm(
    x_blk: jnp.ndarray,
    x_t: jnp.ndarray,
    at_blk: jnp.ndarray,
    da_blk: jnp.ndarray,
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Tier-1 EC step for ONE capacity tile in the engine's (n, batch) layout.

    Computes ``at_blk @ x_blk + da_blk @ x_t`` as a single fused
    :func:`rram_ec_matmul` call (the transposed y^T = x^T At^T + xt^T dA^T
    form), so the streamed scan body and the host-loop fallback share one
    kernel-backed tile step.  ``x_blk``/``x_t``: (cap_n, batch);
    ``at_blk``/``da_blk``: (cap_m, cap_n).  Returns fp32 (cap_m, batch).
    """
    return rram_ec_matmul(x_blk.T, x_t.T, at_blk.T, da_blk.T,
                          interpret=interpret).T


def rram_ec_tile_rmvm(
    y_blk: jnp.ndarray,
    y_t: jnp.ndarray,
    at_blk: jnp.ndarray,
    da_blk: jnp.ndarray,
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """TRANSPOSED tier-1 EC step for ONE capacity tile ((m, batch) layout).

    Computes ``at_blk.T @ y_blk + da_blk.T @ y_t`` as a single fused
    :func:`rram_ec_matmul` call -- the ``z^T = y^T At + y_t^T dA`` form, i.e.
    the same kernel read in the transposed direction, so the transposed
    streamed scan body and the host-loop fallback share one kernel-backed
    tile step with the forward path's operands untouched.
    ``y_blk``/``y_t``: (cap_m, batch); ``at_blk``/``da_blk``:
    (cap_m, cap_n).  Returns fp32 (cap_n, batch).
    """
    return rram_ec_matmul(y_blk.T, y_t.T, at_blk, da_blk,
                          interpret=interpret).T


def rram_ec_group_mvm(
    x_g: jnp.ndarray,
    x_t_g: jnp.ndarray,
    at_g: jnp.ndarray,
    da_g: jnp.ndarray,
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Tier-1 EC step for a STACK of images under an extra leading image axis.

    All operands carry a leading group axis ``g``: ``x_g``/``x_t_g`` are
    (g, n, batch) input panels, ``at_g``/``da_g`` (g, m, n) dense operands.
    Runs the fused :func:`rram_ec_matmul` kernel once per member inside a
    single ``lax.map`` (a scan -- ONE traced program, the kernel grid never
    sees the image axis), returning (g, m, batch).  Member ``g`` is
    bit-identical to a solo :func:`rram_ec_tile_mvm` on its slice.
    """
    def one(ops):
        x, x_t, at, da = ops
        return rram_ec_tile_mvm(x, x_t, at, da, interpret=interpret)

    return jax.lax.map(one, (x_g, x_t_g, at_g, da_g))


def rram_ec_group_rmvm(
    y_g: jnp.ndarray,
    y_t_g: jnp.ndarray,
    at_g: jnp.ndarray,
    da_g: jnp.ndarray,
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """TRANSPOSED grouped tier-1 EC step: the :func:`rram_ec_tile_rmvm`
    mirror of :func:`rram_ec_group_mvm`.  ``y_g``/``y_t_g`` are (g, m, batch),
    ``at_g``/``da_g`` (g, m, n); returns (g, n, batch) -- the same kernel read
    backwards per member under one ``lax.map``."""
    def one(ops):
        y, y_t, at, da = ops
        return rram_ec_tile_rmvm(y, y_t, at, da, interpret=interpret)

    return jax.lax.map(one, (y_g, y_t_g, at_g, da_g))


def solver_richardson_update(
    x: jnp.ndarray, b: jnp.ndarray, y: jnp.ndarray, omega,
    *, block_n: int = 256, interpret: bool | None = None,
):
    """Fused solver step (x + omega*(b - y), b - y) for (n, batch) panels."""
    n, bt = x.shape
    bn = min(block_n, max(1, n))
    pad = (-n) % bn
    xp, bp, yp = (_pad_to(a, (bn, 1)) for a in (x, b, y))
    xn, r = _richardson_update(
        xp, bp, yp, jnp.asarray(omega), block_n=bn,
        interpret=on_cpu() if interpret is None else interpret)
    return (xn[:n], r[:n]) if pad else (xn, r)


def solver_cg_update(
    x: jnp.ndarray, r: jnp.ndarray, p: jnp.ndarray, ap: jnp.ndarray, alpha,
    *, block_n: int = 256, interpret: bool | None = None,
):
    """Fused CG twin-axpy (x + alpha*p, r - alpha*ap), alpha per RHS column."""
    n, bt = x.shape
    bn = min(block_n, max(1, n))
    pad = (-n) % bn
    xp, rp, pp, app = (_pad_to(a, (bn, 1)) for a in (x, r, p, ap))
    xn, rn = _cg_update(
        xp, rp, pp, app, jnp.asarray(alpha), block_n=bn,
        interpret=on_cpu() if interpret is None else interpret)
    return (xn[:n], rn[:n]) if pad else (xn, rn)


def denoise_thomas(
    p: jnp.ndarray, *, lam: float, h: float = -1.0,
    block_b: int = 128, interpret: bool | None = None,
) -> jnp.ndarray:
    """Exact tier-2 solve for (n, batch) panels."""
    n, b = p.shape
    bb = min(block_b, max(1, b))
    pp = _pad_to(p, (1, bb))
    out = _thomas(pp, lam=lam, h=h, block_b=bb,
                  interpret=on_cpu() if interpret is None else interpret)
    return out[:, :b]


def denoise_stencil(
    p: jnp.ndarray, *, lam: float, h: float = -1.0,
    block_b: int = 128, interpret: bool | None = None,
) -> jnp.ndarray:
    """Truncated-Neumann tier-2 denoise for (n, batch) panels."""
    n, b = p.shape
    bb = min(block_b, max(1, b))
    pp = _pad_to(p, (1, bb))
    out = _stencil(pp, lam=lam, h=h, block_b=bb,
                   interpret=on_cpu() if interpret is None else interpret)
    return out[:, :b]
