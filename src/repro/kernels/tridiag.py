"""Pallas TPU kernels for the tier-2 denoise solve (I + lam L^T L) y = p.

Two kernels:

  * ``thomas_solve``: exact Thomas algorithm.  The system matrix is constant
    (Toeplitz tridiagonal + one boundary correction), so the forward-
    elimination coefficients c'_i and the pivots 1/(b_i - a c'_{i-1}) are
    precomputed on host (O(n) scalars) and the kernel only runs the RHS
    recurrences -- a forward and a backward `fori_loop` over rows with the
    whole (n, block_b) panel resident in VMEM.  Grid over batch blocks.

  * ``stencil_denoise``: the truncated-Neumann form y = p - lam * (L^T L) p
    (exact to O(lam^2); the paper's lam = 1e-12 makes the truncation error
    ~1e-24, below fp32 resolution).  A 3-point stencil along rows, fully
    parallel; grid over batch blocks with the full row dimension per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["thomas_solve", "stencil_denoise"]

DEFAULT_BLOCK_B = 128


def _thomas_kernel(p_ref, cp_ref, piv_ref, o_ref, d_ref, *, n, a_coef):
    """Forward/backward RHS recurrence; cp (c') and piv (pivots) precomputed."""
    # Forward elimination: d'_0 = p_0 * piv_0; d'_i = (p_i - a d'_{i-1}) piv_i
    d_ref[0, :] = p_ref[0, :] * piv_ref[0, 0]

    def fwd(i, _):
        d_ref[i, :] = (p_ref[i, :] - a_coef * d_ref[i - 1, :]) * piv_ref[i, 0]
        return 0

    jax.lax.fori_loop(1, n, fwd, 0)

    # Back substitution: y_{n-1} = d'_{n-1}; y_i = d'_i - c'_i y_{i+1}
    o_ref[n - 1, :] = d_ref[n - 1, :]

    def bwd(t, _):
        i = n - 2 - t
        o_ref[i, :] = d_ref[i, :] - cp_ref[i, 0] * o_ref[i + 1, :]
        return 0

    jax.lax.fori_loop(0, n - 1, bwd, 0)


@functools.partial(jax.jit, static_argnames=("lam", "h", "block_b", "interpret"))
def thomas_solve(
    p: jnp.ndarray,
    *,
    lam: float,
    h: float = -1.0,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jnp.ndarray:
    """Solve (I + lam L^T L) y = p for p of shape (n, batch); returns fp32."""
    n, b = p.shape
    assert b % block_b == 0, (b, block_b)
    # Host-side precompute of the constant elimination coefficients.
    diag = jnp.full((n,), 1.0 + lam * (1.0 + h * h), jnp.float32).at[0].set(1.0 + lam)
    a_coef = float(lam * h)  # sub/super diagonal value

    def scan_fn(cprev, bi):
        piv = 1.0 / (bi - a_coef * cprev)
        cnew = a_coef * piv
        return cnew, (cnew, piv)

    _, (cp, piv) = jax.lax.scan(scan_fn, jnp.float32(0.0), diag)
    cp = cp.at[n - 1].set(0.0)  # no superdiagonal on the last row
    cp2 = cp[:, None]
    piv2 = piv[:, None]

    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_thomas_kernel, n=n, a_coef=a_coef),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_b), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, block_b), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, block_b), jnp.float32)],
        interpret=interpret,
    )(p.astype(jnp.float32), cp2, piv2)


def _stencil_kernel(p_ref, o_ref, *, lam, h):
    """y = p - lam * K p, K = L^T L 3-point stencil (row 0 diag is 1)."""
    p = p_ref[...].astype(jnp.float32)
    n = p.shape[0]
    up = jnp.concatenate([p[1:], jnp.zeros_like(p[:1])], axis=0)      # p_{i+1}
    dn = jnp.concatenate([jnp.zeros_like(p[:1]), p[:-1]], axis=0)     # p_{i-1}
    kp = (1.0 + h * h) * p + h * (up + dn)
    row0 = kp[:1] - (h * h) * p[:1]
    kp = jnp.concatenate([row0, kp[1:]], axis=0)
    o_ref[...] = p - lam * kp


@functools.partial(jax.jit, static_argnames=("lam", "h", "block_b", "interpret"))
def stencil_denoise(
    p: jnp.ndarray,
    *,
    lam: float,
    h: float = -1.0,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jnp.ndarray:
    """First-order Neumann denoise of (n, batch) panels; returns fp32."""
    n, b = p.shape
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_stencil_kernel, lam=lam, h=h),
        grid=grid,
        in_specs=[pl.BlockSpec((n, block_b), lambda j: (0, j))],
        out_specs=pl.BlockSpec((n, block_b), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(p.astype(jnp.float32))
