"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "ec_matmul_ref",
    "encode_matmul_ref",
    "tridiag_solve_ref",
    "stencil_denoise_ref",
    "quantize_tile_ref",
]


def quantize_tile_ref(w: jnp.ndarray, levels: int, tile_k: int, tile_n: int) -> jnp.ndarray:
    """Per-(tile_k x tile_n)-tile symmetric quantization (MCA conductance grid).

    Computed in fp32 regardless of input dtype -- this matches the kernels,
    which cast the VMEM tile to fp32 before the conductance rounding (a bf16
    round near a bin edge would otherwise flip bins vs. the oracle).
    """
    k, n = w.shape
    assert k % tile_k == 0 and n % tile_n == 0
    t = w.astype(jnp.float32).reshape(k // tile_k, tile_k, n // tile_n, tile_n)
    scale = jnp.max(jnp.abs(t), axis=(1, 3), keepdims=True)
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.round(t / scale * (levels - 1)) / (levels - 1) * scale
    return q.reshape(k, n)


def encode_matmul_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    eps: jnp.ndarray,
    sigma: float,
    levels: int,
    tile_k: int,
    tile_n: int,
) -> jnp.ndarray:
    """y = x @ W_tilde with W_tilde = Q(W) * (1 + sigma * eps), per-tile Q."""
    q = quantize_tile_ref(w, levels, tile_k, tile_n)
    w_tilde = q * (1.0 + sigma * eps.astype(jnp.float32))
    return x.astype(jnp.float32) @ w_tilde


def ec_matmul_ref(
    x: jnp.ndarray,
    x_tilde: jnp.ndarray,
    w_tilde: jnp.ndarray,
    dw: jnp.ndarray,
) -> jnp.ndarray:
    """Tier-1 EC product (fused form): p = x @ W_tilde + x_tilde @ (W - W_tilde)."""
    f32 = jnp.float32
    return x.astype(f32) @ w_tilde.astype(f32) + x_tilde.astype(f32) @ dw.astype(f32)


def tridiag_solve_ref(p: jnp.ndarray, lam: float, h: float = -1.0) -> jnp.ndarray:
    """Exact solve of (I + lam L^T L) y = p; p is (n, batch)."""
    from repro.core.error_correction import denoise_least_square
    return denoise_least_square(p, lam=lam, h=h, method="thomas")


def stencil_denoise_ref(p: jnp.ndarray, lam: float, h: float = -1.0) -> jnp.ndarray:
    """First-order Neumann: y = p - lam * (L^T L) p; p is (n, batch)."""
    from repro.core.error_correction import denoise_least_square
    return denoise_least_square(p, lam=lam, h=h, method="neumann")
