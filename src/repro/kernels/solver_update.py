"""Pallas TPU kernels for the iterative-solver update hot loop.

An analog iterative solve (``repro.solvers``) alternates one crossbar MVM with
a handful of vector operations.  On hardware the MVM is "free" (analog); the
digital update is the whole inner loop, so the solver vector algebra is fused
into single kernels here, next to the tier-2 solves in :mod:`tridiag`:

  * ``richardson_update``: given the analog product ``y ~= A x``, one kernel
    forms the residual ``r = b - y`` and the relaxed step
    ``x' = x + omega * r`` (the MELISO+ Richardson iteration) in one VMEM
    pass instead of three HBM round-trips.
  * ``cg_update``: the twin axpy of conjugate-gradient,
    ``x' = x + alpha p`` and ``r' = r - alpha (A p)``, with a per-RHS-column
    ``alpha`` (multi-RHS batching).

Both kernels grid over row blocks with the full RHS batch per block; scalar
coefficients travel as tiny (1, batch) operands so they may be traced values
(auto-estimated ``omega``, per-iteration ``alpha``).  Interpret mode on CPU,
Mosaic on TPU -- same convention as the other kernels in this package.

Both are pure traced calls, so they compose with the engine's scan-fused
streamed MVM: a ``solvers.cg(A_streamed, b, backend="pallas")`` iteration
body -- one scanned EC block sweep + one fused twin axpy -- lives entirely
inside the solver's single jitted ``lax.while_loop`` program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["richardson_update", "cg_update"]

DEFAULT_BLOCK_N = 256


def _richardson_kernel(x_ref, b_ref, y_ref, omega_ref, ox_ref, or_ref):
    r = b_ref[...] - y_ref[...]
    or_ref[...] = r
    ox_ref[...] = x_ref[...] + omega_ref[0, 0] * r


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def richardson_update(
    x: jnp.ndarray,
    b: jnp.ndarray,
    y: jnp.ndarray,
    omega: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
):
    """Fused Richardson step on (n, batch) panels.

    Returns ``(x + omega * (b - y), b - y)``; ``omega`` is a scalar (possibly
    traced -- the power-iteration estimate).
    """
    n, bt = x.shape
    assert n % block_n == 0, (n, block_n)
    om = jnp.reshape(omega.astype(jnp.float32), (1, 1))
    grid = (n // block_n,)
    row = pl.BlockSpec((block_n, bt), lambda i: (i, 0))
    return pl.pallas_call(
        _richardson_kernel,
        grid=grid,
        in_specs=[row, row, row, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=(row, row),
        out_shape=(jax.ShapeDtypeStruct((n, bt), jnp.float32),
                   jax.ShapeDtypeStruct((n, bt), jnp.float32)),
        interpret=interpret,
    )(x.astype(jnp.float32), b.astype(jnp.float32), y.astype(jnp.float32), om)


def _cg_kernel(x_ref, r_ref, p_ref, ap_ref, alpha_ref, ox_ref, or_ref):
    a = alpha_ref[0, :][None, :]
    ox_ref[...] = x_ref[...] + a * p_ref[...]
    or_ref[...] = r_ref[...] - a * ap_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def cg_update(
    x: jnp.ndarray,
    r: jnp.ndarray,
    p: jnp.ndarray,
    ap: jnp.ndarray,
    alpha: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
):
    """Fused CG twin-axpy on (n, batch) panels with per-column ``alpha``.

    Returns ``(x + alpha * p, r - alpha * ap)``; ``alpha`` has shape
    ``(batch,)``.
    """
    n, bt = x.shape
    assert n % block_n == 0, (n, block_n)
    al = jnp.reshape(alpha.astype(jnp.float32), (1, bt))
    grid = (n // block_n,)
    row = pl.BlockSpec((block_n, bt), lambda i: (i, 0))
    return pl.pallas_call(
        _cg_kernel,
        grid=grid,
        in_specs=[row, row, row, row, pl.BlockSpec((1, bt), lambda i: (0, 0))],
        out_specs=(row, row),
        out_shape=(jax.ShapeDtypeStruct((n, bt), jnp.float32),
                   jax.ShapeDtypeStruct((n, bt), jnp.float32)),
        interpret=interpret,
    )(x.astype(jnp.float32), r.astype(jnp.float32), p.astype(jnp.float32),
      ap.astype(jnp.float32), al)
