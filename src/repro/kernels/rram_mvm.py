"""Pallas TPU kernels for the RRAM crossbar MVM simulation.

Two kernels, both tiled so that one (block_k x block_n) weight tile == one MCA
array: the VMEM tile *is* the crossbar, and the grid iteration over K-blocks is
the virtualization reassignment loop (DESIGN.md section 2).

  * ``encode_matmul``: y = x_tilde @ W_tilde with the encode (per-tile
    conductance quantization + programming noise) computed **in-VMEM**, so the
    encoded weights never round-trip to HBM.  This is the analog-simulation
    fast path: one HBM read of W instead of (write W_tilde + read W_tilde).

  * ``ec_matmul``: the two-tier-EC serving path.  Computes the fused tier-1
    combination p = x @ W_tilde + x_tilde @ dW (dW = W - W_tilde precomputed at
    "programming" time), reading x/x_tilde once per tile and issuing two MXU
    dots per block -- 33% fewer FLOPs than the paper's three analog products.

Block shapes default to (512, 512) weight tiles (the paper's best-performing
MCA cell size, conveniently 4x the 128x128 MXU tile) and 256-row activation
panels; fp32 accumulation in the output ref across the K grid dimension.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["encode_matmul", "ec_matmul"]

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_K = 512   # MCA cell rows (contraction)
DEFAULT_BLOCK_N = 512   # MCA cell cols (output features)

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams (and introduced
# pltpu.InterpretParams); accept either side of the rename so the kernels run
# on jax 0.4.x and current releases alike.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _interpret_mode():
    """Best-available interpret flag for pallas_call on this jax version."""
    cls = getattr(pltpu, "InterpretParams", None)
    return cls() if cls is not None else True


# --------------------------------------------------------------------------- #
# encode_matmul: on-the-fly encode + matmul
# --------------------------------------------------------------------------- #

def _encode_matmul_kernel(x_ref, w_ref, eps_ref, o_ref, *, sigma, levels, nsteps):
    """One (bm, bn) output block, accumulating over the K grid axis.

    The (bk, bn) weight tile in VMEM is one MCA: quantize with the tile's own
    conductance scale, apply programming noise, then one MXU dot.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(w))
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.round(w / scale * (levels - 1)) / (levels - 1) * scale
    w_tilde = q * (1.0 + sigma * eps_ref[...].astype(jnp.float32))
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w_tilde, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("sigma", "levels", "block_m", "block_k", "block_n", "interpret"),
)
def encode_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    eps: jnp.ndarray,
    *,
    sigma: float,
    levels: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = x @ (Q(w) * (1 + sigma * eps)) with per-(block_k, block_n)-tile Q.

    x: (m, k); w, eps: (k, n).  m, k, n must be multiples of the block shape
    (the ops wrapper pads).  Returns fp32 (m, n).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and eps.shape == w.shape, (x.shape, w.shape, eps.shape)
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        (m, k, n), (block_m, block_k, block_n))
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(
            _encode_matmul_kernel, sigma=sigma, levels=levels, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, eps)


# --------------------------------------------------------------------------- #
# encode_matmul_rng: encode + matmul with IN-KERNEL noise generation
# --------------------------------------------------------------------------- #

def _encode_matmul_rng_kernel(seed_ref, x_ref, w_ref, o_ref, *, sigma, levels,
                              use_prng):
    """Like _encode_matmul_kernel but the programming noise is drawn inside
    the kernel (pltpu PRNG seeded per tile + Box-Muller), so the eps array
    never exists in HBM: the weight tile is read exactly once per MCA
    assignment -- the single-pass analog-simulation path (EXPERIMENTS.md M3).
    """
    i, j, s_ = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(s_ == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(w))
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.round(w / scale * (levels - 1)) / (levels - 1) * scale

    if use_prng:
        pltpu.prng_seed(seed_ref[0], i, j, s_)
        # Two uniform draws -> Box-Muller standard normal.
        bits1 = pltpu.prng_random_bits(w.shape)
        bits2 = pltpu.prng_random_bits(w.shape)
        u1 = (bits1.astype(jnp.uint32) >> 8).astype(jnp.float32) / (1 << 24)
        u2 = (bits2.astype(jnp.uint32) >> 8).astype(jnp.float32) / (1 << 24)
        u1 = jnp.maximum(u1, 1e-7)
        eta = jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)
    else:
        # Old-jax generic interpreter: pltpu PRNG primitives have no CPU
        # lowering; match the TPU interpreter's documented semantics
        # (prng_random_bits stubbed to zeros).
        eta = jnp.zeros_like(w)

    w_tilde = q * (1.0 + sigma * eta)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w_tilde, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("sigma", "levels", "block_m", "block_k", "block_n",
                     "interpret"),
)
def encode_matmul_rng(
    seed: jnp.ndarray,
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    sigma: float,
    levels: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """y = x @ encode(w) with in-VMEM noise: W is the only O(k*n) HBM read.

    Validation caveat (DESIGN.md section 7): the CPU TPU-interpreter stubs
    ``prng_random_bits`` to zeros, so only the sigma=0 path (exact per-tile
    quantized matmul) and determinism are checkable off-TPU; the Box-Muller
    noise path exercises real hardware PRNG.  ``interpret`` accepts
    ``pltpu.InterpretParams()`` on CPU.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0
    grid = (m // block_m, n // block_n, k // block_k)
    if interpret is True:
        interpret = _interpret_mode()
    # The generic (non-TPU) interpreter on old jax cannot lower the pltpu PRNG
    # primitives; fall back to the zero-noise stub there.
    use_prng = not (interpret is True and not hasattr(pltpu, "InterpretParams"))
    return pl.pallas_call(
        functools.partial(_encode_matmul_rng_kernel, sigma=sigma, levels=levels,
                          use_prng=use_prng),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(seed, x, w)


# --------------------------------------------------------------------------- #
# ec_matmul: fused tier-1 error-corrected matmul
# --------------------------------------------------------------------------- #

def _ec_matmul_kernel(x_ref, xt_ref, wt_ref, dw_ref, o_ref):
    """p_block = x @ W_tilde + x_tilde @ dW, fp32 accumulation over K grid."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    xt = xt_ref[...].astype(jnp.float32)
    wt = wt_ref[...].astype(jnp.float32)
    dw = dw_ref[...].astype(jnp.float32)
    acc = jnp.dot(x, wt, preferred_element_type=jnp.float32)
    acc += jnp.dot(xt, dw, preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_k", "block_n", "interpret"))
def ec_matmul(
    x: jnp.ndarray,
    x_tilde: jnp.ndarray,
    w_tilde: jnp.ndarray,
    dw: jnp.ndarray,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_k: int = DEFAULT_BLOCK_K,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused tier-1 EC product p = x @ W_tilde + x_tilde @ (W - W_tilde).

    x, x_tilde: (m, k); w_tilde, dw: (k, n).  Returns fp32 (m, n).
    """
    m, k = x.shape
    _, n = w_tilde.shape
    assert x_tilde.shape == x.shape and dw.shape == w_tilde.shape
    assert m % block_m == 0 and k % block_k == 0 and n % block_n == 0, (
        (m, k, n), (block_m, block_k, block_n))
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        _ec_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, x_tilde, w_tilde, dw)
