"""Online refresh: re-program only the tiles the probes flag as degraded.

The controller follows the SNIPPETS.md snippet-2 write-back pattern: rank
tiles by probe score, re-run closed-loop write-and-verify
(:func:`~repro.core.write_verify.refresh_write_and_verify`) on the worst few,
and bill the *actual* :class:`~repro.core.write_verify.WriteStats` against
the cost of a full reprogram.  A refresh of ``k`` tiles costs at most
``k * tile_write_cost(cfg)``; amortization holds whenever ``k < mb * nb``,
which is exactly the regime stuck-at faults create (damage is sparse and
tile-local, drift is slow and global).  See DESIGN.md section 12.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import crossbar
from repro.core.write_verify import WriteStats, refresh_write_and_verify

__all__ = ["RefreshPolicy", "RefreshReport", "refresh_tiles", "select_tiles",
           "REFRESH_SALT"]

# Distinct key stream for refresh re-programming -- never collides with the
# program-time block keys, DAC draws, or the aging FAULT_SALT stream.
REFRESH_SALT = 0xF5E5


@dataclasses.dataclass(frozen=True)
class RefreshPolicy:
    """When and how much to refresh.

    ``threshold``: probe score above which a tile is a refresh candidate
    (relative per-tile residual; compare against the engine's fresh-image
    ``effective_sigma``).  ``max_tiles``: cap on tiles re-programmed per
    pass (None = all candidates) -- the knob trading refresh stall/energy
    against residual accuracy.
    """

    threshold: float = 0.05
    max_tiles: Optional[int] = None


@dataclasses.dataclass
class RefreshReport:
    """What one refresh pass did and what it cost."""

    tiles: Tuple[Tuple[int, int], ...]   # (i, j) tiles re-programmed, worst first
    write_stats: WriteStats              # actual verify-loop cost (summed)
    full_rewrite_stats: WriteStats       # cost of reprogramming the whole image
    scores_before: np.ndarray            # the (mb, nb) probe map acted on

    @property
    def energy_saving(self) -> float:
        """Fraction of a full-reprogram's energy avoided by tile selection."""
        full = float(self.full_rewrite_stats.energy_j)
        return 1.0 - float(self.write_stats.energy_j) / full if full else 0.0


def select_tiles(scores, policy: RefreshPolicy) -> Tuple[Tuple[int, int], ...]:
    """Candidate tiles, worst score first, thresholded and capped."""
    s = np.asarray(jax.device_get(scores))
    idx = np.argwhere(s > policy.threshold)
    ranked = sorted(map(tuple, idx), key=lambda ij: -s[ij])
    if policy.max_tiles is not None:
        ranked = ranked[: policy.max_tiles]
    return tuple((int(i), int(j)) for i, j in ranked)


def refresh_tiles(A, scores, policy: RefreshPolicy = RefreshPolicy(),
                  *, key: Optional[jax.Array] = None) -> RefreshReport:
    """Re-program the worst tiles of handle ``A`` in place.

    For each selected tile the *source* sub-matrix ``at + da`` (tier-1 keeps
    it exactly) is re-written through the closed verify loop, the handle's
    ``at/da`` blocks are updated with the new image and correction, derived
    execution caches are dropped (:meth:`AnalogMatrix.release`), and the
    :class:`~repro.reliability.aging.AgeLedger` is reset on those tiles --
    bumping ``refresh_count`` so the replayable fault process redraws.

    Refresh keys live in their own stream:
    ``fold_in(fold_in(fold_in(base_key, REFRESH_SALT), i*nb + j), refresh_count)``.
    """
    if A.at_blocks is None or A.da_blocks is None:
        raise ValueError(
            "refresh_tiles needs resident at/da blocks (execution='local'); "
            "streamed and producer handles re-materialize instead of refreshing")
    cfg = A.engine.cfg
    mb, nb = A._grid()
    tiles = select_tiles(scores, policy)
    full = crossbar.matrix_write_cost(*A.shape, cfg)
    if not tiles:
        return RefreshReport(tiles=(), write_stats=WriteStats.zero(),
                             full_rewrite_stats=full,
                             scores_before=np.asarray(jax.device_get(scores)))

    base = A.base_key if key is None else key
    stream = jax.random.fold_in(base, REFRESH_SALT)
    at, da = A.at_blocks, A.da_blocks
    total = WriteStats.zero()
    mask = np.zeros((mb, nb), bool)
    for (i, j) in tiles:
        src = at[i, j] + da[i, j]
        rc = int(A.age.refresh_count[i, j]) if A.age is not None else 0
        k = jax.random.fold_in(jax.random.fold_in(stream, i * nb + j), rc)
        new_at, st = refresh_write_and_verify(src, k, cfg.device,
                                              k_iters=cfg.k_iters)
        at = at.at[i, j].set(new_at)
        da = da.at[i, j].set(src - new_at)
        total = total + st
        mask[i, j] = True
    A.at_blocks, A.da_blocks = at, da
    A.release()
    if A.age is not None:
        A.age = A.age.reset(jnp.asarray(mask))
    return RefreshReport(tiles=tiles, write_stats=total,
                         full_rewrite_stats=full,
                         scores_before=np.asarray(jax.device_get(scores)))
