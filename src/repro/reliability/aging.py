"""Device aging: conductance drift + stuck-at faults on a programmed image.

Everything upstream of this package assumes a freshly verified image; this
module models what the image becomes after ``N`` MVM read disturbs and ``t``
seconds of retention (PAPERS.md: Bocquet et al. embrace exactly these RRAM
failure modes; Ensan et al. show the stuck-at countermeasures):

  * **Drift** -- every stored conductance decays by the smooth log-time power
    law ``G(t) = G0 * (1 + t/t0)^-nu`` (:func:`repro.core.devices.drift_factor`).
    The tier-1 correction operand ``dA`` was measured at *program* time, so
    the corrected MVM's error grows with age -- the physically honest failure
    mode, not an artificial noise injection.
  * **Stuck-at faults** -- each cell independently latches with probability
    ``1 - (1 - fault_rate)^N`` after ``N`` MVMs, sticking at G_off (zero) or
    at the G_on rail of its differential pair.  The per-cell uniform draw is
    a pure function of the handle's base key (``fold_in`` salted, one key per
    capacity block, re-folded by the block's refresh count), so the faulted
    set is *replayable*: re-running a trace reproduces the same failures, and
    the set only grows with ``N`` (a cell faulted at age 100 is still faulted
    at age 200).

State lives in an :class:`AgeLedger` attached to an
:class:`~repro.engine.AnalogMatrix` (``attach_age``): per-capacity-block MVM
counts, retention seconds and refresh counts, plus the per-block fault-process
keys.  :func:`aged_blocks` is the pure transform the engine fuses INTO its
execute dispatch (one jit -- aging adds zero dispatches; the invariant gate
pins this via the ``local-aged-forward-reference`` pipeline).  See DESIGN.md
section 12.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import crossbar
from repro.core.devices import (DeviceModel, drift_factor, drift_factor_py,
                                effective_sigma_py)

__all__ = ["AgeLedger", "attach_age", "attach_group_age", "aged_blocks",
           "fault_probability", "predicted_residual", "FAULT_SALT"]

#: fold_in salt separating the fault-process key stream from the programming
#: (k_a) and input-DAC (k_x) streams derived from the same base key.
FAULT_SALT = 0x0FA17


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AgeLedger:
    """Per-capacity-block age state of one programmed handle (a pytree).

    All fields are (mb, nb)-shaped except ``fault_keys`` (one PRNG key per
    block).  Functional updates only -- ``advanced``/``elapsed``/``reset``
    return new ledgers -- so a ledger checkpoints and restores through
    :class:`~repro.distributed.fault_tolerance.CheckpointManager` like any
    other pytree.
    """

    mvms: jnp.ndarray           # MVM read disturbs per block (float32)
    seconds: jnp.ndarray        # retention time since last (re)program (s)
    refresh_count: jnp.ndarray  # completed per-block refreshes (int32)
    fault_keys: jax.Array       # per-block fault-process keys, (mb, nb, ...)

    @classmethod
    def fresh(cls, base_key: jax.Array, mb: int, nb: int) -> "AgeLedger":
        """Age zero: the state of an image the instant verify completes."""
        fault_base = jax.random.fold_in(base_key, FAULT_SALT)
        return cls(
            mvms=jnp.zeros((mb, nb), jnp.float32),
            seconds=jnp.zeros((mb, nb), jnp.float32),
            refresh_count=jnp.zeros((mb, nb), jnp.int32),
            fault_keys=crossbar.block_keys(fault_base, mb, nb))

    @property
    def grid(self):
        return self.mvms.shape

    def advanced(self, n_mvms: int = 1) -> "AgeLedger":
        """``n_mvms`` more read disturbs on every block."""
        return dataclasses.replace(self, mvms=self.mvms + float(n_mvms))

    def elapsed(self, dt_s: float) -> "AgeLedger":
        """``dt_s`` more seconds of retention on every block."""
        return dataclasses.replace(self, seconds=self.seconds + float(dt_s))

    def reset(self, mask: jnp.ndarray) -> "AgeLedger":
        """Per-block refresh: zero the age where ``mask`` (mb, nb) is True
        and bump the refresh counter -- the next fault draws for those blocks
        come from a fresh fold of their fault keys."""
        mask = jnp.asarray(mask, bool)
        return AgeLedger(
            mvms=jnp.where(mask, 0.0, self.mvms),
            seconds=jnp.where(mask, 0.0, self.seconds),
            refresh_count=self.refresh_count + mask.astype(jnp.int32),
            fault_keys=self.fault_keys)


def attach_age(A) -> "AgeLedger":
    """Attach a fresh :class:`AgeLedger` to an AnalogMatrix handle.

    Local handles only (the aged execute needs the resident block layout);
    returns the ledger it set.  Distributed fault experiments mutate
    ``at_dense`` host-side between solve segments instead (see
    :mod:`repro.reliability.ft_solve`).
    """
    if A.at_blocks is None or A.da_blocks is None or A.mesh_sharded:
        raise ValueError(
            "attach_age needs a local handle with resident at/da blocks; "
            "streamed and distributed handles age via host-side injection")
    mb, nb = A.at_blocks.shape[:2]
    A.age = AgeLedger.fresh(A.base_key, mb, nb)
    return A.age


def attach_group_age(G) -> "AgeLedger":
    """Attach a stacked :class:`AgeLedger` to an AnalogMatrixGroup.

    One ledger per member, stacked along the leading image axis (every field
    gains a ``(size,)`` lead dim), each seeded from its member's OWN base key
    -- member ``g``'s fault draws are bit-identical to a solo handle aged
    from ``member_keys[g]``.  The grouped execute applies all ``size`` aging
    transforms inside its single dispatch and advances every member's counts
    together.  Local dense groups only, like :func:`attach_age`.
    """
    if G.at_blocks is None or G.da_blocks is None or G.mesh_sharded:
        raise ValueError(
            "attach_group_age needs a local group with resident at/da "
            "blocks; streamed and distributed groups age via host-side "
            "injection")
    mb, nb = G.at_blocks.shape[1:3]
    G.ages = jax.vmap(lambda k: AgeLedger.fresh(k, mb, nb))(G.member_keys)
    return G.ages


def fault_probability(device: DeviceModel, mvms) -> jnp.ndarray:
    """P(cell stuck) after ``mvms`` read disturbs: ``1 - (1 - rate)^N``.

    Computed as ``-expm1(N * log1p(-rate))``: the naive form underflows to
    exactly zero in float32 for realistic rates (``1 - 1e-9`` rounds to
    ``1.0``, float32 eps is ~1.2e-7), silently disabling the fault process
    for the low-rate devices."""
    n = jnp.asarray(mvms, jnp.float32)
    return -jnp.expm1(n * jnp.log1p(jnp.float32(-device.fault_rate)))


def aged_blocks(at_blocks: jnp.ndarray, age: AgeLedger,
                device: DeviceModel) -> jnp.ndarray:
    """The physical conductance image after aging: pure, jit-fusable.

    Applies the per-block drift factor to the stored image, then overwrites
    stuck cells: cell ``(i, j, r, c)`` is faulted iff its uniform draw (a
    function of the block's fault key and refresh count only) falls below
    ``fault_probability(device, mvms[i, j])`` -- deterministic, replayable,
    and monotone in the MVM count.  A second uniform picks the latch: G_off
    (zero conductance) or the G_on rail ``sign(w) * max|block|`` of the
    differential pair.  ``fault_rate == 0`` devices skip the fault pass
    entirely (a static Python branch -- no dead ops in the jaxpr).
    """
    decay = drift_factor(device, age.seconds)
    drifted = at_blocks * decay[:, :, None, None]
    if device.fault_rate <= 0.0:
        return drifted

    def per_block(at_blk, dr_blk, n, rc, k):
        u = jax.random.uniform(jax.random.fold_in(k, rc),
                               (2,) + at_blk.shape, jnp.float32)
        stuck = u[0] < fault_probability(device, n)
        scale = jnp.max(jnp.abs(at_blk))
        rail = jnp.where(u[1] < 0.5, 0.0, jnp.sign(at_blk) * scale)
        return jnp.where(stuck, rail, dr_blk)

    return jax.vmap(jax.vmap(per_block))(
        at_blocks, drifted, age.mvms, age.refresh_count, age.fault_keys)


def predicted_residual(device: DeviceModel, *, k_iters: int, seconds: float,
                       mvms: float, n: int) -> float:
    """Analytic health proxy: predicted relative MVM error at this age.

    Pure host-side math (no array reads -- the serving scheduler calls this
    per batch): the programming noise floor after ``k_iters`` verify passes,
    the uncorrected drift mismatch ``1 - (1 + t/t0)^-nu``, and the expected
    stuck-cell contribution ``sqrt(P_fault * n)`` (each of the ~``P * n``
    faulted cells on a row contributes O(1) relative error), combined in
    quadrature.  Monotone in both age axes; exact at age zero
    (== ``effective_sigma``)."""
    sigma_k = effective_sigma_py(device, k_iters)
    drift = 1.0 - drift_factor_py(device, seconds)
    p = -math.expm1(float(mvms) * math.log1p(-device.fault_rate)) \
        if device.fault_rate > 0.0 else 0.0
    return math.sqrt(sigma_k ** 2 + drift ** 2 + p * float(n))
