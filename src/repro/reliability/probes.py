"""Health probes: estimate per-tile degradation without reading the array.

A real deployment cannot read conductances back cheaply -- but it CAN run a
few corrected MVMs against *known* test vectors and compare with the digital
expectation.  One batched probe call localizes damage to capacity tiles:
probe column ``j`` is a fixed cosine ramp supported ONLY on column block
``j``, so output rows of row block ``i`` respond only to tile ``(i, j)`` --
the single (n, nb)-batched corrected MVM therefore yields a full (mb, nb)
per-tile residual map.  Probe executions are real executions: they consume
the engine's key schedule, are billed as input writes, and age the image
(``nb`` read disturbs -- the ledger advances like any other batch).

The scores feed the refresh controller (:mod:`repro.reliability.refresh`),
the SNIPPETS.md snippet-2 write-back pattern: probe, rank, re-verify only the
worst tiles.  See DESIGN.md section 12.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.write_verify import WriteStats

__all__ = ["ProbeReport", "probe_vectors", "probe_tile_scores"]

_TINY = 1e-12


@dataclasses.dataclass(frozen=True)
class ProbeReport:
    """One probe pass: the (mb, nb) per-tile residual map + what it cost."""

    scores: jnp.ndarray        # (mb, nb) relative per-tile residuals
    input_stats: WriteStats    # DAC/EC input-write cost of the probe batch
    n_probes: int              # probe columns executed (== nb)

    @property
    def worst(self) -> float:
        return float(jnp.max(self.scores))


def probe_vectors(n: int, nb: int, cap_n: int) -> jnp.ndarray:
    """The (n, nb) deterministic probe panel: column ``j`` is a unit-norm
    cosine ramp on column block ``j``, zero elsewhere.  A fixed, known
    pattern (not random): the digital expectation is computed once and the
    same probes are reusable across the device lifetime."""
    cols = []
    for j in range(nb):
        lo, hi = j * cap_n, min((j + 1) * cap_n, n)
        ramp = jnp.cos(jnp.pi * (jnp.arange(hi - lo) + 0.5) / (hi - lo))
        v = jnp.zeros((n,), jnp.float32).at[lo:hi].set(ramp)
        cols.append(v / jnp.maximum(jnp.linalg.norm(v), _TINY))
    return jnp.stack(cols, axis=1)


def probe_tile_scores(A, *, key: jax.Array | None = None) -> ProbeReport:
    """Run the probe batch against handle ``A``; returns per-tile scores.

    ``scores[i, j]`` is the relative l2 error of row block ``i`` under probe
    ``j`` -- the health of capacity tile ``(i, j)``.  The digital reference
    is ``A.dense()`` (the source matrix: tier-1 stores it exactly as
    ``A_tilde + dA``, unaffected by aging).  The probe MVM goes through the
    ordinary engine execute, so an attached :class:`~.aging.AgeLedger`
    both *shapes* the measurement (the aged image answers) and *advances*
    (``nb`` read disturbs billed to every block).
    """
    engine = A.engine
    m, n = A.shape
    mb, nb = A._grid()
    cap_m, _cap_n = engine.cfg.geom.capacity
    x = probe_vectors(n, nb, engine.cfg.geom.capacity[1])

    y = engine.mvm(A, x) if key is None else engine.mvm(A, x, key=key)
    y_ref = A.dense() @ x
    if A.age is not None:
        # the engine billed 1 read disturb for the batched call; a batch of
        # nb probe columns physically reads the array nb times.
        A.age = A.age.advanced(nb - 1)

    pad = mb * cap_m - m
    y_pad = jnp.pad(y, ((0, pad), (0, 0))).reshape(mb, cap_m, nb)
    r_pad = jnp.pad(y_ref, ((0, pad), (0, 0))).reshape(mb, cap_m, nb)
    err = jnp.sqrt(jnp.sum((y_pad - r_pad) ** 2, axis=1))
    ref = jnp.sqrt(jnp.sum(r_pad ** 2, axis=1))
    scores = err / jnp.maximum(ref, _TINY)
    return ProbeReport(scores=scores,
                       input_stats=engine.input_write_stats(A, batch=nb),
                       n_probes=nb)
