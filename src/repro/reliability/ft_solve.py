"""Fault-tolerant solves: segmented CG/PDHG with checkpoint/restore recovery.

A device fault mid-solve (a stuck-at cell flipping during iteration k)
poisons the Krylov recurrence: CG's residual is maintained *recursively*, so
after the operator changes the recurrence no longer tracks ``b - A x`` and
the solve either diverges or "converges" to the wrong answer.  The wrapper
here makes solves survive that:

  * the solve runs in SEGMENTS: for CG each segment is one iterative-
    refinement step (digital residual ``r = b - A x``, analog inner CG solve
    of ``A d = r`` capped at ``segment`` iterations, ``x += d``), which both
    measures the TRUE residual against the healthy reference captured at
    entry and keeps converging *below the analog noise floor* where a bare
    warm-started CG plateaus (see :func:`repro.solvers.refinement.refine`);
  * NaN or a residual above ``spike_factor`` x the best seen declares a
    fault, the iterate is rolled back to the last good checkpoint
    (:class:`~repro.distributed.fault_tolerance.CheckpointManager` -- the
    same atomic manifest+npz store distributed training uses), the
    ``on_fault`` callback gets a chance to repair the operator (re-program
    the damaged tiles, swap in a spare array), and the segment re-runs;
  * inside each segment the jitted core additionally early-exits on its own
    NaN/spike detector (``divergence=`` in :func:`repro.solvers.cg` /
    ``pdhg``), so a faulted segment costs at most a few MVMs, not
    ``segment`` of them.

Each segment re-enters the solver eagerly, so operator state mutated by
``on_fault`` / ``segment_hook`` (host-side ``at_dense`` / ``at_blocks``
writes, tile refreshes) is picked up by the next segment -- exactly the
recovery loop the serving and benchmark harnesses drive.  See DESIGN.md
section 12.
"""
from __future__ import annotations

import dataclasses
import tempfile
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault_tolerance import CheckpointManager
from repro.solvers.base import SolveLedger, SolveResult, as_operator
from repro.solvers.krylov import cg
from repro.solvers.pdhg import pdhg

__all__ = ["FaultEvent", "ft_cg", "ft_pdhg"]

_TINY = 1e-30


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One detected divergence: which segment, how it showed, where we went."""

    segment: int        # segment index that tripped the detector
    kind: str           # "nan" | "residual-spike"
    residual: float     # the offending digital residual
    restored_step: int  # checkpoint step rolled back to


def _col_rel(a_ref: np.ndarray, x, b: np.ndarray, bn: np.ndarray
             ) -> np.ndarray:
    """Per-column digital relative residual ||b - A_ref x|| / ||b||."""
    r = b - a_ref @ np.asarray(jax.device_get(x))
    return np.sqrt(np.sum(r * r, axis=0)) / bn


def ft_cg(
    A,
    b: jnp.ndarray,
    *,
    tol: float = 1e-6,
    maxiter: int = 400,
    segment: int = 30,
    inner_tol: float = 1e-2,
    manager: Optional[CheckpointManager] = None,
    key: Optional[jax.Array] = None,
    spike_factor: float = 10.0,
    max_restores: int = 8,
    on_fault: Optional[Callable[[FaultEvent, object], None]] = None,
    segment_hook: Optional[Callable[[int, object], None]] = None,
    backend: Optional[str] = None,
) -> SolveResult:
    """Fault-tolerant CG for SPD ``A`` (any :func:`as_operator` input with a
    ``dense()``; analog handles across all execution modes qualify).

    ``segment_hook(seg, A)`` runs before every segment (the benchmark's fault
    injector); ``on_fault(event, A)`` runs after every detected fault, before
    the retry -- mutate the handle there to repair it.  On a fault the
    iterate is reloaded from the last good checkpoint on disk rather than
    from memory: after a device fault (or a preemption mid-repair) the
    in-memory state is exactly what is no longer trusted.  ``manager``
    defaults to a fresh temp-dir :class:`CheckpointManager`.  Returns a
    :class:`SolveResult` whose ``residuals`` hold one DIGITAL relative
    residual per accepted segment (``iterations`` counts accepted segments,
    like GMRES cycles), and whose ``restores`` counts checkpoint rollbacks.
    """
    op = as_operator(A)
    if op.dense is None:
        raise ValueError("ft_cg needs an operator with dense() for the "
                         "digital outer residual check")
    # Healthy reference, captured at entry: faults injected DURING the solve
    # are judged against the matrix the caller asked to solve with.
    a_ref = np.asarray(jax.device_get(op.dense()), np.float32)
    squeeze = b.ndim == 1
    bb = np.asarray(jax.device_get(b), np.float32)
    bb = bb[:, None] if squeeze else bb
    bn = np.maximum(np.sqrt(np.sum(bb * bb, axis=0)), _TINY)
    key = jax.random.PRNGKey(0) if key is None else key
    if manager is None:
        manager = CheckpointManager(tempfile.mkdtemp(prefix="ft_cg_"))

    x = jnp.zeros((op.shape[1], bb.shape[1]), jnp.float32)
    rel = _col_rel(a_ref, x, bb, bn)
    entry_rel = float(np.max(rel))
    manager.save(0, {"x": x}, blocking=True,
                 extra={"segment": -1, "rel": entry_rel})
    good_step = 0
    seg = 0
    restores = 0
    stalls = 0
    mvms = 0
    total_iters = 0
    seg_hist: List[np.ndarray] = []
    events: List[FaultEvent] = []

    while total_iters < maxiter and float(np.max(rel)) > tol:
        if segment_hook is not None:
            segment_hook(seg, A)
        # One refinement step: digital residual, analog inner solve of
        # A d = r (crude -- its achieved residual is the outer contraction
        # rate), tentative update.  Faults surface as a NaN/spiking TRUE
        # residual of the tentative iterate.
        r = bb - a_ref @ np.asarray(jax.device_get(x))
        res = cg(A, jnp.asarray(r), tol=inner_tol, maxiter=segment,
                 key=jax.random.fold_in(key, 101 + seg), backend=backend,
                 divergence=spike_factor)
        mvms += res.ledger.mvms
        if getattr(A, "age", None) is not None:
            # Traced executes don't advance the ledger; bill the segment.
            A.age = A.age.advanced(res.ledger.mvms)
        x_try = x + res.x
        rel_try = _col_rel(a_ref, x_try, bb, bn)
        worst = float(np.max(rel_try))
        # Three fault signatures, all judged against the healthy reference:
        #   * the inner core tripped its own NaN/spike detector (exited
        #     early, not converged);
        #   * anything non-finite;
        #   * the correction made the residual equation WORSE (digital
        #     ||r - A_ref d|| / ||r|| > 1): a healthy inner solve always
        #     contracts it to roughly its achieved tolerance.
        d_rel = float(np.max(_col_rel(
            a_ref, res.x, r, np.maximum(np.sqrt(np.sum(r * r, axis=0)),
                                        _TINY))))
        early_div = (not res.converged) and int(res.iterations) < segment
        nan_like = not (np.isfinite(worst) and np.isfinite(d_rel))
        if early_div or nan_like or d_rel > 1.0:
            event = FaultEvent(
                segment=seg,
                kind="nan" if nan_like else "residual-spike",
                residual=d_rel if np.isfinite(d_rel) else worst,
                restored_step=good_step)
            events.append(event)
            restores += 1
            x = manager.restore({"x": x}, step=good_step)["x"]
            if on_fault is not None:
                on_fault(event, A)
            seg += 1
            if restores > max_restores:
                break
            continue
        if worst >= float(np.max(rel)):
            stalls += 1
            if stalls >= 2:
                break  # refinement floor: two straight non-contracting steps
            seg += 1
            continue
        stalls = 0
        x = x_try
        rel = rel_try
        seg_hist.append(rel_try)
        total_iters += max(int(res.iterations), 1)
        good_step += 1
        manager.save(good_step, {"x": x}, blocking=True,
                     extra={"segment": seg, "rel": worst})
        seg += 1

    hist = jnp.asarray(np.stack(seg_hist), jnp.float32) if seg_hist \
        else jnp.full((1, bb.shape[1]), jnp.nan, jnp.float32)
    batch = bb.shape[1]
    result = SolveResult(
        x=x[:, 0] if squeeze else x,
        residuals=hist[:, 0] if squeeze else hist,
        iterations=len(seg_hist),
        converged=bool(float(np.max(rel)) <= tol),
        ledger=SolveLedger(write_stats=op.write_stats,
                           input_stats=op.input_stats(batch),
                           mvms=int(mvms)),
        solver="ft-cg",
        initial_residual=entry_rel,
        restores=restores,
    )
    result.fault_events = tuple(events)
    return result


def ft_pdhg(
    A,
    b: jnp.ndarray,
    c: jnp.ndarray,
    *,
    tol: float = 1e-4,
    maxiter: int = 2000,
    segment: int = 200,
    manager: Optional[CheckpointManager] = None,
    key: Optional[jax.Array] = None,
    spike_factor: float = 10.0,
    max_restores: int = 8,
    on_fault: Optional[Callable[[FaultEvent, object], None]] = None,
    segment_hook: Optional[Callable[[int, object], None]] = None,
    eta: float = 0.9,
    power_iters: int = 16,
) -> SolveResult:
    """Fault-tolerant PDHG for ``min c'x s.t. Ax = b, x >= 0``.

    The segmented analogue of :func:`ft_cg` for linear programs: checkpoints
    carry the primal-dual pair ``(x, y)``, and the outer health check is the
    DIGITAL KKT residual (primal feasibility against the entry-time healthy
    ``A``; max of primal/dual infeasibility and the relative gap).
    """
    op = as_operator(A)
    if op.dense is None or op.rmatvec is None:
        raise ValueError("ft_pdhg needs an operator with dense() and rmatvec")
    a_ref = np.asarray(jax.device_get(op.dense()), np.float32)
    squeeze = b.ndim == 1
    bb = np.asarray(jax.device_get(b), np.float32)
    cc = np.asarray(jax.device_get(c), np.float32)
    bb = bb[:, None] if squeeze else bb
    cc = cc[:, None] if squeeze else cc
    bn = 1.0 + np.sqrt(np.sum(bb * bb, axis=0))
    cn = 1.0 + np.sqrt(np.sum(cc * cc, axis=0))
    key = jax.random.PRNGKey(0) if key is None else key
    if manager is None:
        manager = CheckpointManager(tempfile.mkdtemp(prefix="ft_pdhg_"))

    def kkt(x, y) -> np.ndarray:
        xh = np.asarray(jax.device_get(x))
        yh = np.asarray(jax.device_get(y))
        primal = np.sqrt(np.sum((a_ref @ xh - bb) ** 2, axis=0)) / bn
        slack = np.maximum(-(cc + a_ref.T @ yh), 0.0)
        dual = np.sqrt(np.sum(slack * slack, axis=0)) / cn
        pobj = np.sum(cc * xh, axis=0)
        dobj = -np.sum(bb * yh, axis=0)
        gap = np.abs(pobj - dobj) / (1.0 + np.abs(pobj) + np.abs(dobj))
        return np.maximum(np.maximum(primal, dual), gap)

    x = jnp.zeros((op.shape[1], bb.shape[1]), jnp.float32)
    y = jnp.zeros((op.shape[0], bb.shape[1]), jnp.float32)
    rel = kkt(x, y)
    entry_rel = float(np.max(rel))
    best = max(entry_rel, tol)
    manager.save(0, {"x": x, "y": y}, blocking=True,
                 extra={"segment": -1, "rel": entry_rel})
    good_step = 0
    seg = 0
    restores = 0
    stalls = 0
    mvms = mvms_t = mvms_single = 0
    total_iters = 0
    seg_hist: List[np.ndarray] = []
    events: List[FaultEvent] = []

    while total_iters < maxiter and float(np.max(rel)) > tol:
        if segment_hook is not None:
            segment_hook(seg, A)
        # PDHG's KKT residual is non-monotone in its transient, so the
        # in-core spike margin is widened -- the in-core detector's job here
        # is the immediate NaN exit; spike detection is the wrapper's.
        res = pdhg(A, jnp.asarray(bb), jnp.asarray(cc), tol=tol,
                   maxiter=segment, x0=x, y0=y,
                   key=jax.random.fold_in(key, 211 + seg), eta=eta,
                   power_iters=power_iters,
                   divergence=max(spike_factor, 50.0))
        mvms += res.ledger.mvms
        mvms_t += res.ledger.mvms_t
        mvms_single += res.ledger.mvms_single
        if getattr(A, "age", None) is not None:
            A.age = A.age.advanced(res.ledger.mvms + res.ledger.mvms_t)
        rel_try = kkt(res.x, res.dual)
        worst = float(np.max(rel_try))
        # Fault signatures: the inner core's own NaN/spike early exit,
        # anything non-finite, or a digital KKT residual spiking above
        # spike_factor x the best accepted value.
        early_div = (not res.converged) and int(res.iterations) < segment
        nan_like = not np.isfinite(worst)
        if early_div or nan_like or worst > spike_factor * best:
            event = FaultEvent(
                segment=seg,
                kind="nan" if nan_like else "residual-spike",
                residual=worst, restored_step=good_step)
            events.append(event)
            restores += 1
            state = manager.restore({"x": x, "y": y}, step=good_step)
            x, y = state["x"], state["y"]
            if on_fault is not None:
                on_fault(event, A)
            seg += 1
            if restores > max_restores:
                break
            continue
        if worst >= float(np.max(rel)):
            stalls += 1
            if stalls >= 2:
                break  # noise floor: two straight non-contracting segments
            seg += 1
            continue
        stalls = 0
        x, y = res.x, res.dual
        rel = rel_try
        best = min(best, max(worst, tol))
        seg_hist.append(rel_try)
        total_iters += max(int(res.iterations), 1)
        good_step += 1
        manager.save(good_step, {"x": x, "y": y}, blocking=True,
                     extra={"segment": seg, "rel": worst})
        seg += 1

    hist = jnp.asarray(np.stack(seg_hist), jnp.float32) if seg_hist \
        else jnp.full((1, bb.shape[1]), jnp.nan, jnp.float32)
    batch = bb.shape[1]
    stats_t = op.input_stats_t or op.input_stats
    result = SolveResult(
        x=x[:, 0] if squeeze else x,
        residuals=hist[:, 0] if squeeze else hist,
        iterations=len(seg_hist),
        converged=bool(float(np.max(rel)) <= tol),
        ledger=SolveLedger(write_stats=op.write_stats,
                           input_stats=op.input_stats(batch),
                           mvms=int(mvms),
                           input_stats_single=op.input_stats(1),
                           mvms_single=int(mvms_single),
                           input_stats_t=stats_t(batch),
                           mvms_t=int(mvms_t),
                           input_stats_single_t=stats_t(1),
                           mvms_single_t=int(mvms_single)),
        solver="ft-pdhg",
        initial_residual=entry_rel,
        restores=restores,
        dual=y[:, 0] if squeeze else y,
    )
    result.fault_events = tuple(events)
    return result
