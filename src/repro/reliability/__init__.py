"""Device-lifetime reliability: aging, health probes, online refresh, and
fault-tolerant solves.

The paper's write-and-verify loop makes a FRESH image accurate; this package
models what happens to that image over a device lifetime and closes the loop:

  * :mod:`~repro.reliability.aging` -- conductance drift + replayable
    stuck-at faults, applied inside the engine's single jitted dispatch via
    an :class:`~repro.reliability.aging.AgeLedger` attached to the handle;
  * :mod:`~repro.reliability.probes` -- per-tile health estimation from one
    batched corrected MVM against known test vectors;
  * :mod:`~repro.reliability.refresh` -- tile-selective re-program of the
    worst tiles, amortized against a full reprogram;
  * :mod:`~repro.reliability.ft_solve` -- segmented CG/PDHG with digital
    divergence detection and checkpoint/restore recovery.

See DESIGN.md section 12 and docs/reliability.md for the end-to-end story.
"""
from .aging import (AgeLedger, aged_blocks, attach_age, fault_probability,
                    predicted_residual)
from .ft_solve import FaultEvent, ft_cg, ft_pdhg
from .probes import ProbeReport, probe_tile_scores, probe_vectors
from .refresh import (RefreshPolicy, RefreshReport, refresh_tiles,
                      select_tiles)

__all__ = [
    "AgeLedger", "aged_blocks", "attach_age", "fault_probability",
    "predicted_residual",
    "ProbeReport", "probe_tile_scores", "probe_vectors",
    "RefreshPolicy", "RefreshReport", "refresh_tiles", "select_tiles",
    "FaultEvent", "ft_cg", "ft_pdhg",
]
