"""phi3.5-moe-42b-a6.6b -- 16-expert top-2 MoE [hf:microsoft/Phi-3.5-MoE-instruct]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    model=ModelConfig(
        family="moe", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=6400, vocab=32064, act="silu_gated",
        n_experts=16, experts_per_token=2, rope_theta=1e4,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(("long_500k", "pure full attention; no sub-quadratic path"),),
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
)
