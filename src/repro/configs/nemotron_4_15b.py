"""nemotron-4-15b -- dense GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="nemotron-4-15b",
    model=ModelConfig(
        family="transformer", n_layers=32, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=24576, vocab=256000, act="sq_relu",
        rope_theta=1e4,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(("long_500k", "pure full attention; no sub-quadratic path"),),
    source="arXiv:2402.16819; unverified",
)
