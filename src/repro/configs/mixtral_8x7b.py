"""mixtral-8x7b -- 8-expert top-2 MoE with sliding-window attention [arXiv:2401.04088]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="mixtral-8x7b",
    model=ModelConfig(
        family="moe", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=14336, vocab=32000, act="silu_gated",
        n_experts=8, experts_per_token=2, swa_window=4096, rope_theta=1e6,
    ),
    # SWA makes decode memory O(window): long_500k runs with a rolling cache.
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2401.04088; hf",
)
