"""rwkv6-1.6b -- Finch, attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="rwkv6-1.6b",
    model=ModelConfig(
        family="rwkv6", n_layers=24, d_model=2048, d_ff=7168, vocab=65536,
        ssm_head_dim=64,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2404.05892; unverified",
)
