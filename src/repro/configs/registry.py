"""--arch registry: id -> ArchConfig + family module + input specs.

``input_specs(arch, shape, reduced=False)`` builds the exact ShapeDtypeStruct
stand-ins the dry-run lowers against (weak-type-correct, shardable, zero
allocation), including abstract decode caches via ``jax.eval_shape``.
"""
from __future__ import annotations

import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import SHAPES, ArchConfig, ModelConfig, ShapeConfig

__all__ = ["ARCHS", "get_arch", "model_module", "input_specs", "batch_specs",
           "decode_cache_len"]

_MODULES = {
    "rwkv6-1.6b": "rwkv6_1p6b",
    "zamba2-1.2b": "zamba2_1p2b",
    "whisper-tiny": "whisper_tiny",
    "yi-9b": "yi_9b",
    "qwen3-1.7b": "qwen3_1p7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-8b": "qwen3_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "meliso-mvm": "meliso_mvm",
}

ARCHS = tuple(k for k in _MODULES if k != "meliso-mvm")

_FAMILY_MODULES = {
    "transformer": "repro.models.transformer",
    "moe": "repro.models.moe",
    "rwkv6": "repro.models.rwkv6",
    "zamba2": "repro.models.zamba2",
    "whisper": "repro.models.whisper",
    "llama_vision": "repro.models.llama_vision",
}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.ARCH


def model_module(cfg: ModelConfig):
    return importlib.import_module(_FAMILY_MODULES[cfg.family])


def decode_cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """KV budget for decode shapes: SWA archs keep a rolling window."""
    if cfg.swa_window:
        return min(shape.seq_len, cfg.swa_window)
    return shape.seq_len


def batch_specs(arch: ArchConfig, shape: ShapeConfig,
                reduced: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    """Train/prefill batch stand-ins for one step."""
    m = arch.reduced() if reduced else arch.model
    b, s = shape.global_batch, shape.seq_len
    cd = jnp.dtype(m.compute_dtype)
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if m.family == "whisper":
        specs["frames"] = jax.ShapeDtypeStruct((b, s, m.d_model), cd)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    elif m.family == "llama_vision":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["patches"] = jax.ShapeDtypeStruct((b, m.n_patches, m.d_model), cd)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs


def decode_cache_specs(arch: ArchConfig, shape: ShapeConfig,
                       reduced: bool = False):
    """Abstract decode caches (filled KV / SSM state of length seq_len)."""
    m = arch.reduced() if reduced else arch.model
    mod = model_module(m)
    b = shape.global_batch
    max_len = decode_cache_len(m, shape)

    if m.family in ("transformer", "moe"):
        fn = lambda: mod.init_caches(b, max_len, m)
    elif m.family == "rwkv6":
        fn = lambda: mod.init_caches(b, m)
    elif m.family == "zamba2":
        fn = lambda: mod.init_caches(b, max_len, m)
    elif m.family == "whisper":
        cd = jnp.dtype(m.compute_dtype)
        fn = lambda: {"kv": mod.init_caches(b, max_len, m),
                      "enc": jnp.zeros((b, shape.seq_len, m.d_model), cd)}
    elif m.family == "llama_vision":
        cd = jnp.dtype(m.compute_dtype)
        fn = lambda: {"kv": mod.init_caches(b, max_len, m),
                      "patches": jnp.zeros((b, m.n_patches, m.d_model), cd)}
    else:
        raise ValueError(m.family)
    return jax.eval_shape(fn)


def input_specs(arch: ArchConfig, shape_name: str, reduced: bool = False):
    """Everything the (train|prefill|decode) step takes, as ShapeDtypeStructs."""
    shape = SHAPES[shape_name]
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs(arch, shape, reduced)}
    # decode: one new token + filled caches
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return {"tokens": tokens,
            "caches": decode_cache_specs(arch, shape, reduced)}
