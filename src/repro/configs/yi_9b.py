"""yi-9b -- llama-arch dense GQA [arXiv:2403.04652]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="yi-9b",
    model=ModelConfig(
        family="transformer", n_layers=48, d_model=4096, n_heads=32,
        n_kv_heads=4, d_head=128, d_ff=11008, vocab=64000, act="silu_gated",
        rope_theta=5e6,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(("long_500k", "pure full attention; no sub-quadratic path"),),
    source="arXiv:2403.04652; hf",
)
