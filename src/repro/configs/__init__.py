from .base import (SHAPES, ArchConfig, MeshConfig, ModelConfig,
                   RRAMBackendConfig, ShapeConfig, TrainConfig)
from .registry import ARCHS, get_arch, input_specs, model_module

__all__ = ["SHAPES", "ArchConfig", "MeshConfig", "ModelConfig",
           "RRAMBackendConfig", "ShapeConfig", "TrainConfig", "ARCHS",
           "get_arch", "input_specs", "model_module"]
