"""qwen3-8b -- dense GQA with qk_norm [hf:Qwen/Qwen3-8B]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="qwen3-8b",
    model=ModelConfig(
        family="transformer", n_layers=36, d_model=4096, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=12288, vocab=151936, act="silu_gated",
        qk_norm=True, rope_theta=1e6,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(("long_500k", "pure full attention; no sub-quadratic path"),),
    source="hf:Qwen/Qwen3-8B; hf",
)
