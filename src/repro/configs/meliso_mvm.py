"""meliso-mvm -- the paper's own workload: distributed two-tier-EC corrected
MVM at 65,536 x 65,536 (exceeding the paper's 65,025 strong-scaling ceiling),
virtualized onto 512x512-cell MCA tiles across the mesh."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="meliso-mvm",
    model=ModelConfig(
        family="meliso", d_model=65536,   # problem dimension n
        param_dtype="float32", compute_dtype="float32",
    ),
    shapes=("mvm_65k",),
    source="this paper (MELISO+)",
)
