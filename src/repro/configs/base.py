"""Config dataclasses: model architecture, parallelism, RRAM backend, train/serve.

One ``ArchConfig`` per assigned architecture lives in ``repro/configs/<id>.py``;
the registry maps ``--arch`` ids to them.  Shapes (the assigned input-shape set)
are global and arch-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "MeshConfig", "RRAMBackendConfig", "TrainConfig",
           "ArchConfig", "ShapeConfig", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Superset of knobs across the model zoo; families ignore what they don't use."""

    family: str                    # transformer | moe | rwkv6 | zamba2 | whisper | llama_vision | meliso
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 128
    d_ff: int = 0
    vocab: int = 0
    act: str = "silu_gated"        # silu_gated | sq_relu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e4
    swa_window: Optional[int] = None      # sliding-window attention (mixtral)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    experts_per_token: int = 2
    expert_capacity_factor: float = 1.25
    # SSM / RWKV
    ssm_state: int = 64            # mamba2 N (state channels per head)
    ssm_head_dim: int = 64
    d_conv: int = 4
    expand: int = 2                # mamba2 d_inner = expand * d_model
    attn_every: int = 6            # zamba2: shared attn block period
    # enc-dec (whisper)
    n_enc_layers: int = 0
    # vision (llama 3.2)
    cross_attn_every: int = 5      # 1 cross-attn layer per 5 decoder layers
    n_patches: int = 4096
    # dtype policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    @property
    def vocab_pad(self) -> int:
        """Embedding tables padded to a multiple of 256 so the vocab dim
        shards on any mesh (padded logit columns are masked to -inf)."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    """Production mesh topology (launch/mesh.py builds the jax mesh)."""

    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")

    @property
    def shape(self) -> Tuple[int, ...]:
        return ((self.pods, self.data, self.model) if self.pods > 1
                else (self.data, self.model))

    @property
    def n_devices(self) -> int:
        return self.pods * self.data * self.model


@dataclasses.dataclass(frozen=True)
class RRAMBackendConfig:
    """Analog-execution backend for linear layers (the paper's technique)."""

    enabled: bool = False
    device: str = "taox-hfox"
    k_iters: int = 5
    ec: bool = True
    ec_mode: str = "fused"          # faithful | fused
    denoise_method: str = "neumann"  # dense | thomas | neumann
    lam: float = 1e-12
    cell_rows: int = 512
    cell_cols: int = 512
    encode_inputs: bool = True
    dw_dtype: str = "bfloat16"      # beyond-paper: compress the EC correction term


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatch: Optional[int] = None        # per-device microbatch (grad accum)
    remat: str = "block"                    # none | block | full
    zero_sharded_opt: bool = True           # ZeRO-1 optimizer-state sharding
    grad_compression: Optional[str] = None  # None | "int8" (cross-pod)
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    model: ModelConfig
    # Which assigned shapes are runnable (long_500k skipped for full attention).
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skip_reasons: Tuple[Tuple[str, str], ...] = ()
    # Sharding mode per shape kind:
    train_sharding: str = "fsdp_tp"   # fsdp_tp | tp
    infer_sharding: str = "tp"
    source: str = ""

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        m = self.model
        return dataclasses.replace(
            m,
            n_layers=min(m.n_layers, 2),
            d_model=64,
            n_heads=max(2, min(m.n_heads, 4)),
            n_kv_heads=max(1, min(m.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            vocab=256,
            n_experts=min(m.n_experts, 4) if m.n_experts else 0,
            n_enc_layers=min(m.n_enc_layers, 2),
            n_patches=16,
            ssm_state=16,
            ssm_head_dim=16,
            attn_every=2,
            cross_attn_every=2,
            swa_window=min(m.swa_window, 32) if m.swa_window else None,
            param_dtype="float32",
            compute_dtype="float32",
        )
