"""llama-3.2-vision-11b -- decoder with gated cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="llama-3.2-vision-11b",
    model=ModelConfig(
        family="llama_vision", n_layers=40, d_model=4096, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=14336, vocab=128256, act="silu_gated",
        cross_attn_every=5, n_patches=4096, rope_theta=5e5,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(("long_500k", "pure full attention; no sub-quadratic path"),),
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
