"""whisper-tiny -- enc-dec audio backbone, conv frontend stubbed [arXiv:2212.04356]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="whisper-tiny",
    model=ModelConfig(
        family="whisper", n_layers=4, n_enc_layers=4, d_model=384, n_heads=6,
        n_kv_heads=6, d_head=64, d_ff=1536, vocab=51865, act="gelu",
        rope_theta=0.0,          # whisper uses absolute (sinusoidal) positions
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    skip_reasons=(("long_500k", "full attention enc-dec; O(S^2) encoder"),),
    source="arXiv:2212.04356; unverified",
)
