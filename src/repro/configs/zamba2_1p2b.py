"""zamba2-1.2b -- Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from .base import ArchConfig, ModelConfig

ARCH = ArchConfig(
    name="zamba2-1.2b",
    model=ModelConfig(
        family="zamba2", n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_head=64, d_ff=8192, vocab=32000, ssm_state=64, ssm_head_dim=64,
        expand=2, d_conv=4, attn_every=6,
    ),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2411.15242; hf",
)
