"""Evaluation metrics (paper section 2.1) and cost aggregation helpers."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["relative_error", "rel_l2", "rel_linf"]


def relative_error(y: jnp.ndarray, b: jnp.ndarray, p=2) -> jnp.ndarray:
    """epsilon_total = ||y - b||_p / ||b||_p, p in {2, inf} (paper Eq. in 2.1)."""
    y = y.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if p == jnp.inf or p == "inf":
        num = jnp.max(jnp.abs(y - b))
        den = jnp.max(jnp.abs(b))
    else:
        num = jnp.linalg.norm((y - b).reshape(-1))
        den = jnp.linalg.norm(b.reshape(-1))
    return num / jnp.maximum(den, jnp.finfo(jnp.float32).tiny)


def rel_l2(y, b):
    return relative_error(y, b, p=2)


def rel_linf(y, b):
    return relative_error(y, b, p=jnp.inf)
