"""MELISO+ core: RRAM device models, write-verify, two-tier error correction,
virtualized multi-MCA crossbar simulation, and the distributed MVM engine."""

from .devices import (DEVICES, DeviceModel, drift_factor, effective_sigma,
                      encode, get_device, quantize)
from .write_verify import (
    WriteStats,
    adjustable_mat_write_and_verify,
    adjustable_vec_write_and_verify,
    adjustable_write_and_verify,
)
from .error_correction import (
    build_l_matrix,
    corrected_matmul,
    corrected_matvecmul,
    denoise_least_square,
    first_order_correct,
    tridiag_coeffs,
)
from .virtualization import (
    MCAGeometry,
    block_partition,
    generate_mat_chunks,
    generate_vec_chunks,
    reassemble,
    reassignment_count,
    zero_padding,
)
from .crossbar import (
    CrossbarConfig,
    block_keys,
    corrected_mvm,
    encode_tiled,
    input_write_cost,
    matrix_write_cost,
    local_block_keys,
    local_dense_mvm,
    local_dense_rmvm,
    local_program_dense,
    produce_blocks,
    producer_is_traceable,
    program_blocks,
    programmed_block_mvm,
    programmed_block_rmvm,
    streamed_block_mvm,
    streamed_block_rmvm,
    streamed_corrected_mvm,
    streamed_program_blocks,
    write_cost,
)
from .distributed import (
    distributed_corrected_mvm,
    make_distributed_program,
    make_distributed_programmed_mvm,
    make_distributed_rmvm,
    make_distributed_streamed_mvm,
    make_distributed_streamed_program,
    make_distributed_streamed_rmvm,
    mesh_grid_shape,
    pallas_shard_map_supported,
    shard_matrix,
)
from .metrics import rel_l2, rel_linf, relative_error

__all__ = [n for n in dir() if not n.startswith("_")]
