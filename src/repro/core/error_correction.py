"""Two-tier error correction (the paper's core algorithmic contribution).

Tier 1 -- first-order cancellation (paper Eq. 4-7):
    given Ã = A(1+eps_A) and x̃ = x(1+eps_x),
        p = Ãx + Ax̃ - Ãx̃ = Ax(1 - eps_A eps_x)
    cancels every first-order term, leaving the second-order product only.

    Two execution modes are provided:
      * ``faithful``: the paper's three analog products (3 matmuls).
      * ``fused``:    p = Ã(x - x̃) + Ax̃  -- algebraically identical, 2 matmuls
                      (a beyond-paper 33% FLOP reduction; validated in tests).

Tier 2 -- second-order denoising (paper Eq. 8-10, Algorithm 5):
    y(lambda) = (I + lambda * L^T L)^{-1} p,   L = I + h * superdiag (h = -1).

    (I + lambda L^T L) is symmetric positive-definite *tridiagonal*, so three
    methods are provided (all validated against each other):
      * ``dense``:   the paper-faithful dense inverse (O(n^3) setup, O(n^2) apply)
      * ``thomas``:  exact Thomas-algorithm solve, O(n) sequential
      * ``neumann``: truncated Neumann series y ~= p - lambda*K p + (lambda*K)^2 p ...
                     For the paper's lambda = 1e-12 the first-order truncation error
                     is O(lambda^2) ~ 1e-24, far below float32 resolution -- this
                     turns the solve into a 3-point stencil (O(n), fully parallel,
                     fuseable into the matmul epilogue).  Beyond-paper optimization.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "first_order_correct",
    "build_l_matrix",
    "tridiag_coeffs",
    "denoise_least_square",
    "corrected_matvecmul",
    "corrected_matmul",
]


# --------------------------------------------------------------------------- #
# Tier 1: first-order error correction
# --------------------------------------------------------------------------- #

def first_order_correct(
    a: jnp.ndarray,
    a_tilde: jnp.ndarray,
    x: jnp.ndarray,
    x_tilde: jnp.ndarray,
    *,
    mode: str = "fused",
) -> jnp.ndarray:
    """p = Ãx + Ax̃ - Ãx̃ (paper Eq. 7). ``x`` may be a vector or a matrix of
    column vectors; matmul semantics follow ``a @ x``.
    """
    if mode == "faithful":
        # The paper's three analog products, combined digitally.
        return a_tilde @ x + a @ x_tilde - a_tilde @ x_tilde
    if mode == "fused":
        # Identical algebra, one fewer matmul: Ã(x - x̃) + Ax̃.
        return a_tilde @ (x - x_tilde) + a @ x_tilde
    raise ValueError(f"unknown first-order EC mode {mode!r}")


# --------------------------------------------------------------------------- #
# Tier 2: regularized least-squares denoising
# --------------------------------------------------------------------------- #

def build_l_matrix(n: int, h: float = -1.0, dtype=jnp.float32) -> jnp.ndarray:
    """First-order differential matrix L: 1 on diag, h on superdiag (Eq. 9)."""
    return jnp.eye(n, dtype=dtype) + h * jnp.eye(n, k=1, dtype=dtype)


def tridiag_coeffs(n: int, lam: float, h: float = -1.0, dtype=jnp.float32):
    """(sub, diag, super) diagonals of M = I + lam * L^T L.

    L^T L is tridiagonal: (L^T L)_{ii} = 1 + h^2 for i >= 1, and 1 for i = 0;
    (L^T L)_{i,i+1} = (L^T L)_{i+1,i} = h.
    """
    diag = jnp.full((n,), 1.0 + lam * (1.0 + h * h), dtype=dtype)
    diag = diag.at[0].set(1.0 + lam)
    off = jnp.full((n - 1,), lam * h, dtype=dtype)
    return off, diag, off


def _dense_inverse_apply(p: jnp.ndarray, lam: float, h: float) -> jnp.ndarray:
    n = p.shape[0]
    l = build_l_matrix(n, h, dtype=jnp.float32)
    m = jnp.eye(n, dtype=jnp.float32) + lam * (l.T @ l)
    # The paper encodes M^{-1} on the MCA and multiplies; we form the explicit
    # inverse to stay faithful to that dataflow.
    m_inv = jnp.linalg.inv(m)
    return (m_inv @ p.astype(jnp.float32)).astype(p.dtype)


def _thomas_solve(p: jnp.ndarray, lam: float, h: float) -> jnp.ndarray:
    """Exact O(n) tridiagonal solve (vectorized over trailing dims of p)."""
    n = p.shape[0]
    sub, diag, sup = tridiag_coeffs(n, lam, h)
    pf = p.astype(jnp.float32)
    flat = pf.reshape(n, -1)

    def fwd(carry, inp):
        c_prev, d_prev = carry
        b_i, a_i, c_i, d_i = inp
        denom = b_i - a_i * c_prev
        c_new = c_i / denom
        d_new = (d_i - a_i * d_prev) / denom
        return (c_new, d_new), (c_new, d_new)

    a_seq = jnp.concatenate([jnp.zeros((1,), jnp.float32), sub])
    c_seq = jnp.concatenate([sup, jnp.zeros((1,), jnp.float32)])
    zero_row = jnp.zeros((flat.shape[1],), jnp.float32)
    (_, _), (cp, dp) = jax.lax.scan(
        fwd, (jnp.zeros((), jnp.float32), zero_row), (diag, a_seq, c_seq, flat)
    )

    def bwd(carry, inp):
        x_next = carry
        cp_i, dp_i = inp
        x_i = dp_i - cp_i * x_next
        return x_i, x_i

    _, xs = jax.lax.scan(bwd, zero_row, (cp, dp), reverse=True)
    return xs.reshape(p.shape).astype(p.dtype)


def _neumann_apply(p: jnp.ndarray, lam: float, h: float, terms: int = 2) -> jnp.ndarray:
    """y = sum_k (-lam K)^k p with K = L^T L as a 3-point stencil (no matrices)."""
    pf = p.astype(jnp.float32)

    def k_apply(v):
        # (K v)_i = (1+h^2) v_i + h v_{i-1} + h v_{i+1}, boundary-corrected:
        # row 0 diag is 1 (not 1+h^2).
        up = jnp.roll(v, -1, axis=0).at[-1].set(0.0)    # v_{i+1}
        dn = jnp.roll(v, 1, axis=0).at[0].set(0.0)      # v_{i-1}
        out = (1.0 + h * h) * v + h * (up + dn)
        return out.at[0].add(-(h * h) * v[0])

    y = pf
    term = pf
    for _ in range(terms - 1):
        term = -lam * k_apply(term)
        y = y + term
    return y.astype(p.dtype)


def denoise_least_square(
    p: jnp.ndarray,
    lam: float = 1e-12,
    h: float = -1.0,
    method: str = "neumann",
) -> jnp.ndarray:
    """Paper Algorithm 5 (second-order EC). ``p`` is (n,) or (n, batch)."""
    if method == "dense":
        return _dense_inverse_apply(p, lam, h)
    if method == "thomas":
        return _thomas_solve(p, lam, h)
    if method == "neumann":
        return _neumann_apply(p, lam, h)
    raise ValueError(f"unknown denoise method {method!r}")


# --------------------------------------------------------------------------- #
# End-to-end corrected MVM (paper Algorithm 6)
# --------------------------------------------------------------------------- #

def corrected_matvecmul(
    a: jnp.ndarray,
    x: jnp.ndarray,
    a_tilde: jnp.ndarray,
    x_tilde: jnp.ndarray,
    *,
    lam: float = 1e-12,
    h: float = -1.0,
    ec_mode: str = "fused",
    denoise_method: str = "neumann",
) -> jnp.ndarray:
    """correctedMatVecMul: tier-1 + tier-2 on pre-encoded operands."""
    p = first_order_correct(a, a_tilde, x, x_tilde, mode=ec_mode)
    return denoise_least_square(p, lam=lam, h=h, method=denoise_method)


def corrected_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    x_tilde: jnp.ndarray,
    w_tilde: jnp.ndarray,
    *,
    lam: float = 1e-12,
    h: float = -1.0,
    ec_mode: str = "fused",
    denoise_method: str = "neumann",
) -> jnp.ndarray:
    """Row-major orientation used by LM layers: y = x @ W, EC over both operands.

    p = x̃W + xW̃ - x̃W̃  (= xW - Δx ΔW);  fused form: p = xW̃ + x̃(W - W̃).
    Tier-2 denoising runs along the *output-feature* axis (the analog column
    lines), i.e. the last axis -- we transpose through the (n,)-leading
    convention of :func:`denoise_least_square`.
    """
    if ec_mode == "faithful":
        p = x_tilde @ w + x @ w_tilde - x_tilde @ w_tilde
    elif ec_mode == "fused":
        p = x @ w_tilde + x_tilde @ (w - w_tilde)
    else:
        raise ValueError(f"unknown first-order EC mode {ec_mode!r}")
    shape = p.shape
    pt = jnp.moveaxis(p.reshape(-1, shape[-1]), -1, 0)  # (n_out, batch*)
    yt = denoise_least_square(pt, lam=lam, h=h, method=denoise_method)
    return jnp.moveaxis(yt, 0, -1).reshape(shape)
