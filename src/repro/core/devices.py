"""RRAM device models for the MELISO+ simulation.

Four material systems from the paper (Table 1 / Fig. 2-3):

  - EpiRAM        [Choi et al., Nat. Mater. 2018]  -- high precision, high energy
  - Ag-aSi        [Jo et al., Nano Lett. 2010]     -- strong nonlinearity, slow verify
  - AlOx-HfO2     [Woo et al., EDL 2016]           -- noisy, mid energy
  - TaOx-HfOx     [Wu et al., VLSI 2018]           -- noisy but very fast & low energy

Each device is a small frozen dataclass of *effective* constants calibrated so the
single-pass (k=0) write of a 66x66 array reproduces the orders of magnitude of the
paper's Table 1 (see DESIGN.md section 7 for the calibration table and targets).

The programming model: writing a value ``w`` yields

    w_tilde = Q(w) * (1 + sigma_k * eta),      eta ~ N(0, 1)

where ``Q`` is per-tile symmetric quantization to ``levels`` conductance states and

    sigma_k = max(sigma_floor, sigma0 * (1 - effective_gain)**k)

models ``k`` closed-loop adjustableWriteandVerify iterations.  The effective gain is
reduced by the device's potentiation/depression nonlinearity (Ag-aSi's 2.4/-4.88
makes its verify loop converge ~4x slower, reproducing the paper's k~11 plateau
versus k~2 for the other materials).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

__all__ = [
    "DeviceModel",
    "DEVICES",
    "get_device",
    "effective_sigma",
    "drift_factor",
    "quantize",
    "encode",
]


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Effective per-material constants (see DESIGN.md section 7)."""

    name: str
    levels: int            # conductance states available for weight storage
    sigma0: float          # initial relative programming noise (std, multiplicative)
    verify_gain: float     # fraction of residual error removed per verify iteration
    e_write: float         # J per cell per programming pulse
    t_write: float         # s per row programming pulse (rows in a column are parallel)
    nl_pot: float          # potentiation nonlinearity coefficient
    nl_dep: float          # depression nonlinearity coefficient
    # --- lifetime constants (repro.reliability; see DESIGN.md section 12) ---
    # Log-time conductance drift G(t) = G0 * (1 + t / drift_t0)^-drift_nu
    # (smooth at t = 0, the power law for t >> t0) and a stuck-at fault
    # process: each cell independently fails with probability
    # 1 - (1 - fault_rate)^N after N MVM read disturbs, sticking at G_off
    # (zero) or at the G_on rail of its differential pair.
    drift_nu: float = 0.0       # drift exponent (dimensionless)
    drift_t0: float = 1.0       # drift reference time (s)
    fault_rate: float = 0.0     # stuck-at faults per cell per MVM

    @property
    def sigma_floor(self) -> float:
        # Quantization-limited noise floor: uniform quantization error std of a
        # symmetric `levels`-state cell, ~ 1/(levels * sqrt(12)) relative.
        return 1.0 / (self.levels * (12.0 ** 0.5))

    @property
    def effective_gain(self) -> float:
        # Nonlinearity shrinks the usable verify correction per iteration: the
        # write pulse over/undershoots in proportion to |nl|.
        nl = 0.5 * (abs(self.nl_pot) + abs(self.nl_dep))
        return self.verify_gain / (1.0 + 0.35 * nl)


# Lifetime constants: drift exponents span the published filamentary-oxide
# range (~1e-3 for epitaxial devices up to ~2e-2 for the electrochemical
# Ag-aSi system); stuck-at rates order the materials by endurance the same
# way Table 1 orders them by precision (the high-energy EpiRAM cell is also
# the most durable).
DEVICES: Dict[str, DeviceModel] = {
    "epiram": DeviceModel(
        name="epiram", levels=64, sigma0=0.022, verify_gain=0.50,
        e_write=2.3e-8, t_write=6.8e-4, nl_pot=0.5, nl_dep=-0.5,
        drift_nu=0.002, drift_t0=1.0, fault_rate=1e-9,
    ),
    "ag-si": DeviceModel(
        name="ag-si", levels=16, sigma0=0.23, verify_gain=0.60,
        e_write=8.6e-10, t_write=1.5e-2, nl_pot=2.4, nl_dep=-4.88,
        drift_nu=0.02, drift_t0=1.0, fault_rate=2e-7,
    ),
    "alox-hfo2": DeviceModel(
        name="alox-hfo2", levels=8, sigma0=0.60, verify_gain=0.60,
        e_write=1.3e-8, t_write=2.1e-3, nl_pot=1.0, nl_dep=-1.0,
        drift_nu=0.01, drift_t0=1.0, fault_rate=1e-7,
    ),
    "taox-hfox": DeviceModel(
        name="taox-hfox", levels=8, sigma0=0.49, verify_gain=0.60,
        e_write=1.2e-11, t_write=3.1e-6, nl_pot=0.8, nl_dep=-0.8,
        drift_nu=0.015, drift_t0=1.0, fault_rate=5e-8,
    ),
}


def get_device(name: str) -> DeviceModel:
    key = name.lower().replace("_", "-")
    if key not in DEVICES:
        raise KeyError(f"unknown RRAM device {name!r}; known: {sorted(DEVICES)}")
    return DEVICES[key]


def effective_sigma(device: DeviceModel, k: jnp.ndarray | int) -> jnp.ndarray:
    """Residual relative programming noise after ``k`` write-verify iterations."""
    k = jnp.asarray(k, jnp.float32)
    sigma = device.sigma0 * (1.0 - device.effective_gain) ** k
    return jnp.maximum(sigma, device.sigma_floor)


def drift_factor(device: DeviceModel, seconds: jnp.ndarray | float) -> jnp.ndarray:
    """Multiplicative conductance decay after ``seconds`` of retention.

    ``(1 + t/t0)^-nu``: exactly 1 at t = 0 (a freshly verified image is
    unchanged) and the paper-standard log-time power law ``(t/t0)^-nu`` for
    ``t >> t0``.  Applied to the stored image by
    :func:`repro.reliability.aging.aged_blocks`.
    """
    t = jnp.asarray(seconds, jnp.float32)
    return (1.0 + t / device.drift_t0) ** (-device.drift_nu)


def drift_factor_py(device: DeviceModel, seconds: float) -> float:
    """Pure-Python twin of :func:`drift_factor` (host-side cost models)."""
    return (1.0 + float(seconds) / device.drift_t0) ** (-device.drift_nu)


def effective_sigma_py(device: DeviceModel, k: float) -> float:
    """Pure-Python twin of :func:`effective_sigma` (safe inside traced code)."""
    return max(device.sigma0 * (1.0 - device.effective_gain) ** float(k),
               device.sigma_floor)


def quantize(w: jnp.ndarray, levels: int, axis=None) -> jnp.ndarray:
    """Symmetric quantization to ``levels`` conductance states.

    The scale is the max-abs over ``axis`` (the physical tile), mirroring the
    per-array DAC/conductance range of one MCA.  ``levels`` counts states on each
    polarity of the differential pair, so the grid is ``[-1, 1] * scale`` with
    ``levels`` bins per side.
    """
    scale = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.round(w / scale * (levels - 1)) / (levels - 1)
    return q * scale


def encode(
    w: jnp.ndarray,
    key: jax.Array,
    device: DeviceModel,
    k_iters: jnp.ndarray | int = 0,
    quantize_axis=None,
) -> jnp.ndarray:
    """Closed-form encode: quantize + residual programming noise after k iters.

    This is the fast path used by the LM ``rram`` backend; the faithful iterative
    loop (Algorithms 1-2 of the paper) lives in :mod:`repro.core.write_verify` and
    converges to the same residual noise model.
    """
    sigma = effective_sigma(device, k_iters).astype(w.dtype)
    q = quantize(w, device.levels, axis=quantize_axis)
    eta = jax.random.normal(key, w.shape, dtype=w.dtype)
    return q * (1.0 + sigma * eta)
