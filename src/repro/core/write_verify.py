"""adjustableWriteandVerify (paper Algorithms 1 & 2), JAX-native.

Faithful closed-loop programming: re-program the array while the relative
deviation ``delta(A, A_tilde) > eps`` and fewer than ``N`` iterations have run.
Each iteration refines the residual programming noise by the device's effective
verify gain (see :mod:`repro.core.devices` and DESIGN.md section 7 for the
calibration table, the sigma_k model and the validation targets), accruing
write energy and latency.

Implemented with ``jax.lax.while_loop`` so it jits, vmaps, and shards.  The loop
carries (k, A_tilde, key, stats); delta uses the p-norm requested (2 or inf) as in
the paper, but *relative* to ``||A||_p`` so that tolerance is scale-invariant
(the paper's absolute form is recovered by multiplying eps by ``||A||_p``).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .devices import DeviceModel, quantize

__all__ = [
    "WriteStats",
    "adjustable_write_and_verify",
    "adjustable_mat_write_and_verify",
    "adjustable_vec_write_and_verify",
    "refresh_write_and_verify",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class WriteStats:
    """Side-channel accounting for programming cost (a pytree of scalars)."""

    energy_j: jnp.ndarray      # total write energy (J)
    latency_s: jnp.ndarray     # total write latency (s); rows of a pass are parallel
    iterations: jnp.ndarray    # verify iterations actually used (int32)
    final_delta: jnp.ndarray   # relative ||A_tilde - A||_p at exit

    @classmethod
    def zero(cls) -> "WriteStats":
        z = jnp.zeros((), jnp.float32)
        return cls(energy_j=z, latency_s=z, iterations=jnp.zeros((), jnp.int32),
                   final_delta=z)

    def __add__(self, other: "WriteStats") -> "WriteStats":
        return WriteStats(
            energy_j=self.energy_j + other.energy_j,
            # Writes to distinct arrays in one pipeline are sequential per MCA:
            latency_s=self.latency_s + other.latency_s,
            iterations=self.iterations + other.iterations,
            final_delta=jnp.maximum(self.final_delta, other.final_delta),
        )


def _pnorm(x: jnp.ndarray, p) -> jnp.ndarray:
    if p == jnp.inf or p == "inf":
        return jnp.max(jnp.abs(x))
    return jnp.sqrt(jnp.sum(jnp.square(x)))


def adjustable_write_and_verify(
    a: jnp.ndarray,
    key: jax.Array,
    device: DeviceModel,
    *,
    eps: float = 1e-3,
    max_iters: int = 20,
    p=2,
    rows_parallel: bool = True,
) -> Tuple[jnp.ndarray, WriteStats]:
    """Program ``a`` onto an MCA with closed-loop write-and-verify.

    Returns the encoded array and :class:`WriteStats`.  Works for matrices
    (Algorithm 1) and vectors (Algorithm 2); a vector is programmed as one row.
    """
    a = jnp.asarray(a)
    cells = float(a.size)
    rows = float(a.shape[0]) if (a.ndim == 2 and rows_parallel) else 1.0
    norm_a = jnp.maximum(_pnorm(a, p), jnp.finfo(jnp.float32).tiny)
    q = quantize(a, device.levels)

    def program(carry_key, k):
        # Residual noise shrinks with each verify pass (closed-loop refinement).
        sigma = jnp.maximum(
            device.sigma0 * (1.0 - device.effective_gain) ** k.astype(jnp.float32),
            device.sigma_floor,
        )
        nkey, skey = jax.random.split(carry_key)
        eta = jax.random.normal(skey, a.shape, dtype=a.dtype)
        return q * (1.0 + sigma * eta), nkey

    def delta_of(at):
        return _pnorm(at - a, p) / norm_a

    a0, key = program(key, jnp.zeros((), jnp.int32))
    init = (jnp.zeros((), jnp.int32), a0, key,
            jnp.asarray(cells * device.e_write, jnp.float32),
            jnp.asarray(rows * device.t_write, jnp.float32))

    def cond(state):
        k, at, _, _, _ = state
        return jnp.logical_and(k < max_iters, delta_of(at) > eps)

    def body(state):
        k, at, ckey, e, t = state
        k = k + 1
        at, ckey = program(ckey, k)
        e = e + cells * device.e_write
        t = t + rows * device.t_write
        return (k, at, ckey, e, t)

    k, at, _, e, t = jax.lax.while_loop(cond, body, init)
    stats = WriteStats(energy_j=e, latency_s=t, iterations=k,
                       final_delta=delta_of(at))
    return at, stats


def refresh_write_and_verify(
    a: jnp.ndarray,
    key: jax.Array,
    device: DeviceModel,
    *,
    k_iters: int,
) -> Tuple[jnp.ndarray, WriteStats]:
    """Re-program one aged capacity tile back to engine-grade precision.

    The online-refresh variant of :func:`adjustable_write_and_verify` used by
    :mod:`repro.reliability.refresh`: the verify loop targets the SAME
    residual noise the engine's closed-form encode reaches after
    ``cfg.k_iters`` passes (``eps = effective_sigma(device, k_iters)``), and
    is capped at ``k_iters`` iterations -- so one tile's refresh never costs
    more than that tile's share of a full reprogram, and the refreshed tile
    is statistically indistinguishable from a freshly programmed one.
    """
    from .devices import effective_sigma_py
    eps = effective_sigma_py(device, k_iters)
    return adjustable_write_and_verify(a, key, device, eps=eps,
                                       max_iters=int(k_iters))


def adjustable_mat_write_and_verify(a, key, device, **kw):
    """Paper Algorithm 1 (matrix form)."""
    if jnp.ndim(a) != 2:
        raise ValueError("adjustableMatWriteandVerify expects a matrix")
    return adjustable_write_and_verify(a, key, device, **kw)


def adjustable_vec_write_and_verify(x, key, device, **kw):
    """Paper Algorithm 2 (vector form); programmed on a single row."""
    if jnp.ndim(x) != 1:
        raise ValueError("adjustableVecWriteandVerify expects a vector")
    return adjustable_write_and_verify(x, key, device, rows_parallel=False, **kw)
