"""Distributed corrected MVM over a JAX device mesh (paper Algorithm 4).

The paper distributes chunk pairs to MPI ranks; here each mesh device owns a
2-D block of the global matrix (rows over ``row_axis``, contraction columns
over ``col_axis``) and the set of MCA tiles that block maps onto.  Local
corrected MVMs produce tier-1 partials that are aggregated with ``psum`` over
the contraction axis -- the TPU-native image of the paper's MPI reduce -- and
tier-2 denoising then runs on-node on each device's output segment (the
paper's "on-node error correction").  The row partition stays sharded: the
output is produced already distributed, no gather required.

Cost statistics follow the paper's Figs. 4-5 convention: energy/latency are
reported as the mean across MCAs (mean across devices here).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .crossbar import CrossbarConfig, corrected_mvm
from .error_correction import denoise_least_square
from .write_verify import WriteStats

__all__ = ["distributed_corrected_mvm", "shard_matrix"]


def shard_matrix(a: jnp.ndarray, mesh: Mesh, row_axis: str, col_axis: str):
    """Place a global (m, n) matrix block-sharded over (row_axis, col_axis)."""
    return jax.device_put(a, NamedSharding(mesh, P(row_axis, col_axis)))


def _tier1_only(cfg: CrossbarConfig) -> CrossbarConfig:
    """Disable the local tier-2 denoise (lam=0 makes Neumann the identity)."""
    d = dict(cfg.__dict__)
    d["lam"] = 0.0
    d["denoise_method"] = "neumann"
    return CrossbarConfig(**d)


def make_distributed_mvm(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
):
    """Build the shard_map'd corrected-MVM callable (unjitted, lowerable).

    Signature of the returned fn: (a (m, n), x (n, batch), key) ->
    (y (m, batch) row-sharded, WriteStats).  ``row_axes`` may name several
    mesh axes (e.g. ("pod", "data")) for the row partition.
    """
    tier1_cfg = _tier1_only(cfg)

    def local_fn(a_blk, x_blk, k):
        # Per-device key: decorrelate programming noise across ranks.
        for ax in row_axes + (col_axis,):
            k = jax.random.fold_in(k, jax.lax.axis_index(ax))
        p_local, stats = corrected_mvm(a_blk, x_blk, k, tier1_cfg)
        p_local = jax.lax.psum(p_local, axis_name=col_axis)
        if cfg.ec:
            p_local = denoise_least_square(
                p_local, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
        n_ranks = jax.lax.psum(1, axis_name=row_axes + (col_axis,))
        e = jax.lax.psum(stats.energy_j, row_axes + (col_axis,)) / n_ranks
        t = jax.lax.psum(stats.latency_s, row_axes + (col_axis,)) / n_ranks
        stats = WriteStats(energy_j=e, latency_s=t,
                           iterations=stats.iterations,
                           final_delta=stats.final_delta)
        return p_local, stats

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    return jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_spec, col_axis), P(col_axis, None), P()),
        out_specs=(P(row_spec, None), P()),
    )


def distributed_corrected_mvm(
    a: jnp.ndarray,
    x: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axis: str = "data",
    col_axis: str = "model",
) -> Tuple[jnp.ndarray, WriteStats]:
    """y = A @ x with per-device multi-MCA simulation and two-tier EC.

    ``a``: global (m, n), m divisible by mesh[row_axis], n by mesh[col_axis].
    ``x``: (n,) or (n, batch).  Output is (m,) / (m, batch), sharded over rows.
    """
    squeeze = x.ndim == 1
    xb = x[:, None] if squeeze else x
    fn = make_distributed_mvm(cfg, mesh, (row_axis,), col_axis)
    y, stats = jax.jit(fn)(a, xb, key)
    return (y[:, 0] if squeeze else y), stats
