"""Distributed corrected MVM over a JAX device mesh (paper Algorithm 4).

The paper distributes chunk pairs to MPI ranks; here each mesh device owns a
2-D block of the global matrix (rows over ``row_axes``, contraction columns
over ``col_axis``) and the set of MCA tiles that block maps onto.

Program-once dataflow: :func:`make_distributed_program` writes each device's
conductance image (and the tier-1 correction operand dA) exactly once,
returning them still sharded -- the programmed operands are *placed* where
they will be used, like the physical crossbars they model.
:func:`make_distributed_programmed_mvm` then executes corrected MVMs against
those resident operands: local tier-1 partials are aggregated with ``psum``
over the contraction axis -- the TPU-native image of the paper's MPI reduce --
and tier-2 denoising runs on-node on each device's output segment (the
paper's "on-node error correction").  The row partition stays sharded: the
output is produced already distributed, no gather required.

:class:`repro.engine.AnalogEngine` with ``execution="distributed"`` is the
public interface; :func:`distributed_corrected_mvm` remains as a one-shot
deprecation shim.

Cost statistics follow the paper's Figs. 4-5 convention: energy/latency are
reported as the mean across MCAs (mean across devices here).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map
from .crossbar import (CrossbarConfig, assemble_blocks, input_write_cost,
                       matrix_write_cost, program_blocks, programmed_block_mvm,
                       write_cost)
from .error_correction import denoise_least_square
from .virtualization import block_partition
from .write_verify import WriteStats

__all__ = [
    "distributed_corrected_mvm",
    "shard_matrix",
    "make_distributed_program",
    "make_distributed_programmed_mvm",
]


def shard_matrix(a: jnp.ndarray, mesh: Mesh, row_axis, col_axis: str):
    """Place a global (m, n) matrix block-sharded over (row_axis, col_axis)."""
    return jax.device_put(a, NamedSharding(mesh, P(row_axis, col_axis)))


def _device_key(key: jax.Array, axes: Tuple[str, ...]) -> jax.Array:
    """Decorrelate programming/DAC noise across ranks (per-device key)."""
    for ax in axes:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    return key


def _mean_stats(stats: WriteStats, axes: Tuple[str, ...]) -> WriteStats:
    n_ranks = jax.lax.psum(1, axis_name=axes)
    return WriteStats(
        energy_j=jax.lax.psum(stats.energy_j, axes) / n_ranks,
        latency_s=jax.lax.psum(stats.latency_s, axes) / n_ranks,
        iterations=stats.iterations,
        final_delta=stats.final_delta,
    )


def make_distributed_program(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
):
    """Build the shard_map'd program stage (unjitted, lowerable).

    Returned fn: (a (m, n), key) -> (a_tilde, da, WriteStats), with a_tilde/da
    sharded exactly like ``a`` -- the operands are written once and stay
    resident on their devices.
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(a_blk, key):
        k = _device_key(key, axes)
        m_loc, n_loc = a_blk.shape
        at_b, da_b = program_blocks(a_blk, k, cfg)
        stats = _mean_stats(matrix_write_cost(m_loc, n_loc, cfg), axes)
        return (assemble_blocks(at_b, m_loc, n_loc),
                assemble_blocks(da_b, m_loc, n_loc), stats)

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_spec, col_axis), P()),
        out_specs=(P(row_spec, col_axis), P(row_spec, col_axis), P()),
    )


def make_distributed_programmed_mvm(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    stats_include_matrix: bool = False,
):
    """Build the shard_map'd execute stage (unjitted, lowerable).

    Returned fn: (a_tilde, da, x (n, batch), key) -> (y (m, batch) row-sharded,
    WriteStats).  Performs zero matrix-encode work: tier-1 runs against the
    resident operands, partials psum over ``col_axis``, tier-2 denoises
    on-node.  ``stats_include_matrix=True`` reproduces the legacy one-shot
    accounting (programming + input writes in a single figure).
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(at_blk, da_blk, x_blk, key):
        k = _device_key(key, axes)
        m_loc, n_loc = at_blk.shape
        batch = x_blk.shape[1]
        p = programmed_block_mvm(
            block_partition(at_blk, cfg.geom),
            block_partition(da_blk, cfg.geom),
            x_blk, k, cfg, m=m_loc, n=n_loc, tier2=False)
        p = jax.lax.psum(p, axis_name=col_axis)
        if cfg.ec:
            p = denoise_least_square(
                p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
        if stats_include_matrix:
            stats = write_cost(m_loc, n_loc, cfg, batch=batch)
        else:
            stats = input_write_cost(m_loc, n_loc, cfg, batch=batch)
        return p, _mean_stats(stats, axes)

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_spec, col_axis), P(row_spec, col_axis),
                  P(col_axis, None), P()),
        out_specs=(P(row_spec, None), P()),
    )


def distributed_corrected_mvm(
    a: jnp.ndarray,
    x: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axis: str = "data",
    col_axis: str = "model",
) -> Tuple[jnp.ndarray, WriteStats]:
    """y = A @ x with per-device multi-MCA simulation and two-tier EC.

    .. deprecated:: use ``AnalogEngine(cfg, execution="distributed",
       mesh=mesh)`` -- this one-shot form re-programs the full matrix on every
       call.  Kept as a shim composing the program and execute stages.

    ``a``: global (m, n), m divisible by mesh[row_axis], n by mesh[col_axis].
    ``x``: (n,) or (n, batch).  Output is (m,) / (m, batch), sharded over rows.
    """
    squeeze = x.ndim == 1
    xb = x[:, None] if squeeze else x
    program = make_distributed_program(cfg, mesh, (row_axis,), col_axis)
    execute = make_distributed_programmed_mvm(
        cfg, mesh, (row_axis,), col_axis, stats_include_matrix=True)

    def fused(a_, xb_, key_):
        at, da, _ = program(a_, key_)
        return execute(at, da, xb_, key_)

    y, stats = jax.jit(fused)(a, xb, key)
    return (y[:, 0] if squeeze else y), stats
