"""Distributed corrected MVM over a JAX device mesh (paper Algorithm 4).

The paper distributes chunk pairs to MPI ranks; here each mesh device owns a
2-D block of the global matrix (rows over ``row_axes``, contraction columns
over ``col_axis``) and the set of MCA tiles that block maps onto.

Placement and pipeline are orthogonal: each device's *local* stages are the
shared implementations from :mod:`repro.core.crossbar`, wrapped once in
``shard_map``.

  * **Dense placement** (:func:`make_distributed_program` /
    :func:`make_distributed_programmed_mvm`): the global operands exist and
    are block-sharded over the mesh; each device runs
    :func:`~repro.core.crossbar.local_program_dense` /
    :func:`~repro.core.crossbar.local_dense_mvm` on its resident block.
  * **Producer placement** (:func:`make_distributed_streamed_program` /
    :func:`make_distributed_streamed_mvm`): the global matrix NEVER
    materializes.  Each device derives its window of the global capacity-block
    grid from its ``(row, col)`` mesh coordinates and runs the scan-fused
    :func:`~repro.core.crossbar.streamed_program_blocks` /
    :func:`~repro.core.crossbar.streamed_block_mvm` pipelines over only its
    local blocks, with GLOBAL block indices and the GLOBAL ``block_keys``
    schedule -- so the programmed image and every DAC draw are identical,
    block for block, to the single-device streamed sweep (a 1x1 mesh is
    draw-identical to ``execution="streamed"``).

In both placements the programmed operands are written exactly once and stay
resident where they will be used, like the physical crossbars they model;
MVMs run tier-1 locally (optionally through the fused Pallas tile step -- see
:func:`pallas_shard_map_supported`), aggregate partials with ``psum`` over the
contraction axis -- the TPU-native image of the paper's MPI reduce -- and run
tier-2 denoising on-node on each device's output segment (the paper's
"on-node error correction").  The row partition stays sharded: the output is
produced already distributed, no gather required, which is what lets a whole
iterative solve (:mod:`repro.solvers`) keep its x/r/p panels sharded across
the ``lax.while_loop``.

:class:`repro.engine.AnalogEngine` with ``execution="distributed"`` is the
public interface; :func:`distributed_corrected_mvm` remains as a one-shot
deprecation shim.

Cost statistics follow the paper's Figs. 4-5 convention: energy/latency are
reported as the mean across MCAs (mean across devices here).
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map
from .crossbar import (CrossbarConfig, input_write_cost, local_dense_mvm,
                       local_dense_rmvm, local_program_dense,
                       matrix_write_cost, streamed_block_mvm,
                       streamed_block_rmvm, streamed_program_blocks,
                       write_cost)
from .error_correction import denoise_least_square
from .write_verify import WriteStats

__all__ = [
    "distributed_corrected_mvm",
    "shard_matrix",
    "mesh_grid_shape",
    "make_distributed_program",
    "make_distributed_programmed_mvm",
    "make_distributed_rmvm",
    "make_distributed_streamed_program",
    "make_distributed_streamed_mvm",
    "make_distributed_streamed_rmvm",
    "make_distributed_group_program",
    "make_distributed_group_mvm",
    "make_distributed_group_rmvm",
    "pallas_shard_map_supported",
]


def shard_matrix(a: jnp.ndarray, mesh: Mesh, row_axis, col_axis: str):
    """Place a global (m, n) matrix block-sharded over (row_axis, col_axis)."""
    return jax.device_put(a, NamedSharding(mesh, P(row_axis, col_axis)))


def _device_key(key: jax.Array, axes: Tuple[str, ...]) -> jax.Array:
    """Decorrelate programming/DAC noise across ranks (per-device key)."""
    for ax in axes:
        key = jax.random.fold_in(key, jax.lax.axis_index(ax))
    return key


def mesh_grid_shape(mesh: Mesh, row_axes: Tuple[str, ...],
                    col_axis: str) -> Tuple[int, int]:
    """(R, C): how many ways the mesh splits rows and contraction columns."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    r = 1
    for ax in row_axes:
        r *= sizes[ax]
    return r, sizes[col_axis]


def _row_index(row_axes: Tuple[str, ...]) -> jax.Array:
    """This device's row-shard index: row-major over ``row_axes`` (in-trace)."""
    from .compat import axis_size
    idx = jnp.int32(0)
    for ax in row_axes:
        idx = idx * axis_size(ax) + jax.lax.axis_index(ax)
    return idx


def _mean_stats(stats: WriteStats, axes: Tuple[str, ...]) -> WriteStats:
    n_ranks = jax.lax.psum(1, axis_name=axes)
    return WriteStats(
        energy_j=jax.lax.psum(stats.energy_j, axes) / n_ranks,
        latency_s=jax.lax.psum(stats.latency_s, axes) / n_ranks,
        iterations=stats.iterations,
        final_delta=stats.final_delta,
    )


def make_distributed_program(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
):
    """Build the shard_map'd program stage (unjitted, lowerable).

    Returned fn: (a (m, n), key) -> (a_tilde, da, WriteStats), with a_tilde/da
    sharded exactly like ``a`` -- the operands are written once and stay
    resident on their devices.
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(a_blk, key):
        k = _device_key(key, axes)
        m_loc, n_loc = a_blk.shape
        at, da = local_program_dense(a_blk, k, cfg)
        stats = _mean_stats(matrix_write_cost(m_loc, n_loc, cfg), axes)
        return at, da, stats

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_spec, col_axis), P()),
        out_specs=(P(row_spec, col_axis), P(row_spec, col_axis), P()),
    )


def make_distributed_programmed_mvm(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    stats_include_matrix: bool = False,
    use_kernel: bool = False,
):
    """Build the shard_map'd execute stage (unjitted, lowerable).

    Returned fn: (a_tilde, da, x (n, batch), key) -> (y (m, batch) row-sharded,
    WriteStats).  Performs zero matrix-encode work: tier-1 runs against the
    resident operands via the shared per-device stage
    (:func:`~repro.core.crossbar.local_dense_mvm`; ``use_kernel=True``
    dispatches its tile products to the fused Pallas kernel -- gate on
    :func:`pallas_shard_map_supported`), partials psum over ``col_axis``,
    tier-2 denoises on-node.  ``stats_include_matrix=True`` reproduces the
    legacy one-shot accounting (programming + input writes in one figure).
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(at_blk, da_blk, x_blk, key):
        k = _device_key(key, axes)
        m_loc, n_loc = at_blk.shape
        batch = x_blk.shape[1]
        p = local_dense_mvm(at_blk, da_blk, x_blk, k, cfg,
                            tier2=False, use_kernel=use_kernel)
        p = jax.lax.psum(p, axis_name=col_axis)
        if cfg.ec:
            p = denoise_least_square(
                p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
        if stats_include_matrix:
            stats = write_cost(m_loc, n_loc, cfg, batch=batch)
        else:
            stats = input_write_cost(m_loc, n_loc, cfg, batch=batch)
        return p, _mean_stats(stats, axes)

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    kwargs = {}
    if use_kernel:
        # pallas_call has no replication rule; the probe gates lowering, the
        # psum above makes the row partials exact regardless of the checker.
        kwargs["check_vma"] = False
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_spec, col_axis), P(row_spec, col_axis),
                  P(col_axis, None), P()),
        out_specs=(P(row_spec, None), P()),
        **kwargs,
    )


def make_distributed_rmvm(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    use_kernel: bool = False,
):
    """Build the shard_map'd TRANSPOSED execute stage (unjitted, lowerable).

    Returned fn: (a_tilde, da, y (m, batch), key) -> (z (n, batch)
    COLUMN-sharded over ``col_axis``, WriteStats).  The mirror of
    :func:`make_distributed_programmed_mvm` with the contraction flipped:
    ``y`` enters sharded over the ROW axes (the contraction axis of A^T),
    tier-1 runs transposed against the same resident operands via the shared
    per-device stage (:func:`~repro.core.crossbar.local_dense_rmvm`;
    ``use_kernel=True`` dispatches its tile products to the fused Pallas
    transposed tile step), partials psum over ``row_axes``, and tier-2
    denoises on-node on each device's COLUMN segment -- so the output is
    produced already column-sharded, ready to feed the primal update of a
    distributed PDHG iteration without a gather.
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(at_blk, da_blk, y_blk, key):
        k = _device_key(key, axes)
        m_loc, n_loc = at_blk.shape
        batch = y_blk.shape[1]
        p = local_dense_rmvm(at_blk, da_blk, y_blk, k, cfg,
                             tier2=False, use_kernel=use_kernel)
        p = jax.lax.psum(p, axis_name=tuple(row_axes))
        if cfg.ec:
            p = denoise_least_square(
                p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
        stats = input_write_cost(m_loc, n_loc, cfg, batch=batch,
                                 transpose=True)
        return p, _mean_stats(stats, axes)

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    kwargs = {}
    if use_kernel:
        kwargs["check_vma"] = False
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(row_spec, col_axis), P(row_spec, col_axis),
                  P(row_spec, None), P()),
        out_specs=(P(col_axis, None), P()),
        **kwargs,
    )


# --------------------------------------------------------------------------- #
# Grouped placement (a stack of same-geometry images in ONE shard_map program)
# --------------------------------------------------------------------------- #

def _scale_stats(stats: WriteStats, factor: int) -> WriteStats:
    """A group bills ``factor`` members' writes (members program in parallel
    onto disjoint MCA sets, so latency scales with energy here)."""
    return WriteStats(
        energy_j=stats.energy_j * factor,
        latency_s=stats.latency_s * factor,
        iterations=stats.iterations,
        final_delta=stats.final_delta,
    )


def make_distributed_group_program(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
):
    """Build the shard_map'd GROUP program stage (unjitted, lowerable).

    Returned fn: (a_g (g, m, n), keys (g, ...)) -> (at_g, da_g, WriteStats).
    The whole group programs in ONE shard_map dispatch: each device vmaps the
    shared :func:`~repro.core.crossbar.local_program_dense` stage over the
    leading image axis of its (g, m_loc, n_loc) resident slab, with member
    ``g`` consuming the device fold of ``keys[g]`` -- exactly the key a solo
    distributed program of that member would consume, so the stacked image is
    bit-identical to ``g`` solo programs.  Operands stay sharded over
    (``row_axes``, ``col_axis``); the image axis is never split.
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(a_slab, keys):
        dev_keys = jax.vmap(lambda k: _device_key(k, axes))(keys)
        size, m_loc, n_loc = a_slab.shape
        at, da = jax.vmap(lambda a, k: local_program_dense(a, k, cfg))(
            a_slab, dev_keys)
        stats = _mean_stats(
            _scale_stats(matrix_write_cost(m_loc, n_loc, cfg), size), axes)
        return at, da, stats

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(None, row_spec, col_axis), P()),
        out_specs=(P(None, row_spec, col_axis), P(None, row_spec, col_axis),
                   P()),
    )


def make_distributed_group_mvm(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    use_kernel: bool = False,
):
    """Build the shard_map'd GROUP execute stage (unjitted, lowerable).

    Returned fn: (at_g, da_g, x_g (g, n, batch), keys (g, ...)) ->
    (y_g (g, m, batch) row-sharded, WriteStats).  The whole group executes in
    ONE dispatch with ONE collective: tier-1 runs vmapped over the image axis
    against the resident slabs, the stacked (g, m_loc, batch) partials psum
    over ``col_axis`` ONCE for the whole group (not once per member), and
    tier-2 denoises each member's on-node segment.  Member ``g`` under
    ``keys[g]`` is bit-identical to a solo distributed execute of that member
    under the same key.
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(at_slab, da_slab, x_slab, keys):
        dev_keys = jax.vmap(lambda k: _device_key(k, axes))(keys)
        size, m_loc, n_loc = at_slab.shape
        batch = x_slab.shape[2]
        p = jax.vmap(lambda at, da, x, k: local_dense_mvm(
            at, da, x, k, cfg, tier2=False, use_kernel=use_kernel))(
            at_slab, da_slab, x_slab, dev_keys)
        p = jax.lax.psum(p, axis_name=col_axis)      # ONE psum for the group
        if cfg.ec:
            p = jax.vmap(lambda q: denoise_least_square(
                q, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method))(p)
        stats = _mean_stats(
            _scale_stats(input_write_cost(m_loc, n_loc, cfg, batch=batch),
                         size), axes)
        return p, stats

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    kwargs = {"check_vma": False} if use_kernel else {}
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(None, row_spec, col_axis), P(None, row_spec, col_axis),
                  P(None, col_axis, None), P()),
        out_specs=(P(None, row_spec, None), P()),
        **kwargs,
    )


def make_distributed_group_rmvm(
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    use_kernel: bool = False,
):
    """Build the shard_map'd GROUP transposed execute stage (unjitted).

    The :func:`make_distributed_rmvm` mirror of
    :func:`make_distributed_group_mvm`: ``y_g`` (g, m, batch) enters sharded
    over the ROW axes, transposed tier-1 runs vmapped over the image axis, the
    stacked partials psum ONCE over ``row_axes`` for the whole group, and the
    (g, n, batch) output comes back column-sharded over ``col_axis``.
    """
    axes = tuple(row_axes) + (col_axis,)

    def local_fn(at_slab, da_slab, y_slab, keys):
        dev_keys = jax.vmap(lambda k: _device_key(k, axes))(keys)
        size, m_loc, n_loc = at_slab.shape
        batch = y_slab.shape[2]
        p = jax.vmap(lambda at, da, y, k: local_dense_rmvm(
            at, da, y, k, cfg, tier2=False, use_kernel=use_kernel))(
            at_slab, da_slab, y_slab, dev_keys)
        p = jax.lax.psum(p, axis_name=tuple(row_axes))   # ONE psum per group
        if cfg.ec:
            p = jax.vmap(lambda q: denoise_least_square(
                q, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method))(p)
        stats = _mean_stats(
            _scale_stats(input_write_cost(m_loc, n_loc, cfg, batch=batch,
                                          transpose=True), size), axes)
        return p, stats

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    kwargs = {"check_vma": False} if use_kernel else {}
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(None, row_spec, col_axis), P(None, row_spec, col_axis),
                  P(None, row_spec, None), P()),
        out_specs=(P(None, col_axis, None), P()),
        **kwargs,
    )


# --------------------------------------------------------------------------- #
# Producer-driven placement (the matrix never materializes anywhere)
# --------------------------------------------------------------------------- #

def make_distributed_streamed_program(
    block_fn: Callable[[jax.Array, jax.Array], jnp.ndarray],
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    mb: int,
    nb: int,
):
    """Build the shard_map'd producer-driven program stage (unjitted).

    Returned fn: (key,) -> at_blocks (mb, nb, cap_m, cap_n) block-sharded over
    (``row_axes``, ``col_axis``).  Each device derives its window of the
    global block grid from its mesh coordinates and runs ONE scan-fused
    :func:`~repro.core.crossbar.streamed_program_blocks` sweep over only its
    local blocks -- the source matrix is never materialized on any host or
    device, and the per-block keys come from the global ``block_keys``
    schedule so the image is identical to the single-device streamed program.
    Requires ``mb % R == 0`` and ``nb % C == 0`` (validated by the engine).
    """
    r_count, c_count = mesh_grid_shape(mesh, row_axes, col_axis)
    mb_loc, nb_loc = mb // r_count, nb // c_count

    def local_fn(key):
        i0 = _row_index(row_axes) * mb_loc
        j0 = jax.lax.axis_index(col_axis) * nb_loc
        return streamed_program_blocks(
            block_fn, key, cfg, mb_loc, nb_loc,
            block_offset=(i0, j0), grid=(mb, nb))

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(),),
        out_specs=P(row_spec, col_axis, None, None),
        check_vma=False,   # output varies with axis_index, not with an input
    )


def make_distributed_streamed_mvm(
    block_fn: Callable[[jax.Array, jax.Array], jnp.ndarray],
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    m: int,
    n: int,
    mb: int,
    nb: int,
    resident: bool = True,
    use_kernel: bool = False,
):
    """Build the shard_map'd producer-driven execute stage (unjitted).

    Returned fn: ``(at_blocks, x, key) -> y`` when ``resident``, else
    ``(x, key) -> y`` -- ``x`` is the global (n, batch) panel (sharded or
    resharded over ``col_axis`` on entry), ``y`` the global (m, batch) output
    which STAYS row-sharded over ``row_axes`` (no gather), so solver panels
    remain distributed across a whole ``lax.while_loop``.

    Each device runs ONE scan-fused
    :func:`~repro.core.crossbar.streamed_block_mvm` over its local window of
    the global block grid (global producer indices, global key schedule):
    input-DAC encode, per-block dA re-derivation, tier-1 EC (``use_kernel``
    fuses the Pallas tile step), fp32 row accumulation.  Tier-1 partials psum
    over ``col_axis``; tier-2 denoise runs on-node on the local output
    segment.  ``resident=False`` selects the one-shot scan variant: each
    block is re-encoded inside the scan body (draws identical to
    program-then-execute) and immediately consumed, so NO device ever holds
    more than O(one capacity block) of A -- the paper's >= 65,536^2 regime.
    """
    r_count, c_count = mesh_grid_shape(mesh, row_axes, col_axis)
    mb_loc, nb_loc = mb // r_count, nb // c_count
    cap_m, cap_n = cfg.geom.capacity
    # Local logical footprint: exact-capacity shards except on a 1-way axis,
    # where the single device owns the (possibly padded) global edge.
    m_loc = m if r_count == 1 else mb_loc * cap_m
    n_loc = n if c_count == 1 else nb_loc * cap_n

    def local_fn(*args):
        if resident:
            at_loc, x_blk, key = args
        else:
            (x_blk, key), at_loc = args, None
        i0 = _row_index(row_axes) * mb_loc
        j0 = jax.lax.axis_index(col_axis) * nb_loc
        p = streamed_block_mvm(
            block_fn, at_loc, x_blk, key, cfg, m=m_loc, n=n_loc,
            use_kernel=use_kernel, tier2=False,
            block_offset=(i0, j0), grid=(mb, nb))
        p = jax.lax.psum(p, axis_name=col_axis)
        if cfg.ec:
            p = denoise_least_square(
                p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
        return p

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    at_spec = (P(row_spec, col_axis, None, None),) if resident else ()
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=at_spec + (P(col_axis, None), P()),
        out_specs=P(row_spec, None),
        check_vma=False,   # axis_index-derived block windows defeat the
                           # static replication checker; psum is still exact
    )


def make_distributed_streamed_rmvm(
    block_fn: Callable[[jax.Array, jax.Array], jnp.ndarray],
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axes: Tuple[str, ...] = ("data",),
    col_axis: str = "model",
    *,
    m: int,
    n: int,
    mb: int,
    nb: int,
    resident: bool = True,
    use_kernel: bool = False,
):
    """Build the shard_map'd producer-driven TRANSPOSED execute stage.

    Returned fn: ``(at_blocks, y, key) -> z`` when ``resident``, else
    ``(y, key) -> z`` -- ``y`` the global (m, batch) panel sharded over the
    ROW axes (the contraction of A^T), ``z`` the global (n, batch) output
    which comes back COLUMN-sharded over ``col_axis`` (no gather).

    Each device runs ONE scan-fused
    :func:`~repro.core.crossbar.streamed_block_rmvm` over its window of the
    global block grid (global producer indices, global key schedule -- the
    SAME per-block k_x halves as forward execution, so a 1x1 mesh is
    draw-identical to the single-device streamed transposed sweep).
    Transposed tier-1 partials psum over ``row_axes``; tier-2 denoise runs
    on-node on the local column segment.  ``resident=False`` re-encodes each
    block inside the scan (draws identical to program-then-execute), so a
    >= 65,536^2 LP's ``A.T @ y`` runs with no device ever holding more than
    O(one capacity block) of A.
    """
    r_count, c_count = mesh_grid_shape(mesh, row_axes, col_axis)
    mb_loc, nb_loc = mb // r_count, nb // c_count
    cap_m, cap_n = cfg.geom.capacity
    m_loc = m if r_count == 1 else mb_loc * cap_m
    n_loc = n if c_count == 1 else nb_loc * cap_n

    def local_fn(*args):
        if resident:
            at_loc, y_blk, key = args
        else:
            (y_blk, key), at_loc = args, None
        i0 = _row_index(row_axes) * mb_loc
        j0 = jax.lax.axis_index(col_axis) * nb_loc
        p = streamed_block_rmvm(
            block_fn, at_loc, y_blk, key, cfg, m=m_loc, n=n_loc,
            use_kernel=use_kernel, tier2=False,
            block_offset=(i0, j0), grid=(mb, nb))
        p = jax.lax.psum(p, axis_name=tuple(row_axes))
        if cfg.ec:
            p = denoise_least_square(
                p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
        return p

    row_spec = row_axes if len(row_axes) > 1 else row_axes[0]
    at_spec = (P(row_spec, col_axis, None, None),) if resident else ()
    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=at_spec + (P(row_spec, None), P()),
        out_specs=P(col_axis, None),
        check_vma=False,   # axis_index-derived block windows defeat the
                           # static replication checker; psum is still exact
    )


# Cached capability probes: (backend, mesh shape) -> bool.
_PALLAS_PROBE_CACHE: dict = {}


def pallas_shard_map_supported(mesh: Mesh) -> bool:
    """Can the fused Pallas EC tile step lower inside ``shard_map`` here?

    Compiles (never runs) a one-tile :func:`repro.kernels.ops.rram_ec_tile_mvm`
    wrapped in a trivial shard_map over ``mesh``.  On CPU the kernels run in
    interpret mode and this always succeeds; on accelerator backends whose
    Mosaic/Triton lowering rejects the manual-sharding context, the probe
    fails once per (backend, mesh shape), emits a warning, and the engine
    falls back to the reference tile step inside the same scan pipeline --
    the documented behavior of ``backend="pallas"`` +
    ``execution="distributed"`` (numerics are identical either way; only the
    kernel fusion is lost).
    """
    cache_key = (jax.default_backend(), tuple(mesh.devices.shape))
    if cache_key in _PALLAS_PROBE_CACHE:
        return _PALLAS_PROBE_CACHE[cache_key]
    try:
        from repro.kernels import ops as kops

        def local(x):
            eye = jnp.eye(8, dtype=jnp.float32)
            return kops.rram_ec_tile_mvm(x, x, eye, jnp.zeros_like(eye))

        probe = shard_map(local, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          check_vma=False)
        jax.jit(probe).lower(jnp.zeros((8, 1), jnp.float32)).compile()
        ok = True
    except Exception as exc:  # pragma: no cover - backend-specific
        warnings.warn(
            "backend='pallas' cannot lower inside shard_map on this "
            f"backend/mesh ({exc!r}); distributed execution falls back to "
            "the reference tile step (same numerics, no kernel fusion)")
        ok = False
    _PALLAS_PROBE_CACHE[cache_key] = ok
    return ok


def distributed_corrected_mvm(
    a: jnp.ndarray,
    x: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    mesh: Mesh,
    row_axis: str = "data",
    col_axis: str = "model",
) -> Tuple[jnp.ndarray, WriteStats]:
    """y = A @ x with per-device multi-MCA simulation and two-tier EC.

    .. deprecated:: use ``AnalogEngine(cfg, execution="distributed",
       mesh=mesh)`` -- this one-shot form re-programs the full matrix on every
       call.  Kept as a shim composing the program and execute stages.

    ``a``: global (m, n), m divisible by mesh[row_axis], n by mesh[col_axis].
    ``x``: (n,) or (n, batch).  Output is (m,) / (m, batch), sharded over rows.
    """
    squeeze = x.ndim == 1
    xb = x[:, None] if squeeze else x
    program = make_distributed_program(cfg, mesh, (row_axis,), col_axis)
    execute = make_distributed_programmed_mvm(
        cfg, mesh, (row_axis,), col_axis, stats_include_matrix=True)

    def fused(a_, xb_, key_):
        at, da, _ = program(a_, key_)
        return execute(at, da, xb_, key_)

    y, stats = jax.jit(fused)(a, xb, key)
    return (y[:, 0] if squeeze else y), stats
