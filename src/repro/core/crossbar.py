"""Multi-MCA crossbar simulation engine (reference, pure-jnp).

Combines the device models, write-verify encoding, virtualization and the
two-tier error correction into the paper's ``correctedMatVecMul`` /
``distributedMatVecMul`` dataflow, with analytic write-energy / write-latency
accounting that follows the paper's conventions:

  * energy  = every programmed cell costs ``e_write`` per pass (zero padding is
              programmed too, faithfully -- ``skip_zero_pad_writes`` turns on the
              beyond-paper optimization of eliding all-zero chunk writes);
  * latency = rows of one MCA are programmed sequentially, MCAs operate in
              parallel, reassignments (virtualization) serialize; the paper
              reports the *mean across MCAs* (Figs. 4-5), which for a uniform
              workload equals the per-MCA value;
  * passes  = k_iters + 1 write-verify passes (the paper sweeps fixed k);
  * EC      = one extra array write (the replicated X^T matrix, paper sec. 2)
              per assignment plus the input-vector write.

The Pallas kernel in :mod:`repro.kernels.rram_mvm` implements the same
encode+multiply semantics per (cell_rows x cell_cols) VMEM tile; this module is
its oracle at system level.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .devices import DeviceModel, effective_sigma, effective_sigma_py, quantize
from .error_correction import denoise_least_square, first_order_correct
from .virtualization import MCAGeometry, reassignment_count, zero_padding
from .write_verify import WriteStats

__all__ = [
    "CrossbarConfig",
    "encode_tiled",
    "write_cost",
    "corrected_mvm",
    "streamed_corrected_mvm",
]


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Everything needed to run one corrected MVM on a multi-MCA system."""

    device: DeviceModel
    geom: MCAGeometry = MCAGeometry()
    k_iters: int = 5                    # fixed write-verify iterations (paper Fig. 2-3)
    ec: bool = True                     # two-tier error correction on/off
    ec_mode: str = "fused"              # "faithful" (3 products) | "fused" (2)
    denoise_method: str = "neumann"     # "dense" | "thomas" | "neumann"
    lam: float = 1e-12
    h: float = -1.0
    encode_inputs: bool = True          # inputs (x) also pass through the DAC/encode
    skip_zero_pad_writes: bool = False  # beyond-paper: don't program all-zero chunks


# --------------------------------------------------------------------------- #
# Encoding
# --------------------------------------------------------------------------- #

def encode_tiled(
    a: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
) -> jnp.ndarray:
    """Encode a (padded) matrix with *per-MCA-tile* quantization scales.

    ``a`` is (M, N) with M, N multiples of the cell size; each (r x c) tile gets
    its own conductance range (per-array DAC scaling), quantization to the
    device's levels and residual programming noise after ``k_iters`` verify
    passes.
    """
    dev, geom = cfg.device, cfg.geom
    r_, c_ = geom.cell_rows, geom.cell_cols
    m, n = a.shape
    assert m % r_ == 0 and n % c_ == 0, (a.shape, (r_, c_))
    # Per-tile quantization without physical transposes: the (mt, r, nt, c)
    # view is a pure reshape, the per-tile scale reduces axes (1, 3) in place
    # (two whole-matrix transposes removed -- EXPERIMENTS.md Perf M1).
    tiles = a.reshape(m // r_, r_, n // c_, c_)
    q = quantize(tiles, dev.levels, axis=(1, 3))
    sigma = effective_sigma(dev, cfg.k_iters).astype(a.dtype)
    eta = jax.random.normal(key, tiles.shape, dtype=a.dtype)
    enc = q * (1.0 + sigma * eta)
    return enc.reshape(m, n)


def _encode_vec(x: jnp.ndarray, key: jax.Array, cfg: CrossbarConfig) -> jnp.ndarray:
    dev = cfg.device
    q = quantize(x, dev.levels, axis=None)
    sigma = effective_sigma(dev, cfg.k_iters).astype(x.dtype)
    eta = jax.random.normal(key, x.shape, dtype=x.dtype)
    return q * (1.0 + sigma * eta)


# --------------------------------------------------------------------------- #
# Analytic write cost (paper Figs. 2-5 accounting)
# --------------------------------------------------------------------------- #

def write_cost(m: int, n: int, cfg: CrossbarConfig, batch: int = 1) -> WriteStats:
    """Analytic write energy/latency for one corrected MVM of an (m, n) problem."""
    dev, geom = cfg.device, cfg.geom
    cap_m, cap_n = geom.capacity
    mb = -(-m // cap_m)
    nb = -(-n // cap_n)
    reass = mb * nb
    passes = float(cfg.k_iters + 1)

    if cfg.skip_zero_pad_writes:
        # Only the cells covering the true (m, n) footprint are programmed.
        cells_a = float(m) * float(n)
        rows_a_per_mca = reass * min(geom.cell_rows, max(1, m))
    else:
        cells_a = float(mb * cap_m) * float(nb * cap_n)
        rows_a_per_mca = reass * geom.cell_rows

    c_ = geom.cell_cols
    n_pad = nb * cap_n
    energy = cells_a * dev.e_write
    latency = rows_a_per_mca * dev.t_write
    if cfg.encode_inputs:
        energy += float(n_pad) * batch * dev.e_write        # x vector write
        latency += 1.0 * batch * dev.t_write
    if cfg.ec:
        # The replicated X^T array (c x c per MCA assignment, paper sec. 2).
        energy += float(reass * geom.n_mcas) * (c_ * c_) * batch * dev.e_write
        latency += reass * c_ * batch * dev.t_write
    # Pure-Python math throughout: this function is called inside shard_map
    # traces, where any jnp op would produce (un-float-able) tracers.
    return WriteStats(
        energy_j=jnp.float32(energy * passes),
        latency_s=jnp.float32(latency * passes),
        iterations=jnp.int32(cfg.k_iters),
        final_delta=jnp.float32(effective_sigma_py(dev, cfg.k_iters)),
    )


# --------------------------------------------------------------------------- #
# Corrected MVM (reference engine)
# --------------------------------------------------------------------------- #

def _block_mvm(a_blk, x_blk, key, cfg: CrossbarConfig):
    """One capacity-sized block: encode (per-tile) + tier-1 EC product."""
    k_a, k_x = jax.random.split(key)
    a_t = encode_tiled(a_blk, k_a, cfg)
    if cfg.encode_inputs:
        x_t = _encode_vec(x_blk, k_x, cfg)
    else:
        x_t = x_blk
    if cfg.ec:
        return first_order_correct(a_blk, a_t, x_blk, x_t, mode=cfg.ec_mode)
    return a_t @ x_t


def corrected_mvm(
    a: jnp.ndarray,
    x: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
) -> Tuple[jnp.ndarray, WriteStats]:
    """y ~= A @ x on the simulated multi-MCA system (paper Algorithm 6 + 4).

    ``x`` may be (n,) or (n, batch).  The matrix is padded, block-partitioned to
    the system capacity, each block is encoded with per-MCA scales and multiplied
    with tier-1 EC; column-block partials are summed; tier-2 denoising runs on
    the assembled local output (``denoise_scope=local`` in paper terms).
    """
    m, n = a.shape
    squeeze = x.ndim == 1
    xb = x[:, None] if squeeze else x
    batch = xb.shape[1]

    cap_m, cap_n = cfg.geom.capacity
    a_pad = zero_padding(a, cfg.geom)
    mp, np_ = a_pad.shape
    x_pad = jnp.pad(xb, ((0, np_ - n), (0, 0)))
    mb, nb = mp // cap_m, np_ // cap_n

    blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)
    x_chunks = x_pad.reshape(nb, cap_n, batch)
    keys = jax.random.split(key, mb * nb)
    keys = keys.reshape((mb, nb) + keys.shape[1:])   # typed or raw key format

    def per_row(i_blocks, i_keys):
        def per_col(a_blk, x_blk, k):
            return _block_mvm(a_blk, x_blk, k, cfg)
        partials = jax.vmap(per_col)(i_blocks, x_chunks, i_keys)
        return jnp.sum(partials, axis=0)                     # sum over column blocks

    y_blocks = jax.vmap(per_row)(blocks, keys)               # (mb, cap_m, batch)
    p = y_blocks.reshape(mb * cap_m, batch)[:m]
    if cfg.ec:
        p = denoise_least_square(p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
    stats = write_cost(m, n, cfg, batch=1)
    return (p[:, 0] if squeeze else p), stats


def streamed_corrected_mvm(
    block_fn: Callable[[int, int], jnp.ndarray],
    x: jnp.ndarray,
    m: int,
    n: int,
    key: jax.Array,
    cfg: CrossbarConfig,
) -> Tuple[jnp.ndarray, WriteStats]:
    """Large-problem variant: ``A`` is produced block-by-block by ``block_fn(i, j)``
    (each block capacity-sized, already padded), so matrices such as the paper's
    65,025 x 65,025 case never materialize.  Python loop over blocks; the inner
    step is jitted once and reused.
    """
    cap_m, cap_n = cfg.geom.capacity
    mb = -(-m // cap_m)
    nb = -(-n // cap_n)
    squeeze = x.ndim == 1
    xb = x[:, None] if squeeze else x
    batch = xb.shape[1]
    x_pad = jnp.pad(xb, ((0, nb * cap_n - n), (0, 0)))
    x_chunks = x_pad.reshape(nb, cap_n, batch)

    step = jax.jit(lambda a_blk, x_blk, k: _block_mvm(a_blk, x_blk, k, cfg))
    rows = []
    for i in range(mb):
        acc = jnp.zeros((cap_m, batch), jnp.float32)
        for j in range(nb):
            kij = jax.random.fold_in(jax.random.fold_in(key, i), j)
            acc = acc + step(block_fn(i, j), x_chunks[j], kij)
        rows.append(acc)
    p = jnp.concatenate(rows, axis=0)[:m]
    if cfg.ec:
        p = denoise_least_square(p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
    stats = write_cost(m, n, cfg, batch=1)
    return (p[:, 0] if squeeze else p), stats
