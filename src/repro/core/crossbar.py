"""Multi-MCA crossbar simulation engine (reference, pure-jnp).

Combines the device models, write-verify encoding, virtualization and the
two-tier error correction into the paper's ``correctedMatVecMul`` /
``distributedMatVecMul`` dataflow, with analytic write-energy / write-latency
accounting that follows the paper's conventions:

  * energy  = every programmed cell costs ``e_write`` per pass (zero padding is
              programmed too, faithfully -- ``skip_zero_pad_writes`` turns on the
              beyond-paper optimization of eliding all-zero chunk writes);
  * latency = rows of one MCA are programmed sequentially, MCAs operate in
              parallel, reassignments (virtualization) serialize; the paper
              reports the *mean across MCAs* (Figs. 4-5), which for a uniform
              workload equals the per-MCA value;
  * passes  = k_iters + 1 write-verify passes (the paper sweeps fixed k);
  * EC      = one extra array write (the replicated X^T matrix, paper sec. 2)
              per assignment plus the input-vector write.

The Pallas kernel in :mod:`repro.kernels.rram_mvm` implements the same
encode+multiply semantics per (cell_rows x cell_cols) VMEM tile; this module is
its oracle at system level.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .devices import DeviceModel, effective_sigma, effective_sigma_py, quantize
from .error_correction import denoise_least_square
from .virtualization import MCAGeometry, zero_padding
from .write_verify import WriteStats

__all__ = [
    "CrossbarConfig",
    "encode_tiled",
    "write_cost",
    "matrix_write_cost",
    "input_write_cost",
    "tile_write_cost",
    "block_keys",
    "capacity_elements",
    "local_block_keys",
    "program_blocks",
    "programmed_block_mvm",
    "programmed_block_rmvm",
    "local_program_dense",
    "local_dense_mvm",
    "local_dense_rmvm",
    "group_program_blocks",
    "grouped_block_mvm",
    "grouped_block_rmvm",
    "grouped_streamed_program_blocks",
    "grouped_streamed_block_mvm",
    "grouped_streamed_block_rmvm",
    "produce_blocks",
    "producer_is_traceable",
    "streamed_program_blocks",
    "streamed_block_mvm",
    "streamed_block_rmvm",
    "corrected_mvm",
    "streamed_corrected_mvm",
]


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    """Everything needed to run one corrected MVM on a multi-MCA system."""

    device: DeviceModel
    geom: MCAGeometry = MCAGeometry()
    k_iters: int = 5                    # fixed write-verify iterations (paper Fig. 2-3)
    ec: bool = True                     # two-tier error correction on/off
    ec_mode: str = "fused"              # "faithful" (3 products) | "fused" (2)
    denoise_method: str = "neumann"     # "dense" | "thomas" | "neumann"
    lam: float = 1e-12
    h: float = -1.0
    encode_inputs: bool = True          # inputs (x) also pass through the DAC/encode
    skip_zero_pad_writes: bool = False  # beyond-paper: don't program all-zero chunks


# --------------------------------------------------------------------------- #
# Encoding
# --------------------------------------------------------------------------- #

def encode_tiled(
    a: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
) -> jnp.ndarray:
    """Encode a (padded) matrix with *per-MCA-tile* quantization scales.

    ``a`` is (M, N) with M, N multiples of the cell size; each (r x c) tile gets
    its own conductance range (per-array DAC scaling), quantization to the
    device's levels and residual programming noise after ``k_iters`` verify
    passes.
    """
    dev, geom = cfg.device, cfg.geom
    r_, c_ = geom.cell_rows, geom.cell_cols
    m, n = a.shape
    assert m % r_ == 0 and n % c_ == 0, (a.shape, (r_, c_))
    # Per-tile quantization without physical transposes: the (mt, r, nt, c)
    # view is a pure reshape, the per-tile scale reduces axes (1, 3) in place
    # (two whole-matrix transposes removed -- EXPERIMENTS.md Perf M1).
    tiles = a.reshape(m // r_, r_, n // c_, c_)
    q = quantize(tiles, dev.levels, axis=(1, 3))
    sigma = effective_sigma(dev, cfg.k_iters).astype(a.dtype)
    eta = jax.random.normal(key, tiles.shape, dtype=a.dtype)
    enc = q * (1.0 + sigma * eta)
    return enc.reshape(m, n)


def _encode_vec(x: jnp.ndarray, key: jax.Array, cfg: CrossbarConfig) -> jnp.ndarray:
    dev = cfg.device
    q = quantize(x, dev.levels, axis=None)
    sigma = effective_sigma(dev, cfg.k_iters).astype(x.dtype)
    eta = jax.random.normal(key, x.shape, dtype=x.dtype)
    return q * (1.0 + sigma * eta)


# --------------------------------------------------------------------------- #
# Analytic write cost (paper Figs. 2-5 accounting)
# --------------------------------------------------------------------------- #

def write_cost(
    m: int,
    n: int,
    cfg: CrossbarConfig,
    batch: int = 1,
    *,
    include_matrix: bool = True,
    include_inputs: bool = True,
    transpose: bool = False,
) -> WriteStats:
    """Analytic write energy/latency for one corrected MVM of an (m, n) problem.

    The total splits into a *matrix* part (programming the conductance image --
    paid once under the program-once API) and an *input* part (the per-call x
    vector write plus the EC X^T replica, scaling with ``batch``).  The
    ``include_*`` switches select the parts; :func:`matrix_write_cost` and
    :func:`input_write_cost` are the named halves.

    ``transpose=True`` bills the input part of a *transposed* execution
    (``A.T @ y``, DESIGN.md section 5): the DAC vector then has ``m`` entries
    (padded to the capacity row footprint) and the EC replica is the
    row-dimension ``Y^T`` array (r x r per MCA assignment instead of c x c).
    The matrix part is unchanged -- the transposed execution reuses the one
    programmed image, paying zero extra matrix writes.
    """
    dev, geom = cfg.device, cfg.geom
    cap_m, cap_n = geom.capacity
    mb = -(-m // cap_m)
    nb = -(-n // cap_n)
    reass = mb * nb
    passes = float(cfg.k_iters + 1)

    energy = 0.0
    latency = 0.0
    if include_matrix:
        if cfg.skip_zero_pad_writes:
            # Only the cells covering the true (m, n) footprint are programmed.
            cells_a = float(m) * float(n)
            rows_a_per_mca = reass * min(geom.cell_rows, max(1, m))
        else:
            cells_a = float(mb * cap_m) * float(nb * cap_n)
            rows_a_per_mca = reass * geom.cell_rows
        energy += cells_a * dev.e_write
        latency += rows_a_per_mca * dev.t_write

    # Input-side footprint: forward executions write the (padded) n-length x
    # vector and the c x c EC X^T replica; transposed executions write the
    # m-length y vector and the r x r EC Y^T replica against the same image.
    c_ = geom.cell_rows if transpose else geom.cell_cols
    n_pad = mb * cap_m if transpose else nb * cap_n
    if include_inputs:
        if cfg.encode_inputs:
            energy += float(n_pad) * batch * dev.e_write        # x vector write
            latency += 1.0 * batch * dev.t_write
        if cfg.ec:
            # The replicated X^T array (c x c per MCA assignment, paper sec. 2).
            energy += float(reass * geom.n_mcas) * (c_ * c_) * batch * dev.e_write
            latency += reass * c_ * batch * dev.t_write
    # Pure-Python math throughout: this function is called inside shard_map
    # traces, where any jnp op would produce (un-float-able) tracers.
    return WriteStats(
        energy_j=jnp.float32(energy * passes),
        latency_s=jnp.float32(latency * passes),
        iterations=jnp.int32(cfg.k_iters),
        final_delta=jnp.float32(effective_sigma_py(dev, cfg.k_iters)),
    )


def matrix_write_cost(m: int, n: int, cfg: CrossbarConfig) -> WriteStats:
    """One-time programming cost of the (m, n) conductance image."""
    return write_cost(m, n, cfg, include_inputs=False)


def tile_write_cost(cfg: CrossbarConfig) -> WriteStats:
    """Programming cost of ONE capacity block (cap_m x cap_n).

    The unit the refresh controller budgets in
    (:mod:`repro.reliability.refresh`): re-verifying ``k`` worst tiles costs
    ``k`` of these against the full :func:`matrix_write_cost` of a complete
    reprogram -- the amortization that makes tile-selective refresh win."""
    cap_m, cap_n = cfg.geom.capacity
    return matrix_write_cost(cap_m, cap_n, cfg)


def input_write_cost(m: int, n: int, cfg: CrossbarConfig,
                     batch: int = 1, *, transpose: bool = False) -> WriteStats:
    """Per-execution cost: x-vector DAC write + EC X^T replica, per column.

    ``transpose=True`` bills a transposed execution (m-length y vector + the
    row-dimension EC replica; see :func:`write_cost`)."""
    return write_cost(m, n, cfg, batch=batch, include_matrix=False,
                      transpose=transpose)


# --------------------------------------------------------------------------- #
# Program stage / execute stage (the program-once dataflow)
# --------------------------------------------------------------------------- #
#
# The paper's dataflow is program-once / execute-many: the conductance image
# A_tilde is written to the MCAs one time, then reused across MVMs.  The
# functions below factor the old monolithic ``corrected_mvm`` into those two
# stages; :class:`repro.engine.AnalogEngine` is the public handle-based API on
# top, and the legacy entry points at the bottom of this file are thin
# compositions kept for backwards compatibility.
#
# Key discipline (shared by both stages so that program+execute reproduces the
# fused legacy path draw-for-draw): the base key splits into one key per
# capacity block, and each block key splits into (k_a, k_x) -- programming
# consumes k_a, execution consumes k_x.


def block_keys(key: jax.Array, mb: int, nb: int) -> jax.Array:
    """Per-capacity-block PRNG keys, shaped (mb, nb, ...)."""
    keys = jax.random.split(key, mb * nb)
    return keys.reshape((mb, nb) + keys.shape[1:])   # typed or raw key format


def capacity_elements(cfg: CrossbarConfig) -> int:
    """Elements of one capacity block -- the unit every streamed/distributed
    memory budget is expressed in (the AvalBound pass of the invariant gate
    asserts multiples of this; see DESIGN.md section 10)."""
    cap_m, cap_n = cfg.geom.capacity
    return cap_m * cap_n


def local_block_keys(key: jax.Array, mb: int, nb: int, i0, j0,
                     grid: Optional[Tuple[int, int]]) -> jax.Array:
    """The (mb, nb) slab of the GLOBAL ``block_keys(key, *grid)`` schedule
    whose origin sits at block coordinates ``(i0, j0)``.

    The per-block key is a function of the global block index only -- never of
    how the grid is carved across devices -- so the encoded image (and every
    DAC draw) of block (I, J) is identical whether the grid runs on one device
    or is mesh-sharded.  ``i0``/``j0`` may be traced scalars (mesh coordinates
    inside shard_map).  ``grid=None`` means the local grid IS the global grid.
    """
    if grid is None:
        return block_keys(key, mb, nb)
    keys = block_keys(key, *grid)
    start = (i0, j0) + (0,) * (keys.ndim - 2)
    return jax.lax.dynamic_slice(keys, start, (mb, nb) + keys.shape[2:])


def assemble_blocks(blocks: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Inverse of :func:`repro.core.virtualization.block_partition`:
    (mb, nb, cap_m, cap_n) capacity tiles -> dense (m, n), padding sliced."""
    mb, nb, cm, cn = blocks.shape
    return blocks.transpose(0, 2, 1, 3).reshape(mb * cm, nb * cn)[:m, :n]


def program_blocks(
    a: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Program stage: encode A onto the (virtual) MCAs, once.

    Returns ``(at_blocks, da_blocks)``, both (mb, nb, cap_m, cap_n):
    the per-block conductance images ``A_tilde`` and the tier-1 correction
    operands ``dA = A - A_tilde`` (paper Eq. 7, with the first-order product
    rewritten as  p = A_tilde x + dA x_tilde).
    """
    cap_m, cap_n = cfg.geom.capacity
    a_pad = zero_padding(a, cfg.geom)
    mp, np_ = a_pad.shape
    mb, nb = mp // cap_m, np_ // cap_n
    blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)
    keys = block_keys(key, mb, nb)

    def enc_row(row_blocks, row_keys):
        def enc_one(a_blk, k):
            k_a, _ = jax.random.split(k)
            return encode_tiled(a_blk, k_a, cfg)
        return jax.vmap(enc_one)(row_blocks, row_keys)

    at_blocks = jax.vmap(enc_row)(blocks, keys)
    return at_blocks, blocks - at_blocks


def programmed_block_mvm(
    at_blocks: jnp.ndarray,
    da_blocks: jnp.ndarray,
    xb: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    tier2: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Execute stage: corrected MVM against an already-programmed image.

    ``xb`` is (n, batch).  Performs zero matrix-encode work: only the input
    vector passes through the DAC (x -> x_tilde, per block, consuming the k_x
    half of the block key), the tier-1 product is assembled from the stored
    operands as  p = A_tilde x + dA x_tilde,  column-block partials are summed
    and tier-2 denoising runs on the assembled output (``tier2=False`` defers
    it, e.g. until after a cross-device psum).  ``use_kernel=True`` dispatches
    the per-block tier-1 product to the fused Pallas
    :func:`repro.kernels.ops.rram_ec_tile_mvm` tile step (requires
    ``cfg.ec``).  Returns (m, batch).
    """
    mb, nb, cap_m, cap_n = at_blocks.shape
    batch = xb.shape[1]
    x_pad = jnp.pad(xb, ((0, nb * cap_n - n), (0, 0)))
    x_chunks = x_pad.reshape(nb, cap_n, batch)
    keys = block_keys(key, mb, nb)

    if cfg.ec and cfg.ec_mode not in ("fused", "faithful"):
        raise ValueError(f"unknown first-order EC mode {cfg.ec_mode!r}")

    def per_row(at_row, da_row, row_keys):
        def per_col(at_blk, da_blk, x_blk, k):
            _, k_x = jax.random.split(k)
            x_t = _encode_vec(x_blk, k_x, cfg) if cfg.encode_inputs else x_blk
            if not cfg.ec:
                return at_blk @ x_t
            if use_kernel:
                from repro.kernels import ops as kops
                return kops.rram_ec_tile_mvm(x_blk, x_t, at_blk, da_blk)
            if cfg.ec_mode == "faithful":
                # The paper's three analog products, with A = A_tilde + dA.
                return (at_blk @ x_blk + (at_blk + da_blk) @ x_t
                        - at_blk @ x_t)
            return at_blk @ x_blk + da_blk @ x_t             # fused, 2 matmuls
        partials = jax.vmap(per_col)(at_row, da_row, x_chunks, row_keys)
        return jnp.sum(partials, axis=0)                     # sum over column blocks

    y_blocks = jax.vmap(per_row)(at_blocks, da_blocks, keys)   # (mb, cap_m, batch)
    p = y_blocks.reshape(mb * cap_m, batch)[:m]
    if cfg.ec and tier2:
        p = denoise_least_square(p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
    return p


def programmed_block_rmvm(
    at_blocks: jnp.ndarray,
    da_blocks: jnp.ndarray,
    yb: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    tier2: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Transposed execute stage: corrected ``A.T @ y`` against the programmed
    image -- zero re-encode of the conductance image.

    The exact mirror of :func:`programmed_block_mvm` run backwards through the
    crossbar: ``yb`` is (m, batch), the input vector is the ROW-dimension
    chunking of y (each row-block chunk passes through the DAC, consuming the
    SAME k_x key half of block (i, j) as a forward execution would), the
    tier-1 product is assembled from the stored operands as
    ``p = A_tilde^T y + dA^T y_tilde``, ROW-block partials are summed (rows
    are the contraction axis of A^T) and tier-2 denoising runs over the
    assembled (n, batch) column output.  ``use_kernel=True`` dispatches the
    per-block product to the fused Pallas
    :func:`repro.kernels.ops.rram_ec_tile_rmvm` tile step.  Returns (n, batch).
    """
    mb, nb, cap_m, cap_n = at_blocks.shape
    batch = yb.shape[1]
    y_pad = jnp.pad(yb, ((0, mb * cap_m - m), (0, 0)))
    y_chunks = y_pad.reshape(mb, cap_m, batch)
    keys = block_keys(key, mb, nb)

    if cfg.ec and cfg.ec_mode not in ("fused", "faithful"):
        raise ValueError(f"unknown first-order EC mode {cfg.ec_mode!r}")

    def per_col(at_col, da_col, col_keys):
        def per_row(at_blk, da_blk, y_blk, k):
            _, k_x = jax.random.split(k)
            y_t = _encode_vec(y_blk, k_x, cfg) if cfg.encode_inputs else y_blk
            if not cfg.ec:
                return at_blk.T @ y_t
            if use_kernel:
                from repro.kernels import ops as kops
                return kops.rram_ec_tile_rmvm(y_blk, y_t, at_blk, da_blk)
            if cfg.ec_mode == "faithful":
                # The paper's three analog products, transposed.
                return (at_blk.T @ y_blk + (at_blk + da_blk).T @ y_t
                        - at_blk.T @ y_t)
            return at_blk.T @ y_blk + da_blk.T @ y_t         # fused, 2 matmuls
        partials = jax.vmap(per_row)(at_col, da_col, y_chunks, col_keys)
        return jnp.sum(partials, axis=0)                     # sum over row blocks

    z_blocks = jax.vmap(per_col)(at_blocks.swapaxes(0, 1),
                                 da_blocks.swapaxes(0, 1),
                                 keys.swapaxes(0, 1))        # (nb, cap_n, batch)
    p = z_blocks.reshape(nb * cap_n, batch)[:n]
    if cfg.ec and tier2:
        p = denoise_least_square(p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
    return p


def local_program_dense(a: jnp.ndarray, key: jax.Array, cfg: CrossbarConfig
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One device's program stage over a resident dense operand.

    The per-device half of the distributed dense pipeline, shared with the
    local path: :func:`program_blocks` + reassembly to the dense per-device
    layout (the placed conductance image / tier-1 operand).
    """
    m, n = a.shape
    at_b, da_b = program_blocks(a, key, cfg)
    return assemble_blocks(at_b, m, n), assemble_blocks(da_b, m, n)


def local_dense_mvm(
    at: jnp.ndarray,
    da: jnp.ndarray,
    xb: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    *,
    tier2: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """One device's execute stage over resident dense (m, n) operands.

    Partitions to capacity blocks and runs the shared
    :func:`programmed_block_mvm` pipeline -- the SAME implementation the
    local execution mode uses, so the distributed path has no private copy
    of the tier-1 dataflow.  ``tier2=False`` defers denoising until after
    the cross-device psum (the caller's "on-node" tier-2).
    """
    from .virtualization import block_partition
    m, n = at.shape
    return programmed_block_mvm(
        block_partition(at, cfg.geom), block_partition(da, cfg.geom),
        xb, key, cfg, m=m, n=n, tier2=tier2, use_kernel=use_kernel)


def local_dense_rmvm(
    at: jnp.ndarray,
    da: jnp.ndarray,
    yb: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    *,
    tier2: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """One device's transposed execute stage over resident dense operands.

    Partitions to capacity blocks and runs the shared
    :func:`programmed_block_rmvm` pipeline -- the same implementation the
    local execution mode uses, so the distributed transposed path has no
    private copy of the tier-1 dataflow.  ``tier2=False`` defers denoising
    until after the cross-device psum over the ROW axes."""
    from .virtualization import block_partition
    m, n = at.shape
    return programmed_block_rmvm(
        block_partition(at, cfg.geom), block_partition(da, cfg.geom),
        yb, key, cfg, m=m, n=n, tier2=tier2, use_kernel=use_kernel)


# --------------------------------------------------------------------------- #
# Grouped (multi-image) stages: one pipeline over a stack of programmed images
# --------------------------------------------------------------------------- #
#
# A *group* stacks the per-tile images of several same-geometry matrices along
# a leading image axis ``g`` and runs the whole stack as ONE pipeline -- the
# whole-model dispatch primitive behind :class:`repro.engine.AnalogMatrixGroup`
# (an analog transformer block, or all experts of an MoE layer, executes as a
# single device dispatch instead of one per member).  Every grouped stage is a
# ``vmap``/``lax.map`` of the corresponding solo stage with PER-MEMBER keys, so
# member ``g`` of a grouped program/execute consumes exactly the
# ``block_keys(keys[g], mb, nb)`` schedule its solo counterpart would: the
# stacked image is bit-identical, member for member, to solo programming, and
# every grouped DAC draw matches the solo draw under the same member key.

def group_program_blocks(
    a_stack: jnp.ndarray,
    keys: jax.Array,
    cfg: CrossbarConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Program a stack of same-shape matrices in one pipeline.

    ``a_stack`` is (g, m, n); ``keys`` holds one base key per member.  Returns
    ``(at_blocks, da_blocks)``, both (g, mb, nb, cap_m, cap_n).  Member ``g``
    is :func:`program_blocks`\\ ``(a_stack[g], keys[g], cfg)`` exactly (same
    per-block k_a halves, same draws) -- grouping changes the dispatch count,
    never the image.
    """
    return jax.vmap(lambda a, k: program_blocks(a, k, cfg))(a_stack, keys)


def grouped_block_mvm(
    at_blocks: jnp.ndarray,
    da_blocks: jnp.ndarray,
    xb: jnp.ndarray,
    keys: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    tier2: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Corrected MVM of every group member in one pipeline.

    ``at_blocks``/``da_blocks`` are (g, mb, nb, cap_m, cap_n) stacked images,
    ``xb`` is (g, n, batch) -- one input panel per member -- and ``keys`` one
    execute key per member.  Returns (g, m, batch).  Member ``g`` reproduces
    :func:`programmed_block_mvm` under ``keys[g]`` (the identical per-block
    k_x halves), including tier-2 denoise per member.  ``use_kernel=True``
    runs the fused Pallas tile step under a member ``lax.map`` (the kernel
    sees one member at a time -- the extra image axis never reaches the
    pallas grid).
    """
    run = partial(programmed_block_mvm, cfg=cfg, m=m, n=n, tier2=tier2,
                  use_kernel=use_kernel)
    if use_kernel:
        return jax.lax.map(lambda ops: run(*ops),
                           (at_blocks, da_blocks, xb, keys))
    return jax.vmap(lambda at, da, x, k: run(at, da, x, k))(
        at_blocks, da_blocks, xb, keys)


def grouped_block_rmvm(
    at_blocks: jnp.ndarray,
    da_blocks: jnp.ndarray,
    yb: jnp.ndarray,
    keys: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    tier2: bool = True,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Transposed grouped execute: ``A_g.T @ y_g`` for every member at once.

    The exact mirror of :func:`grouped_block_mvm` over
    :func:`programmed_block_rmvm`: ``yb`` is (g, m, batch), the result
    (g, n, batch), and member ``g`` consumes the same per-block k_x halves a
    solo transposed execute under ``keys[g]`` would.
    """
    run = partial(programmed_block_rmvm, cfg=cfg, m=m, n=n, tier2=tier2,
                  use_kernel=use_kernel)
    if use_kernel:
        return jax.lax.map(lambda ops: run(*ops),
                           (at_blocks, da_blocks, yb, keys))
    return jax.vmap(lambda at, da, y, k: run(at, da, y, k))(
        at_blocks, da_blocks, yb, keys)


def _switched_producer(block_fns: Tuple[Callable, ...], g: jax.Array):
    """Member ``g``'s producer as one traceable fn: a ``lax.switch`` over the
    member list (``g`` may be a scan-carried tracer -- only the selected
    branch executes at runtime)."""
    branches = tuple((lambda i, j, f=f: f(i, j)) for f in block_fns)
    return lambda i, j: jax.lax.switch(g, branches, i, j)


def grouped_streamed_program_blocks(
    block_fns: Tuple[Callable, ...],
    keys: jax.Array,
    cfg: CrossbarConfig,
    mb: int,
    nb: int,
) -> jnp.ndarray:
    """Scan-program a group of streamed producers in one pipeline.

    One ``lax.map`` over members, each running the scan-fused
    :func:`streamed_program_blocks` sweep with its own producer (selected by
    ``lax.switch`` on the member index) and its own key schedule -- member
    ``g``'s image is bit-identical to its solo streamed program.  Returns
    (g, mb, nb, cap_m, cap_n).
    """
    def one(ops):
        g, k = ops
        return streamed_program_blocks(
            _switched_producer(block_fns, g), k, cfg, mb, nb)

    return jax.lax.map(one, (jnp.arange(len(block_fns)), keys))


def grouped_streamed_block_mvm(
    block_fns: Tuple[Callable, ...],
    at_blocks: jnp.ndarray,
    xb: jnp.ndarray,
    keys: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    use_kernel: bool = False,
    tier2: bool = True,
) -> jnp.ndarray:
    """Grouped streamed execute: every member's scan-fused MVM in one
    pipeline (dA re-derived per block from each member's own producer).

    ``at_blocks`` is the (g, mb, nb, cap_m, cap_n) stacked resident image,
    ``xb`` (g, n, batch).  Member ``g`` reproduces :func:`streamed_block_mvm`
    under ``keys[g]`` exactly.  Returns (g, m, batch).
    """
    def one(ops):
        g, at, x, k = ops
        return streamed_block_mvm(
            _switched_producer(block_fns, g), at, x, k, cfg, m=m, n=n,
            use_kernel=use_kernel, tier2=tier2)

    return jax.lax.map(one, (jnp.arange(len(block_fns)), at_blocks, xb, keys))


def grouped_streamed_block_rmvm(
    block_fns: Tuple[Callable, ...],
    at_blocks: jnp.ndarray,
    yb: jnp.ndarray,
    keys: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    use_kernel: bool = False,
    tier2: bool = True,
) -> jnp.ndarray:
    """Grouped streamed TRANSPOSED execute: the :func:`streamed_block_rmvm`
    mirror of :func:`grouped_streamed_block_mvm` (``yb`` (g, m, batch) ->
    (g, n, batch), same per-block k_x halves per member as forward)."""
    def one(ops):
        g, at, y, k = ops
        return streamed_block_rmvm(
            _switched_producer(block_fns, g), at, y, k, cfg, m=m, n=n,
            use_kernel=use_kernel, tier2=tier2)

    return jax.lax.map(one, (jnp.arange(len(block_fns)), at_blocks, yb, keys))


# --------------------------------------------------------------------------- #
# Scan-fused streamed stages (single-dispatch pipelines over a block producer)
# --------------------------------------------------------------------------- #
#
# The streamed execution mode consumes a *traceable* block producer
# ``block_fn(i, j) -> (cap_m, cap_n) block``: a pure jax function of the two
# block-index scalars (which may be tracers).  That protocol lets the whole
# mb x nb block sweep trace into ONE ``lax.scan`` program -- one device
# dispatch per program / per MVM -- instead of the O(mb * nb) host->device
# launches of a Python double loop.  Opaque Python producers (``int(i)``
# indexing, file reads, ...) cannot trace; :class:`repro.engine.AnalogEngine`
# keeps a compatibility host loop for those.
#
# All three functions below are pure jax (jit/vmap/scan-safe); the engine owns
# the jit caching (``block_fn`` is a static argument there).


def producer_is_traceable(block_fn, cap_m: int, cap_n: int) -> bool:
    """True when ``block_fn(i, j)`` abstractly traces to a (cap_m, cap_n)
    block from two int32 scalars (the traceable-producer protocol).

    An explicit ``block_fn.traceable`` attribute short-circuits the probe
    (``False`` forces the host loop, e.g. for producers whose trace would be
    valid but unwanted).  The probe itself is one ``jax.eval_shape`` -- no
    FLOPs, no device dispatch.
    """
    forced = getattr(block_fn, "traceable", None)
    if forced is not None:
        return bool(forced)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    try:
        out = jax.eval_shape(block_fn, idx, idx)
    except Exception:
        return False
    return getattr(out, "shape", None) == (cap_m, cap_n)


def produce_blocks(block_fn: Callable[[jax.Array, jax.Array], jnp.ndarray],
                   mb: int, nb: int) -> jnp.ndarray:
    """Materialize all (mb, nb) producer blocks with one two-level scan.

    Returns (mb, nb, cap_m, cap_n).  One traced call of ``block_fn`` instead
    of mb * nb host invocations -- the single-dispatch path behind the
    streamed ``AnalogMatrix.da`` / ``dense()`` views.
    """
    def row_step(_, i):
        def col_step(_, j):
            return None, block_fn(i, j)
        _, row = jax.lax.scan(col_step, None, jnp.arange(nb))
        return None, row

    _, blocks = jax.lax.scan(row_step, None, jnp.arange(mb))
    return blocks


def streamed_program_blocks(
    block_fn: Callable[[jax.Array, jax.Array], jnp.ndarray],
    key: jax.Array,
    cfg: CrossbarConfig,
    mb: int,
    nb: int,
    *,
    block_offset=(0, 0),
    grid: Optional[Tuple[int, int]] = None,
) -> jnp.ndarray:
    """Scan-fused program stage over a traceable producer.

    One ``lax.scan`` over the block-index grid encodes every capacity block
    (same per-block keys and draws as :func:`program_blocks`: the k_a half of
    ``block_keys(key, mb, nb)``), so programming a streamed handle is a single
    device dispatch.  Returns ``at_blocks`` (mb, nb, cap_m, cap_n); the tier-1
    operand dA is intentionally NOT returned -- streamed handles re-derive it
    from the producer at execute time so the source matrix is never resident
    twice.

    ``grid=(MB, NB)`` / ``block_offset=(i0, j0)`` program only the local
    (mb, nb) window of a larger global block grid: the producer is called with
    GLOBAL block indices and the per-block keys come from the global
    :func:`block_keys` schedule (see :func:`local_block_keys`), so a
    mesh-sharded program writes exactly the same conductance image, block for
    block, as the single-device sweep.  The offsets may be traced scalars
    (``jax.lax.axis_index`` inside shard_map).
    """
    i0, j0 = block_offset
    keys = local_block_keys(key, mb, nb, i0, j0, grid)

    def row_step(_, row_xs):
        row_keys, i = row_xs

        def col_step(_, col_xs):
            k, j = col_xs
            k_a, _k_x = jax.random.split(k)
            return None, encode_tiled(block_fn(i, j), k_a, cfg)

        _, at_row = jax.lax.scan(col_step, None, (row_keys, j0 + jnp.arange(nb)))
        return None, at_row

    _, at_blocks = jax.lax.scan(row_step, None, (keys, i0 + jnp.arange(mb)))
    return at_blocks


def streamed_block_mvm(
    block_fn: Callable[[jax.Array, jax.Array], jnp.ndarray],
    at_blocks: Optional[jnp.ndarray],
    xb: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    use_kernel: bool = False,
    tier2: bool = True,
    block_offset=(0, 0),
    grid: Optional[Tuple[int, int]] = None,
) -> jnp.ndarray:
    """Scan-fused execute stage over a streamed block producer.

    One ``lax.scan`` over row blocks (inner scan over column blocks with
    in-place fp32 row accumulation) replaces the per-block host loop: the
    input-DAC encode, the per-block ``dA = block_fn(i, j) - at_blocks[i, j]``
    re-derivation, the tier-1 EC product (``use_kernel=True`` fuses it into
    the Pallas :func:`repro.kernels.rram_ec_matmul` tile step) and the partial
    reduction all live inside one traced program -- one device dispatch per
    MVM.  Key/draw schedule matches :func:`programmed_block_mvm` exactly (the
    k_x half of the per-block key).  ``xb`` is (n, batch); returns (m, batch).

    ``at_blocks`` is normally the resident programmed image from
    :func:`streamed_program_blocks` (the engine's execute-many path).
    ``at_blocks=None`` selects the *one-shot* variant: each block is encoded
    inside the scan body (consuming the k_a key half, identical draws to
    program-then-execute) and immediately consumed, so no programmed image is
    ever resident -- O(one block) memory, the dataflow of the deprecated
    :func:`streamed_corrected_mvm` shim at paper scale.

    ``grid`` / ``block_offset`` select a local window of a global block grid
    exactly as in :func:`streamed_program_blocks` (global producer indices,
    global key schedule); ``m``/``n``/``xb`` are then the LOCAL row/column
    footprint of that window -- the shard_map per-device view.  Column-partial
    psums and tier-2 denoise stay with the caller (``tier2=False``).
    """
    i0, j0 = block_offset
    oneshot = at_blocks is None
    if oneshot:
        cap_m, cap_n = cfg.geom.capacity
        mb, nb = -(-m // cap_m), -(-n // cap_n)
    else:
        mb, nb, cap_m, cap_n = at_blocks.shape
    batch = xb.shape[1]
    if cfg.ec and cfg.ec_mode not in ("fused", "faithful"):
        raise ValueError(f"unknown first-order EC mode {cfg.ec_mode!r}")
    x_pad = jnp.pad(xb, ((0, nb * cap_n - n), (0, 0)))
    x_chunks = x_pad.reshape(nb, cap_n, batch)
    keys = local_block_keys(key, mb, nb, i0, j0, grid)

    def row_step(_, row_xs):
        if oneshot:
            row_keys, i = row_xs
        else:
            at_row, row_keys, i = row_xs

        def col_step(acc, col_xs):
            if oneshot:
                k, j, x_blk = col_xs
                a_blk = block_fn(i, j)
                k_a, k_x = jax.random.split(k)
                at_blk = encode_tiled(a_blk, k_a, cfg)
            else:
                at_blk, k, j, x_blk = col_xs
                _k_a, k_x = jax.random.split(k)
                a_blk = block_fn(i, j) if cfg.ec else None
            x_t = _encode_vec(x_blk, k_x, cfg) if cfg.encode_inputs else x_blk
            if not cfg.ec:
                return acc + at_blk @ x_t, None
            if use_kernel:
                from repro.kernels import ops as kops
                return acc + kops.rram_ec_tile_mvm(
                    x_blk, x_t, at_blk, a_blk - at_blk), None
            if cfg.ec_mode == "faithful":
                return acc + (at_blk @ x_blk + a_blk @ x_t
                              - at_blk @ x_t), None
            return acc + (at_blk @ x_blk + (a_blk - at_blk) @ x_t), None

        acc0 = jnp.zeros((cap_m, batch), jnp.float32)
        col_xs = (row_keys, j0 + jnp.arange(nb), x_chunks) if oneshot else \
            (at_row, row_keys, j0 + jnp.arange(nb), x_chunks)
        acc, _ = jax.lax.scan(col_step, acc0, col_xs)
        return None, acc

    row_xs = (keys, i0 + jnp.arange(mb)) if oneshot else \
        (at_blocks, keys, i0 + jnp.arange(mb))
    _, rows = jax.lax.scan(row_step, None, row_xs)
    p = rows.reshape(mb * cap_m, batch)[:m]
    if cfg.ec and tier2:
        p = denoise_least_square(p, lam=cfg.lam, h=cfg.h,
                                 method=cfg.denoise_method)
    return p


def streamed_block_rmvm(
    block_fn: Callable[[jax.Array, jax.Array], jnp.ndarray],
    at_blocks: Optional[jnp.ndarray],
    yb: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
    *,
    m: int,
    n: int,
    use_kernel: bool = False,
    tier2: bool = True,
    block_offset=(0, 0),
    grid: Optional[Tuple[int, int]] = None,
) -> jnp.ndarray:
    """Scan-fused TRANSPOSED execute stage over a streamed block producer.

    The mirror of :func:`streamed_block_mvm` for ``A.T @ y``: one ``lax.scan``
    over COLUMN blocks (inner scan over row blocks -- the contraction axis of
    A^T -- with in-place fp32 accumulation) fuses the input-DAC encode of the
    row-chunked y, the per-block ``dA`` re-derivation, the transposed tier-1
    EC product (``use_kernel=True`` fuses the Pallas
    :func:`repro.kernels.ops.rram_ec_tile_rmvm` tile step) and the partial
    reduction into one traced program -- ONE device dispatch per transposed
    MVM.  Key/draw schedule matches :func:`programmed_block_rmvm` exactly
    (block (i, j) consumes the same k_x half it would in a forward
    execution).  ``yb`` is (m, batch); returns (n, batch).

    ``at_blocks=None`` selects the one-shot variant (each block re-encoded
    inside the scan with the k_a half -- draws identical to
    program-then-execute, O(one block) memory); ``grid``/``block_offset``
    select a local window of a global block grid exactly as in
    :func:`streamed_block_mvm` (``m``/``n``/``yb`` are then the LOCAL
    footprint; row-partial psums and tier-2 stay with the caller).
    """
    i0, j0 = block_offset
    oneshot = at_blocks is None
    if oneshot:
        cap_m, cap_n = cfg.geom.capacity
        mb, nb = -(-m // cap_m), -(-n // cap_n)
    else:
        mb, nb, cap_m, cap_n = at_blocks.shape
    batch = yb.shape[1]
    if cfg.ec and cfg.ec_mode not in ("fused", "faithful"):
        raise ValueError(f"unknown first-order EC mode {cfg.ec_mode!r}")
    y_pad = jnp.pad(yb, ((0, mb * cap_m - m), (0, 0)))
    y_chunks = y_pad.reshape(mb, cap_m, batch)
    # Column-major sweep over the SAME (mb, nb) key schedule: block (i, j)
    # keeps its global key whichever direction the grid is traversed.
    keys_t = jnp.swapaxes(local_block_keys(key, mb, nb, i0, j0, grid), 0, 1)
    at_t = None if oneshot else jnp.swapaxes(at_blocks, 0, 1)

    def col_step(_, col_xs):
        if oneshot:
            col_keys, j = col_xs
        else:
            at_col, col_keys, j = col_xs

        def row_step(acc, row_xs):
            if oneshot:
                k, i, y_blk = row_xs
                a_blk = block_fn(i, j)
                k_a, k_x = jax.random.split(k)
                at_blk = encode_tiled(a_blk, k_a, cfg)
            else:
                at_blk, k, i, y_blk = row_xs
                _k_a, k_x = jax.random.split(k)
                a_blk = block_fn(i, j) if cfg.ec else None
            y_t = _encode_vec(y_blk, k_x, cfg) if cfg.encode_inputs else y_blk
            if not cfg.ec:
                return acc + at_blk.T @ y_t, None
            if use_kernel:
                from repro.kernels import ops as kops
                return acc + kops.rram_ec_tile_rmvm(
                    y_blk, y_t, at_blk, a_blk - at_blk), None
            if cfg.ec_mode == "faithful":
                return acc + (at_blk.T @ y_blk + a_blk.T @ y_t
                              - at_blk.T @ y_t), None
            return acc + (at_blk.T @ y_blk + (a_blk - at_blk).T @ y_t), None

        acc0 = jnp.zeros((cap_n, batch), jnp.float32)
        row_xs = (col_keys, i0 + jnp.arange(mb), y_chunks) if oneshot else \
            (at_col, col_keys, i0 + jnp.arange(mb), y_chunks)
        acc, _ = jax.lax.scan(row_step, acc0, row_xs)
        return None, acc

    col_xs = (keys_t, j0 + jnp.arange(nb)) if oneshot else \
        (at_t, keys_t, j0 + jnp.arange(nb))
    _, cols = jax.lax.scan(col_step, None, col_xs)
    p = cols.reshape(nb * cap_n, batch)[:n]
    if cfg.ec and tier2:
        p = denoise_least_square(p, lam=cfg.lam, h=cfg.h,
                                 method=cfg.denoise_method)
    return p


# --------------------------------------------------------------------------- #
# Legacy one-shot entry points (deprecated shims over the two-stage dataflow)
# --------------------------------------------------------------------------- #

def corrected_mvm(
    a: jnp.ndarray,
    x: jnp.ndarray,
    key: jax.Array,
    cfg: CrossbarConfig,
) -> Tuple[jnp.ndarray, WriteStats]:
    """y ~= A @ x on the simulated multi-MCA system (paper Algorithm 6 + 4).

    .. deprecated:: use :class:`repro.engine.AnalogEngine` -- this one-shot
       form re-programs the full matrix on every call.  It remains as a shim
       over the program/execute stages for single-use MVMs and tests.

    ``x`` may be (n,) or (n, batch).  The matrix is padded, block-partitioned to
    the system capacity, each block is encoded with per-MCA scales and multiplied
    with tier-1 EC; column-block partials are summed; tier-2 denoising runs on
    the assembled local output (``denoise_scope=local`` in paper terms).
    """
    m, n = a.shape
    squeeze = x.ndim == 1
    xb = x[:, None] if squeeze else x
    at_blocks, da_blocks = program_blocks(a, key, cfg)
    p = programmed_block_mvm(at_blocks, da_blocks, xb, key, cfg, m=m, n=n)
    stats = write_cost(m, n, cfg, batch=xb.shape[1])
    return (p[:, 0] if squeeze else p), stats


def streamed_corrected_mvm(
    block_fn: Callable[[int, int], jnp.ndarray],
    x: jnp.ndarray,
    m: int,
    n: int,
    key: jax.Array,
    cfg: CrossbarConfig,
) -> Tuple[jnp.ndarray, WriteStats]:
    """Large-problem variant: ``A`` is produced block-by-block by ``block_fn(i, j)``
    (each block capacity-sized, already padded), so matrices such as the paper's
    65,025 x 65,025 case never materialize.

    .. deprecated:: use ``AnalogEngine(cfg, execution="streamed")`` -- this
       one-shot form discards the programmed tiles after a single MVM.  It is
       now a thin composition over the scan-fused pipeline: traceable
       producers run the one-shot :func:`streamed_block_mvm` variant (each
       block encoded inside the scan body and immediately consumed -- ONE
       device dispatch, O(one block) memory, so the 65,025^2 case still never
       materializes anything A-sized); opaque Python producers fall back to
       the engine's compatibility host loop (the one remaining Python block
       loop; note that path keeps the programmed image resident).  The
       per-block PRNG schedule follows the engine's ``block_keys`` split (k_a
       programs, k_x drives the input DAC), which replaces this shim's
       historical per-block ``fold_in(fold_in(key, i), j)`` draws --
       statistically identical, numerically different.
    """
    squeeze = x.ndim == 1
    xb = x[:, None] if squeeze else x
    cap_m, cap_n = cfg.geom.capacity
    if producer_is_traceable(block_fn, cap_m, cap_n):
        # Locally-scoped jit: the trace (and the producer closure it pins)
        # is garbage-collected with this call, not cached process-wide.
        run = jax.jit(partial(streamed_block_mvm, block_fn, None,
                              cfg=cfg, m=m, n=n))
        p = run(xb, key)
    else:
        from repro.engine import AnalogEngine   # deferred: engine imports us
        engine = AnalogEngine(cfg, execution="streamed")
        A = engine.program(block_fn, key, shape=(m, n))
        p = engine.mvm(A, xb, key=key)
    stats = write_cost(m, n, cfg, batch=xb.shape[1])
    return (p[:, 0] if squeeze else p), stats
