"""Benchmark matrices (paper section 2.2-2.3, Supplementary A).

The SuiteSparse collection is not available offline, so we provide surrogates
with the *published* dimensions and condition numbers (Supplementary Table 2).
`bcsstk02`-like matrices are built as Q diag(lambda) Q^T with a log-spaced
spectrum hitting the target kappa; `Iperturb` is the paper's slightly perturbed
identity.  For the strong-scaling sizes (up to 65,025^2) an *implicit* banded
generator produces capacity-sized blocks on demand so the matrix never
materializes (fed to ``AnalogEngine(cfg, execution="streamed")``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "make_spd_with_condition",
    "make_iperturb",
    "PAPER_MATRICES",
    "paper_matrix",
    "ImplicitBandedMatrix",
]


def make_spd_with_condition(n: int, kappa: float, seed: int = 0,
                            norm2: float = 1.0) -> np.ndarray:
    """Symmetric positive-definite n x n with condition number ~= kappa."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = np.geomspace(norm2 / kappa, norm2, n)
    return (q * lam) @ q.T


def make_iperturb(n: int, scale: float = 0.05, seed: int = 1) -> np.ndarray:
    """The paper's Iperturb: identity + small perturbation, kappa ~= 1.23."""
    rng = np.random.default_rng(seed)
    p = rng.standard_normal((n, n)) * scale / np.sqrt(n)
    a = np.eye(n) + 0.5 * (p + p.T)
    return a


# Supplementary Table 2: (dim, kappa, ||A||_2). Dubcova2's stats are not
# published ("*"); we reuse Dubcova1's conditioning as the surrogate target.
_PAPER_SPECS: Dict[str, Tuple[int, float, float]] = {
    "bcsstk02": (66, 4.324971e3, 1.822575e4),
    "wang2": (2903, 2.305543e4, 4.138078),
    "add32": (4960, 1.366769e2, 5.749318e-2),
    "c-38": (8127, 1.530683e4, 6.083484e2),
    "dubcova1": (16129, 9.971199, 4.796329),
    "helm3d01": (32226, 2.451897e5, 5.052177e-1),
    "dubcova2": (65025, 9.971199, 4.796329),
}
PAPER_MATRICES = dict(_PAPER_SPECS)


def paper_matrix(name: str, seed: int = 0) -> np.ndarray:
    """Materialize a surrogate of a published matrix (small/medium sizes)."""
    key = name.lower()
    if key == "iperturb":
        return make_iperturb(66)
    if key not in _PAPER_SPECS:
        raise KeyError(f"unknown paper matrix {name!r}")
    n, kappa, norm2 = _PAPER_SPECS[key]
    if n > 20000:
        raise ValueError(
            f"{name} ({n}^2) should not be materialized; use ImplicitBandedMatrix")
    return make_spd_with_condition(n, kappa, seed=seed, norm2=norm2)


@dataclasses.dataclass(frozen=True)
class ImplicitBandedMatrix:
    """Procedurally generated banded-plus-noise matrix for huge problems.

    A = diag_dominant band + seeded pseudo-random off-band texture, defined
    blockwise: ``block(i, j)`` returns the (cap_m x cap_n) block at block-index
    (i, j) without ever forming A.  Deterministic in (seed, i, j).

    ``block`` is a *traceable* producer in the engine's sense (pure jax
    function of the index scalars), so streamed programming and every
    streamed MVM against it fuse into single-dispatch ``lax.scan`` pipelines.
    """

    n: int
    cap_m: int
    cap_n: int
    seed: int = 0
    bandwidth: int = 8
    diag: float = 4.0

    def block(self, i: int, j: int) -> jnp.ndarray:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), i), j)
        blk = 0.05 * jax.random.normal(key, (self.cap_m, self.cap_n), jnp.float32)
        r0, c0 = i * self.cap_m, j * self.cap_n
        rows = r0 + jnp.arange(self.cap_m)[:, None]
        cols = c0 + jnp.arange(self.cap_n)[None, :]
        dist = jnp.abs(rows - cols)
        band = jnp.where(dist <= self.bandwidth,
                         1.0 / (1.0 + dist.astype(jnp.float32)), 0.0)
        blk = blk * (dist <= 3 * self.bandwidth) + band
        blk = blk + self.diag * (rows == cols)
        valid = (rows < self.n) & (cols < self.n)
        return jnp.where(valid, blk, 0.0)

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """Exact blockwise ground-truth A @ x (float64-accumulated on host)."""
        nb_m = -(-self.n // self.cap_m)
        nb_n = -(-self.n // self.cap_n)
        x_pad = jnp.pad(x, (0, nb_n * self.cap_n - self.n))
        xc = x_pad.reshape(nb_n, self.cap_n)
        out = []
        for i in range(nb_m):
            acc = jnp.zeros((self.cap_m,), jnp.float32)
            for j in range(nb_n):
                acc = acc + self.block(i, j) @ xc[j]
            out.append(acc)
        return jnp.concatenate(out)[: self.n]

    def rmatvec(self, y: jnp.ndarray) -> jnp.ndarray:
        """Exact blockwise ground-truth A.T @ y (the transposed-MVM oracle)."""
        nb_m = -(-self.n // self.cap_m)
        nb_n = -(-self.n // self.cap_n)
        y_pad = jnp.pad(y, (0, nb_m * self.cap_m - self.n))
        yc = y_pad.reshape(nb_m, self.cap_m)
        out = []
        for j in range(nb_n):
            acc = jnp.zeros((self.cap_n,), jnp.float32)
            for i in range(nb_m):
                acc = acc + self.block(i, j).T @ yc[i]
            out.append(acc)
        return jnp.concatenate(out)[: self.n]
