"""Version-compat shims for the jax API surface this repo spans.

The codebase targets current jax but must run on 0.4.x containers: a few
symbols moved between releases (``shard_map`` graduated from
``jax.experimental`` and renamed ``check_rep`` -> ``check_vma``, Pallas
renamed ``TPUCompilerParams``).  Import the moved symbols from here so call
sites stay version-agnostic.
"""
from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "axis_size", "pvary", "set_mesh"]

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """``jax.shard_map`` accepting either spelling of the replication check."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    elif "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)


def axis_size(axis_name):
    """Size of a mapped mesh axis; ``psum(1)`` predates ``jax.lax.axis_size``."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def pvary(x, axis_name):
    """Mark ``x`` device-varying over ``axis_name`` for shard_map's vma
    tracking; a no-op on jax versions without varying-manual-axes types."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_name)
    return x


def set_mesh(mesh):
    """Context manager binding the ambient mesh.

    ``jax.set_mesh`` on current jax; on jax < 0.5 the ``Mesh`` object itself
    is the context manager with the same effect.
    """
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
