"""Virtualization layer (paper Algorithms 3-4, 7-9).

Maps arbitrarily-sized matrices onto a fixed physical multi-MCA system:
an ``R x C`` tile of MCAs, each with ``r x c`` cells, so the physical capacity is
``(R*r) x (C*c)``.  Three cases (paper section 4.4):

  * ideal:      problem == capacity        -> direct mapping
  * non-ideal:  problem <  capacity        -> zeroPadding
  * large:      problem >  capacity        -> blockPartition + per-block mapping,
                each MCA is *reassigned* once per block (the paper's
                normalization factor for energy/latency in Fig. 5).

Everything here is shape arithmetic + reshapes; it is used both by the
pure-jnp reference crossbar simulation and by the Pallas kernel's grid layout
(where one kernel block == one MCA assignment).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax.numpy as jnp

__all__ = [
    "MCAGeometry",
    "zero_padding",
    "block_partition",
    "generate_mat_chunks",
    "generate_vec_chunks",
    "reassemble",
    "reassignment_count",
]


@dataclasses.dataclass(frozen=True)
class MCAGeometry:
    """Physical system: R x C tile of MCAs, each r x c cells."""

    tile_rows: int = 8      # R
    tile_cols: int = 8      # C
    cell_rows: int = 512    # r
    cell_cols: int = 512    # c

    @property
    def capacity(self) -> Tuple[int, int]:
        return (self.tile_rows * self.cell_rows, self.tile_cols * self.cell_cols)

    @property
    def n_mcas(self) -> int:
        return self.tile_rows * self.tile_cols

    @property
    def cells_per_mca(self) -> int:
        return self.cell_rows * self.cell_cols


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def zero_padding(a: jnp.ndarray, geom: MCAGeometry) -> jnp.ndarray:
    """Pad a (m, n) matrix or (n,) vector up to whole-block multiples (Alg. 7).

    Padding is to the next multiple of the *capacity* in each dim (so that a
    subsequent block partition tiles exactly)."""
    cap_m, cap_n = geom.capacity
    if a.ndim == 1:
        n = a.shape[0]
        return jnp.pad(a, (0, _ceil_to(n, cap_n) - n))
    m, n = a.shape
    return jnp.pad(a, ((0, _ceil_to(m, cap_m) - m), (0, _ceil_to(n, cap_n) - n)))


def block_partition(a: jnp.ndarray, geom: MCAGeometry) -> jnp.ndarray:
    """blockPartition (Alg. 3): split padded (M, N) into capacity-sized blocks.

    Returns an array of shape (mb, nb, cap_m, cap_n) -- blocks indexed [i, j].
    """
    cap_m, cap_n = geom.capacity
    a = zero_padding(a, geom)
    m, n = a.shape
    mb, nb = m // cap_m, n // cap_n
    return a.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)


def generate_mat_chunks(a: jnp.ndarray, geom: MCAGeometry) -> jnp.ndarray:
    """generateMatChunksSet (Alg. 8): blocks -> per-MCA chunks.

    Returns shape (mb, nb, R, C, r, c): block [i, j], MCA [p, q], cells [l, h].
    """
    blocks = block_partition(a, geom)  # (mb, nb, cap_m, cap_n)
    mb, nb, cap_m, cap_n = blocks.shape
    r_, c_ = geom.cell_rows, geom.cell_cols
    out = blocks.reshape(mb, nb, geom.tile_rows, r_, geom.tile_cols, c_)
    return out.transpose(0, 1, 2, 4, 3, 5)


def generate_vec_chunks(x: jnp.ndarray, geom: MCAGeometry) -> jnp.ndarray:
    """generateVecChunksSet (Alg. 9): x -> (nb, C, c) chunks matching columns."""
    x = zero_padding(x, geom)
    cap_n = geom.capacity[1]
    nb = x.shape[0] // cap_n
    return x.reshape(nb, geom.tile_cols, geom.cell_cols)


def reassemble(y_blocks: jnp.ndarray, m: int) -> jnp.ndarray:
    """Inverse of the row-wise partition for the output vector.

    ``y_blocks`` has shape (mb, cap_m) (column-block partials already summed);
    returns the first ``m`` entries of the concatenation."""
    return y_blocks.reshape(-1)[:m]


def reassignment_count(m: int, n: int, geom: MCAGeometry) -> int:
    """How many times each physical MCA is (re)assigned for an (m, n) problem --
    the paper's virtualization normalization factor."""
    cap_m, cap_n = geom.capacity
    return math.ceil(m / cap_m) * math.ceil(n / cap_n)
