"""Mamba-2 block (SSD), the backbone of zamba2.

Block: in_proj -> (z, x, B, C, dt); causal depthwise conv over (x,B,C); silu;
SSD recurrence y = SSD(C, B, x*dt; a = exp(-exp(A_log) dt)) + D*x; gated
rmsnorm with silu(z); out_proj.  n_groups = 1 (B/C shared across heads).

Projections are separate 2-D kernels (wz/wx/wB/wC/wdt) so the RRAM backend can
program each, and so TP sharding rules see clean (embed -> heads/state) axes.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import Runtime, dense, dense_spec, rmsnorm, rmsnorm_spec
from .linear_attention import chunked_ssd, ssd_decode_step
from .params import spec

__all__ = ["mamba_specs", "mamba_apply", "empty_state"]


def mamba_specs(cfg: ModelConfig) -> Dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    conv_ch = di + 2 * n
    return {
        "ln": rmsnorm_spec(d),
        "wz": dense_spec(d, di, axes=("embed", "heads")),
        "wx": dense_spec(d, di, axes=("embed", "heads")),
        "wB": dense_spec(d, n, axes=("embed", "state")),
        "wC": dense_spec(d, n, axes=("embed", "state")),
        "wdt": dense_spec(d, h, axes=("embed", "heads")),
        "conv_w": spec((cfg.d_conv, conv_ch), (None, "heads"), init="small", scale=0.1),
        "conv_b": spec((conv_ch,), ("heads",), init="zeros"),
        "dt_bias": spec((h,), ("heads",), init="small", scale=0.1),
        "A_log": spec((h,), ("heads",), init="small", scale=0.5),
        "D": spec((h,), ("heads",), init="ones"),
        "norm": {"scale": spec((di,), ("heads",), init="ones")},
        "out": dense_spec(di, d, axes=("heads", "embed")),
    }


def empty_state(b: int, cfg: ModelConfig, dtype) -> Dict:
    di, n, h, p_ = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_ch = di + 2 * n
    return {
        "conv": jnp.zeros((b, cfg.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((b, h, n, p_), jnp.float32),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                 conv_state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv along time.  xbc (B, T, C); w (K, C)."""
    kw = w.shape[0]
    pad = (conv_state if conv_state is not None
           else jnp.zeros((xbc.shape[0], kw - 1, xbc.shape[2]), xbc.dtype))
    xp = jnp.concatenate([pad, xbc], axis=1)              # (B, T+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None] for i in range(kw))
    new_state = xp[:, -(kw - 1):] if kw > 1 else pad[:, :0]
    return out + bias[None, None], new_state


def mamba_apply(p: Dict, x_in: jnp.ndarray, cfg: ModelConfig,
                rt: Optional[Runtime], state: Optional[Dict]
                ) -> Tuple[jnp.ndarray, Dict]:
    """x_in (B, T, D) -> (residual out, new state).  state None => zeros."""
    from .common import constrain_batch
    x_in = constrain_batch(x_in, rt)
    b, t, d = x_in.shape
    di, n, h, ph = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    st = state if state is not None else empty_state(b, cfg, x_in.dtype)

    u = rmsnorm(p["ln"], x_in, cfg.norm_eps)
    z = dense(p["wz"], u, rt)
    xr = dense(p["wx"], u, rt)
    br = dense(p["wB"], u, rt)
    cr = dense(p["wC"], u, rt)
    dt_raw = dense(p["wdt"], u, rt)

    xbc = jnp.concatenate([xr, br, cr], axis=-1)
    xbc, conv_new = _causal_conv(xbc, p["conv_w"].astype(xbc.dtype),
                                 p["conv_b"].astype(xbc.dtype), st["conv"])
    xbc = jax.nn.silu(xbc)
    xr, br, cr = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,T,H)
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt

    xh = xr.reshape(b, t, h, ph)
    v = xh * dt[..., None].astype(xh.dtype)
    q = jnp.broadcast_to(cr[:, :, None, :], (b, t, h, n))
    k = jnp.broadcast_to(br[:, :, None, :], (b, t, h, n))

    if t == 1:
        y1, ssm_new = ssd_decode_step(q[:, 0], k[:, 0], v[:, 0],
                                      log_a[:, 0], st["ssm"])
        y = y1[:, None]
    else:
        y, ssm_new = chunked_ssd(q, k, v, log_a, state0=st["ssm"],
                                 chunk=min(32, t))
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(b, t, di)
    y = rmsnorm({"scale": p["norm"]["scale"]}, y, cfg.norm_eps) * jax.nn.silu(z)
    out = dense(p["out"], y, rt)
    return x_in + out, {"conv": conv_new, "ssm": ssm_new}
