"""Decoder-only transformer LM (yi-9b, qwen3-1.7b/8b, nemotron-4-15b; also the
text backbone reused by whisper's decoder and llama-3.2-vision).

Scan-over-layers with stacked parameters (compact HLO, fast SPMD compiles,
remat-able).  Uniform model interface (all families implement this):

  init_specs(cfg)                          -> spec tree
  loss(params, batch, cfg, rt)             -> scalar CE
  prefill(params, batch, cfg, rt, max_len) -> (last_logits, caches)
  decode_step(params, tokens, caches, cfg, rt) -> (logits, caches)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import (
    Runtime, attention, attention_specs, constrain_batch, cross_entropy_loss,
    dense, embed_spec, init_kv_cache, mlp, mlp_specs, rmsnorm, rmsnorm_spec,
    unembed_spec,
)
from .params import stack_specs

__all__ = ["init_specs", "loss", "forward", "prefill", "decode_step",
           "layer_specs", "layer_apply"]


def layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln_attn": rmsnorm_spec(cfg.d_model),
        "attn": attention_specs(cfg),
        "ln_mlp": rmsnorm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def init_specs(cfg: ModelConfig) -> Dict:
    s = {
        "embed": embed_spec(cfg.vocab_pad, cfg.d_model),
        "layers": stack_specs(cfg.n_layers, layer_specs(cfg)),
        "ln_f": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = unembed_spec(cfg.d_model, cfg.vocab_pad)
    return s


def layer_apply(lp: Dict, x: jnp.ndarray, cfg: ModelConfig, rt: Runtime,
                positions, cache: Optional[Dict]) -> Tuple[jnp.ndarray, Optional[Dict]]:
    x = constrain_batch(x, rt)
    a, cache = attention(lp["attn"], rmsnorm(lp["ln_attn"], x, cfg.norm_eps),
                         cfg, rt, positions=positions, cache=cache)
    x = x + a
    x = x + mlp(lp["mlp"], rmsnorm(lp["ln_mlp"], x, cfg.norm_eps), cfg, rt)
    return x, cache


def _maybe_remat(fn, rt: Runtime):
    if getattr(rt, "remat", "none") in ("block", "full"):
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


def forward(params: Dict, tokens: jnp.ndarray, cfg: ModelConfig, rt: Runtime,
            positions=None, caches: Optional[Dict] = None
            ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """tokens (B, T) -> hidden (B, T, D); scans the stacked layers."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = constrain_batch(params["embed"].astype(cd)[tokens], rt)
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]

    if caches is None:
        def body(h, lp):
            h, _ = layer_apply(lp, h, cfg, rt, positions, None)
            return h, None
        x, _ = jax.lax.scan(_maybe_remat(body, rt), x, params["layers"])
        new_caches = None
    else:
        def body(h, xs):
            lp, cache = xs
            h, cache = layer_apply(lp, h, cfg, rt, positions, cache)
            return h, cache
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), new_caches


def logits_fn(params: Dict, hidden: jnp.ndarray, cfg: ModelConfig,
              rt: Runtime) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = hidden @ params["embed"].astype(hidden.dtype).T
    else:
        logits = dense(params["lm_head"], hidden, rt)
    if cfg.vocab_pad != cfg.vocab:
        # Padded vocab columns (sharding alignment) are masked out.
        col = jnp.arange(cfg.vocab_pad, dtype=jnp.int32)
        logits = jnp.where(col < cfg.vocab, logits, -1e30)
    return logits


def loss(params: Dict, batch: Dict, cfg: ModelConfig, rt: Runtime) -> jnp.ndarray:
    hidden, _ = forward(params, batch["tokens"], cfg, rt)
    logits = logits_fn(params, hidden, cfg, rt)
    return cross_entropy_loss(logits, batch["labels"])


def init_caches(batch: int, max_len: int, cfg: ModelConfig) -> Dict:
    cd = jnp.dtype(cfg.compute_dtype)
    one = init_kv_cache(batch, max_len, cfg, cd)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)


def prefill(params: Dict, batch: Dict, cfg: ModelConfig, rt: Runtime,
            max_len: int) -> Tuple[jnp.ndarray, Dict]:
    tokens = batch["tokens"]
    b, t = tokens.shape
    caches = init_caches(b, max_len, cfg)
    hidden, caches = forward(params, tokens, cfg, rt, caches=caches)
    logits = logits_fn(params, hidden[:, -1:], cfg, rt)
    return logits, caches


def decode_step(params: Dict, tokens: jnp.ndarray, caches: Dict,
                cfg: ModelConfig, rt: Runtime) -> Tuple[jnp.ndarray, Dict]:
    """tokens (B, 1) -> next-token logits (B, 1, V), appended caches."""
    cur = caches["len"][0]                       # scalar per layer (uniform)
    positions = jnp.broadcast_to(cur[None, None], tokens.shape).astype(jnp.int32)
    hidden, caches = forward(params, tokens, cfg, rt,
                             positions=positions, caches=caches)
    return logits_fn(params, hidden, cfg, rt), caches
