"""Programming a model's linear layers onto the RRAM analog backend.

``program_rram`` is a pytree walk of :meth:`repro.engine.AnalogEngine.program`:
every 2-D linear kernel named "w" is programmed once onto the engine and gains
two siblings extracted from the resulting :class:`~repro.engine.AnalogMatrix`:

  * ``w_tilde``: the encoded (quantized + programming-noise) conductance image,
    produced by per-(cell_rows x cell_cols)-tile encoding after ``k_iters``
    write-verify passes -- exactly :func:`repro.core.crossbar.encode_tiled`.
  * ``dw = w - w_tilde``: the tier-1 correction operand (stored in
    ``dw_dtype``; bf16 by default -- dw is O(sigma * w), so the beyond-paper
    compression costs ~sigma * 2^-8 relative error, measured in tests).

It also returns the aggregate :class:`WriteStats` for programming the whole
model -- the analog deployment's one-time write energy/latency (matrix writes
only: per-token input-DAC cost is an execution-time figure under the
program-once accounting), reported by the serve benchmarks.  ``program_specs``
is the shape-level twin used by the dry-run (no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RRAMBackendConfig
from repro.core.crossbar import CrossbarConfig, input_write_cost, \
    matrix_write_cost
from repro.core.devices import get_device
from repro.core.virtualization import MCAGeometry
from repro.core.write_verify import WriteStats
from repro.engine import AnalogEngine
from .params import is_spec, spec

__all__ = ["program_rram", "program_specs", "programming_dispatch_plan",
           "crossbar_cfg", "is_programmed", "strip_rram", "reprogram_rram",
           "analog_image_bytes", "programmed_kernel_shapes",
           "forward_input_stats"]


def crossbar_cfg(cfg: RRAMBackendConfig) -> CrossbarConfig:
    return CrossbarConfig(
        device=get_device(cfg.device),
        geom=MCAGeometry(tile_rows=1, tile_cols=1,
                         cell_rows=cfg.cell_rows, cell_cols=cfg.cell_cols),
        k_iters=cfg.k_iters, ec=cfg.ec, ec_mode=cfg.ec_mode,
        denoise_method=cfg.denoise_method, lam=cfg.lam,
        encode_inputs=cfg.encode_inputs,
    )


def program_rram(
    params: Any,
    cfg: RRAMBackendConfig,
    key: jax.Array,
    *,
    engine: Optional[AnalogEngine] = None,
    group: bool = True,
) -> Tuple[Any, WriteStats]:
    """Return (programmed params, total write stats).

    A pytree walk of the engine's programming stage: each kernel is written
    onto the analog engine exactly once; the dense ``w_tilde``/``dw``
    operands the layers consume are views of the programmed image.  Works on
    real or stacked (scan-over-layers) kernels: a kernel of shape
    (L, d_in, d_out) is encoded per layer (each layer maps onto its own set
    of MCA tiles).

    ``group=True`` (the default) programs all same-shape kernels of the walk
    as ONE grouped dispatch each (the :class:`~repro.engine.AnalogMatrixGroup`
    batching applied to programming): a whole model writes in
    O(distinct kernel shapes) device launches instead of O(kernels).  Each
    kernel keeps the exact per-kernel key of the ungrouped walk (fold
    ``counter`` of ``key``), so every draw is the same random variate under
    either setting; images agree to float32 rounding (~1e-7 -- XLA may
    reassociate the fused encode differently than the eager per-kernel
    path), and the dispatch count drops from O(kernels) to
    O(distinct shapes) (see :func:`programming_dispatch_plan`).
    """
    engine = engine or AnalogEngine(crossbar_cfg(cfg))
    ccfg = engine.cfg
    total = WriteStats.zero()
    counter = [0]
    jobs = []       # (slot dict, kernel, per-kernel key) in walk order

    def visit(tree):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for name, sub in tree.items():
            if name == "w" and hasattr(sub, "ndim") and sub.ndim in (2, 3):
                counter[0] += 1
                k = jax.random.fold_in(key, counter[0])
                out[name] = sub
                out["w_tilde"] = None
                out["dw"] = None
                jobs.append((out, sub, k))
            elif isinstance(sub, dict):
                out[name] = visit(sub)
            else:
                out[name] = sub
        return out

    tree = visit(params)

    def per_layer_stats(m, n, layers):
        per = matrix_write_cost(m, n, ccfg)
        return WriteStats(
            energy_j=per.energy_j * layers, latency_s=per.latency_s * layers,
            iterations=per.iterations, final_delta=per.final_delta)

    def fill(slot, sub, wt):
        slot["w_tilde"] = wt.astype(sub.dtype)
        slot["dw"] = (sub.astype(jnp.float32) - wt).astype(cfg.dw_dtype)

    if not group:
        for slot, sub, k in jobs:
            if sub.ndim == 2:
                handle = engine.program(sub.astype(jnp.float32), k)
                wt = handle.a_tilde
                total = total + handle.write_stats
            else:
                keys = jax.random.split(k, sub.shape[0])
                wt = jax.vmap(engine.encode_dense)(
                    sub.astype(jnp.float32), keys)
                total = total + per_layer_stats(sub.shape[1], sub.shape[2],
                                                sub.shape[0])
            fill(slot, sub, wt)
        return tree, total

    # Grouped programming: bucket the walk by (ndim, shape) and encode each
    # bucket's kernels as one stacked dispatch.  Stacked (L, m, n) kernels
    # keep their per-layer split keys, 2-D kernels their per-kernel fold --
    # member draws match the ungrouped walk exactly.
    buckets: Dict[Tuple, list] = {}
    for job in jobs:
        sub = job[1]
        buckets.setdefault((sub.ndim,) + tuple(sub.shape), []).append(job)
    for bkey, bjobs in buckets.items():   # insertion order == walk order
        stack = jnp.stack([j[1].astype(jnp.float32) for j in bjobs])
        if bkey[0] == 2:
            keys = jnp.stack([j[2] for j in bjobs])
            wts = jax.jit(jax.vmap(engine.encode_dense))(stack, keys)
            m, n = bkey[1:]
            total = total + per_layer_stats(m, n, len(bjobs))
        else:
            layers, m, n = bkey[1:]
            keys = jnp.stack([jax.random.split(j[2], layers) for j in bjobs])
            wts = jax.jit(jax.vmap(jax.vmap(engine.encode_dense)))(stack,
                                                                   keys)
            total = total + per_layer_stats(m, n, len(bjobs) * layers)
        for (slot, sub, _), wt in zip(bjobs, wts):
            fill(slot, sub, wt)
    return tree, total


def programming_dispatch_plan(params: Any) -> Dict[str, int]:
    """Dispatch accounting of one :func:`program_rram` walk over ``params``.

    ``kernels`` is how many programmed kernels the walk visits (the ungrouped
    dispatch count); ``groups`` how many distinct (ndim, shape) buckets they
    collapse into (the grouped dispatch count).  Pure shape math -- works on
    programmed or digital trees."""
    shapes = []

    def visit(tree):
        if isinstance(tree, dict):
            for name, sub in tree.items():
                if name == "w" and hasattr(sub, "ndim") and \
                        sub.ndim in (2, 3):
                    shapes.append((sub.ndim,) + tuple(sub.shape))
                elif isinstance(sub, dict):
                    visit(sub)

    visit(params)
    return {"kernels": len(shapes), "groups": len(set(shapes))}


def is_programmed(params: Any) -> bool:
    """True iff the pytree already carries analog images (``w_tilde``)."""
    found = [False]

    def visit(tree):
        if isinstance(tree, dict):
            if "w_tilde" in tree:
                found[0] = True
            for sub in tree.values():
                visit(sub)

    visit(params)
    return found[0]


def strip_rram(params: Any) -> Any:
    """Drop every ``w_tilde``/``dw`` sibling, returning digital-only params."""

    def visit(tree):
        if not isinstance(tree, dict):
            return tree
        return {name: visit(sub) for name, sub in tree.items()
                if name not in ("w_tilde", "dw")}

    return visit(params)


def reprogram_rram(
    params: Any,
    cfg: RRAMBackendConfig,
    key: jax.Array,
    *,
    engine: Optional[AnalogEngine] = None,
) -> Tuple[Any, WriteStats]:
    """Program a (possibly already-programmed) pytree under a fresh key.

    The per-tenant entry point for the serving image cache: the same digital
    weights programmed under two different keys produce independent device
    draws (independent ``w_tilde`` noise), and every reprogram is billed the
    full one-time matrix :class:`WriteStats` again -- this is the cost a
    write-cost-aware eviction policy is trying not to pay twice."""
    return program_rram(strip_rram(params), cfg, key, engine=engine)


def analog_image_bytes(params: Any) -> int:
    """Resident bytes of the programmed analog operands (w_tilde + dw).

    The serving cache's capacity accounting: what it costs to *keep* a
    tenant's image programmed, as opposed to the :class:`WriteStats` energy
    it costs to *create* it."""
    total = [0]

    def visit(tree):
        if isinstance(tree, dict):
            for name, sub in tree.items():
                if name in ("w_tilde", "dw") and hasattr(sub, "nbytes"):
                    total[0] += int(sub.nbytes)
                else:
                    visit(sub)

    visit(params)
    return total[0]


def programmed_kernel_shapes(params: Any) -> Tuple[Tuple[int, int, int], ...]:
    """(layers, d_in, d_out) of every programmed kernel (layers=1 if 2-D)."""
    out = []

    def visit(tree):
        if isinstance(tree, dict):
            for name, sub in tree.items():
                if name == "w_tilde" and hasattr(sub, "ndim"):
                    if sub.ndim == 2:
                        out.append((1, sub.shape[0], sub.shape[1]))
                    else:
                        out.append(tuple(int(d) for d in sub.shape))
                else:
                    visit(sub)

    visit(params)
    return tuple(out)


def forward_input_stats(params: Any, cfg: RRAMBackendConfig,
                        batch: int = 1) -> WriteStats:
    """Per-forward-pass input-DAC cost through every programmed kernel.

    One token position through ``dense(x, w)`` is one corrected MVM against
    the analog operator A = w^T of shape (d_out, d_in); a forward pass with
    ``batch`` positions therefore pays ``input_write_cost(d_out, d_in,
    batch=batch)`` per layer.  This is the per-MVM side of the
    ``SolveLedger`` split -- the marginal energy/latency of one decode step
    (``batch=B``) or one prefill (``batch=B*T``) once the image is resident.
    """
    ccfg = crossbar_cfg(cfg)
    total = WriteStats.zero()
    for layers, d_in, d_out in programmed_kernel_shapes(params):
        per = input_write_cost(d_out, d_in, ccfg, batch=batch)
        total = total + WriteStats(
            energy_j=per.energy_j * layers, latency_s=per.latency_s * layers,
            iterations=per.iterations, final_delta=per.final_delta)
    return total


def program_specs(specs: Any, cfg: RRAMBackendConfig) -> Any:
    """Spec-tree twin of :func:`program_rram` for dry-runs: adds w_tilde/dw
    ParamSpecs with the same shapes/logical axes as each kernel."""

    def visit(tree):
        if not isinstance(tree, dict):
            return tree
        out = {}
        for name, sub in tree.items():
            if name == "w" and is_spec(sub) and len(sub.shape) in (2, 3):
                out[name] = sub
                out["w_tilde"] = spec(sub.shape, sub.axes, init="zeros",
                                      dtype=sub.dtype)
                out["dw"] = spec(sub.shape, sub.axes, init="zeros",
                                 dtype=cfg.dw_dtype)
            elif isinstance(sub, dict):
                out[name] = visit(sub)
            else:
                out[name] = sub
        return out

    return visit(specs)
