"""Model zoo: all families share the interface
init_specs/loss/prefill/decode_step (see transformer.py docstring)."""
