"""Parameter-spec machinery (flax is not installed; this is the light-weight
pytree convention the whole framework uses).

A model is described once as a *spec tree*: nested dicts whose leaves are
:class:`ParamSpec` (shape + logical sharding axes + initializer).  From the
spec tree we derive everything else:

  * ``materialize(specs, key, dtype)``      -> real parameter pytree
  * ``abstract(specs, dtype)``              -> ShapeDtypeStruct pytree (dry-run!)
  * ``logical_axes(specs)``                 -> pytree of logical-axis tuples
  * sharding: distributed/sharding.py maps logical axes -> mesh PartitionSpecs

Logical axis vocabulary (mapped to mesh axes by rule tables):
  "embed"    - d_model
  "mlp"      - feed-forward hidden
  "heads"    - attention query heads
  "kv_heads" - attention kv heads
  "head_dim" - per-head feature dim
  "vocab"    - vocabulary
  "expert"   - MoE experts
  "state"    - SSM/WKV state channels
  "layer"    - stacked scan-over-layers leading axis (never sharded)
  None       - replicated dim
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec",
    "spec",
    "materialize",
    "abstract",
    "logical_axes",
    "is_spec",
    "tree_paths",
]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: Optional[float] = None  # overrides the default fan-in scale
    dtype: Any = None              # overrides the materialize dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="normal", scale=None, dtype=None) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(s: ParamSpec, key: jax.Array, dtype) -> jnp.ndarray:
    dt = s.dtype or dtype
    if s.init == "zeros":
        return jnp.zeros(s.shape, dt)
    if s.init == "ones":
        return jnp.ones(s.shape, dt)
    if s.init == "embed":
        sc = s.scale if s.scale is not None else 1.0
        return (jax.random.normal(key, s.shape, jnp.float32) * sc).astype(dt)
    if s.init == "small":
        sc = s.scale if s.scale is not None else 0.02
        return (jax.random.normal(key, s.shape, jnp.float32) * sc).astype(dt)
    # default: truncated-normal fan-in scaling on the contraction dim(s):
    # convention -- the LAST axis is the output dim, everything else is fan-in,
    # except stacked-layer ("layer") and expert ("expert") leading axes.
    dims = [d for d, a in zip(s.shape, s.axes) if a not in ("layer", "expert")]
    fan_in = max(1, int(np.prod(dims[:-1])) if len(dims) > 1 else
                 (dims[0] if dims else 1))
    sc = s.scale if s.scale is not None else 1.0 / math.sqrt(fan_in)
    w = jax.random.truncated_normal(key, -2.0, 2.0, s.shape, jnp.float32) * sc
    return w.astype(dt)


def materialize(specs, key: jax.Array, dtype=jnp.float32):
    """Instantiate real parameters from a spec tree (deterministic in key)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract(specs, dtype=jnp.float32):
    """ShapeDtypeStruct pytree -- parameters that are never allocated."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        specs, is_leaf=is_spec)


def logical_axes(specs):
    """Pytree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def tree_paths(tree, is_leaf=None):
    """[(path_string, leaf)] for debugging and checkpoint manifests."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    return [(jax.tree_util.keystr(p), v) for p, v in flat]


def stack_specs(n: int, layer_specs):
    """Prepend an (n,)-sized "layer" axis to every spec (scan-over-layers)."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layer",) + s.axes,
                            s.init, s.scale, s.dtype),
        layer_specs, is_leaf=is_spec)
