"""Chunked linear-attention recurrences for RWKV-6 (per-channel data-dependent
decay) and Mamba-2 SSD (per-head scalar decay).

Both are the same algebra:  S_t = D_t . S_{t-1} + k_t v_t^T,  o_t = q_t^T S_*,
with D diagonal.  A naive time-scan is O(T) sequential elementwise work that
starves the MXU; the chunked form turns everything into (c x c) / (c x D)
matmuls with one inter-chunk scan of length T/c -- the standard SSD/FLA
factorization, TPU-native.

Numerics: the separable intra-chunk form uses exp(+-cumlog decay); per-token
log-decay is clamped to [LOG_CLAMP, -1e-6] (LOG_CLAMP = -1.5) so the within-
chunk exponentials stay inside fp32 range for chunk <= 64.  This bounds the
fastest representable decay to exp(-1.5) ~ 0.22/token -- a documented modeling
deviation (DESIGN.md section 9) that only binds for very-fast-decay channels.

Shapes: q/k (B, T, H, Dk), v (B, T, H, Dv), state (B, H, Dk, Dv).
RWKV: o_t uses S_{t-1} plus a (u . k_t) v_t bonus;  SSD: o_t uses S_t.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["chunked_wkv", "chunked_ssd", "wkv_decode_step", "ssd_decode_step"]

LOG_CLAMP = -1.5


def _chunk(x: jnp.ndarray, c: int) -> jnp.ndarray:
    b, t = x.shape[:2]
    return x.reshape((b, t // c, c) + x.shape[2:])


def chunked_wkv(
    r: jnp.ndarray,            # (B, T, H, Dk) receptance (query)
    k: jnp.ndarray,            # (B, T, H, Dk)
    v: jnp.ndarray,            # (B, T, H, Dv)
    log_w: jnp.ndarray,        # (B, T, H, Dk) per-channel log decay (<= 0)
    u: jnp.ndarray,            # (H, Dk) current-token bonus
    state0: Optional[jnp.ndarray] = None,   # (B, H, Dk, Dv)
    chunk: int = 32,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV-6 WKV. Returns (out (B, T, H, Dv), final_state)."""
    b, t, h, dk = r.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    c = chunk
    f32 = jnp.float32

    lw = jnp.clip(log_w.astype(f32), LOG_CLAMP, -1e-6)
    rc = _chunk(r.astype(f32), c)     # (B, NC, c, H, Dk)
    kc = _chunk(k.astype(f32), c)
    vc = _chunk(v.astype(f32), c)
    lwc = _chunk(lw, c)

    cum = jnp.cumsum(lwc, axis=2)                 # B_tau inclusive
    cum_prev = cum - lwc                          # B_{tau-1}
    total = cum[:, :, -1]                         # (B, NC, H, Dk)

    r_in = rc * jnp.exp(cum_prev)                 # decay from chunk start
    k_out = kc * jnp.exp(-cum)                    # inverse decay
    k_end = kc * jnp.exp(total[:, :, None] - cum)  # decay to chunk end

    # Intra-chunk scores: A[tau, s] = sum_d r'_tau k'_s, strictly lower-tri.
    scores = jnp.einsum("bnchd,bnshd->bnhcs", r_in, k_out)
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    # Bonus diagonal (current token): r_tau . (u * k_tau).
    bonus = jnp.einsum("bnchd,hd,bnchd->bnhc", rc, u.astype(f32), kc)
    out_intra = jnp.einsum("bnhcs,bnshp->bnchp", scores, vc)
    out_intra += bonus[..., None].transpose(0, 1, 3, 2, 4) * vc

    # Inter-chunk: o_tau += (r_tau * exp(cum_prev))^T S_start; scan over chunks.
    kv_end = jnp.einsum("bnchd,bnchp->bnhdp", k_end, vc)   # chunk state delta

    def step(S, xs):
        r_in_n, kv_n, tot_n = xs            # (B, c, H, Dk), (B, H, Dk, Dv), (B, H, Dk)
        o = jnp.einsum("bchd,bhdp->bchp", r_in_n, S)
        S = S * jnp.exp(tot_n)[..., None] + kv_n
        return S, o

    s0 = (jnp.zeros((b, h, dk, dv), f32) if state0 is None
          else state0.astype(f32))
    xs = (r_in.transpose(1, 0, 2, 3, 4), kv_end.transpose(1, 0, 2, 3, 4),
          total.transpose(1, 0, 2, 3))
    s_fin, o_inter = jax.lax.scan(step, s0, xs)
    o_inter = o_inter.transpose(1, 0, 2, 3, 4)             # (B, NC, c, H, Dv)

    out = (out_intra + o_inter).reshape(b, t, h, dv)
    return out.astype(r.dtype), s_fin


def wkv_decode_step(r, k, v, log_w, u, state):
    """Single-token RWKV-6 step. r/k/v/log_w: (B, H, D*); state (B, H, Dk, Dv)."""
    f32 = jnp.float32
    rf, kf, vf = r.astype(f32), k.astype(f32), v.astype(f32)
    lw = jnp.clip(log_w.astype(f32), LOG_CLAMP, -1e-6)
    att = state + (u.astype(f32)[None] * kf)[..., None] * vf[..., None, :]
    out = jnp.einsum("bhd,bhdp->bhp", rf, att)
    state = state * jnp.exp(lw)[..., None] + kf[..., None] * vf[..., None, :]
    return out.astype(r.dtype), state


def chunked_ssd(
    q: jnp.ndarray,            # (B, T, H, N)  (mamba2 C)
    k: jnp.ndarray,            # (B, T, H, N)  (mamba2 B)
    v: jnp.ndarray,            # (B, T, H, P)  (mamba2 x * dt)
    log_a: jnp.ndarray,        # (B, T, H) per-head scalar log decay (<= 0)
    state0: Optional[jnp.ndarray] = None,   # (B, H, N, P)
    chunk: int = 32,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba-2 SSD. o_t includes the current token. Returns (out, final_state)."""
    b, t, h, n = q.shape
    p = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    c = chunk
    f32 = jnp.float32

    la = jnp.clip(log_a.astype(f32), LOG_CLAMP, -1e-9)
    qc = _chunk(q.astype(f32), c)
    kc = _chunk(k.astype(f32), c)
    vc = _chunk(v.astype(f32), c)
    lac = _chunk(la, c)

    cum = jnp.cumsum(lac, axis=2)                  # (B, NC, c, H) inclusive
    total = cum[:, :, -1]

    # Separable inclusive intra decay: exp(L_tau - L_s) = exp(L_tau) exp(-L_s).
    # With per-token log decay clamped to >= LOG_CLAMP and c <= 64 the
    # exponentials stay within fp32 range (|exponent| <= 96).
    q_dec = qc * jnp.exp(cum)[..., None]
    k_inv = kc * jnp.exp(-cum)[..., None]
    scores = jnp.einsum("bnchd,bnshd->bnhcs", q_dec, k_inv)
    tri = jnp.tril(jnp.ones((c, c), bool))         # inclusive of diagonal
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    out_intra = jnp.einsum("bnhcs,bnshp->bnchp", scores, vc)

    k_end = kc * jnp.exp(total[:, :, None] - cum)[..., None]
    kv_end = jnp.einsum("bnchd,bnchp->bnhdp", k_end, vc)
    q_in = qc * jnp.exp(cum)[..., None]

    def step(S, xs):
        q_n, kv_n, tot_n = xs
        o = jnp.einsum("bchd,bhdp->bchp", q_n, S)
        S = S * jnp.exp(tot_n)[:, :, None, None] + kv_n
        return S, o

    s0 = (jnp.zeros((b, h, n, p), f32) if state0 is None else state0.astype(f32))
    xs = (q_in.transpose(1, 0, 2, 3, 4), kv_end.transpose(1, 0, 2, 3, 4),
          total.transpose(1, 0, 2))
    s_fin, o_inter = jax.lax.scan(step, s0, xs)
    o_inter = o_inter.transpose(1, 0, 2, 3, 4)

    out = (out_intra + o_inter).reshape(b, t, h, p)
    return out.astype(q.dtype), s_fin


def ssd_decode_step(q, k, v, log_a, state):
    """Single-token SSD step. q/k (B,H,N), v (B,H,P), log_a (B,H)."""
    f32 = jnp.float32
    a = jnp.exp(jnp.clip(log_a.astype(f32), LOG_CLAMP, 0.0))
    state = state * a[..., None, None] + (k.astype(f32)[..., None]
                                          * v.astype(f32)[..., None, :])
    out = jnp.einsum("bhd,bhdp->bhp", q.astype(f32), state)
    return out.astype(q.dtype), state
