"""Llama-3.2-Vision-11B text backbone: llama-style decoder with gated
cross-attention image layers interleaved every ``cross_attn_every`` layers
(8 super-blocks of 4 self-attn layers + 1 cross-attn layer for the 40-layer
config).  The vision encoder is a STUB: ``input_specs()`` provides precomputed
patch embeddings (B, n_patches, d_model), per the assignment.

Cross-attn layers use a zero-init tanh gate (the published warm-start trick),
attend with no mask, and need no KV update during decode -- patch K/V are
computed once at prefill and carried in the cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import (attention, attention_specs, cross_entropy_loss,
                     embed_spec, init_kv_cache, mlp, mlp_specs, rmsnorm,
                     rmsnorm_spec, unembed_spec)
from .params import stack_specs
from . import transformer as base

__all__ = ["init_specs", "loss", "prefill", "decode_step"]


def _layout(cfg: ModelConfig) -> Tuple[int, int]:
    per = cfg.cross_attn_every - 1          # self layers per super-block
    n_super = cfg.n_layers // cfg.cross_attn_every
    return n_super, per


def cross_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln": rmsnorm_spec(cfg.d_model),
        "attn": attention_specs(cfg, cross=True),
        "ln_mlp": rmsnorm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def init_specs(cfg: ModelConfig) -> Dict:
    n_super, per = _layout(cfg)
    return {
        "embed": embed_spec(cfg.vocab_pad, cfg.d_model),
        "super": stack_specs(n_super, {
            "self": stack_specs(per, base.layer_specs(cfg)),
            "cross": cross_layer_specs(cfg),
        }),
        "ln_f": rmsnorm_spec(cfg.d_model),
        "lm_head": unembed_spec(cfg.d_model, cfg.vocab_pad),
    }


def _cross_apply(cp, x, patches, cfg, rt):
    a, _ = attention(cp["attn"], rmsnorm(cp["ln"], x, cfg.norm_eps), cfg, rt,
                     kv_x=patches, causal=False)
    x = x + a                                    # tanh gate applied inside attention
    m = mlp(cp["mlp"], rmsnorm(cp["ln_mlp"], x, cfg.norm_eps), cfg, rt)
    return x + m


def forward(params, tokens, patches, cfg, rt, positions=None, caches=None):
    from .common import constrain_batch
    cd = jnp.dtype(cfg.compute_dtype)
    x = constrain_batch(params["embed"].astype(cd)[tokens], rt)
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
    n_super, per = _layout(cfg)

    def super_body(carry, xs):
        h = constrain_batch(carry, rt)
        sp, cache = xs

        def self_body(hh, lx):
            lp, c = lx
            hh, c = base.layer_apply(lp, hh, cfg, rt, positions, c)
            return hh, c

        if cache is None:
            def self_body_nc(hh, lp):
                hh, _ = base.layer_apply(lp, hh, cfg, rt, positions, None)
                return hh, None
            fn = self_body_nc
            if getattr(rt, "remat", "none") in ("block", "full"):
                fn = jax.checkpoint(fn, prevent_cse=False)
            h, _ = jax.lax.scan(fn, h, sp["self"])
            new_c = None
        else:
            h, new_c = jax.lax.scan(self_body, h, (sp["self"], cache))
        h = _cross_apply(sp["cross"], h, patches, cfg, rt)
        return h, new_c

    if caches is None:
        def body(h, sp):
            h, _ = super_body(h, (sp, None))
            return h, None
        x, _ = jax.lax.scan(body, x, params["super"])
        new = None
    else:
        def body(h, xs):
            return super_body(h, xs)
        x, new = jax.lax.scan(body, x, (params["super"], caches))
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), new


def loss(params, batch, cfg, rt):
    hidden, _ = forward(params, batch["tokens"], batch["patches"], cfg, rt)
    return cross_entropy_loss(base.logits_fn(params, hidden, cfg, rt),
                              batch["labels"])


def init_caches(b, max_len, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    n_super, per = _layout(cfg)
    one = init_kv_cache(b, max_len, cfg, cd)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_super, per) + a.shape).copy(), one)


def prefill(params, batch, cfg, rt, max_len):
    tokens = batch["tokens"]
    caches = init_caches(tokens.shape[0], max_len, cfg)
    hidden, caches = forward(params, tokens, batch["patches"], cfg, rt,
                             caches=caches)
    logits = base.logits_fn(params, hidden[:, -1:], cfg, rt)
    return logits, {"kv": caches, "patches": batch["patches"]}


def decode_step(params, tokens, caches, cfg, rt):
    cur = caches["kv"]["len"][0, 0]
    positions = jnp.broadcast_to(cur[None, None], tokens.shape).astype(jnp.int32)
    hidden, kv = forward(params, tokens, caches["patches"], cfg, rt,
                         positions=positions, caches=caches["kv"])
    return base.logits_fn(params, hidden, cfg, rt), {"kv": kv,
                                                     "patches": caches["patches"]}
