"""Zamba2 hybrid: Mamba-2 backbone with one *shared* full-attention block
applied periodically (every ``cfg.attn_every`` mamba blocks), fed the concat of
the running hidden state and the original embedding through a per-invocation
input adapter -- the published Zamba2 topology (DESIGN.md section 9 notes the
simplifications: adapters are plain linear, shared block count = 1).

Layout: n_groups = n_layers // attn_every scan groups (stacked mamba params)
with a shared-attention invocation after each group, plus a scanned tail of
n_layers % attn_every mamba blocks.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import (attention, attention_specs, cross_entropy_loss,
                     dense, dense_spec, embed_spec, init_kv_cache, rmsnorm,
                     rmsnorm_spec, unembed_spec)
from .mamba2 import empty_state, mamba_apply, mamba_specs
from .params import stack_specs
from . import transformer as base

__all__ = ["init_specs", "loss", "prefill", "decode_step"]


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    groups = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers % cfg.attn_every
    return groups, cfg.attn_every, tail


def init_specs(cfg: ModelConfig) -> Dict:
    groups, per, tail = _layout(cfg)
    d = cfg.d_model
    s = {
        "embed": embed_spec(cfg.vocab_pad, cfg.d_model),
        "groups": stack_specs(groups, stack_specs(per, mamba_specs(cfg))),
        "shared_attn": {
            "ln": rmsnorm_spec(2 * d),
            "attn": attention_specs(cfg),
        },
        "adapters_in": stack_specs(groups, dense_spec(2 * d, d, axes=("embed", "embed"))),
        "adapters_out": stack_specs(groups, dense_spec(d, d, axes=("embed", "embed"))),
        "ln_f": rmsnorm_spec(d),
        "lm_head": unembed_spec(d, cfg.vocab_pad),
    }
    if tail:
        s["tail"] = stack_specs(tail, mamba_specs(cfg))
    return s


def _shared_attn_specs_note():
    """The shared attention block consumes concat(hidden, embed0) projected to
    d_model by a per-invocation adapter, runs full attention, and its output is
    projected back and added residually (Zamba2's shared-block dataflow)."""


def init_caches(b: int, max_len: int, cfg: ModelConfig) -> Dict:
    cd = jnp.dtype(cfg.compute_dtype)
    groups, per, tail = _layout(cfg)
    one = empty_state(b, cfg, cd)
    stack2 = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (groups, per) + a.shape).copy(), one)
    kv = init_kv_cache(b, max_len, cfg, cd)
    kv_stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (groups,) + a.shape).copy(), kv)
    caches = {"groups": stack2, "kv": kv_stacked}
    if tail:
        caches["tail"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (tail,) + a.shape).copy(), one)
    return caches


def forward(params, tokens, cfg, rt, positions=None, caches=None):
    from .common import constrain_batch
    cd = jnp.dtype(cfg.compute_dtype)
    x0 = constrain_batch(params["embed"].astype(cd)[tokens], rt)
    x = x0
    groups, per, tail = _layout(cfg)
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]

    def mamba_scan(x, stacked, states):
        if states is None:
            def body(h, lp):
                h, _ = mamba_apply(lp, h, cfg, rt, None)
                return h, None
            fn = body
            if getattr(rt, "remat", "none") in ("block", "full"):
                fn = jax.checkpoint(body, prevent_cse=False)
            x, _ = jax.lax.scan(fn, x, stacked)
            return x, None
        def body(h, xs):
            lp, st = xs
            h, st = mamba_apply(lp, h, cfg, rt, st)
            return h, st
        return jax.lax.scan(body, x, (stacked, states))

    def shared_block(x_in, x0_in, ain, aout, kv):
        h = jnp.concatenate([x_in, x0_in], axis=-1)
        h = rmsnorm(params["shared_attn"]["ln"], h, cfg.norm_eps)
        h = dense(ain, h, rt)
        a_out, kv_new = attention(params["shared_attn"]["attn"], h, cfg, rt,
                                  positions=positions, cache=kv)
        return x_in + dense(aout, a_out, rt), kv_new

    if getattr(rt, "remat", "none") in ("block", "full"):
        shared_block = jax.checkpoint(shared_block, prevent_cse=False)

    new_group_states = []
    new_kv = []
    for g in range(groups):
        gp = jax.tree.map(lambda a: a[g], params["groups"])
        gst = (None if caches is None
               else jax.tree.map(lambda a: a[g], caches["groups"]))
        x, gst_new = mamba_scan(constrain_batch(x, rt), gp, gst)
        # Shared attention invocation (rematerialized under remat policy).
        ain = jax.tree.map(lambda a: a[g], params["adapters_in"])
        aout = jax.tree.map(lambda a: a[g], params["adapters_out"])
        kv = None if caches is None else jax.tree.map(lambda a: a[g], caches["kv"])
        x, kv = shared_block(constrain_batch(x, rt), x0, ain, aout, kv)
        new_group_states.append(gst_new)
        new_kv.append(kv)

    new_tail = None
    if tail:
        tst = None if caches is None else caches["tail"]
        x, new_tail = mamba_scan(x, params["tail"], tst)

    out = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if caches is None:
        return out, None
    new_caches = {
        "groups": jax.tree.map(lambda *a: jnp.stack(a), *new_group_states),
        "kv": jax.tree.map(lambda *a: jnp.stack(a), *new_kv),
    }
    if tail:
        new_caches["tail"] = new_tail
    return out, new_caches


def loss(params, batch, cfg, rt):
    hidden, _ = forward(params, batch["tokens"], cfg, rt)
    return cross_entropy_loss(base.logits_fn(params, hidden, cfg, rt),
                              batch["labels"])


def prefill(params, batch, cfg, rt, max_len):
    tokens = batch["tokens"]
    caches = init_caches(tokens.shape[0], max_len, cfg)
    hidden, caches = forward(params, tokens, cfg, rt, caches=caches)
    return base.logits_fn(params, hidden[:, -1:], cfg, rt), caches


def decode_step(params, tokens, caches, cfg, rt):
    cur = caches["kv"]["len"][0]
    positions = jnp.broadcast_to(cur[None, None], tokens.shape).astype(jnp.int32)
    hidden, caches = forward(params, tokens, cfg, rt,
                             positions=positions, caches=caches)
    return base.logits_fn(params, hidden, cfg, rt), caches
