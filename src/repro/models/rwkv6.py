"""RWKV-6 "Finch" (attention-free, data-dependent decay).  rwkv6-1.6b.

Faithful structure: token-shift interpolation, LoRA-produced per-channel decay
log_w = -exp(w0 + tanh(x_w A_w) B_w) (the defining RWKV-6 feature), WKV
recurrence with current-token bonus u, per-head group-norm, gated output, and
squared-ReLU channel-mix.  Simplifications (DESIGN.md section 9): static token-shift
mixing coefficients (RWKV-6's extra data-dependent token-shift LoRA omitted),
layernorms -> rmsnorm, decay clamped per linear_attention.LOG_CLAMP.

Training/prefill use the chunked WKV (matmul form); decode is the O(1)-state
single-token step -- which is why this arch runs the long_500k shape.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import (cross_entropy_loss, dense, dense_spec,
                     embed_spec, rmsnorm, rmsnorm_spec, unembed_spec)
from .linear_attention import chunked_wkv, wkv_decode_step
from .params import spec, stack_specs
from . import transformer as base

__all__ = ["init_specs", "loss", "prefill", "decode_step"]

LORA_R = 64


def _heads(cfg: ModelConfig) -> Tuple[int, int]:
    dh = cfg.ssm_head_dim
    return cfg.d_model // dh, dh


def layer_specs(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    h, dh = _heads(cfg)
    return {
        "ln1": rmsnorm_spec(d),
        "ln2": rmsnorm_spec(d),
        "tm": {
            "mu_r": spec((d,), ("embed",), init="small"),
            "mu_k": spec((d,), ("embed",), init="small"),
            "mu_v": spec((d,), ("embed",), init="small"),
            "mu_g": spec((d,), ("embed",), init="small"),
            "mu_w": spec((d,), ("embed",), init="small"),
            "wr": dense_spec(d, d, axes=("embed", "heads")),
            "wk": dense_spec(d, d, axes=("embed", "heads")),
            "wv": dense_spec(d, d, axes=("embed", "heads")),
            "wg": dense_spec(d, d, axes=("embed", "heads")),
            "wo": dense_spec(d, d, axes=("heads", "embed")),
            "w0": spec((d,), ("heads",), init="small", scale=0.5),
            "w_lora_a": {"w": spec((d, LORA_R), ("embed", None), scale=0.01)},
            "w_lora_b": {"w": spec((LORA_R, d), (None, "heads"), scale=0.01)},
            "u": spec((h, dh), ("heads", None), init="small"),
            "gn_scale": spec((d,), ("heads",), init="ones"),
            "gn_bias": spec((d,), ("heads",), init="zeros"),
        },
        "cm": {
            "mu_k": spec((d,), ("embed",), init="small"),
            "mu_r": spec((d,), ("embed",), init="small"),
            "wk": dense_spec(d, f, axes=("embed", "mlp")),
            "wv": dense_spec(f, d, axes=("mlp", "embed")),
            "wr": dense_spec(d, d, axes=("embed", "embed")),
        },
    }


def init_specs(cfg: ModelConfig) -> Dict:
    return {
        "embed": embed_spec(cfg.vocab_pad, cfg.d_model),
        "layers": stack_specs(cfg.n_layers, layer_specs(cfg)),
        "ln_f": rmsnorm_spec(cfg.d_model),
        "lm_head": unembed_spec(cfg.d_model, cfg.vocab_pad),
    }


def _shift(x: jnp.ndarray, last: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Token shift: previous token's features (zeros / carried state at t=0)."""
    first = (jnp.zeros_like(x[:, :1]) if last is None else last[:, None])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _group_norm(p, x, cfg, eps=1e-5):
    """Per-head layernorm of the WKV output; x (B, T, H, Dh) -> (B, T, D)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    b, t = x.shape[:2]
    y = y.reshape(b, t, -1)
    return (y * p["gn_scale"].astype(jnp.float32)
            + p["gn_bias"].astype(jnp.float32)).astype(x.dtype)


def time_mix(p, x, cfg, rt, state, last_x, chunk=32):
    """Returns (out, new_state, new_last_x). state (B, H, Dk, Dv)."""
    b, t, d = x.shape
    h, dh = _heads(cfg)
    xx = _shift(x, last_x) - x
    xr = x + xx * p["mu_r"].astype(x.dtype)
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xv = x + xx * p["mu_v"].astype(x.dtype)
    xg = x + xx * p["mu_g"].astype(x.dtype)
    xw = x + xx * p["mu_w"].astype(x.dtype)

    r = dense(p["wr"], xr, rt).reshape(b, t, h, dh)
    k = dense(p["wk"], xk, rt).reshape(b, t, h, dh)
    v = dense(p["wv"], xv, rt).reshape(b, t, h, dh)
    g = dense(p["wg"], xg, rt)

    # Data-dependent decay (the RWKV-6 contribution).
    lora = jnp.tanh(dense(p["w_lora_a"], xw, rt)) @ p["w_lora_b"]["w"].astype(x.dtype)
    log_w = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    log_w = log_w.reshape(b, t, h, dh)

    if t == 1:
        out1, state = wkv_decode_step(r[:, 0], k[:, 0], v[:, 0],
                                      log_w[:, 0], p["u"], state)
        out = out1[:, None]
    else:
        out, state = chunked_wkv(r, k, v, log_w, p["u"], state0=state,
                                 chunk=min(chunk, t))
    out = _group_norm(p, out, cfg)
    out = dense(p["wo"], out * jax.nn.silu(g), rt)
    return out, state, x[:, -1]


def channel_mix(p, x, cfg, rt, last_x):
    xx = _shift(x, last_x) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense(p["wk"], xk, rt)))
    return jax.nn.sigmoid(dense(p["wr"], xr, rt)) * dense(p["wv"], k, rt), x[:, -1]


def _empty_state(b, cfg, dtype):
    h, dh = _heads(cfg)
    return {
        "S": jnp.zeros((b, h, dh, dh), jnp.float32),
        "tm_x": jnp.zeros((b, cfg.d_model), dtype),
        "cm_x": jnp.zeros((b, cfg.d_model), dtype),
    }


def init_caches(b: int, cfg: ModelConfig) -> Dict:
    cd = jnp.dtype(cfg.compute_dtype)
    one = _empty_state(b, cfg, cd)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)


def layer_apply(lp, x, cfg, rt, state):
    """state None (training, fresh zeros) or per-layer dict."""
    from .common import constrain_batch
    x = constrain_batch(x, rt)
    st = state if state is not None else _empty_state(x.shape[0], cfg, x.dtype)
    a, s_new, tm_x = time_mix(lp["tm"], rmsnorm(lp["ln1"], x, cfg.norm_eps),
                              cfg, rt, st["S"],
                              None if state is None else st["tm_x"])
    x = x + a
    c, cm_x = channel_mix(lp["cm"], rmsnorm(lp["ln2"], x, cfg.norm_eps),
                          cfg, rt, None if state is None else st["cm_x"])
    x = x + c
    return x, {"S": s_new, "tm_x": tm_x, "cm_x": cm_x}


def forward(params, tokens, cfg, rt, caches=None):
    from .common import constrain_batch
    cd = jnp.dtype(cfg.compute_dtype)
    x = constrain_batch(params["embed"].astype(cd)[tokens], rt)

    if caches is None:
        def body(h, lp):
            h, _ = layer_apply(lp, h, cfg, rt, None)
            return h, None
        fn = body
        if getattr(rt, "remat", "none") in ("block", "full"):
            fn = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(fn, x, params["layers"])
        new = None
    else:
        def body(h, xs):
            lp, st = xs
            h, st = layer_apply(lp, h, cfg, rt, st)
            return h, st
        x, new = jax.lax.scan(body, x, (params["layers"], caches))
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), new


def loss(params, batch, cfg, rt):
    hidden, _ = forward(params, batch["tokens"], cfg, rt)
    logits = base.logits_fn(params, hidden, cfg, rt)
    return cross_entropy_loss(logits, batch["labels"])


def prefill(params, batch, cfg, rt, max_len=None):
    tokens = batch["tokens"]
    caches = init_caches(tokens.shape[0], cfg)
    hidden, caches = forward(params, tokens, cfg, rt, caches=caches)
    return base.logits_fn(params, hidden[:, -1:], cfg, rt), caches


def decode_step(params, tokens, caches, cfg, rt):
    hidden, caches = forward(params, tokens, cfg, rt, caches=caches)
    return base.logits_fn(params, hidden, cfg, rt), caches
