"""Whisper-tiny backbone (audio enc-dec).  The conv/log-mel frontend is a STUB
per the assignment: ``input_specs()`` provides precomputed frame embeddings
(B, S, d_model); sinusoidal positions are added on both sides (the learned
decoder positions of real Whisper are replaced by sinusoidal so the parameter
shapes are independent of the assigned sequence lengths -- DESIGN.md
section 9).

Encoder: bidirectional attention; decoder: causal self-attn + cross-attn to
the encoder states + GELU MLP, pre-layernorm throughout.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .common import (attention, attention_specs, cross_entropy_loss,
                     embed_spec, init_kv_cache, layernorm,
                     layernorm_spec, mlp, mlp_specs, sinusoidal_positions,
                     unembed_spec)
from .params import stack_specs

__all__ = ["init_specs", "loss", "prefill", "decode_step"]


def enc_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln_attn": layernorm_spec(cfg.d_model),
        "attn": attention_specs(cfg),
        "ln_mlp": layernorm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln_self": layernorm_spec(cfg.d_model),
        "self_attn": attention_specs(cfg),
        "ln_cross": layernorm_spec(cfg.d_model),
        "cross_attn": attention_specs(cfg),
        "ln_mlp": layernorm_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def init_specs(cfg: ModelConfig) -> Dict:
    return {
        "enc_layers": stack_specs(cfg.n_enc_layers, enc_layer_specs(cfg)),
        "enc_ln_f": layernorm_spec(cfg.d_model),
        "embed": embed_spec(cfg.vocab_pad, cfg.d_model),
        "dec_layers": stack_specs(cfg.n_layers, dec_layer_specs(cfg)),
        "dec_ln_f": layernorm_spec(cfg.d_model),
        "lm_head": unembed_spec(cfg.d_model, cfg.vocab_pad),
    }


def encode(params, frames, cfg, rt):
    """frames (B, S, D) -> encoder states (B, S, D)."""
    from .common import constrain_batch
    cd = frames.dtype
    pos = sinusoidal_positions(frames.shape[1], cfg.d_model).astype(cd)
    x = constrain_batch(frames + pos[None], rt)

    def body(h, lp):
        a, _ = attention(lp["attn"], layernorm(lp["ln_attn"], h, cfg.norm_eps),
                         cfg, rt, causal=False)
        h = h + a
        h = h + mlp(lp["mlp"], layernorm(lp["ln_mlp"], h, cfg.norm_eps), cfg, rt)
        return h, None

    fn = body
    if getattr(rt, "remat", "none") in ("block", "full"):
        fn = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return layernorm(params["enc_ln_f"], x, cfg.norm_eps)


def _dec_layer(lp, x, enc, cfg, rt, positions, cache):
    a, cache = attention(lp["self_attn"],
                         layernorm(lp["ln_self"], x, cfg.norm_eps),
                         cfg, rt, positions=positions, cache=cache)
    x = x + a
    c, _ = attention(lp["cross_attn"],
                     layernorm(lp["ln_cross"], x, cfg.norm_eps),
                     cfg, rt, kv_x=enc)
    x = x + c
    x = x + mlp(lp["mlp"], layernorm(lp["ln_mlp"], x, cfg.norm_eps), cfg, rt)
    return x, cache


def decode(params, tokens, enc, cfg, rt, positions=None, caches=None):
    from .common import constrain_batch
    cd = jnp.dtype(cfg.compute_dtype)
    x = constrain_batch(params["embed"].astype(cd)[tokens], rt)
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]
    # Sinusoidal positional encoding evaluated at the (possibly dynamic) positions.
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, None, :]
    ang = positions[..., None].astype(jnp.float32) / (10_000.0 ** (2 * dim / d))
    x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(cd)

    if caches is None:
        def body(h, lp):
            h, _ = _dec_layer(lp, h, enc, cfg, rt, positions, None)
            return h, None
        fn = body
        if getattr(rt, "remat", "none") in ("block", "full"):
            fn = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(fn, x, params["dec_layers"])
        new = None
    else:
        def body(h, xs):
            lp, cache = xs
            h, cache = _dec_layer(lp, h, enc, cfg, rt, positions, cache)
            return h, cache
        x, new = jax.lax.scan(body, x, (params["dec_layers"], caches))
    return layernorm(params["dec_ln_f"], x, cfg.norm_eps), new


def loss(params, batch, cfg, rt):
    enc = encode(params, batch["frames"], cfg, rt)
    hidden, _ = decode(params, batch["tokens"], enc, cfg, rt)
    from . import transformer as base
    logits = base.logits_fn(params, hidden, cfg, rt)
    return cross_entropy_loss(logits, batch["labels"])


def init_caches(b, max_len, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    one = init_kv_cache(b, max_len, cfg, cd)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), one)


def prefill(params, batch, cfg, rt, max_len):
    """Encode frames + prefill the decoder prompt. Caches carry the encoder
    states (for cross-attn) alongside the self-attn KV."""
    enc = encode(params, batch["frames"], cfg, rt)
    tokens = batch["tokens"]
    kv = init_caches(tokens.shape[0], max_len, cfg)
    hidden, kv = decode(params, tokens, enc, cfg, rt, caches=kv)
    from . import transformer as base
    logits = base.logits_fn(params, hidden[:, -1:], cfg, rt)
    return logits, {"kv": kv, "enc": enc}


def decode_step(params, tokens, caches, cfg, rt):
    cur = caches["kv"]["len"][0]
    positions = jnp.broadcast_to(cur[None, None], tokens.shape).astype(jnp.int32)
    hidden, kv = decode(params, tokens, caches["enc"], cfg, rt,
                        positions=positions, caches=caches["kv"])
    from . import transformer as base
    logits = base.logits_fn(params, hidden, cfg, rt)
    return logits, {"kv": kv, "enc": caches["enc"]}
