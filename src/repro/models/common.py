"""Shared model components: linear ops (digital + RRAM analog backend), norms,
RoPE, GQA attention (qk-norm / sliding-window / cross-attn / KV cache), MLPs,
embeddings, and the cross-entropy loss.

All linear kernels are 2-D ``(d_in, d_out)`` and named ``"w"`` -- that is the
contract that lets :func:`repro.models.rram.program_rram` swap any layer onto
the analog backend (the paper's technique) without model-specific code.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RRAMBackendConfig
from .params import ParamSpec, spec

__all__ = [
    "Runtime", "dense", "dense_spec", "rmsnorm", "rmsnorm_spec", "layernorm",
    "layernorm_spec", "rope", "attention_specs", "attention", "init_kv_cache",
    "mlp_specs", "mlp", "embed_spec", "unembed_spec", "cross_entropy_loss",
    "sinusoidal_positions",
]


# --------------------------------------------------------------------------- #
# Runtime context (threads the RRAM backend + rng through apply functions)
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class Runtime:
    """Per-call context. ``key`` may be a tracer; ``_salt`` is a trace-time
    counter giving each dense call site its own fold_in salt."""

    rram: Optional[RRAMBackendConfig] = None
    key: Optional[jax.Array] = None
    mesh: Any = None                    # for shard_map layers (MoE)
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    flash_threshold: int = 512 * 512    # t*s above which attention chunks
    q_chunk: int = 1024
    kv_chunk: int = 1024
    causal_skip: bool = False           # static skip of masked KV chunks
    remat: str = "none"                 # none | block | full
    attn_in_dtype: str = "native"       # "native": bf16 operands + fp32 MXU
    #   accumulation (preferred_element_type); "f32": cast K/V to fp32 before
    #   the einsum (costs a full-cache fp32 round-trip -- kept for the perf
    #   ablation in EXPERIMENTS.md section Perf).
    _salt: int = 0

    def next_key(self) -> jax.Array:
        self._salt += 1
        base = self.key if self.key is not None else jax.random.PRNGKey(0)
        return jax.random.fold_in(base, self._salt)


def constrain_batch(x: jnp.ndarray, rt: Optional["Runtime"]) -> jnp.ndarray:
    """Pin activations to batch-over-data sharding (GSPMD left alone will
    sometimes replicate the microbatch; MaxText-style boundary constraints
    keep every layer's working set 1/dp-sized)."""
    if rt is None or rt.mesh is None:
        return x
    sizes = dict(zip(rt.mesh.axis_names, rt.mesh.devices.shape))
    dsz = 1
    for a in rt.batch_axes:
        dsz *= sizes.get(a, 1)
    if x.shape[0] % dsz != 0:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(rt.batch_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(rt.mesh, spec))


def _k_stencil(p: jnp.ndarray, h: float) -> jnp.ndarray:
    """(L^T L) p along the last axis (row-0 diagonal is 1, see core.ec)."""
    up = jnp.concatenate([p[..., 1:], jnp.zeros_like(p[..., :1])], axis=-1)
    dn = jnp.concatenate([jnp.zeros_like(p[..., :1]), p[..., :-1]], axis=-1)
    kp = (1.0 + h * h) * p + h * (up + dn)
    first = kp[..., :1] - (h * h) * p[..., :1]
    return jnp.concatenate([first, kp[..., 1:]], axis=-1)


def _encode_act(x: jnp.ndarray, key: jax.Array, cfg: RRAMBackendConfig) -> jnp.ndarray:
    """DAC-side encoding noise on activations (x -> x_tilde)."""
    from repro.core.devices import effective_sigma_py, get_device
    sigma = effective_sigma_py(get_device(cfg.device), cfg.k_iters)
    eta = jax.random.normal(key, x.shape, dtype=x.dtype)
    return x * (1.0 + jnp.asarray(sigma, x.dtype) * eta)


def dense_spec(d_in: int, d_out: int, axes=("embed", "mlp"), scale=None) -> Dict:
    return {"w": spec((d_in, d_out), axes, scale=scale)}


def dense(p: Dict, x: jnp.ndarray, rt: Optional[Runtime] = None) -> jnp.ndarray:
    """y = x @ w.  If the layer has been programmed onto the RRAM backend
    (``w_tilde``/``dw`` present), runs the two-tier error-corrected analog path:

        tier-1 (fused):  p = x @ W_tilde + x_tilde @ (W - W_tilde)
        tier-2:          y = p - lam * (L^T L) p        (truncated Neumann)
    """
    w = p["w"]
    if rt is None or rt.rram is None or not rt.rram.enabled or "w_tilde" not in p:
        return x @ w
    cfg = rt.rram
    cd = x.dtype
    xt = _encode_act(x, rt.next_key(), cfg) if cfg.encode_inputs else x
    if cfg.ec:
        out = x @ p["w_tilde"].astype(cd) + xt @ p["dw"].astype(cd)
        out32 = out.astype(jnp.float32)
        out = (out32 - cfg.lam * _k_stencil(out32, -1.0)).astype(cd)
    else:
        out = xt @ p["w_tilde"].astype(cd)
    return out


# --------------------------------------------------------------------------- #
# Norms, RoPE, positions
# --------------------------------------------------------------------------- #

def rmsnorm_spec(d: int) -> Dict:
    return {"scale": spec((d,), ("embed",), init="ones")}


def rmsnorm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int) -> Dict:
    return {"scale": spec((d,), ("embed",), init="ones"),
            "bias": spec((d,), ("embed",), init="zeros")}


def layernorm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def _rope_trig(positions: jnp.ndarray, theta: float, dh: int):
    """Full-width (Dh) cos / signed-sin tables, built from iota -- never by
    concatenating computed half-width arrays (see :func:`rope`)."""
    half = dh // 2
    idx = jnp.arange(dh, dtype=jnp.int32)
    freqs = 1.0 / (theta ** ((idx % half).astype(jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., T, Dh)
    sign = jnp.where(idx < half, -1.0, 1.0)
    return jnp.cos(ang)[..., None, :], (sign * jnp.sin(ang))[..., None, :]


def _rope_apply(x: jnp.ndarray, cos2: jnp.ndarray, sin2: jnp.ndarray):
    half = x.shape[-1] // 2
    rot = jnp.concatenate([x[..., half:], x[..., :half]], axis=-1)
    return x * cos2 + rot * sin2


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, Dh); positions: (..., T) int32.

    Rotate-half form: the raw halves of ``x`` are concatenated *before* any
    arithmetic and the rotation runs on full-width (Dh) arrays, with the
    backward pass (a rotation by ``-theta``) spelled the same way via
    ``custom_vjp``.  The textbook ``concat(x1*cos - x2*sin, x2*cos + x1*sin)``
    -- compute on sliced halves, then concatenate -- is bit-identical in IEEE
    arithmetic but is miscompiled by the GSPMD partitioner when the head dim
    arrives sharded (e.g. wk sharded over 'model' propagates into Dh),
    silently producing wrong values; jax's auto-derived rope VJP contains the
    same unsafe pattern.  Only raw slices may feed a concatenate here.
    """
    cos2, sin2 = _rope_trig(positions, theta, x.shape[-1])
    return _rope_apply(x, cos2, sin2).astype(x.dtype)


def _rope_fwd(x, positions, theta):
    return rope(x, positions, theta), positions


def _rope_bwd(theta, positions, g):
    cos2, sin2 = _rope_trig(positions, theta, g.shape[-1])
    return (_rope_apply(g, cos2, -sin2).astype(g.dtype), None)


rope.defvjp(_rope_fwd, _rope_bwd)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------- #
# Attention (GQA, qk-norm, sliding window, self/cross, KV cache)
# --------------------------------------------------------------------------- #

def attention_specs(cfg: ModelConfig, cross: bool = False) -> Dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s: Dict[str, Any] = {
        "wq": dense_spec(d, h * dh, axes=("embed", "heads")),
        "wk": dense_spec(d, kv * dh, axes=("embed", "kv_heads")),
        "wv": dense_spec(d, kv * dh, axes=("embed", "kv_heads")),
        "wo": dense_spec(h * dh, d, axes=("heads", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = {"scale": spec((dh,), (None,), init="ones")}
        s["k_norm"] = {"scale": spec((dh,), (None,), init="ones")}
    if cross:
        s["gate"] = spec((), (), init="zeros")    # llama-vision tanh gate
    return s


def init_kv_cache(batch: int, max_len: int, cfg: ModelConfig, dtype) -> Dict:
    kv, dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, max_len, kv, dh), dtype),
        "v": jnp.zeros((batch, max_len, kv, dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _split_heads(x, n, dh):
    return x.reshape(x.shape[:-1] + (n, dh))


def attention(
    p: Dict,
    x: jnp.ndarray,                       # (B, T, D)
    cfg: ModelConfig,
    rt: Optional[Runtime] = None,
    *,
    positions: Optional[jnp.ndarray] = None,
    kv_x: Optional[jnp.ndarray] = None,   # cross-attention source (B, S, D)
    cache: Optional[Dict] = None,         # decode KV cache
    causal: bool = True,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Returns (out, updated_cache). Handles: training (full seq), prefill
    (full seq + cache fill), decode (T==1 + cache append), cross-attn."""
    b, t, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    cd = x.dtype

    q = _split_heads(dense(p["wq"], x, rt), h, dh)
    src = kv_x if kv_x is not None else x
    k = _split_heads(dense(p["wk"], src, rt), kv, dh)
    v = _split_heads(dense(p["wv"], src, rt), kv, dh)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)[None, :]
    if kv_x is None and cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    q_pos = positions                                        # (B, T)
    if cache is not None and kv_x is None:
        start = cache["len"]
        w_cache = cache["k"].shape[1]
        circular = (cfg.swa_window is not None and w_cache <= cfg.swa_window)
        if circular and t >= w_cache:
            # Sliding-window prefill into a circular cache: keep the last
            # W tokens; token j lives at slot j % W (roll aligns them).
            shift = (t - w_cache) % w_cache
            ck = jnp.roll(k[:, -w_cache:], shift, axis=1).astype(cache["k"].dtype)
            cv = jnp.roll(v[:, -w_cache:], shift, axis=1).astype(cache["v"].dtype)
            cache = {"k": ck, "v": cv, "len": start + t}
            # In-pass attention uses the full-sequence k/v (window-masked).
            kv_pos = q_pos
            kv_valid = jnp.ones(k.shape[:2], bool)
        elif circular:
            # Decode (t small): write at slot len % W.
            slot = start % w_cache
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            new_len = start + t
            cache = {"k": ck, "v": cv, "len": new_len}
            k, v = ck, cv
            # Slot s holds the latest token position == s (mod W), < len.
            s_idx = jnp.arange(w_cache, dtype=jnp.int32)
            tok_pos = new_len - 1 - ((new_len - 1 - s_idx) % w_cache)
            kv_pos = tok_pos[None, :]
            kv_valid = (tok_pos >= 0)[None, :]
        else:
            # Append current k/v at cache["len"].
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, start, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, start, 0, 0))
            cache = {"k": ck, "v": cv, "len": start + t}
            k, v = ck, cv
            kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)[None, :]
            kv_valid = kv_pos < cache["len"]
    else:
        kv_pos = (jnp.arange(k.shape[1], dtype=jnp.int32)[None, :]
                  if kv_x is not None else q_pos)
        kv_valid = None        # fully valid; flash skips masks if non-causal

    # Grouped-query attention: (B, T, KV, G, Dh) vs (B, S, KV, Dh).
    g = h // kv
    qg = q.reshape(b, t, kv, g, dh)
    s_len = k.shape[1]
    is_causal = causal and kv_x is None
    if q_pos.ndim == 1:
        q_pos = q_pos[None, :]
    if kv_pos.ndim == 1:
        kv_pos = kv_pos[None, :]
    q_pos = jnp.broadcast_to(q_pos, (b, t))
    kv_pos = jnp.broadcast_to(kv_pos, (b, s_len))
    if kv_valid is not None:
        kv_valid = jnp.broadcast_to(kv_valid, (b, s_len))

    threshold = rt.flash_threshold if rt is not None else 512 * 512
    if t > 1 and t * s_len > threshold:
        from .flash import flash_attention
        out = flash_attention(
            qg, k, v, q_pos, kv_pos, kv_valid,
            causal=is_causal, window=cfg.swa_window,
            q_chunk=rt.q_chunk if rt else 1024,
            kv_chunk=rt.kv_chunk if rt else 1024,
            causal_skip=rt.causal_skip if rt else False)
    else:
        scale = dh ** -0.5
        f32 = (rt is not None and rt.attn_in_dtype == "f32")
        qin = (qg.astype(jnp.float32) if f32 else qg) * jnp.asarray(
            scale, jnp.float32 if f32 else qg.dtype)
        kin = k.astype(jnp.float32) if f32 else k
        # bf16 operands with fp32 MXU accumulation: no fp32 cache round-trip.
        logits = jnp.einsum("btkgd,bskd->bkgts", qin, kin,
                            preferred_element_type=jnp.float32)
        mask = (kv_valid[:, None, None, None, :] if kv_valid is not None
                else jnp.ones((b, 1, 1, 1, s_len), bool))
        if is_causal:
            cm = q_pos[:, None, None, :, None] >= kv_pos[:, None, None, None, :]
            mask = jnp.logical_and(mask, cm)
            if cfg.swa_window:
                wm = (q_pos[:, None, None, :, None]
                      - kv_pos[:, None, None, None, :]) < cfg.swa_window
                mask = jnp.logical_and(mask, wm)
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        vin = v.astype(jnp.float32) if f32 else v
        out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(vin.dtype), vin,
                         preferred_element_type=jnp.float32).astype(cd)
    out = out.reshape(b, t, h * dh)
    out = dense(p["wo"], out, rt)
    if "gate" in p:                                          # gated cross-attn
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(cd) * out
    return out, cache


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #

def mlp_specs(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu_gated":
        return {
            "wg": dense_spec(d, f, axes=("embed", "mlp")),
            "wu": dense_spec(d, f, axes=("embed", "mlp")),
            "wd": dense_spec(f, d, axes=("mlp", "embed")),
        }
    return {
        "wu": dense_spec(d, f, axes=("embed", "mlp")),
        "wd": dense_spec(f, d, axes=("mlp", "embed")),
    }


def mlp(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
        rt: Optional[Runtime] = None) -> jnp.ndarray:
    if cfg.act == "silu_gated":
        return dense(p["wd"], jax.nn.silu(dense(p["wg"], x, rt))
                     * dense(p["wu"], x, rt), rt)
    u = dense(p["wu"], x, rt)
    if cfg.act == "sq_relu":
        u = jnp.square(jax.nn.relu(u))
    else:
        u = jax.nn.gelu(u)
    return dense(p["wd"], u, rt)


# --------------------------------------------------------------------------- #
# Embeddings + loss
# --------------------------------------------------------------------------- #

def embed_spec(vocab: int, d: int) -> ParamSpec:
    return spec((vocab, d), ("vocab", "embed"), init="embed", scale=0.02)


def unembed_spec(d: int, vocab: int) -> Dict:
    return dense_spec(d, vocab, axes=("embed", "vocab"))


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over positions with label >= 0 (negative labels are padding)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    wmask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * wmask) / jnp.maximum(jnp.sum(wmask), 1.0)
