"""Memory-bounded chunked attention (online softmax), pure JAX.

At the assigned shapes (32k prefill, 4k x 256 train) materializing the full
(T, S) logits is impossible (32k^2 x heads x fp32 >> HBM), so the production
attention path streams KV in chunks with running max/denominator accumulators
-- the flash-attention recurrence -- implemented with ``lax.scan`` so it lowers
to a compact HLO loop on any backend.

``causal_skip`` statically unrolls the query-chunk loop and skips fully-masked
KV chunks (upper triangle) -- a beyond-paper scheduling optimization measured
in EXPERIMENTS.md section Perf (it halves attention FLOPs for causal masks).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _block_attn_nomask(q_blk, k_blk, v_blk, m, l, acc):
    """Mask-free tile (non-causal, fully valid): no pred tensors at all."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk,
                   preferred_element_type=jnp.float32)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
    acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
    return m_new, l_new, acc_new


def _block_attn(q_blk, k_blk, v_blk, mask, m, l, acc):
    """One (q_chunk x kv_chunk) tile of the online-softmax recurrence.

    q_blk: (B, qc, KV, G, Dh) pre-scaled (bf16 ok); k/v_blk: (B, kc, KV, Dh);
    mask: (B, 1, 1, qc, kc) bool; m,l: (B, KV, G, qc) fp32; acc fp32.
    Operands stay in their storage dtype; the MXU accumulates fp32."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk,
                   preferred_element_type=jnp.float32)
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0)=1.
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask, p, 0.0)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_blk.dtype), v_blk,
                    preferred_element_type=jnp.float32)
    acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
    return m_new, l_new, acc_new


def flash_attention(
    qg: jnp.ndarray,            # (B, T, KV, G, Dh) -- grouped query heads
    k: jnp.ndarray,             # (B, S, KV, Dh)
    v: jnp.ndarray,             # (B, S, KV, Dh)
    q_pos: jnp.ndarray,         # (B, T) int32
    kv_pos: jnp.ndarray,        # (B, S) int32
    kv_valid,                   # (B, S) bool, or None == everything valid
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    causal_skip: bool = False,
) -> jnp.ndarray:
    """Returns (B, T, KV, G, Dh) in fp32-accumulated, cast to qg.dtype."""
    b, t, kv, g, dh = qg.shape
    s_len = k.shape[1]
    qc = min(q_chunk, t)
    kc = min(kv_chunk, s_len)
    assert t % qc == 0 and s_len % kc == 0, ((t, qc), (s_len, kc))
    nq, nk = t // qc, s_len // kc
    cd = qg.dtype

    scale = dh ** -0.5
    qf = qg * jnp.asarray(scale, qg.dtype)   # operands keep storage dtype
    kf = k
    vf = v

    q_blocks = qf.reshape(b, nq, qc, kv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qp_blocks = q_pos.reshape(b, nq, qc).transpose(1, 0, 2)
    k_blocks = kf.reshape(b, nk, kc, kv, dh).transpose(1, 0, 2, 3, 4)
    v_blocks = vf.reshape(b, nk, kc, kv, dh).transpose(1, 0, 2, 3, 4)
    kvp_blocks = kv_pos.reshape(b, nk, kc).transpose(1, 0, 2)
    no_mask = (kv_valid is None) and not causal
    if kv_valid is None:
        kv_valid = jnp.ones((b, s_len), bool)
    valid_blocks = kv_valid.reshape(b, nk, kc).transpose(1, 0, 2)

    def mask_for(qp, kvp, valid):
        msk = valid[:, None, None, None, :]
        if causal:
            cm = qp[:, None, None, :, None] >= kvp[:, None, None, None, :]
            msk = jnp.logical_and(msk, cm)
            if window:
                wm = (qp[:, None, None, :, None]
                      - kvp[:, None, None, None, :]) < window
                msk = jnp.logical_and(msk, wm)
        return msk

    def run_q_block(q_blk, qp):
        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, qc, kv, g, dh), jnp.float32)

        if causal_skip and causal:
            # Static unroll: only visit KV chunks that intersect the mask.
            m_, l_, a_ = m0, l0, a0
            q_lo = int(0)  # positions are dynamic; fall back to chunk index
            for j in range(nk):
                m_, l_, a_ = _block_attn(
                    q_blk, k_blocks[j], v_blocks[j],
                    mask_for(qp, kvp_blocks[j], valid_blocks[j]), m_, l_, a_)
            return m_, l_, a_

        def kv_step(carry, xs):
            m_, l_, a_ = carry
            k_blk, v_blk, kvp, valid = xs
            if no_mask:
                m_, l_, a_ = _block_attn_nomask(q_blk, k_blk, v_blk, m_, l_, a_)
            else:
                m_, l_, a_ = _block_attn(
                    q_blk, k_blk, v_blk, mask_for(qp, kvp, valid), m_, l_, a_)
            return (m_, l_, a_), None

        (m_, l_, a_), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k_blocks, v_blocks, kvp_blocks, valid_blocks))
        return m_, l_, a_

    if causal_skip and causal and nq == nk:
        # Fully static schedule: q chunk i attends kv chunks 0..i (plus window
        # lower bound).  Unrolled python loop -> no wasted masked chunks.
        outs = []
        for i in range(nq):
            m_ = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
            l_ = jnp.zeros((b, kv, g, qc), jnp.float32)
            a_ = jnp.zeros((b, qc, kv, g, dh), jnp.float32)
            j_lo = 0
            if window:
                j_lo = max(0, (i * qc - window - kc + 1) // kc)
            for j in range(j_lo, i + 1):
                m_, l_, a_ = _block_attn(
                    q_blocks[i], k_blocks[j], v_blocks[j],
                    mask_for(qp_blocks[i], kvp_blocks[j], valid_blocks[j]),
                    m_, l_, a_)
            outs.append(a_ / jnp.maximum(l_, 1e-30).transpose(0, 3, 1, 2)[..., None])
        out = jnp.stack(outs, axis=0)
    else:
        def q_step(_, xs):
            q_blk, qp = xs
            m_, l_, a_ = run_q_block(q_blk, qp)
            o = a_ / jnp.maximum(l_, 1e-30).transpose(0, 3, 1, 2)[..., None]
            return None, o

        _, out = jax.lax.scan(q_step, None, (q_blocks, qp_blocks))

    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, kv, g, dh)
    return out.astype(cd)
