"""Mixture-of-Experts transformer (mixtral-8x7b, phi3.5-moe).

Token-choice top-k routing with *sort-based* dispatch: assignments are sorted
by expert id, positioned with a cumsum-of-counts, capacity-dropped, and
scattered into an (E, C, D) buffer -- no (N, E, C) one-hot tensor is ever
materialized, so dispatch is O(N k D) memory and the expert matmuls dominate
FLOPs (this is what keeps MODEL_FLOPS/HLO_FLOPS honest in the roofline).

Two execution paths:
  * local (single device / GSPMD-friendly fallback used in smoke tests);
  * shard_map tensor-parallel: batch sharded over the data axes, expert d_ff
    sharded over the model axis, partial down-projections psum-reduced --
    used whenever ``rt.mesh`` is set (the production path).

Expert FFNs are the paper's "many MCA tiles" picture 1:1; their kernels are
named "w" so :func:`repro.models.rram.program_rram` can put them on the analog
backend, and the EC path is honored inside the expert einsums.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.compat import shard_map
from .common import (
    Runtime, attention, attention_specs, cross_entropy_loss,
    embed_spec, rmsnorm, rmsnorm_spec, unembed_spec, _k_stencil,
)
from .params import spec, stack_specs
from . import transformer as base

__all__ = ["init_specs", "loss", "prefill", "decode_step", "moe_apply"]


def moe_specs(cfg: ModelConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": {"w": spec((d, e), ("embed", None), scale=0.02)},
        "wg": {"w": spec((e, d, f), ("expert", "embed", "mlp"))},
        "wu": {"w": spec((e, d, f), ("expert", "embed", "mlp"))},
        "wd": {"w": spec((e, f, d), ("expert", "mlp", "embed"))},
    }


def layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln_attn": rmsnorm_spec(cfg.d_model),
        "attn": attention_specs(cfg),
        "ln_mlp": rmsnorm_spec(cfg.d_model),
        "moe": moe_specs(cfg),
    }


def init_specs(cfg: ModelConfig) -> Dict:
    s = {
        "embed": embed_spec(cfg.vocab_pad, cfg.d_model),
        "layers": stack_specs(cfg.n_layers, layer_specs(cfg)),
        "ln_f": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = unembed_spec(cfg.d_model, cfg.vocab_pad)
    return s


# --------------------------------------------------------------------------- #
# Dispatch / combine
# --------------------------------------------------------------------------- #

def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(cfg.experts_per_token * n_tokens
                  * cfg.expert_capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def _expert_mm(pd: Dict, x: jnp.ndarray, rt: Optional[Runtime]) -> jnp.ndarray:
    """x (E, C, D) @ w (E, D, F), honoring the RRAM EC backend."""
    w = pd["w"]
    if rt is None or rt.rram is None or not rt.rram.enabled or "w_tilde" not in pd:
        return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))
    cfg = rt.rram
    from .common import _encode_act
    xt = _encode_act(x, rt.next_key(), cfg) if cfg.encode_inputs else x
    if cfg.ec:
        out = (jnp.einsum("ecd,edf->ecf", x, pd["w_tilde"].astype(x.dtype))
               + jnp.einsum("ecd,edf->ecf", xt, pd["dw"].astype(x.dtype)))
        o32 = out.astype(jnp.float32)
        return (o32 - cfg.lam * _k_stencil(o32, -1.0)).astype(x.dtype)
    return jnp.einsum("ecd,edf->ecf", xt, pd["w_tilde"].astype(x.dtype))


MOE_TOKEN_CHUNK = 8192


def _moe_ffn_local(p: Dict, x2: jnp.ndarray, cfg: ModelConfig,
                   rt: Optional[Runtime]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x2 (N, D) -> (out (N, D), aux).  Long token streams (32k prefill) run
    through lax.map over fixed-size chunks so the (E, C, D) dispatch buffers
    stay bounded regardless of sequence length."""
    n, d = x2.shape
    ch = MOE_TOKEN_CHUNK
    if n > ch and n % ch == 0:
        xs = x2.reshape(n // ch, ch, d)
        outs, auxs = jax.lax.map(
            lambda xc: _moe_ffn_chunk(p, xc, cfg, rt), xs)
        return outs.reshape(n, d), jnp.mean(auxs)
    return _moe_ffn_chunk(p, x2, cfg, rt)


def _moe_ffn_chunk(p: Dict, x2: jnp.ndarray, cfg: ModelConfig,
                   rt: Optional[Runtime]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n, d = x2.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(n, cfg)

    gates = jax.nn.softmax(
        (x2 @ p["router"]["w"].astype(x2.dtype)).astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                       # (N, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    ef = topi.reshape(-1)                                      # (N*k,)
    order = jnp.argsort(ef, stable=True)
    es = ef[order]
    counts = jnp.bincount(ef, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(es.shape[0], dtype=jnp.int32) - starts[es].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, es * cap + pos, e * cap)

    xs = x2[(order // k)]
    buf = jnp.zeros((e * cap + 1, d), x2.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xs, 0))
    xin = buf[:-1].reshape(e, cap, d)

    h = jax.nn.silu(_expert_mm(p["wg"], xin, rt)) * _expert_mm(p["wu"], xin, rt)
    yout = _expert_mm(p["wd"], h, rt)                          # (E, C, D)

    ys = yout.reshape(e * cap, d)
    got = jnp.where(keep[:, None], ys[jnp.minimum(slot, e * cap - 1)], 0)
    inv = jnp.argsort(order, stable=True)
    out_assign = got[inv].reshape(n, k, d)
    out = jnp.sum(out_assign * topv[..., None].astype(x2.dtype), axis=1)

    # Switch-style load-balance aux: E * sum_e f_e * P_e.
    f_e = jnp.bincount(ef, length=e).astype(jnp.float32) / (n * k)
    p_e = jnp.mean(gates, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return out, aux


def moe_apply(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
              rt: Optional[Runtime]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, T, D) -> (out, aux).  shard_map TP path when rt.mesh is set."""
    b, t, d = x.shape

    if rt is None or rt.mesh is None:
        out, aux = _moe_ffn_local(p, x.reshape(b * t, d), cfg, rt)
        return out.reshape(b, t, d), aux

    mesh = rt.mesh
    mp = rt.model_axis
    # Batch must divide the data axes to shard it; tiny batches (long-context
    # decode with B=1) run replicated across the data axes instead.
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dsz = 1
    for ax in rt.batch_axes:
        dsz *= sizes.get(ax, 1)
    batch_spec = rt.batch_axes if b % dsz == 0 else None

    def local(x_l, router, wg, wu, wd):
        pl = {"router": router, "wg": wg, "wu": wu, "wd": wd}
        bl, tl, _ = x_l.shape
        out_l, aux_l = _moe_ffn_local(pl, x_l.reshape(bl * tl, d), cfg, rt)
        # wg/wu/wd are sharded on d_ff over the model axis: the down-proj
        # partials must be summed across it (tensor parallelism).
        out_l = jax.lax.psum(out_l, axis_name=mp)
        aux_l = jax.lax.pmean(aux_l, axis_name=mp)
        if batch_spec is not None:
            for ax in rt.batch_axes:
                aux_l = jax.lax.pmean(aux_l, axis_name=ax)
        return out_l.reshape(bl, tl, d), aux_l

    in_specs = (
        P(batch_spec, None, None),
        jax.tree.map(lambda _: P(None, None), p["router"]),
        jax.tree.map(lambda _: P(None, None, mp), p["wg"]),
        jax.tree.map(lambda _: P(None, None, mp), p["wu"]),
        jax.tree.map(lambda _: P(None, mp, None), p["wd"]),
    )
    out, aux = shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(batch_spec, None, None), P()),
    )(x, p["router"], p["wg"], p["wu"], p["wd"])
    return out, aux


# --------------------------------------------------------------------------- #
# Model interface
# --------------------------------------------------------------------------- #

init_caches = base.init_caches


def layer_apply(lp, x, cfg, rt, positions, cache):
    from .common import constrain_batch
    x = constrain_batch(x, rt)
    a, cache = attention(lp["attn"], rmsnorm(lp["ln_attn"], x, cfg.norm_eps),
                         cfg, rt, positions=positions, cache=cache)
    x = x + a
    m, aux = moe_apply(lp["moe"], rmsnorm(lp["ln_mlp"], x, cfg.norm_eps), cfg, rt)
    return x + m, cache, aux


def forward(params, tokens, cfg, rt, positions=None, caches=None):
    from .common import constrain_batch
    cd = jnp.dtype(cfg.compute_dtype)
    x = constrain_batch(params["embed"].astype(cd)[tokens], rt)
    if positions is None:
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None, :]

    if caches is None:
        def body(carry, lp):
            h, aux_acc = carry
            h, _, aux = layer_apply(lp, h, cfg, rt, positions, None)
            return (h, aux_acc + aux), None
        fn = body
        if getattr(rt, "remat", "none") in ("block", "full"):
            fn = jax.checkpoint(body, prevent_cse=False)
        (x, aux_sum), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                                       params["layers"])
        new_caches = None
    else:
        def body(carry, xs):
            h, aux_acc = carry
            lp, cache = xs
            h, cache, aux = layer_apply(lp, h, cfg, rt, positions, cache)
            return (h, aux_acc + aux), cache
        (x, aux_sum), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["layers"], caches))
    return rmsnorm(params["ln_f"], x, cfg.norm_eps), new_caches, aux_sum


def loss(params, batch, cfg, rt, aux_weight: float = 0.01):
    hidden, _, aux = forward(params, batch["tokens"], cfg, rt)
    logits = base.logits_fn(params, hidden, cfg, rt)
    return cross_entropy_loss(logits, batch["labels"]) + aux_weight * aux / max(cfg.n_layers, 1)


def prefill(params, batch, cfg, rt, max_len):
    tokens = batch["tokens"]
    b, t = tokens.shape
    caches = base.init_caches(b, max_len, cfg)
    hidden, caches, _ = forward(params, tokens, cfg, rt, caches=caches)
    return base.logits_fn(params, hidden[:, -1:], cfg, rt), caches


def decode_step(params, tokens, caches, cfg, rt):
    cur = caches["len"][0]
    positions = jnp.broadcast_to(cur[None, None], tokens.shape).astype(jnp.int32)
    hidden, caches, _ = forward(params, tokens, cfg, rt,
                                positions=positions, caches=caches)
    return base.logits_fn(params, hidden, cfg, rt), caches
