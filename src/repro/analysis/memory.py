"""Static allocation analysis: the largest array a traced program can hold.

The paper's scalability claim is that a >= 65,536^2 solve never allocates an
A-sized array -- not on the host, not on any device.  That property is
*structural*: it is visible in the jaxpr of the jitted computation before
anything runs.  :func:`max_aval_elements` walks every equation (recursing
into scan/while/cond/pjit/shard_map sub-jaxprs) and returns the largest
intermediate, input, constant or output aval in elements, so tests and
benchmarks can assert ``max_aval_elements(mvm_fn, x, key) << m * n`` without
paying for (or being able to afford) a real A-sized buffer.

Note the per-device view: inside a ``shard_map`` sub-jaxpr the avals are the
per-device block shapes, which is exactly the bound that matters -- a global
array sharded 8 ways shows up as its (A/8)-sized local aval, while a true
A-sized materialization shows up full size on the offending equation.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

try:  # jax >= 0.5 moved the IR types to jax.extend.core
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr, Jaxpr as _Jaxpr
except ImportError:  # pragma: no cover - older jax
    _Jaxpr = jax.core.Jaxpr
    _ClosedJaxpr = jax.core.ClosedJaxpr

__all__ = ["max_aval_elements", "jaxpr_max_elements"]


def _aval_elements(var) -> int:
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) if len(shape) else 1


def _iter_subjaxprs(params: dict):
    for v in params.values():
        if isinstance(v, _Jaxpr):
            yield v
        elif isinstance(v, _ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, _Jaxpr):
                    yield item
                elif isinstance(item, _ClosedJaxpr):
                    yield item.jaxpr


def jaxpr_max_elements(jaxpr) -> int:
    """Largest aval (elements) anywhere in a (closed) jaxpr, recursively."""
    if isinstance(jaxpr, _ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    best = 0
    for var in (*jaxpr.invars, *jaxpr.constvars, *jaxpr.outvars):
        best = max(best, _aval_elements(var))
    for eqn in jaxpr.eqns:
        for var in (*eqn.invars, *eqn.outvars):
            best = max(best, _aval_elements(var))
        for sub in _iter_subjaxprs(eqn.params):
            best = max(best, jaxpr_max_elements(sub))
    return best


def max_aval_elements(fn, *args: Any, **kwargs: Any) -> int:
    """Largest array (in elements) the traced ``fn(*args)`` can ever hold.

    ``args`` may be real arrays or ``jax.ShapeDtypeStruct`` placeholders --
    nothing executes and nothing is allocated; only the trace is inspected.
    """
    return jaxpr_max_elements(jax.make_jaxpr(fn)(*args, **kwargs))
