"""Static allocation analysis: the largest array a traced program can hold.

The paper's scalability claim is that a >= 65,536^2 solve never allocates an
A-sized array -- not on the host, not on any device.  That property is
*structural*: it is visible in the jaxpr of the jitted computation before
anything runs.  :func:`max_aval_elements` walks every equation (recursing
into scan/while/cond/pjit/shard_map/custom_vjp sub-jaxprs) and returns the
largest intermediate, input, constant or output aval in elements, so tests
and benchmarks can assert ``max_aval_elements(mvm_fn, x, key) << m * n``
without paying for (or being able to afford) a real A-sized buffer.

Note the per-device view: inside a ``shard_map`` sub-jaxpr the avals are the
per-device block shapes, which is exactly the bound that matters -- a global
array sharded 8 ways shows up as its (A/8)-sized local aval, while a true
A-sized materialization shows up full size on the offending equation.

The traversal itself lives in :mod:`repro.analysis.verify` -- the shared
IR walker behind every invariant pass -- so there is exactly one
implementation of sub-jaxpr discovery.  (The original walker here missed
jaxprs reached through dict or nested-container params and the
``custom_vjp`` forward rule hidden behind ``fwd_jaxpr_thunk``; see
tests/test_verify.py::TestWalkerRegressions for the known-bad programs.)
For a reporting variant that also names the offending equation and source
line, use :func:`repro.analysis.verify.aval_bound`.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.analysis.verify import jaxpr_max_elements

__all__ = ["max_aval_elements", "jaxpr_max_elements"]


def max_aval_elements(fn, *args: Any, **kwargs: Any) -> int:
    """Largest array (in elements) the traced ``fn(*args)`` can ever hold.

    ``args`` may be real arrays or ``jax.ShapeDtypeStruct`` placeholders --
    nothing executes and nothing is allocated; only the trace is inspected.
    """
    return jaxpr_max_elements(jax.make_jaxpr(fn)(*args, **kwargs))
