"""Static and post-hoc analysis: jaxpr invariants, HLO cost, rooflines.

``repro.analysis.verify`` is the jaxpr invariant verifier (the one IR
walker plus the AvalBound / DispatchCount / KeyReuse / PrecisionLint /
CollectiveAudit passes); ``repro.analysis.pipelines`` registers the
canonical pipeline matrix those passes are run over by
``tools/check_invariants.py``.  See DESIGN.md section 10.
"""
from repro.analysis.memory import jaxpr_max_elements, max_aval_elements
from repro.analysis.verify import (
    CallCounter,
    Report,
    Site,
    Violation,
    aval_bound,
    collective_audit,
    dispatch_count,
    key_reuse,
    precision_lint,
    run_all,
    trace,
)

__all__ = [
    "CallCounter",
    "Report",
    "Site",
    "Violation",
    "aval_bound",
    "collective_audit",
    "dispatch_count",
    "jaxpr_max_elements",
    "key_reuse",
    "max_aval_elements",
    "precision_lint",
    "run_all",
    "trace",
]
