"""Three-term roofline from a compiled dry-run artifact (DESIGN.md section 8).

TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link.
cost_analysis()/memory_analysis() are per-device (the SPMD-partitioned
program), so terms are per-chip directly:

    compute    = HLO_FLOPs / peak
    memory     = HLO_bytes_accessed / HBM_bw
    collective = per-device wire bytes (hlo_parse) / ICI link bw

The dominant term is the bottleneck the perf loop iterates on; the ratio
MODEL_FLOPS/(chips * HLO_FLOPs) exposes remat/redundancy waste; roofline
fraction = useful-compute time / dominant-term time.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import re

from .hlo_cost import analyze_hlo_text


def _cpu_bf16_artifact_bytes(hlo: str) -> float:
    """XLA-CPU has no native bf16 FMAs: it materializes fp32 twins of bf16
    weight stacks (hoisted out of the layer loop), which a TPU build never
    allocates.  Returns the largest such twin's bytes -- a conservative
    single-buffer adjustment to the reported peak (DESIGN.md section 8)."""
    bf16_param_dims = set()
    for m in re.finditer(r"=\s*bf16\[([0-9,]+)\][^=]*parameter\(", hlo):
        bf16_param_dims.add(m.group(1))
    # Distinct def sites: a gated MLP holds two such twins (wg, wu) live at
    # once, so sum the two largest distinct instruction outputs.
    sizes = []
    seen = set()
    for m in re.finditer(r"%([\w.\-]+)\s*=\s*f32\[([0-9,]+)\]", hlo):
        name, dims = m.group(1), m.group(2)
        if dims in bf16_param_dims and name not in seen:
            seen.add(name)
            n = 1
            for d in dims.split(","):
                n *= int(d)
            sizes.append(4.0 * n)
    sizes.sort(reverse=True)
    return float(sum(sizes[:2]))

__all__ = ["HW", "analyze_compiled", "roofline_terms", "format_row"]

HW = {
    "peak_flops": 197e12,     # bf16 / chip
    "hbm_bw": 819e9,          # bytes/s
    "ici_bw": 50e9,           # bytes/s/link
    "hbm_bytes": 16 * 1024**3,
}


def analyze_compiled(compiled, n_devices: int,
                     model_flops: Optional[float] = None) -> Dict[str, Any]:
    # XLA's cost_analysis counts while bodies once; the loop-aware walker in
    # hlo_cost scales by trip count (and catches collectives inside scans).
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):   # jax < 0.5: one dict per device
        xla_cost = xla_cost[0] if xla_cost else {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    hc = analyze_hlo_text(hlo)
    wire, by_op = hc.wire, hc.wire_by_op

    flops = float(hc.flops)
    bytes_acc = float(hc.bytes)
    peak_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                  + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    artifact = _cpu_bf16_artifact_bytes(hlo)
    peak_tpu = max(peak_bytes - artifact, 0.0)

    out = {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collective_wire_bytes": wire,
        "collective_by_op": by_op,
        "xla_unscaled_flops": float(xla_cost.get("flops", 0.0)),
        "xla_unscaled_bytes": float(xla_cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": peak_bytes,
            "cpu_bf16_artifact_bytes": artifact,
            "peak_bytes_tpu": peak_tpu,
            "fits_hbm": bool(peak_tpu <= HW["hbm_bytes"]),
            "fits_hbm_raw_cpu": bool(peak_bytes <= HW["hbm_bytes"]),
        },
        "n_devices": n_devices,
    }
    out.update(roofline_terms(flops, bytes_acc, wire))
    if model_flops:
        per_dev_useful = model_flops / n_devices
        out["model_flops"] = model_flops
        out["useful_ratio"] = (per_dev_useful / flops) if flops else 0.0
        out["useful_time_s"] = per_dev_useful / HW["peak_flops"]
        dom = out["dominant_time_s"]
        out["roofline_fraction"] = (out["useful_time_s"] / dom) if dom else 0.0
    return out


def roofline_terms(flops: float, bytes_acc: float, wire: float) -> Dict[str, Any]:
    t_c = flops / HW["peak_flops"]
    t_m = bytes_acc / HW["hbm_bw"]
    t_x = wire / HW["ici_bw"]
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    return {**terms, "dominant": dom.replace("_s", ""),
            "dominant_time_s": terms[dom]}


def format_row(name: str, r: Dict[str, Any]) -> str:
    return (f"| {name} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant']} | "
            f"{r.get('useful_ratio', 0):.3f} | "
            f"{r.get('roofline_fraction', 0):.3f} | "
            f"{r['memory']['peak_bytes'] / 2**30:.2f} GiB |")
