"""Generate the EXPERIMENTS.md dry-run/roofline tables from the per-cell
JSONs written by launch/dryrun.py.

    PYTHONPATH=src python -m repro.analysis.report            # print tables
    PYTHONPATH=src python -m repro.analysis.report --write    # update EXPERIMENTS.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")
EXPERIMENTS_MD = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                              "EXPERIMENTS.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "mvm_65536"]
ARCH_ORDER = ["rwkv6-1.6b", "zamba2-1.2b", "whisper-tiny", "yi-9b",
              "qwen3-1.7b", "nemotron-4-15b", "qwen3-8b", "mixtral-8x7b",
              "phi3.5-moe-42b-a6.6b", "llama-3.2-vision-11b", "meliso-mvm"]


def load(tag_filter=None) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        with open(path) as f:
            r = json.load(f)
        r["_id"] = base
        r["_tag"] = "v0" if "_v0-" in base else ("rram" if base.endswith("_rram")
                                                 else "")
        recs.append(r)
    return recs


def _key(r):
    a = ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99
    s = SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 99
    return (a, s, len(r["mesh"]))


def dryrun_table(recs) -> str:
    rows = ["| cell | mesh | kind | fits HBM | peak GiB/dev | compile s | "
            "collectives (wire B/dev) |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=_key):
        if r["_tag"]:
            continue
        mesh = "x".join(str(m) for m in r["mesh"])
        coll = r.get("collective_by_op", {})
        coll_s = " ".join(f"{k.replace('collective-','')}:{v:.2e}"
                          for k, v in sorted(coll.items())) or "-"
        mem = r["memory"]
        peak = mem.get("peak_bytes_tpu", mem["peak_bytes"])
        note = ("*" if mem.get("cpu_bf16_artifact_bytes", 0) > 1e9 else "")
        rows.append(
            f"| {r['arch']} x {r['shape']} | {mesh} | {r['kind']} | "
            f"{'yes' if mem['fits_hbm'] else 'NO'} | "
            f"{peak/2**30:.2f}{note} | {r.get('compile_s', 0):.1f} | "
            f"{coll_s} |")
    return "\n".join(rows)


def roofline_table(recs, multi_pod=False) -> str:
    rows = ["| cell | compute s | memory s | collective s | dominant | "
            "MODEL/HLO flops | roofline frac |",
            "|---|---|---|---|---|---|---|"]
    want = 3 if multi_pod else 2
    for r in sorted(recs, key=_key):
        if r["_tag"] or len(r["mesh"]) != want:
            continue
        rows.append(
            f"| {r['arch']} x {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['dominant']}** | {r.get('useful_ratio', 0):.3f} | "
            f"{r.get('roofline_fraction', 0):.3f} |")
    return "\n".join(rows)


def splice(md: str, marker: str, table: str) -> str:
    begin, end = f"<!-- BEGIN {marker} -->", f"<!-- END {marker} -->"
    pattern = re.compile(re.escape(begin) + r".*?" + re.escape(end), re.S)
    return pattern.sub(begin + "\n" + table + "\n" + end, md)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args()
    recs = load()
    t_dry = dryrun_table(recs)
    t_roof = roofline_table(recs, multi_pod=False)
    t_roof_mp = roofline_table(recs, multi_pod=True)
    if args.write:
        with open(EXPERIMENTS_MD) as f:
            md = f.read()
        md = splice(md, "DRYRUN_TABLE", t_dry)
        md = splice(md, "ROOFLINE_TABLE", t_roof)
        md = splice(md, "ROOFLINE_TABLE_MULTIPOD", t_roof_mp)
        with open(EXPERIMENTS_MD, "w") as f:
            f.write(md)
        print(f"updated {EXPERIMENTS_MD} with {len(recs)} cells")
    else:
        print("## Dry-run\n" + t_dry)
        print("\n## Roofline (single-pod)\n" + t_roof)
        print("\n## Roofline (multi-pod)\n" + t_roof_mp)


if __name__ == "__main__":
    main()
