"""The canonical pipeline matrix the invariant verifier runs over.

Every claim-bearing execution path of the engine -- placement (local /
streamed / distributed) x pipeline (MVM / solve) x direction (forward /
rmatvec) x backend (reference / pallas), plus CG and PDHG end-to-end
solve cores -- is registered here as a :class:`PipelineSpec` whose
``build()`` produces a traceable closure and ``ShapeDtypeStruct``
argument specs.  Nothing numeric runs when a pipeline is *verified*:
the closure is traced with :func:`jax.make_jaxpr` and the five passes
of :mod:`repro.analysis.verify` inspect the jaxpr (building a spec may
program a small resident image once).

The distributed ``resident=False`` entries trace the paper-scale regime
-- a virtual 65,536^2 operator (2048-capacity blocks, a 32 x 32 block
grid) whose content is an :class:`~repro.core.matrices.ImplicitBandedMatrix`
producer -- and prove statically that no device ever holds more than a
few capacity blocks, that a warm MVM is a single dispatch with zero
producer re-invocations, and that the only collectives are psums over
the declared mesh axes.

``tools/check_invariants.py`` runs :func:`verify_pipeline` over
:func:`registered_pipelines` and compares :func:`manifest_record`
output against the checked-in ``INVARIANTS.json``.  To add a pipeline:
append a :class:`PipelineSpec` in :func:`registered_pipelines`, then
re-generate the manifest with ``tools/check_invariants.py --update``.
See DESIGN.md section 10 and docs/analysis.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import verify as V

#: virtual paper-scale operator: n^2 = 4.29e9 elements, never materialized
VIRTUAL_N = 65_536
VIRTUAL_CAP = 2_048


@dataclasses.dataclass
class BuiltPipeline:
    """A traceable pipeline: closure + arg specs (+ producer counter)."""

    fn: Callable
    args: Tuple[Any, ...]
    producer: Optional[V.CallCounter] = None
    allowed_axes: Tuple[str, ...] = ()

    def trace(self) -> Tuple[Any, Optional[int]]:
        """(jaxpr, trace-time producer calls); nothing executes."""
        before = self.producer.calls if self.producer is not None else 0
        jaxpr = V.trace(self.fn, *self.args)
        calls = (self.producer.calls - before
                 if self.producer is not None else None)
        return jaxpr, calls


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """One registered placement x pipeline x direction x backend config."""

    name: str
    placement: str            # local | streamed | distributed
    direction: str            # forward | rmatvec | solve
    backend: str              # reference | pallas
    build: Callable[[], BuiltPipeline]
    min_devices: int = 1
    aval_budget: int = 0
    max_top_level: int = 8
    max_producer_calls: Optional[int] = None
    per_device_budget: Optional[int] = None
    allow_baked: bool = False


def _key() -> jax.Array:
    return jax.random.PRNGKey(7)


def _key_spec() -> jax.ShapeDtypeStruct:
    k = _key()
    return jax.ShapeDtypeStruct(k.shape, k.dtype)


def _vec(n: int, batch: Optional[int] = None) -> jax.ShapeDtypeStruct:
    shape = (n,) if batch is None else (n, batch)
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _small_cfg():
    from repro.core import CrossbarConfig, MCAGeometry, get_device
    return CrossbarConfig(device=get_device("taox-hfox"),
                          geom=MCAGeometry(2, 2, 32, 32), k_iters=5, ec=True)


def _virtual_cfg():
    from repro.core import CrossbarConfig, MCAGeometry, get_device
    return CrossbarConfig(device=get_device("taox-hfox"),
                          geom=MCAGeometry(4, 4, 512, 512), k_iters=5,
                          ec=True)


def _mesh(shape: Tuple[int, int]):
    from repro.launch.mesh import make_mesh
    return make_mesh(shape, ("data", "model"))


def _banded(n: int, cap: int, seed: int = 2):
    from repro.core.matrices import ImplicitBandedMatrix
    return ImplicitBandedMatrix(n=n, cap_m=cap, cap_n=cap, seed=seed)


def _build_local(backend: str, transpose: bool) -> BuiltPipeline:
    from repro.engine import AnalogEngine
    cfg = _small_cfg()
    engine = AnalogEngine(cfg, backend=backend)
    key = _key()
    a = jax.random.normal(key, (100, 90), jnp.float32) / 10
    A = engine.program(a, key)
    n_in = a.shape[0] if transpose else a.shape[1]
    return BuiltPipeline(fn=engine.mvm_fn(A, transpose=transpose),
                        args=(_vec(n_in), _key_spec()))


def _build_local_aged() -> BuiltPipeline:
    """Local reference forward MVM with an :class:`AgeLedger` attached:
    drift + replayable stuck-at faults applied to the image INSIDE the one
    jitted execute (DESIGN.md section 12).  Pinned so aging can never
    regress into extra dispatches or key consumptions vs the fresh path."""
    from repro.engine import AnalogEngine
    from repro.reliability.aging import attach_age
    cfg = _small_cfg()
    engine = AnalogEngine(cfg, backend="reference")
    key = _key()
    a = jax.random.normal(key, (100, 90), jnp.float32) / 10
    A = engine.program(a, key)
    attach_age(A)
    A.age = A.age.advanced(1_000).elapsed(3600.0)   # a visibly aged image
    return BuiltPipeline(fn=engine.mvm_fn(A),
                        args=(_vec(a.shape[1]), _key_spec()))


def _build_group(backend: str, transpose: bool) -> BuiltPipeline:
    """Grouped multi-image execution (DESIGN.md section 13): eight
    same-geometry images stacked by ``program_group`` and executed as ONE
    top-level dispatch -- the tentpole claim the DispatchCount pass pins
    (``max_top_level=1``)."""
    from repro.engine import AnalogEngine
    cfg = _small_cfg()
    engine = AnalogEngine(cfg, backend=backend)
    key = _key()
    stack = jax.random.normal(key, (8, 100, 90), jnp.float32) / 10
    G = engine.program_group(stack, key)
    n_in = stack.shape[1] if transpose else stack.shape[2]
    return BuiltPipeline(
        fn=jax.jit(engine.group_mvm_fn(G, transpose=transpose)),
        args=(_vec(n_in), _key_spec()))


def _build_group_moe() -> BuiltPipeline:
    """Eight MoE expert FFN kernels -- a pytree, not a pre-stacked array --
    grouped into one image and executed as a single dispatch: the
    one-launch-per-layer-group pattern a whole analog MoE forward uses."""
    from repro.engine import AnalogEngine
    cfg = _small_cfg()
    engine = AnalogEngine(cfg, backend="reference")
    key = _key()
    stack = jax.random.normal(key, (8, 64, 128), jnp.float32) / 10
    experts = {f"expert_{g}": stack[g] for g in range(stack.shape[0])}
    G = engine.program_group(experts, key)
    return BuiltPipeline(fn=jax.jit(engine.group_mvm_fn(G)),
                        args=(_vec(stack.shape[2]), _key_spec()))


def _build_chain(backend: str) -> BuiltPipeline:
    """The whole-model analog forward: eight square layers chained through
    ``lax.scan`` with a relu between members -- activation in, logits out,
    ONE device dispatch (``engine.chain_mvm``)."""
    from repro.engine import AnalogEngine
    cfg = _small_cfg()
    engine = AnalogEngine(cfg, backend=backend)
    key = _key()
    stack = jax.random.normal(key, (8, 96, 96), jnp.float32) / 10
    G = engine.program_group(stack, key)
    return BuiltPipeline(fn=jax.jit(engine.chain_fn(G, activation="relu")),
                        args=(_vec(stack.shape[2]), _key_spec()))


def _build_streamed(backend: str, transpose: bool) -> BuiltPipeline:
    from repro.engine import AnalogEngine
    cfg = _small_cfg()
    cap = cfg.geom.capacity[0]                       # 64
    n = 4 * cap                                      # 4 x 4 block grid
    engine = AnalogEngine(cfg, execution="streamed", backend=backend)
    producer = V.CallCounter(_banded(n, cap).block)
    A = engine.program(producer, _key(), shape=(n, n))
    return BuiltPipeline(fn=engine.mvm_fn(A, transpose=transpose),
                        args=(_vec(n), _key_spec()), producer=producer)


def _build_distributed_dense(backend: str, transpose: bool,
                             mesh_shape: Tuple[int, int]) -> BuiltPipeline:
    from repro.engine import AnalogEngine
    cfg = _small_cfg()
    cap = cfg.geom.capacity[0]
    n = 2 * cap * max(mesh_shape)                    # divides every mesh dim
    engine = AnalogEngine(cfg, execution="distributed", backend=backend,
                          mesh=_mesh(mesh_shape))
    key = _key()
    a = jax.random.normal(key, (n, n), jnp.float32) / float(n)
    A = engine.program(a, key)
    return BuiltPipeline(fn=engine.mvm_fn(A, transpose=transpose),
                        args=(_vec(n), _key_spec()),
                        allowed_axes=engine.collective_axes)


def _build_virtual(backend: str, transpose: bool,
                   mesh_shape: Tuple[int, int]) -> BuiltPipeline:
    """Paper-scale distributed resident=False producer pipeline."""
    from repro.engine import AnalogEngine
    cfg = _virtual_cfg()
    engine = AnalogEngine(cfg, execution="distributed", backend=backend,
                          mesh=_mesh(mesh_shape))
    producer = V.CallCounter(_banded(VIRTUAL_N, VIRTUAL_CAP).block)
    A = engine.program(producer, _key(), shape=(VIRTUAL_N, VIRTUAL_N),
                       resident=False)
    return BuiltPipeline(fn=engine.mvm_fn(A, transpose=transpose),
                        args=(_vec(VIRTUAL_N), _key_spec()),
                        producer=producer,
                        allowed_axes=engine.collective_axes)


def _build_cg() -> BuiltPipeline:
    from repro.engine import AnalogEngine
    from repro.solvers import as_operator, cg_pipeline
    cfg = _small_cfg()
    cap = cfg.geom.capacity[0]
    n = 4 * cap
    engine = AnalogEngine(cfg, execution="streamed")
    producer = V.CallCounter(_banded(n, cap).block)
    A = engine.program(producer, _key(), shape=(n, n))
    core = cg_pipeline(as_operator(A), tol=1e-5, maxiter=50)
    return BuiltPipeline(fn=core,
                        args=(_vec(n, 1), _vec(n, 1), _key_spec()),
                        producer=producer)


def _build_pdhg(mesh_shape: Tuple[int, int]) -> BuiltPipeline:
    """End-to-end PDHG LP core over the virtual 65,536^2 operator."""
    from repro.engine import AnalogEngine
    from repro.solvers import as_operator, pdhg_pipeline
    cfg = _virtual_cfg()
    engine = AnalogEngine(cfg, execution="distributed",
                          mesh=_mesh(mesh_shape))
    producer = V.CallCounter(_banded(VIRTUAL_N, VIRTUAL_CAP).block)
    A = engine.program(producer, _key(), shape=(VIRTUAL_N, VIRTUAL_N),
                       resident=False)
    core = pdhg_pipeline(as_operator(A), tau=0.1, sigma=0.1, tol=1e-4,
                         maxiter=100)
    n = VIRTUAL_N
    return BuiltPipeline(
        fn=core,
        args=(_vec(n, 1), _vec(n, 1), _vec(n, 1), _vec(n, 1), _key_spec()),
        producer=producer, allowed_axes=engine.collective_axes)


def _build_lsqr() -> BuiltPipeline:
    """End-to-end LSQR least-squares core over a streamed producer: the
    whole Golub-Kahan bidiagonalization solve is ONE traced program."""
    from repro.engine import AnalogEngine
    from repro.solvers import as_operator, lsqr_pipeline
    cfg = _small_cfg()
    cap = cfg.geom.capacity[0]
    n = 4 * cap
    engine = AnalogEngine(cfg, execution="streamed")
    producer = V.CallCounter(_banded(n, cap).block)
    A = engine.program(producer, _key(), shape=(n, n))
    core = lsqr_pipeline(as_operator(A), tol=1e-5, maxiter=50)
    return BuiltPipeline(fn=core,
                        args=(_vec(n, 1), _vec(n, 1), _key_spec()),
                        producer=producer)


def _build_lanczos() -> BuiltPipeline:
    """Lanczos extremal-eigenpair sweep (power-iteration seed included)
    over a streamed producer, one traced program, ``(key)`` in."""
    from repro.engine import AnalogEngine
    from repro.solvers import as_operator, lanczos_pipeline
    cfg = _small_cfg()
    cap = cfg.geom.capacity[0]
    n = 4 * cap
    engine = AnalogEngine(cfg, execution="streamed")
    producer = V.CallCounter(_banded(n, cap).block)
    A = engine.program(producer, _key(), shape=(n, n))
    core = lanczos_pipeline(as_operator(A), tol=1e-4, maxiter=24)
    return BuiltPipeline(fn=core, args=(_key_spec(),), producer=producer)


def _build_admm() -> BuiltPipeline:
    """Linearized-ADMM box-QP core (one matvec + one rmatvec per
    iteration, power-iteration step-size estimate traced in) over a
    streamed producer."""
    from repro.engine import AnalogEngine
    from repro.solvers import admm_pipeline, as_operator
    cfg = _small_cfg()
    cap = cfg.geom.capacity[0]
    n = 4 * cap
    engine = AnalogEngine(cfg, execution="streamed")
    producer = V.CallCounter(_banded(n, cap).block)
    A = engine.program(producer, _key(), shape=(n, n))
    core = admm_pipeline(as_operator(A), lo=-jnp.ones((n,), jnp.float32),
                         hi=jnp.ones((n,), jnp.float32), tol=1e-4,
                         maxiter=100)
    return BuiltPipeline(
        fn=core,
        args=(_vec(n, 1), _vec(n, 1), _vec(n, 1), _key_spec()),
        producer=producer)


def _build_lstsq_virtual(mesh_shape: Tuple[int, int]) -> BuiltPipeline:
    """The paper-scale least-squares acceptance pattern: LSQR over the
    virtual 65,536^2 resident=False operator -- the static proof that a
    whole multi-RHS least-squares solve never materializes an A-sized
    aval on any device of the mesh."""
    from repro.engine import AnalogEngine
    from repro.solvers import as_operator, lsqr_pipeline
    cfg = _virtual_cfg()
    engine = AnalogEngine(cfg, execution="distributed",
                          mesh=_mesh(mesh_shape))
    producer = V.CallCounter(_banded(VIRTUAL_N, VIRTUAL_CAP).block)
    A = engine.program(producer, _key(), shape=(VIRTUAL_N, VIRTUAL_N),
                       resident=False)
    core = lsqr_pipeline(as_operator(A), tol=1e-4, maxiter=50)
    n = VIRTUAL_N
    return BuiltPipeline(fn=core,
                        args=(_vec(n, 1), _vec(n, 1), _key_spec()),
                        producer=producer,
                        allowed_axes=engine.collective_axes)


def _build_serving_decode() -> BuiltPipeline:
    """The serving decode hot path: an analog LM Server's ENTIRE n-token
    greedy decode as one ``lax.scan`` -- the fused pipeline every
    :mod:`repro.serving` batch dispatches exactly once (see DESIGN.md
    section 11)."""
    from repro.configs.base import RRAMBackendConfig
    from repro.configs.registry import get_arch, model_module
    from repro.models import params as P
    from repro.models.common import Runtime
    from repro.train.serve import Server
    cfg = get_arch("rwkv6-1.6b").reduced()
    mod = model_module(cfg)
    prm = P.materialize(mod.init_specs(cfg), _key(), jnp.float32)
    srv = Server(mod, cfg, prm,
                 rt=Runtime(rram=RRAMBackendConfig(enabled=True)),
                 max_len=32, key=_key())
    caches = jax.eval_shape(lambda: mod.init_caches(2, cfg))
    tok = jax.ShapeDtypeStruct((2, 1), jnp.int32)
    return BuiltPipeline(fn=srv.decode_fn(8), args=(tok, caches))


def _cap2(cfg_fn: Callable) -> int:
    from repro.core.crossbar import capacity_elements
    return capacity_elements(cfg_fn())


def registered_pipelines() -> List[PipelineSpec]:
    """The canonical matrix, in a stable order (the manifest order)."""
    small = _cap2(_small_cfg)          # 64 x 64 capacity blocks
    virt = _cap2(_virtual_cfg)         # 2048 x 2048 capacity blocks
    specs: List[PipelineSpec] = []

    for backend in ("reference", "pallas"):
        for transpose, direction in ((False, "forward"), (True, "rmatvec")):
            specs.append(PipelineSpec(
                name=f"local-{direction}-{backend}",
                placement="local", direction=direction, backend=backend,
                build=(lambda b=backend, t=transpose: _build_local(b, t)),
                aval_budget=64 * small))
            specs.append(PipelineSpec(
                name=f"streamed-{direction}-{backend}",
                placement="streamed", direction=direction, backend=backend,
                build=(lambda b=backend, t=transpose: _build_streamed(b, t)),
                aval_budget=64 * small, max_producer_calls=3,
                allow_baked=True))

    group_budget = 8 * 64 * small       # an 8-member group of small images
    for backend in ("reference", "pallas"):
        for transpose, direction in ((False, "forward"), (True, "rmatvec")):
            specs.append(PipelineSpec(
                name=f"group-{direction}-{backend}",
                placement="local", direction=direction, backend=backend,
                build=(lambda b=backend, t=transpose: _build_group(b, t)),
                aval_budget=group_budget, max_top_level=1,
                allow_baked=True))
        specs.append(PipelineSpec(
            name=f"group-chain-wholemodel-{backend}",
            placement="local", direction="forward", backend=backend,
            build=(lambda b=backend: _build_chain(b)),
            aval_budget=group_budget, max_top_level=1, allow_baked=True))
    specs.append(PipelineSpec(
        name="group-moe-experts-reference",
        placement="local", direction="forward", backend="reference",
        build=_build_group_moe, aval_budget=group_budget,
        max_top_level=1, allow_baked=True))

    specs.append(PipelineSpec(
        name="local-aged-forward-reference",
        placement="local", direction="forward", backend="reference",
        build=_build_local_aged, aval_budget=64 * small,
        allow_baked=True))

    for transpose, direction in ((False, "forward"), (True, "rmatvec")):
        specs.append(PipelineSpec(
            name=f"distributed-{direction}-reference",
            placement="distributed", direction=direction,
            backend="reference",
            build=(lambda t=transpose: _build_distributed_dense(
                "reference", t, (1, 1))),
            aval_budget=64 * small, per_device_budget=64 * small))

    for mesh_shape, min_dev in (((1, 1), 1), ((2, 4), 8)):
        tag = f"{mesh_shape[0]}x{mesh_shape[1]}"
        for transpose, direction in ((False, "forward"), (True, "rmatvec")):
            specs.append(PipelineSpec(
                name=f"distributed-virtual65536-{direction}-{tag}",
                placement="distributed", direction=direction,
                backend="reference",
                build=(lambda t=transpose, s=mesh_shape: _build_virtual(
                    "reference", t, s)),
                min_devices=min_dev,
                aval_budget=16 * virt,               # << 65,536^2 = 1024*virt
                max_producer_calls=3,
                per_device_budget=16 * virt,
                allow_baked=True))

    specs.append(PipelineSpec(
        name="solve-cg-streamed-reference",
        placement="streamed", direction="solve", backend="reference",
        build=_build_cg, aval_budget=64 * small, max_producer_calls=3,
        max_top_level=24, allow_baked=True))
    specs.append(PipelineSpec(
        name="serving-decode-fused-rwkv6",
        placement="local", direction="decode", backend="reference",
        build=_build_serving_decode, aval_budget=1 << 20,
        max_top_level=1, allow_baked=True))
    specs.append(PipelineSpec(
        name="solve-pdhg-distributed-virtual65536-1x1",
        placement="distributed", direction="solve", backend="reference",
        build=(lambda: _build_pdhg((1, 1))),
        aval_budget=16 * virt, max_producer_calls=8, max_top_level=64,
        per_device_budget=16 * virt, allow_baked=True))
    specs.append(PipelineSpec(
        name="solve-lsqr-streamed-reference",
        placement="streamed", direction="solve", backend="reference",
        build=_build_lsqr, aval_budget=64 * small, max_producer_calls=6,
        max_top_level=48, allow_baked=True))
    specs.append(PipelineSpec(
        name="solve-lanczos-streamed-reference",
        placement="streamed", direction="solve", backend="reference",
        build=_build_lanczos, aval_budget=64 * small, max_producer_calls=6,
        max_top_level=48, allow_baked=True))
    specs.append(PipelineSpec(
        name="solve-admm-streamed-reference",
        placement="streamed", direction="solve", backend="reference",
        build=_build_admm, aval_budget=64 * small, max_producer_calls=8,
        max_top_level=64, allow_baked=True))
    specs.append(PipelineSpec(
        name="solve-lstsq-distributed-virtual65536-2x4",
        placement="distributed", direction="solve", backend="reference",
        build=(lambda: _build_lstsq_virtual((2, 4))),
        min_devices=8,
        aval_budget=16 * virt, max_producer_calls=8, max_top_level=48,
        per_device_budget=16 * virt, allow_baked=True))
    return specs


def available_pipelines() -> List[PipelineSpec]:
    """Registered pipelines runnable on this host's device count."""
    n_dev = len(jax.devices())
    return [p for p in registered_pipelines() if p.min_devices <= n_dev]


def verify_pipeline(spec: PipelineSpec) -> Dict[str, V.Report]:
    """Build, trace, and run all five passes over one registered pipeline."""
    built = spec.build()
    jaxpr, producer_calls = built.trace()
    return V.run_all(
        jaxpr,
        aval_budget=spec.aval_budget or None,
        max_top_level=spec.max_top_level,
        producer_calls=producer_calls,
        max_producer_calls=spec.max_producer_calls,
        allowed_axes=built.allowed_axes or None,
        per_device_budget=spec.per_device_budget,
        allow_baked=spec.allow_baked)


def manifest_record(spec: PipelineSpec,
                    reports: Dict[str, V.Report]) -> Dict[str, Any]:
    """The JSON-able row ``INVARIANTS.json`` stores for one pipeline."""
    ab = reports["AvalBound"].summary
    dc = reports["DispatchCount"].summary
    kr = reports["KeyReuse"].summary
    pl = reports["PrecisionLint"].summary
    ca = reports["CollectiveAudit"].summary
    return {
        "name": spec.name,
        "placement": spec.placement,
        "direction": spec.direction,
        "backend": spec.backend,
        "min_devices": spec.min_devices,
        "max_elements": ab["max_elements"],
        "aval_budget": spec.aval_budget,
        "top_level_eqns": dc["top_level_eqns"],
        "dispatch_boundaries": dc["dispatch_boundaries"],
        "producer_calls": dc.get("producer_calls"),
        "key_consumptions": kr["consumptions"],
        "distinct_keys": kr["distinct_keys"],
        "psums": ca["psums"],
        "gathers": ca["gathers"],
        "violations": sorted(
            str(v) for r in reports.values() for v in r.violations),
    }
