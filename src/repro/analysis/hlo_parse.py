"""Collective-byte accounting from compiled (post-SPMD) HLO text.

cost_analysis() does not expose collective traffic, so we parse the optimized
HLO: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction, with its output shape(s) and replica group
size, converted to *per-device wire bytes* under ring-algorithm assumptions:

    all-gather        G (g-1)/g        G = gathered (output) bytes
    reduce-scatter    G (g-1)/g        G = unreduced (g x output) bytes
    all-reduce        2 G (g-1)/g      (reduce-scatter + all-gather)
    all-to-all        G (g-1)/g        G = output bytes
    collective-permute  G              one send

The compiled module is the per-device SPMD program, so the sum is already
per-device; the roofline collective term divides by one ICI link bandwidth
(the bottleneck-link serialization assumption, DESIGN.md section 8).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["parse_collectives", "collective_wire_bytes", "count_op"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> List[Dict]:
    """One record per collective instruction (``-done`` halves skipped)."""
    out = []
    for line in hlo_text.splitlines():
        if "-done" in line.split("=")[-1][:60]:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        bytes_out = _shape_bytes(m.group("out"))
        g = max(_group_size(line), 1)
        if op == "all-gather":
            wire = bytes_out * (g - 1) / g
        elif op == "reduce-scatter":
            wire = bytes_out * (g - 1)          # G = g * output
        elif op == "all-reduce":
            wire = 2 * bytes_out * (g - 1) / g
        elif op == "all-to-all":
            wire = bytes_out * (g - 1) / g
        else:  # collective-permute
            wire = bytes_out
        out.append({"op": op, "bytes": bytes_out, "group": g, "wire": wire})
    return out


def collective_wire_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """(total per-device wire bytes, per-op-type breakdown)."""
    recs = parse_collectives(hlo_text)
    by_op: Dict[str, float] = {}
    for r in recs:
        by_op[r["op"]] = by_op.get(r["op"], 0.0) + r["wire"]
    return sum(by_op.values()), by_op


def count_op(hlo_text: str, opname: str) -> int:
    return len([l for l in hlo_text.splitlines()
                if re.search(rf"=\s*[^=]*\b{re.escape(opname)}\(", l)])
