"""Loop-aware HLO cost model (flops / HBM bytes / collective wire bytes).

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once* --
useless for scan-over-layers programs (a 48-layer scan is undercounted 48x),
and the same holds for collectives inside loops.  This walker parses the
optimized (post-SPMD) HLO text, builds the computation call graph, and
evaluates costs bottom-up with **while-loop trip-count scaling** (trip counts
recovered from the loop-condition compare constants, which is exactly how JAX
lowers ``lax.scan``).

Cost conventions (documented in DESIGN.md section 8):
  * dot:      2 * prod(output dims) * prod(contraction dims) flops
  * fusion:   inner flops, boundary-only bytes (fused temporaries are free)
  * DUS/DS:   update/slice bytes (in-place semantics), not the full buffer
  * gather/scatter: 2x output/update bytes
  * collectives: ring wire-bytes model (see hlo_parse) x trip count
  * elementwise/reduce: 1 flop per output element (matmuls dominate anyway)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo_text", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
    "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\((?P<operands>[^)]*)\)(?P<attrs>.*)$")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                      r"(?:\{)?%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")

_ZERO_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "reshape", "domain",
    "opt-barrier", "add-dependency",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_dims(txt: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(txt: str) -> float:
    total = 0.0
    for dt, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(txt: str) -> float:
    total = 0.0
    for _, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    wire: float = 0.0
    wire_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "HloCost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire += o.wire
        for k, v in o.wire_by_op.items():
            self.wire_by_op[k] = self.wire_by_op.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k, self.wire * k,
                       {kk: v * k for kk, v in self.wire_by_op.items()})


def _parse_computations(text: str) -> Tuple[Dict[str, List[_Instr]], Optional[str]]:
    comps: Dict[str, List[_Instr]] = {}
    entry = None
    cur: Optional[str] = None
    comment = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment.sub("", raw).rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.strip().endswith("{"):
                cur = m.group("name")
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            # Operands print either bare (%name, %other) or typed
            # (f32[64,64]{1,0} %name, ...) depending on the XLA version; typed
            # shapes contain commas, so split-on-comma keeps the shape glued to
            # the name and every symtab lookup misses (dots then fall back to
            # contract=1 -- a silent 2*K flop undercount).  Pull the %names
            # directly when present.
            otxt = m.group("operands")
            ops = _OPERAND_NAME_RE.findall(otxt) or [
                o.strip() for o in otxt.split(",") if o.strip()]
            comps[cur].append(_Instr(
                name=m.group("name"), shape=m.group("shape"),
                op=m.group("op"), operands=ops, attrs=m.group("attrs"),
                line=line))
    return comps, entry


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _collective_wire(op: str, bytes_out: float, g: int) -> float:
    if op == "all-gather":
        return bytes_out * (g - 1) / g
    if op == "reduce-scatter":
        return bytes_out * (g - 1)
    if op == "all-reduce":
        return 2 * bytes_out * (g - 1) / g
    if op == "all-to-all":
        return bytes_out * (g - 1) / g
    return bytes_out   # collective-permute


def _trip_count(cond_instrs: List[_Instr]) -> int:
    """jax scans lower to while(cond: iv < C): the bound is the largest int
    constant in the condition computation."""
    best = 1
    for ins in cond_instrs:
        for c in _CONST_RE.findall(ins.line):
            best = max(best, int(c))
    return best


def _dot_flops(ins: _Instr, symtab: Dict[str, str]) -> float:
    out_elems = _numel(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs + ins.line)
    contract = 1.0
    if m and ins.operands:
        lhs_shape = symtab.get(ins.operands[0], "")
        dims = _shape_dims(lhs_shape)
        if dims:
            _, lhs_dims = dims[0]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze_hlo_text(text: str, record: Optional[List] = None) -> HloCost:
    """Evaluate the entry cost.  With ``record`` a list, also appends
    (scaled_bytes, scaled_flops, scaled_wire, op, name, shape[:80]) per leaf
    instruction -- the per-instruction profile used by the perf loop."""
    comps, entry = _parse_computations(text)
    if entry is None:
        return HloCost()
    memo: Dict[str, HloCost] = {}
    scale_of: Dict[str, float] = {entry: 1.0}

    # Pre-pass: propagate execution multiplicity down the call graph so the
    # recorder can attribute loop-scaled costs to leaf instructions.
    def propagate(name: str, scale: float, depth: int = 0):
        if name not in comps or depth > 64:
            return
        scale_of[name] = scale_of.get(name, 0.0) + scale if name != entry else 1.0
        for ins in comps[name]:
            if ins.op == "while":
                mt = _TRIP_RE.search(ins.line)
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = int(mt.group(1)) if mt else (
                    _trip_count(comps.get(mc.group(1), [])) if mc else 1)
                if mb:
                    propagate(mb.group(1), scale * trips, depth + 1)
                if mc:
                    propagate(mc.group(1), scale * trips, depth + 1)
            else:
                for target in _CALL_RE.findall(ins.line):
                    propagate(target, scale, depth + 1)

    fusion_bodies = set()
    for il in comps.values():
        for ins in il:
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if m:
                    fusion_bodies.add(m.group(1))

    if record is not None:
        propagate(entry, 1.0)

    def _root_op(name: str) -> str:
        for ins in comps.get(name, []):
            if "ROOT" in ins.line:
                return ins.op
        instrs = comps.get(name, [])
        return instrs[-1].op if instrs else ""

    def _dims_only(shape: str) -> str:
        return ",".join(d for _, ds in _shape_dims(shape) for d in map(str, ds))

    def _has_full_dus(name: str, out_shape: str) -> bool:
        want = _dims_only(out_shape)
        return any(i.op == "dynamic-update-slice"
                   and _dims_only(i.shape) == want
                   for i in comps.get(name, []))

    def _convert_only(name: str) -> bool:
        ok = {"parameter", "convert", "bitcast", "copy", "reshape"}
        instrs = comps.get(name, [])
        return bool(instrs) and all(i.op in ok for i in instrs)

    def comp_cost(name: str, depth: int = 0) -> HloCost:
        if name in memo:
            return memo[name]
        if name not in comps or depth > 64:
            return HloCost()
        memo[name] = HloCost()       # cycle guard
        total = HloCost()
        symtab = {i.name: i.shape for i in comps[name]}
        for ins in comps[name]:
            c = _instr_cost(ins, symtab, depth)
            if (record is not None and name not in fusion_bodies
                    and ins.op not in ("while", "call", "conditional")):
                sc = scale_of.get(name, 1.0)
                if c.bytes + c.flops + c.wire > 0:
                    record.append((c.bytes * sc, c.flops * sc, c.wire * sc,
                                   ins.op, ins.name, ins.shape[:80]))
            total += c
        memo[name] = total
        return total

    def _instr_cost(ins: _Instr, symtab: Dict[str, str], depth: int) -> HloCost:
        op = ins.op
        if op in _ZERO_OPS:
            return HloCost()
        out_b = _shape_bytes(ins.shape)
        in_b = sum(_shape_bytes(symtab.get(o, "")) for o in ins.operands)

        if op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", ins.line)
            mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
            body = mb.group(1) if mb else None
            cond = mc.group(1) if mc else None
            mt = _TRIP_RE.search(ins.line)
            if mt:
                trips = int(mt.group(1))      # XLA's own known_trip_count
            else:
                trips = _trip_count(comps.get(cond, [])) if cond else 1
            inner = HloCost()
            if body:
                inner += comp_cost(body, depth + 1)
            if cond:
                inner += comp_cost(cond, depth + 1)
            return inner.scaled(trips)

        if op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", ins.line)
            inner = comp_cost(m.group(1), depth + 1) if m else HloCost()
            boundary = out_b + in_b
            if m:
                called = m.group(1)
                # Fused dynamic-slice reads: an operand consumed through a
                # slice inside the fusion (per-layer weight/cache slices of a
                # stacked buffer) costs ~the slice, not the whole stack.
                if any(i.op in ("dynamic-slice", "slice", "gather")
                       for i in comps.get(called, [])):
                    boundary = out_b
                    for o in ins.operands:
                        ob = _shape_bytes(symtab.get(o, ""))
                        boundary += out_b if ob > 4 * out_b else ob
                # In-place loop accumulators: a fusion containing a full-size
                # dynamic-update-slice aliases its big operand with its output
                # (scan ys / KV-cache appends) -- real traffic is the updated
                # slice, not the whole buffer.  Count operands smaller than
                # the output, twice (read slice + write slice).
                if _has_full_dus(called, ins.shape):
                    small = sum(_shape_bytes(symtab.get(o, ""))
                                for o in ins.operands
                                if _shape_bytes(symtab.get(o, "")) < 0.5 * out_b)
                    boundary = 2 * small
                # Pure dtype-convert fusions: XLA-CPU materializes fp32 copies
                # around bf16 dots (no native bf16 FMA); TPU fuses converts
                # into producers/consumers, so they carry no HBM traffic.
                elif _convert_only(called):
                    boundary = 0.0
            return HloCost(inner.flops, boundary, inner.wire,
                           dict(inner.wire_by_op))

        if op in ("call", "custom-call"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.line)
            if m:
                inner = comp_cost(m.group(1), depth + 1)
                return HloCost(inner.flops, inner.bytes + out_b + in_b,
                               inner.wire, dict(inner.wire_by_op))
            return HloCost(0.0, out_b + in_b, 0.0)

        if op == "conditional":
            branches = _CALL_RE.findall(ins.line)
            inner = HloCost()
            for b in branches:
                c = comp_cost(b, depth + 1)
                if c.flops + c.bytes > inner.flops + inner.bytes:
                    inner = c
            inner = HloCost(inner.flops, inner.bytes + out_b + in_b,
                            inner.wire, dict(inner.wire_by_op))
            return inner

        if op in _COLLECTIVES or any(op == c + "-start" for c in _COLLECTIVES):
            base = op.replace("-start", "")
            g = _group_size(ins.line)
            wire = _collective_wire(base, out_b, g)
            return HloCost(0.0, out_b + in_b, wire, {base: wire})

        if op.endswith("-done"):
            return HloCost()

        if op == "dot":
            return HloCost(_dot_flops(ins, symtab), out_b + in_b, 0.0)

        if op == "convolution":
            # approximate: 2 * out_elems * (in_features * window) -- rare here
            return HloCost(2.0 * _numel(ins.shape) * 32, out_b + in_b, 0.0)

        if op in ("dynamic-update-slice",):
            upd = _shape_bytes(symtab.get(ins.operands[1], "")) if len(
                ins.operands) > 1 else out_b
            return HloCost(0.0, 2 * upd, 0.0)
        if op in ("dynamic-slice", "slice"):
            return HloCost(0.0, 2 * out_b, 0.0)
        if op in ("gather",):
            return HloCost(0.0, 2 * out_b, 0.0)
        if op in ("scatter",):
            return HloCost(_numel(ins.shape), 2 * in_b, 0.0)
        if op in ("copy", "copy-start"):
            # Layout-preserving copies of loop carries are aliasing-elided on
            # TPU (CPU HLO inserts them for copy-insertion correctness only);
            # layout-*changing* copies are physical transposes.
            if ins.operands:
                src = symtab.get(ins.operands[0], "")
                if src == ins.shape:
                    return HloCost()
            return HloCost(0.0, out_b + in_b, 0.0)
        if op in ("transpose", "broadcast", "iota",
                  "rng-bit-generator", "pad", "concatenate", "reverse"):
            return HloCost(0.0, out_b + in_b, 0.0)
        if op in ("copy-done",):
            return HloCost()
        if op in ("reduce", "reduce-window", "sort", "cholesky",
                  "triangular-solve"):
            return HloCost(max(in_b / 4.0, _numel(ins.shape)), out_b + in_b, 0.0)

        if op == "convert":
            # Standalone dtype casts: fused (free) on TPU -- XLA-CPU inserts
            # them around bf16 dots because it lacks native bf16 FMAs.
            return HloCost()

        # default elementwise
        return HloCost(_numel(ins.shape), out_b + in_b, 0.0)

    return comp_cost(entry)
