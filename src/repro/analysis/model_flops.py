"""Analytic MODEL_FLOPS per (arch x shape): the useful-compute yardstick for
the roofline's MODEL_FLOPS / HLO_FLOPS waste ratio.

train:   6 * N * tokens            (N = params; N_active for MoE)
prefill: 2 * N * tokens  + attention term
decode:  2 * N * batch   + attention term (KV length = context)

Attention term: 4 * B * L * H * Dh * S_kv per query token (QK^T and PV), with
the causal 1/2 factor for full-sequence passes; window-clipped for SWA.
Embedding-gather FLOPs are ignored (standard convention).
"""
from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs.base import SHAPES, ArchConfig
from repro.configs.registry import model_module
from repro.models import params as PM

__all__ = ["param_count", "active_param_count", "model_flops"]


def param_count(arch: ArchConfig) -> int:
    cfg = arch.model
    specs = model_module(cfg).init_specs(cfg)
    leaves = jax.tree.leaves(PM.abstract(specs))
    return int(sum(np.prod(l.shape) for l in leaves))


def active_param_count(arch: ArchConfig) -> int:
    """MoE: experts count only k/E of their parameters."""
    cfg = arch.model
    n = param_count(arch)
    if cfg.n_experts:
        expert_params = (cfg.n_layers * cfg.n_experts
                         * 3 * cfg.d_model * cfg.d_ff)
        frac = cfg.experts_per_token / cfg.n_experts
        n = n - int(expert_params * (1 - frac))
    return n


def _attn_flops(arch: ArchConfig, n_queries: int, s_kv: float) -> float:
    cfg = arch.model
    if cfg.family == "rwkv6":
        # WKV state update + readout: ~4 * H * Dk * Dv per token per layer.
        h = cfg.d_model // cfg.ssm_head_dim
        return 4.0 * n_queries * cfg.n_layers * h * cfg.ssm_head_dim ** 2
    if cfg.family == "zamba2":
        h = cfg.n_ssm_heads
        ssd = 4.0 * n_queries * cfg.n_layers * h * cfg.ssm_state * cfg.ssm_head_dim
        n_attn = max(cfg.n_layers // cfg.attn_every, 1)
        attn = 4.0 * n_queries * n_attn * cfg.n_heads * cfg.d_head * s_kv
        return ssd + attn
    l_attn = cfg.n_layers + cfg.n_enc_layers
    return 4.0 * n_queries * l_attn * cfg.n_heads * cfg.d_head * s_kv


def model_flops(arch: ArchConfig, shape_name: str) -> Dict[str, float]:
    shape = SHAPES[shape_name]
    cfg = arch.model
    n = param_count(arch)
    n_act = active_param_count(arch)
    b, s = shape.global_batch, shape.seq_len
    window = cfg.swa_window or s

    if shape.kind == "train":
        tokens = b * s
        dense_f = 6.0 * n_act * tokens
        attn_f = 3.0 * _attn_flops(arch, tokens, min(s, window) / 2)
        return {"model_flops": dense_f + attn_f, "params": n,
                "active_params": n_act, "tokens": tokens}
    if shape.kind == "prefill":
        tokens = b * s
        dense_f = 2.0 * n_act * tokens
        attn_f = _attn_flops(arch, tokens, min(s, window) / 2)
        return {"model_flops": dense_f + attn_f, "params": n,
                "active_params": n_act, "tokens": tokens}
    # decode: one token per sequence against an s-long context
    tokens = b
    dense_f = 2.0 * n_act * tokens
    attn_f = _attn_flops(arch, tokens, min(s, window))
    return {"model_flops": dense_f + attn_f, "params": n,
            "active_params": n_act, "tokens": tokens}
