"""Jaxpr invariant verifier: static proofs over traced pipelines.

The paper's headline claims are *structural* properties of the compiled
program, visible in its jaxpr before anything runs:

* a >= 65,536^2 solve never holds an A-sized array (**AvalBound**);
* a streamed solve is a single device dispatch and re-invokes the block
  producer a bounded number of times (**DispatchCount**);
* every PRNG consumption is reachable from a distinct fold of the root
  key, so the k_a/k_x block-key schedule is provably collision-free and
  draw-identity across placements holds (**KeyReuse**);
* no silent float64 leaks and no sub-f32 accumulators in scan carries or
  collective operands (**PrecisionLint**);
* inside ``shard_map`` the only cross-device reductions are psums over
  the declared row/col axes, and no all-gather/all-to-all ships more
  than a per-device block (**CollectiveAudit**).

This module provides the one shared IR walker (:func:`walk_frames` /
:func:`iter_equations`) -- recursing into scan/while/cond/pjit/shard_map
and ``custom_vjp`` sub-jaxprs, including jaxprs reached through dict or
nested-container params and the ``fwd_jaxpr_thunk`` callable -- plus the
five passes.  Each violation carries a :class:`Site` naming the
offending primitive, its path through the IR, and the user source line.

``analysis.memory`` re-exports its ``jaxpr_max_elements`` on top of this
walker so there is exactly one traversal implementation.

The canonical pipeline matrix lives in :mod:`repro.analysis.pipelines`;
``tools/check_invariants.py`` runs every pass over every registered
pipeline against the checked-in ``INVARIANTS.json`` manifest.

See DESIGN.md section 10 (static invariants) and DESIGN.md section 4
(key discipline the KeyReuse pass enforces).
"""
from __future__ import annotations

import dataclasses
import hashlib
import sys
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np

try:  # jax >= 0.4.36 exposes the IR types under jax.extend.core
    from jax.extend.core import ClosedJaxpr, Jaxpr, Literal
except ImportError:  # pragma: no cover - older jax
    from jax.core import ClosedJaxpr, Jaxpr, Literal  # type: ignore

__all__ = [
    "Site",
    "Violation",
    "Report",
    "CallCounter",
    "trace",
    "walk_frames",
    "iter_equations",
    "eqn_subjaxprs",
    "jaxpr_max_elements",
    "aval_bound",
    "dispatch_count",
    "key_reuse",
    "precision_lint",
    "collective_audit",
    "run_all",
]

# Primitives that open a new trace/dispatch scope when they appear at the
# top level of an un-jitted trace.
DISPATCH_PRIMITIVES = frozenset({
    "pjit", "scan", "while", "cond", "shard_map", "remat2",
    "custom_vjp_call_jaxpr", "custom_jvp_call", "custom_vjp_call",
})

# PRNG primitives.  ``random_bits`` is the consumption point jax 0.4.x
# traces `jax.random.*` draws into; raw threefry shows up only in
# lowered/legacy paths but is handled for completeness.
RANDOM_CONSUMERS = frozenset({"random_bits", "threefry2x32"})

COLLECTIVE_REDUCERS = frozenset({"psum", "psum2"})
COLLECTIVE_GATHERS = frozenset({"all_gather", "all_to_all"})

_SUB_JAXPR_DEPTH = 6  # containers nested deeper than this are not scanned


# --------------------------------------------------------------------------
# attribution
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Site:
    """Where a violation lives: primitive, IR path, and user source line."""

    primitive: str
    path: Tuple[str, ...] = ()
    file: Optional[str] = None
    line: Optional[int] = None
    function: Optional[str] = None

    def __str__(self) -> str:
        loc = "/".join((*self.path, self.primitive)) or self.primitive
        if self.file is not None:
            src = self.file.rsplit("/", 1)[-1]
            loc += f" @ {src}:{self.line}"
            if self.function:
                loc += f" (in {self.function})"
        return loc


def _eqn_site(eqn: Any, path: Tuple[str, ...]) -> Site:
    name = getattr(getattr(eqn, "primitive", None), "name", "<jaxpr>")
    file = line = function = None
    try:  # private, best-effort: violations still render without it
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            file = frame.file_name
            line = frame.start_line
            function = frame.function_name
    except Exception:  # pragma: no cover - jax internals moved
        pass
    return Site(name, path, file, line, function)


@dataclasses.dataclass(frozen=True)
class Violation:
    pass_name: str
    message: str
    site: Optional[Site] = None

    def __str__(self) -> str:
        tail = f" [{self.site}]" if self.site is not None else ""
        return f"{self.pass_name}: {self.message}{tail}"


@dataclasses.dataclass
class Report:
    """Result of one pass: summary metrics plus any violations."""

    pass_name: str
    violations: List[Violation] = dataclasses.field(default_factory=list)
    summary: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def assert_ok(self) -> "Report":
        if self.violations:
            lines = "\n  ".join(str(v) for v in self.violations)
            raise AssertionError(f"{self.pass_name} failed:\n  {lines}")
        return self

    def __str__(self) -> str:
        status = "ok" if self.ok else f"{len(self.violations)} violation(s)"
        return f"[{self.pass_name}] {status} {self.summary}"


# --------------------------------------------------------------------------
# the shared walker
# --------------------------------------------------------------------------

def _as_jaxpr(jaxpr: Any) -> Jaxpr:
    return jaxpr.jaxpr if isinstance(jaxpr, ClosedJaxpr) else jaxpr


def _jaxprs_in(value: Any, depth: int = 0) -> Iterator[Jaxpr]:
    """Every jaxpr reachable inside an eqn param value.

    Handles raw ``Jaxpr``/``ClosedJaxpr`` as well as tuples, lists and
    dicts nested up to ``_SUB_JAXPR_DEPTH`` levels -- the seed walker
    only looked one container level deep and missed e.g. dict-valued
    params (see tests/test_verify.py::TestWalkerRegressions).
    """
    if isinstance(value, Jaxpr):
        yield value
    elif isinstance(value, ClosedJaxpr):
        yield value.jaxpr
    elif depth < _SUB_JAXPR_DEPTH:
        if isinstance(value, dict):
            for item in value.values():
                yield from _jaxprs_in(item, depth + 1)
        elif isinstance(value, (tuple, list)):
            for item in value:
                yield from _jaxprs_in(item, depth + 1)


def _custom_vjp_fwd_jaxpr(eqn: Any) -> Optional[Jaxpr]:
    """Materialize the fwd jaxpr hidden behind ``fwd_jaxpr_thunk``.

    In a primal-only trace of a ``jax.custom_vjp`` function the forward
    rule (and any residual it allocates) is reachable *only* through
    this memoized thunk -- params-level container scanning cannot see
    it.  The thunk takes one symbolic-zero flag per primal input.
    """
    thunk = eqn.params.get("fwd_jaxpr_thunk")
    if not callable(thunk):
        return None
    fun = eqn.params.get("fun_jaxpr")
    n = len(fun.jaxpr.invars) if isinstance(fun, ClosedJaxpr) else len(eqn.invars)
    n -= int(eqn.params.get("num_consts", 0) or 0)
    for count in (n, len(eqn.invars), 0):
        try:
            out = thunk(*([False] * max(count, 0)))
        except Exception:
            continue
        if isinstance(out, tuple) and out and isinstance(out[0], (Jaxpr, ClosedJaxpr)):
            return _as_jaxpr(out[0])
        if isinstance(out, (Jaxpr, ClosedJaxpr)):
            return _as_jaxpr(out)
    return None


def eqn_subjaxprs(eqn: Any) -> List[Tuple[str, Jaxpr]]:
    """(label, jaxpr) for every sub-jaxpr an equation can reach."""
    out: List[Tuple[str, Jaxpr]] = []
    seen: set = set()
    for sub in _jaxprs_in(eqn.params):
        if id(sub) not in seen:
            seen.add(id(sub))
            out.append((eqn.primitive.name, sub))
    if eqn.primitive.name in ("custom_vjp_call_jaxpr", "custom_vjp_call"):
        fwd = _custom_vjp_fwd_jaxpr(eqn)
        if fwd is not None and id(fwd) not in seen:
            out.append((f"{eqn.primitive.name}.fwd", fwd))
    return out


class Frame:
    """One jaxpr scope in a walked trace, with bindings to its parent.

    ``defs`` maps each var to the equation producing it inside this
    frame.  ``bindings`` maps frame invars either to the parent operand
    (``("var", parent, outer_var)``) or to an opaque root such as a scan
    carry (``("loop", label, index)``), a trace constant
    (``("const", index)``) or a top-level argument (``("arg", index)``).
    """

    __slots__ = ("jaxpr", "parent", "path", "bindings", "defs",
                 "shard_axes", "carries", "origin_site", "uid")

    def __init__(self, jaxpr: Jaxpr, parent: Optional["Frame"], path: Tuple[str, ...],
                 bindings: Dict[Any, Tuple], shard_axes: Optional[frozenset],
                 carries: Sequence[Any], origin_site: Optional[Site], uid: int):
        self.jaxpr = jaxpr
        self.parent = parent
        self.path = path
        self.bindings = bindings
        self.defs = {v: eqn for eqn in jaxpr.eqns for v in eqn.outvars}
        self.shard_axes = shard_axes
        self.carries = tuple(carries)
        self.origin_site = origin_site
        self.uid = uid


def _child_bindings(eqn: Any, sub: Jaxpr, parent: Frame,
                    scope: str) -> Tuple[Dict[Any, Tuple], Sequence[Any]]:
    """Bind ``sub.invars`` to the parent equation's operands.

    Returns (bindings, carry_vars).  Operand binding is exact for the
    structured control-flow primitives; unknown primitives fall back to
    positional binding when arities match and opaque roots otherwise.
    ``scope`` is unique per (equation, sub-jaxpr), so opaque roots of
    two sibling loops never unify -- they can only *hide* reuse across
    an unknown boundary, never fabricate it.
    """
    name = eqn.primitive.name
    invars = list(sub.invars)
    bindings: Dict[Any, Tuple] = {}
    carries: List[Any] = []

    def bind_positional(sub_vars: Sequence[Any], operands: Sequence[Any]) -> None:
        for sv, ov in zip(sub_vars, operands):
            bindings[sv] = ("var", parent, ov)

    if name == "scan":
        nc = eqn.params.get("num_consts", 0)
        nk = eqn.params.get("num_carry", 0)
        bind_positional(invars[:nc], eqn.invars[:nc])
        for i, sv in enumerate(invars[nc:nc + nk]):
            bindings[sv] = ("loop", scope, i)
            carries.append(sv)
        for i, sv in enumerate(invars[nc + nk:]):
            bindings[sv] = ("loop_x", scope, i)
    elif name == "while":
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        body = _as_jaxpr(eqn.params.get("body_jaxpr"))
        is_body = sub is body
        nconsts = bn if is_body else cn
        lo = cn if is_body else 0
        bind_positional(invars[:nconsts], eqn.invars[lo:lo + nconsts])
        for i, sv in enumerate(invars[nconsts:]):
            # cond and body see the same carry: share the scope token so
            # a key threaded through `while` unifies across both views
            bindings[sv] = ("loop", scope.rsplit("#", 1)[0], i)
            if is_body:
                carries.append(sv)
    elif name in ("cond", "switch"):
        bind_positional(invars, eqn.invars[1:])
    elif len(invars) == len(eqn.invars):
        bind_positional(invars, eqn.invars)
    else:
        for i, sv in enumerate(invars):
            bindings[sv] = ("opaque", scope, i)
    return bindings, carries


def walk_frames(jaxpr: Any) -> Iterator[Frame]:
    """Yield a :class:`Frame` for the jaxpr and every reachable sub-jaxpr."""
    jaxpr = _as_jaxpr(jaxpr)
    uid = [0]
    eqn_uid = [0]
    root_bindings: Dict[Any, Tuple] = {}
    for i, v in enumerate(jaxpr.invars):
        root_bindings[v] = ("arg", "", i)
    for i, v in enumerate(jaxpr.constvars):
        root_bindings[v] = ("const", "", i)
    root = Frame(jaxpr, None, (), root_bindings, None, (), None, uid[0])
    stack = [root]
    while stack:
        frame = stack.pop()
        yield frame
        for eqn in frame.jaxpr.eqns:
            eqn_uid[0] += 1
            for sub_idx, (label, sub) in enumerate(eqn_subjaxprs(eqn)):
                uid[0] += 1
                path = (*frame.path, label)
                scope = f"{eqn_uid[0]}#{sub_idx}"
                bindings, carries = _child_bindings(eqn, sub, frame, scope)
                for i, cv in enumerate(sub.constvars):
                    bindings[cv] = ("const", scope, i)
                shard_axes = frame.shard_axes
                if eqn.primitive.name == "shard_map":
                    mesh = eqn.params.get("mesh")
                    names = getattr(mesh, "axis_names", None) or ()
                    shard_axes = frozenset(names)
                stack.append(Frame(sub, frame, path, bindings, shard_axes,
                                   carries, _eqn_site(eqn, frame.path), uid[0]))


def iter_equations(jaxpr: Any) -> Iterator[Tuple[Any, Frame]]:
    """(eqn, frame) over the whole trace, one shared traversal."""
    for frame in walk_frames(jaxpr):
        for eqn in frame.jaxpr.eqns:
            yield eqn, frame


def trace(fn: Callable, *args: Any, **kwargs: Any) -> ClosedJaxpr:
    """Trace ``fn`` (args may be ShapeDtypeStructs; nothing executes)."""
    return jax.make_jaxpr(fn)(*args, **kwargs)


class CallCounter:
    """Wrap a block producer to count trace-time invocations.

    Replaces the hand-rolled counting-producer test idiom: wrap, trace,
    then hand ``counter.calls`` to :func:`dispatch_count`.
    """

    def __init__(self, fn: Callable):
        self.fn = fn
        self.calls = 0

    def __call__(self, *args: Any, **kwargs: Any) -> Any:
        self.calls += 1
        return self.fn(*args, **kwargs)


# --------------------------------------------------------------------------
# AvalBound
# --------------------------------------------------------------------------

def _aval_elements(var: Any) -> int:
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) if len(shape) else 1


def _aval_str(var: Any) -> str:
    aval = getattr(var, "aval", None)
    return str(getattr(aval, "str_short", lambda: aval)()) if aval is not None else "?"


def aval_bound(jaxpr: Any, budget: Optional[int] = None) -> Report:
    """Largest aval anywhere in the trace, against an element budget.

    Generalizes ``max_aval_elements`` into a reporting pass: the summary
    names the largest aval, its producing equation and source line, so a
    budget violation reads like a compiler diagnostic, not a number.
    """
    best = 0
    best_site: Optional[Site] = None
    best_aval = "?"
    for frame in walk_frames(jaxpr):
        jx = frame.jaxpr
        for var in (*jx.invars, *jx.constvars, *jx.outvars):
            n = _aval_elements(var)
            if n > best:
                best, best_site, best_aval = n, frame.origin_site, _aval_str(var)
        for eqn in jx.eqns:
            for var in (*eqn.invars, *eqn.outvars):
                n = _aval_elements(var)
                if n > best:
                    best, best_site, best_aval = n, _eqn_site(eqn, frame.path), _aval_str(var)
    report = Report("AvalBound", summary={
        "max_elements": best,
        "max_aval": best_aval,
        "at": str(best_site) if best_site is not None else "<toplevel>",
        "budget": budget,
    })
    if budget is not None and best > budget:
        report.violations.append(Violation(
            "AvalBound",
            f"largest aval {best_aval} has {best} elements > budget {budget}",
            best_site))
    return report


def jaxpr_max_elements(jaxpr: Any) -> int:
    """Largest aval (elements) anywhere in a (closed) jaxpr, recursively."""
    return int(aval_bound(jaxpr).summary["max_elements"])


# --------------------------------------------------------------------------
# DispatchCount
# --------------------------------------------------------------------------

def dispatch_count(jaxpr: Any,
                   max_top_level: Optional[int] = None,
                   producer_calls: Optional[int] = None,
                   max_producer_calls: Optional[int] = None) -> Report:
    """Count top-level dispatches and (optionally) producer invocations.

    A fused streamed pipeline is a *single* top-level equation (one
    ``pjit``/``scan``); every extra top-level eqn is an extra device
    dispatch.  ``producer_calls`` comes from a :class:`CallCounter`
    wrapped around the block producer before tracing -- trace-time call
    count is the static number of producer inlinings.
    """
    jx = _as_jaxpr(jaxpr)
    per_prim: Dict[str, int] = {}
    boundaries = 0
    for eqn in jx.eqns:
        name = eqn.primitive.name
        per_prim[name] = per_prim.get(name, 0) + 1
        if name in DISPATCH_PRIMITIVES:
            boundaries += 1
    report = Report("DispatchCount", summary={
        "top_level_eqns": len(jx.eqns),
        "dispatch_boundaries": boundaries,
        "per_primitive": dict(sorted(per_prim.items())),
    })
    if producer_calls is not None:
        report.summary["producer_calls"] = producer_calls
    if max_top_level is not None and len(jx.eqns) > max_top_level:
        site = _eqn_site(jx.eqns[max_top_level], ())
        report.violations.append(Violation(
            "DispatchCount",
            f"{len(jx.eqns)} top-level equations > budget {max_top_level} "
            f"(first excess: {site.primitive})", site))
    if (max_producer_calls is not None and producer_calls is not None
            and producer_calls > max_producer_calls):
        report.violations.append(Violation(
            "DispatchCount",
            f"producer invoked {producer_calls}x at trace time "
            f"> budget {max_producer_calls}"))
    return report


# --------------------------------------------------------------------------
# KeyReuse
# --------------------------------------------------------------------------

def _is_key_var(var: Any) -> bool:
    aval = getattr(var, "aval", None)
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and str(dtype).startswith("key")


def _param_fingerprint(params: Dict[str, Any]) -> str:
    parts = []
    for k in sorted(params):
        v = params[k]
        if isinstance(v, (Jaxpr, ClosedJaxpr)) or callable(v):
            continue
        try:
            parts.append(f"{k}={v!r}")
        except Exception:  # pragma: no cover - exotic param repr
            parts.append(f"{k}=<{type(v).__name__}>")
    return ";".join(parts)


class _KeyProvenance:
    """Structural backward-slice signatures for PRNG key operands.

    Two key operands with identical signatures were produced by the same
    static computation from the same roots -- consuming randomness from
    both is a key-reuse bug.  Signatures follow dataflow across frame
    boundaries (pjit/scan-const operands bind through; scan carries and
    xs are per-loop opaque roots, so a single in-loop consumption of a
    per-iteration key slice is *not* flagged, while two distinct
    consumption sites of the same carried key are).
    """

    #: flag bits for the rootedness half of a signature
    CONST_KEY = 1  # slice reaches a key baked in as a trace constant
    FROM_ARG = 2   # slice reaches a top-level argument

    def __init__(self) -> None:
        # memo value: (signature, root-flags bitmask)
        self._memo: Dict[Tuple[int, Any], Tuple[str, int]] = {}

    def _h(self, *parts: str) -> str:
        return hashlib.sha1("\x1f".join(parts).encode()).hexdigest()[:16]

    def signature(self, frame: Frame, var: Any) -> Tuple[str, int]:
        if isinstance(var, Literal):
            return self._h("lit", repr(getattr(var, "val", None))), 0
        key = (frame.uid, var)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        pending = (self._h("cycle", str(frame.uid), str(var)), 0)
        self._memo[key] = pending
        eqn = frame.defs.get(var)
        if eqn is not None:
            out_idx = next((i for i, ov in enumerate(eqn.outvars) if ov is var), 0)
            parts = [eqn.primitive.name, str(out_idx),
                     _param_fingerprint(eqn.params)]
            flags = 0
            for iv in eqn.invars:
                s, f = self.signature(frame, iv)
                parts.append(s)
                flags |= f
            result = (self._h(*parts), flags)
        else:
            binding = frame.bindings.get(var)
            if binding is None:  # pragma: no cover - malformed jaxpr
                result = pending
            elif binding[0] == "var":
                _, parent, outer = binding
                result = self.signature(parent, outer)
            else:
                kind, scope, idx = binding
                flags = 0
                if kind == "arg":
                    flags |= self.FROM_ARG
                if kind == "const" and _is_key_var(var):
                    flags |= self.CONST_KEY
                result = (self._h(kind, str(scope), str(idx)), flags)
        self._memo[key] = result
        return result


def key_reuse(jaxpr: Any, allow_baked: bool = False) -> Report:
    """Prove every PRNG consumption draws from a distinct key fold.

    Collects each ``random_bits``/threefry consumption site, computes
    the backward-slice signature of its key operand, and flags (a) two
    distinct sites consuming identically-derived keys and (b) keys not
    derived from any traced key argument (baked randomness breaks
    draw-identity between placements).  ``allow_baked=True`` waives (b)
    for pipelines whose *matrix content* is procedurally generated from
    a seed (e.g. ``ImplicitBandedMatrix`` producers) -- content draws
    are data, not noise; the reuse check (a) still applies to them.
    """
    prov = _KeyProvenance()
    consumptions: List[Tuple[str, int, Site]] = []
    limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(limit, 50_000))
    try:
        for eqn, frame in iter_equations(jaxpr):
            if eqn.primitive.name not in RANDOM_CONSUMERS:
                continue
            n_keys = 2 if eqn.primitive.name == "threefry2x32" else 1
            sigs, flags = [], 0
            for iv in eqn.invars[:n_keys]:
                s, f = prov.signature(frame, iv)
                sigs.append(s)
                flags |= f
            consumptions.append(
                (prov._h(*sigs), flags, _eqn_site(eqn, frame.path)))
    finally:
        sys.setrecursionlimit(limit)
    by_sig: Dict[str, List[Site]] = {}
    for sig, _, site in consumptions:
        by_sig.setdefault(sig, []).append(site)
    baked = [site for _, flags, site in consumptions
             if (flags & _KeyProvenance.CONST_KEY)
             or not (flags & _KeyProvenance.FROM_ARG)]
    report = Report("KeyReuse", summary={
        "consumptions": len(consumptions),
        "distinct_keys": len(by_sig),
        "baked": len(baked),
    })
    for sig, sites in sorted(by_sig.items()):
        if len(sites) > 1:
            where = ", ".join(str(s) for s in sites)
            report.violations.append(Violation(
                "KeyReuse",
                f"{len(sites)} consumptions of identically-derived key "
                f"(sites: {where})", sites[0]))
    if not allow_baked:
        for site in baked:
            report.violations.append(Violation(
                "KeyReuse",
                "randomness not derived from any traced key argument "
                "(baked draws break placement draw-identity)", site))
    return report


# --------------------------------------------------------------------------
# PrecisionLint
# --------------------------------------------------------------------------

_SUB_F32 = ("float16", "bfloat16")


def _dtype_name(var: Any) -> Optional[str]:
    aval = getattr(var, "aval", None)
    dtype = getattr(aval, "dtype", None)
    return None if dtype is None else str(dtype)


def precision_lint(jaxpr: Any, allow_f64: bool = False) -> Report:
    """No silent f64 leaks; no sub-f32 accumulators where error compounds.

    Flags float64 avals anywhere (unless ``allow_f64``), float16 or
    bfloat16 scan/while carries (per-iteration rounding accumulates
    across the loop), and sub-f32 psum operands (cross-device reduction
    order makes low-precision sums placement-dependent).
    """
    report = Report("PrecisionLint", summary={})
    n_f64 = n_low_carry = n_low_psum = 0
    for frame in walk_frames(jaxpr):
        for var in frame.carries:
            name = _dtype_name(var)
            if name in _SUB_F32:
                n_low_carry += 1
                report.violations.append(Violation(
                    "PrecisionLint",
                    f"{name} loop carry {_aval_str(var)} (sub-f32 accumulator)",
                    frame.origin_site))
        for eqn in frame.jaxpr.eqns:
            for var in (*eqn.invars, *eqn.outvars):
                if not allow_f64 and _dtype_name(var) == "float64":
                    n_f64 += 1
                    report.violations.append(Violation(
                        "PrecisionLint",
                        f"float64 aval {_aval_str(var)} (silent f64 leak)",
                        _eqn_site(eqn, frame.path)))
            if eqn.primitive.name in COLLECTIVE_REDUCERS:
                for var in eqn.invars:
                    name = _dtype_name(var)
                    if name in _SUB_F32:
                        n_low_psum += 1
                        report.violations.append(Violation(
                            "PrecisionLint",
                            f"{name} psum operand {_aval_str(var)}",
                            _eqn_site(eqn, frame.path)))
    report.summary.update(f64_avals=n_f64, sub_f32_carries=n_low_carry,
                          sub_f32_psum_operands=n_low_psum)
    # de-duplicate repeated flags of the same var flowing through many eqns
    seen: set = set()
    unique: List[Violation] = []
    for v in report.violations:
        k = (v.message, str(v.site))
        if k not in seen:
            seen.add(k)
            unique.append(v)
    report.violations = unique
    return report


# --------------------------------------------------------------------------
# CollectiveAudit
# --------------------------------------------------------------------------

def collective_audit(jaxpr: Any,
                     allowed_axes: Optional[Sequence[str]] = None,
                     per_device_budget: Optional[int] = None) -> Report:
    """Audit collectives inside ``shard_map`` regions.

    ``psum`` reductions may only touch the declared row/col mesh axes,
    and no all-gather/all-to-all may move an operand larger than the
    per-device block budget -- an accidental gather of a sharded A is
    exactly how the scalability claim silently dies.
    """
    allowed = None if allowed_axes is None else frozenset(allowed_axes)
    report = Report("CollectiveAudit", summary={})
    n_psum = n_gather = 0
    for eqn, frame in iter_equations(jaxpr):
        name = eqn.primitive.name
        if frame.shard_axes is None:
            continue
        if name in COLLECTIVE_REDUCERS:
            n_psum += 1
            axes = tuple(a for a in (eqn.params.get("axes") or ())
                         if isinstance(a, str))
            if allowed is not None and not set(axes) <= allowed:
                extra = sorted(set(axes) - allowed)
                report.violations.append(Violation(
                    "CollectiveAudit",
                    f"psum over undeclared axes {extra} "
                    f"(allowed: {sorted(allowed)})",
                    _eqn_site(eqn, frame.path)))
        elif name in COLLECTIVE_GATHERS:
            n_gather += 1
            moved = max((_aval_elements(v) for v in (*eqn.invars, *eqn.outvars)),
                        default=0)
            if per_device_budget is not None and moved > per_device_budget:
                report.violations.append(Violation(
                    "CollectiveAudit",
                    f"{name} moves {moved} elements > per-device budget "
                    f"{per_device_budget}",
                    _eqn_site(eqn, frame.path)))
            elif per_device_budget is None:
                report.violations.append(Violation(
                    "CollectiveAudit",
                    f"{name} inside shard_map with no declared budget",
                    _eqn_site(eqn, frame.path)))
    report.summary.update(psums=n_psum, gathers=n_gather,
                          allowed_axes=sorted(allowed) if allowed else None)
    return report


# --------------------------------------------------------------------------
# convenience driver
# --------------------------------------------------------------------------

def run_all(jaxpr: Any, *,
            aval_budget: Optional[int] = None,
            max_top_level: Optional[int] = None,
            producer_calls: Optional[int] = None,
            max_producer_calls: Optional[int] = None,
            allowed_axes: Optional[Sequence[str]] = None,
            per_device_budget: Optional[int] = None,
            allow_f64: bool = False,
            allow_baked: bool = False) -> Dict[str, Report]:
    """Run all five passes over one trace; keyed by pass name."""
    return {
        "AvalBound": aval_bound(jaxpr, budget=aval_budget),
        "DispatchCount": dispatch_count(
            jaxpr, max_top_level=max_top_level,
            producer_calls=producer_calls,
            max_producer_calls=max_producer_calls),
        "KeyReuse": key_reuse(jaxpr, allow_baked=allow_baked),
        "PrecisionLint": precision_lint(jaxpr, allow_f64=allow_f64),
        "CollectiveAudit": collective_audit(
            jaxpr, allowed_axes=allowed_axes,
            per_device_budget=per_device_budget),
    }
