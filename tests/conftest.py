"""Shared fixtures: analog-system builders and the cross-path parity harness.

Every placement/backend parity test in the suite used to hand-roll the same
boilerplate -- pad the matrix, build the capacity-block producer, spin up
one engine per execution path, run the computation, compare against the
reference path with ``rel_l2 <= 1e-5``.  :func:`assert_path_parity` is that
boilerplate, once: give it a dense matrix, a config and a ``run(engine,
handle)`` callback and it executes the callback across the requested paths
(same base key => identical programming + DAC draws => draw-identical
results) and asserts every path agrees with the reference.  Pass a results
mapping instead to reuse just the comparison half (the grouped-vs-solo
tests do, where the "paths" are group membership rather than placement).

Path names:

  ``local``      dense handle on the default local engine
  ``streamed``   traceable capacity-block producer, execution="streamed"
  ``pallas``     the streamed producer on the pallas tile-step backend
  ``opaque``     non-traceable producer (host loop) on the streamed engine
  ``dist-1x1``   producer on execution="distributed" over a 1x1 mesh
  ``virtual``    the distributed producer with ``resident=False``
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine

PARITY_PATHS = ("local", "streamed", "pallas", "opaque", "dist-1x1",
                "virtual")


def spd_system(n, scale=2.0, key=None):
    """(a, x_true, b) with ``a`` SPD and well-conditioned."""
    key = jax.random.PRNGKey(0) if key is None else key
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + scale * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    return a, x_true, a @ x_true


def analog_cfg(n, device="epiram", ec=True, cell=32):
    """A crossbar config whose tile grid covers an (n, n) matrix."""
    geom = MCAGeometry(tile_rows=max(n // (2 * cell), 1),
                       tile_cols=max(n // (2 * cell), 1),
                       cell_rows=cell, cell_cols=cell)
    return CrossbarConfig(device=get_device(device), geom=geom, k_iters=5,
                          ec=ec)


def make_analog(a, device="epiram", ec=True, cell=32, key=None, **kw):
    """(engine, programmed handle) for a dense matrix on a local engine."""
    key = jax.random.PRNGKey(0) if key is None else key
    cfg = analog_cfg(a.shape[0], device=device, ec=ec, cell=cell)
    engine = AnalogEngine(cfg, **kw)
    return engine, engine.program(a, key)


def block_view(a, cfg):
    """(mb, nb, cap_m, cap_n) capacity-block view of the padded matrix."""
    m, n = a.shape
    cap_m, cap_n = cfg.geom.capacity
    mb, nb = -(-m // cap_m), -(-n // cap_n)
    a_pad = jnp.pad(a, ((0, mb * cap_m - m), (0, nb * cap_n - n)))
    return a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)


def mesh_1x1():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def path_engine(cfg, path):
    """The engine owning one named execution path."""
    if path == "local":
        return AnalogEngine(cfg)
    if path in ("streamed", "opaque"):
        return AnalogEngine(cfg, execution="streamed")
    if path == "pallas":
        return AnalogEngine(cfg, execution="streamed", backend="pallas")
    if path in ("dist-1x1", "virtual"):
        return AnalogEngine(cfg, execution="distributed", mesh=mesh_1x1())
    raise ValueError(f"unknown parity path {path!r}")


def program_path(engine, a, key, path):
    """Program the dense matrix onto the engine the way the path demands."""
    if path == "local":
        return engine.program(a, key)
    blocks = block_view(a, engine.cfg)
    if path == "opaque":
        producer = lambda i, j: blocks[int(i), int(j)]
    else:
        producer = lambda i, j: blocks[i, j]
    kw = {"resident": False} if path == "virtual" else {}
    return engine.program(producer, key, shape=a.shape, **kw)


def run_paths(a, cfg, run, *, key, paths=PARITY_PATHS):
    """{path: run(engine, handle)} with every path programmed from the same
    dense matrix under the same base key."""
    out = {}
    for path in paths:
        engine = path_engine(cfg, path)
        out[path] = run(engine, program_path(engine, a, key, path))
    return out


def _compare(name, got, want, tol, exact):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l), \
        f"path {name!r}: result structure differs from reference"
    for i, (g, w) in enumerate(zip(got_l, want_l)):
        if exact:
            np.testing.assert_array_equal(
                np.asarray(g), np.asarray(w),
                err_msg=f"path {name!r} leaf {i} not bit-identical")
        else:
            err = float(rel_l2(jnp.asarray(g, jnp.float32),
                               jnp.asarray(w, jnp.float32)))
            assert err <= tol, \
                f"path {name!r} leaf {i}: rel_l2 {err:.3e} > {tol:.1e}"


def assert_path_parity(results=None, *, a=None, cfg=None, run=None, key=None,
                       paths=PARITY_PATHS, reference=None, tol=1e-5,
                       exact=()):
    """Assert every path's result matches the reference path's.

    Two calling modes:

    * ``assert_path_parity(a=a, cfg=cfg, run=fn, key=key, paths=...)`` --
      builds one engine + handle per path via :func:`run_paths`, executes
      ``run(engine, handle)`` (any pytree of arrays), compares.
    * ``assert_path_parity({name: pytree, ...}, reference=name)`` -- reuse
      just the comparison over precomputed results (grouped-vs-solo tests).

    ``reference`` defaults to the first entry.  Paths named in ``exact``
    must be BIT-identical to the reference; the rest satisfy
    ``rel_l2 <= tol`` leaf-wise.  Returns the results mapping so callers
    can run extra assertions (iteration counts, ledgers) on any path.
    """
    if results is None:
        if a is None or cfg is None or run is None or key is None:
            raise TypeError("need a=, cfg=, run=, key= when no results "
                            "mapping is given")
        results = run_paths(a, cfg, run, key=key, paths=paths)
    names = list(results)
    reference = names[0] if reference is None else reference
    want = results[reference]
    for name in names:
        if name == reference:
            continue
        _compare(name, results[name], want, tol, name in exact)
    return results
