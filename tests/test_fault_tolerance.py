"""Direct unit tests for repro.distributed.fault_tolerance: the checkpoint
store and the straggler watchdog, exercised in-process (no mesh needed --
the elastic/distributed path is covered by test_distributed.py)."""
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.write_verify import WriteStats
from repro.distributed.fault_tolerance import CheckpointManager, Watchdog


def _tree(seed: int):
    """A realistic solver-state pytree: arrays of mixed dtype plus a
    registered-dataclass WriteStats of scalars."""
    key = jax.random.PRNGKey(seed)
    stats = WriteStats(energy_j=jnp.float32(1.5 * seed),
                       latency_s=jnp.float32(0.25),
                       iterations=jnp.int32(seed),
                       final_delta=jnp.float32(1e-3))
    return {"x": jax.random.normal(key, (16, 3), jnp.float32),
            "step": jnp.int32(seed),
            "stats": stats}


def _assert_trees_equal(got, want):
    leaves_g = jax.tree_util.tree_leaves(got)
    leaves_w = jax.tree_util.tree_leaves(want)
    assert len(leaves_g) == len(leaves_w)
    for g, w in zip(leaves_g, leaves_w):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        assert g.dtype == w.dtype


def test_blocking_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    tree = _tree(4)
    mgr.save(7, tree, blocking=True, extra={"note": "seg-7"})
    out = mgr.restore(_tree(0), step=7)
    _assert_trees_equal(out, tree)
    man = mgr.manifest(7)
    assert man["step"] == 7
    assert man["extra"] == {"note": "seg-7"}
    # leaf metadata is recorded for every pytree leaf, WriteStats included
    assert any("stats" in k for k in man["leaves"])


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    tree = _tree(9)
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1]
    _assert_trees_equal(mgr.restore(_tree(0), step=1), tree)


def test_async_snapshot_is_synchronous(tmp_path):
    """The array snapshot happens at save() time: mutating the live state
    right after an async save must not corrupt the checkpoint."""
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    tree = _tree(5)
    want = jax.tree.map(np.asarray, tree)
    mgr.save(2, tree, blocking=False)
    tree["x"] = tree["x"] * 0.0       # post-save mutation of the live dict
    mgr.wait()
    _assert_trees_equal(mgr.restore(_tree(0), step=2), want)


def test_latest_step_and_gc_keep_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.all_steps() == [3, 4]   # keep_n=2 garbage-collects 1 and 2
    assert mgr.latest_step() == 4
    # restore with no explicit step targets the latest
    _assert_trees_equal(mgr.restore(_tree(0)), _tree(4))
    with pytest.raises(FileNotFoundError):
        CheckpointManager(str(tmp_path / "empty")).restore(_tree(0))


def test_restore_casts_to_target_dtype(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"v": jnp.arange(4, dtype=jnp.float32)}, blocking=True)
    out = mgr.restore({"v": jnp.zeros(4, jnp.bfloat16)}, step=1)
    assert out["v"].dtype == jnp.bfloat16


def test_save_is_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, _tree(1), blocking=True)
    names = os.listdir(str(tmp_path))
    assert names == ["step_000000003"]
    assert not any(n.startswith(".tmp") for n in names)


def test_watchdog_flags_stragglers():
    events = []
    wd = Watchdog(threshold=2.0, patience=2,
                  on_straggler=lambda step: events.append(step))
    # needs >= 5 samples before it will flag anything
    for s in range(5):
        assert not wd.record(s, 1.0)
    assert not wd.record(5, 1.9)       # under threshold x median
    assert wd.record(6, 5.0)           # slow step 1: flagged, no callback yet
    assert events == []
    assert wd.record(7, 5.0)           # slow step 2: patience reached
    assert events == [7]
    assert wd.events == [6, 7]
    # a healthy step resets the consecutive-slow counter
    assert not wd.record(8, 1.0)
    assert wd.record(9, 5.0)
    assert events == [7]               # one slow step after reset: no callback


def test_watchdog_quiet_before_warmup():
    wd = Watchdog(threshold=1.5, patience=1)
    # even an absurd outlier is not flagged before 5 samples exist
    assert not wd.record(0, 100.0)
    assert wd.events == []
