"""Program-once / execute-many AnalogEngine tests.

Covers the ISSUE acceptance criteria: a programmed AnalogMatrix is encoded
exactly once (counted via a monkeypatched ``encode_tiled``), engine output
matches the legacy one-shot ``corrected_mvm`` (and a from-scratch
reimplementation of the seed algorithm) under the same key, batched and
single-vector execution agree, streamed and dense programming are equivalent,
and all execution modes / backends run behind the one interface.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_path_parity
from conftest import block_view as _block_view
from conftest import mesh_1x1 as _mesh_1x1

from repro.analysis import CallCounter, aval_bound, dispatch_count, trace
from repro.core import (CrossbarConfig, MCAGeometry, corrected_mvm,
                        denoise_least_square, first_order_correct, get_device,
                        rel_l2)
from repro.core import crossbar
from repro.engine import AnalogEngine, AnalogMatrix

KEY = jax.random.PRNGKey(7)
GEOM = MCAGeometry(tile_rows=2, tile_cols=2, cell_rows=32, cell_cols=32)


def make_cfg(**kw):
    base = dict(device=get_device("taox-hfox"), geom=GEOM, k_iters=5, ec=True)
    base.update(kw)
    return CrossbarConfig(**base)


@pytest.fixture(scope="module")
def problem():
    a = jax.random.normal(KEY, (100, 90)) / 10
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (90,))
    return a, x


# ----------------------------------------------------------- program-once
def test_program_encodes_exactly_once(problem, monkeypatch):
    """Two successive mvm calls on one handle do zero additional encode work."""
    a, x = problem
    encode = CallCounter(crossbar.encode_tiled)
    monkeypatch.setattr(crossbar, "encode_tiled", encode)
    engine = AnalogEngine(make_cfg())
    A = engine.program(a, KEY)
    programmed = encode.calls
    assert programmed > 0                       # programming does encode
    y1 = engine.mvm(A, x)
    y2 = engine.mvm(A, x)
    assert encode.calls == programmed           # executing never re-encodes
    # successive calls draw fresh input-DAC noise, so outputs differ slightly
    assert bool(jnp.any(y1 != y2))


def test_program_deterministic_under_fixed_key(problem):
    a, _ = problem
    engine = AnalogEngine(make_cfg())
    A1 = engine.program(a, KEY)
    A2 = engine.program(a, KEY)
    np.testing.assert_array_equal(np.asarray(A1.at_blocks),
                                  np.asarray(A2.at_blocks))
    np.testing.assert_array_equal(np.asarray(A1.da_blocks),
                                  np.asarray(A2.da_blocks))
    assert bool(jnp.any(
        engine.program(a, jax.random.fold_in(KEY, 9)).at_blocks
        != A1.at_blocks))


def test_a_tilde_reconstructs_matrix(problem):
    a, _ = problem
    engine = AnalogEngine(make_cfg())
    A = engine.program(a, KEY)
    assert A.a_tilde.shape == a.shape
    np.testing.assert_allclose(np.asarray(A.a_tilde + A.da), np.asarray(a),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------- parity
def _seed_reference_mvm(a, x, key, cfg):
    """The seed repo's one-shot algorithm, reimplemented verbatim: per-block
    encode of A and x inside the same vmap structure, fused tier-1, tier-2."""
    m, n = a.shape
    cap_m, cap_n = cfg.geom.capacity
    from repro.core.virtualization import zero_padding
    a_pad = zero_padding(a, cfg.geom)
    mp, np_ = a_pad.shape
    x_pad = jnp.pad(x[:, None], ((0, np_ - n), (0, 0)))
    mb, nb = mp // cap_m, np_ // cap_n
    blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)
    x_chunks = x_pad.reshape(nb, cap_n, 1)
    keys = jax.random.split(key, mb * nb).reshape(mb, nb, -1)

    def per_row(i_blocks, i_keys):
        def per_col(a_blk, x_blk, k):
            k_a, k_x = jax.random.split(k)
            a_t = crossbar.encode_tiled(a_blk, k_a, cfg)
            x_t = crossbar._encode_vec(x_blk, k_x, cfg)
            return first_order_correct(a_blk, a_t, x_blk, x_t, mode="fused")
        return jnp.sum(jax.vmap(per_col)(i_blocks, x_chunks, i_keys), axis=0)

    y_blocks = jax.vmap(per_row)(blocks, keys)
    p = y_blocks.reshape(mb * cap_m, 1)[:m]
    p = denoise_least_square(p, lam=cfg.lam, h=cfg.h, method=cfg.denoise_method)
    return p[:, 0]


def test_mvm_matches_legacy_corrected_mvm(problem):
    """<= 1e-5 rel-L2 against both the legacy entry point and a from-scratch
    reimplementation of the seed algorithm, same key/config."""
    a, x = problem
    cfg = make_cfg()
    engine = AnalogEngine(cfg)
    y_eng = engine.mvm(engine.program(a, KEY), x)
    y_leg, _ = corrected_mvm(a, x, KEY, cfg)
    y_seed = _seed_reference_mvm(a, x, KEY, cfg)
    assert float(rel_l2(y_eng, y_leg)) <= 1e-5
    assert float(rel_l2(y_eng, y_seed)) <= 1e-5


@pytest.mark.parametrize("ec,encode_inputs", [(True, True), (False, True),
                                              (True, False)])
def test_mvm_config_paths(problem, ec, encode_inputs):
    a, x = problem
    cfg = make_cfg(ec=ec, encode_inputs=encode_inputs)
    engine = AnalogEngine(cfg)
    y_eng = engine.mvm(engine.program(a, KEY), x)
    y_leg, _ = corrected_mvm(a, x, KEY, cfg)
    assert float(rel_l2(y_eng, y_leg)) <= 1e-5


# ------------------------------------------------------------------ batching
def test_single_vector_equals_one_column_batch(problem):
    a, x = problem
    engine = AnalogEngine(make_cfg())
    A = engine.program(a, KEY)
    y1 = engine.mvm(A, x, key=KEY)
    yb = engine.mvm(A, x[:, None], key=KEY)
    assert yb.shape == (a.shape[0], 1)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(yb[:, 0]))


def test_batched_columns_each_accurate(problem):
    a, x = problem
    engine = AnalogEngine(make_cfg())
    A = engine.program(a, KEY)
    xb = jnp.stack([x, -2.0 * x, 0.5 * x], axis=1)
    yb = engine.mvm(A, xb)
    truth = a @ xb
    for j in range(xb.shape[1]):
        assert float(rel_l2(yb[:, j], truth[:, j])) < 5e-2


# ------------------------------------------------------- streamed execution
def test_streamed_equals_dense(problem):
    """Same key => identical encode draws => streamed == local to fp tol."""
    a, x = problem
    cfg = make_cfg()
    m, n = a.shape
    cap_m, cap_n = cfg.geom.capacity
    mb, nb = -(-m // cap_m), -(-n // cap_n)
    a_pad = jnp.pad(a, ((0, mb * cap_m - m), (0, nb * cap_n - n)))
    blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)

    dense = AnalogEngine(cfg)
    streamed = AnalogEngine(cfg, execution="streamed")
    A_d = dense.program(a, KEY)
    A_s = streamed.program(lambda i, j: blocks[i, j], KEY, shape=(m, n))
    # Same keys => same draws; XLA may reassociate the per-tile quantization
    # scale reduction between the vmapped and per-block paths, so compare in
    # norm rather than elementwise.
    assert float(rel_l2(A_s.at_blocks, A_d.at_blocks)) <= 1e-5
    y_d = dense.mvm(A_d, x, key=KEY)
    y_s = streamed.mvm(A_s, x, key=KEY)
    assert float(rel_l2(y_s, y_d)) <= 1e-5


def test_streamed_keeps_only_the_programmed_image(problem):
    """Streamed handles hold A_tilde tiles + the producer, never dA tiles."""
    a, x = problem
    cfg = make_cfg()
    m, n = a.shape
    cap_m, cap_n = cfg.geom.capacity
    mb, nb = -(-m // cap_m), -(-n // cap_n)
    a_pad = jnp.pad(a, ((0, mb * cap_m - m), (0, nb * cap_n - n)))
    blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)
    engine = AnalogEngine(cfg, execution="streamed")
    A = engine.program(lambda i, j: blocks[i, j], KEY, shape=(m, n))
    assert A.da_blocks is None and A.block_fn is not None
    # the dense views still reconstruct the matrix
    np.testing.assert_allclose(np.asarray(A.a_tilde + A.da), np.asarray(a),
                               rtol=1e-5, atol=1e-6)


def test_cross_execution_handle_rejected(problem):
    a, _ = problem
    local = AnalogEngine(make_cfg())
    A = local.program(a, KEY)
    streamed = AnalogEngine(make_cfg(), execution="streamed")
    # a local handle on a streamed engine is fine (same block layout) ...
    assert streamed.mvm(A, jnp.ones((a.shape[1],))).shape == (a.shape[0],)
    # ... but a blocks-layout handle must be rejected by a distributed engine
    # before it reaches shard_map with None operands.
    dist = AnalogEngine.__new__(AnalogEngine)
    dist.cfg, dist.execution, dist.backend = make_cfg(), "distributed", "reference"
    with pytest.raises(ValueError):
        dist._execute(A, jnp.ones((a.shape[1],)), None)


def test_streamed_requires_shape(problem):
    engine = AnalogEngine(make_cfg(), execution="streamed")
    with pytest.raises(ValueError):
        engine.program(lambda i, j: jnp.zeros((64, 64)), KEY)
    with pytest.raises(ValueError):
        AnalogEngine(make_cfg()).program(
            lambda i, j: jnp.zeros((64, 64)), KEY, shape=(64, 64))


def _counting_producer(blocks):
    """Block producer wrapped in the verifier's trace-time call counter."""
    return CallCounter(lambda i, j: blocks[i, j])


def test_streamed_traceable_single_dispatch(problem):
    """The scan-fused pipeline: a traceable producer is invoked O(1) times
    (trace only) per program and per MVM -- never once per block -- and a
    warm MVM re-invokes it zero times (one cached device dispatch)."""
    a, x = problem
    cfg = make_cfg()
    blocks = _block_view(a, cfg)
    mb, nb = blocks.shape[:2]
    assert mb * nb >= 4                      # the loop would pay >= 4 here
    producer = _counting_producer(blocks)
    engine = AnalogEngine(cfg, execution="streamed")
    A = engine.program(producer, KEY, shape=a.shape)
    assert A.block_traceable
    dispatch_count(trace(engine.mvm_fn(A),
                         jax.ShapeDtypeStruct(x.shape, x.dtype),
                         jax.ShapeDtypeStruct(KEY.shape, KEY.dtype)),
                   max_top_level=8,
                   producer_calls=producer.calls,
                   max_producer_calls=4).assert_ok()
    after_program = producer.calls
    y1 = engine.mvm(A, x, key=KEY)
    assert producer.calls - after_program <= 1   # first call traces once
    warm = producer.calls
    y2 = engine.mvm(A, x, key=jax.random.fold_in(KEY, 1))
    assert producer.calls == warm            # warm MVM: zero host work
    assert y1.shape == y2.shape == (a.shape[0],)
    # and the scanned output matches the dense reference path
    dense = AnalogEngine(cfg)
    y_d = dense.mvm(dense.program(a, KEY), x, key=KEY)
    assert float(rel_l2(y1, y_d)) <= 1e-5


def test_streamed_opaque_producer_host_loop(problem):
    """Opaque producers (host-only indexing) fall back to the compat loop --
    one producer invocation per block per MVM -- and still match the scanned
    pipeline exactly (same per-block keys and draws)."""
    a, x = problem
    cfg = make_cfg()
    blocks = _block_view(a, cfg)
    mb, nb = blocks.shape[:2]
    # int() rejects tracers, so the producer is opaque to the scan pipeline
    opaque = CallCounter(lambda i, j: blocks[int(i), int(j)])

    engine = AnalogEngine(cfg, execution="streamed")
    A = engine.program(opaque, KEY, shape=a.shape)
    assert not A.block_traceable
    assert opaque.calls == mb * nb + 1       # +1: the failed traceability probe
    before = opaque.calls
    y_host = engine.mvm(A, x, key=KEY)
    assert opaque.calls - before == mb * nb  # the O(mb*nb) dispatch regime
    A_s = engine.program(lambda i, j: blocks[i, j], KEY, shape=a.shape)
    y_scan = engine.mvm(A_s, x, key=KEY)
    assert float(rel_l2(y_host, y_scan)) <= 1e-5
    # an explicit traceable=False marker forces the host loop too
    forced = lambda i, j: blocks[i, j]
    forced.traceable = False
    assert not engine.program(forced, KEY, shape=a.shape).block_traceable


def test_streamed_pallas_matches_reference(problem):
    """The use_kernel branch of the streamed pipeline (fused rram_ec_matmul
    tile step inside the scan body) against the reference streamed path:
    identical draws, <= 1e-5."""
    a, x = problem
    cfg = make_cfg()
    blocks = _block_view(a, cfg)
    ref = AnalogEngine(cfg, execution="streamed")
    pal = AnalogEngine(cfg, execution="streamed", backend="pallas")
    A_r = ref.program(lambda i, j: blocks[i, j], KEY, shape=a.shape)
    A_p = pal.program(lambda i, j: blocks[i, j], KEY, shape=a.shape)
    y_r = ref.mvm(A_r, x, key=KEY)
    y_p = pal.mvm(A_p, x, key=KEY)
    assert float(rel_l2(y_p, y_r)) <= 1e-5
    # batched panels run through the same fused tile step
    xb = jnp.stack([x, -0.5 * x], axis=1)
    yb_r = ref.mvm(A_r, xb, key=KEY)
    yb_p = pal.mvm(A_p, xb, key=KEY)
    assert float(rel_l2(yb_p, yb_r)) <= 1e-5


def test_streamed_da_and_dense_scanned(problem):
    """AnalogMatrix.da / .dense() on a streamed handle run one scanned
    producer sweep (no per-block host dispatches) and reconstruct A."""
    a, _ = problem
    cfg = make_cfg()
    blocks = _block_view(a, cfg)
    producer = _counting_producer(blocks)
    engine = AnalogEngine(cfg, execution="streamed")
    A = engine.program(producer, KEY, shape=a.shape)
    before = producer.calls
    da = A.da
    assert producer.calls - before <= 1      # one traced sweep, not mb*nb
    np.testing.assert_allclose(np.asarray(A.a_tilde + da), np.asarray(a),
                               rtol=1e-5, atol=1e-6)
    before = producer.calls
    np.testing.assert_allclose(np.asarray(A.dense()), np.asarray(a),
                               rtol=1e-5, atol=1e-6)
    assert producer.calls - before <= 1      # one traced sweep, not mb*nb


def test_streamed_shim_routes_through_engine(problem):
    """The deprecated one-shot shim composes over the scan-fused pipeline:
    same output as program+mvm under the same key (identical k_a/k_x draws),
    O(1) producer invocations (the one-shot scan never materializes the
    image), legacy (matrix + input) accounting preserved."""
    a, x = problem
    cfg = make_cfg()
    m, n = a.shape
    blocks = _block_view(a, cfg)
    producer = _counting_producer(blocks)
    y_shim, stats = crossbar.streamed_corrected_mvm(producer, x, m, n, KEY,
                                                    cfg)
    assert producer.calls <= 3               # probe + one fused scan trace
    engine = AnalogEngine(cfg, execution="streamed")
    A = engine.program(lambda i, j: blocks[i, j], KEY, shape=(m, n))
    y_eng = engine.mvm(A, x, key=KEY)
    assert float(rel_l2(y_shim, y_eng)) <= 1e-5
    np.testing.assert_allclose(
        float(stats.energy_j),
        float(crossbar.write_cost(m, n, cfg, batch=1).energy_j), rtol=1e-6)


def test_input_write_stats_rounds_up_nondivisible():
    """Distributed per-device input cost must ceil-divide the footprint on
    non-divisible mesh shapes, not silently floor it.  (193 rows over 3
    devices: the floored 64-row shard hides a capacity block; the real
    largest shard holds 65 rows and spans two.)"""
    from types import SimpleNamespace
    cfg = make_cfg()                         # capacity 64 x 64
    eng = AnalogEngine.__new__(AnalogEngine)
    eng.cfg, eng.execution, eng.backend = cfg, "distributed", "reference"
    eng.row_axes, eng.col_axis = ("data",), "model"
    eng.mesh = SimpleNamespace(axis_names=("data", "model"),
                               devices=np.zeros((3, 4)))
    A = SimpleNamespace(shape=(193, 90))
    got = eng.input_write_stats(A, batch=2)
    want = crossbar.input_write_cost(-(-193 // 3), -(-90 // 4), cfg, batch=2)
    np.testing.assert_allclose(float(got.energy_j), float(want.energy_j),
                               rtol=1e-6)
    floor = crossbar.input_write_cost(193 // 3, 90 // 4, cfg, batch=2)
    assert float(got.energy_j) > float(floor.energy_j)


# ------------------------------------------- distributed producer placement
def test_distributed_producer_1x1_matches_streamed(problem):
    """Producer-driven distributed execution on a 1x1 mesh is draw-identical
    to the single-device streamed path: same global block-key schedule, same
    scan pipeline, bit-for-bit image, <= 1e-5 values."""
    a, x = problem
    cfg = make_cfg()
    blocks = _block_view(a, cfg)
    streamed = AnalogEngine(cfg, execution="streamed")
    A_s = streamed.program(lambda i, j: blocks[i, j], KEY, shape=a.shape)
    dist = AnalogEngine(cfg, execution="distributed", mesh=_mesh_1x1())
    A_d = dist.program(lambda i, j: blocks[i, j], KEY, shape=a.shape)
    assert A_d.mesh_sharded and A_d.block_traceable
    np.testing.assert_array_equal(np.asarray(A_d.at_blocks),
                                  np.asarray(A_s.at_blocks))
    y_s = streamed.mvm(A_s, x, key=KEY)
    y_d = dist.mvm(A_d, x, key=KEY)
    assert float(rel_l2(y_d, y_s)) <= 1e-5
    # virtual image (resident=False): every MVM re-encodes inside the scan
    # with the identical draws -- same result, no image ever resident.
    A_v = dist.program(lambda i, j: blocks[i, j], KEY, shape=a.shape,
                       resident=False)
    assert A_v.at_blocks is None
    y_v = dist.mvm(A_v, x, key=KEY)
    assert float(rel_l2(y_v, y_d)) <= 1e-5
    # the dense views still reconstruct A from the producer
    np.testing.assert_allclose(np.asarray(A_v.dense()), np.asarray(a),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(A_v.a_tilde + A_v.da),
                               np.asarray(a), rtol=1e-4, atol=1e-5)


def test_distributed_producer_no_a_sized_allocation(problem):
    """The virtual distributed pipeline never traces an A-sized aval: its
    high-water mark is one capacity block (for a procedural producer, the
    paper-scale regime), and a warm MVM re-invokes the producer zero times
    (single cached dispatch)."""
    from repro.core.matrices import ImplicitBandedMatrix
    cfg = make_cfg()
    cap_m, cap_n = cfg.geom.capacity       # 64 x 64
    n = 4 * cap_n                          # 4x4 block grid
    imp = ImplicitBandedMatrix(n=n, cap_m=cap_m, cap_n=cap_n, seed=2)
    producer = CallCounter(imp.block)
    dist = AnalogEngine(cfg, execution="distributed", mesh=_mesh_1x1())
    A = dist.program(producer, KEY, shape=(n, n), resident=False)
    assert producer.calls <= 2               # probe only: nothing programmed
    jx = trace(dist.mvm_fn(A),
               jax.ShapeDtypeStruct((n,), jnp.float32),
               jax.ShapeDtypeStruct(KEY.shape, KEY.dtype))
    # high-water mark well under A: a handful of capacity blocks, never n^2
    aval_bound(jx, budget=4 * cap_m * cap_n).assert_ok()
    assert 4 * cap_m * cap_n < n * n
    # the whole virtual MVM is one fused dispatch, O(1) producer inlinings
    dispatch_count(jx, max_top_level=8, producer_calls=producer.calls,
                   max_producer_calls=3).assert_ok()
    before = producer.calls
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (n,))
    y1 = dist.mvm(A, x, key=KEY)
    assert producer.calls - before <= 1      # one trace
    warm = producer.calls
    y2 = dist.mvm(A, x, key=jax.random.fold_in(KEY, 1))
    assert producer.calls == warm            # warm: zero producer work
    assert y1.shape == y2.shape == (n,)


def test_distributed_producer_validation(problem):
    """Opaque producers, non-dividing grids, and resident=False misuse are
    rejected with actionable errors."""
    from types import SimpleNamespace
    a, _ = problem
    cfg = make_cfg()
    blocks = _block_view(a, cfg)
    dist = AnalogEngine(cfg, execution="distributed", mesh=_mesh_1x1())
    opaque = lambda i, j: blocks[int(i), int(j)]
    with pytest.raises(ValueError, match="traceable"):
        dist.program(opaque, KEY, shape=a.shape)
    with pytest.raises(ValueError, match="resident=False"):
        AnalogEngine(cfg, execution="streamed").program(
            lambda i, j: blocks[i, j], KEY, shape=a.shape, resident=False)
    with pytest.raises(ValueError, match="resident=False"):
        AnalogEngine(cfg).program(a, KEY, resident=False)
    # a (2, 4)-way mesh cannot carve this 4x3 block grid evenly
    fake = AnalogEngine.__new__(AnalogEngine)
    fake.cfg, fake.execution, fake.backend = cfg, "distributed", "reference"
    fake.row_axes, fake.col_axis = ("data",), "model"
    fake.mesh = SimpleNamespace(axis_names=("data", "model"),
                                devices=np.zeros((2, 4)))
    with pytest.raises(ValueError, match="does not divide"):
        fake._program_distributed_streamed(
            lambda i, j: blocks[i, j], a.shape, KEY, True)
    # mesh-sharded handles are rejected by local/streamed engines
    A_d = dist.program(lambda i, j: blocks[i, j], KEY, shape=a.shape)
    with pytest.raises(ValueError, match="mesh-sharded"):
        AnalogEngine(cfg).mvm(A_d, jnp.ones((a.shape[1],)))
    # ... and a STREAMED-programmed producer handle is rejected by a
    # distributed engine: it skipped the mesh/grid validation, so letting it
    # into shard_map would mis-shape the output or fail opaquely.
    A_st = AnalogEngine(cfg, execution="streamed").program(
        lambda i, j: blocks[i, j], KEY, shape=a.shape)
    with pytest.raises(ValueError, match="distributed engine"):
        dist.mvm(A_st, jnp.ones((a.shape[1],)))


# ------------------------------------------------------------ transposed MVMs
def _seed_style_rmvm(a, y, key, cfg):
    """From-scratch transposed oracle: per-block k_x encode of the row-chunked
    y, fused transposed tier-1, row-block reduction, tier-2 over columns --
    independent of the production programmed_block_rmvm implementation."""
    m, n = a.shape
    cap_m, cap_n = cfg.geom.capacity
    from repro.core.virtualization import zero_padding
    a_pad = zero_padding(a, cfg.geom)
    mp, np_ = a_pad.shape
    y_pad = jnp.pad(y[:, None], ((0, mp - m), (0, 0)))
    mb, nb = mp // cap_m, np_ // cap_n
    keys = jax.random.split(key, mb * nb).reshape(mb, nb, -1)
    at_blocks, da_blocks = crossbar.program_blocks(a, key, cfg)
    out = jnp.zeros((np_, 1), jnp.float32)
    for j in range(nb):
        acc = jnp.zeros((cap_n, 1), jnp.float32)
        for i in range(mb):
            _, k_x = jax.random.split(keys[i, j])
            y_blk = y_pad[i * cap_m:(i + 1) * cap_m]
            y_t = crossbar._encode_vec(y_blk, k_x, cfg)
            acc = acc + (at_blocks[i, j].T @ y_blk
                         + da_blocks[i, j].T @ y_t)
            # da = a - a_tilde reproduces p = A_tilde^T y + dA^T y_tilde
        out = out.at[j * cap_n:(j + 1) * cap_n].set(acc)
    p = denoise_least_square(out[:n], lam=cfg.lam, h=cfg.h,
                             method=cfg.denoise_method)
    return p[:, 0]


def test_rmvm_matches_seed_style_oracle(problem):
    """engine.rmvm (A.T @ y) <= 1e-5 against the from-scratch transposed
    reimplementation under the same key/config, and within the analog noise
    class of the digital a.T @ y."""
    a, _ = problem
    cfg = make_cfg()
    engine = AnalogEngine(cfg)
    A = engine.program(a, KEY)
    y = jax.random.normal(jax.random.fold_in(KEY, 5), (a.shape[0],))
    z = engine.rmvm(A, y, key=KEY)
    z_oracle = _seed_style_rmvm(a, y, KEY, cfg)
    assert float(rel_l2(z, z_oracle)) <= 1e-5
    assert float(rel_l2(z, a.T @ y)) < 5e-2          # corrected-accuracy class
    # the operator view is the same execution
    z_op = A.T @ y
    assert z_op.shape == z.shape == (a.shape[1],)


def test_rmvm_parity_across_paths(problem):
    """A.T @ y parity <= 1e-5 across local/streamed/distributed(1x1) and
    reference/pallas tile-step paths (identical per-block keys and draws --
    the transposed mirror of the forward parity tests), including the
    one-shot (resident=False) scan variant and the opaque host loop."""
    a, _ = problem
    cfg = make_cfg()
    y = jax.random.normal(jax.random.fold_in(KEY, 6), (a.shape[0],))
    assert_path_parity(a=a, cfg=cfg, key=KEY,
                       paths=("local", "streamed", "pallas", "opaque",
                              "dist-1x1", "virtual"),
                       run=lambda eng, A: eng.rmvm(A, y, key=KEY))

    # the opaque producer really is the non-traceable host loop
    blocks = _block_view(a, cfg)
    streamed = AnalogEngine(cfg, execution="streamed")
    A_o = streamed.program(lambda i, j: blocks[int(i), int(j)], KEY,
                           shape=a.shape)
    assert not A_o.block_traceable

    # dense distributed placement through the same transposed stage
    dist = AnalogEngine(cfg, execution="distributed", mesh=_mesh_1x1())
    A_dd = dist.program(a, KEY)
    z_dd = dist.rmvm(A_dd, y, key=KEY)
    assert float(rel_l2(z_dd, a.T @ y)) < 5e-2


def test_rmvm_pallas_dense_accuracy(problem):
    """The dense-pallas transposed path (whole-vector DAC draw) reaches the
    same EC accuracy class as the reference path, like the forward test."""
    a, _ = problem
    cfg = make_cfg()
    y = jax.random.normal(jax.random.fold_in(KEY, 6), (a.shape[0],))
    pal = AnalogEngine(cfg, backend="pallas")
    z = pal.rmvm(pal.program(a, KEY), y, key=KEY)
    ref = AnalogEngine(cfg)
    z_ref = ref.rmvm(ref.program(a, KEY), y, key=KEY)
    truth = a.T @ y
    assert float(rel_l2(z, truth)) < 3.0 * float(rel_l2(z_ref, truth)) + 1e-3


def test_transposed_view_ergonomics(problem):
    a, x = problem
    m, n = a.shape
    engine = AnalogEngine(make_cfg())
    A = engine.program(a, KEY)
    assert A.T.shape == (n, m) and A.T.T is A
    assert A.T.m == n and A.T.n == m
    # the view shares the one-time write cost and reconstructs A^T
    assert A.T.write_stats is A.write_stats
    np.testing.assert_allclose(np.asarray(A.T.dense()), np.asarray(a.T),
                               rtol=1e-5, atol=1e-6)
    # engine.mvm on a transposed view is the parent's transposed execution
    y = jax.random.normal(jax.random.fold_in(KEY, 7), (m,))
    np.testing.assert_array_equal(
        np.asarray(engine.mvm(A.T, y, key=KEY)),
        np.asarray(engine.rmvm(A, y, key=KEY)))
    # ... and (A.T).T @ x is a forward MVM again
    np.testing.assert_array_equal(
        np.asarray(engine.rmvm(A.T, x, key=KEY)),
        np.asarray(engine.mvm(A, x, key=KEY)))
    # shape validation names the direction
    with pytest.raises(ValueError, match="A.T @ y"):
        engine.rmvm(A, x)                       # (n,) input into A.T @ y
    with pytest.raises(ValueError, match="A @ x"):
        engine.mvm(A, y)
    # the view cannot smuggle a handle past the cross-engine guard
    other = AnalogEngine(make_cfg(k_iters=2))
    with pytest.raises(ValueError, match="incompatible"):
        other.mvm(A.T, y)


def test_transposed_input_write_stats(problem):
    """Transposed executions bill the m-length DAC pass + the ROW-dimension
    EC replica: on a non-square cell the two directions differ and match the
    analytic transposed write cost."""
    a, _ = problem
    cfg = make_cfg(geom=MCAGeometry(tile_rows=2, tile_cols=2,
                                    cell_rows=32, cell_cols=16))
    engine = AnalogEngine(cfg)
    A = engine.program(a, KEY)
    fwd = A.input_write_stats(batch=2)
    tra = A.T.input_write_stats(batch=2)
    want = crossbar.input_write_cost(*a.shape, cfg, batch=2, transpose=True)
    np.testing.assert_allclose(float(tra.energy_j), float(want.energy_j),
                               rtol=1e-6)
    assert float(tra.energy_j) != float(fwd.energy_j)
    # rmvm_with_stats bills the same per-call transposed cost
    y = jax.random.normal(jax.random.fold_in(KEY, 8), (a.shape[0],))
    _, call = engine.rmvm_with_stats(A, y, key=KEY)
    np.testing.assert_allclose(float(call.energy_j), float(want.energy_j) / 2,
                               rtol=1e-6)


def test_rmvm_streamed_single_dispatch(problem):
    """The transposed scan pipeline keeps the streamed dispatch discipline:
    O(1) producer invocations per rmvm trace, zero when warm, and the
    transposed trace caches independently of the forward one."""
    a, x = problem
    cfg = make_cfg()
    blocks = _block_view(a, cfg)
    producer = _counting_producer(blocks)
    engine = AnalogEngine(cfg, execution="streamed")
    A = engine.program(producer, KEY, shape=a.shape)
    y = jax.random.normal(jax.random.fold_in(KEY, 9), (a.shape[0],))
    dispatch_count(trace(engine.mvm_fn(A, transpose=True),
                         jax.ShapeDtypeStruct(y.shape, y.dtype),
                         jax.ShapeDtypeStruct(KEY.shape, KEY.dtype)),
                   max_top_level=8).assert_ok()
    before = producer.calls
    z1 = engine.rmvm(A, y, key=KEY)
    assert producer.calls - before <= 1      # one transposed trace
    warm = producer.calls
    z2 = engine.rmvm(A, y, key=jax.random.fold_in(KEY, 1))
    assert producer.calls == warm            # warm rmvm: zero host work
    assert z1.shape == z2.shape == (a.shape[1],)
    # forward and transposed pipelines coexist on one handle
    engine.mvm(A, x, key=KEY)
    assert producer.calls - warm <= 1


# -------------------------------------------------------------- pallas backend
def test_pallas_backend_accuracy(problem):
    a, x = problem
    cfg = make_cfg()
    engine = AnalogEngine(cfg, backend="pallas")
    A = engine.program(a, KEY)
    y = engine.mvm(A, x)
    ref = AnalogEngine(cfg)
    y_ref = ref.mvm(ref.program(a, KEY), x)
    b = a @ x
    # Different input-DAC draw structure (one pass vs per-block), so compare
    # statistically: the kernel path must reach the same EC accuracy class.
    assert float(rel_l2(y, b)) < 3.0 * float(rel_l2(y_ref, b)) + 1e-3


# ----------------------------------------------------------------- ergonomics
def test_matmul_operator_and_stats(problem):
    a, x = problem
    engine = AnalogEngine(make_cfg())
    A = engine.program(a, KEY)
    y = A @ x
    assert y.shape == (a.shape[0],)
    assert float(A.write_stats.energy_j) > 0
    y2, call_stats = engine.mvm_with_stats(A, x)
    assert float(call_stats.energy_j) > 0
    # program-once: per-call input cost excludes the matrix write
    assert float(call_stats.energy_j) < float(A.write_stats.energy_j) * 10
    # legacy one-shot accounting == program + one input write
    _, legacy_stats = corrected_mvm(a, x, KEY, make_cfg())
    total = float(A.write_stats.energy_j) + float(call_stats.energy_j)
    np.testing.assert_allclose(total, float(legacy_stats.energy_j), rtol=1e-6)


def test_engine_validates_arguments():
    with pytest.raises(ValueError):
        AnalogEngine(make_cfg(), execution="nope")
    with pytest.raises(ValueError):
        AnalogEngine(make_cfg(), backend="nope")
    with pytest.raises(ValueError):
        AnalogEngine(make_cfg(), execution="distributed")   # mesh required


def test_batch_write_cost_scales(problem):
    """The satellite fix: input write cost must track the real batch size."""
    a, _ = problem
    cfg = make_cfg()
    engine = AnalogEngine(cfg)
    A = engine.program(a, KEY)
    e1 = float(A.input_write_stats(batch=1).energy_j)
    e4 = float(A.input_write_stats(batch=4).energy_j)
    np.testing.assert_allclose(e4, 4.0 * e1, rtol=1e-6)
    # and the legacy shim now passes the real batch through
    x4 = jax.random.normal(KEY, (a.shape[1], 4))
    _, s4 = corrected_mvm(a, x4, KEY, cfg)
    _, s1 = corrected_mvm(a, x4[:, :1], KEY, cfg)
    assert float(s4.energy_j) > float(s1.energy_j)
