"""Distributed tests: run in a subprocess with 8 virtual host devices so the
main pytest process keeps a single device (per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    return json.loads(out.stdout.splitlines()[-1])


PRELUDE = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.compat import set_mesh
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
""")


def test_distributed_mvm_matches_reference():
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro.core import (CrossbarConfig, MCAGeometry,
                                distributed_corrected_mvm, get_device, rel_l2)
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (256, 256))
        x = jax.random.normal(jax.random.fold_in(key, 1), (256,))
        cfg = CrossbarConfig(device=get_device("taox-hfox"),
                             geom=MCAGeometry(2, 2, 32, 32), k_iters=5, ec=True)
        y, st = distributed_corrected_mvm(a, x, key, cfg, mesh)
        raw_cfg = CrossbarConfig(device=get_device("taox-hfox"),
                                 geom=MCAGeometry(2, 2, 32, 32), k_iters=5, ec=False)
        y2, _ = distributed_corrected_mvm(a, x, key, raw_cfg, mesh)
        b = a @ x
        print(json.dumps({"ec": float(rel_l2(y, b)), "raw": float(rel_l2(y2, b)),
                          "E": float(st.energy_j)}))
    """))
    assert res["ec"] < 0.3 * res["raw"]
    assert res["E"] > 0


def test_analog_engine_distributed_program_once():
    """The distributed execution mode behind AnalogEngine: programmed once,
    executed twice, parity with the legacy one-shot entry point."""
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro.core import (CrossbarConfig, MCAGeometry,
                                distributed_corrected_mvm, get_device, rel_l2)
        from repro.engine import AnalogEngine
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (256, 256)) / 16
        x = jax.random.normal(jax.random.fold_in(key, 1), (256,))
        cfg = CrossbarConfig(device=get_device("taox-hfox"),
                             geom=MCAGeometry(2, 2, 32, 32), k_iters=5, ec=True)
        y_legacy, st = distributed_corrected_mvm(a, x, key, cfg, mesh)
        eng = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        A = eng.program(a, key)
        y1, ist = eng.mvm_with_stats(A, x)
        y2 = A @ x                     # second execution, zero re-programming
        b = a @ x
        print(json.dumps({
            "parity": float(rel_l2(y1, y_legacy)),
            "err1": float(rel_l2(y1, b)), "err2": float(rel_l2(y2, b)),
            "E_prog": float(A.write_stats.energy_j),
            "E_call": float(ist.energy_j), "E_legacy": float(st.energy_j)}))
    """))
    assert res["parity"] <= 1e-5
    assert res["err1"] < 0.1 and res["err2"] < 0.1
    assert res["E_prog"] > 0 and res["E_call"] > 0
    # legacy one-shot accounting == program + one input write
    assert abs(res["E_prog"] + res["E_call"] - res["E_legacy"]) \
        <= 1e-6 * res["E_legacy"]


def test_distributed_producer_matches_streamed():
    """Producer-driven distributed programming/MVM on a real 2x4 mesh: the
    global block-key schedule makes the mesh-sharded image bit-identical to
    the single-device streamed image; MVM values agree <= 1e-5 across the
    resident, virtual (resident=False) and pallas-backend paths; the output
    stays row-sharded."""
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
        from repro.core.distributed import pallas_shard_map_supported
        from repro.engine import AnalogEngine
        key = jax.random.PRNGKey(0)
        cfg = CrossbarConfig(device=get_device("taox-hfox"),
                             geom=MCAGeometry(1, 1, 32, 32), k_iters=5,
                             ec=True)
        n = 256                                   # 8x8 grid of 32^2 blocks
        a = jax.random.normal(key, (n, n)) / 16
        blocks = a.reshape(8, 32, 8, 32).transpose(0, 2, 1, 3)
        producer = lambda i, j: blocks[i, j]
        x = jax.random.normal(jax.random.fold_in(key, 1), (n,))

        st = AnalogEngine(cfg, execution="streamed")
        A_s = st.program(producer, key, shape=(n, n))
        y_s = st.mvm(A_s, x, key=key)

        de = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        A_d = de.program(producer, key, shape=(n, n))
        image_equal = bool(jnp.array_equal(A_d.at_blocks, A_s.at_blocks))
        y_d = de.mvm(A_d, x, key=key)
        row_sharded = "data" in str(y_d.sharding.spec)

        A_v = de.program(producer, key, shape=(n, n), resident=False)
        y_v = de.mvm(A_v, x, key=key)

        pallas_ok = pallas_shard_map_supported(mesh)
        if pallas_ok:
            dp = AnalogEngine(cfg, execution="distributed", backend="pallas",
                              mesh=mesh)
            A_p = dp.program(producer, key, shape=(n, n))
            pallas_par = float(rel_l2(dp.mvm(A_p, x, key=key), y_d))
            # dense placement through the same kernel tile step
            A_pd = dp.program(a, key)
            A_rd = de.program(a, key)
            pallas_dense = float(rel_l2(dp.mvm(A_pd, x, key=key),
                                        de.mvm(A_rd, x, key=key)))
        else:
            pallas_par = pallas_dense = -1.0  # documented fallback: reference
        b = a @ x
        print(json.dumps({
            "image_equal": image_equal, "row_sharded": row_sharded,
            "mvm": float(rel_l2(y_d, y_s)), "virt": float(rel_l2(y_v, y_d)),
            "pallas_ok": bool(pallas_ok), "pallas": pallas_par,
            "pallas_dense": pallas_dense,
            "err": float(rel_l2(y_d, b))}))
    """))
    assert res["image_equal"]
    assert res["row_sharded"]
    assert res["mvm"] <= 1e-5
    assert res["virt"] <= 1e-5
    # pallas either passes reference parity or reported its probe fallback
    if res["pallas_ok"]:
        assert res["pallas"] <= 1e-5
        assert res["pallas_dense"] <= 1e-5
    assert res["err"] < 0.1


def test_distributed_rmvm_matches_streamed():
    """Transposed corrected MVMs (A.T @ y) on a real 2x4 mesh: the global
    block-key schedule makes the mesh-sharded transposed sweep agree <= 1e-5
    with the single-device streamed transposed sweep across the resident,
    virtual (resident=False), pallas and dense placements; partials psum over
    the ROW axes and the output comes back COLUMN-sharded."""
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
        from repro.core.distributed import pallas_shard_map_supported
        from repro.engine import AnalogEngine
        key = jax.random.PRNGKey(0)
        cfg = CrossbarConfig(device=get_device("taox-hfox"),
                             geom=MCAGeometry(1, 1, 32, 32), k_iters=5,
                             ec=True)
        n = 256                                   # 8x8 grid of 32^2 blocks
        a = jax.random.normal(key, (n, n)) / 16
        blocks = a.reshape(8, 32, 8, 32).transpose(0, 2, 1, 3)
        producer = lambda i, j: blocks[i, j]
        y = jax.random.normal(jax.random.fold_in(key, 1), (n,))

        st = AnalogEngine(cfg, execution="streamed")
        A_s = st.program(producer, key, shape=(n, n))
        z_s = st.rmvm(A_s, y, key=key)

        de = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        A_d = de.program(producer, key, shape=(n, n))
        z_d = de.rmvm(A_d, y, key=key)
        col_sharded = "model" in str(z_d.sharding.spec)

        A_v = de.program(producer, key, shape=(n, n), resident=False)
        z_v = de.rmvm(A_v, y, key=key)

        A_dd = de.program(a, key)
        z_dd = de.rmvm(A_dd, y, key=key)

        pallas_ok = pallas_shard_map_supported(mesh)
        if pallas_ok:
            dp = AnalogEngine(cfg, execution="distributed", backend="pallas",
                              mesh=mesh)
            A_p = dp.program(producer, key, shape=(n, n))
            pallas_par = float(rel_l2(dp.rmvm(A_p, y, key=key), z_d))
        else:
            pallas_par = -1.0
        b = a.T @ y
        print(json.dumps({
            "col_sharded": col_sharded,
            "mvm": float(rel_l2(z_d, z_s)), "virt": float(rel_l2(z_v, z_d)),
            "dense_err": float(rel_l2(z_dd, b)),
            "pallas_ok": bool(pallas_ok), "pallas": pallas_par,
            "err": float(rel_l2(z_d, b))}))
    """))
    assert res["col_sharded"]
    assert res["mvm"] <= 1e-5
    assert res["virt"] <= 1e-5
    if res["pallas_ok"]:
        assert res["pallas"] <= 1e-5
    assert res["err"] < 0.1 and res["dense_err"] < 0.1


def test_distributed_pdhg_lp():
    """Acceptance: a random feasible LP solved by PDHG over a 2x4 mesh with a
    resident=False procedural producer -- corrected analog matvec + rmatvec
    only, objective within 1e-3 of the digital PDHG oracle, and NEITHER the
    forward nor the transposed jitted MVM ever traces an A-sized aval
    (statically asserted via the AvalBound pass)."""
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro import solvers
        from repro.analysis import CallCounter, aval_bound, dispatch_count, \\
            trace
        from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
        from repro.core.matrices import ImplicitBandedMatrix
        from repro.engine import AnalogEngine
        key = jax.random.PRNGKey(0)
        cfg = CrossbarConfig(device=get_device("epiram"),
                             geom=MCAGeometry(1, 1, 32, 32), k_iters=5,
                             ec=True)
        n = 256
        imp = ImplicitBandedMatrix(n=n, cap_m=32, cap_n=32, seed=7)
        producer = CallCounter(imp.block)

        de = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        A = de.program(producer, key, shape=(n, n), resident=False)
        a = A.dense()                  # host-side oracle materialization
        # feasible LP with known structure: complementary (x*, s) split
        u = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
        x_star = jnp.maximum(u, 0.0)
        s = jnp.maximum(-u, 0.0)
        y_star = jax.random.normal(jax.random.fold_in(key, 2), (n,),
                                   jnp.float32) / 4
        b = a @ x_star
        c = a.T @ y_star + s

        specs = (jax.ShapeDtypeStruct((n,), jnp.float32),
                 jax.ShapeDtypeStruct(key.shape, key.dtype))
        jx_fwd = trace(de.mvm_fn(A), *specs)
        fwd = aval_bound(jx_fwd, budget=n * n // 8)
        fwd.assert_ok()
        t = aval_bound(trace(de.mvm_fn(A, transpose=True), *specs),
                       budget=n * n // 8)
        t.assert_ok()
        dispatch_count(jx_fwd, max_top_level=8).assert_ok()
        after_program = producer.calls

        digital = solvers.pdhg(a, b, c, tol=1e-6, maxiter=30000)
        res = solvers.pdhg(A, b, c, tol=3e-4, maxiter=30000, key=key)
        solve_traces = producer.calls - after_program
        obj_a = float(c @ res.x)
        obj_d = float(c @ digital.x)
        print(json.dumps({
            "iters": int(res.iterations), "converged": bool(res.converged),
            "resid": float(res.final_residual),
            "obj_gap": abs(obj_a - obj_d) / (1 + abs(obj_d)),
            "traces": int(solve_traces),
            "max_fwd": int(fwd.summary["max_elements"]),
            "max_t": int(t.summary["max_elements"]), "A_elems": n * n,
            "E": float(res.ledger.total_energy_j),
            "mvms": int(res.ledger.mvms), "mvms_t": int(res.ledger.mvms_t)}))
    """), timeout=900)
    assert res["converged"] and res["resid"] <= 3e-4
    assert res["obj_gap"] <= 1e-3, res
    # forward AND transposed pipelines bound strictly below A
    assert res["max_fwd"] * 8 <= res["A_elems"], res
    assert res["max_t"] * 8 <= res["A_elems"], res
    # one solve core (fwd + transposed traces): never per-block/per-iteration
    assert res["traces"] <= 6, res
    assert res["mvms"] == res["iters"] + 1 and res["mvms_t"] == res["mvms"]
    assert res["E"] > 0


def test_distributed_producer_solve():
    """End-to-end sharded CG through repro.solvers on a 2x4 mesh: one
    compiled program per solve (producer invoked for traces only), converges,
    matches the digital oracle, and the virtual handle's jitted MVM never
    traces an A-sized aval."""
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro import solvers
        from repro.analysis import CallCounter, aval_bound, trace
        from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
        from repro.engine import AnalogEngine
        from repro.core.matrices import ImplicitBandedMatrix
        key = jax.random.PRNGKey(0)
        cfg = CrossbarConfig(device=get_device("epiram"),
                             geom=MCAGeometry(1, 1, 32, 32), k_iters=5,
                             ec=True)
        n = 256
        # procedural producer: nothing A-sized ever closes over the pipeline
        imp = ImplicitBandedMatrix(n=n, cap_m=32, cap_n=32, seed=5)
        producer = CallCounter(imp.block)
        x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,),
                                   jnp.float32)

        de = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        A = de.program(producer, key, shape=(n, n), resident=False)
        a = A.dense()                      # host-side oracle materialization
        b = a @ x_true
        bound = aval_bound(
            trace(de.mvm_fn(A), jax.ShapeDtypeStruct((n,), jnp.float32),
                  jax.ShapeDtypeStruct(key.shape, key.dtype)),
            budget=n * n // 8)
        bound.assert_ok()
        after_program = producer.calls
        res = solvers.cg(A, b, tol=1e-3, maxiter=40)
        solve_traces = producer.calls - after_program
        oracle = jnp.linalg.solve(a, b)
        print(json.dumps({
            "iters": int(res.iterations), "converged": bool(res.converged),
            "resid": float(res.final_residual),
            "traces": int(solve_traces),
            "max_elems": int(bound.summary["max_elements"]),
            "A_elems": n * n,
            "xerr": float(rel_l2(res.x, oracle)),
            "E": float(res.ledger.total_energy_j)}))
    """))
    assert res["converged"] and res["resid"] <= 1e-3
    assert res["iters"] >= 2
    # probe and static walk excluded: the solve itself adds at most ~2
    # traces (the jitted core) -- never per-block or per-iteration work
    assert res["traces"] <= 3, res
    assert res["max_elems"] * 8 <= res["A_elems"], res   # strictly sub-A
    assert res["xerr"] < 5e-3
    assert res["E"] > 0


@pytest.mark.slow
def test_distributed_scale_65536():
    """The acceptance-scale case: n=65,536 >= the paper's largest problem,
    programmed from a procedural producer over a 2x4 mesh with
    resident=False and SOLVED (CG) -- converging with no A-sized array ever
    allocated (statically asserted on the exact jitted MVM)."""
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro import solvers
        from repro.analysis import CallCounter, aval_bound, dispatch_count, \\
            trace
        from repro.core import CrossbarConfig, MCAGeometry, get_device
        from repro.engine import AnalogEngine
        n, cap = 65536, 2048
        cfg = CrossbarConfig(device=get_device("epiram"),
                             geom=MCAGeometry(1, 1, cap, cap), k_iters=5,
                             ec=True)
        eng = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        def banded(i, j):
            # Deterministic SPD banded generator (traceable, O(block) math):
            # the n^2 encode noise already dominates the sweep, so the
            # producer itself stays RNG-free to keep the test CPU-feasible.
            rows = i * cap + jnp.arange(cap)[:, None]
            cols = j * cap + jnp.arange(cap)[None, :]
            dist = jnp.abs(rows - cols)
            blk = jnp.where(dist <= 8,
                            1.0 / (1.0 + dist.astype(jnp.float32)), 0.0)
            return blk + 16.0 * (rows == cols)
        producer = CallCounter(banded)
        key = jax.random.PRNGKey(0)
        A = eng.program(producer, key, shape=(n, n), resident=False)
        jx = trace(eng.mvm_fn(A),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct(key.shape, key.dtype))
        # paper-scale proof on the exact jitted MVM: high-water mark is
        # O(one capacity block) and the whole sweep is one fused dispatch
        bound = aval_bound(jx, budget=16 * cap * cap)
        bound.assert_ok()
        dispatch_count(jx, max_top_level=8,
                       producer_calls=producer.calls,
                       max_producer_calls=3).assert_ok()
        b = jnp.ones((n,), jnp.float32)
        res = solvers.cg(A, b, tol=2e-2, maxiter=4, key=key)
        print(json.dumps({
            "iters": int(res.iterations), "converged": bool(res.converged),
            "resid": float(res.final_residual), "calls": producer.calls,
            "max_elems": int(bound.summary["max_elements"]),
            "A_elems": n * n,
            "E_write": float(res.ledger.write_energy_j)}))
    """), timeout=1500)
    assert res["converged"], res
    assert res["iters"] >= 1 and res["resid"] <= 2e-2
    # no A-sized allocation: high-water mark is O(one capacity block)
    assert res["max_elems"] * 100 <= res["A_elems"], res
    assert res["calls"] <= 4                      # traces only, never mb*nb
    assert res["E_write"] > 0


def test_compressed_psum_and_ring_matmul():
    res = run_child(PRELUDE + textwrap.dedent("""
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import (compressed_psum,
                                                   ring_collective_matmul)
        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (8, 64))    # 8 shards over 'data'+'model'

        def red(x):
            out, resid = compressed_psum(x, "data", None)
            return out, resid
        from repro.core.compat import shard_map
        f = jax.jit(shard_map(red, mesh=mesh,
                              in_specs=P(("data",), None),
                              out_specs=(P("data", None), P("data", None))))
        out, resid = f(g)
        # exact sum across the 2 'data' shards:
        exact = g[:4] + g[4:]
        err = float(jnp.max(jnp.abs(out[:4] - exact)) / jnp.max(jnp.abs(exact)))

        # ring collective matmul == dense matmul
        x = jax.random.normal(key, (16, 64))
        w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
        def ring(xx, ww):
            return ring_collective_matmul(xx, ww, "model")
        # the ring result is value-replicated over 'model' but the static vma
        # checker cannot prove it -> check_vma=False
        rm = jax.jit(shard_map(ring, mesh=mesh,
                               in_specs=(P(None, None), P("model", None)),
                               out_specs=P(None, None), check_vma=False))
        y = rm(x, w)
        merr = float(jnp.max(jnp.abs(y - x @ w)))
        print(json.dumps({"int8_err": err, "ring_err": merr}))
    """))
    assert res["int8_err"] < 0.02      # int8 quantization error bound
    assert res["ring_err"] < 1e-3


def test_sharded_train_step_matches_single_device():
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro.configs import get_arch, model_module
        from repro.configs.base import TrainConfig
        from repro.models import params as PM
        from repro.train.train_loop import make_train_step
        from repro.train.optimizer import adamw_init
        from repro.launch.steps import build_cell
        from repro.distributed.sharding import param_pspecs, batch_pspec
        from jax.sharding import NamedSharding, PartitionSpec as P

        arch = get_arch("qwen3-1.7b"); cfg = arch.reduced()
        mod = model_module(cfg)
        prm = PM.materialize(mod.init_specs(cfg), jax.random.PRNGKey(0))
        opt = adamw_init(prm)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        tcfg = TrainConfig(microbatch=4)
        step = make_train_step(mod, cfg, tcfg)

        # single device result
        p1, o1, m1 = jax.jit(step)(prm, opt, batch)

        # sharded result
        specs = mod.init_specs(cfg)
        psh = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           param_pspecs(specs, mesh, "fsdp_tp"))
        prm_s = jax.tree.map(lambda a, s: jax.device_put(a, s), prm, psh)
        with set_mesh(mesh):
            p2, o2, m2 = jax.jit(step)(prm_s, opt, batch)
        print(json.dumps({
            "loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
            "gn1": float(m1["grad_norm"]), "gn2": float(m2["grad_norm"])}))
    """))
    assert abs(res["loss1"] - res["loss2"]) < 1e-3
    assert abs(res["gn1"] - res["gn2"]) / max(res["gn1"], 1e-9) < 5e-3


def test_elastic_checkpoint_restore():
    """Save on a (2,4) mesh, restore onto a (4,2) mesh -- elastic rescale."""
    res = run_child(PRELUDE + textwrap.dedent("""
        import tempfile
        from repro.configs import get_arch, model_module
        from repro.models import params as PM
        from repro.distributed import CheckpointManager
        from repro.distributed.sharding import param_pspecs
        from jax.sharding import NamedSharding

        arch = get_arch("qwen3-1.7b"); cfg = arch.reduced()
        mod = model_module(cfg)
        specs = mod.init_specs(cfg)
        prm = PM.materialize(specs, jax.random.PRNGKey(0))
        sh1 = jax.tree.map(lambda ps: NamedSharding(mesh, ps),
                           param_pspecs(specs, mesh, "tp"))
        prm = jax.tree.map(lambda a, s: jax.device_put(a, s), prm, sh1)
        with tempfile.TemporaryDirectory() as d:
            ck = CheckpointManager(d)
            ck.save(7, {"params": prm}, blocking=True)
            mesh2 = make_mesh((4, 2), ("data", "model"))
            sh2 = jax.tree.map(lambda ps: NamedSharding(mesh2, ps),
                               param_pspecs(specs, mesh2, "fsdp_tp"))
            restored = ck.restore({"params": prm}, shardings={"params": sh2})
            ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                     for a, b in zip(jax.tree.leaves(prm),
                                     jax.tree.leaves(restored["params"])))
            print(json.dumps({"ok": bool(ok), "step": ck.latest_step()}))
    """))
    assert res["ok"] and res["step"] == 7


def test_moe_shard_map_matches_local():
    res = run_child(PRELUDE + textwrap.dedent("""
        from repro.configs import get_arch, model_module
        from repro.models import params as PM
        from repro.models.common import Runtime
        from repro.models import moe as M

        arch = get_arch("mixtral-8x7b"); cfg = arch.reduced()
        lp = PM.materialize(M.moe_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        out_local, aux_local = M.moe_apply(lp, x, cfg, Runtime())
        rt = Runtime(mesh=mesh, batch_axes=("data",))
        with set_mesh(mesh):
            out_sm, aux_sm = jax.jit(
                lambda p, xx: M.moe_apply(p, xx, cfg, rt))(lp, x)
        err = float(jnp.max(jnp.abs(out_local - out_sm)))
        print(json.dumps({"err": err, "aux_l": float(aux_local),
                          "aux_s": float(aux_sm)}))
    """))
    assert res["err"] < 2e-2, res
    assert abs(res["aux_l"] - res["aux_s"]) < 2e-2
