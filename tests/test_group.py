"""AnalogMatrixGroup: whole-model single-dispatch execution tests.

Covers the grouped-execution acceptance criteria: grouped member g is
draw-identical to a solo handle programmed under ``fold_in(key, g)`` across
reference/pallas x local/streamed placements (and bit-identical grouped vs
solo WITHIN the distributed path on a 1x1 mesh), grouped MoE experts equal
stacked solo experts, the chained whole-model forward matches the per-layer
loop and traces to ONE top-level dispatch, per-member AgeLedger advancement
matches solo aging, the ``_scan_exec`` pipeline caches stay bounded under
bucket churn, and grouped ``program_rram`` agrees with the ungrouped walk
while collapsing the dispatch plan to distinct kernel shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_path_parity
from conftest import mesh_1x1 as _mesh_1x1

from repro.analysis import dispatch_count, trace
from repro.core import (CrossbarConfig, MCAGeometry, get_device, rel_l2)
from repro.engine import (SCAN_CACHE_MAX, AnalogEngine, AnalogMatrixGroup,
                          _BoundedCache)
from repro.reliability.aging import attach_age, attach_group_age

KEY = jax.random.PRNGKey(7)
GEOM = MCAGeometry(tile_rows=2, tile_cols=2, cell_rows=32, cell_cols=32)
SIZE = 3


def make_cfg(**kw):
    base = dict(device=get_device("taox-hfox"), geom=GEOM, k_iters=5, ec=True)
    base.update(kw)
    return CrossbarConfig(**base)


@pytest.fixture(scope="module")
def stack():
    """SIZE same-geometry member matrices + a shared input vector."""
    a = jax.random.normal(KEY, (SIZE, 100, 90)) / 10
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (90,))
    y = jax.random.normal(jax.random.fold_in(KEY, 2), (100,))
    return a, x, y


def _member_keys(key, size=SIZE):
    return [jax.random.fold_in(key, g) for g in range(size)]


def _solo_handles(engine, a, key):
    return [engine.program(a[g], k) for g, k in enumerate(_member_keys(key))]


# ------------------------------------------------------------- programming
def test_program_group_matches_solo_program(stack):
    """program_group member g draws the same random variates a solo program
    under fold_in(key, g) draws; images agree to float32 rounding (the one
    fused vmapped encode may be reassociated differently by XLA than the
    eager per-member path -- same contract as grouped program_rram)."""
    a, _, _ = stack
    engine = AnalogEngine(make_cfg())
    G = engine.program_group(a, KEY)
    assert isinstance(G, AnalogMatrixGroup)
    assert G.size == SIZE and G.shape == (100, 90)
    for g, A in enumerate(_solo_handles(engine, a, KEY)):
        np.testing.assert_allclose(np.asarray(G.at_blocks[g]),
                                   np.asarray(A.at_blocks), atol=1e-5, rtol=0)
        np.testing.assert_allclose(np.asarray(G.da_blocks[g]),
                                   np.asarray(A.da_blocks), atol=1e-5, rtol=0)


def test_group_of_handles_equals_program_group(stack):
    """engine.group(handles) stacks the existing images EXACTLY (zero
    re-encode work); program_group's fused encode agrees to f32 rounding."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    handles = _solo_handles(engine, a, KEY)
    G1 = engine.program_group(a, KEY)
    G2 = engine.group(handles)
    for g, A in enumerate(handles):      # group() is bit-exact stacking
        np.testing.assert_array_equal(np.asarray(G2.at_blocks[g]),
                                      np.asarray(A.at_blocks))
    np.testing.assert_allclose(np.asarray(G1.at_blocks),
                               np.asarray(G2.at_blocks), atol=1e-5, rtol=0)
    k = jax.random.fold_in(KEY, 3)
    assert float(rel_l2(engine.group_mvm(G1, x, key=k),
                        engine.group_mvm(G2, x, key=k))) <= 1e-5


# ------------------------------------------------------------------ parity
def _grouped_vs_solo(engine, G, handles, x, y, call_key, *, exact=False):
    """The grouped-vs-solo comparison all placement parity tests share:
    grouped member g against the solo handle executed under fold_in(key, g),
    both directions, via the conftest parity harness (results-mapping mode,
    where the "paths" are group membership rather than placement)."""
    Y = engine.group_mvm(G, x, key=call_key)
    Z = engine.group_rmvm(G, y, key=call_key)
    solo = []
    for g, A in enumerate(handles):
        kg = jax.random.fold_in(call_key, g)
        solo.append((engine.mvm(A, x, key=kg), engine.rmvm(A, y, key=kg)))
    grouped = [(Y[g], Z[g]) for g in range(len(handles))]
    assert_path_parity({"solo": solo, "grouped": grouped},
                       reference="solo",
                       exact=("grouped",) if exact else ())
    return Y, Z


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_group_solo_parity_local(stack, backend):
    """Grouped member g == solo handle under fold_in(key, g), both
    directions, reference and pallas backends (<= 1e-5; reference is
    draw-identical)."""
    a, x, y = stack
    engine = AnalogEngine(make_cfg(), backend=backend)
    G = engine.program_group(a, KEY)
    handles = _solo_handles(engine, a, KEY)
    Y, Z = _grouped_vs_solo(engine, G, handles, x, y,
                            jax.random.fold_in(KEY, 4))
    assert Y.shape == (SIZE, 100) and Z.shape == (SIZE, 90)


def test_group_solo_parity_streamed(stack):
    """Grouped lax.switch producer execution == solo streamed handles."""
    a, x, y = stack
    cfg = make_cfg()
    engine = AnalogEngine(cfg, execution="streamed")
    producers = [(lambda g: lambda i, j: _block(a[g], cfg, i, j))(g)
                 for g in range(SIZE)]
    G = engine.program_group(producers, KEY, shape=(100, 90))
    assert G.da_blocks is None          # streamed groups re-derive da in-scan
    handles = [engine.program(producers[g], jax.random.fold_in(KEY, g),
                              shape=(100, 90)) for g in range(SIZE)]
    _grouped_vs_solo(engine, G, handles, x, y, jax.random.fold_in(KEY, 5))


def _block(a, cfg, i, j):
    cm, cn = cfg.geom.capacity
    return jax.lax.dynamic_slice(a, (i * cm, j * cn), (cm, cn))


def test_group_solo_bit_identical_distributed_1x1(stack):
    """Within the distributed path, a 1x1-mesh grouped execute is
    BIT-identical to the solo distributed execute per member."""
    a, x, y = stack
    engine = AnalogEngine(make_cfg(), execution="distributed",
                          mesh=_mesh_1x1())
    G = engine.program_group(a, KEY)
    assert G.mesh_sharded
    handles = _solo_handles(engine, a, KEY)
    _grouped_vs_solo(engine, G, handles, x, y, jax.random.fold_in(KEY, 6),
                     exact=True)


def test_default_key_schedule_matches_solo_calls(stack):
    """With NO explicit key, grouped call c draws exactly what each solo
    handle's call c draws: member g's schedule is preserved inside the
    group (call 0 uses member_keys, call c folds the group counter)."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    handles = _solo_handles(engine, a, KEY)
    G = engine.group(handles)                # bit-exact stacked operands
    for _ in range(2):                       # calls 0 and 1
        Y = engine.group_mvm(G, x)
        for g, A in enumerate(handles):
            np.testing.assert_array_equal(np.asarray(Y[g]),
                                          np.asarray(engine.mvm(A, x)))


def test_moe_experts_pytree_equals_stacked_solo(stack):
    """The MoE pattern: a pytree of expert kernels grouped into one image
    equals the stacked outputs of per-expert solo handles."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    experts = {f"expert_{g}": a[g] for g in range(SIZE)}
    G = engine.program_group(experts, KEY)
    k = jax.random.fold_in(KEY, 7)
    Y = engine.group_mvm(G, x, key=k)
    solo = jnp.stack([
        engine.mvm(A, x, key=jax.random.fold_in(k, g))
        for g, A in enumerate(_solo_handles(engine, a, KEY))])
    assert float(rel_l2(Y, solo)) <= 1e-5


# ----------------------------------------------------------- batched inputs
def test_group_input_shapes(stack):
    """1-D broadcast, 2-D per-member, and 3-D batched inputs agree."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    G = engine.program_group(a, KEY)
    k = jax.random.fold_in(KEY, 8)
    y1 = engine.group_mvm(G, x, key=k)                       # (S, m)
    xm = jnp.stack([x] * SIZE)                               # (S, n)
    y2 = engine.group_mvm(G, xm, key=k)
    xb = jnp.broadcast_to(x[None, :, None], (SIZE, 90, 2))   # (S, n, B)
    y3 = engine.group_mvm(G, xb, key=k)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert y3.shape == (SIZE, 100, 2)
    with pytest.raises(ValueError):
        engine.group_mvm(G, jnp.zeros((SIZE + 1, 90)), key=k)
    with pytest.raises(ValueError):
        engine.group_mvm(G, jnp.zeros((77,)), key=k)


# ------------------------------------------------------------ single dispatch
def test_group_and_chain_single_dispatch(stack):
    """The jitted grouped closures trace to exactly ONE top-level eqn --
    the whole multi-image (or whole-model chained) execute is one launch."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    G = engine.program_group(a, KEY)
    sq = jnp.einsum("gmn,gkn->gmk", a, a)        # (S, 100, 100) square
    C = engine.program_group(sq, KEY)
    k = jax.random.fold_in(KEY, 9)
    for fn, vec in ((engine.group_mvm_fn(G), x),
                    (engine.group_mvm_fn(G, transpose=True),
                     jnp.zeros((100,))),
                    (engine.chain_fn(C, activation="relu"),
                     jnp.zeros((100,)))):
        jaxpr = trace(jax.jit(fn), vec, k)
        report = dispatch_count(jaxpr, max_top_level=1)
        assert not report.violations, report.violations


def test_chain_matches_solo_loop(stack):
    """chain_mvm == the per-layer Python loop with the same activation and
    per-member keys -- activation in, logits out, one dispatch."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    sq = jax.random.normal(KEY, (SIZE, 96, 96)) / 96
    G = engine.program_group(sq, KEY)
    k = jax.random.fold_in(KEY, 10)
    h = jax.random.normal(jax.random.fold_in(KEY, 11), (96,))
    y = engine.chain_mvm(G, h, key=k, activation="relu")
    ref = h
    for g, A in enumerate(_solo_handles(engine, sq, KEY)):
        ref = jax.nn.relu(engine.mvm(A, ref, key=jax.random.fold_in(k, g)))
    assert float(rel_l2(y, ref)) <= 1e-5
    with pytest.raises(ValueError):              # non-square members
        engine.chain_mvm(engine.program_group(a, KEY), x, key=k)
    with pytest.raises(ValueError):              # unknown activation
        engine.chain_mvm(G, h, key=k, activation="swoosh")


# ------------------------------------------------------------------- aging
def test_group_age_ledger_matches_solo(stack):
    """Per-member AgeLedger: grouped aged execution applies each member's
    own drift/fault transform and advances every member exactly as a solo
    aged handle does."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    G = engine.group(_solo_handles(engine, a, KEY))   # bit-exact operands
    attach_group_age(G)
    G.ages = G.ages.advanced(50).elapsed(3600.0)
    k = jax.random.fold_in(KEY, 12)
    Y = engine.group_mvm(G, x, key=k)
    assert float(G.ages.mvms[0, 0, 0]) == 51.0   # advanced inside execute
    for g, A in enumerate(_solo_handles(engine, a, KEY)):
        attach_age(A)
        A.age = A.age.advanced(50).elapsed(3600.0)
        y = engine.mvm(A, x, key=jax.random.fold_in(k, g))
        np.testing.assert_array_equal(np.asarray(Y[g]), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(G.ages.mvms[g]),
                                      np.asarray(A.age.mvms))


# ---------------------------------------------------------- bounded caches
def test_scan_cache_bounded(stack):
    """Bucket churn can't grow the per-handle pipeline cache past
    SCAN_CACHE_MAX: a long-lived server cycling decode/batch buckets holds
    a fixed number of compiled pipelines."""
    a, x, _ = stack
    cfg = make_cfg()
    engine = AnalogEngine(cfg, execution="streamed")
    A = engine.program(lambda i, j: _block(a[0], cfg, i, j), KEY,
                       shape=(100, 90))
    k = jax.random.fold_in(KEY, 13)
    for batch in range(1, SCAN_CACHE_MAX + 5):
        xb = jnp.broadcast_to(x[:, None], (90, batch))
        engine.mvm(A, xb, key=k)
    assert isinstance(A._scan_exec, _BoundedCache)
    assert len(A._scan_exec) <= SCAN_CACHE_MAX
    A.release()
    assert A._scan_exec is None


def test_group_scan_cache_bounded(stack):
    a, x, _ = stack
    cfg = make_cfg()
    engine = AnalogEngine(cfg, execution="streamed")
    producers = [(lambda g: lambda i, j: _block(a[g], cfg, i, j))(g)
                 for g in range(SIZE)]
    G = engine.program_group(producers, KEY, shape=(100, 90))
    k = jax.random.fold_in(KEY, 14)
    for batch in range(1, SCAN_CACHE_MAX + 5):
        xb = jnp.broadcast_to(x[None, :, None], (SIZE, 90, batch))
        engine.group_mvm(G, xb, key=k)
    assert len(G._scan_exec) <= SCAN_CACHE_MAX
    G.release()
    assert G._scan_exec is None


def test_server_decode_cache_bounded():
    """Server._decode is the same bounded LRU: cycling more decode buckets
    than SCAN_CACHE_MAX never holds more compiled pipelines than the cap
    (buckets are built lazily here -- nothing compiles until called)."""
    from repro.train.serve import Server
    srv = Server.__new__(Server)                 # cache behavior only
    srv._decode = _BoundedCache()
    for n in range(2, SCAN_CACHE_MAX + 6):
        srv._decode.put(n, object())
    assert len(srv._decode) <= SCAN_CACHE_MAX
    assert srv._decode.get(SCAN_CACHE_MAX + 5) is not None
    assert srv._decode.get(2) is None            # evicted


# -------------------------------------------------------------- validation
def test_group_validation(stack):
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    other = AnalogEngine(make_cfg(k_iters=3))
    G = engine.program_group(a, KEY)
    with pytest.raises(ValueError):              # mixed member shapes
        engine.program_group([a[0], a[1][:64]], KEY)
    with pytest.raises(ValueError):              # arrays mixed with producers
        engine.program_group([a[0], lambda i, j: a[1]], KEY)
    with pytest.raises(ValueError):              # group() needs handles
        engine.group([])
    with pytest.raises((TypeError, ValueError)):   # solo API on a group
        engine.mvm(G, x)
    with pytest.raises((TypeError, ValueError)):   # cross-engine execution
        other.group_mvm(G, x, key=KEY)
    with pytest.raises(ValueError):              # local engine, producers
        engine.program_group([lambda i, j: a[0]] * 2, KEY, shape=(100, 90))
    with pytest.raises(ValueError):              # default key inside jit
        jax.jit(lambda v: engine.group_mvm(G, v))(x)


def test_group_stats_and_member_views(stack):
    """Write stats total the per-member cost; member(g) is a usable view;
    input stats scale with the group size."""
    a, x, _ = stack
    engine = AnalogEngine(make_cfg())
    G = engine.program_group(a, KEY)
    A = engine.program(a[0], KEY)
    assert G.write_stats.energy_j == pytest.approx(
        SIZE * A.write_stats.energy_j, rel=1e-6)
    member = G.member(1)
    assert member.shape == (100, 90)
    np.testing.assert_array_equal(np.asarray(member.at_blocks),
                                  np.asarray(G.at_blocks[1]))
    gs = G.input_write_stats(batch=4)
    ss = engine.input_write_stats(A, batch=4)
    assert gs.energy_j == pytest.approx(SIZE * ss.energy_j, rel=1e-6)
    assert (G @ x).shape == (SIZE, 100)


# -------------------------------------------------- grouped model programming
def test_program_rram_grouped_parity_and_plan():
    """Grouped program_rram == the ungrouped walk (w_tilde to float32
    rounding, dw within its bf16 quantization floor) and the dispatch plan
    collapses to distinct kernel shapes."""
    from repro.configs.base import RRAMBackendConfig
    from repro.models.rram import program_rram, programming_dispatch_plan
    cfg = RRAMBackendConfig(enabled=True)
    params = {
        "blk0": {"attn": {"w": jax.random.normal(KEY, (64, 48)) / 8},
                 "mlp": {"w": jax.random.normal(
                     jax.random.fold_in(KEY, 1), (48, 64)) / 8}},
        "blk1": {"attn": {"w": jax.random.normal(
                     jax.random.fold_in(KEY, 2), (64, 48)) / 8},
                 "scan": {"w": jax.random.normal(
                     jax.random.fold_in(KEY, 3), (2, 32, 32)) / 8}},
    }
    plan = programming_dispatch_plan(params)
    assert plan == {"kernels": 4, "groups": 3}   # (64,48) x2 collapse
    grouped, gs = program_rram(params, cfg, KEY, group=True)
    solo, ss = program_rram(params, cfg, KEY, group=False)
    flat_g, _ = jax.tree_util.tree_flatten_with_path(grouped)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(solo)
    for (path, lg), (_, ls) in zip(flat_g, flat_s):
        name = jax.tree_util.keystr(path)
        tol = 1e-4 if "dw" in name else 1e-5     # dw stored in bf16
        np.testing.assert_allclose(
            np.asarray(jnp.asarray(lg, jnp.float32)),
            np.asarray(jnp.asarray(ls, jnp.float32)),
            atol=tol, rtol=0, err_msg=name)
    assert gs.energy_j == pytest.approx(ss.energy_j, rel=1e-6)
