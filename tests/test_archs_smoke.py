"""Per-architecture smoke tests: reduced config, one forward/train step and a
prefill+decode step on CPU; asserts output shapes and finiteness (f).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, model_module
from repro.models import params as P
from repro.models.common import Runtime


def build(arch_name):
    arch = get_arch(arch_name)
    cfg = arch.reduced()
    mod = model_module(cfg)
    specs = mod.init_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    return arch, cfg, mod, prm


def tiny_batch(cfg, b=2, t=16, key=jax.random.PRNGKey(1)):
    tokens = jax.random.randint(key, (b, t), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(key, (b, t, cfg.d_model))
    if cfg.family == "llama_vision":
        batch["patches"] = jax.random.normal(key, (b, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch_name", ARCHS)
def test_forward_loss(arch_name):
    arch, cfg, mod, prm = build(arch_name)
    batch = tiny_batch(cfg)
    loss = jax.jit(lambda p, b: mod.loss(p, b, cfg, Runtime()))(prm, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch_name} loss not finite"
    # Reasonable CE magnitude for random init: ~ln(vocab).
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch_name", ARCHS)
def test_train_grad_step(arch_name):
    arch, cfg, mod, prm = build(arch_name)
    batch = tiny_batch(cfg)
    g = jax.jit(jax.grad(lambda p: mod.loss(p, batch, cfg, Runtime())))(prm)
    leaves = jax.tree.leaves(g)
    assert leaves, "no grads"
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves), (
        f"{arch_name}: non-finite grads")
    # At least the embedding must receive signal.
    gnorm = sum(float(jnp.sum(jnp.square(x))) for x in leaves)
    assert gnorm > 0.0


@pytest.mark.parametrize("arch_name", ARCHS)
def test_prefill_decode(arch_name):
    arch, cfg, mod, prm = build(arch_name)
    rt = Runtime()
    batch = tiny_batch(cfg, t=8)
    logits, caches = jax.jit(
        lambda p, b: mod.prefill(p, b, cfg, rt, 16))(prm, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    step = jax.jit(lambda p, t, c: mod.decode_step(p, t, c, cfg, rt))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits2, caches = step(prm, tok, caches)
        assert logits2.shape == (2, 1, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits2)))
        tok = jnp.argmax(logits2[:, -1], -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch_name", ["qwen3-1.7b", "rwkv6-1.6b", "zamba2-1.2b"])
def test_decode_matches_teacher_forcing(arch_name):
    """Prefill+decode must agree with full-sequence forward (cache correctness)."""
    arch, cfg, mod, prm = build(arch_name)
    rt = Runtime()
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    if cfg.family == "rwkv6":
        hidden, _ = mod.forward(prm, tokens, cfg, rt)
    elif cfg.family == "zamba2":
        hidden, _ = mod.forward(prm, tokens, cfg, rt)
    else:
        hidden, _ = mod.forward(prm, tokens, cfg, rt)
    import repro.models.transformer as base
    full_logits = base.logits_fn(prm, hidden, cfg, rt)

    lg, caches = mod.prefill(prm, {"tokens": tokens[:, :8]}, cfg, rt, 16) \
        if cfg.family != "rwkv6" else mod.prefill(prm, {"tokens": tokens[:, :8]}, cfg, rt)
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full_logits[:, 7]),
                               rtol=2e-3, atol=2e-3)
    for t in range(8, 11):
        lg, caches = mod.decode_step(prm, tokens[:, t:t + 1], caches, cfg, rt)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch_name} step {t}")


def test_mixtral_circular_swa_cache_matches_teacher_forcing():
    """Sliding-window circular KV cache: prefill past the window + decode must
    agree with the full-sequence forward (rolling-cache correctness)."""
    import dataclasses
    import repro.models.transformer as base
    arch = get_arch("mixtral-8x7b")
    cfg = dataclasses.replace(arch.reduced(), swa_window=8)
    mod = model_module(cfg)
    prm = P.materialize(mod.init_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    rt = __import__("repro.models.common", fromlist=["Runtime"]).Runtime()
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 20), 0, cfg.vocab)
    hidden, _, _ = mod.forward(prm, tokens, cfg, rt)
    full_logits = base.logits_fn(prm, hidden, cfg, rt)
    lg, c = mod.prefill(prm, {"tokens": tokens[:, :16]}, cfg, rt, 8)
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_logits[:, 15]),
                               rtol=2e-3, atol=2e-3)
    for t in range(16, 19):
        lg, c = mod.decode_step(prm, tokens[:, t:t + 1], c, cfg, rt)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-3, atol=2e-3)
