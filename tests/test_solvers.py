"""Tests for the repro.solvers subsystem: digital-oracle parity, EC on/off,
execution-mode equivalence, multi-RHS batching, ledgers, and the fused Pallas
update kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import (analog_cfg, assert_path_parity, make_analog,
                      spd_system)

from repro import solvers
from repro.core import rel_l2
from repro.core.virtualization import zero_padding
from repro.engine import AnalogEngine

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ digital oracle
@pytest.mark.parametrize("solver", ["richardson", "jacobi", "cg", "bicgstab",
                                    "gmres", "refine"])
def test_digital_matches_linalg_solve(solver):
    a, x_true, b = spd_system(64)
    res = getattr(solvers, solver)(a, b, tol=1e-6, maxiter=100)
    oracle = jnp.linalg.solve(a, b)
    assert res.converged, res
    assert float(rel_l2(res.x, oracle)) < 1e-4, res


def test_gmres_bicgstab_nonsymmetric():
    a, x_true, b = spd_system(64)
    r = jax.random.normal(jax.random.fold_in(KEY, 3), a.shape) / 8
    ns = a + (r - r.T)
    bns = ns @ x_true
    for fn in (solvers.gmres, solvers.bicgstab):
        res = fn(ns, bns, tol=1e-6, maxiter=200)
        assert float(rel_l2(res.x, x_true)) < 1e-4, res


def test_spectral_bounds_and_auto_omega():
    a, _, _ = spd_system(64)
    lmin, lmax = solvers.spectral_bounds(a, iters=32)
    w = np.linalg.eigvalsh(np.asarray(a))
    assert abs(lmax - w[-1]) / w[-1] < 0.1
    assert abs(lmin - w[0]) / w[0] < 0.25
    # auto-omega beats the old hand-tuned omega = 1/3 in iteration count
    _, _, b = spd_system(64)
    auto = solvers.richardson(a, b, tol=1e-6, maxiter=100)
    fixed = solvers.richardson(a, b, omega=1.0 / 3.0, tol=1e-6, maxiter=100)
    assert auto.converged and fixed.converged
    assert auto.iterations < fixed.iterations


def test_early_stopping_and_history():
    a, _, b = spd_system(64)
    res = solvers.cg(a, b, tol=1e-3, maxiter=100)
    assert res.converged and res.iterations < 100
    hist = np.asarray(res.residuals)
    assert np.isfinite(hist[:res.iterations]).all()
    assert np.isnan(hist[res.iterations:]).all()       # early-stopped tail
    assert hist[res.iterations - 1] <= 1e-3


# ----------------------------------------------------------------- analog EC
def test_analog_cg_oracle_parity_with_ec():
    a, x_true, b = spd_system(96)
    _, A = make_analog(a, device="epiram", ec=True)
    res = solvers.cg(A, b, tol=1e-4, maxiter=40)
    oracle = jnp.linalg.solve(a, b)
    assert float(rel_l2(res.x, oracle)) < 5e-3, res


def test_ec_on_beats_ec_off():
    a, x_true, b = spd_system(96)
    _, A_ec = make_analog(a, device="taox-hfox", ec=True)
    _, A_raw = make_analog(a, device="taox-hfox", ec=False)
    r_ec = solvers.cg(A_ec, b, tol=0.0, maxiter=12)
    r_raw = solvers.cg(A_raw, b, tol=0.0, maxiter=12)
    # the honest metric: TRUE digital residual of the returned solution
    t_ec = float(rel_l2(a @ r_ec.x, b))
    t_raw = float(rel_l2(a @ r_raw.x, b))
    assert t_ec < 0.35 * t_raw, (t_ec, t_raw)


def test_streamed_matches_dense_solve():
    # same base key -> identical programming + DAC draws -> identical solve
    a, _, b = spd_system(64)
    assert_path_parity(
        a=a, cfg=analog_cfg(64), key=KEY, paths=("local", "streamed"),
        run=lambda eng, A: (lambda r: (r.x, jnp.float32(r.iterations)))(
            solvers.cg(A, b, tol=1e-4, maxiter=40)))


def test_streamed_solver_traces_once():
    """A CG solve over a traceable streamed producer is one compiled program
    end-to-end: the producer is invoked O(1) times total (traces only), never
    once per block per iteration."""
    a, _, b = spd_system(64)
    eng_d, _ = make_analog(a, device="epiram")
    cfg = eng_d.cfg
    cap_m, cap_n = cfg.geom.capacity
    a_pad = zero_padding(a, cfg.geom)
    mb, nb = a_pad.shape[0] // cap_m, a_pad.shape[1] // cap_n
    blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)
    calls = {"n": 0}

    def producer(i, j):
        calls["n"] += 1
        return blocks[i, j]

    eng_s = AnalogEngine(cfg, execution="streamed")
    A_s = eng_s.program(producer, KEY, shape=a.shape)
    assert A_s.block_traceable
    res = solvers.cg(A_s, b, tol=1e-4, maxiter=40)
    assert res.iterations >= 2               # several MVMs actually ran
    # probe + program trace + one solve-core trace: never per-block/per-iter
    assert calls["n"] <= 4, calls
    oracle = jnp.linalg.solve(a, b)
    assert float(rel_l2(res.x, oracle)) < 5e-3, res


def test_batched_matches_stacked_single_rhs():
    a, _, _ = spd_system(64)
    B = jax.random.normal(jax.random.fold_in(KEY, 9), (64, 3), jnp.float32)
    # digital operator: per-column scalars make the batched solve exactly the
    # stacked single-RHS solves (same iteration space, no cross-column mixing)
    rb = solvers.cg(a, B, tol=1e-6, maxiter=100)
    assert rb.x.shape == (64, 3) and rb.residuals.ndim == 2
    for j in range(3):
        rj = solvers.cg(a, B[:, j], tol=1e-6, maxiter=100)
        assert float(rel_l2(rb.x[:, j], rj.x)) < 1e-5
    # analog path: same statistics, every column below the same error bound
    _, A = make_analog(a, device="epiram")
    rba = solvers.cg(A, B, tol=1e-4, maxiter=40)
    oracle = jnp.linalg.solve(a, B)
    for j in range(3):
        assert float(rel_l2(rba.x[:, j], oracle[:, j])) < 5e-3


def test_refinement_beats_pure_analog_floor():
    a, x_true, b = spd_system(96)
    _, A = make_analog(a, device="taox-hfox", ec=True)
    pure = solvers.cg(A, b, tol=0.0, maxiter=15)
    ref = solvers.refine(A, b, tol=1e-6, maxiter=15, inner_iters=5)
    t_pure = float(rel_l2(a @ pure.x, b))
    t_ref = float(rel_l2(a @ ref.x, b))
    # the digital outer residual pushes below the analog noise floor
    assert t_ref < 0.1 * t_pure, (t_ref, t_pure)
    assert ref.converged


def test_jacobi_uses_programmed_diagonal():
    a, x_true, b = spd_system(64, scale=4.0)      # strongly diagonally dominant
    _, A = make_analog(a, device="epiram")
    res = solvers.jacobi(A, b, tol=1e-3, maxiter=100)
    assert res.converged
    assert float(rel_l2(res.x, x_true)) < 5e-3


def test_distributed_producer_solve_matches_streamed_1x1():
    """A producer-driven execution='distributed' CG solve on a 1x1 mesh is
    draw-identical to the single-device streamed solve (same global block-key
    schedule), stays one compiled program, and never gathers A."""
    from conftest import block_view, mesh_1x1
    a, _, b = spd_system(64)
    cfg = analog_cfg(64)
    res = assert_path_parity(
        a=a, cfg=cfg, key=KEY, paths=("streamed", "dist-1x1"),
        run=lambda eng, A: (lambda r: (r.x, jnp.float32(r.iterations)))(
            solvers.cg(A, b, tol=1e-4, maxiter=40)))
    assert res["streamed"][1] >= 2               # several MVMs actually ran

    # the trace-count proof needs its own counting producer
    blocks = block_view(a, cfg)
    calls = {"n": 0}

    def producer(i, j):
        calls["n"] += 1
        return blocks[i, j]

    eng = AnalogEngine(cfg, execution="distributed", mesh=mesh_1x1())
    A_d = eng.program(producer, KEY, shape=a.shape)
    traces = calls["n"]
    r_d = solvers.cg(A_d, b, tol=1e-4, maxiter=40)
    # probe + program trace + one solve-core trace: one compiled program
    assert calls["n"] - traces <= 1, calls
    assert float(rel_l2(r_d.x, res["streamed"][0])) < 1e-5
    assert r_d.ledger.total_energy_j > 0


# ------------------------------------------------- PDHG linear programming
def test_pdhg_digital_reaches_known_optimum():
    """Digital PDHG on a random feasible LP with a constructed optimal pair:
    objective within 1e-4 of the known optimum, primal feasible, x >= 0."""
    a, b, c, x_star, y_star = solvers.random_feasible_lp(
        jax.random.fold_in(KEY, 11), 48, 64)
    obj_star = float(c @ x_star)
    assert abs(obj_star - float(b @ y_star)) < 1e-5   # strong duality holds
    res = solvers.pdhg(a, b, c, tol=1e-6, maxiter=20000)
    assert res.converged, res
    assert abs(float(c @ res.x) - obj_star) / (1 + abs(obj_star)) < 1e-4
    assert float(rel_l2(a @ res.x, b)) < 1e-4          # primal feasibility
    assert float(res.x.min()) >= 0.0
    assert res.dual is not None and res.dual.shape == b.shape
    # dual objective closes the gap too
    assert abs(-float(b @ res.dual) - obj_star) / (1 + abs(obj_star)) < 1e-4


def test_pdhg_analog_matches_digital_oracle():
    """Acceptance: an analog PDHG solve over a programmed dense local handle
    -- corrected matvec/rmatvec only -- reaches the digital PDHG oracle's
    objective within 1e-3, and the ledger bills forward and transposed MVMs
    separately on top of the one-time write."""
    a, b, c, _, _ = solvers.random_feasible_lp(
        jax.random.fold_in(KEY, 12), 48, 64)
    digital = solvers.pdhg(a, b, c, tol=1e-6, maxiter=20000)
    _, A = make_analog(a, device="epiram")
    res = solvers.pdhg(A, b, c, tol=2e-4, maxiter=20000, key=KEY)
    assert res.converged, res
    obj_a, obj_d = float(c @ res.x), float(c @ digital.x)
    assert abs(obj_a - obj_d) / (1 + abs(obj_d)) <= 1e-3, (obj_a, obj_d)
    led = res.ledger
    assert led.mvms == res.iterations + 1          # init + one matvec/iter
    assert led.mvms_t == led.mvms                  # one rmatvec per matvec
    # 16 power steps = 16 forward + 16 transposed batch-1 setup MVMs,
    # each half billed at its own direction's input-write rate
    assert led.mvms_single == 16 and led.mvms_single_t == 16
    assert led.write_energy_j > 0
    # transposed executions contribute their own billed energy
    assert float(led.input_stats_t.energy_j) > 0
    assert led.total_energy_j == pytest.approx(
        led.write_energy_j
        + led.mvms * float(led.input_stats.energy_j)
        + led.mvms_single * float(led.input_stats_single.energy_j)
        + led.mvms_t * float(led.input_stats_t.energy_j)
        + led.mvms_single_t * float(led.input_stats_single_t.energy_j))


def test_pdhg_batched_matches_stacked():
    """Multi-RHS PDHG (one LP per column) equals the stacked single-column
    solves on a digital operator (per-column scalars, no cross-mixing)."""
    a, B, C, _, _ = solvers.random_feasible_lp(
        jax.random.fold_in(KEY, 13), 32, 48, batch=3)
    rb = solvers.pdhg(a, B, C, tol=1e-5, maxiter=20000)
    assert rb.x.shape == (48, 3) and rb.dual.shape == (32, 3)
    for j in range(3):
        rj = solvers.pdhg(a, B[:, j], C[:, j], tol=1e-5, maxiter=20000)
        assert float(rel_l2(rb.x[:, j], rj.x)) < 1e-4


def test_pdhg_streamed_matches_dense():
    """Same base key => identical programming and DAC draws => a streamed
    producer handle runs the identical PDHG solve as the dense handle."""
    a, b, c, _, _ = solvers.random_feasible_lp(
        jax.random.fold_in(KEY, 14), 64, 64)
    assert_path_parity(
        a=a, cfg=analog_cfg(64), key=KEY, paths=("local", "streamed"),
        run=lambda eng, A: (lambda r: (r.x, jnp.float32(r.iterations)))(
            solvers.pdhg(A, b, c, tol=5e-4, maxiter=5000, key=KEY)))


def test_pdhg_operator_validation():
    a, b, c, _, _ = solvers.random_feasible_lp(
        jax.random.fold_in(KEY, 15), 8, 12)
    with pytest.raises(ValueError, match="rmatvec"):
        solvers.pdhg(solvers.as_operator(lambda v, k: v[:8], shape=(8, 12)),
                     b, c)
    with pytest.raises(ValueError, match="rows"):
        solvers.pdhg(a, c, b)                      # swapped panels
    with pytest.raises(ValueError, match="batch"):
        solvers.pdhg(a, b[:, None], jnp.stack([c, c], axis=1))
    # a bare matvec WITH rmatvec= works
    op = solvers.as_operator(lambda v, k: a @ v, shape=a.shape,
                             rmatvec=lambda u, k: a.T @ u)
    res = solvers.pdhg(op, b, c, tol=1e-5, maxiter=20000)
    assert res.converged


def test_operator_transpose_view():
    """as_operator(A.T) and LinearOperator.T swap matvec/rmatvec and share
    the parent's programmed image and write cost."""
    a, _, _ = spd_system(64)
    a = a[:48]                                     # rectangular (48, 64)
    _, A = make_analog(jnp.pad(a, ((0, 16), (0, 0))))  # square handle
    # dense digital: .T is exact
    op = solvers.as_operator(a)
    v = jax.random.normal(jax.random.fold_in(KEY, 16), (48,))[:, None]
    np.testing.assert_allclose(np.asarray(op.T.matvec(v, KEY)),
                               np.asarray(a.T @ v), rtol=1e-6)
    assert op.T.shape == (64, 48) and op.T.T.shape == a.shape
    # analog: as_operator over the engine view executes the parent's rmvm
    opA = solvers.as_operator(A.T)
    u = jax.random.normal(jax.random.fold_in(KEY, 17), (64,))[:, None]
    want = A.engine.rmvm(A, u, key=KEY)
    np.testing.assert_array_equal(np.asarray(opA.matvec(u, KEY)[:, 0]),
                                  np.asarray(want[:, 0]))
    assert float(opA.write_stats.energy_j) == \
        float(A.write_stats.energy_j)              # shared one-time write


# ------------------------------------------------------- ledger + kernels
# (Entry honesty and ledger additivity moved to the registry-driven
# contract suite in tests/test_solver_contracts.py, which asserts them for
# EVERY registered solver instead of these hand-picked ones.)
def test_pallas_backend_matches_reference_updates():
    a, _, b = spd_system(64)
    eng, A = make_analog(a, device="epiram", backend="pallas")
    r_ref = solvers.cg(A, b, tol=1e-4, maxiter=40)
    r_pal = solvers.cg(A, b, tol=1e-4, maxiter=40, backend="pallas")
    assert r_ref.iterations == r_pal.iterations
    assert float(rel_l2(r_pal.x, r_ref.x)) < 1e-4
    r_ref = solvers.richardson(A, b, tol=1e-4, maxiter=60)
    r_pal = solvers.richardson(A, b, tol=1e-4, maxiter=60, backend="pallas")
    assert float(rel_l2(r_pal.x, r_ref.x)) < 1e-4


def test_fused_update_kernels_match_jnp():
    from repro.kernels import solver_cg_update, solver_richardson_update
    n, bt = 100, 3
    xs = [jax.random.normal(jax.random.fold_in(KEY, i), (n, bt))
          for i in range(5)]
    x, b, y, p, ap = xs
    xn, r = solver_richardson_update(x, b, y, 0.4)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(x + 0.4 * (b - y)),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(r), np.asarray(b - y),
                               rtol=1e-6, atol=1e-6)
    alpha = jnp.array([0.1, -0.2, 0.3])
    xn, rn = solver_cg_update(x, b, p, ap, alpha)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(x + alpha * p),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rn), np.asarray(b - alpha * ap),
                               rtol=1e-6, atol=1e-6)


def test_operator_validation():
    with pytest.raises(ValueError):
        solvers.as_operator(lambda v, k: v)        # callable without shape
    with pytest.raises(ValueError):
        solvers.as_operator(jnp.zeros((3,)))       # not a matrix
    # a bare matvec callable solves through as_operator(..., shape=)
    op = solvers.as_operator(lambda v, k: 2.0 * v, shape=(8, 8))
    res = solvers.cg(op, jnp.ones((8,)), tol=1e-6, maxiter=10)
    assert float(rel_l2(res.x, 0.5 * jnp.ones((8,)))) < 1e-5


# ------------------------------------------------------------- slow sweeps
@pytest.mark.slow
def test_solver_convergence_benchmark_sweep():
    """The full device x EC x solver sweep behind benchmarks/solver_convergence."""
    import benchmarks.solver_convergence as bench
    rows = bench.run(quick=True)
    assert len(rows) == 12                         # 2 devices x 2 ec x 3 solvers
    for r in rows:
        assert float(r["E_total_J"]) > 0
    # EC-on always at least matches EC-off solution error per device/solver
    def err(name):
        return float(next(r for r in rows if r["name"] == name)["x_err"])
    for dev in bench.QUICK_DEVICES:
        for s in ("cg", "bicgstab"):
            assert err(f"solver/{dev}/ec/{s}") < err(f"solver/{dev}/raw/{s}")
