"""Core MELISO+ unit + property tests: devices, write-verify, EC algebra,
virtualization, crossbar cost model.

The property tests run under ``hypothesis`` when it is installed and under
the deterministic ``tests/_hypo.py`` sweep otherwise -- they RUN either
way, no skips on minimal containers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core import (DEVICES, CrossbarConfig, MCAGeometry, WriteStats,
                        adjustable_mat_write_and_verify,
                        adjustable_vec_write_and_verify, block_partition,
                        corrected_mvm, denoise_least_square, effective_sigma,
                        first_order_correct, get_device, quantize, rel_l2,
                        write_cost, zero_padding)
from repro.core.devices import effective_sigma_py
from repro.core.virtualization import reassignment_count

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------- devices
def test_device_registry():
    for name in ("epiram", "ag-si", "alox-hfo2", "taox-hfox"):
        d = get_device(name)
        assert d.levels >= 8 and 0 < d.sigma0 < 1

    with pytest.raises(KeyError):
        get_device("nonexistent")


def test_effective_sigma_monotone_and_floored():
    for d in DEVICES.values():
        sig = [float(effective_sigma(d, k)) for k in range(21)]
        assert all(a >= b - 1e-9 for a, b in zip(sig, sig[1:]))
        assert sig[-1] >= d.sigma_floor - 1e-9
        assert abs(effective_sigma_py(d, 7) - float(effective_sigma(d, 7))) < 1e-6


def test_agsi_converges_slower():
    """Ag-aSi's nonlinearity (2.4/-4.88) must slow the verify loop (paper
    Fig. 2: plateau at k~11 vs k~2)."""
    fast = get_device("taox-hfox")
    slow = get_device("ag-si")
    assert slow.effective_gain < fast.effective_gain


@pytest.mark.property
@given(st.integers(2, 64))
@settings(max_examples=10, deadline=None)
def test_quantize_levels(levels):
    w = jax.random.normal(KEY, (32, 32))
    q = quantize(w, levels)
    # at most (2*levels - 1) distinct values per scale group
    vals = np.unique(np.round(np.asarray(q), 6))
    assert len(vals) <= 2 * levels + 1
    assert float(jnp.max(jnp.abs(q - w))) <= float(jnp.max(jnp.abs(w))) / (levels - 1)


# ---------------------------------------------------------------- write-verify
def test_write_verify_iterates_until_tolerance():
    dev = get_device("epiram")
    a = jax.random.normal(KEY, (64, 64))
    _, tight = adjustable_mat_write_and_verify(a, KEY, dev, eps=0.03, max_iters=20)
    _, loose = adjustable_mat_write_and_verify(a, KEY, dev, eps=0.5, max_iters=20)
    assert int(tight.iterations) >= int(loose.iterations)
    assert float(tight.energy_j) >= float(loose.energy_j)
    assert float(tight.final_delta) <= 0.03 + 1e-6 or int(tight.iterations) == 20


def test_write_verify_vector():
    dev = get_device("taox-hfox")
    x = jax.random.normal(KEY, (66,))
    xt, stats = adjustable_vec_write_and_verify(x, KEY, dev, eps=1e-6, max_iters=3)
    assert xt.shape == x.shape
    assert int(stats.iterations) == 3  # tolerance unreachable -> max iters


# ------------------------------------------------------------------ EC algebra
@pytest.mark.property
@given(st.floats(0.01, 0.5), st.floats(0.01, 0.5), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_first_order_cancellation_identity(sa, sx, seed):
    """p = Ax(1 - eps_A*eps_x) exactly, for multiplicative encode errors
    (paper Eq. 7) -- first-order terms cancel for ANY noise realization."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    a = jax.random.normal(k1, (24, 24), jnp.float64) \
        if jax.config.jax_enable_x64 else jax.random.normal(k1, (24, 24))
    x = jax.random.normal(k2, (24,))
    ea = sa * jax.random.normal(k3, a.shape)
    ex = sx * jax.random.normal(k4, x.shape)
    at = a * (1 + ea)
    xt = x * (1 + ex)
    p = first_order_correct(a, at, x, xt, mode="faithful")
    expected = a @ x - (a * ea) @ (x * ex)
    np.testing.assert_allclose(np.asarray(p), np.asarray(expected),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.property
@given(st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_fused_equals_faithful(seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    a = jax.random.normal(k1, (17, 23))
    x = jax.random.normal(k2, (23, 3))
    at = a * (1 + 0.1 * jax.random.normal(k3, a.shape))
    xt = x * (1 + 0.1 * jax.random.normal(k4, x.shape))
    f = first_order_correct(a, at, x, xt, mode="faithful")
    g = first_order_correct(a, at, x, xt, mode="fused")
    np.testing.assert_allclose(np.asarray(f), np.asarray(g), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("lam", [1e-12, 1e-6, 1e-2])
@pytest.mark.parametrize("n", [8, 66, 257])
def test_denoise_methods_agree(lam, n):
    p = jax.random.normal(KEY, (n, 2))
    yd = denoise_least_square(p, lam, method="dense")
    yt = denoise_least_square(p, lam, method="thomas")
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yt), rtol=1e-4,
                               atol=1e-5)
    if lam <= 1e-6:
        yn = denoise_least_square(p, lam, method="neumann")
        np.testing.assert_allclose(np.asarray(yt), np.asarray(yn), rtol=1e-5,
                                   atol=1e-5)


def test_denoise_solves_the_system():
    """(I + lam L^T L) y == p for the thomas solve."""
    from repro.core.error_correction import build_l_matrix
    n, lam = 40, 0.3
    p = jax.random.normal(KEY, (n,))
    y = denoise_least_square(p, lam, method="thomas")
    l = build_l_matrix(n)
    m = jnp.eye(n) + lam * (l.T @ l)
    np.testing.assert_allclose(np.asarray(m @ y), np.asarray(p), rtol=1e-4,
                               atol=1e-5)


# -------------------------------------------------------------- virtualization
@pytest.mark.property
@given(st.integers(1, 200), st.integers(1, 200), st.integers(1, 4),
       st.integers(1, 4), st.sampled_from([8, 16, 32]))
@settings(max_examples=25, deadline=None)
def test_partition_reassemble_identity(m, n, tr, tc, cell):
    a = jax.random.normal(KEY, (m, n))
    geom = MCAGeometry(tr, tc, cell, cell)
    blocks = block_partition(a, geom)
    mb, nb, cm, cn = blocks.shape
    back = blocks.transpose(0, 2, 1, 3).reshape(mb * cm, nb * cn)[:m, :n]
    assert bool(jnp.all(back == a))
    assert reassignment_count(m, n, geom) == mb * nb


def test_zero_padding_preserves_product():
    a = jax.random.normal(KEY, (66, 66))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (66,))
    geom = MCAGeometry(2, 2, 32, 32)
    ap = zero_padding(a, geom)
    xp = jnp.pad(x, (0, ap.shape[1] - 66))
    np.testing.assert_allclose(np.asarray((ap @ xp)[:66]), np.asarray(a @ x),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ cost model
def test_write_cost_scaling():
    dev = get_device("taox-hfox")
    geom = MCAGeometry(2, 2, 32, 32)
    base = CrossbarConfig(device=dev, geom=geom, k_iters=0, ec=False)
    ec = CrossbarConfig(device=dev, geom=geom, k_iters=0, ec=True)
    k5 = CrossbarConfig(device=dev, geom=geom, k_iters=5, ec=False)
    c0 = write_cost(64, 64, base)
    c_ec = write_cost(64, 64, ec)
    c_k5 = write_cost(64, 64, k5)
    # EC writes the X^T array too: ~2x energy for square problems.
    assert 1.5 < float(c_ec.energy_j) / float(c0.energy_j) < 3.0
    # k+1 passes scale linearly.
    np.testing.assert_allclose(float(c_k5.energy_j), 6 * float(c0.energy_j),
                               rtol=1e-5)
    # virtualization: a 4x larger problem on the same system -> ~4x latency
    c_big = write_cost(256, 64, base)
    assert float(c_big.latency_s) > 3.5 * float(c0.latency_s)


def test_corrected_mvm_ec_beats_raw():
    dev = get_device("alox-hfo2")
    geom = MCAGeometry(2, 2, 64, 64)
    a = jax.random.normal(KEY, (100, 100))
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (100,))
    b = a @ x
    errs = {}
    for ec in (False, True):
        cfg = CrossbarConfig(device=dev, geom=geom, k_iters=5, ec=ec)
        es = []
        for r in range(5):
            y, _ = corrected_mvm(a, x, jax.random.fold_in(KEY, r), cfg)
            es.append(float(rel_l2(y, b)))
        errs[ec] = np.mean(es)
    assert errs[True] < 0.35 * errs[False], errs
