"""Framework-layer tests: flash attention equivalence, RRAM backend
programming, sharding rules, HLO cost model, data pipeline, train/serve,
fault-tolerance components."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, model_module
from repro.configs.base import ModelConfig, RRAMBackendConfig, TrainConfig
from repro.models import params as PM
from repro.models.common import Runtime, attention, attention_specs
from repro.models.rram import program_rram, program_specs

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ flash attention
def mk_attn_cfg(**kw):
    base = dict(family="transformer", d_model=32, n_heads=4, n_kv_heads=2,
                d_head=8, rope_theta=1e4, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("causal_skip", [False, True])
def test_flash_matches_einsum(window, causal_skip):
    cfg = mk_attn_cfg(swa_window=window)
    p = PM.materialize(attention_specs(cfg), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 64, 32))
    # force flash by setting a tiny threshold
    rt_flash = Runtime(flash_threshold=1, q_chunk=16, kv_chunk=16,
                       causal_skip=causal_skip)
    rt_einsum = Runtime(flash_threshold=10 ** 9)
    out_f, _ = attention(p, x, cfg, rt_flash)
    out_e, _ = attention(p, x, cfg, rt_einsum)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_e),
                               rtol=2e-4, atol=2e-4)


def test_flash_cross_attention_and_validity():
    cfg = mk_attn_cfg(rope_theta=0.0)
    p = PM.materialize(attention_specs(cfg), KEY)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 64, 32))
    kvx = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, 32))
    rt_flash = Runtime(flash_threshold=1, q_chunk=16, kv_chunk=16)
    rt_einsum = Runtime(flash_threshold=10 ** 9)
    out_f, _ = attention(p, x, cfg, rt_flash, kv_x=kvx)
    out_e, _ = attention(p, x, cfg, rt_einsum, kv_x=kvx)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_e),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------- RRAM backend
def test_program_rram_and_specs_agree():
    arch = get_arch("qwen3-1.7b")
    cfg = arch.reduced()
    mod = model_module(cfg)
    specs = mod.init_specs(cfg)
    prm = PM.materialize(specs, KEY)
    rcfg = RRAMBackendConfig(enabled=True, cell_rows=32, cell_cols=32)
    prm2, stats = program_rram(prm, rcfg, KEY)
    abs2 = PM.abstract(program_specs(specs, rcfg))
    flat_real = {k for k, _ in PM.tree_paths(prm2)}
    flat_abs = {k for k, _ in PM.tree_paths(abs2)}
    assert flat_real == flat_abs
    assert float(stats.energy_j) > 0 and float(stats.latency_s) > 0
    # dw must be small relative to w (it is O(sigma * w))
    wq = prm2["layers"]["attn"]["wq"]
    rel = float(jnp.linalg.norm(wq["dw"].astype(jnp.float32))
                / jnp.linalg.norm(wq["w"]))
    assert rel < 0.5


def test_rram_dense_ec_reduces_error():
    from repro.models.common import dense
    w = jax.random.normal(KEY, (64, 48)) / 8
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 64))
    rcfg = RRAMBackendConfig(enabled=True, cell_rows=32, cell_cols=32,
                             k_iters=5, device="alox-hfo2")
    p, _ = program_rram({"lin": {"w": w}}, rcfg, KEY)
    ref = x @ w
    rt_ec = Runtime(rram=rcfg, key=jax.random.PRNGKey(5))
    out_ec = dense(p["lin"], x, rt_ec)
    rcfg_no = RRAMBackendConfig(enabled=True, cell_rows=32, cell_cols=32,
                                k_iters=5, device="alox-hfo2", ec=False)
    rt_no = Runtime(rram=rcfg_no, key=jax.random.PRNGKey(5))
    out_no = dense(p["lin"], x, rt_no)
    e_ec = float(jnp.linalg.norm(out_ec - ref) / jnp.linalg.norm(ref))
    e_no = float(jnp.linalg.norm(out_no - ref) / jnp.linalg.norm(ref))
    assert e_ec < 0.35 * e_no, (e_ec, e_no)


# ------------------------------------------------------------ sharding rules
def test_sharding_rules_divisibility():
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    from repro.distributed.sharding import resolve_pspec, param_rules
    from jax.sharding import PartitionSpec as P

    sizes = {"data": 16, "model": 16, "pod": 2}
    rules = {"vocab": ("model",), "embed": ("data",), "mlp": ("model",),
             None: ()}
    # divisible -> sharded
    assert resolve_pspec((151936, 2048), ("vocab", "embed"), rules, sizes) \
        == P("model", "data")
    # non-divisible vocab -> replicated
    assert resolve_pspec((51865, 2048), ("vocab", "embed"), rules, sizes) \
        == P(None, "data")
    # duplicate logical axis: second occurrence falls through
    assert resolve_pspec((64, 64), ("embed", "embed"),
                         {"embed": ("data",), None: ()}, sizes) \
        == P("data", None)


def test_cache_pspecs_heuristic():
    from repro.distributed.sharding import cache_pspecs
    import jax.sharding as jsh
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    tree = {"k": jax.ShapeDtypeStruct((24, 128, 32768, 8, 128), jnp.bfloat16),
            "len": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = cache_pspecs(tree, mesh, global_batch=128)
    assert specs["len"] == jsh.PartitionSpec()


# ------------------------------------------------------------- HLO cost model
def test_hlo_cost_scan_scaling():
    from repro.analysis.hlo_cost import analyze_hlo_text

    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    cost = analyze_hlo_text(comp.as_text())
    expect = 2 * 7 * 128 ** 3
    assert abs(cost.flops - expect) / expect < 0.05


def test_hlo_cost_records_consistent():
    from repro.analysis.hlo_cost import analyze_hlo_text

    def f(x, w):
        return jax.nn.relu(x @ w) @ w.T

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    rec = []
    cost = analyze_hlo_text(comp.as_text(), record=rec)
    assert abs(sum(r[0] for r in rec) - cost.bytes) < 1e-6 * max(cost.bytes, 1)
    assert cost.flops >= 2 * 2 * 64 ** 3 * 0.9


# ---------------------------------------------------------------- data + FT
def _load_check_regressions():
    import importlib.util
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo, "tools", "check_regressions.py")
    spec = importlib.util.spec_from_file_location("check_regressions", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestCheckRegressionsClassname:
    """The junit classname -> pytest-id mapping, incl. class-based tests
    (this class doubles as a live fixture: its own junit classname is
    ``tests.test_framework.TestCheckRegressionsClassname``)."""

    def test_module_level_mapping(self):
        cr = _load_check_regressions()
        assert cr.classname_to_id("tests.test_engine", "test_foo") == \
            "tests/test_engine.py::test_foo"

    def test_class_based_mapping(self):
        """``tests.test_x.TestFoo`` must map to tests/test_x.py::TestFoo::
        test_bar, not the impossible tests/test_x/TestFoo.py::test_bar."""
        cr = _load_check_regressions()
        got = cr.classname_to_id(
            "tests.test_framework.TestCheckRegressionsClassname", "test_x")
        assert got == ("tests/test_framework.py::"
                       "TestCheckRegressionsClassname::test_x")

    def test_unknown_tree_falls_back(self):
        cr = _load_check_regressions()
        assert cr.classname_to_id("other.pkg.mod", "t") == \
            "other/pkg/mod.py::t"
        assert cr.classname_to_id("", "bare") == "bare"

    def test_failed_ids_end_to_end(self):
        cr = _load_check_regressions()
        xml = """<?xml version="1.0"?>
        <testsuites><testsuite>
          <testcase classname="tests.test_framework.TestCheckRegressionsClassname"
                    name="test_class_based_mapping"><failure/></testcase>
          <testcase classname="tests.test_core" name="test_ok"/>
          <testcase classname="tests.test_core" name="test_bad">
            <error/></testcase>
        </testsuite></testsuites>"""
        with tempfile.NamedTemporaryFile("w", suffix=".xml",
                                         delete=False) as fh:
            fh.write(xml)
            path = fh.name
        try:
            got = cr.failed_ids(path)
        finally:
            os.unlink(path)
        assert got == {
            ("tests/test_framework.py::TestCheckRegressionsClassname::"
             "test_class_based_mapping"),
            "tests/test_core.py::test_bad",
        }


def test_data_pipeline_determinism():
    from repro.data.pipeline import synthetic_batch
    cfg = get_arch("qwen3-1.7b").reduced()
    a = synthetic_batch(cfg, 4, 32, step=7, seed=3)
    b = synthetic_batch(cfg, 4, 32, step=7, seed=3)
    c = synthetic_batch(cfg, 4, 32, step=8, seed=3)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["labels"][0, -1] == -1


def test_watchdog_flags_stragglers():
    from repro.distributed.fault_tolerance import Watchdog
    hits = []
    wd = Watchdog(threshold=2.0, patience=2, on_straggler=hits.append)
    for i in range(10):
        wd.record(i, 1.0)
    wd.record(10, 5.0)
    wd.record(11, 5.0)
    assert wd.events and hits == [11]


def test_checkpoint_keep_n_and_atomicity():
    from repro.distributed import CheckpointManager
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d, keep_n=2)
        tree = {"w": jnp.arange(8.0)}
        for s in (1, 2, 3, 4):
            ck.save(s, tree, blocking=True)
        assert ck.all_steps() == [3, 4]
        got = ck.restore(tree, step=4)
        assert np.array_equal(np.asarray(got["w"]), np.arange(8.0))


def test_trainer_loss_decreases_and_resumes():
    from repro.data.pipeline import batches
    from repro.distributed import CheckpointManager
    from repro.train import Trainer
    arch = get_arch("qwen3-1.7b")
    cfg = arch.reduced()
    mod = model_module(cfg)
    prm = PM.materialize(mod.init_specs(cfg), KEY)
    tcfg = TrainConfig(lr=2e-3, warmup_steps=5, total_steps=100, microbatch=2)
    with tempfile.TemporaryDirectory() as d:
        ck = CheckpointManager(d)
        tr = Trainer(mod, cfg, tcfg, prm, ckpt=ck, ckpt_every=10)
        hist = tr.run(batches(cfg, 4, 32), 30)
        assert min(hist["loss"][-5:]) < hist["loss"][0]
        tr.save(blocking=True)
        prm2 = PM.materialize(mod.init_specs(cfg), jax.random.PRNGKey(99))
        tr2 = Trainer(mod, cfg, tcfg, prm2, ckpt=ck)
        tr2.restore()
        assert tr2.step == tr.step
        same = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(jax.tree.leaves(tr.params),
                                   jax.tree.leaves(tr2.params)))
        assert same


def test_server_generate_shapes():
    from repro.train.serve import Server
    arch = get_arch("rwkv6-1.6b")
    cfg = arch.reduced()
    mod = model_module(cfg)
    prm = PM.materialize(mod.init_specs(cfg), KEY)
    srv = Server(mod, cfg, prm, max_len=32)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    out = srv.generate({"tokens": toks}, 5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab)))
