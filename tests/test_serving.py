"""repro.serving: traffic determinism, cache policies, batching invariants,
fused decode dispatch, and end-to-end simulator replay."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import RRAMBackendConfig
from repro.configs.registry import get_arch, model_module
from repro.core.write_verify import WriteStats
from repro.models import params as P
from repro.models.common import Runtime
from repro.models.rram import (analog_image_bytes, forward_input_stats,
                               is_programmed, program_rram, reprogram_rram,
                               strip_rram)
from repro.serving import (BatchingConfig, CacheOverBudgetError, ImageCache,
                           RequestQueue, ServingConfig, TenantSpec,
                           TrafficConfig, bucket_for, generate_trace,
                           simulate)
from repro.train.serve import Server

RRAM = RRAMBackendConfig(enabled=True)


def _build(arch_name="rwkv6-1.6b", seed=0):
    cfg = get_arch(arch_name).reduced()
    mod = model_module(cfg)
    prm = P.materialize(mod.init_specs(cfg), jax.random.PRNGKey(seed),
                        jnp.float32)
    return cfg, mod, prm


# ---------------------------------------------------------------- traffic

TENANTS = (TenantSpec("a", "rwkv6-1.6b"), TenantSpec("b", "qwen3-1.7b"),
           TenantSpec("c", "rwkv6-1.6b"))


def test_trace_deterministic_and_zipf_ordered():
    cfg = TrafficConfig(n_requests=200, zipf_s=1.3, seed=11)
    t1 = generate_trace(TENANTS, cfg)
    t2 = generate_trace(TENANTS, cfg)
    assert t1 == t2
    assert generate_trace(TENANTS, dataclasses.replace(cfg, seed=12)) != t1
    # arrivals sorted, lengths from the configured mixes
    arr = [r.arrival_s for r in t1]
    assert arr == sorted(arr)
    assert {r.prompt_len for r in t1} <= set(cfg.prompt_lens)
    # Zipf skew: first-listed tenant gets the most traffic
    counts = {t.name: sum(r.tenant == t.name for r in t1) for t in TENANTS}
    assert counts["a"] > counts["b"] > 0


# ------------------------------------------------------------------ cache

def _fake_builder(size, energy, latency=0.01):
    def build():
        return object(), size, WriteStats(
            energy_j=jnp.float32(energy), latency_s=jnp.float32(latency),
            iterations=jnp.int32(1), final_delta=jnp.float32(0.0))
    return build


def _drive(policy, accesses, sizes, energies, capacity):
    cache = ImageCache(capacity, policy)
    t = 0.0
    for key in accesses:
        cache.get(key, _fake_builder(sizes[key], energies[key]), t)
        t += 1.0
    return cache


def test_write_cost_eviction_beats_lru_on_skewed_trace():
    """Hot expensive image + rotating cold cheap tenants: LRU flushes the
    expensive image during cold bursts; write-cost-aware keeps it."""
    sizes = {"big": 600, "s1": 250, "s2": 250, "s3": 250}
    energies = {"big": 4.0, "s1": 0.1, "s2": 0.1, "s3": 0.1}
    rng = np.random.Generator(np.random.PCG64(5))
    accesses = []
    for _ in range(60):  # Zipf-ish: big is ~half of traffic
        accesses.append("big" if rng.random() < 0.5
                        else rng.choice(["s1", "s2", "s3"]))
    lru = _drive("lru", accesses, sizes, energies, capacity=900)
    wc = _drive("write_cost", accesses, sizes, energies, capacity=900)
    assert wc.write_energy_j < lru.write_energy_j
    # under write-cost the expensive image is never reprogrammed after a warm-up hit
    assert wc.entries["big"].hits > 1


def test_never_evict_ooms_the_budget():
    cache = ImageCache(800, "never")
    cache.get("a", _fake_builder(500, 1.0), 0.0)
    with pytest.raises(CacheOverBudgetError):
        cache.get("b", _fake_builder(500, 1.0), 1.0)
    # an entry larger than total capacity always raises
    with pytest.raises(CacheOverBudgetError):
        ImageCache(100, "lru").get("x", _fake_builder(500, 1.0), 0.0)


def test_cache_counters_and_reprograms():
    cache = ImageCache(600, "lru")
    cache.get("a", _fake_builder(400, 1.0), 0.0)
    cache.get("a", _fake_builder(400, 1.0), 1.0)          # hit
    cache.get("b", _fake_builder(400, 2.0), 2.0)          # evicts a
    _, out = cache.get("a", _fake_builder(400, 1.0), 3.0)  # reprogram
    assert (cache.hits, cache.misses, cache.reprograms) == (1, 3, 1)
    assert out.reprogrammed and not out.hit
    assert cache.write_energy_j == pytest.approx(4.0)
    assert cache.evictions == 2


# --------------------------------------------------------------- batching

def test_bucket_for():
    assert bucket_for(5, (4, 8, 16)) == 8
    assert bucket_for(4, (4, 8, 16)) == 4
    with pytest.raises(ValueError):
        bucket_for(20, (4, 8, 16))


def test_batcher_packing_invariants_and_no_starvation():
    cfg = TrafficConfig(n_requests=80, rate_rps=50.0, zipf_s=1.2,
                        prompt_lens=(4, 10), prompt_mix=(0.5, 0.5),
                        decode_lens=(3, 7), decode_mix=(0.5, 0.5), seed=3)
    trace = generate_trace(TENANTS, cfg)
    bcfg = BatchingConfig(max_batch=4, prompt_buckets=(4, 16),
                          decode_buckets=(4, 8), batch_buckets=(1, 2, 4))
    q = RequestQueue(bcfg)
    for r in trace:
        q.add(r)
    service_s = 1.0
    now, starts, n_batches = 0.0, {}, 0
    while len(q):
        b = q.form_batch(now)
        if b is None:
            now = q.next_arrival(now)
            continue
        n_batches += 1
        # packing invariants: one image per batch, shapes padded to buckets
        assert len({(r.tenant, r.arch) for r in b.requests}) == 1
        assert b.size <= bcfg.max_batch
        assert b.batch_pad in bcfg.batch_buckets and b.batch_pad >= b.size
        assert all(r.prompt_len <= b.prompt_bucket for r in b.requests)
        assert all(r.decode_len <= b.decode_bucket for r in b.requests)
        # FIFO head-of-line: the batch contains the oldest waiting request
        oldest = min((r for r in trace if r.rid in
                      {x.rid for x in b.requests} | {x.rid for x in q.waiting}
                      and r.arrival_s <= now),
                     key=lambda r: (r.arrival_s, r.rid))
        assert oldest.rid in {r.rid for r in b.requests}
        for r in b.requests:
            starts[r.rid] = now
        now += service_s
    assert len(starts) == len(trace)
    # no-starvation deadline: FIFO service means a request waits at most one
    # batch-service per request ahead of it in arrival order (plus idle gaps).
    for i, r in enumerate(trace):
        assert starts[r.rid] - r.arrival_s <= (i + 1) * service_s + 1e-9
    assert n_batches < len(trace)   # packing actually happened


# ------------------------------------------------- server / fused decode

def test_server_decode_is_single_fused_dispatch():
    from repro.analysis import verify
    cfg, mod, prm = _build()
    srv = Server(mod, cfg, prm, rt=Runtime(rram=RRAM), max_len=32,
                 key=jax.random.PRNGKey(5))
    caches = jax.eval_shape(lambda: mod.init_caches(2, cfg))
    jaxpr = verify.trace(srv.decode_fn(6),
                         jax.ShapeDtypeStruct((2, 1), jnp.int32), caches)
    rep = verify.dispatch_count(jaxpr, max_top_level=1)
    rep.assert_ok()
    assert rep.summary["dispatch_boundaries"] == 1


def test_fused_generate_matches_stepwise_decode_digital():
    """The scan-fused decode must reproduce the unfused per-token loop
    exactly on the deterministic digital path."""
    cfg, mod, prm = _build()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    srv = Server(mod, cfg, prm, max_len=32)
    fused = srv.generate({"tokens": toks}, 6)
    # hand-rolled reference loop
    rt = Runtime(key=jax.random.PRNGKey(9))
    logits, caches = mod.prefill(prm, {"tokens": toks}, cfg, rt, 32)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(5):
        logits, caches = mod.decode_step(prm, tok, caches, cfg, rt)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    np.testing.assert_array_equal(np.asarray(fused),
                                  np.asarray(jnp.concatenate(out, axis=1)))


def test_injectable_key_gives_independent_tenant_draws():
    cfg, mod, prm = _build()
    p1, s1 = program_rram(prm, RRAM, jax.random.PRNGKey(0))
    p2, _ = reprogram_rram(p1, RRAM, jax.random.PRNGKey(1))
    assert is_programmed(p1) and is_programmed(p2)

    def first_wt(tree):
        if isinstance(tree, dict):
            for k, v in tree.items():
                if k == "w_tilde":
                    return np.asarray(v)
                got = first_wt(v)
                if got is not None:
                    return got
        return None

    a, b = first_wt(p1), first_wt(p2)
    assert np.abs(a - b).max() > 0          # independent device draws
    # same key -> identical image (reprogram is deterministic)
    p3, _ = reprogram_rram(p1, RRAM, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(first_wt(p3), a)
    # stripping removes the analog operands
    assert not is_programmed(strip_rram(p1))
    assert analog_image_bytes(p1) > 0 and analog_image_bytes(strip_rram(p1)) == 0
    # pre-programmed params skip programming inside Server
    srv = Server(mod, cfg, p1, rt=Runtime(rram=RRAM), max_len=32)
    assert srv.write_stats is None and srv.params is p1


def test_forward_input_stats_scales_with_batch():
    cfg, mod, prm = _build()
    p, _ = program_rram(prm, RRAM, jax.random.PRNGKey(0))
    s1 = forward_input_stats(p, RRAM, batch=1)
    s4 = forward_input_stats(p, RRAM, batch=4)
    assert float(s1.energy_j) > 0
    assert float(s4.energy_j) == pytest.approx(4 * float(s1.energy_j),
                                               rel=1e-5)


def test_engine_image_nbytes_and_release():
    from repro.engine import AnalogEngine
    from repro.models.rram import crossbar_cfg
    eng = AnalogEngine(crossbar_cfg(RRAM))
    A = eng.program(jax.random.normal(jax.random.PRNGKey(0), (64, 48)),
                    jax.random.PRNGKey(1))
    assert A.image_nbytes > 0
    before = A.image_nbytes
    y = A @ jnp.ones((48,))
    assert y.shape == (64,)
    A.release()
    assert A._padded is None and A._scan_exec is None
    assert A.image_nbytes <= before or A._padded is None


# ------------------------------------------------------------- simulator

def _sim_cfg(rram, n=6, policy="write_cost", run_model=True):
    tenants = (TenantSpec("acme", "rwkv6-1.6b"),
               TenantSpec("initech", "rwkv6-1.6b"))
    traffic = TrafficConfig(n_requests=n, rate_rps=6.0, zipf_s=1.0,
                            prompt_lens=(4, 8), prompt_mix=(0.6, 0.4),
                            decode_lens=(3, 5), decode_mix=(0.6, 0.4), seed=2)
    return ServingConfig(
        tenants=tenants, traffic=traffic,
        batching=BatchingConfig(max_batch=2, prompt_buckets=(4, 8),
                                decode_buckets=(4, 8), batch_buckets=(1, 2)),
        rram=rram, cache_capacity_bytes=1 << 22, policy=policy, seed=0,
        max_len=32, run_model=run_model)


def test_simulator_replay_deterministic_twice_in_one_process():
    cfg = _sim_cfg(RRAM)
    r1 = simulate(cfg)
    r2 = simulate(cfg)
    assert r1.records == r2.records
    assert r1.summary == r2.summary
    assert r1.summary["n_requests"] == 6
    assert r1.summary["joules_per_token"] > 0
    assert r1.cache_stats["misses"] >= 1      # at least one image programmed
    # requests finish after they arrive, with positive service time
    for rec in r1.records:
        assert rec.finish_s > rec.start_s >= rec.arrival_s


def test_simulator_digital_baseline_same_trace():
    ra = simulate(_sim_cfg(RRAM, run_model=False))
    rd = simulate(_sim_cfg(None, run_model=False))
    assert rd.cache_stats is None
    assert rd.summary["write_energy_j"] == 0.0
    # same trace on both backends: identical request ids and token counts
    assert [r.rid for r in ra.records] == [r.rid for r in rd.records]
    assert ra.summary["useful_tokens"] == rd.summary["useful_tokens"]
    # but different clocks/energy (the backends differ)
    assert ra.summary["joules_per_token"] != rd.summary["joules_per_token"]
