"""Property-based solver-contract suite over ``repro.solvers.registry()``.

Four invariants, asserted for EVERY registered solver instead of per-solver
hand-rolled copies (the registry is the single source of truth -- a solver
added there is automatically held to all four):

  1. residual honesty -- the recorded ``final_residual`` tracks the
     family's residual recomputed DIGITALLY at the returned iterates:
     ``recompute <= max(slack * recorded, floor)`` and (for solvers whose
     history is not lagged one step) the reverse bound too;
  2. convergence flag -- ``converged <=> final_residual <= tol``, NaN-robust
     (a NaN residual is never "converged");
  3. iteration-0 honesty -- on trivial instances (zero RHS, exact ``x0``)
     the solver reports ``iterations == 0``, ``converged=True`` and a
     finite entry residual, with the init MVM still billed;
  4. ledger arithmetic -- ``total_energy_j`` decomposes exactly into the
     one-time write plus the four (rate x count) iteration terms, and a
     digital solve bills zero energy while still counting MVMs.

Problems are drawn by hypothesis (``tests/_hypo.py`` falls back to a
deterministic sweep on containers without it); shapes come from a small
sampled set so jit recompilation stays bounded while seeds and conditioning
vary freely.  The placement x backend parity matrix for the PR-10 solvers
(lsqr/lsmr/lanczos/lobpcg/admm) rides on ``conftest.assert_path_parity``.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import HAVE_HYPOTHESIS, HealthCheck, given, settings, st
from conftest import analog_cfg, assert_path_parity, make_analog

from repro import solvers
from repro.solvers import registry

KEY = jax.random.PRNGKey(0)
SPECS = {s.name: s for s in registry()}
NAMES = sorted(SPECS)
NEW_SOLVERS = ("lsqr", "lsmr", "lanczos", "lobpcg", "admm")

# Per-family run budget: enough iterations for the well-conditioned draws
# to converge, but the invariants hold either way.
RUN = {
    "linear": dict(tol=1e-5, maxiter=400),
    "lstsq": dict(tol=1e-5, maxiter=200),
    "lp": dict(tol=1e-4, maxiter=6000),
    "qp": dict(tol=1e-4, maxiter=2000),
    "eigen": dict(tol=1e-3, maxiter=32),
}

_SUPPRESS = list(HealthCheck) if HAVE_HYPOTHESIS else ()


def _solve(spec, problem, a=None, **overrides):
    kw = dict(RUN[spec.family])
    kw.update(overrides)
    return spec.solve(problem["a"] if a is None else a, problem,
                      key=KEY, **kw)


def _assert_honest(spec, problem, res):
    recorded = float(res.final_residual)
    rec = spec.recompute(problem, res)
    assert math.isfinite(recorded), (spec.name, res)
    assert rec <= max(spec.slack * recorded, spec.floor), \
        f"{spec.name}: digital recompute {rec:.3e} vs recorded " \
        f"{recorded:.3e} (slack {spec.slack}, floor {spec.floor})"
    if not spec.lagged_history:
        # Non-lagged histories must not OVERSTATE the residual either.
        assert recorded <= max(spec.slack * rec, spec.floor), \
            f"{spec.name}: recorded {recorded:.3e} overstates digital " \
            f"recompute {rec:.3e}"


def _assert_flag(spec, res, tol):
    final = float(res.final_residual)
    want = math.isfinite(final) and final <= tol
    assert bool(res.converged) == want, (spec.name, final, tol, res.converged)


@pytest.mark.property
@pytest.mark.parametrize("name", NAMES)
@settings(max_examples=5, deadline=None, suppress_health_check=_SUPPRESS)
@given(seed=st.integers(0, 2**16 - 1),
       shape=st.sampled_from([(9, 1), (12, 2)]),
       cond=st.sampled_from([10.0, 200.0]))
def test_contract_residual_honesty_and_flag(name, seed, shape, cond):
    """Invariants 1 + 2 on random digital problems: the recorded residual
    is the digitally-recomputable one, and ``converged`` mirrors it."""
    spec = SPECS[name]
    n, batch = shape
    if not spec.multi_rhs:
        batch = 1
    problem = spec.make_problem(jax.random.PRNGKey(seed), n, batch, cond)
    res = _solve(spec, problem)
    _assert_honest(spec, problem, res)
    _assert_flag(spec, res, RUN[spec.family]["tol"])


@pytest.mark.property
@pytest.mark.parametrize(
    "name", [n for n in NAMES if SPECS[n].make_trivial is not None])
def test_contract_entry_honesty_zero_rhs(name):
    """Invariant 3: a solve already converged at entry (trivial instance)
    reports iterations == 0, converged, and a finite entry residual."""
    spec = SPECS[name]
    for batch in (1, 2) if spec.multi_rhs else (1,):
        problem = spec.make_trivial(8, batch)
        res = _solve(spec, problem, tol=1e-6)
        assert res.iterations == 0, (name, batch, res)
        assert res.converged, (name, batch, res)
        assert math.isfinite(float(res.final_residual)), (name, res)
        assert float(res.final_residual) <= 1e-6


def test_contract_entry_honesty_exact_x0():
    """Invariant 3, exact-``x0`` form, one solver per family that accepts a
    warm start: entry residual is already below tol, zero iterations."""
    ka = jax.random.fold_in(KEY, 21)
    a = SPECS["cg"].make_problem(ka, 12, 1)["a"]
    b = jax.random.normal(jax.random.fold_in(ka, 1), (12,), jnp.float32)
    res = solvers.cg(a, b, x0=jnp.linalg.solve(a, b), tol=1e-5, maxiter=50)
    assert res.iterations == 0 and res.converged

    r = SPECS["lsqr"].make_problem(jax.random.fold_in(KEY, 22), 8, 1)
    x_ls = jnp.linalg.lstsq(r["a"], r["b"])[0]
    for fn in (solvers.lsqr, solvers.lsmr):
        res = fn(r["a"], r["b"], x0=x_ls, tol=1e-4, maxiter=50)
        assert res.iterations == 0 and res.converged, (fn.__name__, res)

    qp = SPECS["admm"].make_problem(jax.random.fold_in(KEY, 23), 12, 1)
    res = solvers.admm(qp["a"], qp["b"], qp["q"], lo=qp["lo"], hi=qp["hi"],
                       x0=qp["x_star"], tol=1e-4, maxiter=200)
    assert res.iterations == 0 and res.converged, res


def test_contract_entry_analog_zero_rhs():
    """Analog zero-RHS entry convergence still bills the one init MVM."""
    a = SPECS["cg"].make_problem(jax.random.fold_in(KEY, 24), 12, 1)["a"]
    a = a + 2.0 * jnp.eye(12)
    _, A = make_analog(a)
    res = solvers.cg(A, jnp.zeros((12,)), tol=1e-6, maxiter=50)
    assert res.iterations == 0 and res.converged, res
    assert res.ledger.mvms == 1


@pytest.mark.property
@pytest.mark.parametrize("name", NAMES)
def test_contract_ledger_arithmetic(name):
    """Invariant 4: on an analog operator the total energy is EXACTLY
    write + sum of the four (MVM count x per-call rate) products; on the
    digital operator the same counts bill zero energy."""
    spec = SPECS[name]
    problem = spec.make_problem(jax.random.PRNGKey(3), 9, 1)
    _, A = make_analog(problem["a"])
    res = _solve(spec, problem, a=A)
    led = res.ledger
    counts = (led.mvms, led.mvms_single, led.mvms_t, led.mvms_single_t)
    assert all(c >= 0 for c in counts) and sum(counts) >= 1, (name, counts)
    assert led.write_energy_j > 0
    assert led.total_energy_j == pytest.approx(
        led.write_energy_j
        + led.mvms * float(led.input_stats.energy_j)
        + led.mvms_single * float(led.input_stats_single.energy_j)
        + led.mvms_t * float(led.input_stats_t.energy_j)
        + led.mvms_single_t * float(led.input_stats_single_t.energy_j))
    if spec.needs_rmatvec:
        assert led.mvms_t + led.mvms_single_t >= 1, (name, counts)
        assert float(led.input_stats_t.energy_j) > 0

    res_d = _solve(spec, problem)
    led_d = res_d.ledger
    assert led_d.total_energy_j == 0.0    # digital operator: free MVMs...
    assert led_d.mvms + led_d.mvms_single >= 1  # ...still counted


# ------------------------------------------------- placement x backend matrix
@pytest.mark.parametrize("name", NEW_SOLVERS)
def test_new_solver_path_parity_matrix(name):
    """The PR-10 solvers run draw-identically (<= 1e-5, same iteration
    count) across the placement x backend matrix: dense local handle,
    streamed producer, streamed pallas tile-step and the distributed 1x1
    mesh.  The resident=False virtual producer re-derives its blocks
    in-scan (reassociated float32 math, ~1e-7 per MVM), which compounds
    over a full solve's recurrences: it matches at 1e-3 with iteration
    drift allowed."""
    spec = SPECS[name]
    problem = spec.make_problem(jax.random.PRNGKey(5), 12, 1)
    cfg = analog_cfg(problem["a"].shape[0])

    def run(engine, A):
        res = _solve(spec, problem, a=A, maxiter=min(
            RUN[spec.family]["maxiter"], 300))
        out = {"x": res.x, "it": jnp.float32(res.iterations)}
        if res.dual is not None:
            out["dual"] = res.dual
        if res.eigenvalues is not None:
            out["eig"] = res.eigenvalues
        return out

    from conftest import run_paths
    results = run_paths(problem["a"], cfg, run, key=KEY,
                        paths=("local", "streamed", "pallas", "dist-1x1",
                               "virtual"))
    drop = {"it"}
    if spec.family == "eigen":
        # Ritz VECTORS are only pinned down to ~residual/gap at the solve
        # tolerance, so cross-path vector comparison is not the invariant.
        # Instead every path's vectors must pass the digital Ritz residual,
        # and the eigenVALUES must agree (5e-5: perturbation sensitivity
        # amplifies the blockwise scan's reassociation noise slightly).
        a_d = problem["a"]
        for path, r in results.items():
            resid = jnp.linalg.norm(a_d @ r["x"] - r["x"] * r["eig"][None, :],
                                    axis=0)
            assert float(jnp.max(resid / jnp.abs(r["eig"]))) <= 5e-3, path
        drop = {"it", "x"}
    for p in ("streamed", "pallas", "dist-1x1"):
        # iteration counts across strictly-scheduled paths are EQUAL
        assert float(results[p]["it"]) == float(results["local"]["it"]), p
    strict = {p: {k: v for k, v in r.items() if k not in drop}
              for p, r in results.items() if p != "virtual"}
    assert_path_parity(strict, reference="local",
                       tol=5e-5 if spec.family == "eigen" else 1e-5)
    loose = {p: {k: v for k, v in results[p].items() if k not in drop}
             for p in ("dist-1x1", "virtual")}
    assert_path_parity(loose, reference="dist-1x1", tol=1e-3)
