"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    denoise_stencil,
    denoise_thomas,
    rram_ec_matmul,
    rram_encode_matmul,
)
from repro.kernels import ref as kref

KEY = jax.random.PRNGKey(42)


def rand(shape, dtype, i):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape).astype(dtype)


@pytest.mark.parametrize("m,k,n,bm,bk,bn", [
    (8, 8, 8, 8, 8, 8),
    (16, 32, 24, 8, 8, 8),
    (32, 16, 16, 16, 16, 16),
    (8, 48, 16, 8, 16, 8),      # multi-step K accumulation
    (24, 24, 40, 8, 8, 8),
])
@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_encode_matmul_sweep(m, k, n, bm, bk, bn, dtype):
    x = rand((m, k), dtype, 0)
    w = rand((k, n), dtype, 1)
    eps = rand((k, n), dtype, 2)
    got = rram_encode_matmul(x, w, eps, sigma=0.13, levels=8,
                             block_m=bm, block_k=bk, block_n=bn)
    want = kref.encode_matmul_ref(x, w, eps, 0.13, 8, bk, bn)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("levels", [4, 8, 64])
def test_encode_matmul_levels(levels):
    x = rand((16, 16), jnp.float32, 3)
    w = rand((16, 16), jnp.float32, 4)
    eps = rand((16, 16), jnp.float32, 5)
    got = rram_encode_matmul(x, w, eps, sigma=0.0, levels=levels,
                             block_m=8, block_k=8, block_n=8)
    want = kref.encode_matmul_ref(x, w, eps, 0.0, levels, 8, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 40, 24), (32, 16, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ec_matmul_sweep(m, k, n, dtype):
    x = rand((m, k), dtype, 6)
    xt = x * (1 + 0.05 * rand((m, k), dtype, 7))
    w = rand((k, n), dtype, 8)
    wt = w * (1 + 0.05 * rand((k, n), dtype, 9))
    dw = (w - wt).astype(dtype)
    got = rram_ec_matmul(x, xt, wt, dw, block_m=8, block_k=8, block_n=8)
    want = kref.ec_matmul_ref(x, xt, wt, dw)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


def test_ec_matmul_unpadded_shapes():
    # 66x66 paper shape: wrapper pads to block multiples and slices back.
    x = rand((66, 66), jnp.float32, 10)
    xt = x * 1.01
    w = rand((66, 66), jnp.float32, 11)
    wt = w * 0.99
    got = rram_ec_matmul(x, xt, wt, w - wt, block_m=32, block_k=32, block_n=32)
    want = kref.ec_matmul_ref(x, xt, wt, w - wt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3)


@pytest.mark.slow
@pytest.mark.parametrize("n,b,bb", [(16, 8, 8), (64, 16, 8), (128, 8, 8), (33, 5, 8)])
@pytest.mark.parametrize("lam", [1e-12, 1e-3, 0.5])
def test_thomas_sweep(n, b, bb, lam):
    p = rand((n, b), jnp.float32, 12)
    got = denoise_thomas(p, lam=lam, block_b=bb)
    want = kref.tridiag_solve_ref(p, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_thomas_vs_dense_inverse():
    from repro.core.error_correction import denoise_least_square
    p = rand((48, 4), jnp.float32, 13)
    got = denoise_thomas(p, lam=1e-2, block_b=4)
    want = denoise_least_square(p, lam=1e-2, method="dense")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,b", [(16, 8), (128, 16), (65, 3)])
@pytest.mark.parametrize("lam", [1e-12, 1e-5])
def test_stencil_sweep(n, b, lam):
    p = rand((n, b), jnp.float32, 14)
    got = denoise_stencil(p, lam=lam, block_b=8)
    want = kref.stencil_denoise_ref(p, lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_stencil_matches_thomas_at_tiny_lam():
    # For lam = 1e-12 the truncated Neumann series is exact to fp32.
    p = rand((96, 8), jnp.float32, 15)
    a = denoise_stencil(p, lam=1e-12, block_b=8)
    b = denoise_thomas(p, lam=1e-12, block_b=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_encode_matmul_rng_inkernel_noise():
    """Single-pass encode kernel (in-kernel PRNG): on CPU the TPU interpreter
    stubs prng_random_bits to zeros, so we validate the sigma=0 exact path,
    determinism, and shapes; the noise distribution is TPU-only."""
    from repro.kernels.rram_mvm import encode_matmul_rng
    seed = jnp.array([7], jnp.int32)
    x = rand((16, 64), jnp.float32, 20)
    w = rand((64, 32), jnp.float32, 21)
    y0 = encode_matmul_rng(seed, x, w, sigma=0.0, levels=8,
                           block_m=16, block_k=32, block_n=32, interpret=True)
    want = kref.encode_matmul_ref(x, w, jnp.zeros_like(w), 0.0, 8, 32, 32)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(want),
                               rtol=2e-5, atol=1e-4)
    y1 = encode_matmul_rng(seed, x, w, sigma=0.1, levels=8,
                           block_m=16, block_k=32, block_n=32, interpret=True)
    y2 = encode_matmul_rng(seed, x, w, sigma=0.1, levels=8,
                           block_m=16, block_k=32, block_n=32, interpret=True)
    assert bool(jnp.all(y1 == y2))
