"""Validation of the paper's quantitative claims (EXPERIMENTS.md section
Paper-validation reads from the benchmark; these tests gate the same
assertions at lower replication counts).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CrossbarConfig, MCAGeometry, corrected_mvm,
                        get_device, rel_l2)
from repro.core.matrices import make_iperturb, paper_matrix

GEOM = MCAGeometry(1, 1, 66, 66)
KEY = jax.random.PRNGKey(0)


def run_device(a, x, b, dev, ec, k=5, reps=6):
    cfg = CrossbarConfig(device=get_device(dev), geom=GEOM, k_iters=k, ec=ec)
    fn = jax.jit(lambda kk: corrected_mvm(a, x, kk, cfg))
    errs, stats = [], None
    for r in range(reps):
        kk = jax.random.fold_in(jax.random.fold_in(KEY, r),
                                hash(dev) % (2 ** 30))
        y, stats = fn(kk)
        errs.append(float(rel_l2(y, b)))
    return float(np.mean(errs)), stats


@pytest.fixture(scope="module")
def m1():
    a = jnp.asarray(paper_matrix("bcsstk02"), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(42), (66,))
    return a, x, a @ x


def test_ec_error_reduction_over_80pct(m1):
    """Paper: >90% reduction of first+second-order error (we gate at 80% for
    the low-replication test; the benchmark reports ~89-95%)."""
    a, x, b = m1
    raw, _ = run_device(a, x, b, "taox-hfox", ec=False)
    ec, _ = run_device(a, x, b, "taox-hfox", ec=True)
    assert ec < 0.2 * raw, (raw, ec)


def test_low_end_device_matches_epiram(m1):
    """Paper: TaOx-HfOx + EC reaches EpiRAM-class accuracy..."""
    a, x, b = m1
    epi, epi_stats = run_device(a, x, b, "epiram", ec=False)
    tao, tao_stats = run_device(a, x, b, "taox-hfox", ec=True)
    assert tao < 1.5 * epi, (tao, epi)
    # ...at >= ~3 orders of magnitude less write energy and ~2 orders less
    # latency (paper: 3-5 and 2 respectively).
    assert float(epi_stats.energy_j) / float(tao_stats.energy_j) > 300
    assert float(epi_stats.latency_s) / float(tao_stats.latency_s) > 50


def test_write_verify_iterations_reduce_error(m1):
    a, x, b = m1
    e0, _ = run_device(a, x, b, "alox-hfo2", ec=False, k=0)
    e5, _ = run_device(a, x, b, "alox-hfo2", ec=False, k=5)
    assert e5 < e0


def test_error_flat_across_cell_sizes():
    """Paper Fig. 4: accuracy is preserved under virtualization."""
    n = 512
    a = jax.random.normal(KEY, (n, n)) / np.sqrt(n)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    b = a @ x
    errs = []
    for cell in (32, 128, 256):
        geom = MCAGeometry(2, 2, cell, cell)
        cfg = CrossbarConfig(device=get_device("taox-hfox"), geom=geom,
                             k_iters=5, ec=True)
        y, _ = corrected_mvm(a, x, KEY, cfg)
        errs.append(float(rel_l2(y, b)))
    assert max(errs) < 3 * min(errs) + 1e-3, errs


def test_small_cells_cost_more_energy_latency():
    """Paper Fig. 4: virtualization reassignments inflate E_w/L_w for small
    arrays."""
    from repro.core import write_cost
    dev = get_device("taox-hfox")
    small = CrossbarConfig(device=dev, geom=MCAGeometry(8, 8, 32, 32),
                           k_iters=5, ec=True)
    big = CrossbarConfig(device=dev, geom=MCAGeometry(8, 8, 512, 512),
                         k_iters=5, ec=True)
    cs = write_cost(4096, 4096, small)
    cb = write_cost(4096, 4096, big)
    assert float(cs.latency_s) > 5 * float(cb.latency_s)
