"""Verifier-on-the-verifier: every pass must flag its known-bad program.

Covers the ISSUE acceptance criteria: for each of the five passes a
deliberately broken pipeline (materializing MVM, double-dispatch loop,
duplicated key, f16 accumulator, stray all-gather) that the pass must
flag; attribution-message snapshots proving violations name the
offending primitive and source line; regression tests for the traversal
gaps the seed walker had (custom_vjp fwd thunk, dict/nested params);
and the registry wiring `tools/check_invariants.py` gates on.
"""
import re

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import verify as V
from repro.analysis.memory import jaxpr_max_elements, max_aval_elements

KEY = jax.random.PRNGKey(3)


# ------------------------------------------------------- walker regressions
class TestWalkerRegressions:
    """Sub-jaxprs the seed walker could not reach must now be walked."""

    def test_custom_vjp_fwd_thunk_reached(self):
        """A big residual allocated in a custom_vjp fwd rule is invisible
        in a primal-only trace except through ``fwd_jaxpr_thunk`` -- the
        one-level param scan of the seed walker returned 8 here."""
        @jax.custom_vjp
        def f(x):
            return jnp.sum(x)

        def fwd(x):
            big = jnp.zeros((1024, 1024)) + x[0]      # hidden residual
            return jnp.sum(x), jnp.sum(big)

        def bwd(res, g):
            return (jnp.ones((8,)) * g * res,)

        f.defvjp(fwd, bwd)
        jx = jax.make_jaxpr(f)(jnp.ones((8,)))
        assert [e.primitive.name for e in jx.jaxpr.eqns] == \
            ["custom_vjp_call_jaxpr"]                 # primal-only: un-inlined
        assert jaxpr_max_elements(jx) == 1024 * 1024

    @staticmethod
    def _rewritten(params_patch):
        """A real jaxpr whose pjit eqn hides its sub-jaxpr per ``patch``."""
        def inner(x):
            return jnp.sin(jnp.outer(x, x)).sum()

        outer = jax.make_jaxpr(jax.jit(inner))(jnp.ones((128,)))
        eqn = outer.jaxpr.eqns[0]
        sub = eqn.params["jaxpr"]
        params = {k: v for k, v in eqn.params.items() if k != "jaxpr"}
        params.update(params_patch(sub))
        new_eqn = eqn.replace(params=params)
        return outer.jaxpr.replace(
            eqns=[new_eqn] + list(outer.jaxpr.eqns[1:]))

    def test_dict_valued_params_walked(self):
        jx = self._rewritten(lambda sub: {"branch_map": {"a": sub}})
        assert jaxpr_max_elements(jx) == 128 * 128

    def test_nested_container_params_walked(self):
        jx = self._rewritten(lambda sub: {"nested": ((("deep", sub),),)})
        assert jaxpr_max_elements(jx) == 128 * 128

    def test_cond_branches_walked(self):
        def f(x, p):
            return jax.lax.cond(p > 0,
                                lambda v: jnp.outer(v, v).sum(),
                                lambda v: jnp.sum(v), x)
        assert max_aval_elements(f, jnp.ones((64,)), jnp.float32(1)) == 64 * 64


# ----------------------------------------------------------- AvalBound
def _materializing_mvm(x):
    """The known-bad memory pipeline: forms the full rank-1 'matrix'."""
    big = jnp.outer(x, x)
    return big @ x


class TestAvalBound:
    def test_flags_materializing_mvm(self):
        jx = V.trace(_materializing_mvm, jnp.ones((512,)))
        report = V.aval_bound(jx, budget=1024)
        assert not report.ok
        assert report.summary["max_elements"] == 512 * 512
        assert report.summary["max_aval"] == "float32[512,512]"

    def test_attribution_names_primitive_and_line(self):
        jx = V.trace(_materializing_mvm, jnp.ones((512,)))
        msg = str(V.aval_bound(jx, budget=1024).violations[0])
        assert re.search(
            r"AvalBound: largest aval float32\[512,512\] has 262144 "
            r"elements > budget 1024 "
            r"\[\w+ @ test_verify\.py:\d+ \(in _materializing_mvm\)\]", msg), msg

    def test_clean_under_budget(self):
        jx = V.trace(lambda x: (x * 2).sum(), jnp.ones((512,)))
        assert V.aval_bound(jx, budget=512).ok

    def test_assert_ok_raises_with_sites(self):
        jx = V.trace(_materializing_mvm, jnp.ones((512,)))
        with pytest.raises(AssertionError, match="AvalBound failed"):
            V.aval_bound(jx, budget=1024).assert_ok()


# ----------------------------------------------------------- DispatchCount
class TestDispatchCount:
    def test_flags_double_dispatch_loop(self):
        """The known-bad dispatch pipeline: one jitted dispatch per step
        instead of one fused scan."""
        def chained(x):
            for _ in range(4):                        # 4 top-level dispatches
                x = jax.jit(jnp.sin)(x)
            return x

        report = V.dispatch_count(V.trace(chained, jnp.ones((8,))),
                                  max_top_level=1)
        assert not report.ok
        assert report.summary["top_level_eqns"] == 4
        assert report.summary["per_primitive"] == {"pjit": 4}
        assert "4 top-level equations > budget 1" in str(report.violations[0])

    def test_single_fused_dispatch_clean(self):
        def fused(x):
            return jax.jit(lambda v: jnp.cos(jnp.sin(v)))(x)

        report = V.dispatch_count(V.trace(fused, jnp.ones((8,))),
                                  max_top_level=1)
        assert report.ok
        assert report.summary["dispatch_boundaries"] == 1

    def test_flags_producer_overcall(self):
        counter = V.CallCounter(lambda i, j: jnp.ones((4, 4)))
        for i in range(5):
            counter(i, 0)                              # per-block re-invocation
        report = V.dispatch_count(V.trace(lambda x: x + 1, jnp.ones((2,))),
                                  producer_calls=counter.calls,
                                  max_producer_calls=3)
        assert not report.ok
        assert "producer invoked 5x" in str(report.violations[0])


# ----------------------------------------------------------- KeyReuse
class TestKeyReuse:
    def test_flags_duplicated_key(self):
        """The known-bad key pipeline: two draws from the same key."""
        def bad(key, x):
            return (jax.random.normal(key, x.shape)
                    + jax.random.normal(key, x.shape) + x)

        report = V.key_reuse(V.trace(bad, KEY, jnp.ones((4,))))
        assert not report.ok
        assert report.summary["consumptions"] == 2
        assert report.summary["distinct_keys"] == 1
        assert "identically-derived key" in str(report.violations[0])

    def test_split_keys_clean(self):
        def good(key, x):
            k1, k2 = jax.random.split(key)
            return (jax.random.normal(k1, x.shape)
                    + jax.random.normal(k2, x.shape) + x)

        report = V.key_reuse(V.trace(good, KEY, jnp.ones((4,))))
        assert report.ok
        assert report.summary["distinct_keys"] == 2

    def test_flags_reuse_inside_scan_body(self):
        """Two sites consuming the same carried key inside one scan."""
        def bad(key, xs):
            def body(c, x):
                a = jax.random.normal(key, ())
                b = jax.random.normal(key, ())
                return c + a + b + x, None

            out, _ = jax.lax.scan(body, 0.0, xs)
            return out

        report = V.key_reuse(V.trace(bad, KEY, jnp.arange(5.0)))
        assert not report.ok

    def test_per_iteration_fold_clean(self):
        """The engine's block-key discipline: fold per index, one site."""
        def good(key, xs):
            def body(c, i):
                k = jax.random.fold_in(key, i)
                return c + jax.random.normal(k, ()), None

            out, _ = jax.lax.scan(body, 0.0, xs)
            return out

        assert V.key_reuse(V.trace(good, KEY, jnp.arange(5))).ok

    def test_flags_baked_key(self):
        def baked(x):
            return jax.random.normal(jax.random.PRNGKey(0), x.shape) + x

        report = V.key_reuse(V.trace(baked, jnp.ones((4,))))
        assert not report.ok
        assert "not derived from any traced key argument" in \
            str(report.violations[0])
        # procedural matrix content waives the baked check, not the reuse one
        assert V.key_reuse(V.trace(baked, jnp.ones((4,))),
                           allow_baked=True).ok

    def test_attribution_names_consumption_site(self):
        def bad(key, x):
            return (jax.random.normal(key, x.shape)
                    + jax.random.normal(key, x.shape) + x)

        msg = str(V.key_reuse(V.trace(bad, KEY, jnp.ones((4,)))).violations[0])
        assert re.search(
            r"KeyReuse: 2 consumptions of identically-derived key "
            r"\(sites: .*random_bits @ test_verify\.py:\d+ \(in bad\)", msg), msg


# ----------------------------------------------------------- PrecisionLint
class TestPrecisionLint:
    def test_flags_f16_accumulator(self):
        """The known-bad precision pipeline: a float16 scan carry."""
        def f16_acc(xs):
            def body(c, x):
                return c + x.astype(jnp.float16), None

            out, _ = jax.lax.scan(body, jnp.float16(0), xs)
            return out

        report = V.precision_lint(V.trace(f16_acc, jnp.ones((5,))))
        assert not report.ok
        assert report.summary["sub_f32_carries"] == 1
        assert re.search(
            r"PrecisionLint: float16 loop carry float16\[\] "
            r"\(sub-f32 accumulator\) \[scan @ test_verify\.py:\d+",
            str(report.violations[0]))

    def test_f32_carry_clean(self):
        def acc(xs):
            out, _ = jax.lax.scan(lambda c, x: (c + x, None), 0.0, xs)
            return out

        assert V.precision_lint(V.trace(acc, jnp.ones((5,)))).ok

    def test_flags_f64_leak(self):
        from jax.experimental import enable_x64
        with enable_x64():
            jx = V.trace(lambda x: x.astype(jnp.float64).sum() * 2.0,
                         jnp.ones((4,), jnp.float32))
        report = V.precision_lint(jx)
        assert not report.ok
        assert report.summary["f64_avals"] > 0
        assert "silent f64 leak" in str(report.violations[0])
        assert V.precision_lint(jx, allow_f64=True).ok


# ----------------------------------------------------------- CollectiveAudit
def _shard_mapped(body):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    return shard_map(body, mesh=mesh, in_specs=P("data", "model"),
                     out_specs=P("data", "model"), check_rep=False)


class TestCollectiveAudit:
    def test_flags_stray_all_gather(self):
        """The known-bad collective pipeline: gathers a full sharded
        operand inside shard_map."""
        def body(blk):
            g = jax.lax.all_gather(blk, "data")       # ships > a block
            return blk + g[0]

        jx = V.trace(_shard_mapped(body), jnp.ones((8, 8)))
        report = V.collective_audit(jx, allowed_axes=("data", "model"),
                                    per_device_budget=16)
        assert not report.ok
        assert report.summary["gathers"] == 1
        assert re.search(
            r"CollectiveAudit: all_gather moves 64 elements > per-device "
            r"budget 16 \[shard_map/all_gather @ test_verify\.py:\d+",
            str(report.violations[0]))

    def test_flags_undeclared_psum_axis(self):
        def body(blk):
            return jax.lax.psum(blk, "data")          # row axis not declared

        jx = V.trace(_shard_mapped(body), jnp.ones((8, 8)))
        report = V.collective_audit(jx, allowed_axes=("model",),
                                    per_device_budget=10_000)
        assert not report.ok
        assert "psum over undeclared axes ['data']" in \
            str(report.violations[0])

    def test_declared_psum_clean(self):
        def body(blk):
            return jax.lax.psum(blk, "model")

        jx = V.trace(_shard_mapped(body), jnp.ones((8, 8)))
        report = V.collective_audit(jx, allowed_axes=("data", "model"),
                                    per_device_budget=10_000)
        assert report.ok
        assert report.summary["psums"] == 1


# ----------------------------------------------------------- registry + gate
class TestPipelineRegistry:
    def test_registry_covers_required_matrix(self):
        from repro.analysis import pipelines as P
        specs = P.registered_pipelines()
        assert len(specs) >= 12
        names = {s.name for s in specs}
        # distributed resident=False forward AND rmatvec at virtual 65,536^2
        assert "distributed-virtual65536-forward-1x1" in names
        assert "distributed-virtual65536-rmatvec-1x1" in names
        assert {s.placement for s in specs} == \
            {"local", "streamed", "distributed"}
        assert {s.backend for s in specs} == {"reference", "pallas"}
        assert {"forward", "rmatvec", "solve"} <= {s.direction for s in specs}
        assert any(s.direction == "solve" and "cg" in s.name for s in specs)
        assert any(s.direction == "solve" and "pdhg" in s.name for s in specs)

    def test_virtual_65536_pipeline_proves_block_bound(self):
        """The paper-scale structural claim, end to end through the
        registry: the virtual 65,536^2 forward MVM traces with a
        high-water mark of ONE capacity block and no violations."""
        from repro.analysis import pipelines as P
        spec = {s.name: s for s in P.registered_pipelines()}[
            "distributed-virtual65536-forward-1x1"]
        reports = P.verify_pipeline(spec)
        for name, report in reports.items():
            assert report.ok, (name, [str(v) for v in report.violations])
        assert reports["AvalBound"].summary["max_elements"] == \
            P.VIRTUAL_CAP * P.VIRTUAL_CAP
        assert reports["DispatchCount"].summary["dispatch_boundaries"] == 1

    def test_manifest_matches_registry(self):
        """INVARIANTS.json rows exist for every 1-device pipeline and
        record no violations (full cross-check is the CI gate)."""
        import json
        import pathlib
        manifest = json.loads(
            (pathlib.Path(__file__).resolve().parent.parent
             / "INVARIANTS.json").read_text())
        from repro.analysis import pipelines as P
        for spec in P.registered_pipelines():
            assert spec.name in manifest, spec.name
            assert manifest[spec.name]["violations"] == []
            assert manifest[spec.name]["max_elements"] <= \
                manifest[spec.name]["aval_budget"]
