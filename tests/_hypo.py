"""Hypothesis facade for the test suite.

CI installs ``hypothesis`` as a first-class dependency (see requirements.txt
and ``--hypothesis-seed=0`` in the workflow), and this module simply
re-exports it.  Minimal containers without hypothesis fall back to a small
deterministic engine implementing the subset the suite uses -- ``given`` /
``settings`` / ``HealthCheck`` and the ``integers`` / ``floats`` /
``booleans`` / ``sampled_from`` strategies -- so the property tests still
RUN (a fixed seeded sweep of ``max_examples`` cases) instead of being
skipped.  Shrinking and coverage-guided generation are hypothesis-only
luxuries; the invariants themselves are checked either way.

Usage (works against both backends)::

    from _hypo import HAVE_HYPOTHESIS, HealthCheck, given, settings, st

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16 - 1), n=st.sampled_from([8, 12]))
    def test_property(seed, n): ...

Strategies must be passed to ``given`` as KEYWORD arguments -- the fallback
relies on it, and it keeps real-hypothesis argument binding unambiguous
under pytest fixtures.
"""
import functools
import inspect
import random

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - CI has hypothesis
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw function ``rng -> value`` with map/filter combinators."""

        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate rejected 1000 draws")
            return _Strategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.draw(rng) for s in strategies))

    st = _Strategies()

    class HealthCheck:
        """Accepts any attribute access; values are inert markers."""
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

        @staticmethod
        def all():
            return []

    _DEFAULT_MAX_EXAMPLES = 10

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        """Records ``max_examples``; every other knob is hypothesis-only."""
        def deco(fn):
            fn._hypo_max_examples = max_examples
            return fn
        return deco

    def given(*strategies, **kwstrategies):
        """Deterministic sweep: runs the test body on ``max_examples`` draws
        from a ``random.Random(0)`` stream (the same cases every run -- a
        regression sweep, not an explorer).  Positional strategies bind to
        the RIGHTMOST parameters, like real hypothesis."""
        if not strategies and not kwstrategies:
            raise TypeError("given() requires at least one strategy")

        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            mapping = dict(kwstrategies)
            if strategies:
                mapping.update(zip(names[-len(strategies):], strategies))

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hypo_max_examples",
                            getattr(fn, "_hypo_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in mapping.items()}
                    fn(*args, **kwargs, **drawn)
            wrapper._hypo_max_examples = getattr(
                fn, "_hypo_max_examples", _DEFAULT_MAX_EXAMPLES)
            # Hide the drawn parameters from pytest's fixture resolution
            # (real hypothesis does the same): the visible signature keeps
            # only the non-strategy parameters.
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in mapping])
            return wrapper
        return deco
