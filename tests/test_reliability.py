"""Unit tests for repro.reliability: aging, probes, refresh, fault-tolerant
solves, and the serving refresh scheduler."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device
from repro.engine import AnalogEngine
from repro.reliability import (RefreshPolicy, attach_age, fault_probability,
                               ft_cg, ft_pdhg, predicted_residual,
                               probe_tile_scores, probe_vectors, refresh_tiles,
                               select_tiles)
from repro.reliability.aging import AgeLedger, aged_blocks

KEY = jax.random.PRNGKey(0)


def _spd(n: int, key=KEY):
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + 2.0 * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    return a, x_true, a @ x_true


def _handle(a, device="epiram", cell=32):
    cfg = CrossbarConfig(device=get_device(device),
                         geom=MCAGeometry(2, 2, cell, cell), k_iters=5,
                         ec=True)
    engine = AnalogEngine(cfg)
    return engine.program(a, jax.random.fold_in(KEY, 7))


# ----------------------------------------------------------------- aging
def test_fault_probability_no_float32_underflow():
    """Regression: 1 - (1 - 1e-9)^N computed naively underflows to 0 in
    float32 (1 - 1e-9 rounds to 1.0) -- the stable form must not."""
    dev = get_device("epiram")           # fault_rate 1e-9
    p = float(fault_probability(dev, 1e5))
    assert p > 0.0
    assert p == pytest.approx(1e-4, rel=0.01)
    # monotone in the MVM count
    assert float(fault_probability(dev, 2e5)) > p


def test_age_ledger_updates_are_functional():
    led = AgeLedger.fresh(KEY, 2, 2)
    led2 = led.advanced(10).elapsed(5.0)
    assert float(led.mvms.max()) == 0.0          # original untouched
    assert float(led2.mvms.min()) == 10.0
    assert float(led2.seconds.min()) == 5.0
    mask = jnp.asarray([[True, False], [False, False]])
    led3 = led2.reset(mask)
    assert float(led3.mvms[0, 0]) == 0.0
    assert float(led3.mvms[1, 1]) == 10.0
    assert int(led3.refresh_count[0, 0]) == 1
    assert int(led3.refresh_count[1, 1]) == 0


def test_aged_blocks_replayable_and_monotone():
    """Same age -> identical fault set; the faulted set only grows with the
    MVM count; a refresh redraws from a fresh fold of the fault keys."""
    a, _, _ = _spd(128)
    A = _handle(a, device="ag-si")        # fault_rate 2e-7: faults show fast
    led = attach_age(A)
    dev = A.engine.cfg.device
    n1 = int(0.5 / (dev.fault_rate * a.size))
    aged1 = aged_blocks(A.at_blocks, led.advanced(n1), dev)
    aged1b = aged_blocks(A.at_blocks, led.advanced(n1), dev)
    np.testing.assert_array_equal(np.asarray(aged1), np.asarray(aged1b))
    stuck1 = np.asarray(jnp.abs(aged1 - A.at_blocks) > 1e-9)
    aged2 = aged_blocks(A.at_blocks, led.advanced(20 * n1), dev)
    stuck2 = np.asarray(jnp.abs(aged2 - A.at_blocks) > 1e-9)
    assert stuck1.sum() > 0
    assert np.all(stuck2[stuck1])                 # faults never heal with age
    assert stuck2.sum() > stuck1.sum()
    refreshed = led.advanced(n1).reset(jnp.ones((2, 2), bool)).advanced(n1)
    aged3 = aged_blocks(A.at_blocks, refreshed, dev)
    stuck3 = np.asarray(jnp.abs(aged3 - A.at_blocks) > 1e-9)
    assert not np.array_equal(stuck3, stuck1)     # refresh redraws the fate


def test_age_zero_is_identity():
    a, _, _ = _spd(128)
    A = _handle(a)
    led = attach_age(A)
    aged = aged_blocks(A.at_blocks, led, A.engine.cfg.device)
    np.testing.assert_array_equal(np.asarray(aged), np.asarray(A.at_blocks))


def test_attach_age_rejects_streamed():
    a, _, _ = _spd(128)
    cfg = CrossbarConfig(device=get_device("epiram"),
                         geom=MCAGeometry(2, 2, 32, 32), k_iters=5, ec=True)
    eng = AnalogEngine(cfg, execution="streamed")
    a_pad = np.asarray(a)
    blocks = a_pad.reshape(2, 64, 2, 64).transpose(0, 2, 1, 3)
    A = eng.program(lambda i, j: jnp.asarray(blocks[i, j]), KEY, shape=a.shape)
    with pytest.raises(ValueError):
        attach_age(A)


def test_predicted_residual_monotone_and_exact_at_zero():
    from repro.core.devices import effective_sigma_py
    dev = get_device("taox-hfox")
    p0 = predicted_residual(dev, k_iters=5, seconds=0.0, mvms=0.0, n=256)
    assert p0 == pytest.approx(effective_sigma_py(dev, 5))
    p_t = predicted_residual(dev, k_iters=5, seconds=100.0, mvms=0.0, n=256)
    p_n = predicted_residual(dev, k_iters=5, seconds=0.0, mvms=1e4, n=256)
    assert p_t > p0 and p_n > p0
    assert predicted_residual(dev, k_iters=5, seconds=200.0, mvms=2e4,
                              n=256) > max(p_t, p_n)


# -------------------------------------------------------- probes + refresh
def test_probe_vectors_unit_norm_block_support():
    x = probe_vectors(100, 4, 32)        # last block is the 4-wide remainder
    assert x.shape == (100, 4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=0),
                               np.ones(4), rtol=1e-5)
    xs = np.asarray(x)
    assert np.all(xs[32:, 0] == 0.0)     # column j supported on block j only
    assert np.all(xs[:32, 1] == 0.0) and np.all(xs[64:, 1] == 0.0)
    assert np.all(xs[:96, 3] == 0.0)


def test_probe_localizes_damaged_tile():
    """Probe scores localize REAL aging damage: the tile holding the worst
    stuck-cell deviation is the probe map's worst entry, and fault-free
    tiles stay near the programming floor.  (Manual ``at_blocks`` edits are
    no good here: tier-1 keeps ``dense() = at + da``, so hand-editing ``at``
    shifts the digital reference by the same delta and cancels.)"""
    a, _, _ = _spd(128)
    A = _handle(a, device="ag-si")
    attach_age(A)
    dev = A.engine.cfg.device
    mvms = int(4 / (dev.fault_rate * a.size))         # ~4 expected faults
    A.age = A.age.advanced(mvms)
    damage = np.asarray(jnp.abs(
        aged_blocks(A.at_blocks, A.age, dev) - A.at_blocks))
    per_tile = damage.max(axis=(2, 3))
    assert per_tile.max() > 0.0                       # the draw did latch cells
    rep = probe_tile_scores(A, key=jax.random.fold_in(KEY, 3))
    s = np.asarray(rep.scores)
    assert s.shape == (2, 2)
    assert np.argmax(s) == np.argmax(per_tile)
    healthy = per_tile == 0.0
    if healthy.any():
        assert s[healthy].max() < 0.05                # near the fresh floor
    assert rep.n_probes == 2
    assert float(rep.input_stats.energy_j) > 0
    # the probe batch aged the image further: nb physical reads
    assert float(A.age.mvms.min()) >= mvms + 2.0


def test_select_tiles_threshold_and_cap():
    scores = np.array([[0.5, 0.01], [0.2, 0.9]])
    assert select_tiles(scores, RefreshPolicy(threshold=0.1)) == \
        ((1, 1), (0, 0), (1, 0))
    assert select_tiles(scores, RefreshPolicy(threshold=0.1, max_tiles=1)) == \
        ((1, 1),)
    assert select_tiles(scores, RefreshPolicy(threshold=2.0)) == ()


def test_refresh_restores_damaged_tile_cheaper_than_full():
    a, _, b = _spd(128)
    bn = float(jnp.linalg.norm(b))
    A = _handle(a, device="ag-si")
    attach_age(A)
    dev = A.engine.cfg.device
    A.age = A.age.advanced(int(4 / (dev.fault_rate * a.size)))
    rep = probe_tile_scores(A, key=jax.random.fold_in(KEY, 3))
    fresh_floor = 0.05                 # above the healthy-tile probe scores
    rr = refresh_tiles(A, rep.scores, RefreshPolicy(threshold=fresh_floor),
                       key=jax.random.fold_in(KEY, 4))
    assert 0 < len(rr.tiles) < 4                   # selective, not a rewrite
    for (i, j) in rr.tiles:
        assert int(A.age.refresh_count[i, j]) == 1
        assert float(A.age.mvms[i, j]) == 0.0
    assert int(np.asarray(A.age.refresh_count).sum()) == len(rr.tiles)
    assert 0 < float(rr.write_stats.energy_j) \
        < float(rr.full_rewrite_stats.energy_j)
    rep2 = probe_tile_scores(A, key=jax.random.fold_in(KEY, 5))
    assert rep2.worst < fresh_floor                # damage gone
    res = solvers.cg(A, b, tol=1e-6, maxiter=60,
                     key=jax.random.fold_in(KEY, 6))
    assert float(jnp.linalg.norm(b - a @ res.x)) / bn < 0.02


def test_refresh_requires_resident_blocks():
    a, _, _ = _spd(128)
    cfg = CrossbarConfig(device=get_device("epiram"),
                         geom=MCAGeometry(2, 2, 32, 32), k_iters=5, ec=True)
    eng = AnalogEngine(cfg, execution="streamed")
    a_pad = np.asarray(a)
    blocks = a_pad.reshape(2, 64, 2, 64).transpose(0, 2, 1, 3)
    A = eng.program(lambda i, j: jnp.asarray(blocks[i, j]), KEY, shape=a.shape)
    with pytest.raises(ValueError):
        refresh_tiles(A, np.ones((2, 2)), RefreshPolicy(threshold=0.0))


# -------------------------------------------------------------- ft solves
def test_ft_cg_healthy_converges_without_restores(tmp_path):
    from repro.distributed.fault_tolerance import CheckpointManager
    a, x_true, b = _spd(128)
    A = _handle(a)
    mgr = CheckpointManager(str(tmp_path))
    res = ft_cg(A, b, tol=1e-4, maxiter=400, segment=25,
                key=jax.random.fold_in(KEY, 9), manager=mgr)
    assert res.converged and res.restores == 0
    assert res.fault_events == ()
    assert res.final_residual < 1e-4
    assert float(jnp.linalg.norm(res.x - x_true)) \
        / float(jnp.linalg.norm(x_true)) < 1e-3
    # each accepted segment checkpointed (plus the step-0 entry state)
    assert mgr.latest_step() == res.iterations
    assert res.ledger.mvms > 0


def test_ft_cg_detects_and_recovers_injected_fault(tmp_path):
    from repro.distributed.fault_tolerance import CheckpointManager
    a, _, b = _spd(128)
    A = _handle(a)
    state = {"saved": None}

    def inject(seg, h):
        if seg == 1 and state["saved"] is None:
            state["saved"] = h.at_blocks
            blocks = np.array(jax.device_get(h.at_blocks))
            blocks[:, 0, :, 3] = np.max(np.abs(blocks))
            h.at_blocks = jnp.asarray(blocks)
            h.release()

    def repair(event, h):
        h.at_blocks = state["saved"]
        h.release()

    res = ft_cg(A, b, tol=1e-4, maxiter=400, segment=25,
                key=jax.random.fold_in(KEY, 9),
                manager=CheckpointManager(str(tmp_path)),
                segment_hook=inject, on_fault=repair)
    assert res.converged, res
    assert res.restores == 1
    assert len(res.fault_events) == 1
    assert res.fault_events[0].kind in ("nan", "residual-spike")
    assert res.final_residual < 1e-4


def test_ft_cg_unrepaired_fault_gives_honest_failure(tmp_path):
    """No on_fault repair: the wrapper keeps restoring until max_restores,
    then reports converged=False -- never a silent wrong answer."""
    from repro.distributed.fault_tolerance import CheckpointManager
    a, _, b = _spd(128)
    A = _handle(a)
    done = {"injected": False}

    def inject(seg, h):
        if not done["injected"]:
            done["injected"] = True
            blocks = np.array(jax.device_get(h.at_blocks))
            blocks[:, 0, :, 3] = np.max(np.abs(blocks))
            h.at_blocks = jnp.asarray(blocks)
            h.release()

    res = ft_cg(A, b, tol=1e-6, maxiter=400, segment=25,
                key=jax.random.fold_in(KEY, 9),
                manager=CheckpointManager(str(tmp_path)),
                segment_hook=inject, max_restores=2)
    assert not res.converged
    assert res.restores == 3              # max_restores + the breaking one


def test_ft_pdhg_healthy_lp(tmp_path):
    from repro.distributed.fault_tolerance import CheckpointManager
    a, b, c, x_star, _ = solvers.random_feasible_lp(
        jax.random.fold_in(KEY, 11), 48, 64)
    A = _handle(np.asarray(a), cell=16)
    # tol must sit above the analog KKT floor for this device/size (~2e-2)
    res = ft_pdhg(A, b, c, tol=5e-2, maxiter=3000, segment=200,
                  key=jax.random.fold_in(KEY, 12),
                  manager=CheckpointManager(str(tmp_path)))
    assert res.converged, res
    assert res.restores == 0
    obj_star = float(c @ x_star)
    assert abs(float(c @ res.x) - obj_star) / (1 + abs(obj_star)) < 0.1
    assert res.dual is not None


def test_ft_pdhg_recovers_from_nan_fault(tmp_path):
    from repro.distributed.fault_tolerance import CheckpointManager
    a, b, c, _, _ = solvers.random_feasible_lp(
        jax.random.fold_in(KEY, 11), 48, 64)
    A = _handle(np.asarray(a), cell=16)
    state = {"saved": None}

    def inject(seg, h):
        # seg 0: this LP can converge within one segment, so the fault must
        # land before the first inner solve to be seen at all
        if state["saved"] is None:
            state["saved"] = h.at_blocks
            blocks = np.array(jax.device_get(h.at_blocks))
            blocks[0, 0, 0, 0] = np.nan
            h.at_blocks = jnp.asarray(blocks)
            h.release()

    def repair(event, h):
        h.at_blocks = state["saved"]
        h.release()

    res = ft_pdhg(A, b, c, tol=5e-2, maxiter=3000, segment=200,
                  key=jax.random.fold_in(KEY, 12),
                  manager=CheckpointManager(str(tmp_path)),
                  segment_hook=inject, on_fault=repair)
    assert res.converged, res
    assert res.restores == 1
    assert len(res.fault_events) == 1


def test_divergence_param_none_is_default_numerics():
    """divergence=None must leave the solver numerics (and jaxpr) untouched;
    a huge finite margin must not change a healthy solve either."""
    a, _, b = _spd(64)
    r0 = solvers.cg(a, b, tol=1e-6, maxiter=40)
    r1 = solvers.cg(a, b, tol=1e-6, maxiter=40, divergence=None)
    r2 = solvers.cg(a, b, tol=1e-6, maxiter=40, divergence=1e9)
    np.testing.assert_array_equal(np.asarray(r0.x), np.asarray(r1.x))
    np.testing.assert_allclose(np.asarray(r0.x), np.asarray(r2.x), atol=1e-6)
    assert r0.iterations == r1.iterations == r2.iterations


# ------------------------------------------------------- serving scheduler
def test_serving_refresh_scheduler_bills_and_replays():
    from repro.configs.base import RRAMBackendConfig
    from repro.serving import (ReliabilityConfig, ServingConfig, TenantSpec,
                               TrafficConfig, simulate)
    tenants = (TenantSpec("a", "zamba2-1.2b"), TenantSpec("b", "zamba2-1.2b"))
    traffic = TrafficConfig(n_requests=16, rate_rps=4.0, seed=3)
    rram = RRAMBackendConfig(enabled=True, device="ag-si", k_iters=3)
    base = dict(tenants=tenants, traffic=traffic, rram=rram, run_model=False)

    r0 = simulate(ServingConfig(**base))
    assert "reliability" not in r0.summary          # off by default
    assert "refreshes" in r0.cache_stats

    rel = ReliabilityConfig(refresh_threshold=0.05, refresh_fraction=0.25)
    r1 = simulate(ServingConfig(**base, reliability=rel))
    rs = r1.summary["reliability"]
    assert rs["refreshes"] > 0
    assert rs["refresh_energy_j"] > 0
    assert rs["refresh_stall_s"] > 0
    assert 0 < rs["mean_predicted_residual"] \
        <= rs["max_predicted_residual"] + 1e-12
    # refresh energy lands in the cache's write ledger -> joules/token
    assert r1.cache_stats["refreshes"] == rs["refreshes"]
    assert r1.cache_stats["write_energy_j"] > r0.cache_stats["write_energy_j"]
    # a loose threshold schedules no refreshes but still reports health
    r2 = simulate(ServingConfig(**base, reliability=ReliabilityConfig(
        refresh_threshold=1e9)))
    rs2 = r2.summary["reliability"]
    assert rs2["refreshes"] == 0
    assert rs2["max_predicted_residual"] > rs["max_predicted_residual"]
    # deterministic replay
    r1b = simulate(ServingConfig(**base, reliability=rel))
    assert r1b.summary == r1.summary and r1b.records == r1.records
