"""Distributed MELISO+ solve: a large matrix programmed ONCE across a device
mesh, then reused for an iterative solve (the paper's MPI distribution mapped
onto shard_map + psum, driven through the program-once AnalogEngine).

    PYTHONPATH=src python examples/meliso_solver.py            # 8 host devices
    PYTHONPATH=src python examples/meliso_solver.py --n 8192 --iters 20

The matrix rows shard over the 'data' axis, the contraction over 'model';
each device simulates its own tile of MCAs and keeps its block of the
programmed conductance image resident.  Every Richardson iteration of the
solve  x_{k+1} = x_k + omega (b - A x_k)  re-executes against the SAME
programmed image -- tier-1 EC locally, psum partials, denoise on-node -- so
the one-time write cost amortizes across the whole solve, which is exactly
the regime (PDHG-style iterative solvers) the companion papers target.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp

from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--device", default="taox-hfox")
    ap.add_argument("--cell", type=int, default=256)
    ap.add_argument("--no-ec", action="store_true")
    args = ap.parse_args()

    mesh = make_mesh((2, 4), ("data", "model"))
    n = args.n
    key = jax.random.PRNGKey(0)
    # Diagonally-dominant SPD-ish system so plain Richardson converges.
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + 2.0 * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    b = a @ x_true

    local = (n // 2, n // 4)
    geom = MCAGeometry(tile_rows=max(local[0] // args.cell, 1),
                       tile_cols=max(local[1] // args.cell, 1),
                       cell_rows=args.cell, cell_cols=args.cell)
    cfg = CrossbarConfig(device=get_device(args.device), geom=geom,
                         k_iters=5, ec=not args.no_ec)

    engine = AnalogEngine(cfg, execution="distributed", mesh=mesh)
    A = engine.program(a, key)                      # programmed ONCE
    print(f"n={n} device={args.device} ec={not args.no_ec} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    print(f"one-time write energy (mean/MCA-system) = "
          f"{float(A.write_stats.energy_j):.3e} J, "
          f"latency = {float(A.write_stats.latency_s):.4f} s")

    omega = 1.0 / 3.0
    x = jnp.zeros((n,), jnp.float32)
    for it in range(args.iters):
        y = A @ x                                   # analog MVM, zero re-encode
        x = x + omega * (b - y)
        if (it + 1) % max(args.iters // 5, 1) == 0:
            print(f"  iter {it + 1:3d}: residual rel_l2 = "
                  f"{float(rel_l2(a @ x, b)):.5f}")

    per_call = A.input_write_stats(batch=1)
    print(f"solution error rel_l2 = {float(rel_l2(x, x_true)):.5f}")
    print(f"per-MVM input-write energy = {float(per_call.energy_j):.3e} J "
          f"({args.iters} executions against one programmed image)")


if __name__ == "__main__":
    main()
