"""Distributed MELISO+ solve through the ``repro.solvers`` subsystem.

A large SPD matrix is programmed ONCE across a device mesh (rows shard over
'data', the contraction over 'model'; each device keeps its block of the
conductance image resident), then *reused* by matvec-only iterative solvers:

  * the legacy fixed-omega Richardson loop (omega = 1/3, what this example
    hand-rolled before the solver layer existed) as the baseline;
  * Richardson with auto-omega from a matvec-only power-iteration spectral
    estimate;
  * conjugate gradients.

Every solver iteration re-executes against the SAME programmed image -- tier-1
EC locally, psum partials, denoise on-node -- so the one-time write cost
amortizes across the whole solve (the PDHG-style regime of the companion
papers), and each ``SolveResult`` ledger splits energy into the one-time
programming cost vs the per-iteration input-write cost.

``--mesh R,C`` picks the placement (R row shards x C contraction shards;
``1,1`` runs the whole solve on one device -- draw-identical to the streamed
path).  ``--producer`` programs through a traceable ``block_fn(i, j)``
producer instead of the dense array: each device scan-programs only its
window of the global block grid.  Note this example's producer reads the
dense copy that exists for error reporting, so the flag demonstrates the
producer-driven pipeline, not the memory win -- procedural producers that
never materialize A are in ``benchmarks/strong_scaling.py``.

    PYTHONPATH=src python examples/meliso_solver.py            # 8 host devices
    PYTHONPATH=src python examples/meliso_solver.py --n 2048 --tol 1e-3
    PYTHONPATH=src python examples/meliso_solver.py --mesh 4,2 --producer
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--tol", type=float, default=1e-3,
                    help="relative-residual stopping tolerance")
    ap.add_argument("--maxiter", type=int, default=50)
    # epiram (64 levels) by default: the 8-level devices' quantization noise
    # floor caps the corrected solve around ~5e-3 relative error, while the
    # precision device reaches <= 1e-3 (sweep the rest via --device /
    # benchmarks/solver_convergence.py).
    ap.add_argument("--device", default="epiram")
    ap.add_argument("--cell", type=int, default=256)
    ap.add_argument("--no-ec", action="store_true")
    ap.add_argument("--mesh", default="2,4", metavar="R,C",
                    help="mesh shape: R row shards x C contraction shards")
    ap.add_argument("--producer", action="store_true",
                    help="exercise the producer-driven distributed code path "
                         "(here the producer reads a dense copy kept for "
                         "error reporting, so it demonstrates the pipeline, "
                         "not the memory win; see "
                         "benchmarks/strong_scaling.py for procedural "
                         "producers that never materialize A)")
    args = ap.parse_args()

    try:
        rows, cols = (int(v) for v in args.mesh.split(","))
    except ValueError:
        raise SystemExit(f"--mesh must be 'R,C' integers, got {args.mesh!r}")
    if rows * cols > jax.device_count():
        raise SystemExit(
            f"--mesh {rows}x{cols} needs {rows * cols} devices but only "
            f"{jax.device_count()} are available")
    mesh = make_mesh((rows, cols), ("data", "model"))
    n = args.n
    key = jax.random.PRNGKey(0)
    # Diagonally-dominant SPD system (spectrum ~2 +- O(1/sqrt(n))).
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + 2.0 * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    b = a @ x_true

    local = (n // rows, n // cols)
    geom = MCAGeometry(tile_rows=max(local[0] // args.cell, 1),
                       tile_cols=max(local[1] // args.cell, 1),
                       cell_rows=args.cell, cell_cols=args.cell)
    cfg = CrossbarConfig(device=get_device(args.device), geom=geom,
                         k_iters=5, ec=not args.no_ec)

    engine = AnalogEngine(cfg, execution="distributed", mesh=mesh)
    if args.producer:
        cap_m, cap_n = cfg.geom.capacity
        mb, nb = -(-n // cap_m), -(-n // cap_n)
        a_pad = jnp.pad(a, ((0, mb * cap_m - n), (0, nb * cap_n - n)))
        blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)
        A = engine.program(lambda i, j: blocks[i, j], key,
                           shape=(n, n))       # programmed ONCE, per window
    else:
        A = engine.program(a, key)             # programmed ONCE
    print(f"n={n} device={args.device} ec={not args.no_ec} "
          f"producer={args.producer} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    print(f"one-time write energy (mean/MCA-system) = "
          f"{float(A.write_stats.energy_j):.3e} J, "
          f"latency = {float(A.write_stats.latency_s):.4f} s\n")

    # The analog noise floor of ONE corrected MVM: solves cannot reliably
    # push their true residual below the operator's own relative error, so a
    # tighter --tol than this is unreachable on this device/EC configuration.
    y_probe = A @ x_true
    noise_floor = float(rel_l2(y_probe, b))
    below_floor = args.tol < noise_floor
    if below_floor:
        print(f"WARNING: --tol {args.tol:.1e} is below the analog noise "
              f"floor ~{noise_floor:.1e} of this configuration; solvers will "
              "stall at the floor (use repro.solvers.refine to converge "
              "below it).  Reporting achieved residuals instead of "
              "asserting convergence.\n")

    runs = [
        ("richardson omega=1/3 (old loop)",
         lambda: solvers.richardson(A, b, omega=1.0 / 3.0, tol=args.tol,
                                    maxiter=args.maxiter)),
        ("richardson auto-omega",
         lambda: solvers.richardson(A, b, tol=args.tol,
                                    maxiter=args.maxiter)),
        ("cg",
         lambda: solvers.cg(A, b, tol=args.tol, maxiter=args.maxiter)),
    ]
    # The convergence asserts hold for the default precision configuration;
    # the noisy 8-level devices / --no-ec runs are demonstrations of the
    # quantization floor, and a below-floor --tol is physically unreachable
    # (warned above) -- neither is expected to hit --tol.
    check = args.device == "epiram" and not args.no_ec and not below_floor
    print(f"{'solver':34s} {'iters':>5s} {'resid':>9s} {'x err':>9s} "
          f"{'E_write J':>10s} {'E_iters J':>10s}")
    baseline_iters = None
    for name, run in runs:
        res = run()
        err = float(rel_l2(res.x, x_true))
        led = res.ledger
        print(f"{name:34s} {res.iterations:5d} {res.final_residual:9.2e} "
              f"{err:9.2e} {led.write_energy_j:10.3e} "
              f"{led.iteration_energy_j:10.3e}")
        if baseline_iters is None:
            baseline_iters = res.iterations
        elif check:
            assert res.iterations < baseline_iters, \
                (name, res.iterations, baseline_iters)
            assert err <= args.tol, (name, err)
        assert led.write_energy_j > 0 and led.iteration_energy_j > 0
    if below_floor:
        print(f"\nnoise floor ~{noise_floor:.1e} (requested tol "
              f"{args.tol:.1e} not reachable without refinement)")

    print("\nper-MVM input-write energy = "
          f"{float(A.input_write_stats(batch=1).energy_j):.3e} J "
          "(amortized against one programmed image)")


if __name__ == "__main__":
    main()
