"""Distributed MELISO+ solve: a large corrected MVM sharded over a device
mesh (the paper's MPI distribution mapped onto shard_map + psum).

    PYTHONPATH=src python examples/meliso_solver.py            # 8 host devices
    PYTHONPATH=src python examples/meliso_solver.py --n 8192

The matrix rows shard over the 'data' axis, the contraction over 'model';
each device simulates its own 8x8 tile of MCAs, applies tier-1 EC locally,
psums partials, and denoises on-node -- then we report accuracy vs the exact
product plus the paper-convention write energy/latency (mean across MCAs).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp

from repro.core import (CrossbarConfig, MCAGeometry, distributed_corrected_mvm,
                        get_device, rel_l2, rel_linf)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--device", default="taox-hfox")
    ap.add_argument("--cell", type=int, default=256)
    ap.add_argument("--no-ec", action="store_true")
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    n = args.n
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n, n), jnp.float32) / jnp.sqrt(n)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    b = a @ x

    local = (n // 2, n // 4)
    geom = MCAGeometry(tile_rows=max(local[0] // args.cell, 1),
                       tile_cols=max(local[1] // args.cell, 1),
                       cell_rows=args.cell, cell_cols=args.cell)
    cfg = CrossbarConfig(device=get_device(args.device), geom=geom,
                         k_iters=5, ec=not args.no_ec)
    y, stats = distributed_corrected_mvm(a, x, key, cfg, mesh)
    print(f"n={n} device={args.device} ec={not args.no_ec} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    print(f"rel_l2={float(rel_l2(y, b)):.5f} rel_linf={float(rel_linf(y, b)):.5f}")
    print(f"write energy (mean/MCA-system) = {float(stats.energy_j):.3e} J, "
          f"latency = {float(stats.latency_s):.4f} s")
    print(f"output sharding: {y.sharding}")


if __name__ == "__main__":
    main()
