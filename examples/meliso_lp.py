"""Linear programming on ONE programmed crossbar image (PDHG).

The companion RRAM-PDHG paper's regime: a standard-form LP

    min c'x   s.t.   A x = b,  x >= 0

is solved by the primal-dual hybrid gradient method, which touches the
constraint matrix only through ``A @ x`` and ``A.T @ y``.  Both directions
read the SAME conductance image -- the matrix is programmed exactly once and
every PDHG iteration (one corrected forward MVM + one corrected TRANSPOSED
MVM) amortizes that write, with forward and transposed input-write costs
billed separately in the :class:`~repro.solvers.SolveLedger`.

The LP is generated with a KNOWN optimal primal-dual pair
(:func:`repro.solvers.random_feasible_lp`), so the example reports the true
objective gap of both the digital PDHG oracle and the analog solve.

``--mesh R,C`` distributes the solve: the image is block-sharded over the
mesh, the forward MVM psums over the contraction columns (output
row-sharded), the transposed MVM psums over the ROWS (output column-sharded)
-- so the whole jitted PDHG while_loop keeps its x/y panels sharded with no
gathers.  ``--producer`` programs through a traceable ``block_fn(i, j)``
producer instead of the dense array.

    PYTHONPATH=src python examples/meliso_lp.py
    PYTHONPATH=src python examples/meliso_lp.py --n 1024 --m 768
    PYTHONPATH=src python examples/meliso_lp.py --mesh 2,4 --producer
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=256, help="LP constraints (rows)")
    ap.add_argument("--n", type=int, default=512, help="LP variables (cols)")
    ap.add_argument("--tol", type=float, default=2e-4,
                    help="KKT-residual stopping tolerance")
    ap.add_argument("--maxiter", type=int, default=20000)
    ap.add_argument("--device", default="epiram")
    ap.add_argument("--cell", type=int, default=64)
    ap.add_argument("--mesh", default="1,1", metavar="R,C",
                    help="mesh shape (1,1 = single device)")
    ap.add_argument("--producer", action="store_true",
                    help="program through a block producer (the distributed "
                         "scan-programmed pipeline)")
    args = ap.parse_args()

    try:
        rows, cols = (int(v) for v in args.mesh.split(","))
    except ValueError:
        raise SystemExit(f"--mesh must be 'R,C' integers, got {args.mesh!r}")
    if rows * cols > jax.device_count():
        raise SystemExit(
            f"--mesh {rows}x{cols} needs {rows * cols} devices but only "
            f"{jax.device_count()} are available")

    key = jax.random.PRNGKey(0)
    a, b, c, x_star, y_star = solvers.random_feasible_lp(
        key, args.m, args.n)
    obj_star = float(c @ x_star)

    geom = MCAGeometry(tile_rows=1, tile_cols=1,
                       cell_rows=args.cell, cell_cols=args.cell)
    cfg = CrossbarConfig(device=get_device(args.device), geom=geom,
                         k_iters=5, ec=True)
    if rows * cols == 1:
        engine = AnalogEngine(cfg)
        A = engine.program(a, key)
    else:
        mesh = make_mesh((rows, cols), ("data", "model"))
        engine = AnalogEngine(cfg, execution="distributed", mesh=mesh)
        if args.producer:
            cap_m, cap_n = cfg.geom.capacity
            mb, nb = -(-args.m // cap_m), -(-args.n // cap_n)
            a_pad = jnp.pad(a, ((0, mb * cap_m - args.m),
                                (0, nb * cap_n - args.n)))
            blocks = a_pad.reshape(mb, cap_m, nb, cap_n).transpose(0, 2, 1, 3)
            A = engine.program(lambda i, j: blocks[i, j], key,
                               shape=a.shape)
        else:
            A = engine.program(a, key)

    print(f"LP: {args.m} constraints x {args.n} vars, device={args.device}, "
          f"mesh={args.mesh}, producer={args.producer}")
    print(f"known optimum c'x* = {obj_star:.6f} (= b'y* = "
          f"{float(b @ y_star):.6f})")
    print(f"one-time write energy = {float(A.write_stats.energy_j):.3e} J\n")

    # Oracle: the same algorithm on the exact digital operator, run to the
    # same tolerance (PDHG is O(1/k); a much tighter digital tol would just
    # burn iterations without changing the comparison).
    digital = solvers.pdhg(a, b, c, tol=args.tol, maxiter=args.maxiter)
    analog = solvers.pdhg(A, b, c, tol=args.tol, maxiter=args.maxiter,
                          key=key)

    print(f"{'solver':20s} {'iters':>6s} {'kkt':>9s} {'objective':>11s} "
          f"{'gap to *':>9s} {'E_write J':>10s} {'E_iters J':>10s}")
    for name, res in (("pdhg digital", digital), ("pdhg analog", analog)):
        obj = float(c @ res.x)
        gap = abs(obj - obj_star) / (1 + abs(obj_star))
        led = res.ledger
        print(f"{name:20s} {res.iterations:6d} {res.final_residual:9.2e} "
              f"{obj:11.6f} {gap:9.2e} {led.write_energy_j:10.3e} "
              f"{led.iteration_energy_j:10.3e}")

    obj_a, obj_d = float(c @ analog.x), float(c @ digital.x)
    obj_gap = abs(obj_a - obj_d) / (1 + abs(obj_d))
    assert analog.converged and digital.converged
    assert obj_gap <= 1e-3, (obj_a, obj_d)
    assert float(rel_l2(a @ analog.x, b)) < 10 * args.tol

    led = analog.ledger
    print(f"\nledger: {led.mvms} forward MVMs @ "
          f"{float(led.input_stats.energy_j):.3e} J + {led.mvms_t} "
          f"transposed MVMs @ {float(led.input_stats_t.energy_j):.3e} J + "
          f"{led.mvms_single}+{led.mvms_single_t} setup MVMs, one matrix write "
          f"{led.write_energy_j:.3e} J")
    print(f"analog objective within {obj_gap:.1e} of the digital oracle")


if __name__ == "__main__":
    main()
