"""Batched serving demo: prefill + greedy decode on any registry arch,
digital or RRAM-analog backend (the paper's technique as a deployment mode).

    PYTHONPATH=src python examples/serve_lm.py
    PYTHONPATH=src python examples/serve_lm.py --rram --device taox-hfox

With --rram the weights are programmed onto simulated crossbars once
(write energy/latency reported -- the analog deployment's one-time cost) and
every matmul runs the fused two-tier-EC analog path.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, model_module
from repro.configs.base import RRAMBackendConfig
from repro.models import params as PM
from repro.models.common import Runtime
from repro.train.serve import Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--rram", action="store_true")
    ap.add_argument("--device", default="taox-hfox")
    ap.add_argument("--no-ec", action="store_true")
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.reduced()
    mod = model_module(cfg)
    params = PM.materialize(mod.init_specs(cfg), jax.random.PRNGKey(0))

    rt = Runtime()
    if args.rram:
        rt = Runtime(rram=RRAMBackendConfig(
            enabled=True, device=args.device, ec=not args.no_ec,
            cell_rows=32, cell_cols=32, k_iters=5),
            key=jax.random.PRNGKey(9))

    srv = Server(mod, cfg, params, rt=rt,
                 max_len=args.prompt_len + args.tokens + 8)
    if srv.write_stats is not None:
        print(f"analog programming: E={float(srv.write_stats.energy_j):.3e} J, "
              f"L={float(srv.write_stats.latency_s):.3e} s "
              f"(one-time, device={args.device})")

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.family == "whisper":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, args.prompt_len, cfg.d_model))
    if cfg.family == "llama_vision":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_patches, cfg.d_model))

    t0 = time.perf_counter()
    out = srv.generate(batch, args.tokens)
    dt = time.perf_counter() - t0
    total = args.batch * args.tokens
    print(f"arch={args.arch} backend={'rram' if args.rram else 'digital'} "
          f"batch={args.batch}")
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s incl. prefill+compile)")
    print("first sequence:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
