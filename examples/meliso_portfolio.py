"""Box-constrained portfolio selection on ONE programmed crossbar image
(linearized ADMM).

A factor-model mean-variance portfolio:

    min_x  (1/2)||F x||^2 - lam * mu'x    s.t.  0 <= x <= cap

where ``F`` is the (k, n) factor-loading matrix (so ``F'F`` is the
low-rank risk model), ``mu`` the expected returns, and the box keeps every
position long and capped.  This is exactly the
:func:`repro.solvers.admm` form ``min (1/2)||Ax - b||^2 + q'x`` with
``b = 0`` and ``q = -lam * mu``: the loadings are programmed ONCE and every
ADMM iteration is one corrected forward MVM (``F x``, the factor
exposures) plus one corrected TRANSPOSED MVM (``F'u``, the risk
gradient) against the same image -- plus a handful of power-iteration
matvecs up front to size the linearized step, all billed to the ledger.

The digital oracle is the same algorithm on the exact operator; the
acceptance metric is the relative objective gap.

    PYTHONPATH=src python examples/meliso_portfolio.py
    PYTHONPATH=src python examples/meliso_portfolio.py --assets 192 --cap 0.1
    PYTHONPATH=src python examples/meliso_portfolio.py --device taox-hfox
"""
import argparse

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine


def objective(f, q, x) -> float:
    return float(0.5 * jnp.sum((f @ x) ** 2) + q @ x)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--assets", type=int, default=96, help="universe size n")
    ap.add_argument("--factors", type=int, default=32,
                    help="risk factors k (rows of F)")
    ap.add_argument("--cap", type=float, default=0.08,
                    help="per-position upper bound")
    ap.add_argument("--lam", type=float, default=0.5,
                    help="return-seeking weight on mu'x")
    ap.add_argument("--tol", type=float, default=1e-4)
    ap.add_argument("--maxiter", type=int, default=2000)
    ap.add_argument("--device", default="epiram")
    ap.add_argument("--cell", type=int, default=32)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kf, km, kp = jax.random.split(key, 3)
    n, k = args.assets, args.factors
    f = jax.random.normal(kf, (k, n), jnp.float32) / jnp.sqrt(jnp.float32(k))
    mu = 0.05 + 0.02 * jax.random.normal(km, (n,), jnp.float32)
    b = jnp.zeros((k,), jnp.float32)
    q = -args.lam * mu
    lo, hi = jnp.zeros((n,)), jnp.full((n,), args.cap)

    geom = MCAGeometry(tile_rows=1, tile_cols=1,
                       cell_rows=args.cell, cell_cols=args.cell)
    cfg = CrossbarConfig(device=get_device(args.device), geom=geom,
                         k_iters=5, ec=True)
    engine = AnalogEngine(cfg)
    F = engine.program(f, kp)

    print(f"portfolio: {n} assets, {k} factors, box [0, {args.cap}], "
          f"device={args.device}")
    print(f"one-time write energy = {float(F.write_stats.energy_j):.3e} J\n")

    digital = solvers.admm(f, b, q, lo=lo, hi=hi, tol=args.tol,
                           maxiter=args.maxiter)
    analog = solvers.admm(F, b, q, lo=lo, hi=hi, tol=args.tol,
                          maxiter=args.maxiter, key=kp)

    print(f"{'solver':16s} {'iters':>6s} {'kkt':>9s} {'objective':>11s} "
          f"{'gross':>7s} {'at cap':>6s} {'E_iters J':>10s}")
    for tag, res in (("admm digital", digital), ("admm analog", analog)):
        w = jnp.clip(res.x, 0.0, args.cap)
        at_cap = int(jnp.sum(w >= args.cap - 1e-6))
        print(f"{tag:16s} {res.iterations:6d} {res.final_residual:9.2e} "
              f"{objective(f, q, res.x):11.6f} {float(jnp.sum(w)):7.3f} "
              f"{at_cap:6d} {res.ledger.iteration_energy_j:10.3e}")

    assert digital.converged and analog.converged
    obj_d, obj_a = objective(f, q, digital.x), objective(f, q, analog.x)
    obj_gap = abs(obj_a - obj_d) / (1 + abs(obj_d))
    assert obj_gap <= 1e-3, (obj_a, obj_d)
    # The split copy (res.dual) is the box-feasible iterate.
    assert float(jnp.min(analog.dual)) >= -1e-6
    assert float(jnp.max(analog.dual)) <= args.cap + 1e-6
    w_gap = float(rel_l2(analog.x, digital.x))

    led = analog.ledger
    print(f"\nledger: {led.mvms + led.mvms_single} forward + "
          f"{led.mvms_t + led.mvms_single_t} transposed MVMs (incl. the "
          f"power-iteration step sizing) against one programmed image, "
          f"write {led.write_energy_j:.3e} J")
    print(f"analog objective within {obj_gap:.1e} of the digital oracle, "
          f"weights within {w_gap:.1e}")


if __name__ == "__main__":
    main()
