"""Multi-exposure 1-D deblurring on ONE programmed crossbar image (LSQR).

A classic rectangular inverse problem: a piecewise-smooth signal is
observed through TWO Gaussian blur kernels of different widths (stacked
into an overdetermined (2n, n) operator) with additive readout noise, and
recovered by ``min ||A x - b||``.  Both Golub-Kahan directions -- ``A @ v``
and ``A.T @ u`` -- read the SAME conductance image: the operator is
programmed exactly once and every bidiagonalization step (one corrected
forward MVM + one corrected TRANSPOSED MVM) amortizes that write, with
forward and transposed input-write costs billed separately in the
:class:`~repro.solvers.SolveLedger`.

The example solves with both :func:`repro.solvers.lsqr` and
:func:`repro.solvers.lsmr` (same bidiagonalization, different recurrence:
LSMR monotonically decreases ``||A^T r||``).  Blur operators are
ill-conditioned, so iteration count acts as regularization
(semiconvergence) and the dense SVD solution would amplify the noise --
the oracle here is the same algorithm on the exact digital operator at
the same tolerance, compared in OBSERVATION space (``A x``, which the
data constrain) and by reconstruction error against the known truth.

    PYTHONPATH=src python examples/meliso_lstsq.py
    PYTHONPATH=src python examples/meliso_lstsq.py --n 256 --sigma 4.0
    PYTHONPATH=src python examples/meliso_lstsq.py --device taox-hfox
"""
import argparse

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.engine import AnalogEngine


def blur_matrix(n: int, sigma: float) -> jnp.ndarray:
    """(n, n) circulant Gaussian blur with kernel width ``sigma``."""
    idx = jnp.arange(n, dtype=jnp.float32)
    d = jnp.minimum(jnp.abs(idx[:, None] - idx[None, :]),
                    n - jnp.abs(idx[:, None] - idx[None, :]))
    k = jnp.exp(-0.5 * (d / sigma) ** 2)
    return k / jnp.sum(k, axis=1, keepdims=True)


def piecewise_signal(n: int, key) -> jnp.ndarray:
    """A few random steps + a smooth bump: edges AND gradients to recover."""
    k1, k2 = jax.random.split(key)
    steps = jnp.cumsum(jnp.where(
        jax.random.uniform(k1, (n,)) < 4.0 / n,
        jax.random.normal(k2, (n,)), 0.0))
    t = jnp.linspace(0.0, 1.0, n)
    bump = 0.8 * jnp.exp(-0.5 * ((t - 0.35) / 0.08) ** 2)
    return steps + bump


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128, help="signal length")
    ap.add_argument("--sigma", type=float, default=2.0,
                    help="width of the narrower blur kernel")
    ap.add_argument("--noise", type=float, default=1e-3,
                    help="additive observation noise level")
    ap.add_argument("--tol", type=float, default=1e-5)
    ap.add_argument("--maxiter", type=int, default=200)
    ap.add_argument("--device", default="epiram")
    ap.add_argument("--cell", type=int, default=32)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kx, kn, kp = jax.random.split(key, 3)
    x_true = piecewise_signal(args.n, kx)
    # Two exposures through different blurs -> overdetermined (2n, n).
    a = jnp.concatenate([blur_matrix(args.n, args.sigma),
                         blur_matrix(args.n, 2.0 * args.sigma)], axis=0)
    b = a @ x_true + args.noise * jax.random.normal(kn, (2 * args.n,))

    geom = MCAGeometry(tile_rows=1, tile_cols=1,
                       cell_rows=args.cell, cell_cols=args.cell)
    cfg = CrossbarConfig(device=get_device(args.device), geom=geom,
                         k_iters=5, ec=True)
    engine = AnalogEngine(cfg)
    A = engine.program(a, kp)

    print(f"deblurring: ({2 * args.n}, {args.n}) two-exposure operator, "
          f"device={args.device}, noise={args.noise:g}")
    print(f"one-time write energy = {float(A.write_stats.energy_j):.3e} J\n")

    runs = {}
    print(f"{'solver':16s} {'iters':>6s} {'residual':>9s} "
          f"{'vs truth':>9s} {'E_iters J':>10s}")
    for algo, fn in (("lsqr", solvers.lsqr), ("lsmr", solvers.lsmr)):
        digital = fn(a, b, tol=args.tol, maxiter=args.maxiter)
        analog = fn(A, b, tol=args.tol, maxiter=args.maxiter, key=kp)
        runs[algo] = (digital, analog)
        for tag, res in ((f"{algo} digital", digital),
                         (f"{algo} analog", analog)):
            print(f"{tag:16s} {res.iterations:6d} "
                  f"{res.final_residual:9.2e} "
                  f"{float(rel_l2(res.x, x_true)):9.2e} "
                  f"{res.ledger.iteration_energy_j:10.3e}")

    digital, analog = runs["lsqr"]
    assert digital.converged and analog.converged
    # Observation space is what the data constrain: both reconstructions
    # must predict the same (de)blurred measurements...
    obs_gap = float(rel_l2(a @ analog.x, a @ digital.x))
    assert obs_gap <= 1e-3, obs_gap
    # ...and the analog reconstruction must match the digital QUALITY.
    err_a = float(rel_l2(analog.x, x_true))
    err_d = float(rel_l2(digital.x, x_true))
    assert err_a <= 1.2 * err_d + 1e-3, (err_a, err_d)

    led = analog.ledger
    print(f"\nledger: {led.mvms} forward MVMs + {led.mvms_t} transposed "
          f"MVMs against one programmed image, write "
          f"{led.write_energy_j:.3e} J")
    print(f"analog LSQR predicts the digital observations to {obs_gap:.1e}; "
          f"truth error {err_a:.3f} vs digital {err_d:.3f}")


if __name__ == "__main__":
    main()
