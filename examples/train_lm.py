"""End-to-end training driver: data pipeline -> sharded train step ->
checkpointing/preemption/watchdog, on any --arch from the registry.

    PYTHONPATH=src python examples/train_lm.py                          # smoke
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b --steps 5 \
        --preset reduced   # any assigned arch, reduced config

The ``100m`` preset is a ~112M-parameter qwen3-family model -- the
"train a ~100M model for a few hundred steps" driver (CPU-viable at --seq 256;
on real accelerators raise --batch/--seq).  Checkpoints restore elastically
(see --resume).
"""
import argparse
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.configs import get_arch, model_module
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import Prefetcher, batches
from repro.distributed import CheckpointManager
from repro.models import params as PM
from repro.train import Trainer


def preset_config(name: str, arch_name: str) -> ModelConfig:
    if name == "reduced":
        return get_arch(arch_name).reduced()
    if name == "smoke":
        return dataclasses.replace(
            get_arch("qwen3-1.7b").reduced(), n_layers=4, d_model=128, d_ff=512)
    if name == "100m":
        return ModelConfig(
            family="transformer", n_layers=10, d_model=640, n_heads=10,
            n_kv_heads=5, d_head=64, d_ff=2560, vocab=32768, qk_norm=True,
            act="silu_gated", param_dtype="float32", compute_dtype="float32")
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "reduced"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = preset_config(args.preset, args.arch)
    mod = model_module(cfg)
    params = PM.materialize(mod.init_specs(cfg), jax.random.PRNGKey(0),
                            jnp.dtype(cfg.param_dtype))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={args.arch} preset={args.preset} params={n_params/1e6:.1f}M")

    tcfg = TrainConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps,
                       microbatch=max(args.batch // 2, 1))
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2)
    trainer = Trainer(mod, cfg, tcfg, params, ckpt=ckpt,
                      ckpt_every=args.ckpt_every)
    if args.resume and ckpt.latest_step() is not None:
        trainer.restore()
        print(f"resumed from step {trainer.step}")

    data = Prefetcher(batches(cfg, args.batch, args.seq,
                              start_step=trainer.step))
    hist = trainer.run(data, args.steps)
    data.stop()
    losses = hist["loss"]
    for i in range(0, len(losses), max(len(losses) // 10, 1)):
        print(f"step {trainer.step - len(losses) + i + 1:>5}  "
              f"loss {losses[i]:.4f}  ({hist['step_time'][i]*1e3:.0f} ms)")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"straggler events: {len(trainer.watchdog.events)}")
    trainer.save(blocking=True)
    print(f"checkpointed at step {trainer.step} -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
