"""Quickstart: program-once / execute-many corrected MVM in a dozen lines.

    PYTHONPATH=src python examples/quickstart.py

Programs the paper's 66x66 bcsstk02 matrix onto a simulated TaOx-HfOx
multi-MCA crossbar ONCE, then reuses the programmed image for many corrected
MVMs -- the paper's serving model: the write energy is a one-time cost and
every subsequent analog MVM pays only the input-DAC write.  Prints the
Table-1-style comparison against the high-precision EpiRAM device.
"""
import jax
import jax.numpy as jnp

from repro.core import CrossbarConfig, MCAGeometry, get_device, rel_l2
from repro.core.matrices import paper_matrix
from repro.engine import AnalogEngine


def main():
    a = jnp.asarray(paper_matrix("bcsstk02"), jnp.float32)   # kappa = 4325
    key = jax.random.PRNGKey(0)
    xs = [jax.random.normal(jax.random.fold_in(key, i), (66,))
          for i in range(8)]                                 # a serving stream
    geom = MCAGeometry(tile_rows=1, tile_cols=1, cell_rows=66, cell_cols=66)

    print(f"{'device':<12} {'EC':<6} {'rel_l2':>9} {'E_program (J)':>14} "
          f"{'E_per_mvm (J)':>14}")
    for dev_name in ("epiram", "taox-hfox"):
        for ec in (False, True):
            if dev_name == "epiram" and ec:
                continue  # the benchmark device runs raw (paper Table 1)
            cfg = CrossbarConfig(device=get_device(dev_name), geom=geom,
                                 k_iters=5, ec=ec)
            engine = AnalogEngine(cfg)
            A = engine.program(a, jax.random.PRNGKey(1))     # one-time write
            errs = [float(rel_l2(A @ x, a @ x)) for x in xs]  # many executions
            per_call = A.input_write_stats(batch=1)
            print(f"{dev_name:<12} {str(ec):<6} "
                  f"{sum(errs) / len(errs):>9.4f} "
                  f"{float(A.write_stats.energy_j):>14.3e} "
                  f"{float(per_call.energy_j):>14.3e}")

    print("\n-> the noisy-but-fast TaOx-HfOx device + error correction reaches "
          "EpiRAM-class accuracy at ~1000x less write energy (the paper's "
          "headline result) -- and under program-once serving the matrix "
          "write is paid a single time across the whole MVM stream.")


if __name__ == "__main__":
    main()
