"""Quickstart: the paper's corrected MVM in ten lines.

    PYTHONPATH=src python examples/quickstart.py

Runs A @ x on a simulated TaOx-HfOx multi-MCA crossbar (66x66, the paper's
bcsstk02 setting) with and without the two-tier error correction, and prints
the Table-1-style comparison against the high-precision EpiRAM device.
"""
import jax
import jax.numpy as jnp

from repro.core import (CrossbarConfig, MCAGeometry, corrected_mvm,
                        get_device, rel_l2)
from repro.core.matrices import paper_matrix


def main():
    a = jnp.asarray(paper_matrix("bcsstk02"), jnp.float32)   # kappa = 4325
    x = jax.random.normal(jax.random.PRNGKey(0), (66,))
    b = a @ x                                                # ground truth
    geom = MCAGeometry(tile_rows=1, tile_cols=1, cell_rows=66, cell_cols=66)

    print(f"{'device':<12} {'EC':<4} {'rel_l2':>9} {'E_w (J)':>11} {'L_w (s)':>10}")
    for dev_name in ("epiram", "taox-hfox"):
        for ec in (False, True):
            if dev_name == "epiram" and ec:
                continue  # the benchmark device runs raw (paper Table 1)
            cfg = CrossbarConfig(device=get_device(dev_name), geom=geom,
                                 k_iters=5, ec=ec)
            y, stats = corrected_mvm(a, x, jax.random.PRNGKey(1), cfg)
            print(f"{dev_name:<12} {str(ec):<4} {float(rel_l2(y, b)):>9.4f} "
                  f"{float(stats.energy_j):>11.3e} {float(stats.latency_s):>10.4f}")

    print("\n-> the noisy-but-fast TaOx-HfOx device + error correction reaches "
          "EpiRAM-class accuracy at ~1000x less write energy (the paper's "
          "headline result).")


if __name__ == "__main__":
    main()
