"""Spectral graph partitioning on ONE programmed crossbar image.

The graph Laplacian of a planted two-community graph (a stochastic block
model) is programmed ONCE into analog conductances, then interrogated
purely through corrected matvecs:

  * :func:`repro.solvers.lanczos` sweeps BOTH extremal eigenpairs in one
    pass -- ``lambda_max`` bounds the spectrum (step sizing), and the
    near-zero ``lambda_min`` certifies the Laplacian's constant kernel;
  * :func:`repro.solvers.lobpcg` (``which="smallest"``, k=2) extracts the
    Fiedler pair -- the second-smallest eigenvector -- whose SIGN pattern
    is the spectral bipartition.

The planted labels are known, so the example reports partition accuracy
(up to the global sign flip) for the analog solve against the digital
oracle, plus the write-once/iterate-many energy split.

    PYTHONPATH=src python examples/meliso_spectral.py
    PYTHONPATH=src python examples/meliso_spectral.py --n 256 --p-out 0.05
    PYTHONPATH=src python examples/meliso_spectral.py --device taox-hfox
"""
import argparse

import jax
import jax.numpy as jnp

from repro import solvers
from repro.core import CrossbarConfig, MCAGeometry, get_device
from repro.engine import AnalogEngine


def sbm_laplacian(n: int, p_in: float, p_out: float, key):
    """Laplacian of a two-block stochastic block model + planted labels."""
    half = n // 2
    labels = jnp.concatenate([jnp.ones((half,)), -jnp.ones((n - half,))])
    same = labels[:, None] == labels[None, :]
    p = jnp.where(same, p_in, p_out)
    u = jax.random.uniform(key, (n, n))
    upper = jnp.triu(jnp.where(u < p, 1.0, 0.0), k=1)
    adj = upper + upper.T
    lap = jnp.diag(jnp.sum(adj, axis=1)) - adj
    return lap.astype(jnp.float32), labels


def accuracy(fiedler, labels) -> float:
    """Fraction of planted labels recovered, up to the global sign flip."""
    pred = jnp.where(fiedler >= 0, 1.0, -1.0)
    hits = float(jnp.mean(pred == labels))
    return max(hits, 1.0 - hits)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128, help="graph vertices")
    ap.add_argument("--p-in", type=float, default=0.30,
                    help="intra-community edge probability")
    ap.add_argument("--p-out", type=float, default=0.02,
                    help="inter-community edge probability")
    ap.add_argument("--tol", type=float, default=1e-4)
    ap.add_argument("--maxiter", type=int, default=100)
    ap.add_argument("--device", default="epiram")
    ap.add_argument("--cell", type=int, default=32)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kg, kp = jax.random.split(key)
    lap, labels = sbm_laplacian(args.n, args.p_in, args.p_out, kg)

    geom = MCAGeometry(tile_rows=1, tile_cols=1,
                       cell_rows=args.cell, cell_cols=args.cell)
    cfg = CrossbarConfig(device=get_device(args.device), geom=geom,
                         k_iters=5, ec=True)
    engine = AnalogEngine(cfg)
    L = engine.program(lap, kp)

    print(f"SBM: {args.n} vertices, p_in={args.p_in}, p_out={args.p_out}, "
          f"device={args.device}")
    print(f"one-time write energy = {float(L.write_stats.energy_j):.3e} J\n")

    # One Lanczos sweep brackets the whole spectrum, matvec-only.
    sweep = solvers.lanczos(L, tol=args.tol, maxiter=48, key=kp)
    lmin, lmax = (float(v) for v in sweep.eigenvalues)
    ref = jnp.linalg.eigvalsh(lap)
    print(f"lanczos spectrum: [{lmin:.4f}, {lmax:.4f}] in "
          f"{sweep.iterations} steps (digital eigh: [{float(ref[0]):.4f}, "
          f"{float(ref[-1]):.4f}])")

    digital = solvers.lobpcg(lap, 2, which="smallest", tol=args.tol,
                             maxiter=args.maxiter)
    analog = solvers.lobpcg(L, 2, which="smallest", tol=args.tol,
                            maxiter=args.maxiter, key=kp)

    print(f"\n{'solver':16s} {'iters':>6s} {'ritz res':>9s} "
          f"{'lambda_2':>9s} {'accuracy':>9s} {'E_iters J':>10s}")
    for tag, res in (("lobpcg digital", digital), ("lobpcg analog", analog)):
        acc = accuracy(res.x[:, 1], labels)
        print(f"{tag:16s} {res.iterations:6d} {res.final_residual:9.2e} "
              f"{float(res.eigenvalues[1]):9.4f} {acc:9.3f} "
              f"{res.ledger.iteration_energy_j:10.3e}")

    # The Laplacian kernel is the constant vector: lambda_min ~ 0.
    assert abs(lmin) <= 1e-2 * max(1.0, lmax), (lmin, lmax)
    assert lmax <= 1.05 * float(ref[-1]) + args.tol
    acc_a = accuracy(analog.x[:, 1], labels)
    acc_d = accuracy(digital.x[:, 1], labels)
    assert acc_d >= 0.95, acc_d
    assert acc_a >= acc_d - 0.05, (acc_a, acc_d)

    led = analog.ledger
    print(f"\nledger: {led.mvms + led.mvms_single} matvecs against one "
          f"programmed image, write {led.write_energy_j:.3e} J")
    print(f"analog Fiedler partition recovers {100 * acc_a:.1f}% of the "
          f"planted communities (digital: {100 * acc_d:.1f}%)")


if __name__ == "__main__":
    main()
