"""Device-lifetime reliability end to end: age, probe, refresh, recover.

Act 1 -- the lifetime of ONE programmed image on a faulty device: an SPD
system is programmed once, solved fresh, then aged by the device's own
read-disturb fault process (drift + replayable stuck-at latches, applied
inside the engine's single jitted dispatch).  The aged solve degrades; the
probe panel localizes the damage to specific capacity tiles; a
tile-selective refresh re-runs closed-loop write-and-verify on only those
tiles and restores the solve at a fraction of the full-reprogram energy.

Act 2 -- surviving a fault MID-solve: the same system is programmed across
a 2x4 device mesh and handed to the fault-tolerant CG wrapper.  A stuck
column is injected into the sharded conductance image during segment 1; the
digital residual check (against the healthy reference captured at entry)
flags the divergence, the iterate rolls back to the last good checkpoint on
disk, the ``on_fault`` callback repairs the operator, and the solve
converges anyway.

    PYTHONPATH=src python examples/meliso_reliability.py
    PYTHONPATH=src python examples/meliso_reliability.py --n 512 --mesh 4,2

See DESIGN.md section 12 and docs/reliability.md.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import CrossbarConfig, MCAGeometry, get_device
from repro.engine import AnalogEngine
from repro.launch.mesh import make_mesh
from repro.reliability import (RefreshPolicy, attach_age, ft_cg,
                               predicted_residual, probe_tile_scores,
                               refresh_tiles)
from repro.solvers import cg


def _spd(n: int, key: jax.Array):
    r = jax.random.normal(key, (n, n), jnp.float32) / n
    a = r + r.T + 2.0 * jnp.eye(n, dtype=jnp.float32)
    x_true = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    return a, a @ x_true


def lifetime_act(n: int, device: str) -> None:
    key = jax.random.PRNGKey(0)
    a, b = _spd(n, key)
    bn = float(jnp.linalg.norm(b))
    dev = get_device(device)
    cfg = CrossbarConfig(device=dev, geom=MCAGeometry(2, 2, 32, 32),
                         k_iters=5, ec=True)
    engine = AnalogEngine(cfg)
    A = engine.program(a, jax.random.fold_in(key, 7))   # programmed ONCE
    attach_age(A)

    def digital_rel(salt: int) -> float:
        res = cg(A, b, tol=1e-6, maxiter=120, key=jax.random.fold_in(key, salt))
        return float(jnp.linalg.norm(b - a @ res.x)) / bn

    fresh = digital_rel(11)
    # Age until ~8 cells of the image have latched under read disturb.
    mvms = max(1, int(8.0 / (dev.fault_rate * n * n)))
    A.age = A.age.advanced(mvms)
    pred = predicted_residual(dev, k_iters=cfg.k_iters, seconds=0.0,
                              mvms=mvms, n=n)
    aged = digital_rel(12)
    print(f"[lifetime] n={n} device={device}: fresh solve {fresh:.2e}, "
          f"after {mvms} MVMs aged solve {aged:.2e} "
          f"(analytic prediction {pred:.2e})")
    assert aged > fresh, "aging should visibly degrade the solve"

    report = probe_tile_scores(A, key=jax.random.fold_in(key, 13))
    print("[lifetime] per-tile probe scores (rel l2):")
    for row in np.asarray(report.scores):
        print("            " + "  ".join(f"{s:8.2e}" for s in row))

    rr = refresh_tiles(A, report.scores, RefreshPolicy(threshold=0.01),
                       key=jax.random.fold_in(key, 14))
    restored = digital_rel(15)
    print(f"[lifetime] refreshed {len(rr.tiles)}/{report.scores.size} tiles "
          f"{list(rr.tiles)}: solve {restored:.2e}, "
          f"energy {float(rr.write_stats.energy_j):.3e} J vs full reprogram "
          f"{float(rr.full_rewrite_stats.energy_j):.3e} J "
          f"({rr.energy_saving:.0%} saved)")
    assert restored <= 2.0 * fresh, (restored, fresh)
    assert float(rr.write_stats.energy_j) \
        < float(rr.full_rewrite_stats.energy_j)


def fault_act(n: int, mesh_shape) -> None:
    mesh = make_mesh(mesh_shape, ("data", "model"))
    key = jax.random.PRNGKey(2)
    a, b = _spd(n, key)
    cfg = CrossbarConfig(device=get_device("epiram"),
                         geom=MCAGeometry(2, 2, 16, 16), k_iters=5, ec=True)
    engine = AnalogEngine(cfg, execution="distributed", mesh=mesh)
    A = engine.program(a, jax.random.fold_in(key, 7))

    state = {"saved": None}

    def inject(seg, h):
        if seg == 1 and state["saved"] is None:
            state["saved"] = h.at_dense
            dense = np.array(jax.device_get(h.at_dense))
            dense[:, 5] = np.max(np.abs(dense))  # column stuck at G_on rail
            h.at_dense = jax.device_put(jnp.asarray(dense),
                                        h.at_dense.sharding)
            print("[fault]    segment 1: column 5 latched at the G_on rail")

    def repair(event, h):
        h.at_dense = state["saved"]
        print(f"[fault]    detected ({event.kind}, digital residual "
              f"{event.residual:.2e}) -> rolled back to checkpoint step "
              f"{event.restored_step}, operator repaired")

    res = ft_cg(A, b, tol=1e-4, maxiter=400, segment=25,
                key=jax.random.fold_in(key, 9), segment_hook=inject,
                on_fault=repair)
    print(f"[fault]    converged={res.converged} after {res.iterations} "
          f"accepted segments, {res.restores} restore(s), final digital "
          f"residual {res.final_residual:.2e} on "
          f"{jax.device_count()} devices")
    assert res.converged and res.restores >= 1, (res.converged, res.restores)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    # ag-si: the highest fault-rate device in the zoo -- damage shows up in
    # few MVMs, which keeps the example quick (sweep the rest via
    # benchmarks/reliability.py).
    ap.add_argument("--device", default="ag-si")
    ap.add_argument("--mesh", default="2,4", metavar="R,C")
    args = ap.parse_args()
    try:
        rows, cols = (int(v) for v in args.mesh.split(","))
    except ValueError:
        raise SystemExit(f"--mesh must be 'R,C' integers, got {args.mesh!r}")
    if rows * cols > jax.device_count():
        raise SystemExit(
            f"--mesh {rows}x{cols} needs {rows * cols} devices but only "
            f"{jax.device_count()} are available")

    lifetime_act(args.n, args.device)
    print()
    fault_act(min(args.n, 128), (rows, cols))


if __name__ == "__main__":
    main()
