#!/usr/bin/env python
"""CI regression gate: fail on *new* test failures, not pre-existing ones.

Runs pytest with the given arguments, collects failing test ids from the
junit XML, and compares them against the allowlist in
``tests/known_failures.txt`` (one ``path::testid`` per line, ``#`` comments).
Exit code is non-zero only when a failure is NOT on the allowlist, so a
known-bad test never masks a fresh regression.  Stale allowlist entries
(now passing) FAIL the gate too: an entry that lingers after its test is
fixed would silently re-tolerate the next regression of that test, so the
list must shrink the moment it can (``--allow-stale`` downgrades this to a
report for local triage runs).

With ``--coverage-xml`` the gate also reads a Cobertura XML (as written by
``pytest --cov --cov-report=xml``) and fails when any module under the
watched prefixes (default ``src/repro/solvers/``) has ZERO executed lines:
a brand-new solver module that no test imports is a contract violation of
the registry-driven suite, not a coverage-percentage judgement call.

    python tools/check_regressions.py -- -m "not slow"
    python tools/check_regressions.py --baseline tests/known_failures.txt -- -q
    python tools/check_regressions.py --coverage-xml coverage.xml -- -q \\
        --cov=repro.solvers --cov-report=xml:coverage.xml
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import xml.etree.ElementTree as ET

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_baseline(path: str) -> set:
    if not os.path.exists(path):
        return set()
    out = set()
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line and not line.startswith("#"):
                out.add(line)
    return out


def classname_to_id(cls: str, name: str, repo: str = REPO) -> str:
    """Map a junit (classname, name) pair back to a pytest node id.

    The junit ``classname`` is the dotted module path PLUS any containing
    test classes (``tests.test_x.TestFoo`` for
    ``tests/test_x.py::TestFoo::test_bar``), so blindly replacing dots with
    slashes manufactures paths like ``tests/test_x/TestFoo.py`` that can
    never match an allowlist entry.  Resolve instead by finding the longest
    dotted prefix that is an actual ``.py`` file on disk and treating the
    remaining segments as ``::``-joined class qualifiers; fall back to the
    whole-classname-is-the-module mapping when nothing exists (junit from a
    different tree).
    """
    if not cls:
        return name
    parts = cls.split(".")
    for k in range(len(parts), 0, -1):
        path = "/".join(parts[:k]) + ".py"
        if os.path.exists(os.path.join(repo, path)):
            return "::".join([path] + parts[k:] + [name])
    return "/".join(parts) + f".py::{name}"


def failed_ids(junit_path: str) -> set:
    tree = ET.parse(junit_path)
    out = set()
    for case in tree.iter("testcase"):
        if case.find("failure") is not None or case.find("error") is not None:
            out.add(classname_to_id(case.get("classname", ""),
                                    case.get("name", "")))
    return out


def uncovered_modules(coverage_xml: str, prefixes: tuple) -> list:
    """Watched-prefix modules with statements but ZERO executed lines.

    Cobertura ``filename`` attributes are relative to the coverage source
    root (``repro/solvers/x.py`` when run with ``PYTHONPATH=src``), so
    matching is on the normalized suffix of each watched prefix.
    """
    tree = ET.parse(coverage_xml)
    tails = tuple(p.replace("\\", "/").strip("/").split("src/")[-1] + "/"
                  for p in prefixes)
    out = []
    for cls in tree.iter("class"):
        fname = (cls.get("filename") or "").replace("\\", "/")
        if not any(t in fname or fname.startswith(t) for t in tails):
            continue
        lines = list(cls.iter("line"))
        if lines and all(int(ln.get("hits", "0")) == 0 for ln in lines):
            out.append(fname)
    return sorted(set(out))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=os.path.join(REPO, "tests", "known_failures.txt"))
    ap.add_argument("--allow-stale", action="store_true",
                    help="report stale allowlist entries without failing "
                         "(local triage); CI keeps the default hard gate")
    ap.add_argument("--coverage-xml", default=None,
                    help="Cobertura XML from the pytest run; enables the "
                         "zero-coverage module gate")
    ap.add_argument("--coverage-watch", action="append", default=None,
                    metavar="PREFIX",
                    help="source prefix the zero-coverage gate watches "
                         "(repeatable; default src/repro/solvers/)")
    ap.add_argument("pytest_args", nargs="*",
                    help="arguments forwarded to pytest (after --)")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as tmp:
        junit = os.path.join(tmp, "junit.xml")
        cmd = [sys.executable, "-m", "pytest", f"--junitxml={junit}",
               *args.pytest_args]
        print("+", " ".join(cmd), flush=True)
        proc = subprocess.run(cmd, cwd=REPO)
        if not os.path.exists(junit):
            print("check_regressions: pytest produced no junit xml "
                  f"(exit {proc.returncode})")
            return proc.returncode or 1
        failures = failed_ids(junit)
        # Exit codes other than 0 (all passed) / 1 (some tests failed) mean
        # the run itself is unusable -- no tests collected (5), usage error
        # (4), internal error (3), interrupted (2).  A failure-free junit
        # from such a run must NOT turn CI green.
        if proc.returncode not in (0, 1):
            print(f"check_regressions: pytest exit {proc.returncode} "
                  "(not a pass/fail outcome) -- propagating.")
            return proc.returncode

    known = load_baseline(args.baseline)
    new = sorted(f for f in failures if f not in known)
    stale = sorted(k for k in known if k not in failures)
    expected = sorted(f for f in failures if f in known)

    rc = 0
    if expected:
        print(f"\n{len(expected)} known failure(s) (allowlisted):")
        for f in expected:
            print(f"  KNOWN {f}")
    if stale:
        print(f"\n{len(stale)} allowlist entr(ies) now pass -- prune "
              f"{args.baseline}:")
        for f in stale:
            print(f"  STALE {f}")
        if not args.allow_stale:
            print("stale entries fail the gate (a lingering entry would "
                  "mask that test's NEXT regression); prune the list or "
                  "pass --allow-stale for local triage.")
            rc = 1
    if new:
        print(f"\n{len(new)} NEW failure(s):")
        for f in new:
            print(f"  NEW   {f}")
        rc = 1

    if args.coverage_xml:
        if not os.path.exists(args.coverage_xml):
            print(f"\ncheck_regressions: --coverage-xml "
                  f"{args.coverage_xml} was not produced by the run.")
            rc = rc or 1
        else:
            watch = tuple(args.coverage_watch or ("src/repro/solvers/",))
            dead = uncovered_modules(args.coverage_xml, watch)
            if dead:
                print(f"\n{len(dead)} watched module(s) with ZERO covered "
                      "lines (no test imports them):")
                for f in dead:
                    print(f"  UNCOVERED {f}")
                rc = 1
            else:
                print(f"\ncoverage gate: no zero-coverage modules under "
                      f"{', '.join(watch)}.")

    if rc == 0:
        print("\ncheck_regressions: no new failures.")
    return rc


if __name__ == "__main__":
    sys.exit(main())
